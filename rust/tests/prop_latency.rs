//! Property suite for the mergeable log-linear latency histograms
//! behind the fleet fold ([`ips::metrics::LatencyStats`]):
//!
//! 1. **merge ≡ concatenation** — merging two histograms is
//!    indistinguishable from recording both streams into one collector
//!    (bucket-exact, so the fleet fold is associative and
//!    order-independent);
//! 2. **bounded quantile error** — every histogram percentile brackets
//!    the exact rank statistic from below within the configured
//!    relative-error bound, and never escapes the observed `[min, max]`
//!    range (the PR-7 clamp bugfix, generalized);
//! 3. **sharded fold ≡ serial record** — round-robin sharding a stream
//!    over k collectors and merging them back reproduces the serial
//!    collector byte for byte (the serial-vs-parallel fleet invariant
//!    at the data-structure level).
//!
//! Failures shrink to a minimal sample vector.

use ips::metrics::LatencyStats;
use ips::util::prop::{self, one_of, tuple2, u64_up_to, vec_of};

/// Quantile grid the properties sweep (endpoints included).
const Q_GRID: [f64; 6] = [0.0, 0.25, 0.5, 0.9, 0.99, 1.0];

/// Sample span: ns values from the sub-microsecond linear region up to
/// tens of seconds, so draws cross many power-of-two bands.
const MAX_NS: u64 = 50_000_000_000;

fn record_all(sub: u32, samples: &[u64]) -> LatencyStats {
    let mut s = LatencyStats::with_resolution(sub, 0);
    for &v in samples {
        s.record(v);
    }
    s
}

/// Exact rank-`q` statistic (the oracle the histogram approximates).
fn exact_percentile(sorted: &[u64], q: f64) -> u64 {
    let target = ((q * sorted.len() as f64).ceil() as usize).max(1);
    sorted[target.min(sorted.len()) - 1]
}

fn same_moments(a: &LatencyStats, b: &LatencyStats) -> Result<(), String> {
    if a.count() != b.count() {
        return Err(format!("count {} != {}", a.count(), b.count()));
    }
    if a.min() != b.min() || a.max() != b.max() {
        return Err(format!(
            "range [{}, {}] != [{}, {}]",
            a.min(),
            a.max(),
            b.min(),
            b.max()
        ));
    }
    // equal sums and counts -> bit-identical means
    if a.mean().to_bits() != b.mean().to_bits() {
        return Err(format!("mean {} != {}", a.mean(), b.mean()));
    }
    if a.bucket_counts() != b.bucket_counts() {
        return Err("bucket counts diverge".into());
    }
    for q in Q_GRID {
        if a.percentile(q) != b.percentile(q) {
            return Err(format!("p{q}: {} != {}", a.percentile(q), b.percentile(q)));
        }
    }
    Ok(())
}

#[test]
fn merge_is_concatenation() {
    let gen = tuple2(
        one_of(vec![2u32, 8, 64, 256]),
        tuple2(vec_of(u64_up_to(MAX_NS), 0, 64), vec_of(u64_up_to(MAX_NS), 0, 64)),
    );
    prop::check("merge == concatenated stream", 256, gen, |&(sub, (ref xs, ref ys))| {
        let mut merged = record_all(sub, xs);
        merged.merge(&record_all(sub, ys));
        let mut both = xs.clone();
        both.extend_from_slice(ys);
        same_moments(&merged, &record_all(sub, &both))
    });
}

#[test]
fn percentiles_bracket_the_exact_rank_within_bound() {
    let gen = tuple2(one_of(vec![2u32, 8, 64, 256]), vec_of(u64_up_to(MAX_NS), 1, 96));
    prop::check("quantile error is bounded", 256, gen, |&(sub, ref xs)| {
        let s = record_all(sub, xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        let eps = s.relative_error_bound();
        for q in Q_GRID {
            let exact = exact_percentile(&sorted, q);
            let approx = s.percentile(q);
            if approx < exact {
                return Err(format!("p{q}: approx {approx} below exact {exact}"));
            }
            let bound = exact + (exact as f64 * eps) as u64 + 1;
            if approx > bound {
                return Err(format!(
                    "p{q}: approx {approx} exceeds exact {exact} + {:.1}% bound {bound}",
                    eps * 100.0
                ));
            }
            if approx > s.max() || approx < s.min() {
                return Err(format!(
                    "p{q}: {approx} escapes observed [{}, {}]",
                    s.min(),
                    s.max()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_fold_matches_serial_record() {
    let gen = tuple2(
        tuple2(one_of(vec![2u32, 64]), u64_up_to(7)),
        vec_of(u64_up_to(MAX_NS), 0, 128),
    );
    prop::check("k-way shard + merge == serial", 256, gen, |&((sub, k), ref xs)| {
        let shards = k as usize + 1;
        let mut parts: Vec<LatencyStats> =
            (0..shards).map(|_| LatencyStats::with_resolution(sub, 0)).collect();
        for (i, &v) in xs.iter().enumerate() {
            parts[i % shards].record(v);
        }
        let mut folded = LatencyStats::with_resolution(sub, 0);
        for p in &parts {
            folded.merge(p);
        }
        same_moments(&folded, &record_all(sub, xs))
    });
}
