//! System-level property tests for the multi-tenant front end:
//! merging/interleaving per-tenant traces must preserve each tenant's
//! op order and global arrival-time monotonicity, and a full
//! multi-tenant run must conserve the attribution ledger no matter the
//! scheduler or mix.

use ips::config::{presets, MixKind, SchedKind, Scheme};
use ips::host::{merge_traces, MultiTenantSimulator, TenantId};
use ips::metrics::Ledger;
use ips::trace::scenario::Scenario;
use ips::trace::{OpKind, Trace, TraceOp};
use ips::util::prop::{self, usize_in, vec_of, Gen};

/// Generator of per-tenant op lists: for each tenant, a list of
/// (gap, len-pages, is-read) triples turned into a monotone trace.
struct TenantTraceGen;

impl Gen for TenantTraceGen {
    type Value = Vec<Vec<(u32, u8, bool)>>;
    fn gen(&self, rng: &mut ips::util::rng::Rng) -> Self::Value {
        let tenants = rng.range(1, 6) as usize;
        (0..tenants)
            .map(|_| {
                let n = rng.range(0, 40) as usize;
                (0..n)
                    .map(|_| {
                        (
                            rng.below(1_000_000) as u32,
                            rng.range(1, 8) as u8,
                            rng.chance(0.3),
                        )
                    })
                    .collect()
            })
            .collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() - 1].to_vec());
        }
        for (i, ops) in v.iter().enumerate() {
            if !ops.is_empty() {
                let mut w = v.clone();
                w[i] = ops[..ops.len() / 2].to_vec();
                out.push(w);
            }
        }
        out
    }
}

fn build_traces(spec: &[Vec<(u32, u8, bool)>]) -> Vec<Trace> {
    spec.iter()
        .enumerate()
        .map(|(ti, ops)| {
            let mut at = 0u64;
            let mut trace = Trace { name: format!("t{ti}"), ops: Vec::new() };
            for (i, &(gap, pages, is_read)) in ops.iter().enumerate() {
                at += gap as u64;
                trace.ops.push(TraceOp {
                    at,
                    kind: if is_read { OpKind::Read } else { OpKind::Write },
                    offset: (i as u64) * 4096,
                    len: pages as u32 * 4096,
                });
            }
            trace
        })
        .collect()
}

#[test]
fn merge_preserves_per_tenant_order_and_monotonicity() {
    prop::check("multi-tenant merge", 256, TenantTraceGen, |spec| {
        let traces = build_traces(spec);
        let merged = merge_traces(&traces);
        // 1. global arrival-time monotonicity
        for w in merged.windows(2) {
            if w[0].op.at > w[1].op.at {
                return Err(format!(
                    "arrival order violated: {} then {}",
                    w[0].op.at, w[1].op.at
                ));
            }
        }
        // 2. per-tenant subsequences are exactly the input traces
        for (ti, t) in traces.iter().enumerate() {
            let sub: Vec<TraceOp> = merged
                .iter()
                .filter(|x| x.tenant == TenantId(ti as u16))
                .map(|x| x.op)
                .collect();
            if sub != t.ops {
                return Err(format!("tenant {ti} op order changed"));
            }
        }
        // 3. nothing lost, nothing invented
        let total: usize = traces.iter().map(|t| t.ops.len()).sum();
        if merged.len() != total {
            return Err(format!("{} ops in, {} out", total, merged.len()));
        }
        Ok(())
    });
}

#[test]
fn merged_arrival_ties_break_by_tenant_id() {
    // all ops at t=0: the merge must interleave tenant-by-tenant in id
    // order, each tenant's block keeping its own order
    let spec: Vec<Vec<(u32, u8, bool)>> = vec![vec![(0, 1, false); 3]; 4];
    let traces = build_traces(&spec);
    let merged = merge_traces(&traces);
    let tenants: Vec<u16> = merged.iter().map(|x| x.tenant.0).collect();
    let mut expect = Vec::new();
    for t in 0..4u16 {
        expect.extend(std::iter::repeat(t).take(3));
    }
    assert_eq!(tenants, expect);
}

/// Full-engine property: for random small (scheme, scheduler, mix)
/// draws, the run conserves attribution (tenants + background equals
/// the device ledger) and per-tenant request counts match the traces.
#[test]
fn random_mt_runs_conserve_attribution() {
    let schemes = [Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc];
    let scheds = SchedKind::all();
    let mixes = MixKind::all();
    prop::check(
        "mt attribution conservation",
        12,
        vec_of(usize_in(0, 1000), 3, 3),
        |draw| {
            let scheme = schemes[draw[0] % schemes.len()];
            let sched = scheds[draw[1] % scheds.len()];
            let mix = mixes[draw[2] % mixes.len()];
            let mut cfg = presets::small();
            cfg.cache.scheme = scheme;
            cfg.cache.slc_cache_bytes = 1 << 20;
            cfg.host.tenants = 3;
            cfg.host.scheduler = sched;
            cfg.host.mix = mix;
            cfg.host.aggressor_cache_mult = 1.5;
            cfg.sim.verify = true;
            cfg.sim.seed = (draw[0] * 31 + draw[1] * 7 + draw[2]) as u64;
            let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty)
                .map_err(|e| format!("{scheme:?}/{sched:?}/{mix:?}: {e}"))?;
            let mut sum = Ledger::default();
            for t in &s.tenants {
                sum.merge(&t.ledger);
            }
            sum.merge(&s.background);
            if sum != s.ledger {
                return Err(format!(
                    "{scheme:?}/{sched:?}/{mix:?}: attribution leak: {sum:?} != {:?}",
                    s.ledger
                ));
            }
            if s.write_latency.count() == 0 {
                return Err("no writes served".into());
            }
            Ok(())
        },
    );
}
