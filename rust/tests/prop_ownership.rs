//! Property tests for the exact per-tenant page-ownership machinery
//! (`ftl::owner`), with shrinking on the generated op scripts:
//!
//! * **owner-tag conservation** — after arbitrary interleavings of
//!   host writes, overwrites, GC, reprogram conversion, and idle-time
//!   reclamation, every valid page has exactly one owner, and that
//!   owner is the tenant whose logical band the page's LPN falls in
//!   (tenants own disjoint LPN bands, so the map is the oracle);
//! * **residency accounting** — per tenant, pages charged (SLC cache
//!   writes) minus pages released (residency-exit events) equals a
//!   physical scan of the valid SLC-resident pages the tenant owns;
//! * **engine closure** — full multi-tenant runs under owner
//!   attribution still conserve the attribution ledger, and the
//!   partitioner's per-tenant occupancy equals the physical scan:
//!   Σ per-tenant tagged SLC pages == partitioner occupancy.

use ips::cache::{baseline::Baseline, ips::Ips, CachePolicy};
use ips::config::{presets, AttributionMode, Config, MixKind, SchedKind, Scheme};
use ips::flash::{BlockAddr, Lpn, PageKind, PlaneId};
use ips::ftl::Ftl;
use ips::host::MultiTenantSimulator;
use ips::metrics::Ledger;
use ips::trace::scenario::Scenario;
use ips::util::prop::{self, Gen};
use ips::util::rng::Rng;

/// Width of each tenant's private LPN band (the ownership oracle).
const BAND: u64 = 1000;

/// A generated FTL-level exercise: a scheme, a tenant count, and a
/// script of (selector, offset) pairs decoded into per-tenant writes,
/// overwrites, direct TLC writes, and idle windows.
#[derive(Clone, Debug)]
struct OwnershipScript {
    scheme: Scheme,
    tenants: usize,
    ops: Vec<(u64, u64)>,
}

struct OwnershipGen;

impl Gen for OwnershipGen {
    type Value = OwnershipScript;
    fn gen(&self, rng: &mut Rng) -> OwnershipScript {
        OwnershipScript {
            scheme: if rng.chance(0.5) { Scheme::Ips } else { Scheme::Baseline },
            tenants: rng.range(1, 4) as usize,
            ops: (0..rng.range(0, 280) as usize)
                .map(|_| (rng.below(1 << 16), rng.below(BAND / 2)))
                .collect(),
        }
    }
    fn shrink(&self, v: &OwnershipScript) -> Vec<OwnershipScript> {
        let mut out = Vec::new();
        if !v.ops.is_empty() {
            let mut w = v.clone();
            w.ops.truncate(v.ops.len() / 2);
            out.push(w);
            let mut w = v.clone();
            w.ops.pop();
            out.push(w);
            let mut w = v.clone();
            w.ops.remove(0);
            out.push(w);
        }
        if v.tenants > 1 {
            let mut w = v.clone();
            w.tenants -= 1;
            out.push(w);
        }
        out
    }
}

fn script_cfg(scheme: Scheme) -> Config {
    let mut cfg = presets::small();
    cfg.cache.scheme = scheme;
    // shrink both cache flavours so ~300-op scripts reach the
    // post-exhaustion paths (reprogram conversion / the TLC cliff)
    cfg.cache.slc_cache_bytes = 128 << 10; // 32 SLC pages (baseline)
    cfg.cache.ips_block_fraction = 0.05; // 3 blocks/plane of IPS window
    cfg
}

/// Physical scan: valid SLC-resident pages owned by `t`.
fn slc_resident_owned(ftl: &Ftl, t: u16) -> u64 {
    let g = *ftl.array.geometry();
    let mut count = 0u64;
    for p in 0..g.planes() {
        for b in 0..g.blocks_per_plane {
            let addr = BlockAddr { plane: PlaneId(p), block: b };
            let blk = ftl.array.block(addr);
            for pib in blk.valid_pages() {
                if blk.page_kind(pib) == PageKind::Slc
                    && ftl.owner_of(addr.page(&g, pib / 3, (pib % 3) as u8)) == Some(t)
                {
                    count += 1;
                }
            }
        }
    }
    count
}

#[test]
fn owner_tags_conserve_and_residency_matches_charges() {
    prop::check("owner-tag conservation", 48, OwnershipGen, |script| {
        let cfg = script_cfg(script.scheme);
        let mut ftl = Ftl::new(&cfg).map_err(|e| e.to_string())?;
        ftl.set_tenant_count(script.tenants);
        let mut policy: Box<dyn CachePolicy> = match script.scheme {
            Scheme::Ips => Box::new(Ips::new(&cfg)),
            _ => Box::new(Baseline::new(&cfg)),
        };
        policy.init(&mut ftl).map_err(|e| e.to_string())?;
        let mut charged = vec![0u64; script.tenants];
        let mut released = vec![0u64; script.tenants];
        let mut now = 0u64;
        for &(sel, off) in &script.ops {
            let t = (sel % script.tenants as u64) as usize;
            let lpn = Lpn(t as u64 * BAND + off);
            let before = ftl.ledger;
            match (sel >> 4) % 8 {
                // mostly cache-path writes (fresh or overwriting)
                0..=5 => {
                    ftl.set_tenant(Some(t as u16));
                    ftl.ledger.host_page();
                    let c = policy
                        .host_write_page(&mut ftl, lpn, now)
                        .map_err(|e| e.to_string())?;
                    now = now.max(c.end);
                }
                // a direct TLC write (bypasses the cache)
                6 => {
                    ftl.set_tenant(Some(t as u16));
                    ftl.ledger.host_page();
                    let c = ftl.host_write_tlc(lpn, now).map_err(|e| e.to_string())?;
                    now = now.max(c.end);
                }
                // an idle window (baseline reclamation; IPS no-op)
                _ => {
                    ftl.set_tenant(None);
                    now = policy
                        .idle_work(&mut ftl, now, now + 2_000_000_000)
                        .map_err(|e| e.to_string())?;
                }
            }
            ftl.set_tenant(None);
            let diff = ftl.ledger.diff(&before);
            charged[t] += diff.slc_cache_writes;
            let ev = ftl.take_owner_events();
            if ev.released_unowned != 0 {
                return Err(format!(
                    "{} unowned releases — every page was written with a tenant context",
                    ev.released_unowned
                ));
            }
            for (i, &r) in ev.released.iter().enumerate() {
                released[i] += r;
            }
            if ftl.tagged_pages() > ftl.map.live() {
                return Err(format!(
                    "{} tags > {} mapped pages",
                    ftl.tagged_pages(),
                    ftl.map.live()
                ));
            }
        }
        // exactly one owner per valid page, and it matches the oracle
        if ftl.tagged_pages() != ftl.map.live() {
            return Err(format!(
                "tagged {} != mapped {} (a valid page lost or never got its owner)",
                ftl.tagged_pages(),
                ftl.map.live()
            ));
        }
        for (lpn, ppa) in ftl.map.iter_mapped() {
            let want = (lpn.0 / BAND) as u16;
            let got = ftl.owner_of(ppa);
            if got != Some(want) {
                return Err(format!("{lpn:?} at {ppa:?}: owner {got:?} != band {want}"));
            }
        }
        // residency closure: charged − released == physical residency
        for t in 0..script.tenants {
            let resident = slc_resident_owned(&ftl, t as u16);
            if charged[t] < released[t] || charged[t] - released[t] != resident {
                return Err(format!(
                    "tenant {t}: charged {} − released {} != {} resident SLC pages",
                    charged[t], released[t], resident
                ));
            }
        }
        ftl.audit().map_err(|e| e.to_string())?;
        Ok(())
    });
}

/// Full-engine property: random (scheme, scheduler, mix) cells under
/// owner attribution + partitioning conserve the attribution ledger,
/// and the partitioner's occupancy equals the owner-tag scan.
#[test]
fn owner_attribution_runs_close_and_occupancy_is_exact() {
    let schemes = Scheme::all();
    let scheds = SchedKind::all();
    let mixes = MixKind::all();
    prop::check(
        "owner attribution closure",
        8,
        prop::vec_of(prop::usize_in(0, 1000), 3, 3),
        |draw| {
            let scheme = schemes[draw[0] % schemes.len()];
            let sched = scheds[draw[1] % scheds.len()];
            let mix = mixes[draw[2] % mixes.len()];
            let mut cfg = presets::small();
            cfg.cache.scheme = scheme;
            cfg.cache.slc_cache_bytes = 1 << 20;
            cfg.host.tenants = 3;
            cfg.host.scheduler = sched;
            cfg.host.mix = mix;
            cfg.host.aggressor_cache_mult = 1.5;
            cfg.host.attribution = AttributionMode::Owner;
            cfg.cache.partition.enabled = true;
            cfg.cache.partition.reserved_frac = 0.6;
            cfg.sim.verify = true;
            cfg.sim.seed = (draw[0] * 31 + draw[1] * 7 + draw[2]) as u64;
            let mut sim = MultiTenantSimulator::new(cfg)
                .map_err(|e| format!("{scheme:?}/{sched:?}/{mix:?}: {e}"))?;
            let s = sim
                .run(Scenario::Bursty)
                .map_err(|e| format!("{scheme:?}/{sched:?}/{mix:?}: {e}"))?;
            // attribution closure survives owner re-attribution
            let mut sum = Ledger::default();
            for t in &s.tenants {
                sum.merge(&t.ledger);
            }
            sum.merge(&s.background);
            if sum != s.ledger {
                return Err(format!("{scheme:?}/{sched:?}/{mix:?}: attribution leak"));
            }
            if s.attribution != "owner" {
                return Err(format!("mislabelled run: {}", s.attribution));
            }
            // Σ per-tenant tagged SLC pages == partitioner occupancy
            let part = sim.partitioner();
            if part.enabled() {
                for t in 0..3u16 {
                    let occ = part.occupancy(t as usize);
                    let resident = slc_resident_owned(sim.ftl(), t);
                    if occ != resident {
                        return Err(format!(
                            "{scheme:?}/{sched:?}/{mix:?}: tenant {t} occupancy {occ} != \
                             {resident} tagged SLC-resident pages"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
