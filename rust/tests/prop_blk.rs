//! Property suite for the block front end (`ips::blk`).
//!
//! Two invariants, each checked against a first-principles oracle that
//! never calls into the planner's own bookkeeping:
//!
//! 1. **Sector conservation**: for any scatter-gather payload and any
//!    merge window, the union of the plan's per-page coverage bitmaps
//!    is exactly the input sector set — no sector lost, none claimed
//!    twice, no coverage bit outside the page.
//! 2. **RMW conservation through the FTL**: driving the planned bios
//!    through a real [`ips::sim::Simulator`], the FTL observes exactly
//!    one host page per planned piece and exactly one pre-read per
//!    partially-covered page (counted straight off the raw sector set).
//!
//! Failures shrink to a minimal segment list via the hand-rolled
//! `ips::util::prop` runner (seed from `IPS_PROP_SEED`).

use ips::blk::{self, Bio, Segment};
use ips::config::{presets, BlkConfig, Scheme};
use ips::sim::Simulator;
use ips::trace::scenario::Scenario;
use ips::util::prop::{self, tuple2, u64_up_to, vec_of, Gen};
use ips::util::rng::Rng;
use std::collections::{BTreeMap, BTreeSet};

const SECTOR: u32 = 512;
const PAGE: u64 = 4096;
const SPP: u64 = PAGE / SECTOR as u64; // sectors per page

/// Disjoint, ascending `(sector, n_sectors)` runs — one scatter-gather
/// payload. Lengths up to 96 sectors so single segments span many
/// pages; gaps up to 48 so pieces sometimes revisit a page boundary.
struct SegListGen;

impl Gen for SegListGen {
    type Value = Vec<(u64, u32)>;
    fn gen(&self, rng: &mut Rng) -> Self::Value {
        let n = 1 + rng.below(6) as usize;
        let mut segs = Vec::with_capacity(n);
        let mut cursor = rng.below(64);
        for _ in 0..n {
            let start = cursor + rng.below(48);
            let len = 1 + rng.below(96) as u32;
            segs.push((start, len));
            cursor = start + len as u64;
        }
        segs
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > 1 {
            for i in 0..v.len() {
                let mut c = v.clone();
                c.remove(i);
                out.push(c);
            }
        }
        for i in 0..v.len() {
            if v[i].1 > 1 {
                let mut c = v.clone();
                c[i].1 /= 2;
                out.push(c);
            }
        }
        out
    }
}

fn segments(segs: &[(u64, u32)]) -> Vec<Segment> {
    segs.iter().map(|&(sector, n_sectors)| Segment { sector, n_sectors }).collect()
}

fn sector_set(segs: &[(u64, u32)]) -> BTreeSet<u64> {
    let mut set = BTreeSet::new();
    for &(start, n) in segs {
        for s in start..start + n as u64 {
            set.insert(s);
        }
    }
    set
}

fn blk_cfg(merge_window: u32) -> BlkConfig {
    BlkConfig {
        enabled: true,
        sector_bytes: SECTOR,
        merge_window,
        rmw: true,
        flush_every: 0,
        fua: false,
    }
}

#[test]
fn split_merge_preserves_the_exact_sector_set() {
    prop::check(
        "split/merge sector conservation",
        400,
        tuple2(SegListGen, u64_up_to(16)),
        |(segs, window)| {
            let cfg = blk_cfg(*window as u32);
            let bio = Bio::write(0, segments(segs), false);
            let plan = blk::plan(&bio, &cfg, PAGE);
            let want = sector_set(segs);
            let full = blk::full_mask(SPP as u32);
            let mut got = BTreeSet::new();
            let mut claimed = 0u64;
            for io in &plan.pages {
                if io.coverage == 0 {
                    return Err(format!("page {} planned with empty coverage", io.page));
                }
                if io.coverage & !full != 0 {
                    return Err(format!(
                        "page {} coverage {:#x} spills past the page",
                        io.page, io.coverage
                    ));
                }
                claimed += io.coverage.count_ones() as u64;
                for bit in 0..SPP {
                    if io.coverage & (1 << bit) != 0 {
                        got.insert(io.page * SPP + bit);
                    }
                }
            }
            if got != want {
                return Err(format!(
                    "sector set changed: planned {} sectors, input had {}",
                    got.len(),
                    want.len()
                ));
            }
            if claimed != want.len() as u64 {
                return Err(format!(
                    "sectors claimed twice: {claimed} coverage bits for {} sectors",
                    want.len()
                ));
            }
            // a read of the same payload plans the same pages but must
            // never schedule an RMW pre-read
            let rplan = blk::plan(&Bio::read(0, segments(segs)), &cfg, PAGE);
            if rplan.pages.iter().any(|p| p.pre_read) {
                return Err("read planned a pre-read".into());
            }
            if rplan.rmw_reads != 0 {
                return Err("read counted RMW".into());
            }
            Ok(())
        },
    );
}

#[test]
fn rmw_conservation_holds_through_the_ftl() {
    prop::check(
        "host pages + RMW pre-reads match the raw sector sets",
        60,
        vec_of(SegListGen, 1, 5),
        |payloads: &Vec<Vec<(u64, u32)>>| {
            // oracle, straight off the raw sectors: one host page per
            // distinct page per bio, one pre-read per partial page
            let mut want_pages = 0u64;
            let mut want_rmw = 0u64;
            for segs in payloads {
                let mut per_page: BTreeMap<u64, u64> = BTreeMap::new();
                for s in sector_set(segs) {
                    *per_page.entry(s / SPP).or_default() += 1;
                }
                want_pages += per_page.len() as u64;
                want_rmw += per_page.values().filter(|&&n| n < SPP).count() as u64;
            }
            let mut cfg = presets::small();
            cfg.cache.scheme = Scheme::Ips;
            cfg.blk = blk_cfg(64); // window wide enough to coalesce every revisit
            let mut sim = Simulator::new(cfg).map_err(|e| e.to_string())?;
            let bios = payloads
                .iter()
                .enumerate()
                .map(|(i, segs)| Ok(Bio::write(i as u64 * 1_000_000, segments(segs), false)));
            let s = sim.run_bios("prop", bios, Scenario::Bursty).map_err(|e| e.to_string())?;
            if s.ledger.host_pages != want_pages {
                return Err(format!(
                    "FTL saw {} host pages, sectors say {want_pages}",
                    s.ledger.host_pages
                ));
            }
            if s.blk.write_pages != want_pages {
                return Err(format!(
                    "front end counted {} write pages, sectors say {want_pages}",
                    s.blk.write_pages
                ));
            }
            if s.ledger.host_reads != want_rmw {
                return Err(format!(
                    "FTL saw {} pre-reads, partial pages say {want_rmw}",
                    s.ledger.host_reads
                ));
            }
            if s.blk.rmw_reads != want_rmw {
                return Err(format!(
                    "front end counted {} RMW reads, partial pages say {want_rmw}",
                    s.blk.rmw_reads
                ));
            }
            Ok(())
        },
    );
}
