//! Cross-module integration for the multi-tenant host front end:
//! every scheme serves the aggressor+victims mix, per-tenant metrics
//! are complete, cross-tenant interference orders Baseline vs IPS the
//! way the paper's cliff analysis predicts, and the fleet runner is
//! thread-count-invariant.

use ips::config::{MixKind, SchedKind, Scheme};
use ips::coordinator::fleet::{run_fleet, summary_table, tenant_table, FleetSpec};
use ips::host::MultiTenantSimulator;
use ips::metrics::Ledger;
use ips::trace::scenario::Scenario;

fn mt_cfg(scheme: Scheme, sched: SchedKind) -> ips::config::Config {
    let mut cfg = ips::config::presets::small();
    cfg.cache.scheme = scheme;
    cfg.cache.slc_cache_bytes = 1 << 20;
    cfg.host.tenants = 4; // 1 aggressor + 3 victims
    cfg.host.scheduler = sched;
    cfg.host.mix = MixKind::AggressorVictims;
    cfg.host.aggressor_cache_mult = 4.0; // well past the cliff
    cfg.host.victim_req_bytes = 4096; // single-page, latency-sensitive
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000; // exact percentiles
    cfg
}

#[test]
fn all_five_schemes_serve_four_tenants() {
    for scheme in Scheme::all() {
        let cfg = mt_cfg(scheme, SchedKind::Fifo);
        let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty)
            .unwrap_or_else(|e| panic!("{scheme:?} failed: {e}"));
        assert_eq!(s.scheme, scheme.name());
        assert_eq!(s.tenants.len(), 4);
        // per-tenant p50/p99 and WA are all reportable
        for t in &s.tenants {
            assert!(t.write_latency.count() > 0, "{}: {} served", s.scheme, t.name);
            assert!(t.p50_write_latency() > 0, "{}: {} p50", s.scheme, t.name);
            assert!(
                t.p99_write_latency() >= t.p50_write_latency(),
                "{}: {} p99 >= p50",
                s.scheme,
                t.name
            );
            assert!(t.wa() >= 1.0 - 1e-9, "{}: {} WA sane: {}", s.scheme, t.name, t.wa());
        }
        // attribution closes exactly
        let mut sum = Ledger::default();
        for t in &s.tenants {
            sum.merge(&t.ledger);
        }
        sum.merge(&s.background);
        assert_eq!(sum, s.ledger, "{}: tenants + background == device", s.scheme);
        // the detail table renders every tenant plus device/background rows
        assert_eq!(tenant_table(&s).len(), 4 + 2);
    }
}

#[test]
fn aggressor_cliff_inflates_victim_p99_more_under_baseline_than_ips() {
    let run = |scheme| {
        let cfg = mt_cfg(scheme, SchedKind::Fifo);
        MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
    };
    let base = run(Scheme::Baseline);
    let ips = run(Scheme::Ips);
    // same mix shape either way: an aggressor and three victims
    assert!(base.tenant("aggressor").is_some() && base.tenant("victim-1").is_some());
    let base_p99 = base.max_victim_p99();
    let ips_p99 = ips.max_victim_p99();
    assert!(
        base_p99 > ips_p99,
        "victims inherit the baseline cliff: baseline p99 {} ns vs ips p99 {} ns",
        base_p99,
        ips_p99
    );
    // the victims' own writes are small and paced — the tail comes from
    // waiting behind the aggressor, i.e. the neighbour's cliff
    let victim_bytes: u64 = base
        .tenants
        .iter()
        .filter(|t| t.name.starts_with("victim"))
        .map(|t| t.host_bytes_written)
        .sum();
    assert!(victim_bytes * 2 < base.tenants[0].host_bytes_written, "aggressor dominates load");
}

#[test]
fn schedulers_shift_tail_latency_between_tenants() {
    let run = |sched| {
        let cfg = mt_cfg(Scheme::Baseline, sched);
        MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
    };
    let fifo = run(SchedKind::Fifo);
    let rr = run(SchedKind::RoundRobin);
    let wfq = run(SchedKind::WeightedFair);
    // identical offered load across schedulers
    assert_eq!(fifo.host_bytes_written, rr.host_bytes_written);
    assert_eq!(fifo.host_bytes_written, wfq.host_bytes_written);
    // fair schedulers protect the victims at least as well as FIFO
    assert!(rr.max_victim_p99() <= fifo.max_victim_p99());
    assert!(wfq.max_victim_p99() <= fifo.max_victim_p99());
}

#[test]
fn fleet_sweep_is_thread_count_invariant() {
    let spec = |threads| FleetSpec {
        base: {
            let mut b = mt_cfg(Scheme::Baseline, SchedKind::Fifo);
            b.host.aggressor_cache_mult = 2.0; // keep the sweep fast
            b
        },
        schemes: vec![Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc],
        scheds: vec![SchedKind::Fifo, SchedKind::RoundRobin],
        mixes: vec![MixKind::AggressorVictims],
        variants: vec![ips::coordinator::fleet::IsolationVariant::Shared],
        attributions: vec![ips::config::AttributionMode::Proportional],
        scenario: Scenario::Bursty,
        seed: 1234,
        threads,
    };
    let serial = run_fleet(&spec(1)).unwrap();
    let parallel = run_fleet(&spec(8)).unwrap();
    assert_eq!(serial.len(), 6);
    let a = summary_table(&serial).render();
    let b = summary_table(&parallel).render();
    assert_eq!(a, b, "byte-identical summaries regardless of thread count");
    // per-run seeds are deterministic and distinct
    let mut seeds: Vec<u64> = serial.iter().map(|s| s.seed).collect();
    seeds.sort_unstable();
    seeds.dedup();
    assert_eq!(seeds.len(), 6);
}

#[test]
fn daily_scenario_runs_idle_work_between_tenant_streams() {
    // Uniform paced streams under the daily scenario: the baseline's
    // idle-time reclamation shows up as background (unattributed) work.
    let mut cfg = mt_cfg(Scheme::Baseline, SchedKind::RoundRobin);
    cfg.host.mix = MixKind::Uniform;
    cfg.cache.idle_threshold = ips::config::MS;
    let s = MultiTenantSimulator::run_once(cfg, Scenario::Daily).unwrap();
    assert!(s.host_bytes_written > 0);
    // flush/idle reclamation happened and is attributed to no tenant
    assert!(
        s.background.slc2tlc_migrations > 0,
        "baseline reclamation is background work: {:?}",
        s.background
    );
    for t in &s.tenants {
        assert_eq!(t.ledger.slc2tlc_migrations, 0, "{} never charged for reclamation", t.name);
    }
}
