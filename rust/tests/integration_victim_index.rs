//! Differential guarantee for the victim-selection index: with
//! `sim.victim_index` on vs off, every scheme must produce **byte
//! identical** run summaries — ledger counters, latency statistics
//! (counts, means, percentiles, raw samples), WA, simulated end time —
//! on bursty and daily scenarios, single- and multi-tenant, under both
//! attribution modes. The index is a pure performance change; any
//! divergence is a bug.

use ips::config::{presets, AttributionMode, Config, MixKind, SchedKind, Scheme, MS, SEC};
use ips::host::{MultiTenantSimulator, MultiTenantSummary};
use ips::metrics::RunSummary;
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};

fn single_cfg(scheme: Scheme, index: bool) -> Config {
    let mut c = presets::small();
    c.cache.scheme = scheme;
    c.cache.slc_cache_bytes = 1 << 20;
    c.cache.idle_threshold = 10 * MS;
    c.sim.verify = true; // audits the index against a fresh rescan
    c.sim.latency_samples = 4096;
    c.sim.victim_index = index;
    c
}

fn run_single(scheme: Scheme, scen: Scenario, index: bool) -> RunSummary {
    let mut sim = Simulator::new(single_cfg(scheme, index)).unwrap();
    let trace = match scen {
        // 4× the cache: over the cliff, GC-heavy
        Scenario::Bursty => scenario::sequential_fill("seq", 4 << 20, sim.logical_bytes()),
        // idle gaps drive reclamation / AGC / coop background pipelines
        Scenario::Daily => scenario::daily_streams(3, 1 << 20, 60 * SEC, sim.logical_bytes()),
    };
    sim.run(&trace, scen).unwrap()
}

fn assert_summaries_match(a: &RunSummary, b: &RunSummary, label: &str) {
    assert_eq!(a.ledger, b.ledger, "{label}: ledger diverged");
    assert_eq!(a.sim_end, b.sim_end, "{label}: simulated end diverged");
    assert_eq!(a.host_bytes_written, b.host_bytes_written, "{label}: volume diverged");
    assert_eq!(a.write_latency.count(), b.write_latency.count(), "{label}: write count");
    assert_eq!(
        a.write_latency.mean().to_bits(),
        b.write_latency.mean().to_bits(),
        "{label}: mean write latency"
    );
    for q in [0.5, 0.95, 0.99] {
        assert_eq!(
            a.write_latency.percentile(q),
            b.write_latency.percentile(q),
            "{label}: p{q} write latency"
        );
    }
    assert_eq!(a.write_latency.raw_us(), b.write_latency.raw_us(), "{label}: raw samples");
    assert_eq!(a.read_latency.count(), b.read_latency.count(), "{label}: read count");
    assert_eq!(a.wa().to_bits(), b.wa().to_bits(), "{label}: WA");
}

#[test]
fn five_schemes_bursty_identical_with_and_without_index() {
    for scheme in Scheme::all() {
        let with = run_single(scheme, Scenario::Bursty, true);
        let without = run_single(scheme, Scenario::Bursty, false);
        assert_summaries_match(&with, &without, &format!("{scheme:?}/bursty"));
    }
}

#[test]
fn five_schemes_daily_identical_with_and_without_index() {
    for scheme in Scheme::all() {
        let with = run_single(scheme, Scenario::Daily, true);
        let without = run_single(scheme, Scenario::Daily, false);
        assert_summaries_match(&with, &without, &format!("{scheme:?}/daily"));
    }
}

// --- multi-tenant ---------------------------------------------------

fn mt_cfg(scheme: Scheme, tenants: u32, attr: AttributionMode, index: bool) -> Config {
    let mut cfg = presets::small();
    cfg.cache.scheme = scheme;
    cfg.cache.slc_cache_bytes = 1 << 20;
    cfg.cache.idle_threshold = MS;
    cfg.host.tenants = tenants;
    cfg.host.scheduler = SchedKind::RoundRobin;
    cfg.host.mix = MixKind::AggressorVictims;
    cfg.host.attribution = attr;
    if attr == AttributionMode::Owner {
        // exercise the partitioner's eviction path (eviction_candidate
        // → evict_tenant_blocks) on top of the tenant-aware victims
        cfg.cache.partition.enabled = true;
        cfg.cache.partition.reserved_frac = 0.5;
    }
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000;
    cfg.sim.victim_index = index;
    cfg
}

fn assert_mt_match(a: &MultiTenantSummary, b: &MultiTenantSummary, label: &str) {
    assert_eq!(a.ledger, b.ledger, "{label}: device ledger diverged");
    assert_eq!(a.background, b.background, "{label}: background ledger diverged");
    assert_eq!(a.sim_end, b.sim_end, "{label}: simulated end diverged");
    assert_eq!(a.host_bytes_written, b.host_bytes_written, "{label}: volume diverged");
    assert_eq!(a.wa().to_bits(), b.wa().to_bits(), "{label}: WA diverged");
    assert_eq!(a.tenants.len(), b.tenants.len());
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.ledger, y.ledger, "{label}/{}: tenant ledger", x.name);
        assert_eq!(
            x.write_latency.count(),
            y.write_latency.count(),
            "{label}/{}: write count",
            x.name
        );
        assert_eq!(
            x.p99_write_latency(),
            y.p99_write_latency(),
            "{label}/{}: p99",
            x.name
        );
        assert_eq!(
            x.migrated_pages_owned, y.migrated_pages_owned,
            "{label}/{}: owned moves",
            x.name
        );
    }
}

#[test]
fn multi_tenant_proportional_identical() {
    for scen in [Scenario::Bursty, Scenario::Daily] {
        for scheme in [Scheme::Baseline, Scheme::Ips] {
            let a = MultiTenantSimulator::run_once(
                mt_cfg(scheme, 4, AttributionMode::Proportional, true),
                scen,
            )
            .unwrap();
            let b = MultiTenantSimulator::run_once(
                mt_cfg(scheme, 4, AttributionMode::Proportional, false),
                scen,
            )
            .unwrap();
            assert_mt_match(&a, &b, &format!("{scheme:?}/{scen:?}/proportional"));
        }
    }
}

#[test]
fn multi_tenant_owner_attribution_identical() {
    // owner attribution turns on the TenantAware victim policy and the
    // eviction hook — the index's tie-break and the owner histograms
    // both sit on this path
    for scen in [Scenario::Bursty, Scenario::Daily] {
        for scheme in [Scheme::Baseline, Scheme::IpsAgc] {
            let a = MultiTenantSimulator::run_once(
                mt_cfg(scheme, 4, AttributionMode::Owner, true),
                scen,
            )
            .unwrap();
            let b = MultiTenantSimulator::run_once(
                mt_cfg(scheme, 4, AttributionMode::Owner, false),
                scen,
            )
            .unwrap();
            assert_mt_match(&a, &b, &format!("{scheme:?}/{scen:?}/owner"));
        }
    }
}

#[test]
fn single_tenant_owner_identical() {
    let a = MultiTenantSimulator::run_once(
        mt_cfg(Scheme::Baseline, 1, AttributionMode::Owner, true),
        Scenario::Daily,
    )
    .unwrap();
    let b = MultiTenantSimulator::run_once(
        mt_cfg(Scheme::Baseline, 1, AttributionMode::Owner, false),
        Scenario::Daily,
    )
    .unwrap();
    assert_mt_match(&a, &b, "baseline/daily/owner/single-tenant");
}
