//! Integration tests for exact per-tenant ownership + tenant-aware
//! GC/AGC victim selection:
//!
//! * the **differential** guarantee — a single tenant running with the
//!   full owner machinery (page tagging, tenant-aware victim policy,
//!   exact releases, the eviction hook armed) is byte-identical to the
//!   plain shared/proportional path, for every scheme, bursty AND
//!   daily: with one tenant every debt is equal and every tag is its
//!   own, so nothing may perturb;
//! * the **headline** — with an aggressor and a victim under the
//!   partitioned variant, owner attribution charges migration work to
//!   the tenants whose pages moved: the victim's attributed migration
//!   pages *decrease* vs proportional attribution, while per-tenant WA
//!   attribution still sums to the total device WA (closure);
//! * the **eviction hook** — a slice-over-budget tenant's blocks are
//!   reclaimed first, and a tenant owning nothing is never touched.

use ips::cache::{baseline::Baseline, CachePolicy};
use ips::config::{presets, AttributionMode, Config, MixKind, SchedKind, Scheme};
use ips::flash::{BlockAddr, Lpn, PageKind, PlaneId};
use ips::ftl::Ftl;
use ips::host::{MultiTenantSimulator, MultiTenantSummary};
use ips::metrics::Ledger;
use ips::trace::scenario::Scenario;

/// Physical scan: valid SLC-resident pages owned by `t`.
fn slc_resident_owned(ftl: &Ftl, t: u16) -> u64 {
    let g = *ftl.array.geometry();
    let mut count = 0u64;
    for p in 0..g.planes() {
        for b in 0..g.blocks_per_plane {
            let addr = BlockAddr { plane: PlaneId(p), block: b };
            let blk = ftl.array.block(addr);
            for pib in blk.valid_pages() {
                if blk.page_kind(pib) == PageKind::Slc
                    && ftl.owner_of(addr.page(&g, pib / 3, (pib % 3) as u8)) == Some(t)
                {
                    count += 1;
                }
            }
        }
    }
    count
}

fn base_cfg(scheme: Scheme) -> Config {
    let mut cfg = presets::small();
    cfg.cache.scheme = scheme;
    cfg.cache.slc_cache_bytes = 1 << 20;
    cfg.host.tenants = 4;
    cfg.host.scheduler = SchedKind::Fifo;
    cfg.host.mix = MixKind::AggressorVictims;
    cfg.host.aggressor_cache_mult = 4.0;
    cfg.host.victim_req_bytes = 4096;
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000;
    cfg
}

/// The metric surface two runs must agree on to count as identical
/// (attribution labels deliberately excluded — they differ by design).
fn metrics_fingerprint(s: &MultiTenantSummary) -> String {
    let mut out = format!(
        "ledger={:?} background={:?} sim_end={} host_bytes={} writes={} reads={} \
         w_mean={} w_p50={} w_p99={} r_p99={}",
        s.ledger,
        s.background,
        s.sim_end,
        s.host_bytes_written,
        s.write_latency.count(),
        s.read_latency.count(),
        s.write_latency.mean().to_bits(),
        s.write_latency.percentile_best(0.50),
        s.write_latency.percentile_best(0.99),
        s.read_latency.percentile_best(0.99),
    );
    for t in &s.tenants {
        out.push_str(&format!(
            " [{} ledger={:?} bytes={} mean={} p50={} p99={}]",
            t.name,
            t.ledger,
            t.host_bytes_written,
            t.mean_write_latency().to_bits(),
            t.p50_write_latency(),
            t.p99_write_latency(),
        ));
    }
    out
}

fn owned_single_tenant(mut cfg: Config) -> Config {
    cfg.host.tenants = 1;
    cfg.host.attribution = AttributionMode::Owner;
    cfg.cache.partition.enabled = true;
    cfg.cache.partition.reserved_frac = 1.0;
    cfg
}

#[test]
fn single_tenant_owner_machinery_is_byte_identical_to_greedy_shared() {
    for scheme in Scheme::all() {
        let mut shared = base_cfg(scheme);
        shared.host.tenants = 1;
        shared.cache.partition.enabled = false;
        let owned = owned_single_tenant(base_cfg(scheme));
        let a = MultiTenantSimulator::run_once(shared, Scenario::Bursty)
            .unwrap_or_else(|e| panic!("{scheme:?} shared: {e}"));
        let b = MultiTenantSimulator::run_once(owned, Scenario::Bursty)
            .unwrap_or_else(|e| panic!("{scheme:?} owned: {e}"));
        assert_eq!(a.attribution, "proportional");
        assert_eq!(b.attribution, "owner");
        assert_eq!(
            metrics_fingerprint(&a),
            metrics_fingerprint(&b),
            "{scheme:?}: owner tagging + tenant-aware victim selection must be \
             invisible to a single tenant (bursty)"
        );
    }
}

#[test]
fn single_tenant_owner_differential_holds_in_daily_scenario_too() {
    // daily adds idle-time reclamation, AGC feeding, the flush, and the
    // eviction-hook call site — none may fire or perturb for one tenant
    for scheme in [Scheme::Baseline, Scheme::IpsAgc, Scheme::Coop] {
        let mut shared = base_cfg(scheme);
        shared.host.tenants = 1;
        shared.host.mix = MixKind::Uniform;
        shared.cache.idle_threshold = ips::config::MS;
        shared.cache.partition.enabled = false;
        let owned = owned_single_tenant(shared.clone());
        let a = MultiTenantSimulator::run_once(shared, Scenario::Daily).unwrap();
        let b = MultiTenantSimulator::run_once(owned, Scenario::Daily).unwrap();
        assert_eq!(metrics_fingerprint(&a), metrics_fingerprint(&b), "{scheme:?} daily");
    }
}

/// The headline config: one aggressor whose churn (several times its
/// own region) keeps GC running for the whole burst, plus one paced
/// victim whose post-cliff writes keep tripping over that GC.
fn headline_cfg(attr: AttributionMode) -> Config {
    let mut cfg = presets::small();
    cfg.geometry.blocks_per_plane = 24; // tighten OP so GC runs hot
    cfg.cache.scheme = Scheme::Baseline;
    cfg.cache.slc_cache_bytes = 1 << 20;
    cfg.host.tenants = 2;
    cfg.host.scheduler = SchedKind::Fifo;
    cfg.host.mix = MixKind::AggressorVictims;
    cfg.host.aggressor_cache_mult = 64.0; // ~4.7× its region: heavy churn
    cfg.host.victim_req_bytes = 16 << 10;
    cfg.host.attribution = attr;
    cfg.cache.partition.enabled = true;
    cfg.cache.partition.reserved_frac = 0.75;
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000;
    cfg
}

fn migration_pages(t: &ips::metrics::TenantStats) -> u64 {
    t.ledger.gc_migrations + t.ledger.slc2tlc_migrations
}

#[test]
fn owner_attribution_shrinks_the_victims_migration_bill_and_still_closes() {
    let prop =
        MultiTenantSimulator::run_once(headline_cfg(AttributionMode::Proportional), Scenario::Bursty)
            .unwrap();
    let owner =
        MultiTenantSimulator::run_once(headline_cfg(AttributionMode::Owner), Scenario::Bursty)
            .unwrap();
    // identical offered load (paired seeds, same traces)
    assert_eq!(prop.host_bytes_written, owner.host_bytes_written);
    // closure holds under BOTH attributions: per-tenant WA attribution
    // sums to the total device WA
    for s in [&prop, &owner] {
        let mut sum = Ledger::default();
        for t in &s.tenants {
            sum.merge(&t.ledger);
        }
        sum.merge(&s.background);
        assert_eq!(sum, s.ledger, "{} attribution closes exactly", s.attribution);
        assert_eq!(
            sum.total_programs(),
            s.ledger.total_programs(),
            "{}: attributed programs sum to the device WA numerator",
            s.attribution
        );
    }
    // under proportional attribution the victim pays for GC its
    // requests merely *triggered* — overwhelmingly the aggressor's data
    let v_prop = migration_pages(prop.tenant("victim-1").unwrap());
    let v_owner = migration_pages(owner.tenant("victim-1").unwrap());
    assert!(
        v_prop > 0,
        "the churn must make victim requests trigger GC (got a quiet run)"
    );
    assert!(
        v_owner < v_prop,
        "owner tags must shrink the victim's migration bill: owner {v_owner} \
         vs proportional {v_prop}"
    );
    // the moved data belonged to the aggressor, and the owner run says so
    let agg = owner.tenant("aggressor").unwrap();
    let victim = owner.tenant("victim-1").unwrap();
    assert!(agg.migrated_pages_owned > victim.migrated_pages_owned);
    assert!(agg.migrated_pages_owned > 0);
    assert!(agg.migration_ns_owned > 0, "relocation cost is priced, not just counted");
    // proportional runs cannot know whose pages moved
    for t in &prop.tenants {
        assert_eq!(t.migrated_pages_owned, 0, "{}: no owner table, no owned moves", t.name);
    }
}

#[test]
fn daily_owner_run_with_eviction_path_keeps_occupancy_exact() {
    // Multi-tenant Daily under owner attribution + tight slices: the
    // engine's idle windows exercise the full background pipeline —
    // eviction_candidate → evict_tenant_blocks → idle_work → event
    // drain — and the occupancy==tagged-residency invariant must
    // survive it (the hook reads occupancy mid-window; the drain
    // settles it afterwards).
    let mut cfg = presets::small();
    cfg.cache.scheme = Scheme::Baseline;
    cfg.cache.slc_cache_bytes = 1 << 20;
    cfg.cache.idle_threshold = ips::config::MS;
    cfg.host.tenants = 2;
    cfg.host.scheduler = SchedKind::RoundRobin;
    cfg.host.mix = MixKind::Uniform;
    cfg.host.aggressor_cache_mult = 4.0; // volume shared by the tenants
    cfg.host.attribution = AttributionMode::Owner;
    cfg.cache.partition.enabled = true;
    // tiny reserved slices: both tenants run over budget, so the
    // eviction hook has a live candidate in every idle window
    cfg.cache.partition.reserved_frac = 0.1;
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000;
    let mut sim = MultiTenantSimulator::new(cfg).unwrap();
    let s = sim.run(Scenario::Daily).unwrap();
    // idle-time reclamation ran (hook and/or generic idle work)
    assert!(
        s.background.slc2tlc_migrations > 0,
        "daily idle windows must reclaim cache: {:?}",
        s.background
    );
    // attribution still closes
    let mut sum = Ledger::default();
    for t in &s.tenants {
        sum.merge(&t.ledger);
    }
    sum.merge(&s.background);
    assert_eq!(sum, s.ledger, "closure across the eviction path");
    // the headline invariant: per-tenant occupancy equals the physical
    // owner-tag scan even after hook-driven reclamation
    let part = sim.partitioner();
    assert!(part.enabled());
    for t in 0..2u16 {
        assert_eq!(
            part.occupancy(t as usize),
            slc_resident_owned(sim.ftl(), t),
            "tenant {t}: occupancy must stay exact through eviction"
        );
    }
}

#[test]
fn eviction_hook_targets_only_the_tenants_blocks() {
    let mut cfg = presets::small();
    cfg.cache.scheme = Scheme::Baseline;
    cfg.cache.slc_cache_bytes = 256 << 10; // two 32-page SLC blocks
    let mut ftl = Ftl::new(&cfg).unwrap();
    ftl.set_tenant_count(2);
    let mut pol = Baseline::new(&cfg);
    pol.init(&mut ftl).unwrap();
    // tenant 1 fills the whole cache; tenant 0 caches nothing
    let mut t = 0;
    ftl.set_tenant(Some(1));
    for i in 0..64u64 {
        ftl.ledger.host_page();
        let c = pol.host_write_page(&mut ftl, Lpn(2000 + i), t).unwrap();
        t = t.max(c.end);
    }
    ftl.set_tenant(None);
    // retire the full active blocks without reclaiming anything
    // (zero-length idle window starts no atomic units)
    let end = pol.idle_work(&mut ftl, t, t).unwrap();
    assert_eq!(end, t);
    assert_eq!(ftl.ledger.slc2tlc_migrations, 0);
    let _ = ftl.take_owner_events();
    // tenant 0 owns nothing cached: the hook must not touch a block
    let before = ftl.ledger;
    let end = pol.evict_tenant_blocks(&mut ftl, 0, t, t + 600_000_000_000).unwrap();
    assert_eq!(end, t, "no blocks hold tenant 0's pages");
    assert_eq!(ftl.ledger, before);
    // tenant 1 is the hoarder: its blocks are reclaimed, atomically
    let end = pol.evict_tenant_blocks(&mut ftl, 1, t, t + 600_000_000_000).unwrap();
    assert!(end > t);
    assert_eq!(ftl.ledger.slc2tlc_migrations, 64, "every cached page migrated out");
    let ev = ftl.take_owner_events();
    assert_eq!(ev.released[1], 64, "all of tenant 1's residency released");
    assert_eq!(ev.released[0], 0);
    assert_eq!(ev.moves[1].slc2tlc_migrations, 64);
    // data survived the eviction at its new TLC locations
    for i in 0..64u64 {
        assert!(ftl.map.get(Lpn(2000 + i)).is_some());
    }
    ftl.audit().unwrap();
}
