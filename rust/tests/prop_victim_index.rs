//! Property suite for the incremental victim-selection index: random
//! write / invalidate / close / pop sequences drive **two FTLs in
//! lockstep** — one on the bucket index, one on the historical linear
//! scan — and every observable (each pop's pick, completions, ledgers,
//! closed-list order, the greedy gain peek) must match exactly, under
//! both `Greedy` and `TenantAware` policies with 1 and 4 tenants.
//! Bucket membership is additionally checked against a fresh rescan
//! through `Ftl::audit` (the index audit), and the owner histograms
//! behind `dominant_owner` / `owned_valid_in_block` are checked against
//! a valid-page scan oracle. Failures shrink to a minimal op sequence.

use ips::config::{presets, Scheme};
use ips::flash::{BlockAddr, BlockMode, Lpn, PlaneId};
use ips::ftl::{gc, Ftl, VictimPolicy};
use ips::metrics::Attribution;
use ips::util::prop::{self, tuple2, u64_up_to, vec_of};
use std::cmp::Reverse;

/// Raw generated op: `(kind, argument)`, interpreted by `step`.
type RawOp = (u64, u64);

const LPN_SPAN: u64 = 512;
/// First LPN used for cache-block fills (disjoint from host writes).
const CACHE_BASE: u64 = 100_000;

struct Pair {
    /// Index-backed FTL (the implementation under test).
    a: Ftl,
    /// Scan-backed oracle FTL.
    b: Ftl,
    /// LPNs written into cache blocks so far (overwrite targets).
    cache_lpns: Vec<u64>,
    /// Monotonic counter for fresh cache LPNs.
    next_cache: u64,
    tenants: usize,
}

/// Pair builder with per-side knob configurators: side `a` is the
/// implementation under test, side `b` the oracle.
fn build_pair_with(
    tenants: usize,
    policy: VictimPolicy,
    set_a: fn(&mut ips::config::Config),
    set_b: fn(&mut ips::config::Config),
) -> Pair {
    let mk = |set: fn(&mut ips::config::Config)| {
        let mut cfg = presets::small();
        cfg.cache.scheme = Scheme::TlcOnly;
        set(&mut cfg);
        let mut f = Ftl::new(&cfg).unwrap();
        if tenants > 0 {
            f.set_tenant_count(tenants);
            f.set_victim_policy(policy);
            f.set_tenant(Some(0));
        }
        f
    };
    Pair { a: mk(set_a), b: mk(set_b), cache_lpns: Vec::new(), next_cache: 0, tenants }
}

fn build_pair(tenants: usize, policy: VictimPolicy) -> Pair {
    build_pair_with(
        tenants,
        policy,
        |c| c.sim.victim_index = true,
        |c| c.sim.victim_index = false,
    )
}

/// Both sides on the bucket index: `a` flat vectors, `b` the BTreeSet
/// backend — the PR9 flat-layout lockstep.
fn build_flat_pair(tenants: usize, policy: VictimPolicy) -> Pair {
    build_pair_with(
        tenants,
        policy,
        |c| {
            c.sim.victim_index = true;
            c.sim.flat_index = true;
        },
        |c| {
            c.sim.victim_index = true;
            c.sim.flat_index = false;
        },
    )
}

/// Apply one op to both FTLs; `Err` on any observable divergence.
fn step(p: &mut Pair, op: RawOp) -> Result<(), String> {
    let planes = p.a.planes() as u64;
    let (kind, arg) = op;
    match kind % 5 {
        // host TLC write (overwrites invalidate, GC may run inline)
        0 => {
            let lpn = Lpn(arg % LPN_SPAN);
            let ra = p.a.host_write_tlc(lpn, 0);
            let rb = p.b.host_write_tlc(lpn, 0);
            match (ra, rb) {
                (Ok(ca), Ok(cb)) if ca == cb => {}
                (Err(_), Err(_)) => {}
                (ca, cb) => return Err(format!("host write diverged: {ca:?} vs {cb:?}")),
            }
        }
        // fill a fresh SLC block on a plane and close it
        1 => {
            let plane = PlaneId((arg % planes) as u32);
            let ra = p.a.alloc_block(plane, BlockMode::Slc);
            let rb = p.b.alloc_block(plane, BlockMode::Slc);
            let (ba, bb) = match (ra, rb) {
                (Ok(x), Ok(y)) => (x, y),
                (Err(_), Err(_)) => return Ok(()),
                (x, y) => return Err(format!("alloc diverged: {x:?} vs {y:?}")),
            };
            if ba != bb {
                return Err(format!("alloc picked different blocks: {ba:?} vs {bb:?}"));
            }
            for i in 0..4u64 {
                let lpn = Lpn(CACHE_BASE + p.next_cache * 4 + i);
                p.cache_lpns.push(lpn.0);
                p.a.program_slc_into(ba, lpn, Attribution::SlcCacheWrite, 0)
                    .map_err(|e| format!("a: slc program: {e}"))?;
                p.b.program_slc_into(bb, lpn, Attribution::SlcCacheWrite, 0)
                    .map_err(|e| format!("b: slc program: {e}"))?;
            }
            p.next_cache += 1;
            p.a.register_closed(ba);
            p.b.register_closed(bb);
        }
        // overwrite a previously cached LPN: invalidates a page that
        // may sit inside a closed block (the index's hot update)
        2 => {
            if p.cache_lpns.is_empty() {
                return Ok(());
            }
            let lpn = Lpn(p.cache_lpns[(arg as usize) % p.cache_lpns.len()]);
            let ra = p.a.host_write_tlc(lpn, 0);
            let rb = p.b.host_write_tlc(lpn, 0);
            match (ra, rb) {
                (Ok(ca), Ok(cb)) if ca == cb => {}
                (Err(_), Err(_)) => {}
                (ca, cb) => return Err(format!("overwrite diverged: {ca:?} vs {cb:?}")),
            }
        }
        // explicit victim pop: the pick itself must match
        3 => {
            let plane = PlaneId((arg % planes) as u32);
            let va = p.a.pop_victim(plane);
            let vb = p.b.pop_victim(plane);
            if va != vb {
                return Err(format!("pop_victim({plane:?}) diverged: {va:?} vs {vb:?}"));
            }
            // a popped (unreclaimed) victim stays erasable later only
            // through GC paths; leave it orphaned on both sides alike
        }
        // switch the writing tenant (tenant-aware debt accounting)
        _ => {
            if p.tenants > 0 {
                let t = (arg % p.tenants as u64) as u16;
                p.a.set_tenant(Some(t));
                p.b.set_tenant(Some(t));
            }
        }
    }
    Ok(())
}

/// Valid-page scan oracle for the owner histograms.
fn dominant_oracle(f: &Ftl, addr: BlockAddr) -> Option<u16> {
    let g = *f.array.geometry();
    let blk = f.array.block(addr);
    let mut counts: Vec<(u16, u32)> = Vec::new();
    for pib in blk.valid_pages() {
        if let Some(o) = f.owner_of(addr.page(&g, pib / 3, (pib % 3) as u8)) {
            match counts.iter_mut().find(|(t, _)| *t == o) {
                Some((_, c)) => *c += 1,
                None => counts.push((o, 1)),
            }
        }
    }
    counts.into_iter().max_by_key(|&(t, c)| (c, Reverse(t))).map(|(t, _)| t)
}

fn owned_oracle(f: &Ftl, addr: BlockAddr, t: u16) -> u32 {
    let g = *f.array.geometry();
    let blk = f.array.block(addr);
    blk.valid_pages()
        .filter(|&pib| f.owner_of(addr.page(&g, pib / 3, (pib % 3) as u8)) == Some(t))
        .count() as u32
}

fn final_checks(p: &mut Pair) -> Result<(), String> {
    if p.a.ledger != p.b.ledger {
        return Err(format!("ledgers diverged:\n  {:?}\n  {:?}", p.a.ledger, p.b.ledger));
    }
    for pl in 0..p.a.planes() {
        let plane = PlaneId(pl);
        if p.a.closed_blocks(plane) != p.b.closed_blocks(plane) {
            return Err(format!(
                "closed list diverged on plane {pl}: {:?} vs {:?}",
                p.a.closed_blocks(plane),
                p.b.closed_blocks(plane)
            ));
        }
        // the greedy-gain peek answers from the index on one side and
        // a closed-list rescan on the other
        let ga = gc::greedy_gain(&mut p.a, plane);
        let gb = gc::greedy_gain(&mut p.b, plane);
        if ga != gb {
            return Err(format!("greedy_gain diverged on plane {pl}: {ga} vs {gb}"));
        }
        // owner histograms == valid-page scan, per closed block
        for &b in p.a.closed_blocks(plane) {
            let addr = BlockAddr { plane, block: b };
            if p.a.dominant_owner(addr) != dominant_oracle(&p.a, addr) {
                return Err(format!("dominant_owner({addr:?}) != scan oracle"));
            }
            for t in 0..p.tenants.max(1) as u16 {
                if p.a.owned_valid_in_block(addr, t) != owned_oracle(&p.a, addr, t) {
                    return Err(format!("owned_valid_in_block({addr:?}, {t}) != scan oracle"));
                }
            }
        }
    }
    // bucket membership must match a fresh rescan (Ftl::audit runs the
    // index audit on the indexed side)
    p.a.audit().map_err(|e| format!("indexed audit: {e}"))?;
    p.b.audit().map_err(|e| format!("oracle audit: {e}"))?;
    // drain every plane: the full pop sequence must agree
    for pl in 0..p.a.planes() {
        let plane = PlaneId(pl);
        loop {
            let va = p.a.pop_victim(plane);
            let vb = p.b.pop_victim(plane);
            if va != vb {
                return Err(format!("drain pop diverged on plane {pl}: {va:?} vs {vb:?}"));
            }
            if va.is_none() {
                break;
            }
        }
    }
    Ok(())
}

fn run_property_on(
    name: &'static str,
    tenants: usize,
    policy: VictimPolicy,
    build: fn(usize, VictimPolicy) -> Pair,
) {
    prop::check(
        name,
        48,
        vec_of(tuple2(u64_up_to(4), u64_up_to(1 << 16)), 0, 96),
        |ops| {
            let mut pair = build(tenants, policy);
            for &op in ops {
                step(&mut pair, op)?;
            }
            final_checks(&mut pair)
        },
    );
}

fn run_property(name: &'static str, tenants: usize, policy: VictimPolicy) {
    run_property_on(name, tenants, policy, build_pair);
}

#[test]
fn index_matches_scan_untracked_greedy() {
    run_property("victim index == scan (no tenants, greedy)", 0, VictimPolicy::Greedy);
}

#[test]
fn index_matches_scan_single_tenant_greedy() {
    run_property("victim index == scan (1 tenant, greedy)", 1, VictimPolicy::Greedy);
}

#[test]
fn index_matches_scan_single_tenant_aware() {
    // with one tenant every debt is equal: tenant-aware must reduce to
    // greedy on both backends
    run_property("victim index == scan (1 tenant, tenant-aware)", 1, VictimPolicy::TenantAware);
}

#[test]
fn index_matches_scan_four_tenants_aware() {
    run_property("victim index == scan (4 tenants, tenant-aware)", 4, VictimPolicy::TenantAware);
}

#[test]
fn flat_matches_tree_untracked_greedy() {
    run_property_on(
        "flat buckets == BTreeSet buckets (no tenants, greedy)",
        0,
        VictimPolicy::Greedy,
        build_flat_pair,
    );
}

#[test]
fn flat_matches_tree_single_tenant_greedy() {
    run_property_on(
        "flat buckets == BTreeSet buckets (1 tenant, greedy)",
        1,
        VictimPolicy::Greedy,
        build_flat_pair,
    );
}

#[test]
fn flat_matches_tree_four_tenants_aware() {
    run_property_on(
        "flat buckets == BTreeSet buckets (4 tenants, tenant-aware)",
        4,
        VictimPolicy::TenantAware,
        build_flat_pair,
    );
}
