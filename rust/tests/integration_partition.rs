//! Integration tests for per-tenant SLC-cache partitioning + QoS
//! admission control:
//!
//! * the **differential** guarantee — a partitioned config with a
//!   single tenant owning 100% of the cache produces byte-identical
//!   metrics to the shared-cache path, for every scheme (this guards
//!   the gated-write refactor of all four cache schemes);
//! * the **headline** — under aggressor+victims, victim p99 with
//!   partitioning+QoS sits strictly below the shared-cache victim
//!   p99, and the aggressor is the only throttled tenant;
//! * the device-QD ablation sweep runs end to end in smoke form.

use ips::config::{MixKind, QosMode, SchedKind, Scheme};
use ips::coordinator::fleet::{device_qd_sweep, summary_table, IsolationVariant};
use ips::host::{MultiTenantSimulator, MultiTenantSummary};
use ips::trace::scenario::Scenario;

fn base_cfg(scheme: Scheme) -> ips::config::Config {
    let mut cfg = ips::config::presets::small();
    cfg.cache.scheme = scheme;
    cfg.cache.slc_cache_bytes = 1 << 20;
    cfg.host.tenants = 4;
    cfg.host.scheduler = SchedKind::Fifo;
    cfg.host.mix = MixKind::AggressorVictims;
    cfg.host.aggressor_cache_mult = 4.0;
    cfg.host.victim_req_bytes = 4096;
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000;
    cfg
}

/// The metric surface two runs must agree on to count as identical.
fn metrics_fingerprint(s: &MultiTenantSummary) -> String {
    let mut out = format!(
        "ledger={:?} background={:?} sim_end={} host_bytes={} writes={} reads={} \
         w_mean={} w_p50={} w_p99={} r_p99={}",
        s.ledger,
        s.background,
        s.sim_end,
        s.host_bytes_written,
        s.write_latency.count(),
        s.read_latency.count(),
        s.write_latency.mean().to_bits(),
        s.write_latency.percentile_best(0.50),
        s.write_latency.percentile_best(0.99),
        s.read_latency.percentile_best(0.99),
    );
    for t in &s.tenants {
        out.push_str(&format!(
            " [{} ledger={:?} bytes={} mean={} p50={} p99={}]",
            t.name,
            t.ledger,
            t.host_bytes_written,
            t.mean_write_latency().to_bits(),
            t.p50_write_latency(),
            t.p99_write_latency(),
        ));
    }
    out
}

#[test]
fn single_tenant_full_partition_is_byte_identical_to_shared() {
    for scheme in Scheme::all() {
        let mut shared = base_cfg(scheme);
        shared.host.tenants = 1;
        shared.cache.partition.enabled = false;

        let mut owned = shared.clone();
        owned.cache.partition.enabled = true;
        owned.cache.partition.reserved_frac = 1.0; // the tenant owns 100%

        let a = MultiTenantSimulator::run_once(shared, Scenario::Bursty)
            .unwrap_or_else(|e| panic!("{scheme:?} shared: {e}"));
        let b = MultiTenantSimulator::run_once(owned, Scenario::Bursty)
            .unwrap_or_else(|e| panic!("{scheme:?} partitioned: {e}"));
        assert!(!a.partitioned);
        // tlc-only has no cache to partition, so its partitioner
        // reports itself disabled even when asked for
        assert_eq!(b.partitioned, scheme != Scheme::TlcOnly, "{scheme:?}");
        assert_eq!(
            metrics_fingerprint(&a),
            metrics_fingerprint(&b),
            "{scheme:?}: a sole tenant owning the whole cache must be \
             indistinguishable from the shared-cache path"
        );
    }
}

#[test]
fn single_tenant_differential_holds_in_daily_scenario_too() {
    // idle-time background work (reclamation, AGC) goes through the
    // partitioner's background accounting — it must not disturb the
    // differential either
    for scheme in [Scheme::Baseline, Scheme::IpsAgc, Scheme::Coop] {
        let mut shared = base_cfg(scheme);
        shared.host.tenants = 1;
        shared.host.mix = MixKind::Uniform;
        shared.cache.idle_threshold = ips::config::MS;
        shared.cache.partition.enabled = false;
        let mut owned = shared.clone();
        owned.cache.partition.enabled = true;
        owned.cache.partition.reserved_frac = 1.0;
        let a = MultiTenantSimulator::run_once(shared, Scenario::Daily).unwrap();
        let b = MultiTenantSimulator::run_once(owned, Scenario::Daily).unwrap();
        assert_eq!(metrics_fingerprint(&a), metrics_fingerprint(&b), "{scheme:?} daily");
    }
}

fn qos_cfg(scheme: Scheme) -> ips::config::Config {
    let mut cfg = base_cfg(scheme);
    cfg.cache.partition.enabled = true;
    cfg.cache.partition.reserved_frac = 0.75;
    cfg.host.qos.mode = QosMode::Strict;
    // well under the small geometry's SLC bandwidth (~32 MB/s), well
    // over any victim's offered load (~2 MB/s)
    cfg.host.qos.rate_mbps = 8.0;
    cfg.host.qos.burst_bytes = 256 << 10;
    cfg
}

#[test]
fn partition_plus_qos_beats_shared_victim_p99_and_throttles_only_the_aggressor() {
    for scheme in [Scheme::Baseline, Scheme::Ips] {
        let shared = {
            let cfg = base_cfg(scheme);
            MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
        };
        let isolated = {
            let cfg = qos_cfg(scheme);
            MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
        };
        // identical offered load either way
        assert_eq!(shared.host_bytes_written, isolated.host_bytes_written);
        assert!(
            isolated.max_victim_p99() < shared.max_victim_p99(),
            "{scheme:?}: partitioned+qos victim p99 {} must sit strictly below shared {}",
            isolated.max_victim_p99(),
            shared.max_victim_p99()
        );
        // the aggressor is the only throttled tenant
        assert_eq!(
            isolated.throttled_tenants(),
            vec!["aggressor"],
            "{scheme:?}: victims stay within budget and are never stalled"
        );
        let agg = isolated.tenant("aggressor").unwrap();
        assert!(agg.throttle_stalls > 0, "{scheme:?}: the aggressor was actually held back");
        assert!(agg.throttle_stall_ns > 0);
        // nobody was throttled in the shared run (QoS was off)
        assert_eq!(shared.total_throttle_stalls(), 0);
    }
}

#[test]
fn partitioning_protects_the_victims_reserved_slices() {
    let mut cfg = base_cfg(Scheme::Baseline);
    cfg.cache.partition.enabled = true;
    cfg.cache.partition.reserved_frac = 0.75;
    let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
    assert!(s.partitioned);
    assert!(s.cache_capacity_pages > 0);
    let agg = s.tenant("aggressor").unwrap();
    // the burst overflows the aggressor's slice: allocations denied
    assert!(agg.slc_denied_pages > 0, "the aggressor hit its slice limit");
    // per-tenant occupancies never exceeded slice + whole shared pool
    let shared_pool: u64 =
        s.cache_capacity_pages - s.tenants.iter().map(|t| t.cache_reserved_pages).sum::<u64>();
    for t in &s.tenants {
        assert!(t.cache_reserved_pages > 0, "{} owns a slice", t.name);
        assert!(
            t.cache_occupancy_peak <= t.cache_reserved_pages + shared_pool,
            "{}: peak {} within slice {} + shared {}",
            t.name,
            t.cache_occupancy_peak,
            t.cache_reserved_pages,
            shared_pool
        );
    }
    // attribution still closes under partitioning
    let mut sum = ips::metrics::Ledger::default();
    for t in &s.tenants {
        sum.merge(&t.ledger);
    }
    sum.merge(&s.background);
    assert_eq!(sum, s.ledger, "partitioning must not leak attribution");
}

#[test]
fn slo_mode_is_quiet_when_targets_hold_and_bites_when_they_do_not() {
    // a generous SLO no victim ever violates: no throttling at all
    let mut cfg = qos_cfg(Scheme::Baseline);
    cfg.host.qos.mode = QosMode::Slo;
    cfg.host.qos.slo_p99 = 3_600_000 * ips::config::MS; // one hour
    let quiet = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
    assert_eq!(
        quiet.total_throttle_stalls(),
        0,
        "work-conserving: no stalls while every tenant meets the SLO"
    );
    // a tight SLO the aggressor's backlog breaks: enforcement kicks in
    let mut cfg = qos_cfg(Scheme::Baseline);
    cfg.host.qos.mode = QosMode::Slo;
    cfg.host.qos.slo_p99 = 10 * ips::config::MS;
    let tight = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
    assert!(tight.total_throttle_stalls() > 0, "SLO breach triggers throttling");
    assert_eq!(tight.throttled_tenants(), vec!["aggressor"]);
}

#[test]
fn device_qd_ablation_smoke() {
    // the ROADMAP ablation in CI-sized form: every point runs, load is
    // constant, and the per-point summaries render
    let mut base = ips::config::presets::small();
    base.cache.slc_cache_bytes = 1 << 20;
    base.host.tenants = 3;
    base.host.aggressor_cache_mult = 2.0;
    base.sim.latency_samples = 100_000;
    let points = device_qd_sweep(&base, Scenario::Bursty, &[1, 8]).unwrap();
    assert_eq!(points.len(), 2);
    // identical offered load and request population at every depth —
    // the window only changes *when* things dispatch, never *what*
    assert_eq!(points[0].1.host_bytes_written, points[1].1.host_bytes_written);
    assert_eq!(points[0].1.write_latency.count(), points[1].1.write_latency.count());
    for (qd, s) in &points {
        assert!(s.max_victim_p99() > 0, "qd {qd} measured victim tails");
    }
    let summaries: Vec<MultiTenantSummary> = points.into_iter().map(|(_, s)| s).collect();
    let rendered = summary_table(&summaries).render();
    assert!(rendered.contains("victim_p99_ms"));
}

#[test]
fn variant_axis_reports_match_their_configs() {
    // one cell per variant through the raw engine, labels intact
    for variant in IsolationVariant::all() {
        let mut cfg = base_cfg(Scheme::Baseline);
        cfg.host.qos.rate_mbps = 8.0;
        cfg.host.qos.burst_bytes = 256 << 10;
        variant.apply(&mut cfg);
        let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
        match variant {
            IsolationVariant::Shared => {
                assert!(!s.partitioned);
                assert_eq!(s.qos_mode, "off");
            }
            IsolationVariant::Partitioned => {
                assert!(s.partitioned);
                assert_eq!(s.qos_mode, "off");
            }
            IsolationVariant::PartitionedQos => {
                assert!(s.partitioned);
                assert_eq!(s.qos_mode, "strict");
            }
        }
    }
}
