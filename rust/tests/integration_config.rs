//! Config-system integration: TOML file → Config → Simulator, plus
//! CLI parse coverage of the launcher surface.

use ips::config::{presets, Config, Scheme};
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};

#[test]
fn toml_file_drives_a_run() {
    let dir = std::env::temp_dir().join("ips_cfg_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        r#"
# experiment override
[cache]
scheme = "ips"
idle_threshold_ns = 5_000_000

[sim]
seed = 1234
verify = true
"#,
    )
    .unwrap();
    let cfg = Config::load(&path, presets::small()).unwrap();
    assert_eq!(cfg.cache.scheme, Scheme::Ips);
    assert_eq!(cfg.sim.seed, 1234);
    let mut sim = Simulator::new(cfg).unwrap();
    let t = scenario::sequential_fill("seq", 1 << 20, sim.logical_bytes());
    let s = sim.run(&t, Scenario::Bursty).unwrap();
    assert_eq!(s.seed, 1234);
    assert_eq!(s.scheme, "ips");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_toml_rejected_with_context() {
    let err = Config::from_toml_str("[cache]\nscheme = \"nope\"", presets::small());
    assert!(err.is_err());
    let err = Config::from_toml_str("[ssd]\npages_per_block = 100", presets::small());
    assert!(err.is_err(), "non-multiple-of-3 pages per block");
}

#[test]
fn cli_surface_parses() {
    use ips::util::cli::Command;
    let cmd = Command::new("ips", "x")
        .subcommand(
            Command::new("reproduce", "r")
                .opt("fig", Some('f'), "N", "figure", Some("all"))
                .opt("scale", None, "N", "scale", Some("4")),
        )
        .subcommand(Command::new("list", "l"));
    let p = cmd
        .parse_from(vec!["reproduce".into(), "--fig".into(), "10".into()])
        .unwrap();
    assert_eq!(p.subcommand, Some("reproduce"));
    assert_eq!(p.sub().unwrap().get("fig"), Some("10"));
    assert_eq!(p.sub().unwrap().get_u64("scale").unwrap(), 4);
}

#[test]
fn host_section_drives_a_multitenant_run() {
    use ips::host::MultiTenantSimulator;
    let cfg = Config::from_toml_str(
        "[host]\ntenants = 3\nscheduler = \"round-robin\"\nmix = \"uniform\"",
        presets::small(),
    )
    .unwrap();
    let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
    assert_eq!(s.tenants.len(), 3);
    assert_eq!(s.scheduler, "round-robin");
    assert_eq!(s.mix, "uniform");
    assert!(s.host_bytes_written > 0);
}

#[test]
fn presets_compose_with_scaling() {
    use ips::coordinator::experiment::scale_config;
    for scale in [1u32, 2, 4, 8, 16] {
        let cfg = scale_config(presets::table1(), scale);
        cfg.validate().unwrap_or_else(|e| panic!("scale {scale}: {e}"));
        let coop = scale_config(presets::coop64(), scale);
        coop.validate().unwrap_or_else(|e| panic!("coop scale {scale}: {e}"));
    }
}
