//! Differential guarantee for the streaming workload path
//! (§Streaming workloads): with `sim.streaming_traces` on vs off,
//! every scheme must produce **byte identical** summaries — ledger
//! counters, latency statistics, WA, simulated end time, fault
//! outcome — on bursty and daily scenarios, single- and multi-tenant,
//! with fault injection armed so the `at_frac` trigger computed from
//! the sources' analytic horizons lands on the same nanosecond as the
//! historical materialized-trace scan. Streaming is a pure
//! generation/queueing change; any divergence is a bug.
//!
//! The file also pins the tentpole's memory claim: on the streaming
//! path no materialized trace ever exists, so the peak number of ops
//! resident in the host at once is bounded by queue window × tenants
//! even when the workload is orders of magnitude larger.

use ips::config::{presets, Config, FaultKind, MixKind, SchedKind, Scheme, MS};
use ips::host::{MultiTenantSimulator, MultiTenantSummary};
use ips::metrics::RunSummary;
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::trace::source::{bursty_source, SynthSource};
use ips::trace::{profiles, synth};

// --- single-tenant: Simulator::run vs Simulator::run_source ---------

fn single_cfg(scheme: Scheme) -> Config {
    let mut c = presets::small();
    c.cache.scheme = scheme;
    c.cache.slc_cache_bytes = 1 << 20;
    c.cache.idle_threshold = 10 * MS;
    c.sim.verify = true;
    c.sim.latency_samples = 4096;
    c
}

fn assert_summaries_match(a: &RunSummary, b: &RunSummary, label: &str) {
    assert_eq!(a.ledger, b.ledger, "{label}: ledger diverged");
    assert_eq!(a.sim_end, b.sim_end, "{label}: simulated end diverged");
    assert_eq!(a.host_bytes_written, b.host_bytes_written, "{label}: volume diverged");
    assert_eq!(a.write_latency.count(), b.write_latency.count(), "{label}: write count");
    assert_eq!(
        a.write_latency.mean().to_bits(),
        b.write_latency.mean().to_bits(),
        "{label}: mean write latency"
    );
    for q in [0.5, 0.95, 0.99] {
        assert_eq!(
            a.write_latency.percentile(q),
            b.write_latency.percentile(q),
            "{label}: p{q} write latency"
        );
    }
    assert_eq!(a.write_latency.raw_us(), b.write_latency.raw_us(), "{label}: raw samples");
    assert_eq!(a.read_latency.count(), b.read_latency.count(), "{label}: read count");
    assert_eq!(a.wa().to_bits(), b.wa().to_bits(), "{label}: WA");
}

/// Daily: a materialized synthetic day replayed with `run` vs the
/// never-materialized `SynthSource` fed straight into `run_source`.
#[test]
fn five_schemes_daily_run_source_identical() {
    let p = &profiles::ALL[0];
    for scheme in Scheme::all() {
        let mut a = Simulator::new(single_cfg(scheme)).unwrap();
        let trace = synth::generate_scaled(p, 7, a.logical_bytes(), 4e-3);
        let oracle = a.run(&trace, Scenario::Daily).unwrap();

        let mut b = Simulator::new(single_cfg(scheme)).unwrap();
        let src = SynthSource::new_scaled(p, 7, b.logical_bytes(), 4e-3);
        let streamed = b.run_source(src, Scenario::Daily).unwrap();

        assert_summaries_match(&streamed, &oracle, &format!("{scheme:?}/daily"));
    }
}

/// Bursty: materialize-then-`to_bursty` vs the streaming bursty
/// rewrite (`bursty_source`'s O(1)-memory counting pre-pass).
#[test]
fn five_schemes_bursty_run_source_identical() {
    let p = &profiles::ALL[1];
    for scheme in Scheme::all() {
        let mut a = Simulator::new(single_cfg(scheme)).unwrap();
        let daily = synth::generate_scaled(p, 11, a.logical_bytes(), 4e-3);
        let trace = scenario::to_bursty(&daily, a.logical_bytes());
        let oracle = a.run(&trace, Scenario::Bursty).unwrap();

        let mut b = Simulator::new(single_cfg(scheme)).unwrap();
        let limit = b.logical_bytes();
        let src = bursty_source(SynthSource::new_scaled(p, 11, limit, 4e-3), limit);
        let streamed = b.run_source(src, Scenario::Bursty).unwrap();

        assert_summaries_match(&streamed, &oracle, &format!("{scheme:?}/bursty"));
    }
}

// --- multi-tenant: sim.streaming_traces on vs off -------------------

fn mt_cfg(scheme: Scheme, fault: FaultKind, streaming: bool) -> Config {
    let mut cfg = presets::small();
    cfg.cache.scheme = scheme;
    cfg.cache.slc_cache_bytes = 1 << 20;
    cfg.cache.idle_threshold = MS;
    cfg.host.tenants = 3;
    cfg.host.scheduler = SchedKind::RoundRobin;
    cfg.host.mix = MixKind::AggressorVictims;
    // arm the fault so the trigger time — at_frac × workload horizon —
    // must agree between the streamed sources' analytic horizons and
    // the oracle's scan of the materialized traces
    cfg.fault.kind = fault;
    cfg.fault.at_frac = 0.5;
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000;
    cfg.sim.streaming_traces = streaming;
    cfg
}

fn assert_mt_match(a: &MultiTenantSummary, b: &MultiTenantSummary, label: &str) {
    assert_eq!(a.fault, b.fault, "{label}: fault outcome diverged");
    assert_eq!(a.ledger, b.ledger, "{label}: device ledger diverged");
    assert_eq!(a.background, b.background, "{label}: background ledger diverged");
    assert_eq!(a.sim_end, b.sim_end, "{label}: simulated end diverged");
    assert_eq!(a.host_bytes_written, b.host_bytes_written, "{label}: volume diverged");
    assert_eq!(a.wa().to_bits(), b.wa().to_bits(), "{label}: WA diverged");
    assert_eq!(a.tenants.len(), b.tenants.len());
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.ledger, y.ledger, "{label}/{}: tenant ledger", x.name);
        assert_eq!(
            x.write_latency.count(),
            y.write_latency.count(),
            "{label}/{}: write count",
            x.name
        );
        assert_eq!(
            x.read_latency.count(),
            y.read_latency.count(),
            "{label}/{}: read count",
            x.name
        );
        assert_eq!(x.p99_write_latency(), y.p99_write_latency(), "{label}/{}: p99", x.name);
        assert_eq!(
            x.migrated_pages_owned, y.migrated_pages_owned,
            "{label}/{}: owned moves",
            x.name
        );
    }
}

/// Five schemes × both scenarios, plane-loss armed at half the
/// horizon: streaming on vs off must be byte identical, fault timing
/// included. Daily exercises the idle-window reclamation path (idle
/// gaps come from the bounded queues' `next_arrival`, not a
/// materialized trace scan).
#[test]
fn multi_tenant_streaming_identical_with_plane_loss() {
    for scen in [Scenario::Bursty, Scenario::Daily] {
        for scheme in Scheme::all() {
            let a =
                MultiTenantSimulator::run_once(mt_cfg(scheme, FaultKind::PlaneLoss, true), scen)
                    .unwrap();
            let b =
                MultiTenantSimulator::run_once(mt_cfg(scheme, FaultKind::PlaneLoss, false), scen)
                    .unwrap();
            assert_mt_match(&a, &b, &format!("{scheme:?}/{scen:?}/plane-loss"));
        }
    }
}

/// The other fault flavour — a latency slowdown whose onset is also
/// horizon-derived — plus the healthy no-fault case.
#[test]
fn multi_tenant_streaming_identical_slowdown_and_healthy() {
    for fault in [FaultKind::Slowdown, FaultKind::None] {
        let a = MultiTenantSimulator::run_once(
            mt_cfg(Scheme::Ips, fault, true),
            Scenario::Bursty,
        )
        .unwrap();
        let b = MultiTenantSimulator::run_once(
            mt_cfg(Scheme::Ips, fault, false),
            Scenario::Bursty,
        )
        .unwrap();
        assert_mt_match(&a, &b, &format!("ips/bursty/{fault:?}"));
    }
}

// --- bounded residency (the tentpole's acceptance bar) --------------

/// On the streaming path the host never holds a materialized trace:
/// the peak number of ops buffered at once stays within queue window ×
/// tenants even though the workload itself is hundreds of times
/// larger.
#[test]
fn streaming_peak_resident_ops_is_window_bounded() {
    let mut cfg = mt_cfg(Scheme::Ips, FaultKind::None, true);
    cfg.host.queue_depth = 8;
    cfg.cache.slc_cache_bytes = 4 << 20;
    cfg.host.aggressor_cache_mult = 4.0; // aggressor alone issues >> 8×3 ops
    let mut sim = MultiTenantSimulator::new(cfg).unwrap();
    let summary = sim.run(Scenario::Bursty).unwrap();

    let bound = sim.resident_op_bound();
    assert_eq!(bound, 8 * 3, "window bound should be depth × tenants");
    assert!(
        sim.peak_resident_ops() <= bound,
        "peak resident ops {} exceeded the window bound {bound}",
        sim.peak_resident_ops()
    );
    let total_requests: u64 = summary
        .tenants
        .iter()
        .map(|t| t.write_latency.count() + t.read_latency.count())
        .sum();
    assert!(
        total_requests > 4 * bound as u64,
        "workload too small to make the bound meaningful ({total_requests} requests)"
    );
}
