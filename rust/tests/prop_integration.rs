//! System-level property tests: random workloads through every scheme
//! must preserve the DESIGN.md invariants (mapping bijection, ledger
//! conservation, WA ≥ 1, reprogram restrictions, breakdown closure).

use ips::config::{presets, Scheme, MS};
use ips::reliability::ReliabilityAudit;
use ips::sim::Simulator;
use ips::trace::scenario::Scenario;
use ips::trace::{OpKind, Trace, TraceOp};
use ips::util::prop::{self, tuple2, u64_up_to, usize_in, vec_of, Gen};

/// Generator of random small traces: (kind, offset page, len pages, gap).
struct TraceGen;

impl Gen for TraceGen {
    type Value = Vec<(u8, u64, u8, u32)>;
    fn gen(&self, rng: &mut ips::util::rng::Rng) -> Self::Value {
        let n = rng.range(1, 120) as usize;
        (0..n)
            .map(|_| {
                (
                    rng.below(4) as u8, // 0 => read, else write
                    rng.below(3000),
                    rng.range(1, 16) as u8,
                    rng.below(200_000_000) as u32, // gap up to 200ms
                )
            })
            .collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[1..].to_vec());
        }
        out
    }
}

fn to_trace(spec: &[(u8, u64, u8, u32)]) -> Trace {
    let mut t = 0u64;
    let ops = spec
        .iter()
        .map(|&(k, page, len, gap)| {
            t += gap as u64;
            TraceOp {
                at: t,
                kind: if k == 0 { OpKind::Read } else { OpKind::Write },
                offset: page * 4096,
                len: len as u32 * 4096,
            }
        })
        .collect();
    Trace { name: "prop".into(), ops }
}

fn check_scheme(scheme: Scheme) {
    prop::check(
        &format!("system invariants under random traces ({})", scheme.name()),
        24,
        TraceGen,
        |spec| {
            let mut cfg = presets::small();
            cfg.cache.scheme = scheme;
            cfg.cache.slc_cache_bytes = 512 << 10;
            cfg.cache.idle_threshold = 10 * MS;
            cfg.sim.verify = true; // ftl.audit() runs at end
            let max_rep = cfg.cache.max_reprograms;
            let mut sim = Simulator::new(cfg).map_err(|e| e.to_string())?;
            let trace = to_trace(spec);
            let s = sim.run(&trace, Scenario::Daily).map_err(|e| e.to_string())?;
            // WA ≥ 1 whenever anything was written
            if s.ledger.host_pages > 0 && s.wa() < 1.0 - 1e-9 {
                return Err(format!("WA {} < 1", s.wa()));
            }
            // ledger parts sum to raw array counter (checked in audit,
            // re-checked here explicitly)
            let raw = sim.ftl().array.counters().pages_programmed();
            if raw != s.ledger.total_programs() {
                return Err(format!("ledger {} != raw {raw}", s.ledger.total_programs()));
            }
            // breakdown closes
            let (a, b, c) = s.ledger.breakdown();
            if s.ledger.host_pages > 0 && (a + b + c - 1.0).abs() > 1e-9 {
                return Err(format!("breakdown {a}+{b}+{c} != 1"));
            }
            // device-study restrictions hold structurally
            ReliabilityAudit::run(&sim.ftl().array, max_rep).map_err(|e| e.to_string())?;
            Ok(())
        },
    );
}

#[test]
fn invariants_baseline() {
    check_scheme(Scheme::Baseline);
}

#[test]
fn invariants_ips() {
    check_scheme(Scheme::Ips);
}

#[test]
fn invariants_ips_agc() {
    check_scheme(Scheme::IpsAgc);
}

#[test]
fn invariants_coop() {
    check_scheme(Scheme::Coop);
}

#[test]
fn mapping_survives_random_overwrite_storm() {
    // Heavier targeted property: tight LPN range, many overwrites —
    // worst case for mapping/GC interaction.
    prop::check(
        "overwrite storm keeps mapping audit-clean",
        12,
        tuple2(u64_up_to(u64::MAX), usize_in(200, 800)),
        |&(seed, n)| {
            let mut cfg = presets::small();
            cfg.cache.scheme = Scheme::IpsAgc;
            cfg.cache.idle_threshold = 5 * MS;
            cfg.sim.verify = true;
            cfg.sim.seed = seed;
            let mut rng = ips::util::rng::Rng::new(seed);
            let mut sim = Simulator::new(cfg).map_err(|e| e.to_string())?;
            let mut t = 0u64;
            let ops: Vec<TraceOp> = (0..n)
                .map(|_| {
                    t += rng.below(50_000_000);
                    TraceOp {
                        at: t,
                        kind: OpKind::Write,
                        offset: rng.below(64) * 4096, // 64-page hot set
                        len: 4096,
                    }
                })
                .collect();
            let trace = Trace { name: "storm".into(), ops };
            sim.run(&trace, Scenario::Daily).map_err(|e| e.to_string())?;
            Ok(())
        },
    );
}

#[test]
fn shrinker_produces_valid_traces() {
    let g = TraceGen;
    let mut rng = ips::util::rng::Rng::new(1);
    let v = g.gen(&mut rng);
    for s in g.shrink(&v) {
        assert!(s.len() < v.len());
        let _ = to_trace(&s);
    }
    let _ = vec_of(u64_up_to(3), 0, 3); // module linkage sanity
}
