//! End-to-end guarantees for the block front end.
//!
//! 1. **Differential oracle**: with page-aligned requests and merging
//!    disabled, routing a trace through the bio layer must produce
//!    **byte identical** run summaries to the page front end — every
//!    scheme, bursty and daily. The blk path is a refinement, not a
//!    semantic change, in that mode.
//! 2. **Barrier cost model**: schemes whose write pointer needs no
//!    forcing (`write_barrier` is a no-op: tlc-only, ips, ips/agc) run
//!    flush-heavy workloads byte-identically to flush-free ones on the
//!    serial engine; the baseline pays the barrier in stranded SLC
//!    word lines and an earlier cache cliff.
//! 3. **Multi-tenant**: a flush-heavy workload widens the
//!    baseline-vs-IPS victim p99 gap — barriers drain the device
//!    window, and the baseline's window is full of stranded-cache TLC
//!    programs while IPS keeps absorbing at cache speed.
//! 4. **RMW closure**: a sub-page zipfian bio stream keeps the FTL
//!    read ledger exactly equal to planned read pages + RMW pre-reads.

use ips::config::{presets, Config, MixKind, SchedKind, Scheme, MS, SEC};
use ips::host::{MultiTenantSimulator, MultiTenantSummary};
use ips::metrics::RunSummary;
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::trace::synth;

fn single_cfg(scheme: Scheme, blk: bool, flush_every: u32) -> Config {
    let mut c = presets::small();
    c.cache.scheme = scheme;
    c.cache.slc_cache_bytes = 1 << 20;
    c.cache.idle_threshold = 10 * MS;
    c.sim.verify = true;
    c.sim.latency_samples = 4096;
    c.blk.enabled = blk;
    c.blk.merge_window = 0;
    c.blk.flush_every = flush_every;
    c
}

fn run_single(scheme: Scheme, scen: Scenario, blk: bool, flush_every: u32) -> RunSummary {
    let mut sim = Simulator::new(single_cfg(scheme, blk, flush_every)).unwrap();
    let trace = match scen {
        // 4x the cache: over the cliff, GC-heavy
        Scenario::Bursty => scenario::sequential_fill("seq", 4 << 20, sim.logical_bytes()),
        // idle gaps drive reclamation / AGC / coop background pipelines
        Scenario::Daily => scenario::daily_streams(3, 1 << 20, 60 * SEC, sim.logical_bytes()),
    };
    sim.run(&trace, scen).unwrap()
}

fn assert_summaries_match(a: &RunSummary, b: &RunSummary, label: &str) {
    assert_eq!(a.ledger, b.ledger, "{label}: ledger diverged");
    assert_eq!(a.sim_end, b.sim_end, "{label}: simulated end diverged");
    assert_eq!(a.host_bytes_written, b.host_bytes_written, "{label}: volume diverged");
    assert_eq!(a.write_latency.count(), b.write_latency.count(), "{label}: write count");
    assert_eq!(
        a.write_latency.mean().to_bits(),
        b.write_latency.mean().to_bits(),
        "{label}: mean write latency"
    );
    for q in [0.5, 0.95, 0.99] {
        assert_eq!(
            a.write_latency.percentile(q),
            b.write_latency.percentile(q),
            "{label}: p{q} write latency"
        );
    }
    assert_eq!(a.write_latency.raw_us(), b.write_latency.raw_us(), "{label}: raw samples");
    assert_eq!(a.read_latency.count(), b.read_latency.count(), "{label}: read count");
    assert_eq!(a.wa().to_bits(), b.wa().to_bits(), "{label}: WA");
}

#[test]
fn five_schemes_bursty_identical_blk_vs_page() {
    for scheme in Scheme::all() {
        let blk = run_single(scheme, Scenario::Bursty, true, 0);
        let page = run_single(scheme, Scenario::Bursty, false, 0);
        assert!(blk.blk.bios > 0, "{scheme:?}: bio path actually ran");
        assert!(page.blk.is_empty(), "{scheme:?}: page path stays off the bio counters");
        assert_summaries_match(&blk, &page, &format!("{scheme:?}/bursty"));
    }
}

#[test]
fn five_schemes_daily_identical_blk_vs_page() {
    for scheme in Scheme::all() {
        let blk = run_single(scheme, Scenario::Daily, true, 0);
        let page = run_single(scheme, Scenario::Daily, false, 0);
        assert!(blk.blk.bios > 0, "{scheme:?}: bio path actually ran");
        assert_summaries_match(&blk, &page, &format!("{scheme:?}/daily"));
    }
}

#[test]
fn periodic_flush_is_free_where_the_write_pointer_needs_no_forcing() {
    // tlc-only, ips, and ips/agc inherit the no-op write_barrier: their
    // write pointer survives a power-fail boundary as-is (reprogram
    // completes word lines in place), so on the serial engine a barrier
    // after every 4th write must change nothing but the flush counter
    for scheme in [Scheme::TlcOnly, Scheme::Ips, Scheme::IpsAgc] {
        let flushed = run_single(scheme, Scenario::Bursty, true, 4);
        let plain = run_single(scheme, Scenario::Bursty, true, 0);
        assert!(flushed.blk.flushes > 0, "{scheme:?}: barriers actually fired");
        assert_eq!(plain.blk.flushes, 0, "{scheme:?}: control run is barrier-free");
        assert_summaries_match(&flushed, &plain, &format!("{scheme:?}/flush-every-4"));
    }
}

#[test]
fn baseline_flush_heavy_strands_slc_and_hits_the_cliff_early() {
    // the baseline's write_barrier retires partially written active
    // blocks: their unwritten word lines are stranded, so a barrier
    // every 2 bios burns cache capacity the plain run still has —
    // fewer host pages absorbed at SLC speed, more on the TLC cliff
    let flushed = run_single(Scheme::Baseline, Scenario::Bursty, true, 2);
    let plain = run_single(Scheme::Baseline, Scenario::Bursty, true, 0);
    assert!(flushed.blk.flushes > 0);
    assert!(
        flushed.ledger.slc_cache_writes < plain.ledger.slc_cache_writes,
        "stranding must waste SLC capacity: {} absorbed with barriers vs {} without",
        flushed.ledger.slc_cache_writes,
        plain.ledger.slc_cache_writes
    );
    assert!(
        flushed.ledger.tlc_direct_writes > plain.ledger.tlc_direct_writes,
        "the pages SLC lost land on the TLC cliff: {} vs {}",
        flushed.ledger.tlc_direct_writes,
        plain.ledger.tlc_direct_writes
    );
    // same host pages either way; the flush-heavy run just pays more
    // for them where the host can see it (the end-of-run flush is NOT
    // in write_latency, and the plain run's extra migrations happen
    // there — so only host-visible latency is a sound comparison)
    assert_eq!(flushed.ledger.host_pages, plain.ledger.host_pages);
    assert!(
        flushed.write_latency.mean() > plain.write_latency.mean(),
        "TLC-speed programs must show up in mean write latency: {} vs {}",
        flushed.write_latency.mean(),
        plain.write_latency.mean()
    );
}

// --- multi-tenant ----------------------------------------------------

fn mt_run(scheme: Scheme, flush_every: u32) -> MultiTenantSummary {
    let mut cfg = presets::small();
    cfg.cache.scheme = scheme;
    // sized so the whole mix fits in cache WITHOUT barriers: the plain
    // runs stay at SLC speed under both schemes, and only the
    // baseline's stranding barrier can push anyone over the cliff
    cfg.cache.slc_cache_bytes = 2 << 20;
    cfg.host.aggressor_cache_mult = 0.25;
    cfg.host.victim_req_bytes = 4096;
    // no idle-time reclamation: its erases would dominate victim tails
    // in all four runs and drown the effect under test
    cfg.cache.idle_threshold = 10 * SEC;
    cfg.host.tenants = 4;
    cfg.host.scheduler = SchedKind::RoundRobin;
    cfg.host.mix = MixKind::AggressorVictims;
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000;
    cfg.blk.enabled = true;
    cfg.blk.merge_window = 0;
    cfg.blk.flush_every = flush_every;
    MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
}

#[test]
fn flush_heavy_widens_the_baseline_vs_ips_victim_p99_gap() {
    // The workload fits in cache, so without barriers both schemes
    // serve victims at SLC speed and the p99 gap is noise. With a
    // barrier every 2nd write, the baseline's write_barrier strands
    // its active blocks — the small pool is gone within a few bios and
    // every later victim write pays the 3 ms TLC cliff — while the
    // IPS barrier is a no-op and the drain only waits on SLC-speed
    // in-flight writes. The victim-p99 gap must widen.
    let base_flush = mt_run(Scheme::Baseline, 2);
    let ips_flush = mt_run(Scheme::Ips, 2);
    let base_plain = mt_run(Scheme::Baseline, 0);
    let ips_plain = mt_run(Scheme::Ips, 0);
    for s in [&base_flush, &ips_flush] {
        assert_eq!(s.front_end, "blk");
        assert!(s.blk.flushes > 0, "{}: barriers actually fired", s.scheme);
    }
    let gap_flush = base_flush.max_victim_p99() as i128 - ips_flush.max_victim_p99() as i128;
    let gap_plain = base_plain.max_victim_p99() as i128 - ips_plain.max_victim_p99() as i128;
    assert!(
        gap_flush > gap_plain,
        "victim p99 gap must widen under flush pressure: {gap_flush} ns with barriers \
         vs {gap_plain} ns without"
    );
}

// --- sub-page streams -------------------------------------------------

#[test]
fn zipfian_subpage_stream_closes_the_rmw_read_ledger() {
    // every FTL read in a bio run is either a planned read page or an
    // RMW pre-read — the ledger must close exactly, and a skewed
    // sub-page stream must actually exercise the RMW path
    let mut cfg = single_cfg(Scheme::Ips, true, 0);
    cfg.blk.merge_window = 8;
    let mut sim = Simulator::new(cfg).unwrap();
    let bios = synth::bio_zipf("zipf", 7, sim.logical_bytes(), 512, 4000);
    let s = sim.run_bios("zipf", bios.into_iter().map(Ok), Scenario::Bursty).unwrap();
    assert!(s.blk.rmw_reads > 0, "zipfian sizes include sub-page writes");
    assert!(s.blk.read_pages > 0, "stream mixes reads in");
    assert_eq!(
        s.ledger.host_reads,
        s.blk.read_pages + s.blk.rmw_reads,
        "every FTL read is a planned read page or an RMW pre-read"
    );
    assert_eq!(s.ledger.host_pages, s.blk.write_pages, "every host page came off a plan");
}
