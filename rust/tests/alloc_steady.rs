//! Counting-allocator assertion for the batched-dispatch scratch
//! (§Perf pass #2): once the planner scratch has grown to the largest
//! bio seen, the steady-state dispatch loop — `blk::plan_into` per bio
//! — performs **zero** heap allocations. This is the property the
//! engines' run-long `plan_buf` relies on; `plan()` allocating per bio
//! is exactly the churn the satellite removed.
//!
//! The file holds a single test: the counter is a process-global and
//! parallel sibling tests would pollute the delta.

use ips::blk::{plan, plan_into, Bio, Plan, Segment};
use ips::config::BlkConfig;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Allocation-counting wrapper around the system allocator.
struct Counting;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

const PAGE: u64 = 4096;

#[test]
fn steady_state_planning_allocates_nothing() {
    let cfg = BlkConfig { sector_bytes: 512, merge_window: 4, rmw: true, ..Default::default() };

    // a varied steady-state workload: aligned full pages, spanning
    // segments, sub-page RMW pieces, scatter-gather, flushes — all no
    // larger than the warmup bio below
    let bios: Vec<Bio> = vec![
        Bio::write(0, vec![Segment { sector: 8, n_sectors: 8 }], false),
        Bio::write(0, vec![Segment { sector: 6, n_sectors: 12 }], false),
        Bio::write(
            0,
            vec![Segment { sector: 0, n_sectors: 4 }, Segment { sector: 4, n_sectors: 4 }],
            true,
        ),
        Bio::write(0, vec![Segment { sector: 2, n_sectors: 3 }], false),
        Bio::read(0, vec![Segment { sector: 16, n_sectors: 24 }]),
        Bio::flush(0),
    ];
    // warmup: the largest shape the loop will see grows the scratch to
    // its high-water capacity
    let warm = Bio::write(0, vec![Segment { sector: 0, n_sectors: 48 }], false);

    let mut buf = Plan::default();
    plan_into(&warm, &cfg, PAGE, &mut buf);
    for b in &bios {
        plan_into(b, &cfg, PAGE, &mut buf);
    }

    // steady state: many passes over the workload, zero allocations
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..64 {
        for b in &bios {
            plan_into(b, &cfg, PAGE, &mut buf);
            std::hint::black_box(&buf);
        }
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(delta, 0, "steady-state plan_into allocated {delta} times");

    // the allocate-per-bio oracle really does churn — the counter works
    let before = ALLOCS.load(Ordering::SeqCst);
    for b in &bios {
        std::hint::black_box(plan(b, &cfg, PAGE));
    }
    assert!(
        ALLOCS.load(Ordering::SeqCst) > before,
        "plan() should allocate per bio; did the counter break?"
    );
}
