//! Counting-allocator assertion for the streaming workload path
//! (§Streaming workloads): once a [`SynthSource`] and its bounded
//! [`SubmissionQueue`] window are constructed, the steady-state
//! generate → buffer → pop loop performs **zero** heap allocations.
//! This pins both satellites at once: the per-op `weights: Vec<f64>`
//! churn the hoisted `SizeMix` table removed, and the zero-allocation
//! refill discipline of the windowed queue.
//!
//! The file holds a single test: the counter is a process-global and
//! parallel sibling tests would pollute the delta.

use ips::host::{SubmissionQueue, TenantId};
use ips::trace::source::{OpSource, SynthSource};
use ips::trace::{profiles, synth};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Allocation-counting wrapper around the system allocator.
struct Counting;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for Counting {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: Counting = Counting;

#[test]
fn steady_state_streaming_allocates_nothing() {
    let p = &profiles::ALL[0];
    let limit = 1u64 << 30;

    // --- bare source: op generation itself is allocation-free --------
    let mut src = SynthSource::new_scaled(p, 42, limit, 2e-3);
    for _ in 0..64 {
        // warmup: crosses at least one burst boundary
        std::hint::black_box(src.next_op());
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..2000 {
        let op = src.next_op().expect("source drained during steady state");
        std::hint::black_box(op);
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(delta, 0, "steady-state next_op allocated {delta} times");

    // --- windowed queue: refill + pop + resident count, still zero ---
    let src = SynthSource::new_scaled(p, 43, limit, 2e-3);
    let mut q = SubmissionQueue::from_source(TenantId(0), 8, Box::new(src));
    for _ in 0..32 {
        q.pop();
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..2000 {
        let now = q.next_arrival().expect("queue drained during steady state");
        std::hint::black_box(q.resident_bytes(now));
        std::hint::black_box(q.pop());
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(delta, 0, "steady-state queue loop allocated {delta} times");

    // --- the materializing oracle really does churn — counter works --
    let before = ALLOCS.load(Ordering::SeqCst);
    std::hint::black_box(synth::generate_scaled(p, 42, limit, 1e-4));
    assert!(
        ALLOCS.load(Ordering::SeqCst) > before,
        "generate_scaled should materialize a trace; did the counter break?"
    );
}
