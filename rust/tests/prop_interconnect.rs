//! Property suite for the interconnect timing model's differential
//! oracle: under a **degenerate geometry** (one plane per die per
//! channel) with `bus_ns_per_page = 0`, the three-level channel/die/
//! plane arbitration must collapse onto the historical per-plane lump
//! **byte-for-byte**. Random op sequences drive two FTLs in lockstep —
//! one with `sim.interconnect = true`, one with the lump — and every
//! completion (start, end, AND the queued/transfer/array phase split),
//! ledger, and resource drain point must match exactly. Failures
//! shrink to a minimal op sequence (`util::prop`).
//!
//! The contended-geometry behaviour (where the models legitimately
//! diverge) is covered by `tests/integration_interconnect.rs`.

use ips::config::{presets, Config, Scheme};
use ips::flash::{BlockMode, Lpn, PlaneId};
use ips::ftl::Ftl;
use ips::metrics::Attribution;
use ips::util::prop::{self, tuple2, u64_up_to, vec_of};

/// Raw generated op: `(kind, argument)`, interpreted by `step`.
type RawOp = (u64, u64);

const LPN_SPAN: u64 = 512;
/// First LPN used for cache-block fills (disjoint from host writes).
const CACHE_BASE: u64 = 100_000;

/// One plane per die per channel: every plane owns its die and its
/// channel, so die exclusivity degenerates to plane exclusivity and
/// (with a zero-cost bus) nothing is left for the interconnect to add.
fn degenerate_cfg(interconnect: bool) -> Config {
    let mut cfg = presets::small();
    cfg.geometry.channels = 4;
    cfg.geometry.chips_per_channel = 1;
    cfg.geometry.dies_per_chip = 1;
    cfg.geometry.planes_per_die = 1;
    cfg.timing.bus_ns_per_page = 0;
    cfg.cache.scheme = Scheme::TlcOnly;
    cfg.sim.interconnect = interconnect;
    cfg
}

struct Pair {
    /// Interconnect-backed FTL (the implementation under test).
    a: Ftl,
    /// Lump-backed oracle FTL.
    b: Ftl,
    /// LPNs written into cache blocks so far (overwrite targets).
    cache_lpns: Vec<u64>,
    /// Monotonic counter for fresh cache LPNs.
    next_cache: u64,
}

fn build_pair() -> Pair {
    Pair {
        a: Ftl::new(&degenerate_cfg(true)).unwrap(),
        b: Ftl::new(&degenerate_cfg(false)).unwrap(),
        cache_lpns: Vec::new(),
        next_cache: 0,
    }
}

/// Apply one op to both FTLs; `Err` on any observable divergence.
fn step(p: &mut Pair, op: RawOp) -> Result<(), String> {
    let planes = p.a.planes() as u64;
    let (kind, arg) = op;
    match kind % 6 {
        // host TLC write (GC may run inline; completions must match
        // including the phase split)
        0 => {
            let lpn = Lpn(arg % LPN_SPAN);
            let ra = p.a.host_write_tlc(lpn, 0);
            let rb = p.b.host_write_tlc(lpn, 0);
            match (ra, rb) {
                (Ok(ca), Ok(cb)) if ca == cb => {}
                (Err(_), Err(_)) => {}
                (ca, cb) => return Err(format!("host write diverged: {ca:?} vs {cb:?}")),
            }
        }
        // fill a fresh SLC block on a plane and close it
        1 => {
            let plane = PlaneId((arg % planes) as u32);
            let ra = p.a.alloc_block(plane, BlockMode::Slc);
            let rb = p.b.alloc_block(plane, BlockMode::Slc);
            let (ba, bb) = match (ra, rb) {
                (Ok(x), Ok(y)) => (x, y),
                (Err(_), Err(_)) => return Ok(()),
                (x, y) => return Err(format!("alloc diverged: {x:?} vs {y:?}")),
            };
            if ba != bb {
                return Err(format!("alloc picked different blocks: {ba:?} vs {bb:?}"));
            }
            for i in 0..4u64 {
                let lpn = Lpn(CACHE_BASE + p.next_cache * 4 + i);
                p.cache_lpns.push(lpn.0);
                let ca = p
                    .a
                    .program_slc_into(ba, lpn, Attribution::SlcCacheWrite, 0)
                    .map_err(|e| format!("a: slc program: {e}"))?;
                let cb = p
                    .b
                    .program_slc_into(bb, lpn, Attribution::SlcCacheWrite, 0)
                    .map_err(|e| format!("b: slc program: {e}"))?;
                if ca != cb {
                    return Err(format!("slc program diverged: {ca:?} vs {cb:?}"));
                }
            }
            p.next_cache += 1;
            p.a.register_closed(ba);
            p.b.register_closed(bb);
        }
        // overwrite a previously cached LPN (invalidations + GC churn)
        2 => {
            if p.cache_lpns.is_empty() {
                return Ok(());
            }
            let lpn = Lpn(p.cache_lpns[(arg as usize) % p.cache_lpns.len()]);
            let ra = p.a.host_write_tlc(lpn, 0);
            let rb = p.b.host_write_tlc(lpn, 0);
            match (ra, rb) {
                (Ok(ca), Ok(cb)) if ca == cb => {}
                (Err(_), Err(_)) => {}
                (ca, cb) => return Err(format!("overwrite diverged: {ca:?} vs {cb:?}")),
            }
        }
        // host read (mapped: array + data-out path; unmapped: instant)
        3 => {
            let lpn = Lpn(arg % (LPN_SPAN * 2));
            let ra = p.a.host_read(lpn, 0);
            let rb = p.b.host_read(lpn, 0);
            match (ra, rb) {
                (Ok(ca), Ok(cb)) if ca == cb => {}
                (Err(_), Err(_)) => {}
                (ca, cb) => return Err(format!("read diverged: {ca:?} vs {cb:?}")),
            }
        }
        // migrate one cached page + flush every plane's batch (the
        // grouped flush path; singleton die groups must match the
        // per-plane lump loop exactly)
        4 => {
            if p.cache_lpns.is_empty() {
                return Ok(());
            }
            let lpn = Lpn(p.cache_lpns[(arg as usize) % p.cache_lpns.len()]);
            let (sa, sb) = (p.a.map.get(lpn), p.b.map.get(lpn));
            if sa != sb {
                return Err(format!("mapping diverged for {lpn:?}: {sa:?} vs {sb:?}"));
            }
            let Some(src) = sa else { return Ok(()) };
            let ra = p.a.migrate_page(src, Attribution::GcMigration, 0);
            let rb = p.b.migrate_page(src, Attribution::GcMigration, 0);
            match (ra, rb) {
                (Ok(ca), Ok(cb)) if ca == cb => {}
                (Err(_), Err(_)) => return Ok(()),
                (ca, cb) => return Err(format!("migrate diverged: {ca:?} vs {cb:?}")),
            }
            let fa = p.a.flush_all_migration(0, Attribution::GcMigration);
            let fb = p.b.flush_all_migration(0, Attribution::GcMigration);
            match (fa, fb) {
                (Ok(ea), Ok(eb)) if ea == eb => {}
                (Err(_), Err(_)) => {}
                (ea, eb) => return Err(format!("flush diverged: {ea:?} vs {eb:?}")),
            }
        }
        // grouped reclamation: pop the greedy victim of up to two
        // planes (removing them from the closed lists / victim index)
        // and drain them as one group — whose no-multi-plane fallback
        // must be the exact sequential unit chain
        _ => {
            let p1 = (arg % planes) as u32;
            let p2 = ((arg / planes) % planes) as u32;
            let mut batch = Vec::new();
            for plane in [p1, p2] {
                if batch.iter().any(|a: &ips::flash::BlockAddr| a.plane.0 == plane) {
                    continue;
                }
                let va = p.a.pop_victim(PlaneId(plane));
                let vb = p.b.pop_victim(PlaneId(plane));
                if va != vb {
                    return Err(format!("pop_victim({plane}) diverged: {va:?} vs {vb:?}"));
                }
                if let Some(addr) = va {
                    batch.push(addr);
                }
            }
            if batch.is_empty() {
                return Ok(());
            }
            let ea = p.a.reclaim_blocks_group(&batch, Attribution::Slc2Tlc, 0);
            let eb = p.b.reclaim_blocks_group(&batch, Attribution::Slc2Tlc, 0);
            match (ea, eb) {
                (Ok(x), Ok(y)) if x == y => {}
                (Err(_), Err(_)) => {}
                (x, y) => return Err(format!("grouped reclaim diverged: {x:?} vs {y:?}")),
            }
            for &addr in &batch {
                if p.a.array.block(addr).is_erased() {
                    let _ = p.a.array.push_free(addr);
                    let _ = p.b.array.push_free(addr);
                }
            }
        }
    }
    Ok(())
}

fn final_checks(p: &mut Pair) -> Result<(), String> {
    if p.a.ledger != p.b.ledger {
        return Err(format!("ledgers diverged:\n  {:?}\n  {:?}", p.a.ledger, p.b.ledger));
    }
    if p.a.array.counters() != p.b.array.counters() {
        return Err(format!(
            "raw counters diverged:\n  {:?}\n  {:?}",
            p.a.array.counters(),
            p.b.array.counters()
        ));
    }
    if p.a.array.all_idle_at() != p.b.array.all_idle_at() {
        return Err(format!(
            "drain points diverged: {} vs {}",
            p.a.array.all_idle_at(),
            p.b.array.all_idle_at()
        ));
    }
    for pl in 0..p.a.planes() {
        let plane = PlaneId(pl);
        if p.a.array.plane_busy_until(plane) != p.b.array.plane_busy_until(plane) {
            return Err(format!("plane {pl} timelines diverged"));
        }
    }
    p.a.audit().map_err(|e| format!("interconnect audit: {e}"))?;
    p.b.audit().map_err(|e| format!("lump audit: {e}"))?;
    Ok(())
}

#[test]
fn degenerate_interconnect_is_byte_identical_to_the_lump() {
    prop::check(
        "interconnect == lump (degenerate geometry, bus 0)",
        48,
        vec_of(tuple2(u64_up_to(5), u64_up_to(1 << 16)), 0, 96),
        |ops| {
            let mut pair = build_pair();
            for &op in ops {
                step(&mut pair, op)?;
            }
            final_checks(&mut pair)
        },
    );
}

#[test]
fn degenerate_pair_with_nonzero_ops_really_exercises_the_model() {
    // a deterministic sanity pass: one of everything, checked exactly
    let mut pair = build_pair();
    for op in [(1u64, 0u64), (1, 1), (0, 7), (2, 0), (3, 7), (4, 1), (5, 0), (0, 8)] {
        step(&mut pair, op).unwrap();
    }
    final_checks(&mut pair).unwrap();
    assert!(pair.a.ledger.total_programs() > 0, "the script really programmed pages");
    assert!(pair.a.array.interconnect_enabled());
    assert!(!pair.b.array.interconnect_enabled());
}
