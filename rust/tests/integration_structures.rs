//! Differential guarantee for the hot-path data-structure pass (§Perf
//! pass #2): with `sim.flat_index` / `sim.soa_blocks` /
//! `sim.incremental_attribution` / `sim.batched_dispatch` on vs off,
//! every scheme must produce **byte identical** run summaries — ledger
//! counters, latency statistics (counts, means, percentiles, raw
//! samples), WA, simulated end time — on bursty and daily scenarios,
//! single- and multi-tenant. All four are pure layout/bookkeeping
//! changes; any divergence is a bug. Each knob is also toggled alone
//! so a regression localizes to one structure.

use ips::config::{presets, AttributionMode, Config, MixKind, SchedKind, Scheme, MS, SEC};
use ips::host::{MultiTenantSimulator, MultiTenantSummary};
use ips::metrics::RunSummary;
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};

/// The four §Perf knobs, as a mask for per-knob localization.
const KNOBS: [&str; 4] =
    ["flat_index", "soa_blocks", "incremental_attribution", "batched_dispatch"];

fn set_knob(c: &mut Config, name: &str, on: bool) {
    match name {
        "flat_index" => c.sim.flat_index = on,
        "soa_blocks" => c.sim.soa_blocks = on,
        "incremental_attribution" => c.sim.incremental_attribution = on,
        "batched_dispatch" => c.sim.batched_dispatch = on,
        other => panic!("unknown knob {other}"),
    }
}

fn single_cfg(scheme: Scheme, on: &[&str]) -> Config {
    let mut c = presets::small();
    c.cache.scheme = scheme;
    c.cache.slc_cache_bytes = 1 << 20;
    c.cache.idle_threshold = 10 * MS;
    c.sim.verify = true; // audits arenas/indices against fresh rescans
    c.sim.latency_samples = 4096;
    for k in KNOBS {
        set_knob(&mut c, k, on.contains(&k));
    }
    c
}

fn run_single(scheme: Scheme, scen: Scenario, on: &[&str]) -> RunSummary {
    let mut sim = Simulator::new(single_cfg(scheme, on)).unwrap();
    let trace = match scen {
        // 4× the cache: over the cliff, GC-heavy
        Scenario::Bursty => scenario::sequential_fill("seq", 4 << 20, sim.logical_bytes()),
        // idle gaps drive reclamation / AGC / coop background pipelines
        Scenario::Daily => scenario::daily_streams(3, 1 << 20, 60 * SEC, sim.logical_bytes()),
    };
    sim.run(&trace, scen).unwrap()
}

fn assert_summaries_match(a: &RunSummary, b: &RunSummary, label: &str) {
    assert_eq!(a.ledger, b.ledger, "{label}: ledger diverged");
    assert_eq!(a.sim_end, b.sim_end, "{label}: simulated end diverged");
    assert_eq!(a.host_bytes_written, b.host_bytes_written, "{label}: volume diverged");
    assert_eq!(a.write_latency.count(), b.write_latency.count(), "{label}: write count");
    assert_eq!(
        a.write_latency.mean().to_bits(),
        b.write_latency.mean().to_bits(),
        "{label}: mean write latency"
    );
    for q in [0.5, 0.95, 0.99] {
        assert_eq!(
            a.write_latency.percentile(q),
            b.write_latency.percentile(q),
            "{label}: p{q} write latency"
        );
    }
    assert_eq!(a.write_latency.raw_us(), b.write_latency.raw_us(), "{label}: raw samples");
    assert_eq!(a.read_latency.count(), b.read_latency.count(), "{label}: read count");
    assert_eq!(a.wa().to_bits(), b.wa().to_bits(), "{label}: WA");
}

#[test]
fn five_schemes_bursty_identical_all_knobs() {
    for scheme in Scheme::all() {
        let new = run_single(scheme, Scenario::Bursty, &KNOBS);
        let oracle = run_single(scheme, Scenario::Bursty, &[]);
        assert_summaries_match(&new, &oracle, &format!("{scheme:?}/bursty"));
    }
}

#[test]
fn five_schemes_daily_identical_all_knobs() {
    for scheme in Scheme::all() {
        let new = run_single(scheme, Scenario::Daily, &KNOBS);
        let oracle = run_single(scheme, Scenario::Daily, &[]);
        assert_summaries_match(&new, &oracle, &format!("{scheme:?}/daily"));
    }
}

#[test]
fn each_knob_alone_is_identical() {
    // one knob at a time against the all-off oracle, on the scheme that
    // exercises every structure (reprogram chain + cache + GC)
    let oracle = run_single(Scheme::Ips, Scenario::Bursty, &[]);
    for k in KNOBS {
        let one = run_single(Scheme::Ips, Scenario::Bursty, &[k]);
        assert_summaries_match(&one, &oracle, &format!("ips/bursty/{k}"));
    }
}

// --- multi-tenant ---------------------------------------------------

fn mt_cfg(scheme: Scheme, tenants: u32, attr: AttributionMode, on: bool) -> Config {
    let mut cfg = presets::small();
    cfg.cache.scheme = scheme;
    cfg.cache.slc_cache_bytes = 1 << 20;
    cfg.cache.idle_threshold = MS;
    cfg.host.tenants = tenants;
    cfg.host.scheduler = SchedKind::RoundRobin;
    cfg.host.mix = MixKind::AggressorVictims;
    cfg.host.attribution = attr;
    if attr == AttributionMode::Owner {
        // exercise the partitioner's flat argmax eviction path on top
        // of the tenant-aware victims
        cfg.cache.partition.enabled = true;
        cfg.cache.partition.reserved_frac = 0.5;
    }
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000;
    for k in KNOBS {
        set_knob(&mut cfg, k, on);
    }
    cfg
}

fn assert_mt_match(a: &MultiTenantSummary, b: &MultiTenantSummary, label: &str) {
    assert_eq!(a.ledger, b.ledger, "{label}: device ledger diverged");
    assert_eq!(a.background, b.background, "{label}: background ledger diverged");
    assert_eq!(a.sim_end, b.sim_end, "{label}: simulated end diverged");
    assert_eq!(a.host_bytes_written, b.host_bytes_written, "{label}: volume diverged");
    assert_eq!(a.wa().to_bits(), b.wa().to_bits(), "{label}: WA diverged");
    assert_eq!(a.tenants.len(), b.tenants.len());
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.ledger, y.ledger, "{label}/{}: tenant ledger", x.name);
        assert_eq!(
            x.write_latency.count(),
            y.write_latency.count(),
            "{label}/{}: write count",
            x.name
        );
        assert_eq!(x.p99_write_latency(), y.p99_write_latency(), "{label}/{}: p99", x.name);
        assert_eq!(
            x.migrated_pages_owned, y.migrated_pages_owned,
            "{label}/{}: owned moves",
            x.name
        );
    }
}

#[test]
fn multi_tenant_proportional_identical_all_knobs() {
    for scen in [Scenario::Bursty, Scenario::Daily] {
        for scheme in [Scheme::Baseline, Scheme::Ips] {
            let a = MultiTenantSimulator::run_once(
                mt_cfg(scheme, 4, AttributionMode::Proportional, true),
                scen,
            )
            .unwrap();
            let b = MultiTenantSimulator::run_once(
                mt_cfg(scheme, 4, AttributionMode::Proportional, false),
                scen,
            )
            .unwrap();
            assert_mt_match(&a, &b, &format!("{scheme:?}/{scen:?}/proportional"));
        }
    }
}

#[test]
fn multi_tenant_owner_attribution_identical_all_knobs() {
    // owner attribution turns on the TenantAware victim policy and the
    // partitioner eviction hook — the flat index tie-break and the SoA
    // owner scans both sit on this path
    for scen in [Scenario::Bursty, Scenario::Daily] {
        for scheme in [Scheme::Coop, Scheme::IpsAgc] {
            let a = MultiTenantSimulator::run_once(
                mt_cfg(scheme, 4, AttributionMode::Owner, true),
                scen,
            )
            .unwrap();
            let b = MultiTenantSimulator::run_once(
                mt_cfg(scheme, 4, AttributionMode::Owner, false),
                scen,
            )
            .unwrap();
            assert_mt_match(&a, &b, &format!("{scheme:?}/{scen:?}/owner"));
        }
    }
}

#[test]
fn single_tenant_owner_identical_all_knobs() {
    let a = MultiTenantSimulator::run_once(
        mt_cfg(Scheme::TlcOnly, 1, AttributionMode::Owner, true),
        Scenario::Daily,
    )
    .unwrap();
    let b = MultiTenantSimulator::run_once(
        mt_cfg(Scheme::TlcOnly, 1, AttributionMode::Owner, false),
        Scenario::Daily,
    )
    .unwrap();
    assert_mt_match(&a, &b, "tlc-only/daily/owner/single-tenant");
}
