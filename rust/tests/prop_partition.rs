//! Property tests for the cache partitioner and the QoS token
//! buckets, with shrinking on the generated tenant mix:
//!
//! * per-tenant slice occupancies always sum to ≤ the cache capacity;
//! * reserved slices are never cross-evicted (a tenant with headroom
//!   in its own slice is always granted an SLC allocation);
//! * token buckets never go negative (and never exceed their burst);
//! * full multi-tenant runs under any isolation variant still conserve
//!   the attribution ledger.

use ips::cache::{CacheGrant, CachePartitioner};
use ips::config::{presets, MixKind, QosConfig, QosMode, SchedKind, Scheme};
use ips::coordinator::fleet::IsolationVariant;
use ips::host::{MultiTenantSimulator, QosGate};
use ips::metrics::{Attribution, Ledger};
use ips::trace::scenario::Scenario;
use ips::util::prop::{self, Gen};
use ips::util::rng::Rng;

/// A generated tenant mix + allocation-event script for the
/// partitioner: weights per tenant, a capacity, a reserved fraction,
/// and a sequence of (tenant, event) pairs where the event is an SLC
/// allocation attempt, a reprogram write, a background release, or a
/// reclamation.
#[derive(Clone, Debug)]
struct PartitionScript {
    weights: Vec<f64>,
    capacity: u64,
    reserved_pct: u64,
    by_weight: bool,
    ops: Vec<(u8, u8)>,
}

struct PartitionGen;

impl Gen for PartitionGen {
    type Value = PartitionScript;
    fn gen(&self, rng: &mut Rng) -> PartitionScript {
        let tenants = rng.range(1, 6) as usize;
        PartitionScript {
            weights: (0..tenants).map(|_| 0.5 + rng.f64() * 4.0).collect(),
            capacity: rng.range(4, 400),
            reserved_pct: rng.range(0, 100),
            by_weight: rng.chance(0.5),
            ops: (0..rng.range(0, 300) as usize)
                .map(|_| (rng.below(8) as u8, rng.below(4) as u8))
                .collect(),
        }
    }
    fn shrink(&self, v: &PartitionScript) -> Vec<PartitionScript> {
        let mut out = Vec::new();
        if !v.ops.is_empty() {
            let mut w = v.clone();
            w.ops.truncate(v.ops.len() / 2);
            out.push(w);
            let mut w = v.clone();
            w.ops.pop();
            out.push(w);
        }
        if v.weights.len() > 1 {
            let mut w = v.clone();
            w.weights.pop();
            out.push(w);
        }
        if v.reserved_pct > 0 {
            let mut w = v.clone();
            w.reserved_pct /= 2;
            out.push(w);
        }
        out
    }
}

fn build(script: &PartitionScript) -> CachePartitioner {
    let mut cfg = presets::small();
    cfg.cache.partition.enabled = true;
    cfg.cache.partition.reserved_frac = script.reserved_pct as f64 / 100.0;
    cfg.cache.partition.by_weight = script.by_weight;
    CachePartitioner::new(&cfg, &script.weights, script.capacity)
}

#[test]
fn occupancies_sum_to_at_most_capacity_and_reserved_is_never_cross_evicted() {
    prop::check("partitioner invariants", 256, PartitionGen, |script| {
        let n = script.weights.len();
        let mut p = build(script);
        // static sanity: slices fit the capacity
        let reserved_sum: u64 = (0..n).map(|t| p.reserved(t)).sum();
        if reserved_sum > p.capacity() {
            return Err(format!("reserved {reserved_sum} > capacity {}", p.capacity()));
        }
        for (step, &(traw, ev)) in script.ops.iter().enumerate() {
            let t = traw as usize % n;
            let contended = step % 2 == 0;
            let mut diff = Ledger::default();
            match ev {
                // an SLC allocation attempt, honoring the grant like
                // the engine does
                0 => match p.grant(t, contended) {
                    CacheGrant::Slc => diff.program(Attribution::SlcCacheWrite),
                    CacheGrant::Reprogram => diff.program(Attribution::ReprogramHost),
                    CacheGrant::Tlc => diff.program(Attribution::TlcDirectWrite),
                },
                // a host-driven reprogram
                1 => diff.program(Attribution::ReprogramHost),
                // background reclamation of up to 3 pages
                2 => {
                    diff.slc2tlc_migrations = (step % 3) as u64 + 1;
                    p.charge_background(&diff);
                    diff = Ledger::default();
                }
                // an AGC reprogram feeding the window
                _ => diff.program(Attribution::AgcReprogram),
            }
            p.charge(t, &diff);
            // invariant 1: occupancies sum to ≤ capacity
            if p.total_occupancy() > p.capacity() {
                return Err(format!(
                    "step {step}: total occupancy {} > capacity {}",
                    p.total_occupancy(),
                    p.capacity()
                ));
            }
            // invariant 2: reserved slices are never cross-evicted — a
            // tenant below its reservation always gets an SLC grant
            for v in 0..n {
                if p.occupancy(v) < p.reserved(v) && p.reserved(v) < p.capacity() {
                    let g = p.grant(v, true);
                    if g != CacheGrant::Slc {
                        return Err(format!(
                            "step {step}: tenant {v} has {}/{} of its slice but was \
                             granted {g:?}",
                            p.occupancy(v),
                            p.reserved(v)
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Lockstep flat-vs-tree differential (§Perf pass #2): the same script
/// drives one partitioner on the flat argmax backend and one on the
/// BTree-index oracle; every grant decision and every occupancy
/// observable must match at every step.
#[test]
fn flat_argmax_matches_tree_backend() {
    prop::check("partitioner flat == tree", 256, PartitionGen, |script| {
        let n = script.weights.len();
        let mk = |flat: bool| {
            let mut cfg = presets::small();
            cfg.cache.partition.enabled = true;
            cfg.cache.partition.reserved_frac = script.reserved_pct as f64 / 100.0;
            cfg.cache.partition.by_weight = script.by_weight;
            cfg.sim.flat_index = flat;
            CachePartitioner::new(&cfg, &script.weights, script.capacity)
        };
        let mut pf = mk(true);
        let mut pt = mk(false);
        for (step, &(traw, ev)) in script.ops.iter().enumerate() {
            let t = traw as usize % n;
            let contended = step % 2 == 0;
            let mut diff = Ledger::default();
            match ev {
                0 => {
                    let gf = pf.grant(t, contended);
                    let gt = pt.grant(t, contended);
                    if gf != gt {
                        return Err(format!("step {step}: grant diverged: {gf:?} vs {gt:?}"));
                    }
                    match gf {
                        CacheGrant::Slc => diff.program(Attribution::SlcCacheWrite),
                        CacheGrant::Reprogram => diff.program(Attribution::ReprogramHost),
                        CacheGrant::Tlc => diff.program(Attribution::TlcDirectWrite),
                    }
                }
                1 => diff.program(Attribution::ReprogramHost),
                2 => {
                    diff.slc2tlc_migrations = (step % 3) as u64 + 1;
                    pf.charge_background(&diff);
                    pt.charge_background(&diff);
                    diff = Ledger::default();
                }
                _ => diff.program(Attribution::AgcReprogram),
            }
            pf.charge(t, &diff);
            pt.charge(t, &diff);
            if pf.total_occupancy() != pt.total_occupancy() {
                return Err(format!(
                    "step {step}: total occupancy diverged: {} vs {}",
                    pf.total_occupancy(),
                    pt.total_occupancy()
                ));
            }
            for v in 0..n {
                if pf.occupancy(v) != pt.occupancy(v) || pf.reserved(v) != pt.reserved(v) {
                    return Err(format!(
                        "step {step}: tenant {v} diverged: occ {}/{} reserved {}/{}",
                        pf.occupancy(v),
                        pt.occupancy(v),
                        pf.reserved(v),
                        pt.reserved(v)
                    ));
                }
            }
        }
        Ok(())
    });
}

/// A generated token-bucket exercise: weights, a config, and a script
/// of (tenant, dt, bytes, kind) events.
#[derive(Clone, Debug)]
struct BucketScript {
    weights: Vec<f64>,
    rate_mbps: f64,
    burst_kib: u64,
    ops: Vec<(u8, u32, u32, u8)>,
}

struct BucketGen;

impl Gen for BucketGen {
    type Value = BucketScript;
    fn gen(&self, rng: &mut Rng) -> BucketScript {
        let tenants = rng.range(1, 5) as usize;
        BucketScript {
            weights: (0..tenants).map(|_| 0.25 + rng.f64() * 4.0).collect(),
            rate_mbps: 1.0 + rng.f64() * 100.0,
            burst_kib: rng.range(4, 2048),
            ops: (0..rng.range(1, 400) as usize)
                .map(|_| {
                    (
                        rng.below(8) as u8,
                        rng.below(5_000_000) as u32,
                        rng.below(1 << 21) as u32,
                        rng.below(3) as u8,
                    )
                })
                .collect(),
        }
    }
    fn shrink(&self, v: &BucketScript) -> Vec<BucketScript> {
        let mut out = Vec::new();
        if !v.ops.is_empty() {
            let mut w = v.clone();
            w.ops.truncate(v.ops.len() / 2);
            out.push(w);
            let mut w = v.clone();
            w.ops.pop();
            out.push(w);
        }
        out
    }
}

#[test]
fn token_buckets_never_go_negative_nor_above_burst() {
    prop::check("token-bucket bounds", 256, BucketGen, |script| {
        let cfg = QosConfig {
            mode: QosMode::Strict,
            rate_mbps: script.rate_mbps,
            burst_bytes: script.burst_kib << 10,
            slo_p99: 50_000_000,
        };
        let mut gate = QosGate::new(&cfg, &script.weights);
        let n = script.weights.len();
        let mut now = 0u64;
        for &(traw, dt, bytes, kind) in &script.ops {
            let t = traw as usize % n;
            now += dt as u64;
            match kind {
                0 => {
                    let _ = gate.admit(t, bytes as u64, now, now);
                }
                1 => gate.charge(t, bytes as u64, now),
                _ => gate.record_latency(t, dt as u64, now),
            }
            for v in 0..n {
                let tokens = gate.tokens(v);
                if tokens < 0.0 {
                    return Err(format!("tenant {v} bucket went negative: {tokens}"));
                }
                if tokens > gate.burst(v) + 1e-6 {
                    return Err(format!(
                        "tenant {v} bucket {tokens} above burst {}",
                        gate.burst(v)
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Full-engine property: random (scheme, scheduler, mix, variant)
/// draws conserve the attribution ledger and keep the partitioner's
/// per-tenant reporting consistent.
#[test]
fn random_isolated_runs_conserve_attribution() {
    let schemes = [Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc, Scheme::Coop];
    let scheds = SchedKind::all();
    let mixes = MixKind::all();
    let variants = IsolationVariant::all();
    prop::check(
        "isolated attribution conservation",
        10,
        prop::vec_of(prop::usize_in(0, 1000), 4, 4),
        |draw| {
            let scheme = schemes[draw[0] % schemes.len()];
            let sched = scheds[draw[1] % scheds.len()];
            let mix = mixes[draw[2] % mixes.len()];
            let variant = variants[draw[3] % variants.len()];
            let mut cfg = presets::small();
            cfg.cache.scheme = scheme;
            cfg.cache.slc_cache_bytes = 1 << 20;
            cfg.host.tenants = 3;
            cfg.host.scheduler = sched;
            cfg.host.mix = mix;
            cfg.host.aggressor_cache_mult = 1.5;
            cfg.host.qos.rate_mbps = 8.0;
            cfg.host.qos.burst_bytes = 128 << 10;
            cfg.sim.verify = true;
            cfg.sim.seed = (draw[0] * 31 + draw[1] * 7 + draw[2] * 3 + draw[3]) as u64;
            variant.apply(&mut cfg);
            let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty)
                .map_err(|e| format!("{scheme:?}/{sched:?}/{mix:?}/{variant:?}: {e}"))?;
            let mut sum = Ledger::default();
            for t in &s.tenants {
                sum.merge(&t.ledger);
            }
            sum.merge(&s.background);
            if sum != s.ledger {
                return Err(format!(
                    "{scheme:?}/{sched:?}/{mix:?}/{variant:?}: attribution leak"
                ));
            }
            if s.write_latency.count() == 0 {
                return Err("no writes served".into());
            }
            // partition reporting is internally consistent
            if s.partitioned {
                let reserved: u64 = s.tenants.iter().map(|t| t.cache_reserved_pages).sum();
                if reserved > s.cache_capacity_pages {
                    return Err(format!(
                        "reserved {reserved} > capacity {}",
                        s.cache_capacity_pages
                    ));
                }
            } else if s.tenants.iter().any(|t| t.cache_reserved_pages != 0) {
                return Err("shared run reports reserved slices".into());
            }
            Ok(())
        },
    );
}
