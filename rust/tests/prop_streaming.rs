//! Lockstep property suite for the streaming workload sources
//! (§Streaming workloads): every [`OpSource`] must emit the **byte
//! identical** op sequence its historical materializing generator
//! produces — same count, same `TraceOp`s, same order — and report a
//! `horizon()` equal to the materialized maximum arrival, across
//! profiles × seeds × scales (with shrinking), tenant mixes, and the
//! bursty rewrite. The bounded submission-queue window is pinned
//! against a straightforward O(backlog) recomputation of
//! `resident_bytes` so the incremental count cannot drift.

use ips::config::{presets, MixKind, Nanos};
use ips::host::{tenant, SubmissionQueue, TenantId};
use ips::trace::source::{bursty_source, MaterializedSource, OpSource, SynthSource};
use ips::trace::{profiles, scenario, synth, OpKind, Trace, TraceOp};
use ips::util::prop;

fn drain<S: OpSource>(mut src: S) -> (Vec<TraceOp>, Nanos) {
    let h = src.horizon();
    let mut ops = Vec::new();
    while let Some(op) = src.next_op() {
        ops.push(op);
    }
    (ops, h)
}

fn max_at(ops: &[TraceOp]) -> Nanos {
    ops.iter().map(|o| o.at).max().unwrap_or(0)
}

fn lockstep(streamed: &[TraceOp], materialized: &[TraceOp]) -> Result<(), String> {
    if streamed.len() != materialized.len() {
        return Err(format!(
            "op count diverged: streamed {} vs materialized {}",
            streamed.len(),
            materialized.len()
        ));
    }
    for (i, (a, b)) in streamed.iter().zip(materialized).enumerate() {
        if a != b {
            return Err(format!("op {i} diverged: streamed {a:?} vs materialized {b:?}"));
        }
    }
    Ok(())
}

/// `SynthSource` vs `generate_scaled`: same ops, same horizon, for
/// every profile at random seeds and volume scales.
#[test]
fn synth_source_lockstep_across_profiles_seeds_scales() {
    let profile_idx = prop::one_of((0..profiles::ALL.len()).collect());
    let seeds = prop::u64_up_to(u64::MAX - 1);
    // small volume fractions keep each case fast; the shape of the RNG
    // walk (burst loop, break-on-target, gap draws) is scale-invariant
    let scales = prop::one_of(vec![5e-4, 1e-3, 2e-3]);
    prop::check(
        "synth source lockstep",
        24,
        prop::tuple2(prop::tuple2(profile_idx, seeds), scales),
        |&((pi, seed), scale)| {
            let p = &profiles::ALL[pi];
            let limit = 1u64 << 30;
            let (streamed, horizon) = drain(SynthSource::new_scaled(p, seed, limit, scale));
            let t = synth::generate_scaled(p, seed, limit, scale);
            lockstep(&streamed, &t.ops)?;
            if horizon != max_at(&t.ops) {
                return Err(format!(
                    "horizon {horizon} != materialized max arrival {}",
                    max_at(&t.ops)
                ));
            }
            Ok(())
        },
    );
}

/// The streaming bursty rewrite vs materialize-then-`to_bursty`.
#[test]
fn bursty_source_lockstep() {
    let profile_idx = prop::one_of((0..profiles::ALL.len()).collect());
    prop::check(
        "bursty rewrite lockstep",
        12,
        prop::tuple2(profile_idx, prop::u64_up_to(1 << 40)),
        |&(pi, seed)| {
            let p = &profiles::ALL[pi];
            let daily = synth::generate_scaled(p, seed, 1 << 28, 1e-3);
            let expect = scenario::to_bursty(&daily, 1 << 26);
            let src = bursty_source(SynthSource::new_scaled(p, seed, 1 << 28, 1e-3), 1 << 26);
            let (streamed, horizon) = drain(src);
            lockstep(&streamed, &expect.ops)?;
            if horizon != max_at(&expect.ops) {
                return Err(format!("bursty horizon {horizon} diverged"));
            }
            Ok(())
        },
    );
}

/// `build_mix_sources` vs `build_mix`: per mix × tenant count × seed,
/// every tenant's source streams its oracle trace byte for byte and
/// knows the same horizon.
#[test]
fn tenant_mix_sources_lockstep() {
    let mixes = prop::one_of(MixKind::all().to_vec());
    let tenants = prop::usize_in(1, 6);
    prop::check(
        "tenant mix sources lockstep",
        24,
        prop::tuple2(prop::tuple2(mixes, tenants), prop::u64_up_to(1 << 40)),
        |&((mix, n), seed)| {
            let mut cfg = presets::small();
            cfg.host.mix = mix;
            cfg.host.tenants = n as u32;
            let logical = 48u64 << 20;
            let (specs_t, traces) =
                tenant::build_mix(&cfg, logical, seed).map_err(|e| e.to_string())?;
            let (specs_s, sources) =
                tenant::build_mix_sources(&cfg, logical, seed).map_err(|e| e.to_string())?;
            if specs_t.len() != specs_s.len() {
                return Err("spec count diverged".into());
            }
            for ((st, ss), (trace, mut src)) in
                specs_t.iter().zip(&specs_s).zip(traces.into_iter().zip(sources))
            {
                if st.name != ss.name || st.weight.to_bits() != ss.weight.to_bits() {
                    return Err(format!("{mix:?}: spec {} diverged", st.name));
                }
                let h = src.horizon();
                let mut got = Vec::new();
                while let Some(op) = src.next_op() {
                    got.push(op);
                }
                lockstep(&got, &trace.ops).map_err(|e| format!("{mix:?}/{}: {e}", st.name))?;
                if h != max_at(&trace.ops) {
                    return Err(format!("{mix:?}/{}: horizon {h} diverged", st.name));
                }
            }
            Ok(())
        },
    );
}

/// The bounded queue window drains any source in exact order, never
/// buffers more than `depth`, and its incremental `resident_bytes`
/// matches a from-scratch O(backlog) recomputation at every probe —
/// the satellite's no-rescan count can't drift from the old semantics.
#[test]
fn queue_window_resident_bytes_matches_scan_oracle() {
    // arrival gaps (ns) build an arrival-sorted trace; depth varies
    let gaps = prop::vec_of(prop::u64_up_to(300), 1, 64);
    let depths = prop::usize_in(1, 12);
    prop::check(
        "queue resident-bytes oracle",
        48,
        prop::tuple2(gaps, depths),
        |(gaps, depth)| {
            let depth = *depth;
            let mut at = 0u64;
            let ops: Vec<TraceOp> = gaps
                .iter()
                .enumerate()
                .map(|(i, &g)| {
                    at += g;
                    TraceOp {
                        at,
                        kind: OpKind::Write,
                        offset: (i as u64) * 4096,
                        len: 4096 * (1 + (i as u32 % 3)),
                    }
                })
                .collect();
            let trace = Trace { name: "prop".into(), ops: ops.clone() };
            let mut q = SubmissionQueue::from_source(
                TenantId(0),
                depth,
                Box::new(MaterializedSource::new(trace)),
            );
            // replay: walk time forward, popping ready heads, probing
            // the incremental count against the historical scan of the
            // *remaining* op list at every step
            let mut remaining: std::collections::VecDeque<TraceOp> = ops.into();
            let mut now = 0u64;
            let mut popped = 0usize;
            loop {
                let scan: u64 = remaining
                    .iter()
                    .take(depth)
                    .take_while(|op| op.at <= now)
                    .map(|op| op.len as u64)
                    .sum();
                let inc = q.resident_bytes(now);
                if inc != scan {
                    return Err(format!(
                        "resident_bytes diverged at now={now} (popped {popped}): \
                         incremental {inc} vs scan {scan}"
                    ));
                }
                if q.backlog() > depth.max(1) {
                    return Err(format!("window exceeded depth: {}", q.backlog()));
                }
                if q.head_ready(now) {
                    let op = q.pop().ok_or("ready head missing")?;
                    let expect = remaining.pop_front().ok_or("oracle drained early")?;
                    if op != expect {
                        return Err(format!("pop order diverged: {op:?} vs {expect:?}"));
                    }
                    popped += 1;
                } else {
                    match q.next_arrival() {
                        Some(next) => now = now.max(next),
                        None => break,
                    }
                }
            }
            if !remaining.is_empty() {
                return Err("queue drained before the oracle".into());
            }
            Ok(())
        },
    );
}
