//! Integration guarantees for the interconnect timing model:
//!
//! * the **degenerate-geometry identity** — with `bus_ns_per_page = 0`
//!   and one plane per die per channel, `sim.interconnect = true` must
//!   produce **byte identical** run summaries to the plane-lump model,
//!   for every scheme, bursty AND daily, single- and multi-tenant
//!   (this is the oracle that says the refactor changed the *model*,
//!   not the simulator);
//! * the **headline** — under a contended geometry (4 channels,
//!   2 dies/chip, 2 planes/die, nonzero bus time), IPS's page-granular
//!   in-place switch beats the baseline's block-granular reclamation
//!   by MORE than the lump model could see: the victim-p99 ratio
//!   (baseline / ips) grows when channel-bus serialization and
//!   die-level exclusivity become visible;
//! * **phase reporting** — interconnect runs attribute per-tenant
//!   queued / transfer / array time, and the fleet tables carry it.

use ips::config::{presets, Config, MixKind, SchedKind, Scheme, MS, SEC, US};
use ips::coordinator::fleet;
use ips::host::{MultiTenantSimulator, MultiTenantSummary};
use ips::metrics::RunSummary;
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};

/// One plane per die per channel + zero-cost bus: the degenerate
/// geometry under which the interconnect model must collapse onto the
/// lump exactly.
fn degenerate_cfg(scheme: Scheme, interconnect: bool) -> Config {
    let mut c = presets::small();
    c.geometry.channels = 4;
    c.geometry.chips_per_channel = 1;
    c.geometry.dies_per_chip = 1;
    c.geometry.planes_per_die = 1;
    c.timing.bus_ns_per_page = 0;
    c.cache.scheme = scheme;
    c.cache.slc_cache_bytes = 1 << 20;
    c.cache.idle_threshold = 10 * MS;
    c.sim.verify = true;
    c.sim.latency_samples = 4096;
    c.sim.interconnect = interconnect;
    c
}

fn run_single(scheme: Scheme, scen: Scenario, interconnect: bool) -> RunSummary {
    let mut sim = Simulator::new(degenerate_cfg(scheme, interconnect)).unwrap();
    let trace = match scen {
        Scenario::Bursty => scenario::sequential_fill("seq", 4 << 20, sim.logical_bytes()),
        Scenario::Daily => scenario::daily_streams(3, 1 << 20, 60 * SEC, sim.logical_bytes()),
    };
    sim.run(&trace, scen).unwrap()
}

fn assert_summaries_match(a: &RunSummary, b: &RunSummary, label: &str) {
    assert_eq!(a.ledger, b.ledger, "{label}: ledger diverged");
    assert_eq!(a.sim_end, b.sim_end, "{label}: simulated end diverged");
    assert_eq!(a.host_bytes_written, b.host_bytes_written, "{label}: volume diverged");
    assert_eq!(a.host_bytes_read, b.host_bytes_read, "{label}: read volume diverged");
    assert_eq!(a.write_latency.count(), b.write_latency.count(), "{label}: write count");
    assert_eq!(
        a.write_latency.mean().to_bits(),
        b.write_latency.mean().to_bits(),
        "{label}: mean write latency"
    );
    for q in [0.5, 0.95, 0.99] {
        assert_eq!(
            a.write_latency.percentile(q),
            b.write_latency.percentile(q),
            "{label}: p{q} write latency"
        );
    }
    assert_eq!(a.write_latency.raw_us(), b.write_latency.raw_us(), "{label}: raw samples");
    assert_eq!(a.read_latency.count(), b.read_latency.count(), "{label}: read count");
    // the phase split itself is part of the identity: the degenerate
    // interconnect attributes exactly what the lump attributed
    assert_eq!(a.write_phases, b.write_phases, "{label}: write phase split");
    assert_eq!(a.read_phases, b.read_phases, "{label}: read phase split");
    assert_eq!(a.write_phases.transfer_ns, 0, "{label}: zero-cost bus moves nothing");
    assert_eq!(a.wa().to_bits(), b.wa().to_bits(), "{label}: WA");
}

#[test]
fn five_schemes_bursty_identical_with_degenerate_interconnect() {
    for scheme in Scheme::all() {
        let ic = run_single(scheme, Scenario::Bursty, true);
        let lump = run_single(scheme, Scenario::Bursty, false);
        assert_summaries_match(&ic, &lump, &format!("{scheme:?}/bursty"));
    }
}

#[test]
fn five_schemes_daily_identical_with_degenerate_interconnect() {
    for scheme in Scheme::all() {
        let ic = run_single(scheme, Scenario::Daily, true);
        let lump = run_single(scheme, Scenario::Daily, false);
        assert_summaries_match(&ic, &lump, &format!("{scheme:?}/daily"));
    }
}

// --- multi-tenant identity ------------------------------------------

fn mt_degenerate_cfg(scheme: Scheme, tenants: u32, interconnect: bool) -> Config {
    let mut cfg = degenerate_cfg(scheme, interconnect);
    cfg.cache.idle_threshold = MS;
    cfg.host.tenants = tenants;
    cfg.host.scheduler = SchedKind::RoundRobin;
    cfg.host.mix = MixKind::AggressorVictims;
    cfg.sim.latency_samples = 100_000;
    cfg
}

fn assert_mt_match(a: &MultiTenantSummary, b: &MultiTenantSummary, label: &str) {
    assert_eq!(a.ledger, b.ledger, "{label}: device ledger diverged");
    assert_eq!(a.background, b.background, "{label}: background ledger diverged");
    assert_eq!(a.sim_end, b.sim_end, "{label}: simulated end diverged");
    assert_eq!(a.host_bytes_written, b.host_bytes_written, "{label}: volume diverged");
    assert_eq!(a.write_phases, b.write_phases, "{label}: device phase split");
    assert_eq!(a.wa().to_bits(), b.wa().to_bits(), "{label}: WA diverged");
    assert_eq!(a.tenants.len(), b.tenants.len());
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.ledger, y.ledger, "{label}/{}: tenant ledger", x.name);
        assert_eq!(
            x.write_latency.count(),
            y.write_latency.count(),
            "{label}/{}: write count",
            x.name
        );
        assert_eq!(x.p99_write_latency(), y.p99_write_latency(), "{label}/{}: p99", x.name);
        assert_eq!(x.write_phases, y.write_phases, "{label}/{}: phase split", x.name);
    }
}

#[test]
fn multi_tenant_degenerate_interconnect_identical() {
    for scen in [Scenario::Bursty, Scenario::Daily] {
        for scheme in [Scheme::Baseline, Scheme::Ips] {
            let ic = MultiTenantSimulator::run_once(
                mt_degenerate_cfg(scheme, 4, true),
                scen,
            )
            .unwrap();
            let lump = MultiTenantSimulator::run_once(
                mt_degenerate_cfg(scheme, 4, false),
                scen,
            )
            .unwrap();
            assert_eq!(ic.timing_model, "interconnect");
            assert_eq!(lump.timing_model, "lump");
            assert_mt_match(&ic, &lump, &format!("{scheme:?}/{scen:?}"));
        }
    }
}

#[test]
fn single_tenant_degenerate_interconnect_identical() {
    let ic = MultiTenantSimulator::run_once(
        mt_degenerate_cfg(Scheme::IpsAgc, 1, true),
        Scenario::Daily,
    )
    .unwrap();
    let lump = MultiTenantSimulator::run_once(
        mt_degenerate_cfg(Scheme::IpsAgc, 1, false),
        Scenario::Daily,
    )
    .unwrap();
    assert_mt_match(&ic, &lump, "ips-agc/daily/single-tenant");
}

// --- the headline: contention the lump could not see -----------------

/// Contended geometry: 4 channels × 2 dies/chip × 2 planes/die — the
/// acceptance shape (≥ 4 channels, ≥ 2 dies/chip, nonzero bus time).
fn contended_cfg(scheme: Scheme, interconnect: bool) -> Config {
    let mut cfg = presets::small();
    cfg.geometry.channels = 4;
    cfg.geometry.chips_per_channel = 1;
    cfg.geometry.dies_per_chip = 2;
    cfg.geometry.planes_per_die = 2;
    cfg.timing.bus_ns_per_page = 20 * US;
    cfg.cache.scheme = scheme;
    cfg.cache.slc_cache_bytes = 1 << 20;
    cfg.cache.idle_threshold = MS;
    cfg.host.tenants = 4;
    cfg.host.scheduler = SchedKind::RoundRobin;
    cfg.host.mix = MixKind::AggressorVictims;
    // a 2× burst ends well before the paced victims do (4 ms gaps ×
    // ≥ 64 requests ≈ 256 ms of victim arrivals), so the baseline's
    // idle-window reclamation runs INTO live victim traffic — the
    // Fig. 7 conflict the headline measures; device_qd = 1 keeps
    // burst-era queueing out of the victims' tail so the reclamation
    // conflict is what p99 sees under both timing models
    cfg.host.aggressor_cache_mult = 2.0;
    cfg.host.victim_gap = 4 * MS;
    cfg.host.device_qd = 1;
    cfg.sim.verify = true;
    cfg.sim.latency_samples = 100_000;
    cfg.sim.interconnect = interconnect;
    cfg
}

fn victim_p99(scheme: Scheme, interconnect: bool) -> f64 {
    let s = MultiTenantSimulator::run_once(
        contended_cfg(scheme, interconnect),
        Scenario::Daily,
    )
    .unwrap();
    (s.max_victim_p99() as f64).max(1.0)
}

#[test]
fn ips_beats_baseline_by_more_once_the_interconnect_is_visible() {
    // Daily aggressor+victims: the aggressor's burst fills the cache,
    // and the baseline reclaims it in idle windows the paced victims
    // keep arriving into (the Fig. 7 conflict). Under the lump, a
    // reclamation unit only occupies its own plane; under the
    // interconnect it also holds the die and pushes reads+programs
    // over the shared channel bus — so the victims' tail under the
    // baseline grows by more than under IPS, whose in-place switch
    // moves no data at all.
    let base_lump = victim_p99(Scheme::Baseline, false);
    let base_ic = victim_p99(Scheme::Baseline, true);
    let ips_lump = victim_p99(Scheme::Ips, false);
    let ips_ic = victim_p99(Scheme::Ips, true);
    let ratio_lump = base_lump / ips_lump;
    let ratio_ic = base_ic / ips_ic;
    println!(
        "victim p99 ms — baseline: lump {:.3} ic {:.3}; ips: lump {:.3} ic {:.3}; \
         ratio lump {ratio_lump:.3} -> ic {ratio_ic:.3}",
        base_lump / 1e6,
        base_ic / 1e6,
        ips_lump / 1e6,
        ips_ic / 1e6,
    );
    assert!(
        base_ic > base_lump,
        "bus+die contention must worsen the baseline's victim tail: \
         ic {base_ic} vs lump {base_lump}"
    );
    assert!(
        ratio_ic > ratio_lump,
        "IPS's advantage must GROW when the interconnect is modelled: \
         baseline/ips p99 ratio {ratio_ic:.3} (interconnect) vs {ratio_lump:.3} (lump)"
    );
}

#[test]
fn contended_run_reports_per_tenant_phase_breakdown() {
    let s = MultiTenantSimulator::run_once(
        contended_cfg(Scheme::Ips, true),
        Scenario::Bursty,
    )
    .unwrap();
    assert_eq!(s.timing_model, "interconnect");
    for t in &s.tenants {
        assert!(t.write_phases.ops > 0, "{}: phases attributed", t.name);
        assert!(t.write_phases.transfer_ns > 0, "{}: bus time visible", t.name);
        assert!(t.write_phases.array_ns > 0, "{}: array time visible", t.name);
    }
    // the fleet's per-tenant table carries the breakdown columns
    let rendered = fleet::tenant_table(&s).render();
    for col in ["q_ms", "xfer_ms", "arr_ms"] {
        assert!(rendered.contains(col), "tenant table misses {col}");
    }
    // and the device-wide summary table does too
    let rendered = fleet::summary_table(std::slice::from_ref(&s)).render();
    for col in ["q_ms", "xfer_ms", "arr_ms"] {
        assert!(rendered.contains(col), "summary table misses {col}");
    }
}
