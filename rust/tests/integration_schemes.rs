//! Cross-module integration: full simulations per scheme, asserting
//! the paper's qualitative results and the end-of-run invariants.

use ips::config::{presets, Config, Scheme, MS, SEC};
use ips::reliability::ReliabilityAudit;
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::trace::{profiles, synth};

fn cfg(scheme: Scheme) -> Config {
    let mut c = presets::small();
    c.cache.scheme = scheme;
    c.cache.slc_cache_bytes = 1 << 20;
    c.cache.idle_threshold = 10 * MS;
    c.sim.verify = true; // full audit at end of every run
    c
}

fn run(scheme: Scheme, scen: Scenario, volume: u64) -> ips::metrics::RunSummary {
    let c = cfg(scheme);
    let mut sim = Simulator::new(c).unwrap();
    let trace = scenario::sequential_fill("seq", volume, sim.logical_bytes());
    sim.run(&trace, scen).unwrap()
}

#[test]
fn bursty_ips_beats_baseline_beyond_cache() {
    let vol = 4u64 << 20; // 4x the 1 MiB cache
    let base = run(Scheme::Baseline, Scenario::Bursty, vol);
    let ips = run(Scheme::Ips, Scenario::Bursty, vol);
    let ratio = ips.mean_write_latency() / base.mean_write_latency();
    assert!(ratio < 0.95, "paper Fig. 10a direction: ratio={ratio:.3}");
}

#[test]
fn daily_wa_ordering_matches_paper() {
    // baseline migrates (~2x), IPS keeps ~1, IPS/agc in between
    let c = cfg(Scheme::Baseline);
    let p = profiles::by_name("HM_0").unwrap();
    let mk = |scheme| {
        let mut sim = Simulator::new(cfg(scheme)).unwrap();
        let t = synth::generate_scaled(p, 3, sim.logical_bytes(), 0.0008);
        sim.run(&t, Scenario::Daily).unwrap()
    };
    let base = mk(Scheme::Baseline);
    let ips = mk(Scheme::Ips);
    let agc = mk(Scheme::IpsAgc);
    assert!(base.wa() > 1.3, "baseline daily amplifies: {}", base.wa());
    assert!(ips.wa() < 1.05, "IPS daily stays ~1: {}", ips.wa());
    assert!(agc.wa() >= ips.wa() - 1e-9, "AGC adds (bounded) WA");
    let _ = c;
}

#[test]
fn reliability_restrictions_hold_after_every_scheme() {
    for scheme in [Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc, Scheme::Coop] {
        let c = cfg(scheme);
        let max_rep = c.cache.max_reprograms;
        let mut sim = Simulator::new(c).unwrap();
        let trace = scenario::sequential_fill("seq", 3 << 20, sim.logical_bytes());
        sim.run(&trace, Scenario::Daily).unwrap();
        let audit = ReliabilityAudit::run(&sim.ftl().array, max_rep)
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        assert!(audit.max_reprograms <= 2, "{scheme:?}");
        if matches!(scheme, Scheme::Ips | Scheme::IpsAgc | Scheme::Coop) {
            assert!(audit.ips_blocks > 0, "{scheme:?} used IPS blocks");
        }
    }
}

#[test]
fn coop_outlives_cache_exhaustion_and_flushes() {
    let mut c = cfg(Scheme::Coop);
    c.cache.ips_block_fraction = 0.4;
    let mut sim = Simulator::new(c).unwrap();
    // write 8 MiB through a ~1 MiB trad + small IPS cache with idle gaps
    let trace = scenario::daily_streams(4, 2 << 20, 30 * SEC, sim.logical_bytes());
    let s = sim.run(&trace, Scenario::Daily).unwrap();
    assert!(s.ledger.host_pages >= (8 << 20) / 4096);
    assert!(
        s.ledger.coop_reprogram_writes + s.ledger.slc2tlc_migrations > 0,
        "trad cache was drained one way or the other"
    );
}

#[test]
fn tlc_only_is_the_latency_floor_scheme() {
    let vol = 2u64 << 20;
    let tlc = run(Scheme::TlcOnly, Scenario::Bursty, vol);
    let base = run(Scheme::Baseline, Scenario::Bursty, vol);
    // with volume 2x cache, baseline still beats raw TLC on average
    assert!(base.mean_write_latency() < tlc.mean_write_latency());
    assert!((tlc.wa() - 1.0).abs() < 1e-9);
}

#[test]
fn deterministic_runs_same_seed() {
    let p = profiles::by_name("PRXY_0").unwrap();
    let mk = || {
        let mut sim = Simulator::new(cfg(Scheme::IpsAgc)).unwrap();
        let t = synth::generate_scaled(p, 9, sim.logical_bytes(), 0.0008);
        sim.run(&t, Scenario::Daily).unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.ledger, b.ledger);
    assert_eq!(a.sim_end, b.sim_end);
    assert_eq!(a.write_latency.count(), b.write_latency.count());
}

#[test]
fn read_after_write_everywhere() {
    // every written LPN remains readable at flash speed after heavy
    // churn across all schemes (mapping integrity end to end)
    for scheme in [Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc, Scheme::Coop] {
        let mut sim = Simulator::new(cfg(scheme)).unwrap();
        let mut trace = scenario::sequential_fill("seq", 2 << 20, sim.logical_bytes());
        let dur = trace.duration();
        // read back the first 64 pages after a long idle gap
        for i in 0..64u64 {
            trace.ops.push(ips::trace::TraceOp {
                at: dur + 60 * SEC + i,
                kind: ips::trace::OpKind::Read,
                offset: i * 4096,
                len: 4096,
            });
        }
        let s = sim.run(&trace, Scenario::Daily).unwrap();
        assert_eq!(s.read_latency.count(), 64, "{scheme:?}");
        assert!(s.read_latency.min() > 0, "{scheme:?}: reads hit flash, not a hole");
    }
}
