//! Acceptance test for the device-population fleet axis (PR 7): eight
//! heterogeneous devices (capacity / OP / pre-aged wear) run every
//! scheme on the aggressor+victims mix, per-device histograms fold into
//! fleet-wide percentiles by pure merges, and the rollup is
//! byte-identical whether the population ran on one thread or eight.

use ips::config::presets;
use ips::coordinator::fleet::{
    device_table, fold_population, population_csv, population_json, population_table,
    run_population, run_population_streaming, PopulationSpec,
};

fn population(devices: u32, threads: usize) -> PopulationSpec {
    let mut base = presets::small();
    base.cache.slc_cache_bytes = 1 << 20;
    base.host.tenants = 3; // 1 aggressor + 2 victims
    base.host.aggressor_cache_mult = 1.5;
    PopulationSpec::heterogeneous(base, devices, 42, threads)
}

#[test]
fn fleet_rollup_is_byte_identical_serial_vs_parallel() {
    let spec = population(8, 1);
    assert_eq!(spec.schemes.len(), 5, "all schemes ride the population");
    let serial = run_population(&spec).unwrap();
    let parallel = run_population(&population(8, 8)).unwrap();
    assert_eq!(serial.len(), 5 * 8, "5 schemes x 8 devices");

    let a = fold_population(&serial);
    let b = fold_population(&parallel);
    let ja = population_json(&a);
    let jb = population_json(&b);
    assert_eq!(ja, jb, "fleet JSON is thread-count-invariant, byte for byte");
    assert_eq!(
        population_csv(&a),
        population_csv(&b),
        "and so is the CSV export"
    );
    assert_eq!(
        population_table(&a).render(),
        population_table(&b).render(),
        "and the rendered table"
    );
    assert!(ja.starts_with("{\"rows\":["));
    for scheme in ["tlc-only", "baseline", "ips", "ips-agc", "coop"] {
        assert!(ja.contains(&format!("\"scheme\":\"{scheme}\"")), "{scheme} row present");
    }

    // every cell folded the whole population and its quantiles are
    // bounded by what was actually observed (the PR-7 clamp fix)
    assert_eq!(a.len(), 5);
    for c in &a {
        assert_eq!(c.devices, 8);
        assert!(c.write_latency.count() > 0);
        assert!(c.victim_latency.count() > 0, "{}: victim tenants folded", c.scheme);
        for q in [0.5, 0.9, 0.99, 0.999, 1.0] {
            let p = c.write_latency.percentile(q);
            assert!(p >= c.write_latency.min() && p <= c.write_latency.max());
        }
        assert!(c.victim_latency.percentile(0.999) >= c.victim_latency.percentile(0.99));
    }
}

#[test]
fn fleet_path_never_carries_raw_sample_vectors() {
    let runs = run_population(&population(8, 4)).unwrap();
    for r in &runs {
        assert!(
            r.summary.write_latency.raw_us().is_empty(),
            "{} device {}: fleet devices must not retain raw vectors",
            r.scheme.name(),
            r.profile.device
        );
        assert!(r.summary.read_latency.raw_us().is_empty());
        for t in &r.summary.tenants {
            assert!(t.write_latency.raw_us().is_empty());
            assert!(t.read_latency.raw_us().is_empty());
        }
    }
    // the per-device detail view renders the heterogeneity axes
    let detail = device_table(&runs).render();
    for col in ["bpp", "logical_frac", "pre_age", "victim_p99_ms"] {
        assert!(detail.contains(col), "device table lists {col}");
    }
}

#[test]
fn faulted_streaming_rollup_is_byte_identical_and_memory_bounded() {
    // PR 8 acceptance: a faulted population streams its fold — devices
    // are folded and dropped as they finish — and the rollup is
    // byte-identical to the collect-then-fold path at any thread count.
    let mut spec = population(8, 1);
    spec.fault_rate = 0.5;
    let mut par = population(8, 8);
    par.fault_rate = 0.5;
    let runs = run_population(&spec).unwrap();
    let reference = population_json(&fold_population(&runs));
    let (c1, csv1, st1) = run_population_streaming(&spec).unwrap();
    let (c8, csv8, st8) = run_population_streaming(&par).unwrap();
    assert_eq!(population_json(&c1), reference, "streaming == collected, serially");
    assert_eq!(population_json(&c8), reference, "and on 8 threads, byte for byte");
    assert_eq!(csv1, csv8, "per-device row stream is order-deterministic");
    assert_eq!(st1.runs, 5 * 8, "5 schemes x 8 devices");
    // bounded memory: the resident-run high-water never exceeds one
    // run per worker — far below the 40-run population
    assert_eq!(st1.peak_resident_runs, 1, "serial streams one run at a time");
    assert!(st8.peak_resident_runs <= 8, "<= one resident run per worker");
    for c in &c1 {
        assert_eq!(
            c.devices_healthy + c.devices_faulted,
            c.devices,
            "{}: the healthy/faulted split partitions the population",
            c.scheme
        );
    }
    assert!(reference.contains("\"healthy_victim_p99_ms\""));
    assert!(reference.contains("\"faulted_victim_p99_ms\""));
}

#[test]
fn population_is_heterogeneous_and_paired_across_schemes() {
    let spec = population(8, 1);
    let profiles = spec.profiles();
    assert_eq!(profiles.len(), 8);
    let distinct = |f: &dyn Fn(&ips::coordinator::fleet::DeviceProfile) -> u64| {
        let mut v: Vec<u64> = profiles.iter().map(f).collect();
        v.sort_unstable();
        v.dedup();
        v.len()
    };
    assert!(distinct(&|p| p.blocks_per_plane as u64) >= 2, "capacity varies");
    assert!(distinct(&|p| (p.logical_frac * 100.0) as u64) >= 3, "OP varies");
    assert!(distinct(&|p| p.pre_age_erases as u64) >= 3, "wear varies");

    // pairing: each scheme's 8 devices are the same 8 devices, so the
    // cross-scheme comparison isolates the scheme from the hardware
    let runs = run_population(&spec).unwrap();
    for scheme_runs in runs.chunks(8) {
        let devs: Vec<_> = scheme_runs.iter().map(|r| r.profile).collect();
        assert_eq!(devs, profiles, "{}: same population", scheme_runs[0].scheme.name());
    }
}
