//! Bench: Fig. 12 end-to-end — cooperative design vs big-cache
//! baseline, bursty volume point + daily cell.
use ips::coordinator::{experiment, ExpOptions};
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };
    let coop = experiment::coop_config(&opts);
    let base = experiment::baseline64_config(&opts);
    let cache = base.cache.slc_cache_bytes;
    for (cfg, tag) in [(&coop, "coop"), (&base, "baseline64")] {
        h.bench(&format!("fig12a/bursty-2x-cache/{tag}"), None, || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let t = scenario::sequential_fill("f12", cache * 2, sim.logical_bytes());
            black_box(sim.run(&t, Scenario::Bursty).unwrap());
        });
        h.bench(&format!("fig12b/daily-HM_0/{tag}"), None, || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let t = experiment::workload_trace(&opts, "HM_0", sim.logical_bytes()).unwrap();
            black_box(sim.run(&t, Scenario::Daily).unwrap());
        });
    }
    h.finish();
}
