//! Bench: the block front end — page vs bio path on the same trace
//! (the planner's overhead when it degenerates to the page walk), a
//! skewed sub-page stream (split/merge/RMW all hot), and an
//! object-store scatter-gather PUT/GET mix with flush barriers.
//!
//! Under `IPS_BENCH_SMOKE=1` the deterministic counters of every run —
//! ledger pages, RMW pre-reads, merges, flushes, WA — gate against a
//! golden snapshot, so a planner change that shifts what the FTL sees
//! fails CI instead of silently bending figures.
use ips::config::{presets, Scheme, MS};
use ips::metrics::RunSummary;
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::trace::synth;
use ips::util::bench::{black_box, Harness};
use ips::util::golden;

fn cfg(scheme: Scheme, blk: bool) -> ips::config::Config {
    let mut c = presets::small();
    c.cache.scheme = scheme;
    c.cache.slc_cache_bytes = 1 << 20;
    c.cache.idle_threshold = 10 * MS;
    c.blk.enabled = blk;
    c.blk.merge_window = if blk { 8 } else { 0 };
    c
}

fn main() {
    let mut h = Harness::new();
    let mut rows: Vec<(String, RunSummary)> = Vec::new();

    // page vs bio front end on one page-aligned trace: the bio path's
    // planning overhead, isolated (identical flash work by the
    // integration_blk differential)
    for (label, blk) in [("bio/page-fe", false), ("bio/blk-fe", true)] {
        let mut c = cfg(Scheme::Ips, blk);
        c.blk.merge_window = 0;
        let trace = {
            let sim = Simulator::new(c.clone()).unwrap();
            scenario::sequential_fill("seq", 4 << 20, sim.logical_bytes())
        };
        let mut last = None;
        h.bench(label, Some(trace.ops.len() as u64), || {
            let mut sim = Simulator::new(c.clone()).unwrap();
            let s = sim.run(&trace, Scenario::Bursty).unwrap();
            black_box(s.sim_end);
            last = Some(s);
        });
        if let Some(s) = last {
            rows.push((label.to_string(), s));
        }
    }

    // skewed sub-page writes: every planner path (split, merge, RMW
    // pre-read) on a zipfian sector stream
    {
        let c = cfg(Scheme::Ips, true);
        let footprint = Simulator::new(c.clone()).unwrap().logical_bytes();
        let bios = synth::bio_zipf("bench", 42, footprint, 512, 20_000);
        let mut last = None;
        h.bench("bio/zipf-subpage", Some(bios.len() as u64), || {
            let mut sim = Simulator::new(c.clone()).unwrap();
            let s = sim
                .run_bios("zipf", bios.iter().cloned().map(Ok), Scenario::Bursty)
                .unwrap();
            black_box(s.blk.rmw_reads);
            last = Some(s);
        });
        if let Some(s) = last {
            rows.push(("bio/zipf-subpage".to_string(), s));
        }
    }

    // scatter-gather PUTs + point GETs + explicit flush barriers
    {
        let c = cfg(Scheme::Ips, true);
        let footprint = Simulator::new(c.clone()).unwrap().logical_bytes();
        let bios = synth::bio_object_store("bench", 42, footprint, 512, 20_000);
        let mut last = None;
        h.bench("bio/object-store", Some(bios.len() as u64), || {
            let mut sim = Simulator::new(c.clone()).unwrap();
            let s = sim
                .run_bios("objstore", bios.iter().cloned().map(Ok), Scenario::Bursty)
                .unwrap();
            black_box(s.blk.flushes);
            last = Some(s);
        });
        if let Some(s) = last {
            rows.push(("bio/object-store".to_string(), s));
        }
    }

    // golden regression gate under smoke mode: wall-clock-free counters
    if std::env::var("IPS_BENCH_SMOKE").as_deref() == Ok("1") && !rows.is_empty() {
        let mut json = String::from("{\"rows\":[");
        for (i, (name, s)) in rows.iter().enumerate() {
            if i > 0 {
                json.push(',');
            }
            json.push_str(&format!(
                "{{\"name\":\"{name}\",\"host_pages\":{},\"host_reads\":{},\
                 \"bios\":{},\"splits\":{},\"merges\":{},\"rmw\":{},\"flushes\":{},\
                 \"sim_end\":{},\"wa\":\"{:.4}\"}}",
                s.ledger.host_pages,
                s.ledger.host_reads,
                s.blk.bios,
                s.blk.splits,
                s.blk.merges,
                s.blk.rmw_reads,
                s.blk.flushes,
                s.sim_end,
                s.wa(),
            ));
        }
        json.push_str("]}\n");
        golden::check_and_report("fig_bio", &json);
    }

    h.finish();
}
