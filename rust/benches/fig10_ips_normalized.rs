//! Bench: Fig. 10 end-to-end — one (workload × scheme × scenario) cell
//! of the normalized grid per iteration.
use ips::config::Scheme;
use ips::coordinator::{experiment, ExpOptions};
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };
    for (scen, tag) in [(Scenario::Bursty, "a-bursty"), (Scenario::Daily, "b-daily")] {
        for scheme in [Scheme::Baseline, Scheme::Ips] {
            let cfg = experiment::exp_config(&opts, scheme);
            h.bench(&format!("fig10{tag}/HM_0/{}", scheme.name()), None, || {
                let mut sim = Simulator::new(cfg.clone()).unwrap();
                let daily =
                    experiment::workload_trace(&opts, "HM_0", sim.logical_bytes()).unwrap();
                let t = match scen {
                    Scenario::Bursty => scenario::to_bursty(&daily, sim.logical_bytes()),
                    Scenario::Daily => daily,
                };
                black_box(sim.run(&t, scen).unwrap());
            });
        }
    }
    h.finish();
}
