//! Bench: ablations over the design choices DESIGN.md calls out —
//! SLC layer-group width, idle threshold, cache size.
use ips::config::{Scheme, MS};
use ips::coordinator::{experiment, ExpOptions};
use ips::sim::Simulator;
use ips::trace::scenario::Scenario;
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };
    for layers in [1u32, 2, 4] {
        let mut cfg = experiment::exp_config(&opts, Scheme::Ips);
        cfg.cache.group_layers = layers;
        h.bench(&format!("ablation/group-layers/{layers}"), None, || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let t = experiment::workload_trace(&opts, "HM_0", sim.logical_bytes()).unwrap();
            black_box(sim.run(&t, Scenario::Daily).unwrap());
        });
    }
    for idle_ms in [10u64, 100, 1000] {
        let mut cfg = experiment::exp_config(&opts, Scheme::IpsAgc);
        cfg.cache.idle_threshold = idle_ms * MS;
        h.bench(&format!("ablation/idle-threshold/{idle_ms}ms"), None, || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let t = experiment::workload_trace(&opts, "HM_0", sim.logical_bytes()).unwrap();
            black_box(sim.run(&t, Scenario::Daily).unwrap());
        });
    }
    for mult in [1u64, 2, 4] {
        let mut cfg = experiment::exp_config(&opts, Scheme::Baseline);
        cfg.cache.slc_cache_bytes *= mult;
        h.bench(&format!("ablation/cache-size/x{mult}"), None, || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let t = experiment::workload_trace(&opts, "HM_0", sim.logical_bytes()).unwrap();
            black_box(sim.run(&t, Scenario::Daily).unwrap());
        });
    }
    h.finish();
}
