//! Bench: ablations over the design choices DESIGN.md calls out —
//! SLC layer-group width, idle threshold, cache size, and the
//! device-side queue depth (`host.device_qd`) that decides how much a
//! scheduler's dispatch order can matter to the victims' tail.
use ips::config::{Scheme, MS};
use ips::coordinator::fleet::{device_qd_sweep, interconnect_sweep, qd_joint_sweep};
use ips::coordinator::{experiment, ExpOptions};
use ips::sim::Simulator;
use ips::trace::scenario::Scenario;
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };
    for layers in [1u32, 2, 4] {
        let mut cfg = experiment::exp_config(&opts, Scheme::Ips);
        cfg.cache.group_layers = layers;
        h.bench(&format!("ablation/group-layers/{layers}"), None, || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let t = experiment::workload_trace(&opts, "HM_0", sim.logical_bytes()).unwrap();
            black_box(sim.run(&t, Scenario::Daily).unwrap());
        });
    }
    for idle_ms in [10u64, 100, 1000] {
        let mut cfg = experiment::exp_config(&opts, Scheme::IpsAgc);
        cfg.cache.idle_threshold = idle_ms * MS;
        h.bench(&format!("ablation/idle-threshold/{idle_ms}ms"), None, || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let t = experiment::workload_trace(&opts, "HM_0", sim.logical_bytes()).unwrap();
            black_box(sim.run(&t, Scenario::Daily).unwrap());
        });
    }
    for mult in [1u64, 2, 4] {
        let mut cfg = experiment::exp_config(&opts, Scheme::Baseline);
        cfg.cache.slc_cache_bytes *= mult;
        h.bench(&format!("ablation/cache-size/x{mult}"), None, || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let t = experiment::workload_trace(&opts, "HM_0", sim.logical_bytes()).unwrap();
            black_box(sim.run(&t, Scenario::Daily).unwrap());
        });
    }
    // device-QD ablation (ROADMAP): multi-tenant aggressor+victims,
    // the window size the scheduler's dispatch order acts through
    {
        let mut base = experiment::exp_config(&opts, Scheme::Baseline);
        base.host.tenants = 4;
        base.sim.latency_samples = 100_000;
        let qds = [1usize, 2, 4, 8, 16, 32];
        let mut points = Vec::new();
        h.bench("ablation/device-qd/sweep", Some(qds.len() as u64), || {
            points = device_qd_sweep(&base, Scenario::Bursty, &qds).unwrap();
        });
        // render the per-point victim tails from the measured run
        // (empty when a bench filter skipped the sweep)
        if !points.is_empty() {
            println!("\n== ablation: device-qd (aggressor+victims, fifo) ==");
            for (qd, s) in &points {
                println!(
                    "  qd {:>2}: device p99 {:>9.3} ms  victim p99 {:>9.3} ms  wa {:.3}",
                    qd,
                    s.write_latency.percentile_best(0.99) as f64 / 1e6,
                    s.max_victim_p99() as f64 / 1e6,
                    s.wa()
                );
            }
        }
    }
    // joint host-SQ × device-window ablation (ROADMAP): the two
    // windows interact — only the device side was swept before
    {
        let mut base = experiment::exp_config(&opts, Scheme::Baseline);
        base.host.tenants = 4;
        base.sim.latency_samples = 100_000;
        let sqs = [1usize, 8, 64];
        let qds = [1usize, 4, 16];
        let mut points = Vec::new();
        h.bench("ablation/qd-joint/sweep", Some((sqs.len() * qds.len()) as u64), || {
            points = qd_joint_sweep(&base, Scenario::Bursty, &sqs, &qds).unwrap();
        });
        if !points.is_empty() {
            println!("\n== ablation: qd-joint (aggressor+victims, fifo) ==");
            for (sq, qd, s) in &points {
                println!(
                    "  sq {:>2} x qd {:>2}: device p99 {:>9.3} ms  victim p99 {:>9.3} ms  wa {:.3}",
                    sq,
                    qd,
                    s.write_latency.percentile_best(0.99) as f64 / 1e6,
                    s.max_victim_p99() as f64 / 1e6,
                    s.wa()
                );
            }
        }
    }

    // channel/die scaling under the interconnect timing model: the
    // ablation axis PR 5 opens — victim tails and the per-phase
    // (queued/transfer/array) breakdown against real parallelism
    {
        let mut base = experiment::exp_config(&opts, Scheme::Baseline);
        base.host.tenants = 4;
        base.sim.latency_samples = 100_000;
        let channels = [1u32, 2, 4];
        let dies = [1u32, 2];
        let mut points = Vec::new();
        h.bench(
            "ablation/interconnect/sweep",
            Some((channels.len() * dies.len()) as u64),
            || {
                points =
                    interconnect_sweep(&base, Scenario::Bursty, &channels, &dies).unwrap();
            },
        );
        if !points.is_empty() {
            println!("\n== ablation: interconnect channel/die scaling (aggressor+victims) ==");
            for (ch, dies, s) in &points {
                println!(
                    "  ch {:>2} x dies {:>2}: victim p99 {:>9.3} ms  q {:>7.3}  xfer {:>7.3}  \
                     arr {:>7.3} ms/op",
                    ch,
                    dies,
                    s.max_victim_p99() as f64 / 1e6,
                    s.write_phases.mean_queued_ns() / 1e6,
                    s.write_phases.mean_transfer_ns() / 1e6,
                    s.write_phases.mean_array_ns() / 1e6,
                );
            }
        }
    }

    h.finish();
}
