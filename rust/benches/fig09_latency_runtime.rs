//! Bench: Fig. 9 end-to-end — runtime latency capture (100k raw
//! samples) on bursty HM_0, baseline vs IPS.
use ips::config::Scheme;
use ips::coordinator::{experiment, ExpOptions};
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };
    for scheme in [Scheme::Baseline, Scheme::Ips] {
        let mut cfg = experiment::exp_config(&opts, scheme);
        cfg.sim.latency_samples = 100_000;
        h.bench(&format!("fig09/latency-capture/{}", scheme.name()), None, || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let daily = experiment::workload_trace(&opts, "HM_0", sim.logical_bytes()).unwrap();
            let t = scenario::to_bursty(&daily, sim.logical_bytes());
            black_box(sim.run(&t, Scenario::Bursty).unwrap());
        });
    }
    h.finish();
}
