//! Bench: the simulator's hot paths in isolation (the §Perf targets):
//! host TLC page writes (mapping + allocator + timing), SLC cache
//! writes, reprogram chain, host reads, GC cycles, trace generation.
use ips::config::{presets, Scheme};
use ips::flash::Lpn;
use ips::ftl::Ftl;
use ips::metrics::Attribution;
use ips::trace::{profiles, synth};
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let mut cfg = presets::bench_medium();
    cfg.cache.scheme = Scheme::TlcOnly;

    // host TLC write path, striped over planes
    {
        let cfg = cfg.clone();
        let mut ftl = Ftl::new(&cfg).unwrap();
        let mut lpn = 0u64;
        let mut t = 0u64;
        let lim = ftl.map.lpn_limit();
        h.bench("hotpath/host_write_tlc", Some(1000), || {
            for _ in 0..1000 {
                lpn = (lpn + 1) % lim;
                let c = ftl.host_write_tlc(Lpn(lpn), t).unwrap();
                t = c.end;
            }
            black_box(&ftl);
        });
    }

    // SLC cache program into a scheme block
    {
        let cfg = cfg.clone();
        let mut ftl = Ftl::new(&cfg).unwrap();
        use ips::flash::{BlockMode, PlaneId};
        let mut addr = ftl.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        let mut lpn = 0u64;
        h.bench("hotpath/program_slc", Some(1000), || {
            for _ in 0..1000 {
                lpn += 1;
                if ftl.array.block(addr).slc_free_wls() == 0 {
                    // recycle: unmap + invalidate everything, then erase
                    let pibs: Vec<u32> = ftl.array.block(addr).valid_pages().collect();
                    let g = *ftl.array.geometry();
                    for pib in pibs {
                        if let Some(l) = ftl.array.block(addr).lpn_at(pib) {
                            ftl.map.clear(l).unwrap();
                        }
                        ftl.array.invalidate(addr.page(&g, pib / 3, (pib % 3) as u8)).unwrap();
                    }
                    ftl.array.erase(addr, 0).unwrap();
                    ftl.array.push_free(addr).unwrap();
                    addr = ftl.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
                }
                ftl.program_slc_into(addr, Lpn(lpn % 100000), Attribution::SlcCacheWrite, 0)
                    .unwrap();
            }
            black_box(&ftl);
        });
    }

    // host reads over a populated range
    {
        let cfg = cfg.clone();
        let mut ftl = Ftl::new(&cfg).unwrap();
        for i in 0..10_000u64 {
            ftl.host_write_tlc(Lpn(i), 0).unwrap();
        }
        let mut i = 0u64;
        h.bench("hotpath/host_read", Some(1000), || {
            for _ in 0..1000 {
                i = (i + 7) % 10_000;
                black_box(ftl.host_read(Lpn(i), u64::MAX / 2).unwrap());
            }
        });
    }

    // trace generation
    {
        let p = profiles::by_name("HM_0").unwrap();
        let mut seed = 0u64;
        h.bench("hotpath/synth_trace_1MiB", None, || {
            seed += 1;
            black_box(synth::generate_scaled(p, seed, u64::MAX, 1.0 / 20480.0));
        });
    }

    // victim selection over a closed-heavy plane: the linear scan the
    // index replaced vs the bucket index (same FTL state either way).
    // greedy_gain is pop_victim's pick without the pop, so this is the
    // per-decision cost every GC pop / AGC idle step / eviction pays.
    for (label, use_index) in [("scan", false), ("index", true)] {
        let mut cfg = presets::bench_medium();
        cfg.cache.scheme = Scheme::TlcOnly;
        cfg.sim.victim_index = use_index;
        let mut ftl = Ftl::new(&cfg).unwrap();
        use ips::flash::PlaneId;
        use ips::ftl::gc;
        // fill plane 0 twice over a bounded LPN range: every block
        // closes and most carry invalid pages from the overwrites
        let span = cfg.geometry.pages_per_plane() / 2;
        let mut t = 0u64;
        for i in 0..span * 2 {
            let c = ftl.host_write_tlc_on(PlaneId(0), Lpn(i % span), t).unwrap();
            t = c.end;
        }
        let closed = ftl.closed_count(PlaneId(0));
        h.bench(
            &format!("hotpath/victim_pick/{label}/closed={closed}"),
            Some(1000),
            || {
                for _ in 0..1000 {
                    black_box(gc::greedy_gain(&mut ftl, PlaneId(0)));
                }
            },
        );
    }
    h.finish();
}
