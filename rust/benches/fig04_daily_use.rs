//! Bench: Fig. 4 end-to-end — periodic streams with idle reclamation.
use ips::config::{Scheme, SEC};
use ips::coordinator::{experiment, ExpOptions};
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };
    for scheme in [Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc] {
        let cfg = experiment::exp_config(&opts, scheme);
        let stream = ((20u64 << 30) as f64 * opts.volume()) as u64;
        let pages = 5 * stream / 4096;
        h.bench(&format!("fig04/daily-streams/{}", scheme.name()), Some(pages), || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let t = scenario::daily_streams(5, stream, 600 * SEC, sim.logical_bytes());
            black_box(sim.run(&t, Scenario::Daily).unwrap());
        });
    }
    h.finish();
}
