//! Bench: the PR-4 perf trajectory — victim-index vs linear-scan
//! wall clock across all five schemes, written to `BENCH_PR4.json`.
//!
//! Unlike the figure benches this one measures the *simulator itself*
//! (host pages per wall-clock second), so each cell is a self-timed
//! paired run via [`ips::coordinator::perf::run_cell`] rather than a
//! harness closure: the scan and index runs inside a cell must replay
//! the identical trace once each, and the cell asserts the two produced
//! identical simulation results (the differential guarantee).
//!
//! Under `IPS_BENCH_SMOKE=1` the matrix shrinks to the small preset so
//! CI catches bit-rot cheaply; the real trajectory comes from
//! `ips perf --preset large` (the `perf-smoke` CI job uploads the small
//! variant as an artifact every run). Override the output path with
//! `IPS_PERF_OUT`.

use ips::config::Scheme;
use ips::coordinator::perf;
use ips::trace::scenario::Scenario;
use ips::util::bench::fmt_duration;

fn main() {
    let smoke = std::env::var("IPS_BENCH_SMOKE").map(|s| s == "1").unwrap_or(false);
    // an optional substring filter, like the harness benches take
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let (preset, volume_mult) = if smoke { ("small", 1.2) } else { ("medium", 2.0) };
    let base = perf::preset_by_name(preset).unwrap();
    println!(
        "fig_perf: preset={preset} volume x{volume_mult} of logical ({} planes x {} blocks)",
        base.geometry.planes(),
        base.geometry.blocks_per_plane
    );

    let mut cells = Vec::new();
    for scheme in Scheme::all() {
        for scen in [Scenario::Bursty, Scenario::Daily] {
            let name = format!("perf/{preset}/{}/{}", scheme.name(), scen.name());
            if let Some(f) = &filter {
                if !name.contains(f.as_str()) {
                    continue;
                }
            }
            let c = perf::run_cell(preset, &base, scheme, scen, volume_mult).unwrap();
            println!(
                "{name:<40} scan {:>10}  index {:>10}  speedup {:>6.2}x  {}",
                fmt_duration(c.scan_wall),
                fmt_duration(c.index_wall),
                c.speedup(),
                if c.identical { "ok" } else { "DIVERGED" }
            );
            assert!(
                c.identical,
                "{name}: scan and index runs diverged — the index changed simulation results"
            );
            cells.push(c);
        }
    }

    if !cells.is_empty() {
        let out = std::env::var("IPS_PERF_OUT").unwrap_or_else(|_| {
            let root = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
            format!("{root}/BENCH_PR4.json")
        });
        std::fs::write(&out, perf::perf_json(&cells)).unwrap();
        let bursty_best = cells
            .iter()
            .filter(|c| c.scenario == "bursty")
            .map(|c| c.speedup())
            .fold(0.0f64, f64::max);
        println!("\nwrote {out}; best GC-heavy bursty speedup {bursty_best:.2}x");
    }
    println!("\n{} perf cell(s) complete.", cells.len());

    // PR-5 trajectory: the same matrix as a lump-vs-interconnect
    // comparison — NOT a differential (the models legitimately
    // diverge); the record is wall-clock overhead + the simulated-time
    // contention the lump was hiding. Skipped when a filter excluded
    // everything above.
    let mut timing_cells = Vec::new();
    for scheme in Scheme::all() {
        for scen in [Scenario::Bursty, Scenario::Daily] {
            let name = format!("timing/{preset}/{}/{}", scheme.name(), scen.name());
            if let Some(f) = &filter {
                if !name.contains(f.as_str()) {
                    continue;
                }
            }
            let c = perf::run_timing_cell(preset, &base, scheme, scen, volume_mult).unwrap();
            println!(
                "{name:<40} lump {:>10}  ic {:>10}  overhead {:>5.2}x  sim-time {:>6.4}x",
                fmt_duration(c.lump_wall),
                fmt_duration(c.ic_wall),
                c.overhead(),
                c.sim_end_ratio(),
            );
            // no monotonicity assert here: daily idle windows and the
            // multi-plane batched flush legitimately reshape simulated
            // time in both directions (the ratio is the measurement)
            timing_cells.push(c);
        }
    }
    if !timing_cells.is_empty() {
        let out = std::env::var("IPS_PERF5_OUT").unwrap_or_else(|_| {
            let root = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
            format!("{root}/BENCH_PR5.json")
        });
        std::fs::write(&out, perf::timing_json(&timing_cells)).unwrap();
        println!("\nwrote {out}");
    }
    println!("{} timing cell(s) complete.", timing_cells.len());
}
