//! Bench: per-tenant cache isolation end to end — shared cache vs
//! per-tenant partitioning vs partitioning+QoS, paired (same seeds,
//! same traces) across the PR-1 tenant mixes on baseline and IPS.
//! The headline: under aggressor+victims, victim p99 with
//! partitioned+QoS must sit strictly below the shared-cache victim
//! p99. Also times one cell per variant so isolation overhead on the
//! hot dispatch path stays visible.
use ips::config::{AttributionMode, MixKind, QosMode, SchedKind, Scheme};
use ips::coordinator::fleet::{
    run_fleet, summary_json, summary_table, FleetSpec, IsolationVariant,
};
use ips::coordinator::{experiment, ExpOptions};
use ips::host::{MultiTenantSimulator, MultiTenantSummary};
use ips::trace::scenario::Scenario;
use ips::util::bench::{black_box, Harness};
use ips::util::golden;

fn is_variant(s: &MultiTenantSummary, v: IsolationVariant) -> bool {
    // anchored to the one variant mapping: MultiTenantSummary::variant_name
    match v {
        IsolationVariant::PartitionedQos => s.variant_name().starts_with("partitioned+"),
        _ => s.variant_name() == v.name(),
    }
}

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };

    let tuned = |scheme: Scheme| {
        let mut cfg = experiment::exp_config(&opts, scheme);
        cfg.host.tenants = 4;
        cfg.host.scheduler = SchedKind::Fifo; // worst case for victims
        cfg.host.mix = MixKind::AggressorVictims;
        // sustained rate below the device's SLC bandwidth, well above
        // any victim's offered load
        cfg.host.qos.rate_mbps = 32.0;
        cfg.host.qos.burst_bytes = 256 << 10;
        cfg.sim.latency_samples = 100_000;
        cfg
    };

    // isolation overhead on the dispatch hot path, one run per variant
    for variant in IsolationVariant::all() {
        let mut cfg = tuned(Scheme::Baseline);
        variant.apply(&mut cfg);
        h.bench(&format!("partition/baseline/{}", variant.name()), None, || {
            let s = MultiTenantSimulator::run_once(cfg.clone(), Scenario::Bursty).unwrap();
            black_box(s.max_victim_p99());
        });
    }

    // the figure: (baseline, ips) × all PR-1 mixes × all variants ×
    // both attribution modes, paired seeds so every comparison is
    // apples-to-apples
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let spec = FleetSpec {
        base: tuned(Scheme::Baseline),
        schemes: vec![Scheme::Baseline, Scheme::Ips],
        scheds: vec![SchedKind::Fifo],
        mixes: MixKind::all().to_vec(),
        variants: IsolationVariant::all().to_vec(),
        attributions: AttributionMode::all().to_vec(),
        scenario: Scenario::Bursty,
        seed: 42,
        threads,
    };
    let cells = spec.jobs().len() as u64;
    let mut results: Vec<MultiTenantSummary> = Vec::new();
    h.bench("partition/fleet", Some(cells), || {
        results = run_fleet(&spec).unwrap();
    });

    // render only when the fleet cell actually ran (it is skipped
    // under a `cargo bench -- <filter>` that does not match it)
    if !results.is_empty() {
        println!("\n== fig_partition: shared vs partitioned vs partitioned+qos ==");
        print!("{}", summary_table(&results).render());

        // smoke mode doubles as the golden regression gate: the sim is
        // deterministic, so the summary rows must match the committed
        // snapshot byte-for-byte (attribution drift fails CI here)
        if std::env::var("IPS_BENCH_SMOKE").as_deref() == Ok("1") {
            golden::check_and_report("fig_partition", &summary_json(&results));
        }

        println!("\nvictim p99 (aggressor+victims, fifo):");
        for scheme in [Scheme::Baseline, Scheme::Ips] {
            let get = |v: IsolationVariant| {
                results
                    .iter()
                    .find(|s| {
                        s.scheme == scheme.name()
                            && s.mix == MixKind::AggressorVictims.name()
                            && s.attribution == "proportional"
                            && is_variant(s, v)
                    })
                    .expect("fleet covered every variant")
            };
            let shared = get(IsolationVariant::Shared);
            let part = get(IsolationVariant::Partitioned);
            let qos = get(IsolationVariant::PartitionedQos);
            let verdict = if qos.max_victim_p99() < shared.max_victim_p99() {
                "OK: partitioned+qos strictly below shared"
            } else {
                "REGRESSION: partitioned+qos not below shared"
            };
            println!(
                "  {:<9} shared {:>9.3} ms | partitioned {:>9.3} ms | \
                 partitioned+qos {:>9.3} ms  [{}]",
                scheme.name(),
                shared.max_victim_p99() as f64 / 1e6,
                part.max_victim_p99() as f64 / 1e6,
                qos.max_victim_p99() as f64 / 1e6,
                verdict
            );
            println!(
                "  {:<9} throttled tenants under qos: {:?} ({} stalls)",
                "",
                qos.throttled_tenants(),
                qos.total_throttle_stalls()
            );
        }
    }

    // the SLO mode, for completeness: enforce only while victims miss
    // their p99 target
    let mut slo_cfg = tuned(Scheme::Baseline);
    slo_cfg.cache.partition.enabled = true;
    slo_cfg.host.qos.mode = QosMode::Slo;
    slo_cfg.host.qos.slo_p99 = 20 * ips::config::MS;
    h.bench("partition/baseline/slo-mode", None, || {
        let s = MultiTenantSimulator::run_once(slo_cfg.clone(), Scenario::Bursty).unwrap();
        black_box(s.total_throttle_stalls());
    });

    h.finish();
}
