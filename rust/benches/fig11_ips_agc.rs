//! Bench: Fig. 11 end-to-end — daily IPS/agc cell incl. idle-time AGC
//! reprogramming (the interruptible step machinery).
use ips::config::Scheme;
use ips::coordinator::{experiment, ExpOptions};
use ips::sim::Simulator;
use ips::trace::scenario::Scenario;
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };
    for scheme in [Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc] {
        let cfg = experiment::exp_config(&opts, scheme);
        for w in ["HM_0", "USR_0"] {
            h.bench(&format!("fig11/daily/{w}/{}", scheme.name()), None, || {
                let mut sim = Simulator::new(cfg.clone()).unwrap();
                let t = experiment::workload_trace(&opts, w, sim.logical_bytes()).unwrap();
                black_box(sim.run(&t, Scenario::Daily).unwrap());
            });
        }
    }
    h.finish();
}
