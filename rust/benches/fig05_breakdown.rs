//! Bench: Fig. 5 end-to-end — breakdown/WA run for one representative
//! workload per scenario (baseline scheme, as in the paper).
use ips::config::Scheme;
use ips::coordinator::{experiment, ExpOptions};
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };
    for (scen, tag) in [(Scenario::Bursty, "bursty"), (Scenario::Daily, "daily")] {
        for w in ["HM_0", "PRXY_0"] {
            let cfg = experiment::exp_config(&opts, Scheme::Baseline);
            h.bench(&format!("fig05/breakdown/{tag}/{w}"), None, || {
                let mut sim = Simulator::new(cfg.clone()).unwrap();
                let daily = experiment::workload_trace(&opts, w, sim.logical_bytes()).unwrap();
                let t = match scen {
                    Scenario::Bursty => scenario::to_bursty(&daily, sim.logical_bytes()),
                    Scenario::Daily => daily,
                };
                black_box(sim.run(&t, scen).unwrap());
            });
        }
    }
    h.finish();
}
