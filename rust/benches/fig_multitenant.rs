//! Bench: multi-tenant interference end-to-end — the aggressor+victims
//! mix through each scheduler on baseline vs IPS, per-run timing +
//! simulated-request throughput, plus the fleet runner's parallel
//! speedup over serial execution.
use ips::config::{AttributionMode, MixKind, SchedKind, Scheme};
use ips::coordinator::fleet::{run_fleet, summary_json, FleetSpec, IsolationVariant};
use ips::coordinator::{experiment, ExpOptions};
use ips::host::MultiTenantSimulator;
use ips::trace::scenario::Scenario;
use ips::util::bench::{black_box, Harness};
use ips::util::golden;

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };

    for scheme in [Scheme::Baseline, Scheme::Ips] {
        for sched in SchedKind::all() {
            let mut cfg = experiment::exp_config(&opts, scheme);
            cfg.host.tenants = 4;
            cfg.host.scheduler = sched;
            cfg.host.mix = MixKind::AggressorVictims;
            let reqs = {
                // one dry run to size the throughput denominator
                let s = MultiTenantSimulator::run_once(cfg.clone(), Scenario::Bursty).unwrap();
                s.write_latency.count() + s.read_latency.count()
            };
            h.bench(
                &format!("multitenant/{}/{}", scheme.name(), sched.name()),
                Some(reqs),
                || {
                    let s =
                        MultiTenantSimulator::run_once(cfg.clone(), Scenario::Bursty).unwrap();
                    black_box(s.max_victim_p99());
                },
            );
        }
    }

    // fleet fan-out: serial vs all-cores over the same 2x3 sweep
    let mut last_fleet = Vec::new();
    for (label, threads) in [("fleet/serial", 1usize), ("fleet/parallel", 0)] {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            threads
        };
        let mut base = experiment::exp_config(&opts, Scheme::Baseline);
        base.host.tenants = 4;
        base.sim.latency_samples = 100_000;
        let spec = FleetSpec {
            base,
            schemes: vec![Scheme::Baseline, Scheme::Ips],
            scheds: SchedKind::all().to_vec(),
            mixes: vec![MixKind::AggressorVictims],
            variants: vec![IsolationVariant::Shared],
            attributions: vec![AttributionMode::Proportional],
            scenario: Scenario::Bursty,
            seed: 42,
            threads,
        };
        let cells = spec.jobs().len() as u64;
        h.bench(label, Some(cells), || {
            last_fleet = run_fleet(&spec).unwrap();
            black_box(last_fleet.len());
        });
    }

    // golden regression gate under smoke mode (see fig_partition)
    if std::env::var("IPS_BENCH_SMOKE").as_deref() == Ok("1") && !last_fleet.is_empty() {
        golden::check_and_report("fig_multitenant", &summary_json(&last_fleet));
    }

    h.finish();
}
