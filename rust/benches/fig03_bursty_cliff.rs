//! Bench: Fig. 3 end-to-end — bursty sequential fill across the cliff
//! (baseline vs IPS), per-run timing + simulated-pages throughput.
use ips::config::Scheme;
use ips::coordinator::{experiment, ExpOptions};
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::util::bench::{black_box, Harness};

fn main() {
    let mut h = Harness::new();
    let opts = ExpOptions { scale: 16, ..ExpOptions::default() };
    for scheme in [Scheme::Baseline, Scheme::Ips] {
        let cfg = experiment::exp_config(&opts, scheme);
        let cache = cfg.cache.slc_cache_bytes;
        let pages = (cache * 5 / 2) / 4096;
        h.bench(&format!("fig03/bursty-cliff/{}", scheme.name()), Some(pages), || {
            let mut sim = Simulator::new(cfg.clone()).unwrap();
            let trace = scenario::sequential_fill("b", cache * 5 / 2, sim.logical_bytes());
            black_box(sim.run(&trace, Scenario::Bursty).unwrap());
        });
    }
    h.finish();
}
