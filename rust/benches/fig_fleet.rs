//! Bench: the device-population fleet axis — N heterogeneous SSDs
//! (capacity / OP / pre-aged wear) per scheme, folded into fleet-wide
//! percentiles by pure histogram merges. Times the sharded sweep and
//! the serial fold separately, so a regression in either the per-device
//! runs or the merge path shows up on its own line.
//!
//! Under `IPS_BENCH_SMOKE=1` the deterministic fleet rollup
//! (`population_json`: counts, quantiles, WA — no wall clock) gates
//! against a golden snapshot: a change to histogram binning, merge
//! semantics, profile derivation, or seeding fails CI instead of
//! silently bending the fleet figures.
use ips::config::{presets, MixKind, Scheme};
use ips::coordinator::fleet::{
    fold_population, population_json, run_population, run_population_streaming, PopulationSpec,
};
use ips::trace::scenario::Scenario;
use ips::util::bench::{black_box, Harness};
use ips::util::golden;

fn spec(devices: u32, threads: usize) -> PopulationSpec {
    let mut base = presets::small();
    base.cache.slc_cache_bytes = 1 << 20;
    base.host.tenants = 3;
    base.host.aggressor_cache_mult = 1.5;
    PopulationSpec {
        base,
        devices,
        schemes: vec![Scheme::Baseline, Scheme::Ips],
        mixes: vec![MixKind::AggressorVictims],
        scenario: Scenario::Bursty,
        fault_rate: 0.0,
        seed: 42,
        threads,
    }
}

fn main() {
    let mut h = Harness::new();

    // the full sweep: profile derivation + per-device runs + fold
    let mut json = None;
    {
        let s = spec(4, 2);
        let jobs = s.devices as u64 * s.schemes.len() as u64;
        h.bench("fleet/population-4dev", Some(jobs), || {
            let runs = run_population(&s).unwrap();
            let cells = fold_population(&runs);
            black_box(cells.len());
            json = Some(population_json(&cells));
        });
    }

    // the fold alone: pure histogram / ledger / phase merges over a
    // fixed set of device runs (the mergeability story, isolated)
    {
        let runs = run_population(&spec(4, 2)).unwrap();
        h.bench("fleet/fold-only", Some(runs.len() as u64), || {
            let cells = fold_population(&runs);
            black_box(cells[0].write_latency.count());
        });
    }

    // the streaming sharded fold with fault injection on: the
    // rack-scale path (bounded resident runs, healthy/faulted split)
    {
        let mut s = spec(4, 2);
        s.fault_rate = 0.5;
        let jobs = s.devices as u64 * s.schemes.len() as u64;
        h.bench("fleet/streaming-faulted-4dev", Some(jobs), || {
            let (cells, csv, stats) = run_population_streaming(&s).unwrap();
            black_box((cells.len(), csv.len(), stats.peak_resident_runs));
        });
    }

    // materialized-trace oracle vs zero-materialization sources on the
    // same fleet shape (the streaming-workloads tentpole): identical
    // deterministic cells, different generation path. VmHWM is
    // process-monotone, so the peak-RSS lines below record the
    // high-water *at that point in the run*, not a strict A/B — the
    // 1000-device CI step is where the memory gap is visible.
    {
        let mut m = spec(4, 2);
        m.base.sim.streaming_traces = false;
        let s = spec(4, 2);
        let jobs = s.devices as u64 * s.schemes.len() as u64;
        h.bench("fleet/materialized-traces-4dev", Some(jobs), || {
            let (cells, _, stats) = run_population_streaming(&m).unwrap();
            black_box((cells.len(), stats.peak_resident_runs));
        });
        h.bench("fleet/streaming-traces-4dev", Some(jobs), || {
            let (cells, _, stats) = run_population_streaming(&s).unwrap();
            black_box((cells.len(), stats.peak_resident_runs));
        });
        for (label, sp) in [("materialized", &m), ("streaming", &s)] {
            let (_, _, stats) = run_population_streaming(sp).unwrap();
            println!(
                "fleet/{label}-traces-4dev: wall {:.3} s, peak RSS {} KiB (VmHWM)",
                stats.wall_clock.as_secs_f64(),
                stats.peak_rss_kb
            );
        }
    }

    if std::env::var("IPS_BENCH_SMOKE").as_deref() == Ok("1") {
        if let Some(json) = json {
            golden::check_and_report("fig_fleet", &json);
        }
    }

    h.finish();
}
