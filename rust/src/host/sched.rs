//! Pluggable request schedulers merging per-tenant submission queues.
//!
//! The scheduler sees only the *ready heads* (one per tenant, arrived
//! requests) and picks which to dispatch next. Dispatch order is what
//! decides who waits behind whom on the shared flash planes, so the
//! three policies produce genuinely different per-tenant tails:
//!
//! * [`Fifo`] — global arrival order. A bursty aggressor's backlog is
//!   dispatched ahead of every later-arriving victim request; the
//!   victims inherit the aggressor's cache cliff.
//! * [`RoundRobin`] — one request per tenant in rotation; victims
//!   overtake the aggressor's backlog at every turn.
//! * [`WeightedFair`] — least-attained-service first, byte-accounted
//!   and weight-normalized (start-time fair queueing without the
//!   virtual clock: with a single dispatch point, attained service is
//!   the exact fairness currency).

use crate::config::{Nanos, SchedKind};

/// What the scheduler knows about one tenant's ready head.
#[derive(Clone, Copy, Debug)]
pub struct HeadInfo {
    /// Arrival time of the head request.
    pub arrival: Nanos,
    /// Request size in bytes.
    pub bytes: u64,
}

/// A request scheduler over N tenant queues.
pub trait Scheduler: Send {
    /// Display name.
    fn name(&self) -> &'static str;
    /// Choose among ready heads (`ready[i]` is `Some` iff tenant i's
    /// head request has arrived). Returns the tenant index to dispatch,
    /// or `None` iff no head is ready.
    fn pick(&mut self, ready: &[Option<HeadInfo>]) -> Option<usize>;
    /// Account `bytes` of service delivered to tenant `i`.
    fn charge(&mut self, _i: usize, _bytes: u64) {}
}

/// Build the scheduler selected by `kind` for tenants with `weights`.
pub fn build(kind: SchedKind, weights: &[f64]) -> Box<dyn Scheduler> {
    match kind {
        SchedKind::Fifo => Box::new(Fifo),
        SchedKind::RoundRobin => Box::new(RoundRobin { cursor: 0 }),
        SchedKind::WeightedFair => Box::new(WeightedFair {
            attained: vec![0.0; weights.len()],
            weights: weights.iter().map(|w| w.max(1e-9)).collect(),
        }),
    }
}

/// Global arrival order (ties to the lowest tenant index).
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn pick(&mut self, ready: &[Option<HeadInfo>]) -> Option<usize> {
        ready
            .iter()
            .enumerate()
            .filter_map(|(i, h)| h.map(|h| (h.arrival, i)))
            .min()
            .map(|(_, i)| i)
    }
}

/// One request per tenant in rotation.
pub struct RoundRobin {
    cursor: usize,
}

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }
    fn pick(&mut self, ready: &[Option<HeadInfo>]) -> Option<usize> {
        let n = ready.len();
        if n == 0 {
            return None;
        }
        for k in 0..n {
            let i = (self.cursor + k) % n;
            if ready[i].is_some() {
                self.cursor = (i + 1) % n;
                return Some(i);
            }
        }
        None
    }
}

/// Least virtual finish tag first: `(attained + head bytes) / weight`,
/// so a large head request is charged its own size up front — the SFQ
/// finish-time rule, which keeps one tenant's jumbo requests from
/// starving small-request tenants even between charges.
pub struct WeightedFair {
    attained: Vec<f64>,
    weights: Vec<f64>,
}

impl Scheduler for WeightedFair {
    fn name(&self) -> &'static str {
        "weighted-fair"
    }
    fn pick(&mut self, ready: &[Option<HeadInfo>]) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for (i, h) in ready.iter().enumerate() {
            let Some(h) = h else { continue };
            let v = (self.attained[i] + h.bytes as f64) / self.weights[i];
            if best.map(|(bv, _)| v < bv).unwrap_or(true) {
                best = Some((v, i));
            }
        }
        best.map(|(_, i)| i)
    }
    fn charge(&mut self, i: usize, bytes: u64) {
        self.attained[i] += bytes as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head(arrival: Nanos, bytes: u64) -> Option<HeadInfo> {
        Some(HeadInfo { arrival, bytes })
    }

    #[test]
    fn fifo_picks_earliest_arrival() {
        let mut s = build(SchedKind::Fifo, &[1.0; 3]);
        assert_eq!(s.pick(&[head(10, 1), head(5, 1), head(7, 1)]), Some(1));
        assert_eq!(s.pick(&[None, None, head(7, 1)]), Some(2));
        assert_eq!(s.pick(&[None, None, None]), None);
        // ties break to the lowest index
        assert_eq!(s.pick(&[head(5, 1), head(5, 1), None]), Some(0));
    }

    #[test]
    fn round_robin_rotates_and_skips_empty() {
        let mut s = build(SchedKind::RoundRobin, &[1.0; 3]);
        let all = [head(0, 1), head(0, 1), head(0, 1)];
        assert_eq!(s.pick(&all), Some(0));
        assert_eq!(s.pick(&all), Some(1));
        assert_eq!(s.pick(&all), Some(2));
        assert_eq!(s.pick(&all), Some(0));
        // tenant 1 not ready -> skipped without stalling the rotation
        assert_eq!(s.pick(&[head(0, 1), None, head(0, 1)]), Some(2));
        assert_eq!(s.pick(&[head(0, 1), None, head(0, 1)]), Some(0));
    }

    #[test]
    fn weighted_fair_tracks_attained_service() {
        let mut s = build(SchedKind::WeightedFair, &[1.0, 1.0]);
        let all = [head(0, 4096), head(0, 4096)];
        let first = s.pick(&all).unwrap();
        s.charge(first, 64 << 10); // tenant `first` got 64 KiB of service
        let second = s.pick(&all).unwrap();
        assert_ne!(first, second, "service debt flips the pick");
    }

    #[test]
    fn weighted_fair_respects_weights() {
        // tenant 0 weighs 4x: it may consume 4x the bytes before
        // tenant 1 overtakes it.
        let mut s = build(SchedKind::WeightedFair, &[4.0, 1.0]);
        let all = [head(0, 4096), head(0, 4096)];
        let mut count0 = 0;
        for _ in 0..50 {
            let i = s.pick(&all).unwrap();
            s.charge(i, 4096);
            if i == 0 {
                count0 += 1;
            }
        }
        assert!((35..=45).contains(&count0), "~4/5 of slots to weight 4: {count0}");
    }
}
