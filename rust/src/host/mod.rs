//! Multi-tenant host front end: NVMe-style per-tenant submission
//! queues merged by a pluggable request scheduler before the cache
//! scheme / FTL path.
//!
//! The paper evaluates IPS under single-stream workloads; a production
//! deployment serves many tenants whose streams contend for the *same*
//! SLC cache — exactly the regime where the bursty performance cliff
//! and reclamation conflicts hurt the most, because one tenant's burst
//! fills the shared cache and every neighbour pays TLC-class latency.
//! This module makes that regime measurable:
//!
//! * each tenant drives its own [`Trace`] through a bounded
//!   [`queue::SubmissionQueue`];
//! * a [`sched::Scheduler`] (FIFO, round-robin, weighted-fair-share)
//!   picks which queue head is dispatched next;
//! * every request is tagged with a [`TenantId`] end-to-end, and the
//!   engine diffs the FTL ledger around each request so
//!   [`crate::metrics::TenantStats`] carries per-tenant latency
//!   percentiles, bandwidth, and attributed write amplification next
//!   to the device-wide totals;
//! * [`tenant`] builds the tenant-mix scenarios (one aggressor + K
//!   victims, uniform fan-out, read-heavy, write-heavy);
//! * [`qos`] puts per-tenant token buckets in front of the scheduler
//!   (admission control), and [`crate::cache::partition`] carves the
//!   SLC cache into per-tenant reserved slices — together they turn
//!   the shared fast tier into a fair one.
//!
//! The thread-parallel (scheme × scheduler × mix) sweep lives in
//! [`crate::coordinator::fleet`]; the `multi-tenant` subcommand and
//! the `fig_multitenant` bench drive it.

pub mod engine;
pub mod qos;
pub mod queue;
pub mod sched;
pub mod tenant;

pub use engine::{MultiTenantSimulator, MultiTenantSummary};
pub use qos::QosGate;
pub use queue::SubmissionQueue;
pub use sched::Scheduler;
pub use tenant::TenantSpec;

use crate::trace::{Trace, TraceOp};

/// Tenant identifier, stable for the duration of a run (dense,
/// 0-based; doubles as the queue index).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TenantId(pub u16);

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// One host request tagged with its submitting tenant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaggedOp {
    /// Submitting tenant.
    pub tenant: TenantId,
    /// The request itself.
    pub op: TraceOp,
}

/// Merge per-tenant traces into one arrival-ordered stream.
///
/// Guarantees (property-tested in `tests/prop_multitenant.rs`):
/// * output arrival times are non-decreasing;
/// * each tenant's subsequence preserves that tenant's op order
///   (arrival ties across tenants break by tenant id).
///
/// This is the *trace-level* view of the merge — what a FIFO scheduler
/// dispatches. The runtime schedulers reorder only among requests that
/// are simultaneously resident in their queues.
pub fn merge_traces(traces: &[Trace]) -> Vec<TaggedOp> {
    let mut out: Vec<TaggedOp> = Vec::with_capacity(traces.iter().map(|t| t.ops.len()).sum());
    for (i, t) in traces.iter().enumerate() {
        let tenant = TenantId(i as u16);
        out.extend(t.ops.iter().map(|&op| TaggedOp { tenant, op }));
    }
    // stable sort: equal (at, tenant) keys keep per-tenant input order
    out.sort_by_key(|x| (x.op.at, x.tenant));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::OpKind;

    fn trace(name: &str, ats: &[u64]) -> Trace {
        Trace {
            name: name.into(),
            ops: ats
                .iter()
                .enumerate()
                .map(|(i, &at)| TraceOp {
                    at,
                    kind: OpKind::Write,
                    offset: i as u64 * 4096,
                    len: 4096,
                })
                .collect(),
        }
    }

    #[test]
    fn merge_orders_by_arrival_then_tenant() {
        let a = trace("a", &[0, 10, 20]);
        let b = trace("b", &[5, 10, 15]);
        let m = merge_traces(&[a, b]);
        assert_eq!(m.len(), 6);
        assert!(m.windows(2).all(|w| w[0].op.at <= w[1].op.at));
        // the at=10 tie goes to tenant 0 first
        let tie: Vec<_> = m.iter().filter(|x| x.op.at == 10).map(|x| x.tenant).collect();
        assert_eq!(tie, vec![TenantId(0), TenantId(1)]);
    }

    #[test]
    fn merge_preserves_per_tenant_order() {
        let a = trace("a", &[0, 0, 0]); // dense ties within one tenant
        let b = trace("b", &[0, 1]);
        let m = merge_traces(&[a.clone(), b]);
        let sub: Vec<_> =
            m.iter().filter(|x| x.tenant == TenantId(0)).map(|x| x.op).collect();
        assert_eq!(sub, a.ops, "tenant 0 subsequence intact");
    }

    #[test]
    fn merge_of_empty_is_empty() {
        assert!(merge_traces(&[]).is_empty());
        assert!(merge_traces(&[Trace::default()]).is_empty());
    }
}
