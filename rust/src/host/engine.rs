//! The multi-tenant simulation engine: per-tenant submission queues →
//! scheduler → cache scheme / FTL, with per-tenant metric attribution.
//!
//! Timing model: the front end dispatches one request at a time in
//! scheduler order, with at most `host.device_qd` requests in flight —
//! when the window is full it waits for the earliest completion. That
//! back-pressure is what makes dispatch *order* observable: a victim
//! request picked late waits behind the aggressor's backlog on the
//! shared planes, so its latency carries the neighbour's cliff.
//! Within a request, pages still spread over planes exactly like the
//! single-tenant [`crate::sim::Simulator`].
//!
//! Attribution: the engine snapshots the FTL [`Ledger`] around every
//! request; the diff (host pages, programs, synchronous GC) is charged
//! to the submitting tenant. Idle-time background work and the
//! end-of-workload flush are charged to the device's `background`
//! ledger instead — no tenant owns them.

use super::qos::{Admission, QosGate};
use super::queue::SubmissionQueue;
use super::sched::{self, HeadInfo, Scheduler};
use super::tenant::{self, TenantSpec};
use crate::blk::{self, Bio, BioKind};
use crate::cache::{self, CachePartitioner, CachePolicy};
use crate::config::{AttributionMode, Config, FaultKind, Nanos};
use crate::flash::{Lpn, PlaneId};
use crate::ftl::{Ftl, MoveCounters, VictimPolicy};
use crate::metrics::{
    BandwidthTimeline, BlkStats, LatencyStats, Ledger, PhaseStats, TenantStats, SCOPE_PAGE,
    SCOPE_REQUEST,
};
use crate::trace::scenario::Scenario;
use crate::trace::OpKind;
use crate::Result;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A configured multi-tenant simulator (one scheme, one scheduler,
/// one tenant mix over one fresh SSD).
pub struct MultiTenantSimulator {
    cfg: Config,
    ftl: Ftl,
    policy: Box<dyn CachePolicy>,
    sched: Box<dyn Scheduler>,
    queues: Vec<SubmissionQueue>,
    stats: Vec<TenantStats>,
    /// Per-tenant cache slices + reprogram-budget accounting.
    part: CachePartitioner,
    /// Token-bucket admission control ahead of the scheduler.
    qos: QosGate,
    now: Nanos,
    /// Absolute trigger time of the configured fault (None = healthy
    /// device or already fired). Computed in `new()` as
    /// `fault.at_frac × max trace arrival`.
    fault_at: Option<Nanos>,
    /// Did the fault actually fire during `run`?
    fault_fired: bool,
}

/// Everything a multi-tenant run produced.
#[derive(Clone, Debug)]
pub struct MultiTenantSummary {
    /// Scheme name.
    pub scheme: String,
    /// Scheduler name.
    pub scheduler: String,
    /// Tenant-mix name.
    pub mix: String,
    /// Scenario name.
    pub scenario: String,
    /// PRNG seed used.
    pub seed: u64,
    /// Per-tenant statistics, in tenant order.
    pub tenants: Vec<TenantStats>,
    /// Device-wide write-request latencies.
    pub write_latency: LatencyStats,
    /// Device-wide read-request latencies.
    pub read_latency: LatencyStats,
    /// Device-wide phase split (queued / bus transfer / array) of the
    /// flash ops behind host writes.
    pub write_phases: PhaseStats,
    /// Device-wide phase split of the flash ops behind host reads.
    pub read_phases: PhaseStats,
    /// Timing backend the run used ("lump" | "interconnect").
    pub timing_model: String,
    /// Front end the run used ("page" | "blk").
    pub front_end: String,
    /// Device-wide block-front-end counters (all zero under "page").
    pub blk: BlkStats,
    /// Device-wide host write bandwidth.
    pub bandwidth: BandwidthTimeline,
    /// Device-wide ledger (everything the flash programmed).
    pub ledger: Ledger,
    /// Unattributed programs: idle-time reclamation + final flush.
    pub background: Ledger,
    /// Was per-tenant cache partitioning enforced?
    pub partitioned: bool,
    /// QoS admission-control mode ("off" | "strict" | "slo").
    pub qos_mode: String,
    /// Attribution mode ("proportional" | "owner").
    pub attribution: String,
    /// SLC cache capacity the partitioner carved up (pages).
    pub cache_capacity_pages: u64,
    /// Simulated end time.
    pub sim_end: Nanos,
    /// Fault that fired during the run ("none" for a healthy device,
    /// else the [`crate::config::FaultKind`] name).
    pub fault: String,
    /// Bytes the host wrote (all tenants).
    pub host_bytes_written: u64,
    /// Host-side wall clock of the simulation.
    pub wall_clock: std::time::Duration,
}

impl MultiTenantSummary {
    /// Device-wide write amplification.
    pub fn wa(&self) -> f64 {
        self.ledger.write_amplification()
    }
    /// Look a tenant up by name.
    pub fn tenant(&self, name: &str) -> Option<&TenantStats> {
        self.tenants.iter().find(|t| t.name == name)
    }
    /// Worst victim tail latency (ns) — the cross-tenant interference
    /// headline for the aggressor-victims mix.
    pub fn max_victim_p99(&self) -> Nanos {
        self.tenants
            .iter()
            .filter(|t| t.name.starts_with("victim"))
            .map(|t| t.p99_write_latency())
            .max()
            .unwrap_or(0)
    }
    /// The isolation label this run actually executed under, derived
    /// from the effective config ("shared", "partitioned",
    /// "partitioned+strict", "shared+slo", ...). More specific than the
    /// fleet's `IsolationVariant` axis names — `partitioned+qos` cells
    /// report which QoS mode really ran.
    pub fn variant_name(&self) -> String {
        match (self.partitioned, self.qos_mode.as_str()) {
            (false, "off") => "shared".into(),
            (false, mode) => format!("shared+{mode}"),
            (true, "off") => "partitioned".into(),
            (true, mode) => format!("partitioned+{mode}"),
        }
    }
    /// Total QoS throttle stalls across all tenants.
    pub fn total_throttle_stalls(&self) -> u64 {
        self.tenants.iter().map(|t| t.throttle_stalls).sum()
    }
    /// Names of the tenants the QoS gate throttled at least once.
    pub fn throttled_tenants(&self) -> Vec<&str> {
        self.tenants
            .iter()
            .filter(|t| t.throttle_stalls > 0)
            .map(|t| t.name.as_str())
            .collect()
    }
}

impl MultiTenantSimulator {
    /// Build the simulator from `cfg` (scheme from `cfg.cache.scheme`,
    /// front end from `cfg.host`, tenant traces from
    /// `cfg.host.mix` × `cfg.sim.seed`).
    pub fn new(cfg: Config) -> Result<MultiTenantSimulator> {
        cfg.validate()?;
        let mut ftl = Ftl::new(&cfg)?;
        if cfg.host.attribution == AttributionMode::Owner {
            // exact ownership: tag pages per tenant, and let GC/AGC
            // break victim ties by owning-tenant debt (single-tenant
            // picks stay byte-identical to greedy — differential-tested)
            ftl.set_tenant_count(cfg.host.tenants as usize);
            ftl.set_victim_policy(VictimPolicy::TenantAware);
        }
        let mut policy = cache::build(&cfg);
        policy.init(&mut ftl)?;
        let logical = ftl.map.lpn_limit() * cfg.geometry.page_bytes as u64;
        // Fault trigger: a fraction of the arrival horizon, resolved
        // here before replay starts — so the same `at_frac` schedules
        // proportionally across scenarios/scales. Streaming sources
        // report their span analytically (closed form, or an
        // O(1)-memory arrival replay); the oracle path scans the
        // materialized traces. Both place the trigger at the same
        // nanosecond (differential-tested).
        let (specs, queues, fault_at) = if cfg.sim.streaming_traces {
            let (specs, sources) = tenant::build_mix_sources(&cfg, logical, cfg.sim.seed)?;
            let mut sources = sources;
            let fault_at = if cfg.fault.kind != FaultKind::None {
                let horizon = sources.iter_mut().map(|s| s.horizon()).max().unwrap_or(0);
                Some((horizon as f64 * cfg.fault.at_frac) as Nanos)
            } else {
                None
            };
            let queues: Vec<SubmissionQueue> = specs
                .iter()
                .zip(sources)
                .map(|(s, src)| SubmissionQueue::from_source(s.id, cfg.host.queue_depth, src))
                .collect();
            (specs, queues, fault_at)
        } else {
            let (specs, traces) = tenant::build_mix(&cfg, logical, cfg.sim.seed)?;
            let fault_at = if cfg.fault.kind != FaultKind::None {
                let horizon = traces
                    .iter()
                    .flat_map(|t| t.ops.iter().map(|o| o.at))
                    .max()
                    .unwrap_or(0);
                Some((horizon as f64 * cfg.fault.at_frac) as Nanos)
            } else {
                None
            };
            let queues: Vec<SubmissionQueue> = specs
                .iter()
                .zip(&traces)
                .map(|(s, t)| SubmissionQueue::new(s.id, cfg.host.queue_depth, t))
                .collect();
            (specs, queues, fault_at)
        };
        let weights: Vec<f64> = specs.iter().map(|s| s.weight).collect();
        let sched = sched::build(cfg.host.scheduler, &weights);
        let stats: Vec<TenantStats> = specs
            .iter()
            .map(|s: &TenantSpec| {
                TenantStats::new(
                    s.id.0,
                    s.name.clone(),
                    s.weight,
                    cfg.sim.hist_sub_buckets,
                    cfg.sim.latency_samples,
                    cfg.sim.bandwidth_window,
                )
            })
            .collect();
        let part = CachePartitioner::new(&cfg, &weights, policy.slc_capacity_pages(&ftl));
        let qos = QosGate::new(&cfg.host.qos, &weights);
        Ok(MultiTenantSimulator {
            cfg,
            ftl,
            policy,
            sched,
            queues,
            stats,
            part,
            qos,
            now: 0,
            fault_at,
            fault_fired: false,
        })
    }

    /// Access the FTL (diagnostics, audits).
    pub fn ftl(&self) -> &Ftl {
        &self.ftl
    }
    /// Access the cache partitioner (diagnostics, property tests).
    pub fn partitioner(&self) -> &CachePartitioner {
        &self.part
    }

    /// Drain the FTL's owner events: apply exact cache-residency
    /// releases to the partitioner and credit owned relocation work to
    /// the owning tenants. Owner attribution only.
    ///
    /// `charge_ledgers` is true on the request path: migration programs
    /// move from the dispatching tenant's diff to the owners' ledgers
    /// (the caller keeps only the returned unowned remainder).
    /// Background work (idle, flush) passes false — it stays on the
    /// *background* ledger exactly as under proportional attribution
    /// (so a single tenant is indistinguishable from the shared path),
    /// while the owned-move metrics still record whose data moved.
    fn absorb_owner_events(&mut self, migr_ns: u64, charge_ledgers: bool) -> MoveCounters {
        if !self.ftl.has_owner_events() {
            // common case on the per-page hot path: no exits, no moves
            // — skip the drain's vector churn entirely
            return MoveCounters::default();
        }
        let ev = self.ftl.take_owner_events();
        self.part.apply_owner_events(&ev);
        for (t, mv) in ev.moves.iter().enumerate() {
            let pages = mv.total();
            if pages == 0 {
                continue;
            }
            let ts = &mut self.stats[t];
            if charge_ledgers {
                ts.ledger.gc_migrations += mv.gc_migrations;
                ts.ledger.slc2tlc_migrations += mv.slc2tlc_migrations;
                ts.ledger.agc_reprogram_writes += mv.agc_reprograms;
                ts.ledger.coop_reprogram_writes += mv.coop_reprograms;
            }
            ts.migrated_pages_owned += pages;
            ts.migration_ns_owned += pages * migr_ns;
        }
        ev.moves_unowned
    }
    /// Scheme name.
    pub fn scheme_name(&self) -> &'static str {
        self.policy.name()
    }
    /// Tenant count.
    pub fn tenants(&self) -> usize {
        self.queues.len()
    }
    /// High-water mark of buffered trace ops across all queues. On the
    /// streaming path this is the engine's *entire* workload residency
    /// — no materialized `Trace` exists anywhere — so it must stay
    /// ≤ [`Self::resident_op_bound`] (asserted by the acceptance test).
    pub fn peak_resident_ops(&self) -> usize {
        self.queues.iter().map(|q| q.peak_buffered()).sum()
    }
    /// Σ queue window capacities (queue depth × tenants): the bound
    /// [`Self::peak_resident_ops`] may never exceed.
    pub fn resident_op_bound(&self) -> usize {
        self.queues.iter().map(|q| q.window_cap()).sum()
    }

    /// Drive every queue dry under `scenario`; returns the summary.
    pub fn run(&mut self, scenario: Scenario) -> Result<MultiTenantSummary> {
        let wall0 = std::time::Instant::now();
        let idle_threshold = self.cfg.cache.idle_threshold;
        let owner_attr = self.cfg.host.attribution == AttributionMode::Owner;
        // per-page relocation cost estimate: one read + a third of a
        // one-shot TLC word-line program
        let migr_ns = self.cfg.timing.tlc_read + self.cfg.timing.tlc_prog / 3;
        let page = self.cfg.geometry.page_bytes as u64;
        let lpn_limit = self.ftl.map.lpn_limit();
        let qd = self.cfg.host.device_qd.max(1);
        let mut write_latency = LatencyStats::with_resolution(
            self.cfg.sim.hist_sub_buckets,
            self.cfg.sim.latency_samples,
        );
        let mut read_latency = LatencyStats::with_resolution(
            self.cfg.sim.hist_sub_buckets,
            self.cfg.sim.latency_samples,
        );
        let mut write_phases = PhaseStats::default();
        let mut read_phases = PhaseStats::default();
        let mut bandwidth = BandwidthTimeline::new(self.cfg.sim.bandwidth_window);
        let mut host_bytes = 0u64;
        let mut last_end: Nanos = 0;
        // in-flight dispatched requests: (completion time, tenant)
        let mut inflight: BinaryHeap<Reverse<(Nanos, usize)>> = BinaryHeap::new();
        // per-tenant outstanding commands (bounded by the SQ depth)
        let mut outstanding = vec![0usize; self.queues.len()];
        // block front end: config snapshot, device-wide counters, and
        // per-tenant write counts toward the periodic flush barrier
        let blk_cfg = self.cfg.blk;
        let mut blk_total = BlkStats::default();
        let mut writes_since_flush = vec![0u32; self.queues.len()];
        // attribution backend (§Perf): scoped incremental deltas pushed
        // by the ledger's event methods (the default) vs the historical
        // full-struct snapshot/diff per window (the oracle). Both are
        // byte-identical by the `scope == diff` property.
        let inc = self.cfg.sim.incremental_attribution;
        // dispatch scratch (§Perf): with batched dispatch the per-
        // iteration ready vector and the per-bio plan are reused across
        // the whole run (zero steady-state allocations, asserted by the
        // counting-allocator test); the oracle path reallocates them
        // every iteration like the historical loop did.
        let batched = self.cfg.sim.batched_dispatch;
        let mut ready_scratch: Vec<Option<HeadInfo>> = Vec::with_capacity(self.queues.len());
        let mut plan_buf = blk::Plan::default();

        loop {
            // fire the scheduled fault once the clock crosses its
            // trigger (checked before dispatch so the very next request
            // sees the degraded device)
            if self.fault_at.map(|fa| self.now >= fa).unwrap_or(false) {
                self.fault_at = None;
                self.fault_fired = true;
                match self.cfg.fault.kind {
                    FaultKind::PlaneLoss => {
                        let plane = PlaneId(self.cfg.fault.plane);
                        let bg_before = (!inc).then(|| self.ftl.ledger);
                        if inc {
                            self.ftl.ledger.scope_reset(SCOPE_REQUEST);
                        }
                        let end = self.ftl.retire_plane(plane, self.now)?;
                        self.policy.retire_plane(&mut self.ftl, plane)?;
                        last_end = last_end.max(end);
                        // salvage migrations are device-initiated
                        // background work, like idle reclamation
                        let bg = match bg_before {
                            Some(b) => self.ftl.ledger.diff(&b),
                            None => self.ftl.ledger.scope_take(SCOPE_REQUEST),
                        };
                        self.part.charge_background(&bg);
                        if owner_attr {
                            let _ = self.absorb_owner_events(migr_ns, false);
                        }
                    }
                    FaultKind::Slowdown => {
                        self.ftl.array.set_program_slowdown(self.cfg.fault.slow_x100);
                    }
                    FaultKind::None => {}
                }
            }

            // retire completions up to the front-end clock
            while inflight.peek().map(|&Reverse((t, _))| t <= self.now).unwrap_or(false) {
                let Reverse((_, ti)) = inflight.pop().expect("peeked");
                outstanding[ti] -= 1;
            }

            // earliest token-bucket refill among QoS-throttled heads
            // (a wake-up event: throttling must never deadlock the loop)
            let mut next_token: Option<Nanos> = None;

            // dispatch if the device window is open and a head is ready
            if inflight.len() < qd {
                let now = self.now;
                // tenants with an *arrived* head, before any masking:
                // the partitioner meters the reprogram budget only
                // while neighbours are actually waiting (skip the scan
                // entirely on unpartitioned runs)
                let arrived = if self.part.enabled() {
                    self.queues.iter().filter(|q| q.head_ready(now)).count()
                } else {
                    0
                };
                let qos = &mut self.qos;
                // fill the ready mask in one pass over the queues; the
                // buffer is the run-long scratch under batched dispatch
                // and a fresh per-iteration vector under the oracle —
                // identical contents either way
                let mut ready_fresh: Vec<Option<HeadInfo>>;
                let ready: &mut Vec<Option<HeadInfo>> = if batched {
                    ready_scratch.clear();
                    &mut ready_scratch
                } else {
                    ready_fresh = Vec::with_capacity(self.queues.len());
                    &mut ready_fresh
                };
                for (ti, q) in self.queues.iter().enumerate() {
                    let slot = (|| {
                        let head = q.head().filter(|op| op.at <= now);
                        // live starvation signal for the SLO mode: how
                        // long has this tenant's head been waiting?
                        qos.observe(ti, head.map(|op| op.at), now);
                        // NVMe SQ window: a tenant may not exceed its
                        // queue depth in outstanding commands
                        if outstanding[ti] >= q.depth {
                            return None;
                        }
                        let head = head?;
                        let info = HeadInfo { arrival: head.at, bytes: head.len as u64 };
                        // QoS gate: an over-budget tenant is masked
                        // from the scheduler until its bucket refills
                        match qos.admit(ti, info.bytes, info.arrival, now) {
                            Admission::Admit => Some(info),
                            Admission::ThrottleUntil(t) => {
                                next_token =
                                    Some(next_token.map(|x: Nanos| x.min(t)).unwrap_or(t));
                                None
                            }
                        }
                    })();
                    ready.push(slot);
                }
                if let Some(i) = self.sched.pick(&ready[..]) {
                    let op = self.queues[i].pop().expect("picked head exists");
                    let issue = self.now.max(op.at);
                    let before = (!inc).then(|| self.ftl.ledger);
                    if inc {
                        self.ftl.ledger.scope_reset(SCOPE_REQUEST);
                    }
                    self.ftl.set_tenant(Some(i as u16));
                    let first_lpn = (op.offset / page) % lpn_limit;
                    let n_pages = (op.len as u64).div_ceil(page).max(1);
                    let contended = arrived > 1;
                    let mut req_end = issue;
                    // per-request phase split, folded into the tenant's
                    // and the device's accountants after dispatch
                    let mut req_phases = PhaseStats::default();
                    // unowned relocation remainder accumulated across
                    // the request's per-page drains (owner mode)
                    let mut unowned_moves = MoveCounters::default();
                    // block-front-end counters for this one request
                    let mut bstats = BlkStats::default();
                    // zero-length write plan: dropped before latency /
                    // bandwidth accounting (see `BlkStats::empty_bios`)
                    let mut skip_sample = false;
                    if blk_cfg.enabled {
                        let mut bio = Bio::from_op(&op, blk_cfg.sector_bytes);
                        if blk_cfg.fua && bio.kind == BioKind::Write {
                            bio.fua = true;
                        }
                        // plan into the run-long scratch under batched
                        // dispatch; the oracle allocates per bio
                        if batched {
                            blk::plan_into(&bio, &blk_cfg, page, &mut plan_buf);
                        } else {
                            plan_buf = blk::plan(&bio, &blk_cfg, page);
                        }
                        let plan = &plan_buf;
                        bstats.bios = 1;
                        bstats.splits = plan.splits;
                        bstats.merges = plan.merges;
                        match plan.kind {
                            BioKind::Write if plan.pages.is_empty() => {
                                // zero-length payload: no pages, no
                                // sample — a 0 ns latency entry would
                                // skew this tenant's p50
                                bstats.bios = 0;
                                bstats.empty_bios = 1;
                                skip_sample = true;
                            }
                            BioKind::Write => {
                                bstats.rmw_reads = plan.rmw_reads;
                                bstats.write_pages = plan.pages.len() as u64;
                                for io in &plan.pages {
                                    let lpn = Lpn(io.page % lpn_limit);
                                    // sub-page write: pre-read the page
                                    // first, billed to this tenant; the
                                    // program waits for the read
                                    let mut issue_t = issue;
                                    if io.pre_read {
                                        let pre = self.ftl.host_read(lpn, issue)?;
                                        req_phases.add(&pre);
                                        issue_t = pre.end;
                                        req_end = req_end.max(pre.end);
                                    }
                                    self.ftl.ledger.host_page();
                                    let c = if self.part.enabled() {
                                        let grant = self.part.grant(i, contended);
                                        let page_before = (!inc).then(|| self.ftl.ledger);
                                        if inc {
                                            self.ftl.ledger.scope_reset(SCOPE_PAGE);
                                        }
                                        let c = self.policy.host_write_page_gated(
                                            &mut self.ftl,
                                            lpn,
                                            issue_t,
                                            grant,
                                        )?;
                                        let pd = match page_before {
                                            Some(b) => self.ftl.ledger.diff(&b),
                                            None => self.ftl.ledger.scope_take(SCOPE_PAGE),
                                        };
                                        self.part.charge(i, &pd);
                                        if owner_attr {
                                            let u = self.absorb_owner_events(migr_ns, true);
                                            unowned_moves.add(&u);
                                        }
                                        c
                                    } else {
                                        self.policy.host_write_page(
                                            &mut self.ftl,
                                            lpn,
                                            issue_t,
                                        )?
                                    };
                                    req_phases.add(&c);
                                    req_end = req_end.max(c.end);
                                }
                                writes_since_flush[i] += 1;
                                let barrier = bio.fua
                                    || (blk_cfg.flush_every > 0
                                        && writes_since_flush[i] >= blk_cfg.flush_every);
                                if barrier {
                                    if bio.fua {
                                        bstats.fua_writes = 1;
                                    }
                                    writes_since_flush[i] = 0;
                                    // the barrier orders against every
                                    // dispatched write: drain the device
                                    // window first
                                    let drain = inflight
                                        .iter()
                                        .map(|&Reverse((t, _))| t)
                                        .fold(req_end, |a, b| a.max(b));
                                    let t_end =
                                        self.policy.write_barrier(&mut self.ftl, drain)?;
                                    req_end = req_end.max(t_end);
                                    bstats.flushes = 1;
                                }
                            }
                            BioKind::Read => {
                                bstats.read_pages = plan.pages.len() as u64;
                                for io in &plan.pages {
                                    let lpn = Lpn(io.page % lpn_limit);
                                    let c = self.ftl.host_read(lpn, issue)?;
                                    req_phases.add(&c);
                                    req_end = req_end.max(c.end);
                                }
                            }
                            BioKind::Flush => {
                                // a host flush persists everything this
                                // tenant wrote: restart its periodic
                                // `flush_every` countdown too, or the
                                // next write could double-barrier
                                writes_since_flush[i] = 0;
                                let drain = inflight
                                    .iter()
                                    .map(|&Reverse((t, _))| t)
                                    .fold(issue, |a, b| a.max(b));
                                let t_end = self.policy.write_barrier(&mut self.ftl, drain)?;
                                req_end = req_end.max(t_end);
                                bstats.flushes = 1;
                            }
                        }
                    } else {
                        match op.kind {
                            OpKind::Write if self.part.enabled() => {
                            for k in 0..n_pages {
                                let lpn = Lpn((first_lpn + k) % lpn_limit);
                                self.ftl.ledger.host_page();
                                // cache admission decided per page: the
                                // partitioner sees every allocation
                                let grant = self.part.grant(i, contended);
                                let page_before = (!inc).then(|| self.ftl.ledger);
                                if inc {
                                    self.ftl.ledger.scope_reset(SCOPE_PAGE);
                                }
                                let c = self.policy.host_write_page_gated(
                                    &mut self.ftl,
                                    lpn,
                                    issue,
                                    grant,
                                )?;
                                req_phases.add(&c);
                                let pd = match page_before {
                                    Some(b) => self.ftl.ledger.diff(&b),
                                    None => self.ftl.ledger.scope_take(SCOPE_PAGE),
                                };
                                self.part.charge(i, &pd);
                                if owner_attr {
                                    // drain per page so the next page's
                                    // grant sees releases this page's
                                    // reclamation already earned
                                    let u = self.absorb_owner_events(migr_ns, true);
                                    unowned_moves.add(&u);
                                }
                                req_end = req_end.max(c.end);
                            }
                        }
                        OpKind::Write => {
                            // unpartitioned: the pre-PR hot path, no
                            // per-page snapshots or grants
                            for k in 0..n_pages {
                                let lpn = Lpn((first_lpn + k) % lpn_limit);
                                self.ftl.ledger.host_page();
                                let c = self.policy.host_write_page(&mut self.ftl, lpn, issue)?;
                                req_phases.add(&c);
                                req_end = req_end.max(c.end);
                            }
                        }
                        OpKind::Read => {
                            for k in 0..n_pages {
                                let lpn = Lpn((first_lpn + k) % lpn_limit);
                                let c = self.ftl.host_read(lpn, issue)?;
                                req_phases.add(&c);
                                req_end = req_end.max(c.end);
                            }
                        }
                        }
                    }
                    self.ftl.set_tenant(None);
                    let lat = req_end - op.at; // includes queueing in the SQ
                    let mut diff = match before {
                        Some(b) => self.ftl.ledger.diff(&b),
                        None => self.ftl.ledger.scope_take(SCOPE_REQUEST),
                    };
                    if owner_attr {
                        // exact releases + owner-charged relocations; the
                        // dispatcher keeps only the unowned remainder of
                        // any migration work its request triggered
                        let tail = self.absorb_owner_events(migr_ns, true);
                        unowned_moves.add(&tail);
                        diff.gc_migrations = unowned_moves.gc_migrations;
                        diff.slc2tlc_migrations = unowned_moves.slc2tlc_migrations;
                        diff.agc_reprogram_writes = unowned_moves.agc_reprograms;
                        diff.coop_reprogram_writes = unowned_moves.coop_reprograms;
                    }
                    let st = &mut self.stats[i];
                    st.ledger.merge(&diff);
                    st.cache_occupancy_peak =
                        st.cache_occupancy_peak.max(self.part.occupancy(i));
                    st.blk.merge(&bstats);
                    blk_total.merge(&bstats);
                    match op.kind {
                        OpKind::Write if skip_sample => {}
                        OpKind::Write => {
                            st.write_latency.record(lat);
                            st.write_phases.merge(&req_phases);
                            st.bandwidth.record(req_end, op.len as u64);
                            st.host_bytes_written += op.len as u64;
                            write_latency.record(lat);
                            write_phases.merge(&req_phases);
                            bandwidth.record(req_end, op.len as u64);
                            host_bytes += op.len as u64;
                            self.qos.record_latency(i, lat, req_end);
                        }
                        OpKind::Read => {
                            st.read_latency.record(lat);
                            st.read_phases.merge(&req_phases);
                            read_latency.record(lat);
                            read_phases.merge(&req_phases);
                        }
                    }
                    self.sched.charge(i, op.len as u64);
                    self.qos.charge(i, op.len as u64, issue);
                    inflight.push(Reverse((req_end, i)));
                    outstanding[i] += 1;
                    last_end = last_end.max(req_end);
                    continue;
                }
            }

            // Nothing dispatchable: advance to the next event. Only
            // *future* arrivals count — an already-arrived head that is
            // blocked (device window full, or its tenant at SQ depth)
            // is unblocked by a completion, never by its own arrival;
            // a QoS-throttled head is unblocked by its bucket refill.
            let next_arrival = self
                .queues
                .iter()
                .filter_map(|q| q.next_arrival())
                .filter(|&a| a > self.now)
                .min();
            let next_completion = inflight.peek().map(|&Reverse((t, _))| t);
            let next_token = next_token.filter(|&t| t > self.now);
            let target = if inflight.len() >= qd {
                // window full: only a completion can unblock dispatch
                next_completion.expect("full window has completions")
            } else {
                match (next_arrival, next_completion, next_token) {
                    (None, None, None) => break,
                    (a, None, t) => {
                        // no completion pending: the device is
                        // physically quiescent, so the gap before the
                        // next arrival *or* token refill is an idle
                        // window for background work (daily) — a
                        // QoS-throttled head does not keep the flash
                        // busy
                        let next =
                            [a, t].into_iter().flatten().min().expect("arm has one event");
                        if scenario == Scenario::Daily {
                            let quiesce = self.now.max(last_end);
                            if next > quiesce.saturating_add(idle_threshold) {
                                let start = quiesce.saturating_add(idle_threshold);
                                let bg_before = (!inc).then(|| self.ftl.ledger);
                                if inc {
                                    self.ftl.ledger.scope_reset(SCOPE_REQUEST);
                                }
                                // per-tenant eviction first: a tenant over
                                // its reserved slice reclaims its own
                                // blocks before generic idle work runs
                                let start = if owner_attr {
                                    match self.part.eviction_candidate() {
                                        Some(t) => self.policy.evict_tenant_blocks(
                                            &mut self.ftl,
                                            t as u16,
                                            start,
                                            next,
                                        )?,
                                        None => start,
                                    }
                                } else {
                                    start
                                };
                                self.policy.idle_work(&mut self.ftl, start, next)?;
                                // background reclamation recycles cache
                                // capacity owned by no tenant...
                                let bg = match bg_before {
                                    Some(b) => self.ftl.ledger.diff(&b),
                                    None => self.ftl.ledger.scope_take(SCOPE_REQUEST),
                                };
                                self.part.charge_background(&bg);
                                // ...unless the owner table knows better:
                                // exact releases + owned-move metrics
                                // (ledger attribution stays background)
                                if owner_attr {
                                    let _ = self.absorb_owner_events(migr_ns, false);
                                }
                            }
                        }
                        next
                    }
                    (a, c, t) => [a, c, t].into_iter().flatten().min().expect("some event"),
                }
            };
            self.now = self.now.max(target);
        }

        self.now = self.now.max(last_end);

        // end-of-workload flush (unattributed background work, except
        // that owner attribution charges owned relocations to owners)
        if scenario.flush_at_end() {
            let end = self.policy.flush(&mut self.ftl, self.now)?;
            self.now = self.now.max(end);
            if owner_attr {
                let _ = self.absorb_owner_events(migr_ns, false);
            }
        }

        if self.cfg.sim.verify {
            self.ftl.audit()?;
        }

        // background = device total minus everything tenants caused
        let mut attributed = Ledger::default();
        for t in &self.stats {
            attributed.merge(&t.ledger);
        }
        let background = self.ftl.ledger.diff(&attributed);

        // fold partition/QoS accounting into the per-tenant stats
        for (i, st) in self.stats.iter_mut().enumerate() {
            st.cache_reserved_pages = if self.part.enabled() { self.part.reserved(i) } else { 0 };
            st.slc_denied_pages = self.part.denied(i);
            st.throttle_stalls = self.qos.stalls(i);
            st.throttle_stall_ns = self.qos.stall_ns(i);
        }

        Ok(MultiTenantSummary {
            scheme: self.policy.name().to_string(),
            scheduler: self.sched.name().to_string(),
            mix: self.cfg.host.mix.name().to_string(),
            scenario: scenario.name().to_string(),
            seed: self.cfg.sim.seed,
            tenants: self.stats.clone(),
            write_latency,
            read_latency,
            write_phases,
            read_phases,
            timing_model: (if self.cfg.sim.interconnect { "interconnect" } else { "lump" })
                .to_string(),
            front_end: (if self.cfg.blk.enabled { "blk" } else { "page" }).to_string(),
            blk: blk_total,
            bandwidth,
            ledger: self.ftl.ledger,
            background,
            partitioned: self.part.enabled(),
            qos_mode: self.qos.mode_name().to_string(),
            attribution: self.cfg.host.attribution.name().to_string(),
            cache_capacity_pages: self.part.capacity(),
            sim_end: self.now,
            fault: (if self.fault_fired { self.cfg.fault.kind.name() } else { "none" })
                .to_string(),
            host_bytes_written: host_bytes,
            wall_clock: wall0.elapsed(),
        })
    }

    /// Convenience: build + run in one call.
    pub fn run_once(cfg: Config, scenario: Scenario) -> Result<MultiTenantSummary> {
        MultiTenantSimulator::new(cfg)?.run(scenario)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, MixKind, SchedKind, Scheme};

    fn mt_cfg(scheme: Scheme, sched: SchedKind) -> Config {
        let mut cfg = presets::small();
        cfg.cache.scheme = scheme;
        cfg.cache.slc_cache_bytes = 1 << 20;
        cfg.host.tenants = 4;
        cfg.host.scheduler = sched;
        cfg.host.mix = MixKind::AggressorVictims;
        cfg.host.victim_req_bytes = 4096;
        cfg.sim.verify = true;
        cfg.sim.latency_samples = 100_000;
        cfg
    }

    #[test]
    fn four_tenants_complete_and_attribute() {
        let cfg = mt_cfg(Scheme::Baseline, SchedKind::Fifo);
        let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
        assert_eq!(s.tenants.len(), 4);
        assert_eq!(s.tenants[0].name, "aggressor");
        // every tenant got service
        for t in &s.tenants {
            assert!(t.write_latency.count() > 0, "{} served", t.name);
            assert!(t.host_bytes_written > 0);
        }
        // attribution closes: tenants + background == device ledger
        let mut sum = Ledger::default();
        for t in &s.tenants {
            sum.merge(&t.ledger);
        }
        sum.merge(&s.background);
        assert_eq!(sum, s.ledger, "attribution is exhaustive");
        // the aggressor wrote the bulk of the bytes
        assert!(s.tenants[0].host_bytes_written > s.host_bytes_written / 2);
    }

    #[test]
    fn round_robin_protects_victims_vs_fifo() {
        let run = |sched| {
            let cfg = mt_cfg(Scheme::Baseline, sched);
            MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
        };
        let fifo = run(SchedKind::Fifo);
        let rr = run(SchedKind::RoundRobin);
        // identical offered load either way
        assert_eq!(fifo.host_bytes_written, rr.host_bytes_written);
        // victims dodge the aggressor's backlog under round-robin
        assert!(
            rr.max_victim_p99() <= fifo.max_victim_p99(),
            "rr {} <= fifo {}",
            rr.max_victim_p99(),
            fifo.max_victim_p99()
        );
    }

    #[test]
    fn sq_depth_caps_a_tenants_outstanding() {
        // With depth 1 even FIFO cannot let the aggressor occupy the
        // whole device window, so the victims' tail shrinks (or at
        // worst matches) vs a deep queue.
        let run = |depth| {
            let mut cfg = mt_cfg(Scheme::Baseline, SchedKind::Fifo);
            cfg.host.queue_depth = depth;
            MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
        };
        let deep = run(64);
        let shallow = run(1);
        assert_eq!(deep.host_bytes_written, shallow.host_bytes_written);
        assert!(
            shallow.max_victim_p99() < deep.max_victim_p99(),
            "depth 1 {} < depth 64 {}",
            shallow.max_victim_p99(),
            deep.max_victim_p99()
        );
    }

    #[test]
    fn all_mixes_run_on_ips() {
        for mix in MixKind::all() {
            let mut cfg = mt_cfg(Scheme::Ips, SchedKind::WeightedFair);
            cfg.host.mix = mix;
            let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
            assert!(s.host_bytes_written > 0, "{mix:?} wrote data");
            assert!(s.wa() >= 0.999, "{mix:?} WA sane: {}", s.wa());
        }
    }

    #[test]
    fn read_heavy_records_read_latencies() {
        let mut cfg = mt_cfg(Scheme::Baseline, SchedKind::RoundRobin);
        cfg.host.mix = MixKind::ReadHeavy;
        let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
        assert!(s.read_latency.count() > 0);
        for t in &s.tenants {
            assert!(t.read_latency.count() > 0, "{} read back", t.name);
        }
    }

    #[test]
    fn interconnect_run_attributes_phases_per_tenant() {
        let mut cfg = mt_cfg(Scheme::Ips, SchedKind::RoundRobin);
        cfg.sim.interconnect = true;
        cfg.timing.bus_ns_per_page = 10_000;
        let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
        assert_eq!(s.timing_model, "interconnect");
        assert!(s.write_phases.ops > 0);
        assert!(s.write_phases.transfer_ns > 0, "bus transfers show up in the split");
        assert!(s.write_phases.array_ns > 0);
        // every tenant that wrote carries its own phase attribution,
        // and the per-tenant splits sum to the device-wide one
        let mut sum = crate::metrics::PhaseStats::default();
        for t in &s.tenants {
            assert!(t.write_phases.ops > 0, "{} has a phase split", t.name);
            assert!(t.write_phases.transfer_ns > 0, "{} paid the bus", t.name);
            sum.merge(&t.write_phases);
        }
        assert_eq!(sum, s.write_phases, "tenant splits sum to the device split");
    }

    #[test]
    fn lump_run_reports_pure_array_phases() {
        let cfg = mt_cfg(Scheme::Baseline, SchedKind::Fifo);
        let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
        assert_eq!(s.timing_model, "lump");
        assert!(s.write_phases.ops > 0);
        assert_eq!(s.write_phases.transfer_ns, 0, "no bus exists under the lump");
        assert!(s.write_phases.array_ns > 0);
    }

    #[test]
    fn blk_rmw_billed_to_requesting_tenant() {
        let mut cfg = mt_cfg(Scheme::Ips, SchedKind::RoundRobin);
        cfg.blk.enabled = true;
        cfg.blk.merge_window = 0;
        // sub-page victim requests: every victim write must pre-read
        cfg.host.victim_req_bytes = 1536;
        let s = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
        assert_eq!(s.front_end, "blk");
        assert!(s.blk.bios > 0);
        for t in s.tenants.iter().filter(|t| t.name.starts_with("victim")) {
            assert!(t.blk.rmw_reads > 0, "{} paid RMW pre-reads", t.name);
            assert!(
                t.ledger.host_reads >= t.blk.rmw_reads,
                "{} pre-reads land in its own ledger",
                t.name
            );
        }
        // attribution still closes with pre-reads in the mix
        let mut sum = Ledger::default();
        for t in &s.tenants {
            sum.merge(&t.ledger);
        }
        sum.merge(&s.background);
        assert_eq!(sum, s.ledger, "attribution is exhaustive under blk");
    }

    #[test]
    fn blk_page_aligned_front_end_matches_page_path() {
        // page-aligned requests, merging off: the blk front end resolves
        // to the same per-page op sequence as the page path
        let run = |blk: bool| {
            let mut cfg = mt_cfg(Scheme::Baseline, SchedKind::RoundRobin);
            cfg.blk.enabled = blk;
            cfg.blk.merge_window = 0;
            MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
        };
        let pg = run(false);
        let bk = run(true);
        assert_eq!(pg.ledger, bk.ledger);
        assert_eq!(pg.sim_end, bk.sim_end);
        assert_eq!(pg.host_bytes_written, bk.host_bytes_written);
        for (x, y) in pg.tenants.iter().zip(&bk.tenants) {
            assert_eq!(x.ledger, y.ledger, "{} ledger matches", x.name);
            assert_eq!(x.p99_write_latency(), y.p99_write_latency());
        }
    }

    #[test]
    fn deterministic_summaries() {
        let run = || {
            let cfg = mt_cfg(Scheme::Coop, SchedKind::WeightedFair);
            MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.sim_end, b.sim_end);
        assert_eq!(a.ledger, b.ledger);
        assert_eq!(a.write_latency.count(), b.write_latency.count());
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(x.p99_write_latency(), y.p99_write_latency());
            assert_eq!(x.ledger, y.ledger);
        }
    }

    #[test]
    fn mid_run_plane_loss_degrades_but_completes() {
        use crate::config::FaultKind;
        for scheme in [Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc, Scheme::Coop] {
            let mut cfg = mt_cfg(scheme, SchedKind::RoundRobin);
            cfg.fault.kind = FaultKind::PlaneLoss;
            cfg.fault.at_frac = 0.5;
            cfg.fault.plane = 1;
            let s = MultiTenantSimulator::run_once(cfg.clone(), Scenario::Bursty).unwrap();
            assert_eq!(s.fault, "plane-loss", "{scheme:?} fault fired");
            // every tenant still completes its whole trace
            let healthy = {
                cfg.fault.kind = FaultKind::None;
                MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap()
            };
            assert_eq!(healthy.fault, "none");
            assert_eq!(
                s.host_bytes_written, healthy.host_bytes_written,
                "{scheme:?}: identical offered load on the degraded device"
            );
            // the salvage migrations show up as background work
            assert!(
                s.ledger.gc_migrations >= healthy.ledger.gc_migrations,
                "{scheme:?}: salvage adds migrations"
            );
        }
    }

    #[test]
    fn mid_run_slowdown_stretches_write_tail() {
        use crate::config::FaultKind;
        let mut cfg = mt_cfg(Scheme::Baseline, SchedKind::RoundRobin);
        cfg.fault.kind = FaultKind::Slowdown;
        cfg.fault.at_frac = 0.0; // slow from the first request
        cfg.fault.slow_x100 = 400;
        let slow = MultiTenantSimulator::run_once(cfg.clone(), Scenario::Bursty).unwrap();
        assert_eq!(slow.fault, "slowdown");
        cfg.fault.kind = FaultKind::None;
        let healthy = MultiTenantSimulator::run_once(cfg, Scenario::Bursty).unwrap();
        assert_eq!(slow.host_bytes_written, healthy.host_bytes_written);
        assert!(
            slow.sim_end > healthy.sim_end,
            "4x program/erase time must stretch the run: {} vs {}",
            slow.sim_end,
            healthy.sim_end
        );
    }
}
