//! Tenant-mix scenarios: who the tenants are and what they drive.
//!
//! Each tenant owns a disjoint slice of the logical address space
//! (production SSDs namespace tenants the same way), so per-tenant
//! write-amplification attribution is honest — no tenant invalidates
//! another tenant's pages. Mixes (selected by
//! [`crate::config::MixKind`]):
//!
//! * **aggressor-victims** — tenant 0 bursts
//!   `aggressor_cache_mult ×` the SLC cache size with no think time
//!   (the §III bursty cliff), while K victims issue small paced writes.
//!   The victims' p99 is the cross-tenant interference metric.
//! * **uniform** — every tenant paces the same moderate sequential
//!   stream.
//! * **read-heavy** — every tenant writes a small working set, then
//!   mostly reads it back.
//! * **write-heavy** — every tenant bursts at once (collective cliff).

use super::TenantId;
use crate::config::{Config, MixKind, Nanos};
use crate::trace::scenario::BURSTY_WRITE_BYTES;
use crate::trace::source::OpSource;
use crate::trace::{OpKind, Trace, TraceOp};
use crate::util::rng::{mix64, Rng};
use crate::{Error, Result};

/// A tenant's identity and scheduling weight.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// Tenant id (dense; queue index).
    pub id: TenantId,
    /// Display name ("aggressor", "victim-1", ...).
    pub name: String,
    /// Weighted-fair-share weight.
    pub weight: f64,
}

/// One tenant's disjoint logical-address slice.
#[derive(Clone, Copy, Debug)]
struct Region {
    start: u64,
    len: u64,
}

fn regions(cfg: &Config, logical_bytes: u64) -> Result<Vec<Region>> {
    let n = cfg.host.tenants as u64;
    let page = cfg.geometry.page_bytes as u64;
    let raw = logical_bytes / n;
    let len = raw - raw % page;
    if len < 2 * BURSTY_WRITE_BYTES as u64 {
        return Err(Error::config(format!(
            "logical space too small for {n} tenants ({len} B per tenant)"
        )));
    }
    Ok((0..n).map(|i| Region { start: i * len, len }).collect())
}

/// Sequential writes of `req_bytes` each, totalling `volume`, wrapping
/// inside `region`, arrivals starting at `t0` spaced `gap` apart.
fn stream(name: &str, region: Region, volume: u64, req_bytes: u32, t0: Nanos, gap: Nanos) -> Trace {
    let req = (req_bytes as u64).min(region.len) as u32;
    let n = (volume / req as u64).max(1);
    let wrap = region.len - region.len % req as u64;
    let ops = (0..n)
        .map(|i| TraceOp {
            at: t0 + i * gap.max(1),
            kind: OpKind::Write,
            offset: region.start + (i * req as u64) % wrap,
            len: req,
        })
        .collect();
    Trace { name: name.to_string(), ops }
}

/// Rough lower bound on how long the device stays busy serving
/// `volume` bytes (all-SLC programs, full plane parallelism). Used to
/// pace victims so their requests overlap the aggressor's burst.
fn busy_estimate(cfg: &Config, volume: u64) -> Nanos {
    let pages = (volume / cfg.geometry.page_bytes as u64).max(1);
    let planes = cfg.geometry.planes().max(1) as u64;
    (pages * cfg.timing.slc_prog) / planes
}

/// Build the tenant specs and their traces for `cfg.host` over a
/// device with `logical_bytes` of logical capacity.
///
/// Deterministic in `seed` (victim arrival jitter only); the same
/// `(cfg, logical_bytes, seed)` always yields byte-identical traces.
pub fn build_mix(cfg: &Config, logical_bytes: u64, seed: u64) -> Result<(Vec<TenantSpec>, Vec<Trace>)> {
    let h = &cfg.host;
    let regs = regions(cfg, logical_bytes)?;
    let n = h.tenants as usize;
    let cache = cfg.cache.slc_cache_bytes.max(cfg.geometry.page_bytes as u64);
    let agg_volume = ((cache as f64) * h.aggressor_cache_mult) as u64;
    let mut specs = Vec::with_capacity(n);
    let mut traces = Vec::with_capacity(n);

    match h.mix {
        MixKind::AggressorVictims => {
            for (i, &reg) in regs.iter().enumerate() {
                if i == 0 {
                    specs.push(TenantSpec {
                        id: TenantId(0),
                        name: "aggressor".into(),
                        weight: h.aggressor_weight,
                    });
                    // the §III burst: no think time, cache-cliff volume
                    traces.push(stream("aggressor", reg, agg_volume, BURSTY_WRITE_BYTES, 0, 1));
                } else {
                    specs.push(TenantSpec {
                        id: TenantId(i as u16),
                        name: format!("victim-{i}"),
                        weight: 1.0,
                    });
                    traces.push(victim_trace(cfg, reg, i, agg_volume, seed, OpKind::Write));
                }
            }
        }
        MixKind::Uniform => {
            let volume = (agg_volume / n as u64).max(BURSTY_WRITE_BYTES as u64);
            for (i, &reg) in regs.iter().enumerate() {
                specs.push(TenantSpec {
                    id: TenantId(i as u16),
                    name: format!("tenant-{i}"),
                    weight: 1.0,
                });
                // paced: the per-op gap spreads each stream over the
                // device-busy estimate instead of front-loading it
                let ops = volume / BURSTY_WRITE_BYTES as u64;
                let gap = (busy_estimate(cfg, agg_volume) / ops.max(1)).max(1);
                traces.push(stream(
                    &format!("tenant-{i}"),
                    reg,
                    volume,
                    BURSTY_WRITE_BYTES,
                    i as u64,
                    gap,
                ));
            }
        }
        MixKind::ReadHeavy => {
            for (i, &reg) in regs.iter().enumerate() {
                specs.push(TenantSpec {
                    id: TenantId(i as u16),
                    name: format!("reader-{i}"),
                    weight: 1.0,
                });
                traces.push(victim_trace(cfg, reg, i, agg_volume, seed, OpKind::Read));
            }
        }
        MixKind::WriteHeavy => {
            let volume = (agg_volume / n as u64).max(BURSTY_WRITE_BYTES as u64);
            for (i, &reg) in regs.iter().enumerate() {
                specs.push(TenantSpec {
                    id: TenantId(i as u16),
                    name: format!("writer-{i}"),
                    weight: 1.0,
                });
                // everyone bursts at once: the collective cliff
                traces.push(stream(
                    &format!("writer-{i}"),
                    reg,
                    volume,
                    BURSTY_WRITE_BYTES,
                    i as u64,
                    1,
                ));
            }
        }
    }
    Ok((specs, traces))
}

/// A latency-sensitive tenant: small paced requests overlapping the
/// aggressor's busy window. `tail` = `Read` turns the back half of the
/// trace into read-backs of the tenant's own writes (read-heavy mix).
fn victim_trace(
    cfg: &Config,
    reg: Region,
    tenant: usize,
    agg_volume: u64,
    seed: u64,
    tail: OpKind,
) -> Trace {
    let h = &cfg.host;
    let req = (h.victim_req_bytes as u64).min(reg.len) as u32;
    let busy = busy_estimate(cfg, agg_volume).max(h.victim_gap);
    let n = (busy / h.victim_gap).clamp(64, 5000);
    let wrap = reg.len - reg.len % req as u64;
    let mut rng = Rng::new(mix64(seed, tenant as u64));
    // phase-shift tenants so their arrivals don't lock step
    let mut at = (tenant as u64 * h.victim_gap) / (h.tenants as u64).max(1);
    let mut ops = Vec::with_capacity(n as usize);
    let write_prefix = match tail {
        OpKind::Write => n,
        OpKind::Read => (n / 4).max(1),
    };
    for i in 0..n {
        let kind = if i < write_prefix { OpKind::Write } else { OpKind::Read };
        // reads walk the already-written prefix of the region
        let idx = match kind {
            OpKind::Write => i,
            OpKind::Read => i % write_prefix,
        };
        ops.push(TraceOp {
            at,
            kind,
            offset: reg.start + (idx * req as u64) % wrap,
            len: req,
        });
        // jittered pacing: mean `victim_gap`, never zero
        let jitter = 0.5 + rng.f64();
        at += ((h.victim_gap as f64 * jitter) as Nanos).max(1);
    }
    let name = match tail {
        OpKind::Write => format!("victim-{tenant}"),
        OpKind::Read => format!("reader-{tenant}"),
    };
    Trace { name, ops }
}

// --- streaming sources (§Streaming workloads) ------------------------
//
// Twins of `stream` / `victim_trace`, emitting the same ops one at a
// time so `MultiTenantSimulator` never materializes a tenant trace.
// `build_mix` stays untouched as the byte-identical oracle; the
// lockstep property suite pins `build_mix_sources` against it for
// every mix kind.

/// Streaming twin of [`stream`]: pure arithmetic, closed-form horizon.
pub struct StreamSource {
    name: String,
    region_start: u64,
    wrap: u64,
    req: u32,
    t0: Nanos,
    gap: Nanos,
    n: u64,
    i: u64,
}

impl StreamSource {
    fn new(name: &str, region: Region, volume: u64, req_bytes: u32, t0: Nanos, gap: Nanos) -> StreamSource {
        let req = (req_bytes as u64).min(region.len) as u32;
        let n = (volume / req as u64).max(1);
        let wrap = region.len - region.len % req as u64;
        StreamSource {
            name: name.to_string(),
            region_start: region.start,
            wrap,
            req,
            t0,
            gap: gap.max(1),
            n,
            i: 0,
        }
    }
}

impl OpSource for StreamSource {
    fn next_op(&mut self) -> Option<TraceOp> {
        if self.i >= self.n {
            return None;
        }
        let i = self.i;
        self.i += 1;
        Some(TraceOp {
            at: self.t0 + i * self.gap,
            kind: OpKind::Write,
            offset: self.region_start + (i * self.req as u64) % self.wrap,
            len: self.req,
        })
    }
    fn horizon(&mut self) -> Nanos {
        self.t0 + (self.n - 1) * self.gap
    }
    fn name(&self) -> &str {
        &self.name
    }
}

/// Streaming twin of [`victim_trace`]: same jittered RNG walk carried
/// as incremental state. The horizon is resolved eagerly at
/// construction by replaying the arrival walk with a clone of the RNG
/// (n ≤ 5000, O(1) memory) — the op stream itself is untouched.
pub struct VictimSource {
    name: String,
    rng: Rng,
    at: Nanos,
    i: u64,
    n: u64,
    req: u32,
    wrap: u64,
    region_start: u64,
    write_prefix: u64,
    victim_gap: Nanos,
    horizon: Nanos,
}

impl VictimSource {
    fn new(
        cfg: &Config,
        reg: Region,
        tenant: usize,
        agg_volume: u64,
        seed: u64,
        tail: OpKind,
    ) -> VictimSource {
        let h = &cfg.host;
        let req = (h.victim_req_bytes as u64).min(reg.len) as u32;
        let busy = busy_estimate(cfg, agg_volume).max(h.victim_gap);
        let n = (busy / h.victim_gap).clamp(64, 5000);
        let rng = Rng::new(mix64(seed, tenant as u64));
        let at = (tenant as u64 * h.victim_gap) / (h.tenants as u64).max(1);
        let write_prefix = match tail {
            OpKind::Write => n,
            OpKind::Read => (n / 4).max(1),
        };
        // arrival-walk replay: op n-1 lands after n-1 jittered steps
        let mut probe_rng = rng.clone();
        let mut horizon = at;
        for _ in 1..n {
            let jitter = 0.5 + probe_rng.f64();
            horizon += ((h.victim_gap as f64 * jitter) as Nanos).max(1);
        }
        let name = match tail {
            OpKind::Write => format!("victim-{tenant}"),
            OpKind::Read => format!("reader-{tenant}"),
        };
        VictimSource {
            name,
            rng,
            at,
            i: 0,
            n,
            req,
            wrap: reg.len - reg.len % req as u64,
            region_start: reg.start,
            write_prefix,
            victim_gap: h.victim_gap,
            horizon,
        }
    }
}

impl OpSource for VictimSource {
    fn next_op(&mut self) -> Option<TraceOp> {
        if self.i >= self.n {
            return None;
        }
        let i = self.i;
        self.i += 1;
        let kind = if i < self.write_prefix { OpKind::Write } else { OpKind::Read };
        let idx = match kind {
            OpKind::Write => i,
            OpKind::Read => i % self.write_prefix,
        };
        let op = TraceOp {
            at: self.at,
            kind,
            offset: self.region_start + (idx * self.req as u64) % self.wrap,
            len: self.req,
        };
        // jittered pacing: mean `victim_gap`, never zero — drawn after
        // every op (including the last) to mirror the materialized walk
        let jitter = 0.5 + self.rng.f64();
        self.at += ((self.victim_gap as f64 * jitter) as Nanos).max(1);
        Some(op)
    }
    fn horizon(&mut self) -> Nanos {
        self.horizon
    }
    fn name(&self) -> &str {
        &self.name
    }
}

/// Streaming twin of [`build_mix`]: same specs, but each tenant gets a
/// pull-based source instead of a materialized trace. Deterministic in
/// `seed` exactly like `build_mix` — per (mix, tenant), the source's
/// op stream is byte-identical to the oracle trace.
pub fn build_mix_sources(
    cfg: &Config,
    logical_bytes: u64,
    seed: u64,
) -> Result<(Vec<TenantSpec>, Vec<Box<dyn OpSource>>)> {
    let h = &cfg.host;
    let regs = regions(cfg, logical_bytes)?;
    let n = h.tenants as usize;
    let cache = cfg.cache.slc_cache_bytes.max(cfg.geometry.page_bytes as u64);
    let agg_volume = ((cache as f64) * h.aggressor_cache_mult) as u64;
    let mut specs = Vec::with_capacity(n);
    let mut sources: Vec<Box<dyn OpSource>> = Vec::with_capacity(n);

    match h.mix {
        MixKind::AggressorVictims => {
            for (i, &reg) in regs.iter().enumerate() {
                if i == 0 {
                    specs.push(TenantSpec {
                        id: TenantId(0),
                        name: "aggressor".into(),
                        weight: h.aggressor_weight,
                    });
                    sources.push(Box::new(StreamSource::new(
                        "aggressor",
                        reg,
                        agg_volume,
                        BURSTY_WRITE_BYTES,
                        0,
                        1,
                    )));
                } else {
                    specs.push(TenantSpec {
                        id: TenantId(i as u16),
                        name: format!("victim-{i}"),
                        weight: 1.0,
                    });
                    sources.push(Box::new(VictimSource::new(
                        cfg,
                        reg,
                        i,
                        agg_volume,
                        seed,
                        OpKind::Write,
                    )));
                }
            }
        }
        MixKind::Uniform => {
            let volume = (agg_volume / n as u64).max(BURSTY_WRITE_BYTES as u64);
            for (i, &reg) in regs.iter().enumerate() {
                specs.push(TenantSpec {
                    id: TenantId(i as u16),
                    name: format!("tenant-{i}"),
                    weight: 1.0,
                });
                let ops = volume / BURSTY_WRITE_BYTES as u64;
                let gap = (busy_estimate(cfg, agg_volume) / ops.max(1)).max(1);
                sources.push(Box::new(StreamSource::new(
                    &format!("tenant-{i}"),
                    reg,
                    volume,
                    BURSTY_WRITE_BYTES,
                    i as u64,
                    gap,
                )));
            }
        }
        MixKind::ReadHeavy => {
            for (i, &reg) in regs.iter().enumerate() {
                specs.push(TenantSpec {
                    id: TenantId(i as u16),
                    name: format!("reader-{i}"),
                    weight: 1.0,
                });
                sources.push(Box::new(VictimSource::new(
                    cfg,
                    reg,
                    i,
                    agg_volume,
                    seed,
                    OpKind::Read,
                )));
            }
        }
        MixKind::WriteHeavy => {
            let volume = (agg_volume / n as u64).max(BURSTY_WRITE_BYTES as u64);
            for (i, &reg) in regs.iter().enumerate() {
                specs.push(TenantSpec {
                    id: TenantId(i as u16),
                    name: format!("writer-{i}"),
                    weight: 1.0,
                });
                sources.push(Box::new(StreamSource::new(
                    &format!("writer-{i}"),
                    reg,
                    volume,
                    BURSTY_WRITE_BYTES,
                    i as u64,
                    1,
                )));
            }
        }
    }
    Ok((specs, sources))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, MixKind};

    fn cfg(mix: MixKind) -> Config {
        let mut c = presets::small();
        c.host.mix = mix;
        c.host.tenants = 4;
        c
    }

    const LOGICAL: u64 = 48 << 20;

    #[test]
    fn regions_are_disjoint_and_page_aligned() {
        let c = cfg(MixKind::Uniform);
        let regs = regions(&c, LOGICAL).unwrap();
        assert_eq!(regs.len(), 4);
        for w in regs.windows(2) {
            assert_eq!(w[0].start + w[0].len, w[1].start);
        }
        assert_eq!(regs[0].len % c.geometry.page_bytes as u64, 0);
    }

    #[test]
    fn mixes_build_for_all_kinds() {
        for mix in MixKind::all() {
            let c = cfg(mix);
            let (specs, traces) = build_mix(&c, LOGICAL, 7).unwrap();
            assert_eq!(specs.len(), 4);
            assert_eq!(traces.len(), 4);
            for (s, t) in specs.iter().zip(&traces) {
                assert!(!t.ops.is_empty(), "{} has ops under {:?}", s.name, mix);
                // arrival-sorted, as the queues require
                assert!(t.ops.windows(2).all(|w| w[0].at <= w[1].at));
            }
        }
    }

    #[test]
    fn tenants_stay_inside_their_regions() {
        for mix in MixKind::all() {
            let c = cfg(mix);
            let regs = regions(&c, LOGICAL).unwrap();
            let (_, traces) = build_mix(&c, LOGICAL, 7).unwrap();
            for (t, reg) in traces.iter().zip(&regs) {
                for op in &t.ops {
                    assert!(op.offset >= reg.start, "{mix:?}: {} < {}", op.offset, reg.start);
                    assert!(
                        op.offset + op.len as u64 <= reg.start + reg.len,
                        "{mix:?}: op leaves region"
                    );
                }
            }
        }
    }

    #[test]
    fn aggressor_bursts_and_victims_pace() {
        let c = cfg(MixKind::AggressorVictims);
        let (specs, traces) = build_mix(&c, LOGICAL, 7).unwrap();
        assert_eq!(specs[0].name, "aggressor");
        let agg_gap =
            traces[0].ops.windows(2).map(|w| w[1].at - w[0].at).max().unwrap_or(0);
        assert!(agg_gap <= 1, "aggressor has no think time");
        // aggressor volume drives the cache over the cliff
        assert!(traces[0].total_write_bytes() >= 2 * c.cache.slc_cache_bytes);
        let victim_gap =
            traces[1].ops.windows(2).map(|w| w[1].at - w[0].at).min().unwrap_or(0);
        assert!(victim_gap >= c.host.victim_gap / 2, "victims are paced");
    }

    #[test]
    fn read_heavy_is_mostly_reads() {
        let c = cfg(MixKind::ReadHeavy);
        let (_, traces) = build_mix(&c, LOGICAL, 7).unwrap();
        for t in &traces {
            let reads = t.ops.iter().filter(|o| o.kind == OpKind::Read).count();
            assert!(reads * 2 > t.ops.len(), "reads dominate: {}/{}", reads, t.ops.len());
        }
    }

    #[test]
    fn sources_match_traces_for_every_mix() {
        for mix in MixKind::all() {
            let c = cfg(mix);
            let (specs_t, traces) = build_mix(&c, LOGICAL, 7).unwrap();
            let (specs_s, sources) = build_mix_sources(&c, LOGICAL, 7).unwrap();
            assert_eq!(specs_t.len(), specs_s.len());
            for ((st, ss), (trace, mut src)) in
                specs_t.iter().zip(&specs_s).zip(traces.into_iter().zip(sources))
            {
                assert_eq!(st.name, ss.name, "{mix:?}: spec name");
                assert_eq!(st.weight.to_bits(), ss.weight.to_bits(), "{mix:?}: weight");
                let materialized_horizon =
                    trace.ops.iter().map(|o| o.at).max().unwrap_or(0);
                assert_eq!(src.horizon(), materialized_horizon, "{mix:?}/{}: horizon", st.name);
                let mut got = Vec::new();
                while let Some(op) = src.next_op() {
                    got.push(op);
                }
                assert_eq!(got, trace.ops, "{mix:?}/{}: op stream diverged", st.name);
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let c = cfg(MixKind::AggressorVictims);
        let (_, a) = build_mix(&c, LOGICAL, 42).unwrap();
        let (_, b) = build_mix(&c, LOGICAL, 42).unwrap();
        assert_eq!(a.iter().map(|t| &t.ops).collect::<Vec<_>>(),
                   b.iter().map(|t| &t.ops).collect::<Vec<_>>());
        let (_, d) = build_mix(&c, LOGICAL, 43).unwrap();
        assert_ne!(a[1].ops, d[1].ops, "victim jitter follows the seed");
    }
}
