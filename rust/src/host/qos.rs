//! QoS admission control: per-tenant token buckets in front of the
//! request schedulers.
//!
//! The PR-1 schedulers decide *order* among ready requests, but a
//! bursty aggressor still occupies the device window whenever the
//! victims are momentarily idle — and the backlog it builds inside the
//! device is what the victims' tail latency pays for. The [`QosGate`]
//! adds *admission* control ahead of scheduling: each tenant owns a
//! token bucket (sustained rate × scheduler weight, plus a burst
//! budget); a tenant whose bucket cannot cover its head request is
//! masked from the scheduler until the bucket refills, and the engine
//! treats the refill time as a wake-up event so throttling never
//! deadlocks the dispatch loop.
//!
//! Two enforcement modes (config `[host.qos] mode`):
//!
//! * **strict** — buckets always enforced; the device holds slack for
//!   latecomers even when nobody is waiting.
//! * **slo** — work-conserving: buckets are enforced *only while some
//!   other tenant is missing the configured victim-p99 SLO*. While the
//!   device is keeping its promises, even an over-budget tenant
//!   dispatches freely. Two breach signals feed the mode: a completed
//!   write over the target arms a breach *pulse* that expires after
//!   one SLO interval (or on the tenant's next compliant completion),
//!   and the *age of a waiting head request* is the live level signal
//!   that catches a FIFO monopoly where starved victims never
//!   complete at all. Both signals decay, so a single slow write from
//!   a tenant that then goes idle cannot throttle its neighbours
//!   forever.
//!
//! Invariants (property-tested in `tests/prop_partition.rs`): bucket
//! levels always stay within `[0, burst]` — refills saturate at the
//! burst budget and debits saturate at zero, so a bucket can never go
//! negative no matter the (dispatch, refill) interleaving.

use crate::config::{Nanos, QosConfig, QosMode};

/// One tenant's token bucket.
#[derive(Clone, Debug)]
struct Bucket {
    /// Current tokens (bytes of admissible traffic).
    tokens: f64,
    /// Bucket capacity (burst budget, bytes).
    burst: f64,
    /// Refill rate (bytes per nanosecond).
    rate: f64,
    /// Last refill timestamp.
    last: Nanos,
}

impl Bucket {
    fn refill(&mut self, now: Nanos) {
        if now > self.last {
            self.tokens = (self.tokens + (now - self.last) as f64 * self.rate).min(self.burst);
            self.last = now;
        }
    }
}

/// Admission decision for one ready head request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Dispatchable now.
    Admit,
    /// Masked from the scheduler until roughly this time.
    ThrottleUntil(Nanos),
}

/// Per-tenant token-bucket admission controller.
#[derive(Clone, Debug)]
pub struct QosGate {
    mode: QosMode,
    slo_p99: Nanos,
    buckets: Vec<Bucket>,
    /// An over-target completion arms a breach pulse until this time
    /// (one SLO interval past the completion); the tenant's next
    /// compliant completion disarms it early.
    breach_until: Vec<Nanos>,
    /// Tenant's head request has been waiting past the SLO (live
    /// starvation signal, updated by [`QosGate::observe`]).
    starved: Vec<bool>,
    /// Expiry of the gate's latest mask on this tenant. Waiting the
    /// gate itself imposed must not count as an SLO breach — starvation
    /// is measured from `max(arrival, mask expiry)` — or two
    /// over-budget tenants would keep each other throttled forever.
    throttled_until: Vec<Nanos>,
    /// Arrival of the last request we counted a throttle-stall for
    /// (dedupes the per-request stall count across dispatch attempts).
    last_stalled_arrival: Vec<Option<Nanos>>,
    /// Distinct requests throttled, per tenant.
    stalls: Vec<u64>,
    /// Estimated delay imposed by throttling, per tenant (ns).
    stall_ns: Vec<u64>,
}

impl QosGate {
    /// Build the gate for tenants with the given scheduler `weights`.
    pub fn new(cfg: &QosConfig, weights: &[f64]) -> QosGate {
        let n = weights.len();
        let buckets = weights
            .iter()
            .map(|&w| Bucket {
                tokens: cfg.burst_bytes as f64,
                burst: cfg.burst_bytes as f64,
                rate: cfg.rate_bytes_per_ns(w),
                last: 0,
            })
            .collect();
        QosGate {
            mode: cfg.mode,
            slo_p99: cfg.slo_p99,
            buckets,
            breach_until: vec![0; n],
            starved: vec![false; n],
            throttled_until: vec![0; n],
            last_stalled_arrival: vec![None; n],
            stalls: vec![0; n],
            stall_ns: vec![0; n],
        }
    }

    /// Is admission control active at all?
    pub fn enabled(&self) -> bool {
        self.mode != QosMode::Off
    }
    /// Mode name for reports.
    pub fn mode_name(&self) -> &'static str {
        self.mode.name()
    }
    /// Distinct requests throttled for tenant `t`.
    pub fn stalls(&self, t: usize) -> u64 {
        self.stalls[t]
    }
    /// Estimated throttle-imposed delay for tenant `t` (ns).
    pub fn stall_ns(&self, t: usize) -> u64 {
        self.stall_ns[t]
    }
    /// Current token level of tenant `t` (bytes, without refilling).
    pub fn tokens(&self, t: usize) -> f64 {
        self.buckets[t].tokens
    }
    /// Burst budget of tenant `t` (bytes).
    pub fn burst(&self, t: usize) -> f64 {
        self.buckets[t].burst
    }

    /// Update tenant `t`'s live starvation signal: `head_arrival` is
    /// the arrival time of its oldest waiting request, `None` when the
    /// tenant has nothing waiting. Called every dispatch round. A head
    /// the gate itself is masking does not count — only waiting the
    /// *device* imposes is an SLO breach.
    pub fn observe(&mut self, t: usize, head_arrival: Option<Nanos>, now: Nanos) {
        if self.mode != QosMode::Slo {
            return;
        }
        self.starved[t] = head_arrival
            .map(|a| {
                // count only the wait the device imposed: time spent
                // under the gate's own mask is excluded even after the
                // mask lapses
                let device_wait_start = a.max(self.throttled_until[t]);
                now.saturating_sub(device_wait_start) > self.slo_p99
            })
            .unwrap_or(false);
    }

    /// Decide whether tenant `t`'s head request (`bytes`, arrived at
    /// `arrival`) may enter the scheduler at `now`.
    pub fn admit(&mut self, t: usize, bytes: u64, arrival: Nanos, now: Nanos) -> Admission {
        if self.mode == QosMode::Off {
            return Admission::Admit;
        }
        self.buckets[t].refill(now);
        // an oversized request (> burst) passes on a full bucket —
        // otherwise it could never be admitted at all
        let need = (bytes as f64).min(self.buckets[t].burst);
        if self.buckets[t].tokens >= need {
            return Admission::Admit;
        }
        if self.mode == QosMode::Slo && !self.slo_violated_for(t, now) {
            // work-conserving: nobody is missing their tail target, so
            // the over-budget tenant may proceed
            return Admission::Admit;
        }
        let b = &self.buckets[t];
        let deficit = need - b.tokens;
        let wait = (deficit / b.rate.max(1e-12)).ceil() as Nanos;
        let mut until = now.saturating_add(wait.max(1));
        if self.mode == QosMode::Slo {
            // enforcement may lapse before the bucket refills: when no
            // other tenant is starving, the latest active breach pulse
            // bounds how long this tenant can actually be held
            let others_starved =
                self.starved.iter().enumerate().any(|(u, &s)| u != t && s);
            if !others_starved {
                let lapse = self
                    .breach_until
                    .iter()
                    .enumerate()
                    .filter(|&(u, &bu)| u != t && bu > now)
                    .map(|(_, &bu)| bu)
                    .max();
                if let Some(l) = lapse {
                    until = until.min(l);
                }
            }
        }
        self.throttled_until[t] = until;
        if self.last_stalled_arrival[t] != Some(arrival) {
            self.last_stalled_arrival[t] = Some(arrival);
            self.stalls[t] += 1;
            self.stall_ns[t] += until - now;
        }
        Admission::ThrottleUntil(until)
    }

    /// Account `bytes` of dispatched service for tenant `t` (called
    /// alongside `Scheduler::charge`). Debits saturate at zero: in SLO
    /// mode a tenant may dispatch while in debt, and the bucket floor
    /// is what keeps the debt from becoming unbounded punishment.
    pub fn charge(&mut self, t: usize, bytes: u64, now: Nanos) {
        if self.mode == QosMode::Off {
            return;
        }
        self.buckets[t].refill(now);
        self.buckets[t].tokens = (self.buckets[t].tokens - bytes as f64).max(0.0);
    }

    /// Record a completed write latency for tenant `t` that finished
    /// at `end` (SLO detection). An over-target write arms a breach
    /// pulse lasting one SLO interval; a compliant write disarms it —
    /// the most recent completion is authoritative. A request the gate
    /// itself stalled carries self-inflicted latency and never arms a
    /// pulse (it would re-trigger the very enforcement that caused it).
    pub fn record_latency(&mut self, t: usize, lat: Nanos, end: Nanos) {
        if self.mode != QosMode::Slo {
            return;
        }
        let arrival = end.saturating_sub(lat);
        let self_inflicted = self.last_stalled_arrival[t] == Some(arrival);
        self.breach_until[t] = if lat > self.slo_p99 && !self_inflicted {
            end.saturating_add(self.slo_p99)
        } else {
            0
        };
    }

    /// Is any *other* tenant missing the SLO at `now` — either a
    /// recently completed write over the target, or a head request
    /// starving past it?
    fn slo_violated_for(&self, t: usize, now: Nanos) -> bool {
        self.starved
            .iter()
            .enumerate()
            .any(|(u, &st)| u != t && (st || self.breach_until[u] > now))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;

    fn cfg(mode: QosMode) -> QosConfig {
        QosConfig {
            mode,
            rate_mbps: 8.0, // 8 B/µs = 0.008 B/ns
            burst_bytes: 64 << 10,
            slo_p99: 10 * MS,
        }
    }

    #[test]
    fn off_admits_everything_for_free() {
        let mut g = QosGate::new(&cfg(QosMode::Off), &[1.0]);
        for i in 0..100 {
            assert_eq!(g.admit(0, 1 << 30, i, i), Admission::Admit);
            g.charge(0, 1 << 30, i);
        }
        assert_eq!(g.stalls(0), 0);
    }

    #[test]
    fn strict_throttles_past_the_burst_budget() {
        let mut g = QosGate::new(&cfg(QosMode::Strict), &[1.0]);
        // burn the burst budget at t=0
        assert_eq!(g.admit(0, 64 << 10, 0, 0), Admission::Admit);
        g.charge(0, 64 << 10, 0);
        // the next request must wait for the refill
        match g.admit(0, 64 << 10, 1, 0) {
            Admission::ThrottleUntil(t) => {
                // 64 KiB at 0.008 B/ns = 8.192 ms
                assert!((8_000_000..9_000_000).contains(&t), "refill wait {t}");
            }
            a => panic!("expected throttle, got {a:?}"),
        }
        assert_eq!(g.stalls(0), 1);
        // repeated attempts for the same request count once
        let _ = g.admit(0, 64 << 10, 1, 1000);
        assert_eq!(g.stalls(0), 1);
        // after the refill the request is admitted
        assert_eq!(g.admit(0, 64 << 10, 1, 10_000_000), Admission::Admit);
    }

    #[test]
    fn slo_mode_is_work_conserving_until_violated() {
        let mut g = QosGate::new(&cfg(QosMode::Slo), &[1.0, 1.0]);
        g.charge(0, 64 << 10, 0); // tenant 0 over budget
        // no one is missing their SLO: admit anyway
        assert_eq!(g.admit(0, 64 << 10, 0, 0), Admission::Admit);
        // tenant 1 reports a tail-latency breach -> enforcement kicks in
        g.record_latency(1, 20 * MS, 0);
        assert!(matches!(g.admit(0, 64 << 10, 0, 0), Admission::ThrottleUntil(_)));
        // tenant 1's own bucket is unaffected by its own breach
        assert_eq!(g.admit(1, 4096, 0, 0), Admission::Admit);
    }

    #[test]
    fn slo_breach_pulse_decays_with_time_and_on_compliant_completions() {
        let mut g = QosGate::new(&cfg(QosMode::Slo), &[1.0, 1.0]);
        // breach completed at t=0: enforcement holds for one SLO
        // interval (10 ms), then expires even if tenant 1 goes idle
        g.record_latency(1, 20 * MS, 0);
        g.charge(0, 10 << 20, 5 * MS); // keep tenant 0's bucket empty
        assert!(matches!(g.admit(0, 64 << 10, 0, 5 * MS), Admission::ThrottleUntil(_)));
        g.charge(0, 10 << 20, 11 * MS);
        assert_eq!(
            g.admit(0, 64 << 10, 0, 11 * MS),
            Admission::Admit,
            "a stale breach from an idle tenant must not throttle forever"
        );
        // a fresh breach followed by a compliant completion disarms early
        g.record_latency(1, 20 * MS, 12 * MS);
        g.record_latency(1, MS, 13 * MS);
        g.charge(0, 10 << 20, 13 * MS);
        assert_eq!(g.admit(0, 64 << 10, 0, 13 * MS), Admission::Admit);
    }

    #[test]
    fn starving_head_triggers_slo_enforcement_without_completions() {
        // the FIFO-monopoly case: the victim never completes a write,
        // so only its waiting head can signal the breach
        let mut g = QosGate::new(&cfg(QosMode::Slo), &[1.0, 1.0]);
        g.charge(0, 64 << 10, 0); // aggressor over budget
        assert_eq!(g.admit(0, 64 << 10, 0, 0), Admission::Admit, "no breach yet");
        // victim head waiting 20 ms > 10 ms SLO; aggressor still broke
        g.observe(1, Some(0), 20 * MS);
        g.charge(0, 1 << 20, 20 * MS); // keep the bucket empty at the breach
        assert!(matches!(g.admit(0, 64 << 10, 0, 20 * MS), Admission::ThrottleUntil(_)));
        // the victim drains: signal clears, aggressor flows again
        g.observe(1, None, 20 * MS);
        g.record_latency(1, MS, 20 * MS); // a healthy completion, below the SLO
        assert_eq!(g.admit(0, 64 << 10, 0, 20 * MS), Admission::Admit);
    }

    #[test]
    fn gate_inflicted_delay_never_counts_as_an_slo_breach() {
        // the mutual-throttling trap: once the gate masks tenant 0,
        // tenant 0's aging head and inflated completion latency must
        // not read as SLO breaches, or two over-budget tenants would
        // keep each other throttled forever
        let mut g = QosGate::new(
            &QosConfig {
                mode: QosMode::Slo,
                rate_mbps: 8.0,
                burst_bytes: 64 << 10,
                slo_p99: 5 * MS,
            },
            &[1.0, 1.0],
        );
        // tenant 1 starves for real -> over-budget tenant 0 is masked
        g.observe(1, Some(0), 6 * MS);
        g.charge(0, 10 << 20, 6 * MS);
        assert!(matches!(g.admit(0, 64 << 10, 0, 6 * MS), Admission::ThrottleUntil(_)));
        // tenant 0's head is old (age > slo) but the wait is the
        // gate's own doing: it must not register as starvation
        g.observe(0, Some(0), 7 * MS);
        // tenant 1 drains; no genuine breach signal remains
        g.observe(1, None, 7 * MS);
        g.charge(1, 10 << 20, 7 * MS);
        assert_eq!(
            g.admit(1, 64 << 10, 0, 7 * MS),
            Admission::Admit,
            "tenant 0's gate-masked wait must not throttle tenant 1"
        );
        // the throttled request's completion carries gate-imposed
        // latency: it must not arm a breach pulse either
        g.record_latency(0, 20 * MS, 20 * MS); // arrival 0 = the stalled request
        assert_eq!(g.admit(1, 64 << 10, 0, 8 * MS), Admission::Admit);
    }

    #[test]
    fn buckets_stay_within_bounds() {
        let mut g = QosGate::new(&cfg(QosMode::Strict), &[2.0]);
        let burst = g.burst(0);
        let mut now = 0;
        for i in 0..1000u64 {
            now += (i * 37) % 100_000;
            let _ = g.admit(0, (i * 997) % (1 << 20), i, now);
            g.charge(0, (i * 31) % (1 << 18), now);
            assert!(g.tokens(0) >= 0.0, "never negative");
            assert!(g.tokens(0) <= burst, "never above burst");
        }
    }

    #[test]
    fn weight_scales_the_refill_rate() {
        let c = cfg(QosMode::Strict);
        let mut heavy = QosGate::new(&c, &[4.0]);
        let mut light = QosGate::new(&c, &[1.0]);
        for g in [&mut heavy, &mut light] {
            g.charge(0, 64 << 10, 0); // empty both buckets
        }
        let wait_of = |g: &mut QosGate| match g.admit(0, 64 << 10, 0, 0) {
            Admission::ThrottleUntil(t) => t,
            Admission::Admit => 0,
        };
        let h = wait_of(&mut heavy);
        let l = wait_of(&mut light);
        assert!(h > 0 && l > 0 && h * 3 < l, "4x weight refills ~4x faster: {h} vs {l}");
    }
}
