//! NVMe-style per-tenant submission queue.
//!
//! A queue holds one tenant's remaining trace in arrival order. At any
//! front-end time `now`, the head is *ready* when it has arrived; the
//! `depth` bound models the NVMe submission-queue depth — the engine
//! caps each tenant at `depth` outstanding commands, so a tenant
//! whose window is full is skipped by the scheduler until one of its
//! requests completes.

use super::TenantId;
use crate::config::Nanos;
use crate::trace::{Trace, TraceOp};
use std::collections::VecDeque;

/// One tenant's submission queue.
#[derive(Clone, Debug)]
pub struct SubmissionQueue {
    /// Owning tenant.
    pub tenant: TenantId,
    /// Queue depth (max outstanding commands for this tenant).
    pub depth: usize,
    ops: VecDeque<TraceOp>,
}

impl SubmissionQueue {
    /// Build a queue over `trace` (ops must be arrival-sorted; [`Trace`]
    /// generators produce them that way).
    pub fn new(tenant: TenantId, depth: usize, trace: &Trace) -> SubmissionQueue {
        debug_assert!(
            trace.ops.windows(2).all(|w| w[0].at <= w[1].at),
            "trace must be arrival-sorted"
        );
        SubmissionQueue { tenant, depth: depth.max(1), ops: trace.ops.iter().copied().collect() }
    }

    /// The head request, if the queue is non-empty.
    pub fn head(&self) -> Option<&TraceOp> {
        self.ops.front()
    }

    /// Is the head request ready (arrived) at `now`?
    pub fn head_ready(&self, now: Nanos) -> bool {
        self.head().map(|op| op.at <= now).unwrap_or(false)
    }

    /// Bytes resident in the queue window at `now` (arrived requests,
    /// capped at `depth`) — a backlog diagnostic.
    pub fn resident_bytes(&self, now: Nanos) -> u64 {
        self.ops
            .iter()
            .take(self.depth)
            .take_while(|op| op.at <= now)
            .map(|op| op.len as u64)
            .sum()
    }

    /// Pop the head request.
    pub fn pop(&mut self) -> Option<TraceOp> {
        self.ops.pop_front()
    }

    /// Arrival time of the next (head) request.
    pub fn next_arrival(&self) -> Option<Nanos> {
        self.head().map(|op| op.at)
    }

    /// Requests left.
    pub fn backlog(&self) -> usize {
        self.ops.len()
    }

    /// Fully drained?
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::OpKind;

    fn q(depth: usize, ats: &[u64]) -> SubmissionQueue {
        let t = Trace {
            name: "q".into(),
            ops: ats
                .iter()
                .map(|&at| TraceOp { at, kind: OpKind::Write, offset: 0, len: 4096 })
                .collect(),
        };
        SubmissionQueue::new(TenantId(0), depth, &t)
    }

    #[test]
    fn readiness_follows_arrivals() {
        let mut sq = q(8, &[10, 20]);
        assert!(!sq.head_ready(5));
        assert!(sq.head_ready(10));
        assert_eq!(sq.pop().unwrap().at, 10);
        assert_eq!(sq.next_arrival(), Some(20));
        assert_eq!(sq.backlog(), 1);
        sq.pop();
        assert!(sq.is_empty());
        assert!(!sq.head_ready(100));
    }

    #[test]
    fn resident_bytes_respects_depth_and_arrivals() {
        let sq = q(2, &[0, 0, 0, 50]);
        // depth caps at 2 even though 3 ops have arrived at t=0
        assert_eq!(sq.resident_bytes(0), 2 * 4096);
        let sq = q(8, &[0, 0, 0, 50]);
        assert_eq!(sq.resident_bytes(0), 3 * 4096);
        assert_eq!(sq.resident_bytes(50), 4 * 4096);
    }
}
