//! NVMe-style per-tenant submission queue, windowed over a streaming
//! source.
//!
//! A queue is a bounded window (at most `depth` buffered requests)
//! pulled on demand from an [`OpSource`] (§Streaming workloads). At any
//! front-end time `now`, the head is *ready* when it has arrived; the
//! `depth` bound models the NVMe submission-queue depth — the engine
//! caps each tenant at `depth` outstanding commands, so a tenant whose
//! window is full is skipped by the scheduler until one of its
//! requests completes. Because the engine never looks past the head,
//! the window is also the queue's entire memory footprint: the
//! workload behind it stays un-materialized, which is what makes
//! per-device trace memory O(queue window) instead of O(trace).
//!
//! Invariants:
//! * the window holds the next ≤ `depth` ops of the source, in arrival
//!   order; it is non-empty unless the source is exhausted (refilled at
//!   construction and after every `pop`);
//! * `arrived`/`resident` track the window's arrived prefix
//!   incrementally (satellite: no O(backlog) rescan) — valid because
//!   the engine clock is monotone, which `resident_bytes` debug-asserts;
//! * `peak_buffered` is the high-water window occupancy, the bound the
//!   streaming acceptance test asserts (`≤ depth × tenants` fleet-wide).

use super::TenantId;
use crate::config::Nanos;
use crate::trace::source::{MaterializedSource, OpSource};
use crate::trace::{Trace, TraceOp};
use std::collections::VecDeque;

/// One tenant's submission queue: a bounded window over a source.
pub struct SubmissionQueue {
    /// Owning tenant.
    pub tenant: TenantId,
    /// Queue depth (max outstanding commands for this tenant; also the
    /// window capacity).
    pub depth: usize,
    source: Box<dyn OpSource>,
    window: VecDeque<TraceOp>,
    /// Length of the window prefix known to have arrived by `frontier`.
    arrived: usize,
    /// Bytes in that arrived prefix (the incremental resident count).
    resident: u64,
    /// Latest `now` ever passed to [`resident_bytes`] (monotone).
    frontier: Nanos,
    peak_buffered: usize,
}

impl SubmissionQueue {
    /// Build a queue over a materialized `trace` (ops must be
    /// arrival-sorted; [`Trace`] generators produce them that way).
    /// This is the oracle feed: same windowed queue, materialized
    /// source behind it.
    pub fn new(tenant: TenantId, depth: usize, trace: &Trace) -> SubmissionQueue {
        SubmissionQueue::from_source(tenant, depth, Box::new(MaterializedSource::new(trace.clone())))
    }

    /// Build a queue windowed over any streaming `source`.
    pub fn from_source(
        tenant: TenantId,
        depth: usize,
        source: Box<dyn OpSource>,
    ) -> SubmissionQueue {
        let depth = depth.max(1);
        let mut q = SubmissionQueue {
            tenant,
            depth,
            source,
            window: VecDeque::with_capacity(depth),
            arrived: 0,
            resident: 0,
            frontier: 0,
            peak_buffered: 0,
        };
        q.refill();
        q
    }

    /// Top the window back up to `depth` from the source.
    fn refill(&mut self) {
        while self.window.len() < self.depth {
            match self.source.next_op() {
                Some(op) => {
                    debug_assert!(
                        self.window.back().is_none_or(|b| b.at <= op.at),
                        "source must be arrival-sorted"
                    );
                    self.window.push_back(op);
                }
                None => break,
            }
        }
        self.peak_buffered = self.peak_buffered.max(self.window.len());
    }

    /// The head request, if the queue is non-empty.
    pub fn head(&self) -> Option<&TraceOp> {
        self.window.front()
    }

    /// Is the head request ready (arrived) at `now`?
    pub fn head_ready(&self, now: Nanos) -> bool {
        self.head().map(|op| op.at <= now).unwrap_or(false)
    }

    /// Bytes resident in the queue window at `now` (arrived requests,
    /// capped at `depth`) — a backlog diagnostic. Maintained
    /// incrementally: the arrived frontier only advances, so `now` must
    /// be monotone across calls (the engine clock is).
    pub fn resident_bytes(&mut self, now: Nanos) -> u64 {
        debug_assert!(now >= self.frontier, "engine time must be monotone");
        self.frontier = self.frontier.max(now);
        while self.arrived < self.window.len() {
            let op = self.window[self.arrived];
            if op.at > now {
                break; // window is arrival-sorted: nothing later has arrived either
            }
            self.resident += op.len as u64;
            self.arrived += 1;
        }
        self.resident
    }

    /// Pop the head request and pull the window's replacement from the
    /// source.
    pub fn pop(&mut self) -> Option<TraceOp> {
        let op = self.window.pop_front()?;
        if self.arrived > 0 {
            self.arrived -= 1;
            self.resident -= op.len as u64;
        }
        self.refill();
        Some(op)
    }

    /// Arrival time of the next (head) request.
    pub fn next_arrival(&self) -> Option<Nanos> {
        self.head().map(|op| op.at)
    }

    /// Requests buffered in the window (≤ `depth`; the source behind it
    /// may hold arbitrarily more).
    pub fn backlog(&self) -> usize {
        self.window.len()
    }

    /// Fully drained? (The window is refilled eagerly, so an empty
    /// window means the source is exhausted too.)
    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// High-water mark of buffered requests (≤ `depth`).
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }

    /// The window capacity (the bound `peak_buffered` must obey).
    pub fn window_cap(&self) -> usize {
        self.depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::source::SeqFillSource;
    use crate::trace::OpKind;

    fn q(depth: usize, ats: &[u64]) -> SubmissionQueue {
        let t = Trace {
            name: "q".into(),
            ops: ats
                .iter()
                .map(|&at| TraceOp { at, kind: OpKind::Write, offset: 0, len: 4096 })
                .collect(),
        };
        SubmissionQueue::new(TenantId(0), depth, &t)
    }

    #[test]
    fn readiness_follows_arrivals() {
        let mut sq = q(8, &[10, 20]);
        assert!(!sq.head_ready(5));
        assert!(sq.head_ready(10));
        assert_eq!(sq.pop().unwrap().at, 10);
        assert_eq!(sq.next_arrival(), Some(20));
        assert_eq!(sq.backlog(), 1);
        sq.pop();
        assert!(sq.is_empty());
        assert!(!sq.head_ready(100));
    }

    #[test]
    fn resident_bytes_respects_depth_and_arrivals() {
        let mut sq = q(2, &[0, 0, 0, 50]);
        // depth caps at 2 even though 3 ops have arrived at t=0
        assert_eq!(sq.resident_bytes(0), 2 * 4096);
        let mut sq = q(8, &[0, 0, 0, 50]);
        assert_eq!(sq.resident_bytes(0), 3 * 4096);
        assert_eq!(sq.resident_bytes(50), 4 * 4096);
    }

    #[test]
    fn resident_count_stays_incremental_across_pops() {
        let mut sq = q(2, &[0, 0, 0, 50]);
        assert_eq!(sq.resident_bytes(0), 2 * 4096);
        // popping an arrived op both shrinks the resident set and pulls
        // the third t=0 op into the window, where the frontier finds it
        sq.pop();
        assert_eq!(sq.resident_bytes(0), 2 * 4096);
        sq.pop();
        assert_eq!(sq.resident_bytes(10), 4096);
        sq.pop();
        assert_eq!(sq.resident_bytes(49), 0);
        assert_eq!(sq.resident_bytes(50), 4096);
    }

    #[test]
    fn window_stays_bounded_over_a_streaming_source() {
        // 256 ops behind a depth-4 window: the queue never buffers more
        // than 4, yet drains the whole workload in source order
        let src = SeqFillSource::new("w", 256 * 32 * 1024, 1 << 20);
        let mut sq = SubmissionQueue::from_source(TenantId(1), 4, Box::new(src));
        let mut n = 0u64;
        let mut last = 0;
        while let Some(op) = sq.pop() {
            assert!(op.at >= last);
            last = op.at;
            n += 1;
            assert!(sq.backlog() <= 4);
        }
        assert_eq!(n, 256);
        assert!(sq.is_empty());
        assert_eq!(sq.peak_buffered(), 4);
        assert_eq!(sq.window_cap(), 4);
    }
}
