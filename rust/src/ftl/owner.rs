//! Exact per-tenant page ownership: the side table that tags every
//! valid physical page with the tenant whose data it holds.
//!
//! PR-2's `CachePartitioner` accounted cache occupancy from per-request
//! ledger diffs and *released* capacity proportionally (highest
//! occupancy first) because nothing in the stack knew which physical
//! pages a tenant actually owned. The [`OwnerTable`] closes that gap:
//! the FTL tags pages at program time (host writes inherit the
//! dispatching tenant, relocations inherit the source page's owner) and
//! clears tags on invalidation, so releases, GC-debt scoring, and
//! migration-cost attribution can all be exact.
//!
//! The table mirrors [`super::Mapping`]'s chunked layout: the Table-I
//! SSD has ~100 M physical pages, so a dense `Vec<u16>` would cost
//! 200 MB up front; 64 Ki-entry chunks allocate on first touch instead.
//!
//! **Per-block owner histograms** (§Perf): `dominant_owner` and the
//! eviction hook's `owned_valid_in_block` used to rescan every valid
//! page of a block on every tenant-aware GC tie-break and every
//! eviction-candidate pass. The table now maintains a small
//! `(tenant, count)` histogram per block, updated O(distinct owners)
//! on every tag/transfer/clear, so those queries stop touching pages
//! entirely. Tags are cleared *before* invalidation (see
//! [`super::Ftl`]'s page-exit path), so tagged ⊆ valid and the
//! histogram always equals a fresh valid-page scan — the property
//! suite pins this.
//!
//! Invariants (property-tested in `tests/prop_ownership.rs`):
//! * a page has an owner iff it is valid and was written while owner
//!   tracking was enabled — exactly one owner, never two;
//! * the owner of a valid page equals the tenant owning its LPN (tenant
//!   address regions are disjoint, so this is checkable from the map);
//! * Σ per-tenant *SLC-resident* tagged pages equals the partitioner's
//!   per-tenant occupancy under owner attribution.

use crate::flash::Ppa;

const CHUNK_BITS: usize = 16;
const CHUNK: usize = 1 << CHUNK_BITS;
/// Sentinel for "no owner" inside a chunk.
const NO_OWNER: u16 = u16::MAX;

/// Chunked physical-page → owning-tenant side table, with per-block
/// owner histograms.
#[derive(Debug, Default)]
pub struct OwnerTable {
    chunks: Vec<Option<Box<[u16; CHUNK]>>>,
    tagged: u64,
    /// Per-block `(tenant, tagged pages)` histogram; the outer vec is
    /// allocated on the first tag (single-stream runs never pay it).
    hist: Vec<Vec<(u16, u32)>>,
    n_blocks: usize,
    pages_per_block: u64,
}

impl OwnerTable {
    /// Table covering physical pages `[0, total_pages)` grouped into
    /// blocks of `pages_per_block` (the histogram key).
    pub fn new(total_pages: u64, pages_per_block: u32) -> OwnerTable {
        let n_chunks = (total_pages as usize).div_ceil(CHUNK);
        let ppb = pages_per_block.max(1) as u64;
        OwnerTable {
            chunks: (0..n_chunks).map(|_| None).collect(),
            tagged: 0,
            hist: Vec::new(),
            n_blocks: total_pages.div_ceil(ppb) as usize,
            pages_per_block: ppb,
        }
    }

    /// Number of currently tagged pages.
    pub fn tagged(&self) -> u64 {
        self.tagged
    }

    #[inline]
    fn split(ppa: Ppa) -> (usize, usize) {
        ((ppa.0 >> CHUNK_BITS) as usize, (ppa.0 & (CHUNK as u64 - 1)) as usize)
    }

    /// Owner of `ppa`, if tagged.
    #[inline]
    pub fn get(&self, ppa: Ppa) -> Option<u16> {
        let (c, o) = Self::split(ppa);
        match self.chunks.get(c)? {
            Some(chunk) => {
                let v = chunk[o];
                if v == NO_OWNER {
                    None
                } else {
                    Some(v)
                }
            }
            None => None,
        }
    }

    /// Tag `ppa` with `owner` (replaces any previous tag). `owner` must
    /// not be the sentinel `u16::MAX` — tenant counts are validated to
    /// 65535 in the config layer.
    pub fn set(&mut self, ppa: Ppa, owner: u16) {
        debug_assert!(owner != NO_OWNER, "owner id collides with the sentinel");
        let (c, o) = Self::split(ppa);
        if c >= self.chunks.len() {
            return;
        }
        let chunk = self.chunks[c].get_or_insert_with(|| Box::new([NO_OWNER; CHUNK]));
        let prev = chunk[o];
        if prev == owner {
            return;
        }
        chunk[o] = owner;
        if prev == NO_OWNER {
            self.tagged += 1;
        } else {
            self.hist_sub(ppa, prev);
        }
        self.hist_add(ppa, owner);
    }

    /// Clear `ppa`'s tag and return the previous owner, if any.
    pub fn take(&mut self, ppa: Ppa) -> Option<u16> {
        let (c, o) = Self::split(ppa);
        let v = match self.chunks.get_mut(c)? {
            Some(chunk) => {
                let v = chunk[o];
                if v == NO_OWNER {
                    return None;
                }
                chunk[o] = NO_OWNER;
                v
            }
            None => return None,
        };
        self.tagged -= 1;
        self.hist_sub(ppa, v);
        Some(v)
    }

    // --- per-block owner histograms --------------------------------

    #[inline]
    fn block_of(&self, ppa: Ppa) -> usize {
        (ppa.0 / self.pages_per_block) as usize
    }

    fn hist_add(&mut self, ppa: Ppa, owner: u16) {
        let b = self.block_of(ppa);
        if b >= self.n_blocks {
            return;
        }
        if self.hist.is_empty() {
            self.hist = vec![Vec::new(); self.n_blocks];
        }
        let h = &mut self.hist[b];
        match h.iter_mut().find(|(t, _)| *t == owner) {
            Some((_, c)) => *c += 1,
            None => h.push((owner, 1)),
        }
    }

    fn hist_sub(&mut self, ppa: Ppa, owner: u16) {
        let b = self.block_of(ppa);
        if b >= self.n_blocks || self.hist.is_empty() {
            return;
        }
        let h = &mut self.hist[b];
        if let Some(i) = h.iter().position(|&(t, _)| t == owner) {
            h[i].1 -= 1;
            if h[i].1 == 0 {
                h.swap_remove(i);
            }
        }
    }

    /// Tagged pages of `owner` in flat block `block_index` — what
    /// `owned_valid_in_block` used to count by scanning valid pages.
    pub fn owned_in_block(&self, block_index: usize, owner: u16) -> u32 {
        self.hist
            .get(block_index)
            .and_then(|h| h.iter().find(|&&(t, _)| t == owner))
            .map(|&(_, c)| c)
            .unwrap_or(0)
    }

    /// The tenant with the most tagged pages in flat block
    /// `block_index` (ties to the lowest tenant id), `None` when the
    /// block holds no tags — `dominant_owner`'s histogram backend.
    pub fn dominant_in_block(&self, block_index: usize) -> Option<u16> {
        let h = self.hist.get(block_index)?;
        h.iter().copied().max_by_key(|&(t, c)| (c, std::cmp::Reverse(t))).map(|(t, _)| t)
    }

    /// Resident memory estimate in bytes (for reports).
    pub fn memory_bytes(&self) -> usize {
        self.chunks.iter().filter(|c| c.is_some()).count() * CHUNK * 2
            + self.chunks.len() * std::mem::size_of::<Option<Box<[u16; CHUNK]>>>()
    }
}

/// Per-tenant relocation counters, split by the attribution category of
/// the move. The engine drains these (via [`super::Ftl::take_owner_events`])
/// to charge migration work to the tenants whose *data* moved instead
/// of the tenant whose request happened to trigger the move.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MoveCounters {
    /// Pages relocated by inline/background GC (TLC → TLC).
    pub gc_migrations: u64,
    /// Pages migrated out of the SLC cache (SLC → TLC reclamation).
    pub slc2tlc_migrations: u64,
    /// Pages moved by AGC into used SLC word lines (reprogram).
    pub agc_reprograms: u64,
    /// Traditional-cache pages reprogrammed into the IPS window (coop).
    pub coop_reprograms: u64,
}

impl MoveCounters {
    /// Total pages moved.
    pub fn total(&self) -> u64 {
        self.gc_migrations + self.slc2tlc_migrations + self.agc_reprograms + self.coop_reprograms
    }

    /// Accumulate another batch (the engine drains per page but
    /// adjusts the dispatcher's ledger once per request).
    pub fn add(&mut self, other: &MoveCounters) {
        self.gc_migrations += other.gc_migrations;
        self.slc2tlc_migrations += other.slc2tlc_migrations;
        self.agc_reprograms += other.agc_reprograms;
        self.coop_reprograms += other.coop_reprograms;
    }
}

/// Everything the owner machinery accumulated since the last drain:
/// per-tenant SLC-residency releases and per-tenant relocations, plus
/// the unowned remainder (pages written before tracking was enabled,
/// or whose owner was lost to a same-operation invalidation).
#[derive(Clone, Debug, Default)]
pub struct OwnerEvents {
    /// Pages that left SLC residency, indexed by owning tenant.
    pub released: Vec<u64>,
    /// Pages that left SLC residency with no recorded owner.
    pub released_unowned: u64,
    /// Relocated pages, indexed by owning tenant.
    pub moves: Vec<MoveCounters>,
    /// Relocated pages with no recorded owner.
    pub moves_unowned: MoveCounters,
}

impl OwnerEvents {
    /// Total released pages (owned + unowned).
    pub fn total_released(&self) -> u64 {
        self.released.iter().sum::<u64>() + self.released_unowned
    }
    /// Total moved pages (owned + unowned).
    pub fn total_moved(&self) -> u64 {
        self.moves.iter().map(|m| m.total()).sum::<u64>() + self.moves_unowned.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_take_roundtrip() {
        let mut t = OwnerTable::new(1 << 20, 96);
        assert_eq!(t.get(Ppa(5)), None);
        t.set(Ppa(5), 3);
        assert_eq!(t.get(Ppa(5)), Some(3));
        assert_eq!(t.tagged(), 1);
        t.set(Ppa(5), 4); // retag does not double-count
        assert_eq!(t.tagged(), 1);
        assert_eq!(t.take(Ppa(5)), Some(4));
        assert_eq!(t.get(Ppa(5)), None);
        assert_eq!(t.take(Ppa(5)), None);
        assert_eq!(t.tagged(), 0);
    }

    #[test]
    fn chunks_allocate_lazily() {
        let mut t = OwnerTable::new(1 << 24, 96);
        let empty = t.memory_bytes();
        t.set(Ppa(0), 1);
        t.set(Ppa(1), 2);
        let one = t.memory_bytes();
        assert!(one > empty);
        assert!(one < empty + 2 * CHUNK * 2, "only one chunk allocated");
    }

    #[test]
    fn out_of_range_is_inert() {
        let mut t = OwnerTable::new(100, 96);
        t.set(Ppa(1 << 40), 1);
        assert_eq!(t.get(Ppa(1 << 40)), None);
        assert_eq!(t.take(Ppa(1 << 40)), None);
        assert_eq!(t.tagged(), 0);
        assert_eq!(t.dominant_in_block(0), None);
    }

    #[test]
    fn histograms_track_tag_transfer_and_clear() {
        // 96 pages per block: Ppa 0..96 = block 0, 96..192 = block 1
        let mut t = OwnerTable::new(1 << 20, 96);
        assert_eq!(t.dominant_in_block(0), None, "untouched table has no histogram");
        t.set(Ppa(0), 2);
        t.set(Ppa(1), 2);
        t.set(Ppa(2), 1);
        t.set(Ppa(96), 1); // lands in block 1
        assert_eq!(t.owned_in_block(0, 2), 2);
        assert_eq!(t.owned_in_block(0, 1), 1);
        assert_eq!(t.owned_in_block(1, 1), 1);
        assert_eq!(t.dominant_in_block(0), Some(2));
        assert_eq!(t.dominant_in_block(1), Some(1));
        // retag transfers the count between tenants
        t.set(Ppa(1), 1);
        assert_eq!(t.owned_in_block(0, 2), 1);
        assert_eq!(t.owned_in_block(0, 1), 2);
        assert_eq!(t.dominant_in_block(0), Some(1));
        // a (count) tie breaks to the lowest tenant id
        t.set(Ppa(3), 2);
        assert_eq!(t.owned_in_block(0, 1), t.owned_in_block(0, 2));
        assert_eq!(t.dominant_in_block(0), Some(1));
        // clears drain the histogram back to empty
        for p in [0u64, 1, 2, 3] {
            t.take(Ppa(p));
        }
        assert_eq!(t.dominant_in_block(0), None);
        assert_eq!(t.owned_in_block(0, 1), 0);
        assert_eq!(t.dominant_in_block(1), Some(1), "other blocks unaffected");
    }

    #[test]
    fn move_counters_total() {
        let m = MoveCounters {
            gc_migrations: 1,
            slc2tlc_migrations: 2,
            agc_reprograms: 3,
            coop_reprograms: 4,
        };
        assert_eq!(m.total(), 10);
        let ev = OwnerEvents {
            released: vec![2, 3],
            released_unowned: 1,
            moves: vec![m, MoveCounters::default()],
            moves_unowned: MoveCounters { gc_migrations: 5, ..MoveCounters::default() },
        };
        assert_eq!(ev.total_released(), 6);
        assert_eq!(ev.total_moved(), 15);
    }
}
