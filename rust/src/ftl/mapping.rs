//! Page-level address mapping (L2P), chunked for memory efficiency.
//!
//! The Table-I SSD has ~100 M physical pages; a dense `Vec<u32>` for
//! the whole logical space would cost 400 MB even for workloads that
//! touch a few GB. The table is therefore split into 64 Ki-entry
//! chunks allocated on first touch. Physical page addresses fit `u32`
//! at any supported geometry (checked at construction).

use crate::flash::{Lpn, Ppa};
use crate::{Error, Result};

const CHUNK_BITS: usize = 16;
const CHUNK: usize = 1 << CHUNK_BITS;
const NONE: u32 = u32::MAX;

/// Chunked logical→physical page map.
pub struct Mapping {
    chunks: Vec<Option<Box<[u32; CHUNK]>>>,
    lpn_limit: u64,
    live: u64,
}

impl Mapping {
    /// Build a map covering LPNs `[0, lpn_limit)`; `ppa_limit` is the
    /// number of physical pages (must fit in `u32` minus the sentinel).
    pub fn new(lpn_limit: u64, ppa_limit: u64) -> Result<Mapping> {
        if ppa_limit >= NONE as u64 {
            return Err(Error::config(format!(
                "geometry has {ppa_limit} physical pages; mapping supports < {NONE}"
            )));
        }
        let n_chunks = (lpn_limit as usize).div_ceil(CHUNK);
        Ok(Mapping { chunks: (0..n_chunks).map(|_| None).collect(), lpn_limit, live: 0 })
    }

    /// Highest mappable LPN + 1.
    pub fn lpn_limit(&self) -> u64 {
        self.lpn_limit
    }

    /// Number of currently mapped LPNs.
    pub fn live(&self) -> u64 {
        self.live
    }

    #[inline]
    fn index(&self, lpn: Lpn) -> Result<(usize, usize)> {
        if lpn.0 >= self.lpn_limit {
            return Err(Error::invariant(format!(
                "LPN {} out of range (limit {})",
                lpn.0, self.lpn_limit
            )));
        }
        Ok(((lpn.0 >> CHUNK_BITS) as usize, (lpn.0 & (CHUNK as u64 - 1)) as usize))
    }

    /// Current physical location of `lpn`, if mapped.
    #[inline]
    pub fn get(&self, lpn: Lpn) -> Option<Ppa> {
        let (c, o) = self.index(lpn).ok()?;
        match &self.chunks[c] {
            Some(chunk) => {
                let v = chunk[o];
                if v == NONE {
                    None
                } else {
                    Some(Ppa(v as u64))
                }
            }
            None => None,
        }
    }

    /// Map `lpn` → `ppa`; returns the previous location if any.
    pub fn set(&mut self, lpn: Lpn, ppa: Ppa) -> Result<Option<Ppa>> {
        let (c, o) = self.index(lpn)?;
        let chunk = self.chunks[c].get_or_insert_with(|| Box::new([NONE; CHUNK]));
        let old = chunk[o];
        chunk[o] = ppa.0 as u32;
        if old == NONE {
            self.live += 1;
            Ok(None)
        } else {
            Ok(Some(Ppa(old as u64)))
        }
    }

    /// Unmap `lpn`; returns the previous location if any.
    pub fn clear(&mut self, lpn: Lpn) -> Result<Option<Ppa>> {
        let (c, o) = self.index(lpn)?;
        match &mut self.chunks[c] {
            Some(chunk) => {
                let old = chunk[o];
                chunk[o] = NONE;
                if old == NONE {
                    Ok(None)
                } else {
                    self.live -= 1;
                    Ok(Some(Ppa(old as u64)))
                }
            }
            None => Ok(None),
        }
    }

    /// Resident memory estimate in bytes (for reports).
    pub fn memory_bytes(&self) -> usize {
        self.chunks.iter().filter(|c| c.is_some()).count() * CHUNK * 4
            + self.chunks.len() * std::mem::size_of::<Option<Box<[u32; CHUNK]>>>()
    }

    /// Iterate all mapped (LPN, PPA) pairs — audits only (slow).
    pub fn iter_mapped(&self) -> impl Iterator<Item = (Lpn, Ppa)> + '_ {
        self.chunks.iter().enumerate().flat_map(|(ci, chunk)| {
            chunk
                .iter()
                .flat_map(move |c| {
                    c.iter().enumerate().filter(|(_, &v)| v != NONE).map(move |(o, &v)| {
                        (Lpn(((ci << CHUNK_BITS) + o) as u64), Ppa(v as u64))
                    })
                })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, tuple2, u64_up_to, vec_of};

    #[test]
    fn set_get_clear() {
        let mut m = Mapping::new(1 << 20, 1 << 20).unwrap();
        assert_eq!(m.get(Lpn(5)), None);
        assert_eq!(m.set(Lpn(5), Ppa(77)).unwrap(), None);
        assert_eq!(m.get(Lpn(5)), Some(Ppa(77)));
        assert_eq!(m.live(), 1);
        assert_eq!(m.set(Lpn(5), Ppa(99)).unwrap(), Some(Ppa(77)));
        assert_eq!(m.live(), 1);
        assert_eq!(m.clear(Lpn(5)).unwrap(), Some(Ppa(99)));
        assert_eq!(m.get(Lpn(5)), None);
        assert_eq!(m.live(), 0);
    }

    #[test]
    fn out_of_range_rejected() {
        let mut m = Mapping::new(100, 100).unwrap();
        assert!(m.set(Lpn(100), Ppa(0)).is_err());
        assert_eq!(m.get(Lpn(100)), None);
    }

    #[test]
    fn oversized_ppa_space_rejected() {
        assert!(Mapping::new(10, u32::MAX as u64).is_err());
    }

    #[test]
    fn chunks_lazy() {
        let mut m = Mapping::new(1 << 24, 1 << 24).unwrap();
        let empty = m.memory_bytes();
        m.set(Lpn(0), Ppa(1)).unwrap();
        m.set(Lpn(1), Ppa(2)).unwrap();
        let one_chunk = m.memory_bytes();
        assert!(one_chunk > empty);
        assert!(one_chunk < empty + 2 * CHUNK * 4, "only one chunk allocated");
    }

    #[test]
    fn model_based_property() {
        // Property: Mapping behaves like a HashMap reference model.
        use std::collections::HashMap;
        let gen = vec_of(tuple2(u64_up_to(500), u64_up_to(10_000)), 0, 128);
        prop::check("mapping matches hashmap model", 128, gen, |ops| {
            let mut m = Mapping::new(512, 20_000).unwrap();
            let mut model: HashMap<u64, u64> = HashMap::new();
            for &(lpn, ppa) in ops {
                if ppa % 7 == 0 {
                    let got = m.clear(Lpn(lpn)).map_err(|e| e.to_string())?;
                    let want = model.remove(&lpn);
                    if got.map(|p| p.0) != want {
                        return Err(format!("clear({lpn}): {got:?} != {want:?}"));
                    }
                } else {
                    let got = m.set(Lpn(lpn), Ppa(ppa)).map_err(|e| e.to_string())?;
                    let want = model.insert(lpn, ppa);
                    if got.map(|p| p.0) != want {
                        return Err(format!("set({lpn}): {got:?} != {want:?}"));
                    }
                }
                if m.live() != model.len() as u64 {
                    return Err(format!("live {} != model {}", m.live(), model.len()));
                }
            }
            // final state equality
            for (lpn, ppa) in model.iter() {
                if m.get(Lpn(*lpn)) != Some(Ppa(*ppa)) {
                    return Err(format!("final mismatch at {lpn}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn iter_mapped_complete() {
        let mut m = Mapping::new(1 << 17, 1 << 17).unwrap();
        m.set(Lpn(1), Ppa(10)).unwrap();
        m.set(Lpn(70_000), Ppa(20)).unwrap(); // second chunk
        let pairs: Vec<_> = m.iter_mapped().collect();
        assert_eq!(pairs.len(), 2);
        assert!(pairs.contains(&(Lpn(1), Ppa(10))));
        assert!(pairs.contains(&(Lpn(70_000), Ppa(20))));
    }
}
