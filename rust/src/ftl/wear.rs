//! Wear levelling (paper §IV-D2).
//!
//! IPS's wear story: every cell in an IPS block experiences the same
//! program + 2-reprogram pattern per erase cycle, so **erase count** is
//! the levelling metric. Two mechanisms implement it here:
//!
//! * allocation picks the free block with the lowest erase count
//!   (bounded-window scan, [`pick_free_block`]);
//! * the traditional SLC cache is spread evenly over planes by its
//!   scheme (block-pool construction in [`crate::cache::baseline`]).
//!
//! [`WearReport`] summarises the spread for audits and the ablation
//! bench.

use crate::flash::{BlockAddr, FlashArray, PlaneId};

/// Bounded scan window for the min-erase pick.
const PICK_WINDOW: usize = 8;

/// Allocate the lowest-erase-count free block (within a bounded
/// window) from `plane`.
pub fn pick_free_block(array: &mut FlashArray, plane: PlaneId) -> Option<BlockAddr> {
    array.pop_free_min_erase(plane, PICK_WINDOW)
}

/// Erase-count distribution summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct WearReport {
    /// Lowest per-block erase count.
    pub min: u32,
    /// Highest per-block erase count.
    pub max: u32,
    /// Mean erase count.
    pub mean: f64,
    /// Standard deviation of erase counts.
    pub std: f64,
}

impl WearReport {
    /// Compute over every block in the array.
    pub fn compute(array: &FlashArray) -> WearReport {
        let g = *array.geometry();
        let mut n = 0u64;
        let mut sum = 0u64;
        let mut sum2 = 0u128;
        let mut min = u32::MAX;
        let mut max = 0u32;
        for p in 0..g.planes() {
            for b in 0..g.blocks_per_plane {
                let ec = array.block(BlockAddr { plane: PlaneId(p), block: b }).erase_count();
                n += 1;
                sum += ec as u64;
                sum2 += (ec as u128) * (ec as u128);
                min = min.min(ec);
                max = max.max(ec);
            }
        }
        if n == 0 {
            return WearReport::default();
        }
        let mean = sum as f64 / n as f64;
        let var = (sum2 as f64 / n as f64) - mean * mean;
        WearReport { min: if min == u32::MAX { 0 } else { min }, max, mean, std: var.max(0.0).sqrt() }
    }

    /// Max-to-mean ratio (1.0 = perfectly level). 0 when unused.
    pub fn imbalance(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.max as f64 / self.mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::flash::{BlockMode, Lpn};

    #[test]
    fn min_erase_pick_prefers_cold_blocks() {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut array = FlashArray::new(&cfg);
        // Cycle one block a few times so it is "hot".
        let hot = array.pop_free(PlaneId(0)).unwrap();
        array.block_mut(hot).set_mode(BlockMode::Slc).unwrap();
        for _ in 0..3 {
            array.program_slc(hot, Lpn(0), 0).unwrap();
            let g = *array.geometry();
            array.invalidate(hot.page(&g, 0, 0)).unwrap();
            array.erase(hot, 0).unwrap();
            array.push_free(hot).unwrap(); // back to free list tail
            let again = array.pop_free_min_erase(PlaneId(0), 64).unwrap();
            // min-erase pick should NOT return the hot block
            assert_ne!(again, hot);
            array.push_free(again).unwrap();
            let hot2 = {
                // re-acquire hot for the next cycle: find it in the list
                let mut found = None;
                for _ in 0..cfg.geometry.blocks_per_plane {
                    let c = array.pop_free(PlaneId(0)).unwrap();
                    if c == hot {
                        found = Some(c);
                        break;
                    }
                    array.push_free(c).unwrap();
                }
                found.unwrap()
            };
            assert_eq!(hot2, hot);
        }
    }

    #[test]
    fn wear_report_on_fresh_array_is_zero() {
        let cfg = presets::small();
        let array = FlashArray::new(&cfg);
        let r = WearReport::compute(&array);
        assert_eq!(r.max, 0);
        assert_eq!(r.mean, 0.0);
        assert_eq!(r.imbalance(), 0.0);
    }
}
