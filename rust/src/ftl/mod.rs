//! The flash translation layer: address mapping, write streams,
//! garbage collection (inline + advanced), and wear levelling.
//!
//! [`Ftl`] owns the [`FlashArray`], the [`Mapping`], and the
//! write-amplification [`Ledger`]; cache schemes ([`crate::cache`])
//! drive it through composite operations that keep mapping, validity
//! and attribution consistent by construction:
//!
//! * [`Ftl::host_write_tlc`] — host page straight to TLC space
//!   (page-granular, Table-I 3 ms), striped round-robin over planes;
//! * [`Ftl::program_slc_into`] / [`Ftl::reprogram_into`] — cache
//!   writes into scheme-chosen blocks;
//! * [`Ftl::migrate_page`] + [`Ftl::flush_migration`] — valid-page
//!   migration batched into one-shot TLC word-line programs;
//! * [`Ftl::reclaim_block`] — the baseline's atomic block-reclamation
//!   unit (migrate every valid page, then erase);
//! * [`Ftl::maybe_gc`] / [`gc::gc_once`] — greedy inline GC under
//!   free-block watermarks.

pub mod agc;
pub mod gc;
pub mod mapping;
pub mod wear;

pub use mapping::Mapping;

use crate::config::{Config, Nanos};
use crate::flash::array::Completion;
use crate::flash::{BlockAddr, BlockMode, FlashArray, Lpn, PlaneId, Ppa};
use crate::metrics::{Attribution, Ledger};
use crate::{Error, Result};

/// Per-plane migration stream: destination block + pending one-shot batch.
#[derive(Default)]
struct MigrStream {
    active: Option<BlockAddr>,
    /// (lpn, source ppa) pairs awaiting a one-shot program.
    pending: Vec<(Lpn, Ppa)>,
}

/// The flash translation layer.
pub struct Ftl {
    /// The timed flash back end.
    pub array: FlashArray,
    /// Logical→physical page map.
    pub map: Mapping,
    /// Attributed write counters.
    pub ledger: Ledger,
    /// Per-plane active host-TLC write block.
    host_tlc: Vec<Option<BlockAddr>>,
    /// Per-plane migration stream.
    migr: Vec<MigrStream>,
    /// Per-plane closed (fully written, GC-eligible) blocks.
    closed: Vec<Vec<u32>>,
    /// Round-robin plane pointer for host TLC striping.
    rr: u32,
    n_planes: u32,
    gc_low_blocks: usize,
    gc_high_blocks: usize,
}

impl Ftl {
    /// Build an FTL over a fresh array.
    pub fn new(cfg: &Config) -> Result<Ftl> {
        let array = FlashArray::new(cfg);
        let g = cfg.geometry;
        let total_pages = g.total_pages();
        // Physical pages consumed by a dedicated (traditional) SLC
        // cache: those blocks hold 1 page per word line but block their
        // full TLC capacity.
        let cache_blocks = match cfg.cache.scheme {
            crate::config::Scheme::Baseline | crate::config::Scheme::Coop => {
                let slc_pages = cfg.cache.slc_cache_bytes / g.page_bytes as u64;
                slc_pages.div_ceil(g.wordlines_per_block() as u64)
            }
            _ => 0,
        };
        let reserved = cache_blocks * g.pages_per_block as u64;
        let logical_fraction = 0.80;
        let lpn_limit =
            ((total_pages.saturating_sub(reserved)) as f64 * logical_fraction) as u64;
        if lpn_limit == 0 {
            return Err(Error::config("no logical capacity left after cache reservation"));
        }
        let n_planes = g.planes();
        let low = ((g.blocks_per_plane as f64 * cfg.cache.gc_low_watermark) as usize).max(2);
        let high = ((g.blocks_per_plane as f64 * cfg.cache.gc_high_watermark) as usize)
            .max(low + 1);
        Ok(Ftl {
            array,
            map: Mapping::new(lpn_limit, total_pages)?,
            ledger: Ledger::default(),
            host_tlc: (0..n_planes).map(|_| None).collect(),
            migr: (0..n_planes).map(|_| MigrStream::default()).collect(),
            closed: (0..n_planes).map(|_| Vec::new()).collect(),
            rr: 0,
            n_planes,
            gc_low_blocks: low,
            gc_high_blocks: high,
        })
    }

    /// Number of planes.
    pub fn planes(&self) -> u32 {
        self.n_planes
    }

    /// Next plane in the host round-robin order (advances the pointer).
    pub fn next_plane(&mut self) -> PlaneId {
        let p = PlaneId(self.rr % self.n_planes);
        self.rr = self.rr.wrapping_add(1);
        p
    }

    /// Allocate an erased block in `plane` and set its mode.
    /// Applies the wear-levelling pick policy (§IV-D2).
    pub fn alloc_block(&mut self, plane: PlaneId, mode: BlockMode) -> Result<BlockAddr> {
        let addr = wear::pick_free_block(&mut self.array, plane).ok_or_else(|| {
            Error::Flash(format!(
                "plane {} out of free blocks (closed: {}, mode: {mode:?})",
                plane.0,
                self.closed[plane.0 as usize].len()
            ))
        })?;
        self.array.block_mut(addr).set_mode(mode)?;
        Ok(addr)
    }

    /// Register a fully written block as GC-eligible.
    pub fn register_closed(&mut self, addr: BlockAddr) {
        self.closed[addr.plane.0 as usize].push(addr.block);
    }

    /// Closed-block count in a plane (diagnostics).
    pub fn closed_count(&self, plane: PlaneId) -> usize {
        self.closed[plane.0 as usize].len()
    }

    /// Pop the GC victim with the most invalid pages from a plane's
    /// closed list (greedy policy). Returns `None` when no closed block
    /// has any invalid page.
    pub fn pop_victim(&mut self, plane: PlaneId) -> Option<BlockAddr> {
        let list = &mut self.closed[plane.0 as usize];
        let mut best: Option<(usize, u32)> = None;
        for (i, &b) in list.iter().enumerate() {
            let inv = self.array.block(BlockAddr { plane, block: b }).invalid_count();
            if inv > 0 && best.map(|(_, bi)| inv > bi).unwrap_or(true) {
                best = Some((i, inv));
            }
        }
        let (idx, _) = best?;
        let block = list.swap_remove(idx);
        Some(BlockAddr { plane, block })
    }

    // --- host path ----------------------------------------------------

    /// Write one host page directly to TLC space (page-granular).
    pub fn host_write_tlc(&mut self, lpn: Lpn, now: Nanos) -> Result<Completion> {
        let plane = self.next_plane();
        self.host_write_tlc_on(plane, lpn, now)
    }

    /// Write one host page to TLC space on a specific plane.
    pub fn host_write_tlc_on(
        &mut self,
        plane: PlaneId,
        lpn: Lpn,
        now: Nanos,
    ) -> Result<Completion> {
        self.maybe_gc(plane, now)?;
        let addr = self.ensure_host_block(plane)?;
        let (ppa, done) = self.array.program_tlc_page(addr, lpn, now)?;
        self.remap_host(lpn, ppa)?;
        self.ledger.program(Attribution::TlcDirectWrite);
        Ok(done)
    }

    fn ensure_host_block(&mut self, plane: PlaneId) -> Result<BlockAddr> {
        let slot = plane.0 as usize;
        if let Some(addr) = self.host_tlc[slot] {
            if self.array.block(addr).tlc_free_pages() > 0 {
                return Ok(addr);
            }
            self.register_closed(addr);
        }
        let fresh = self
            .alloc_block(plane, BlockMode::Tlc)
            .map_err(|e| Error::Flash(format!("host stream: {e}")))?;
        self.host_tlc[slot] = Some(fresh);
        Ok(fresh)
    }

    /// Program one host/cache page into a scheme-chosen SLC block or
    /// IPS window block.
    pub fn program_slc_into(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        attr: Attribution,
        now: Nanos,
    ) -> Result<Completion> {
        let (ppa, done) = self.array.program_slc(addr, lpn, now)?;
        self.remap_host(lpn, ppa)?;
        self.ledger.program(attr);
        Ok(done)
    }

    /// One reprogram operation into a scheme-chosen IPS block: reads
    /// the word line's existing content first (required by the
    /// reprogram procedure, §IV-A), then programs the added page.
    /// Returns (new page, word line now full, completion).
    pub fn reprogram_into(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        attr: Attribution,
        now: Nanos,
    ) -> Result<(Ppa, bool, Completion)> {
        // Charge the pre-read of the word line's existing content
        // (the reprogram procedure reads the original data first,
        // §IV-A).
        let g = *self.array.geometry();
        let now = match self.array.block(addr).next_reprogram_wl() {
            Some(w) => {
                let lsb = addr.page(&g, w, 0);
                match self.array.read(lsb, now) {
                    Ok(c) => c.end,
                    Err(_) => now,
                }
            }
            None => now,
        };
        let (ppa, full, done) = self.array.reprogram(addr, lpn, now)?;
        self.remap_host(lpn, ppa)?;
        self.ledger.program(attr);
        Ok((ppa, full, done))
    }

    fn remap_host(&mut self, lpn: Lpn, ppa: Ppa) -> Result<()> {
        if let Some(old) = self.map.set(lpn, ppa)? {
            self.array.invalidate(old)?;
        }
        Ok(())
    }

    /// Serve a host read. Unmapped LPNs are served from the controller
    /// (deterministic zero-fill) with no flash access.
    pub fn host_read(&mut self, lpn: Lpn, now: Nanos) -> Result<Completion> {
        self.ledger.host_reads += 1;
        match self.map.get(lpn) {
            Some(ppa) => self.array.read(ppa, now),
            None => Ok(Completion { start: now, end: now }),
        }
    }

    // --- migration ------------------------------------------------------

    /// Queue one valid page for migration to TLC space in its own
    /// plane (read is charged immediately; the program happens when the
    /// one-shot batch fills or [`Ftl::flush_migration`] runs).
    /// Returns the read completion.
    pub fn migrate_page(
        &mut self,
        src: Ppa,
        attr: Attribution,
        now: Nanos,
    ) -> Result<Completion> {
        let g = *self.array.geometry();
        let pa = src.expand(&g);
        let lpn = self
            .array
            .block(BlockAddr { plane: pa.plane, block: pa.block })
            .lpn_at(pa.page_in_block())
            .ok_or_else(|| Error::invariant("migrate_page of page with no LPN"))?;
        let read_done = self.array.read(src, now)?;
        let stream = &mut self.migr[pa.plane.0 as usize];
        stream.pending.push((lpn, src));
        if stream.pending.len() >= 3 {
            self.flush_migration_plane(pa.plane, read_done.end, attr)?;
        }
        Ok(read_done)
    }

    /// Flush a plane's pending migration batch (partial one-shot if
    /// fewer than 3 pages). Returns the program completion if anything
    /// was written.
    pub fn flush_migration_plane(
        &mut self,
        plane: PlaneId,
        now: Nanos,
        attr: Attribution,
    ) -> Result<Option<Completion>> {
        let pending = std::mem::take(&mut self.migr[plane.0 as usize].pending);
        if pending.is_empty() {
            return Ok(None);
        }
        // Drop entries whose mapping moved on since they were queued.
        let mut lpns: Vec<Lpn> = Vec::with_capacity(pending.len());
        let mut srcs: Vec<Ppa> = Vec::with_capacity(pending.len());
        for (lpn, src) in pending {
            if self.map.get(lpn) == Some(src) {
                lpns.push(lpn);
                srcs.push(src);
            }
        }
        if lpns.is_empty() {
            return Ok(None);
        }
        let addr = self.ensure_migr_block(plane)?;
        let (ppas, done) = self.array.program_tlc(addr, &lpns, now)?;
        for ((lpn, src), new) in lpns.iter().zip(srcs.iter()).zip(ppas.iter()) {
            self.array.invalidate(*src)?;
            self.map.set(*lpn, *new)?;
            self.ledger.program(attr);
        }
        Ok(Some(done))
    }

    /// Flush all planes' migration batches.
    pub fn flush_all_migration(&mut self, now: Nanos, attr: Attribution) -> Result<Nanos> {
        let mut end = now;
        for p in 0..self.n_planes {
            if let Some(c) = self.flush_migration_plane(PlaneId(p), now, attr)? {
                end = end.max(c.end);
            }
        }
        Ok(end)
    }

    fn ensure_migr_block(&mut self, plane: PlaneId) -> Result<BlockAddr> {
        let slot = plane.0 as usize;
        if let Some(addr) = self.migr[slot].active {
            if self.array.block(addr).tlc_free_wls() > 0 {
                return Ok(addr);
            }
            self.register_closed(addr);
        }
        let fresh = self
            .alloc_block(plane, BlockMode::Tlc)
            .map_err(|e| Error::Flash(format!("migration stream: {e}")))?;
        self.migr[slot].active = Some(fresh);
        Ok(fresh)
    }

    /// The baseline's atomic reclamation unit: migrate every valid
    /// page of `addr` to TLC space and erase it. Once started it runs
    /// to completion (paper §IV-B: a host write arriving mid-unit
    /// "has to be delayed until the reclamation process is finished").
    /// Returns the erase completion.
    pub fn reclaim_block(
        &mut self,
        addr: BlockAddr,
        attr: Attribution,
        now: Nanos,
    ) -> Result<Completion> {
        let g = *self.array.geometry();
        let mut t = now;
        loop {
            // take up to one word-line batch of valid pages at a time
            let victims: Vec<Ppa> = {
                let blk = self.array.block(addr);
                blk.valid_pages()
                    .take(3)
                    .map(|pib| addr.page(&g, pib / 3, (pib % 3) as u8))
                    .collect()
            };
            if victims.is_empty() {
                break;
            }
            for src in victims {
                let c = self.migrate_page(src, attr, t)?;
                t = c.end;
            }
            if let Some(c) = self.flush_migration_plane(addr.plane, t, attr)? {
                t = c.end;
            }
        }
        self.array.erase(addr, t)
    }

    // --- garbage collection ---------------------------------------------

    /// Free-block count of a plane.
    pub fn free_blocks(&self, plane: PlaneId) -> usize {
        self.array.free_block_count(plane)
    }

    /// GC low watermark (blocks).
    pub fn gc_low_blocks(&self) -> usize {
        self.gc_low_blocks
    }

    /// Inline GC: if the plane is below the low watermark, run greedy
    /// GC cycles until the high watermark (or no victim). Host writes
    /// behind it queue on the plane — the realistic GC stall.
    pub fn maybe_gc(&mut self, plane: PlaneId, now: Nanos) -> Result<()> {
        if self.array.free_block_count(plane) >= self.gc_low_blocks {
            return Ok(());
        }
        let mut guard = 0;
        while self.array.free_block_count(plane) < self.gc_high_blocks {
            if !gc::gc_once(self, plane, now)? {
                if self.array.free_block_count(plane) == 0 {
                    return Err(Error::Flash(format!(
                        "plane {}: capacity exhausted (no GC victim with invalid pages)",
                        plane.0
                    )));
                }
                break;
            }
            guard += 1;
            if guard > self.array.geometry().blocks_per_plane {
                return Err(Error::invariant("GC loop did not converge"));
            }
        }
        Ok(())
    }

    // --- audits -----------------------------------------------------------

    /// Full-consistency audit: ledger vs raw counters, mapping vs
    /// per-block back-pointers, per-block counters. Slow; tests and
    /// end-of-run verification only.
    pub fn audit(&self) -> Result<()> {
        let raw = self.array.counters().pages_programmed();
        let led = self.ledger.total_programs();
        if raw != led {
            return Err(Error::invariant(format!(
                "ledger total {led} != array pages programmed {raw}"
            )));
        }
        let g = *self.array.geometry();
        for p in 0..self.n_planes {
            self.array.audit_plane(PlaneId(p))?;
        }
        for (lpn, ppa) in self.map.iter_mapped() {
            let pa = ppa.expand(&g);
            let blk = self.array.block(BlockAddr { plane: pa.plane, block: pa.block });
            if !blk.is_valid(pa.page_in_block()) {
                return Err(Error::invariant(format!(
                    "mapped {lpn:?} points at invalid page {ppa:?}"
                )));
            }
            if blk.lpn_at(pa.page_in_block()) != Some(lpn) {
                return Err(Error::invariant(format!(
                    "back-pointer mismatch at {ppa:?}: {:?} != {lpn:?}",
                    blk.lpn_at(pa.page_in_block())
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn ftl() -> Ftl {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        Ftl::new(&cfg).unwrap()
    }

    #[test]
    fn host_tlc_write_maps_and_attributes() {
        let mut f = ftl();
        let c = f.host_write_tlc(Lpn(7), 0).unwrap();
        assert_eq!(c.end - c.start, f.array.timing().tlc_prog);
        assert!(f.map.get(Lpn(7)).is_some());
        assert_eq!(f.ledger.tlc_direct_writes, 1);
        f.audit().unwrap();
    }

    #[test]
    fn overwrite_invalidates_old() {
        let mut f = ftl();
        f.host_write_tlc(Lpn(7), 0).unwrap();
        let old = f.map.get(Lpn(7)).unwrap();
        f.host_write_tlc(Lpn(7), 0).unwrap();
        let new = f.map.get(Lpn(7)).unwrap();
        assert_ne!(old, new);
        let g = *f.array.geometry();
        let pa = old.expand(&g);
        assert!(!f
            .array
            .block(BlockAddr { plane: pa.plane, block: pa.block })
            .is_valid(pa.page_in_block()));
        f.audit().unwrap();
    }

    #[test]
    fn writes_stripe_round_robin() {
        let mut f = ftl();
        let n = f.planes() as u64;
        for i in 0..n {
            f.host_write_tlc(Lpn(i), 0).unwrap();
        }
        // all planes got exactly one page
        let g = *f.array.geometry();
        for p in 0..f.planes() {
            let total: u32 = (0..g.blocks_per_plane)
                .map(|b| f.array.block(BlockAddr { plane: PlaneId(p), block: b }).written_count())
                .sum();
            assert_eq!(total, 1, "plane {p}");
        }
    }

    #[test]
    fn reads_hit_mapped_and_miss_unmapped() {
        let mut f = ftl();
        f.host_write_tlc(Lpn(3), 0).unwrap();
        let hit = f.host_read(Lpn(3), 1_000_000_000).unwrap();
        assert_eq!(hit.end - hit.start, f.array.timing().tlc_read);
        let miss = f.host_read(Lpn(999), 0).unwrap();
        assert_eq!(miss.end, miss.start, "unmapped read served from controller");
        assert_eq!(f.ledger.host_reads, 2);
    }

    #[test]
    fn migration_moves_and_preserves_mapping() {
        let mut f = ftl();
        for i in 0..6u64 {
            f.host_write_tlc(Lpn(i), 0).unwrap();
        }
        let src = f.map.get(Lpn(0)).unwrap();
        f.migrate_page(src, Attribution::GcMigration, 0).unwrap();
        f.flush_all_migration(0, Attribution::GcMigration).unwrap();
        let new = f.map.get(Lpn(0)).unwrap();
        assert_ne!(src, new);
        assert!(f.ledger.gc_migrations >= 1);
        f.audit().unwrap();
    }

    #[test]
    fn gc_reclaims_space_under_pressure() {
        // Small plane, fill logical space then overwrite to force GC.
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut f = Ftl::new(&cfg).unwrap();
        let lpns = 2_000u64;
        let mut t = 0;
        // Write volume exceeds physical capacity per plane so GC must
        // run to keep up (live set stays at `lpns` pages).
        for round in 0..14 {
            for i in 0..lpns {
                let c = f.host_write_tlc(Lpn(i), t).unwrap();
                t = t.max(c.end);
            }
            // array must stay consistent under sustained overwrites
            if round % 2 == 0 {
                f.audit().unwrap();
            }
        }
        assert!(f.array.counters().erases > 0, "GC must have run");
        assert!(f.ledger.gc_migrations > 0 || f.ledger.total_programs() > 0);
        f.audit().unwrap();
    }

    #[test]
    fn reclaim_block_unit_empties_and_erases() {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut f = Ftl::new(&cfg).unwrap();
        // build an SLC block with some valid pages
        let addr = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        for i in 0..8u64 {
            f.program_slc_into(addr, Lpn(1000 + i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        // overwrite a couple so some pages are invalid
        f.host_write_tlc(Lpn(1000), 0).unwrap();
        let c = f.reclaim_block(addr, Attribution::Slc2Tlc, 0).unwrap();
        assert!(c.end > 0);
        assert!(f.array.block(addr).is_erased());
        assert_eq!(f.ledger.slc2tlc_migrations, 7, "7 valid pages migrated");
        // mappings survived the move
        for i in 1..8u64 {
            assert!(f.map.get(Lpn(1000 + i)).is_some());
        }
        f.audit().unwrap();
    }
}
