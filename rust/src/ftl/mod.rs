//! The flash translation layer: address mapping, write streams,
//! garbage collection (inline + advanced), and wear levelling.
//!
//! [`Ftl`] owns the [`FlashArray`], the [`Mapping`], and the
//! write-amplification [`Ledger`]; cache schemes ([`crate::cache`])
//! drive it through composite operations that keep mapping, validity
//! and attribution consistent by construction:
//!
//! * [`Ftl::host_write_tlc`] — host page straight to TLC space
//!   (page-granular, Table-I 3 ms), striped round-robin over planes;
//! * [`Ftl::program_slc_into`] / [`Ftl::reprogram_into`] — cache
//!   writes into scheme-chosen blocks;
//! * [`Ftl::migrate_page`] + [`Ftl::flush_migration_plane`] — valid-page
//!   migration batched into one-shot TLC word-line programs (and
//!   [`Ftl::flush_migration_group`] / [`Ftl::reclaim_blocks_group`] —
//!   multi-plane die-interleaved batching under the interconnect model);
//! * [`Ftl::reclaim_block`] — the baseline's atomic block-reclamation
//!   unit (migrate every valid page, then erase);
//! * [`Ftl::maybe_gc`] / [`gc::gc_once`] — greedy inline GC under
//!   free-block watermarks.

pub mod agc;
pub mod gc;
pub mod mapping;
pub mod owner;
pub mod victim_index;
pub mod wear;

pub use mapping::Mapping;
pub use owner::{MoveCounters, OwnerEvents, OwnerTable};
pub use victim_index::VictimIndex;

use crate::config::{Config, Nanos};
use crate::flash::array::Completion;
use crate::flash::{BlockAddr, BlockMode, FlashArray, Lpn, PageKind, PlaneId, Ppa};
use crate::metrics::{Attribution, Ledger};
use crate::{Error, Result};

/// GC/AGC victim-selection policy.
///
/// `Greedy` is the paper's policy (most invalid pages first) and the
/// single-stream default. `TenantAware` keeps the same primary key —
/// reclamation efficiency is not negotiable — but breaks ties toward
/// the block whose *dominant owner* carries the highest GC debt
/// (pages that tenant has invalidated so far), so the tenant creating
/// the GC work is the first to have its blocks collected. With one
/// tenant (or equal debts) every pick is byte-identical to `Greedy`,
/// which the differential tests pin.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum VictimPolicy {
    /// Most invalid pages first (paper §II-C).
    #[default]
    Greedy,
    /// Greedy, with ties broken by owning-tenant GC debt.
    TenantAware,
}

/// Per-plane migration stream: destination block + pending one-shot batch.
#[derive(Default)]
struct MigrStream {
    active: Option<BlockAddr>,
    /// (lpn, source ppa) pairs awaiting a one-shot program.
    pending: Vec<(Lpn, Ppa)>,
}

/// The flash translation layer.
pub struct Ftl {
    /// The timed flash back end.
    pub array: FlashArray,
    /// Logical→physical page map.
    pub map: Mapping,
    /// Attributed write counters.
    pub ledger: Ledger,
    /// Per-plane active host-TLC write block.
    host_tlc: Vec<Option<BlockAddr>>,
    /// Per-plane migration stream.
    migr: Vec<MigrStream>,
    /// Per-plane closed (fully written, GC-eligible) blocks.
    closed: Vec<Vec<u32>>,
    /// Round-robin plane pointer for host TLC striping.
    rr: u32,
    n_planes: u32,
    gc_low_blocks: usize,
    gc_high_blocks: usize,
    /// Per-page owner tags (valid pages only; see [`owner`]).
    owners: OwnerTable,
    /// Owner bookkeeping enabled (set by the multi-tenant engine via
    /// [`Ftl::set_tenant_count`]; single-stream runs keep it off so the
    /// hot path is untouched).
    track_owners: bool,
    /// Tenant whose request is currently being served (host writes are
    /// tagged with it; `None` during background work).
    tenant_ctx: Option<u16>,
    /// Victim-selection policy for [`Ftl::pop_victim`].
    victim_policy: VictimPolicy,
    /// Per-block last-write timestamp (flat block index → the end time
    /// of the newest program that landed in the block). Makes "coldest
    /// block" an explicit signal for eviction instead of a queue-order
    /// proxy; stale after an erase until the block's first reuse write,
    /// which only eviction paths over *written* blocks ever consult.
    block_write_ns: Vec<Nanos>,
    /// Incremental invalid-count bucket index over the closed lists
    /// (`sim.victim_index`, the default). `None` = the historical
    /// linear-scan backend, kept as the differential oracle and the
    /// perf harness's baseline.
    vindex: Option<VictimIndex>,
    /// Per-tenant SLC-residency releases since the last drain.
    owner_releases: Vec<u64>,
    /// Residency releases of pages with no recorded owner.
    owner_releases_unowned: u64,
    /// Per-tenant relocations since the last drain.
    owner_moves: Vec<MoveCounters>,
    /// Relocations of pages with no recorded owner.
    owner_moves_unowned: MoveCounters,
    /// GC debt per tenant: pages the tenant has invalidated by
    /// overwriting (the work GC will eventually have to absorb).
    invalidation_debt: Vec<u64>,
    /// Any release/move recorded since the last drain? Lets the
    /// engine's per-page drain skip allocation in the common
    /// nothing-happened case.
    owner_events_dirty: bool,
}

impl Ftl {
    /// Build an FTL over a fresh array.
    pub fn new(cfg: &Config) -> Result<Ftl> {
        let array = FlashArray::new(cfg);
        let g = cfg.geometry;
        let total_pages = g.total_pages();
        // Physical pages consumed by a dedicated (traditional) SLC
        // cache: those blocks hold 1 page per word line but block their
        // full TLC capacity.
        let cache_blocks = match cfg.cache.scheme {
            crate::config::Scheme::Baseline | crate::config::Scheme::Coop => {
                let slc_pages = cfg.cache.slc_cache_bytes / g.page_bytes as u64;
                slc_pages.div_ceil(g.wordlines_per_block() as u64)
            }
            _ => 0,
        };
        let reserved = cache_blocks * g.pages_per_block as u64;
        // exported logical capacity; 1 - logical_frac stays back as
        // over-provisioning (per-device knob on the fleet's OP axis)
        let lpn_limit = ((total_pages.saturating_sub(reserved)) as f64
            * cfg.sim.logical_frac) as u64;
        if lpn_limit == 0 {
            return Err(Error::config("no logical capacity left after cache reservation"));
        }
        let n_planes = g.planes();
        let low = ((g.blocks_per_plane as f64 * cfg.cache.gc_low_watermark) as usize).max(2);
        let high = ((g.blocks_per_plane as f64 * cfg.cache.gc_high_watermark) as usize)
            .max(low + 1);
        let vindex = if cfg.sim.victim_index {
            Some(VictimIndex::new(
                n_planes,
                g.blocks_per_plane,
                g.pages_per_block,
                cfg.sim.flat_index,
            ))
        } else {
            None
        };
        Ok(Ftl {
            array,
            map: Mapping::new(lpn_limit, total_pages)?,
            ledger: Ledger::default(),
            host_tlc: (0..n_planes).map(|_| None).collect(),
            migr: (0..n_planes).map(|_| MigrStream::default()).collect(),
            closed: (0..n_planes).map(|_| Vec::new()).collect(),
            rr: 0,
            n_planes,
            gc_low_blocks: low,
            gc_high_blocks: high,
            owners: OwnerTable::new(total_pages, g.pages_per_block),
            track_owners: false,
            tenant_ctx: None,
            victim_policy: VictimPolicy::Greedy,
            block_write_ns: vec![0; g.blocks() as usize],
            vindex,
            owner_releases: Vec::new(),
            owner_releases_unowned: 0,
            owner_moves: Vec::new(),
            owner_moves_unowned: MoveCounters::default(),
            invalidation_debt: Vec::new(),
            owner_events_dirty: false,
        })
    }

    // --- per-tenant ownership ------------------------------------------

    /// Enable owner bookkeeping for `n` tenants. Called once by the
    /// multi-tenant engine before any host write; single-stream runs
    /// never call it, so their write path carries zero overhead.
    pub fn set_tenant_count(&mut self, n: usize) {
        self.track_owners = n > 0;
        self.owner_releases = vec![0; n];
        self.owner_moves = vec![MoveCounters::default(); n];
        self.invalidation_debt = vec![0; n];
    }

    /// Set (or clear) the tenant whose request is being served.
    pub fn set_tenant(&mut self, t: Option<u16>) {
        self.tenant_ctx = t;
    }

    /// Select the GC/AGC victim policy.
    pub fn set_victim_policy(&mut self, p: VictimPolicy) {
        self.victim_policy = p;
    }

    /// Owner of a physical page, if tagged.
    pub fn owner_of(&self, ppa: Ppa) -> Option<u16> {
        self.owners.get(ppa)
    }

    /// Number of currently tagged pages (diagnostics / audits).
    pub fn tagged_pages(&self) -> u64 {
        self.owners.tagged()
    }

    /// GC debt of tenant `t`: pages it invalidated by overwriting.
    pub fn gc_debt(&self, t: usize) -> u64 {
        self.invalidation_debt.get(t).copied().unwrap_or(0)
    }

    /// Anything accumulated since the last drain? (Allocation-free
    /// fast path for the engine's per-page drains.)
    pub fn has_owner_events(&self) -> bool {
        self.owner_events_dirty
    }

    /// Drain the accumulated release/move events (per-tenant vectors
    /// are reset to zero; the engine applies them to the partitioner
    /// and the per-tenant ledgers).
    pub fn take_owner_events(&mut self) -> OwnerEvents {
        self.owner_events_dirty = false;
        let n = self.owner_releases.len();
        OwnerEvents {
            released: std::mem::replace(&mut self.owner_releases, vec![0; n]),
            released_unowned: std::mem::take(&mut self.owner_releases_unowned),
            moves: std::mem::replace(&mut self.owner_moves, vec![MoveCounters::default(); n]),
            moves_unowned: std::mem::take(&mut self.owner_moves_unowned),
        }
    }

    /// Flat block index of `addr` (the owner table's histogram key).
    fn block_index(&self, addr: BlockAddr) -> usize {
        let g = self.array.geometry();
        (addr.plane.0 as u64 * g.blocks_per_plane as u64 + addr.block as u64) as usize
    }

    /// Record that a program landed in `addr`, completing at `at`.
    fn note_block_write(&mut self, addr: BlockAddr, at: Nanos) {
        let i = self.block_index(addr);
        self.block_write_ns[i] = at;
    }

    /// End time of the newest program that landed in `addr` (0 if the
    /// block was never written). The explicit "coldness" signal the
    /// baseline/partitioner eviction sorts by — for FIFO-filled blocks
    /// it is monotone in queue order, so FIFO-equivalent workloads see
    /// the historical eviction order unchanged (unit-tested).
    pub fn last_block_write(&self, addr: BlockAddr) -> Nanos {
        self.block_write_ns[self.block_index(addr)]
    }

    /// Valid pages of `addr` owned by tenant `t` (eviction scoring).
    /// Answered from the owner table's per-block histogram — O(distinct
    /// owners in the block), not O(valid pages). Tags are cleared
    /// before invalidation, so tagged ⊆ valid and the histogram equals
    /// a fresh scan (pinned by `tests/prop_victim_index.rs`).
    pub fn owned_valid_in_block(&self, addr: BlockAddr, t: u16) -> u32 {
        self.owners.owned_in_block(self.block_index(addr), t)
    }

    /// The tenant owning the plurality of `addr`'s valid pages (ties
    /// break to the lowest tenant id; `None` if nothing is tagged).
    /// Histogram-backed; see [`Ftl::owned_valid_in_block`].
    pub fn dominant_owner(&self, addr: BlockAddr) -> Option<u16> {
        self.owners.dominant_in_block(self.block_index(addr))
    }

    /// Record a residency release for `owner` (or the unowned pool).
    fn release_event(&mut self, owner: Option<u16>) {
        self.owner_events_dirty = true;
        match owner {
            Some(o) if (o as usize) < self.owner_releases.len() => {
                self.owner_releases[o as usize] += 1;
            }
            _ => self.owner_releases_unowned += 1,
        }
    }

    /// Record a relocation of a page owned by `owner` under `attr`.
    fn note_move(&mut self, owner: Option<u16>, attr: Attribution) {
        self.owner_events_dirty = true;
        let slot = match owner {
            Some(o) if (o as usize) < self.owner_moves.len() => {
                &mut self.owner_moves[o as usize]
            }
            _ => &mut self.owner_moves_unowned,
        };
        match attr {
            Attribution::GcMigration => slot.gc_migrations += 1,
            Attribution::Slc2Tlc => slot.slc2tlc_migrations += 1,
            Attribution::AgcReprogram => slot.agc_reprograms += 1,
            Attribution::CoopReprogram => slot.coop_reprograms += 1,
            _ => {}
        }
    }

    /// A valid page is about to be invalidated: clear its tag and, if
    /// it was SLC-resident (stored one bit per cell — the fast tier),
    /// emit a residency release for its owner. Returns the owner so
    /// relocations can transfer it to the destination page.
    fn note_page_exit(&mut self, ppa: Ppa) -> Option<u16> {
        let g = *self.array.geometry();
        let pa = ppa.expand(&g);
        let kind = self
            .array
            .block(BlockAddr { plane: pa.plane, block: pa.block })
            .page_kind(pa.page_in_block());
        let owner = self.owners.take(ppa);
        if kind == PageKind::Slc {
            self.release_event(owner);
        }
        owner
    }

    /// Number of planes.
    pub fn planes(&self) -> u32 {
        self.n_planes
    }

    /// Next plane in the host round-robin order (advances the pointer).
    /// Planes retired by fault injection are skipped; at least one live
    /// plane always exists ([`Ftl::retire_plane`] refuses the last one).
    pub fn next_plane(&mut self) -> PlaneId {
        for _ in 0..self.n_planes {
            let p = PlaneId(self.rr % self.n_planes);
            self.rr = self.rr.wrapping_add(1);
            if !self.array.plane_lost(p) {
                return p;
            }
        }
        PlaneId(self.rr % self.n_planes)
    }

    /// Allocate an erased block in `plane` and set its mode.
    /// Applies the wear-levelling pick policy (§IV-D2).
    pub fn alloc_block(&mut self, plane: PlaneId, mode: BlockMode) -> Result<BlockAddr> {
        let addr = wear::pick_free_block(&mut self.array, plane).ok_or_else(|| {
            Error::Flash(format!(
                "plane {} out of free blocks (closed: {}, mode: {mode:?})",
                plane.0,
                self.closed[plane.0 as usize].len()
            ))
        })?;
        self.array.block_mut(addr).set_mode(mode)?;
        Ok(addr)
    }

    /// Register a fully written block as GC-eligible.
    pub fn register_closed(&mut self, addr: BlockAddr) {
        let slot = addr.plane.0 as usize;
        self.closed[slot].push(addr.block);
        if self.vindex.is_some() {
            let pos = self.closed[slot].len() - 1;
            let inv = self.array.block(addr).invalid_count();
            self.vindex.as_mut().expect("checked").insert(addr, pos, inv);
        }
    }

    /// Closed-block count in a plane (diagnostics).
    pub fn closed_count(&self, plane: PlaneId) -> usize {
        self.closed[plane.0 as usize].len()
    }

    /// The plane's closed list in its current (swap_remove-permuted)
    /// order — tie order for victim selection. Exposed for the
    /// differential oracle in `tests/prop_victim_index.rs`.
    pub fn closed_blocks(&self, plane: PlaneId) -> &[u32] {
        &self.closed[plane.0 as usize]
    }

    /// Is the incremental victim index active (vs the scan oracle)?
    pub fn victim_index_enabled(&self) -> bool {
        self.vindex.is_some()
    }

    /// Invalidate one physical page, keeping the victim index's bucket
    /// for the owning block current. Every FTL-internal invalidation
    /// MUST go through here — a direct `array.invalidate` on a closed
    /// block would silently stale the index (the audit catches it).
    fn invalidate_page(&mut self, ppa: Ppa) -> Result<()> {
        self.array.invalidate(ppa)?;
        if let Some(ix) = &mut self.vindex {
            let pa = ppa.expand(self.array.geometry());
            ix.note_invalidate(pa.plane, pa.block);
        }
        Ok(())
    }

    /// Pop the next GC victim from a plane's closed list. The primary
    /// key is always the invalid-page count (greedy, paper §II-C);
    /// under [`VictimPolicy::TenantAware`] ties between equally good
    /// victims break toward the block whose dominant owner carries the
    /// most GC debt. Returns `None` when no closed block has any
    /// invalid page.
    ///
    /// With the victim index (the default) the pick is O(1) amortized;
    /// the linear-scan backend is kept as the byte-identical oracle
    /// (`sim.victim_index = false`, differential-tested).
    pub fn pop_victim(&mut self, plane: PlaneId) -> Option<BlockAddr> {
        let idx = if self.vindex.is_some() {
            self.pick_victim_indexed(plane)?
        } else {
            self.pick_victim_scan(plane)?
        };
        let slot = plane.0 as usize;
        let block = self.closed[slot].swap_remove(idx);
        if let Some(ix) = &mut self.vindex {
            ix.remove(BlockAddr { plane, block });
            // swap_remove moved the list's last block into the hole:
            // re-key it so tie order keeps tracking the list
            if idx < self.closed[slot].len() {
                ix.reposition(BlockAddr { plane, block: self.closed[slot][idx] }, idx);
            }
        }
        Some(BlockAddr { plane, block })
    }

    /// Index-backed pick: the max bucket's first-in-list block; the
    /// tenant-aware tie-break walks only that bucket. The walk replaces
    /// its pick on `(debt, position)` — strictly greater debt, or equal
    /// debt at a smaller list position — which resolves to "maximal
    /// debt, ties toward minimal position" regardless of bucket
    /// iteration order. For the in-order tree oracle that is exactly
    /// the historical strictly-greater walk; the unordered flat backend
    /// needs the explicit position key to stay byte-identical.
    fn pick_victim_indexed(&mut self, plane: PlaneId) -> Option<usize> {
        let (pos, block, max_inv) = self.vindex.as_mut().expect("indexed mode").peek_max(plane)?;
        if self.victim_policy == VictimPolicy::Greedy || !self.track_owners {
            return Some(pos as usize);
        }
        let mut pick = pos;
        let mut pick_debt = self.victim_debt(BlockAddr { plane, block });
        let ix = self.vindex.as_ref().expect("indexed mode");
        for (p2, b2) in ix.ties(plane, max_inv) {
            if p2 == pos {
                continue; // the greedy pick itself
            }
            let debt = self.victim_debt(BlockAddr { plane, block: b2 });
            if debt > pick_debt || (debt == pick_debt && p2 < pick) {
                pick = p2;
                pick_debt = debt;
            }
        }
        Some(pick as usize)
    }

    /// Linear-scan pick (the historical hot path; now the oracle).
    fn pick_victim_scan(&self, plane: PlaneId) -> Option<usize> {
        let list = &self.closed[plane.0 as usize];
        let mut best: Option<(usize, u32)> = None;
        for (i, &b) in list.iter().enumerate() {
            let inv = self.array.block(BlockAddr { plane, block: b }).invalid_count();
            if inv > 0 && best.map(|(_, bi)| inv > bi).unwrap_or(true) {
                best = Some((i, inv));
            }
        }
        let (first, max_inv) = best?;
        if self.victim_policy == VictimPolicy::Greedy || !self.track_owners {
            return Some(first);
        }
        // Tenant-aware tie-break: the greedy scan above picks the
        // *first* block at the max; re-scan only the ties (rare) and
        // prefer the one whose dominant owner has the highest debt.
        // Equal debts keep the first pick, so a single-tenant run is
        // byte-identical to the greedy policy.
        let mut pick = first;
        let mut pick_debt = self.victim_debt(BlockAddr { plane, block: list[first] });
        for (i, &b) in list.iter().enumerate().skip(first + 1) {
            let addr = BlockAddr { plane, block: b };
            if self.array.block(addr).invalid_count() != max_inv {
                continue;
            }
            let debt = self.victim_debt(addr);
            if debt > pick_debt {
                pick = i;
                pick_debt = debt;
            }
        }
        Some(pick)
    }

    fn victim_debt(&self, addr: BlockAddr) -> u64 {
        match self.dominant_owner(addr) {
            Some(t) => self.gc_debt(t as usize),
            None => 0,
        }
    }

    // --- host path ----------------------------------------------------

    /// Write one host page directly to TLC space (page-granular).
    pub fn host_write_tlc(&mut self, lpn: Lpn, now: Nanos) -> Result<Completion> {
        let plane = self.next_plane();
        self.host_write_tlc_on(plane, lpn, now)
    }

    /// Write one host page to TLC space on a specific plane. A plane
    /// retired by fault injection redirects to the next live plane, so
    /// scheme fallback paths never have to know about faults.
    pub fn host_write_tlc_on(
        &mut self,
        plane: PlaneId,
        lpn: Lpn,
        now: Nanos,
    ) -> Result<Completion> {
        let plane = if self.array.plane_lost(plane) { self.next_plane() } else { plane };
        self.maybe_gc(plane, now)?;
        let addr = self.ensure_host_block(plane)?;
        let (ppa, done) = self.array.program_tlc_page(addr, lpn, now)?;
        self.note_block_write(addr, done.end);
        self.remap_host(lpn, ppa)?;
        self.ledger.program(Attribution::TlcDirectWrite);
        Ok(done)
    }

    fn ensure_host_block(&mut self, plane: PlaneId) -> Result<BlockAddr> {
        let slot = plane.0 as usize;
        if let Some(addr) = self.host_tlc[slot] {
            if self.array.block(addr).tlc_free_pages() > 0 {
                return Ok(addr);
            }
            self.register_closed(addr);
        }
        let fresh = self
            .alloc_block(plane, BlockMode::Tlc)
            .map_err(|e| Error::Flash(format!("host stream: {e}")))?;
        self.host_tlc[slot] = Some(fresh);
        Ok(fresh)
    }

    /// Program one host/cache page into a scheme-chosen SLC block or
    /// IPS window block.
    pub fn program_slc_into(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        attr: Attribution,
        now: Nanos,
    ) -> Result<Completion> {
        let (ppa, done) = self.array.program_slc(addr, lpn, now)?;
        self.note_block_write(addr, done.end);
        self.remap_host(lpn, ppa)?;
        self.ledger.program(attr);
        Ok(done)
    }

    /// One reprogram operation into a scheme-chosen IPS block: reads
    /// the word line's existing content first (required by the
    /// reprogram procedure, §IV-A), then programs the added page.
    /// Returns (new page, word line now full, completion).
    pub fn reprogram_into(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        attr: Attribution,
        now: Nanos,
    ) -> Result<(Ppa, bool, Completion)> {
        // Charge the pre-read of the word line's existing content
        // (the reprogram procedure reads the original data first,
        // §IV-A). Its phase split is folded into the returned
        // completion so the engines attribute the whole composite.
        let g = *self.array.geometry();
        let target_wl = self.array.block(addr).next_reprogram_wl();
        let mut pre_read: Option<Completion> = None;
        let now = match target_wl {
            Some(w) => {
                let lsb = addr.page(&g, w, 0);
                match self.array.read(lsb, now) {
                    Ok(c) => {
                        pre_read = Some(c);
                        c.end
                    }
                    Err(_) => now,
                }
            }
            None => now,
        };
        // The first reprogram of a word line takes its resident SLC
        // page to 2 bits/cell: that page leaves the fast tier in place.
        // Capture its owner *before* the op — the op itself may be the
        // overwrite that invalidates it.
        let lsb_exit = if self.track_owners {
            match target_wl {
                Some(w)
                    if self.array.block(addr).wl(w).pages() == 1
                        && self.array.block(addr).is_valid(w * 3) =>
                {
                    let lsb = addr.page(&g, w, 0);
                    Some(self.owners.get(lsb))
                }
                _ => None,
            }
        } else {
            None
        };
        let (ppa, full, mut done) = self.array.reprogram(addr, lpn, now)?;
        if let Some(r) = pre_read {
            done.fold_phases(&r);
        }
        self.note_block_write(addr, done.end);
        let prev_owner = self.remap_host(lpn, ppa)?;
        if let Some(owner) = lsb_exit {
            self.release_event(owner);
        }
        if self.track_owners
            && matches!(attr, Attribution::AgcReprogram | Attribution::CoopReprogram)
        {
            self.note_move(prev_owner, attr);
        }
        self.ledger.program(attr);
        Ok((ppa, full, done))
    }

    /// Point `lpn` at `ppa`, invalidating any previous location. With
    /// owner tracking on, the new page inherits the old page's owner
    /// (relocations move data, not ownership) or, for first writes, the
    /// current tenant context; an invalidated SLC-resident page emits a
    /// residency release, and an overwrite books GC debt against the
    /// writing tenant. Returns the previous owner (relocation callers
    /// use it for move attribution).
    fn remap_host(&mut self, lpn: Lpn, ppa: Ppa) -> Result<Option<u16>> {
        let old = self.map.set(lpn, ppa)?;
        if !self.track_owners {
            if let Some(old) = old {
                self.invalidate_page(old)?;
            }
            return Ok(None);
        }
        let mut prev_owner = None;
        if let Some(old) = old {
            prev_owner = self.note_page_exit(old);
            self.invalidate_page(old)?;
            if let Some(t) = self.tenant_ctx {
                if let Some(d) = self.invalidation_debt.get_mut(t as usize) {
                    *d += 1;
                }
            }
        }
        if let Some(o) = prev_owner.or(self.tenant_ctx) {
            self.owners.set(ppa, o);
        }
        Ok(prev_owner)
    }

    /// Serve a host read. Unmapped LPNs are served from the controller
    /// (deterministic zero-fill) with no flash access.
    pub fn host_read(&mut self, lpn: Lpn, now: Nanos) -> Result<Completion> {
        self.ledger.host_read_event();
        match self.map.get(lpn) {
            Some(ppa) => self.array.read(ppa, now),
            None => Ok(Completion::instant(now)),
        }
    }

    // --- migration ------------------------------------------------------

    /// Queue one valid page for migration to TLC space in its own
    /// plane (read is charged immediately; the program happens when the
    /// one-shot batch fills or [`Ftl::flush_migration_plane`] runs).
    /// Returns the read completion.
    pub fn migrate_page(
        &mut self,
        src: Ppa,
        attr: Attribution,
        now: Nanos,
    ) -> Result<Completion> {
        let (plane, read_done) = self.queue_migration_read(src, now)?;
        if self.migr[plane.0 as usize].pending.len() >= 3 {
            self.flush_migration_plane(plane, read_done.end, attr)?;
        }
        Ok(read_done)
    }

    /// Read `src` and queue it on its plane's migration stream WITHOUT
    /// the automatic batch flush (the grouped reclamation path flushes
    /// whole plane sets as multi-plane one-shots instead).
    fn queue_migration_read(&mut self, src: Ppa, now: Nanos) -> Result<(PlaneId, Completion)> {
        let g = *self.array.geometry();
        let pa = src.expand(&g);
        let lpn = self
            .array
            .block(BlockAddr { plane: pa.plane, block: pa.block })
            .lpn_at(pa.page_in_block())
            .ok_or_else(|| Error::invariant("migrate_page of page with no LPN"))?;
        let read_done = self.array.read(src, now)?;
        self.migr[pa.plane.0 as usize].pending.push((lpn, src));
        Ok((pa.plane, read_done))
    }

    /// Take a plane's pending batch, drop stale entries, and claim the
    /// destination block. `None` when nothing live is pending.
    fn prepare_migration_flush(
        &mut self,
        plane: PlaneId,
    ) -> Result<Option<(BlockAddr, Vec<Lpn>, Vec<Ppa>)>> {
        let pending = std::mem::take(&mut self.migr[plane.0 as usize].pending);
        if pending.is_empty() {
            return Ok(None);
        }
        // Drop entries whose mapping moved on since they were queued.
        let mut lpns: Vec<Lpn> = Vec::with_capacity(pending.len());
        let mut srcs: Vec<Ppa> = Vec::with_capacity(pending.len());
        for (lpn, src) in pending {
            if self.map.get(lpn) == Some(src) {
                lpns.push(lpn);
                srcs.push(src);
            }
        }
        if lpns.is_empty() {
            return Ok(None);
        }
        let addr = self.ensure_migr_block(plane)?;
        Ok(Some((addr, lpns, srcs)))
    }

    /// Post-program bookkeeping of one flushed batch: owner transfer,
    /// source invalidation, remap, attribution.
    fn commit_migration_flush(
        &mut self,
        lpns: &[Lpn],
        srcs: &[Ppa],
        ppas: &[Ppa],
        attr: Attribution,
    ) -> Result<()> {
        for ((lpn, src), new) in lpns.iter().zip(srcs.iter()).zip(ppas.iter()) {
            if self.track_owners {
                // the destination inherits the source page's owner; an
                // SLC-resident source releases its owner's residency,
                // and the move is booked against that owner
                let owner = self.note_page_exit(*src);
                if let Some(o) = owner {
                    self.owners.set(*new, o);
                }
                self.note_move(owner, attr);
            }
            self.invalidate_page(*src)?;
            self.map.set(*lpn, *new)?;
            self.ledger.program(attr);
        }
        Ok(())
    }

    /// Flush a plane's pending migration batch (partial one-shot if
    /// fewer than 3 pages). Returns the program completion if anything
    /// was written.
    pub fn flush_migration_plane(
        &mut self,
        plane: PlaneId,
        now: Nanos,
        attr: Attribution,
    ) -> Result<Option<Completion>> {
        let Some((addr, lpns, srcs)) = self.prepare_migration_flush(plane)? else {
            return Ok(None);
        };
        let (ppas, done) = self.array.program_tlc(addr, &lpns, now)?;
        self.note_block_write(addr, done.end);
        self.commit_migration_flush(&lpns, &srcs, &ppas, attr)?;
        Ok(Some(done))
    }

    /// Flush the pending migration batches of a set of planes, all
    /// issued at `now`. With multi-plane batching available
    /// ([`FlashArray::multiplane_enabled`]) the one-shot programs of
    /// sibling planes issue as die-interleaved groups; otherwise each
    /// plane flushes independently at `now` (byte-identical to the
    /// historical per-plane loop — distinct planes never queued on each
    /// other under the lump). Returns the latest program end.
    pub fn flush_migration_group(
        &mut self,
        planes: &[PlaneId],
        now: Nanos,
        attr: Attribution,
    ) -> Result<Nanos> {
        let mut end = now;
        if !self.array.multiplane_enabled() {
            for &p in planes {
                if let Some(c) = self.flush_migration_plane(p, now, attr)? {
                    end = end.max(c.end);
                }
            }
            return Ok(end);
        }
        let mut preps: Vec<(BlockAddr, Vec<Lpn>, Vec<Ppa>)> = Vec::new();
        for &p in planes {
            if let Some(prep) = self.prepare_migration_flush(p)? {
                preps.push(prep);
            }
        }
        if preps.is_empty() {
            return Ok(end);
        }
        let ops: Vec<(BlockAddr, &[Lpn])> =
            preps.iter().map(|(addr, lpns, _)| (*addr, lpns.as_slice())).collect();
        let results = self.array.program_tlc_group(&ops, now)?;
        drop(ops);
        for ((addr, lpns, srcs), (ppas, done)) in preps.into_iter().zip(results) {
            self.note_block_write(addr, done.end);
            self.commit_migration_flush(&lpns, &srcs, &ppas, attr)?;
            end = end.max(done.end);
        }
        Ok(end)
    }

    /// Flush all planes' migration batches (multi-plane batched when
    /// the interconnect model allows it).
    pub fn flush_all_migration(&mut self, now: Nanos, attr: Attribution) -> Result<Nanos> {
        let planes: Vec<PlaneId> = (0..self.n_planes).map(PlaneId).collect();
        self.flush_migration_group(&planes, now, attr)
    }

    fn ensure_migr_block(&mut self, plane: PlaneId) -> Result<BlockAddr> {
        let slot = plane.0 as usize;
        if let Some(addr) = self.migr[slot].active {
            if self.array.block(addr).tlc_free_wls() > 0 {
                return Ok(addr);
            }
            self.register_closed(addr);
        }
        let fresh = self
            .alloc_block(plane, BlockMode::Tlc)
            .map_err(|e| Error::Flash(format!("migration stream: {e}")))?;
        self.migr[slot].active = Some(fresh);
        Ok(fresh)
    }

    /// Up to one word-line batch (3 pages) of `addr`'s valid pages —
    /// the per-round migration unit shared by the sequential and the
    /// grouped reclamation paths (one-shot programs take ≤ 3 pages).
    fn next_wl_victims(&self, addr: BlockAddr) -> Vec<Ppa> {
        let g = self.array.geometry();
        self.array
            .block(addr)
            .valid_pages()
            .take(3)
            .map(|pib| addr.page(g, pib / 3, (pib % 3) as u8))
            .collect()
    }

    /// The baseline's atomic reclamation unit: migrate every valid
    /// page of `addr` to TLC space and erase it. Once started it runs
    /// to completion (paper §IV-B: a host write arriving mid-unit
    /// "has to be delayed until the reclamation process is finished").
    /// Returns the erase completion.
    pub fn reclaim_block(
        &mut self,
        addr: BlockAddr,
        attr: Attribution,
        now: Nanos,
    ) -> Result<Completion> {
        let mut t = now;
        loop {
            let victims = self.next_wl_victims(addr);
            if victims.is_empty() {
                break;
            }
            for src in victims {
                let c = self.migrate_page(src, attr, t)?;
                t = c.end;
            }
            if let Some(c) = self.flush_migration_plane(addr.plane, t, attr)? {
                t = c.end;
            }
        }
        self.array.erase(addr, t)
    }

    /// Multi-plane batched reclamation: drain a set of blocks on
    /// **distinct planes** in lockstep word-line rounds — each round
    /// reads up to one word line's worth of valid pages per block (the
    /// reads proceed in parallel on their planes), then flushes every
    /// participating plane's batch as one multi-plane interleaved
    /// one-shot group; emptied blocks are erased together at the end.
    /// Distinct dies/channels proceed in parallel throughout.
    ///
    /// Requires the interconnect's multi-plane capability; without it
    /// the blocks are reclaimed as the historical sequential atomic
    /// units (byte-identical to calling [`Ftl::reclaim_block`] in
    /// order), so the degenerate-geometry differential holds. Returns
    /// the last erase end.
    pub fn reclaim_blocks_group(
        &mut self,
        addrs: &[BlockAddr],
        attr: Attribution,
        now: Nanos,
    ) -> Result<Nanos> {
        if !self.array.multiplane_enabled() || addrs.len() <= 1 {
            let mut t = now;
            for &addr in addrs {
                t = t.max(self.reclaim_block(addr, attr, t)?.end);
            }
            return Ok(t);
        }
        debug_assert!(
            {
                let mut planes: Vec<u32> = addrs.iter().map(|a| a.plane.0).collect();
                planes.sort_unstable();
                planes.windows(2).all(|w| w[0] != w[1])
            },
            "grouped reclamation takes at most one block per plane"
        );
        let g = *self.array.geometry();
        // settle any pre-existing pending entries on the involved
        // planes first, so each round's batch stays one-shot-sized
        let planes: Vec<PlaneId> = addrs.iter().map(|a| a.plane).collect();
        let mut t = self.flush_migration_group(&planes, now, attr)?;
        let mut guard = 0u32;
        loop {
            let mut round_planes: Vec<PlaneId> = Vec::new();
            let mut reads_end = t;
            for &addr in addrs {
                let victims = self.next_wl_victims(addr);
                if victims.is_empty() {
                    continue;
                }
                let mut tb = t;
                for src in victims {
                    let (_plane, c) = self.queue_migration_read(src, tb)?;
                    tb = c.end;
                }
                reads_end = reads_end.max(tb);
                round_planes.push(addr.plane);
            }
            if round_planes.is_empty() {
                break;
            }
            t = self.flush_migration_group(&round_planes, reads_end, attr)?;
            guard += 1;
            if guard > g.pages_per_block {
                return Err(Error::invariant("grouped reclamation did not converge"));
            }
        }
        let mut end = t;
        for &addr in addrs {
            end = end.max(self.array.erase(addr, t)?.end);
        }
        Ok(end)
    }

    // --- fault injection --------------------------------------------------

    /// Retire `plane` mid-run (fault injection): stop allocating from
    /// it, salvage every resident valid page to a live plane, and purge
    /// its closed blocks from victim selection. Salvaged programs are
    /// page-granular TLC writes billed as [`Attribution::GcMigration`]
    /// — the device is relocating data it already owns. Returns the end
    /// time of the salvage, or an error when `plane` is the last live
    /// one (a device cannot lose its only plane and keep serving).
    ///
    /// The plane's pending migration batch is dropped, not flushed: its
    /// entries are still valid mapped pages, so the salvage sweep below
    /// relocates them anyway — flushing would need a destination block
    /// in the dying plane.
    pub fn retire_plane(&mut self, plane: PlaneId, now: Nanos) -> Result<Nanos> {
        if self.array.plane_lost(plane) {
            return Ok(now); // idempotent: already retired
        }
        if self.array.live_planes() <= 1 {
            return Err(Error::Flash(format!(
                "plane {}: cannot retire the last live plane",
                plane.0
            )));
        }
        let slot = plane.0 as usize;
        self.migr[slot].pending.clear();
        self.migr[slot].active = None;
        self.host_tlc[slot] = None;
        self.array.mark_plane_lost(plane);

        // Salvage sweep: walk every block of the plane and relocate its
        // valid pages to live planes via the round-robin pointer (which
        // now skips lost planes). Reads on the lost plane still work —
        // only allocation died.
        let g = *self.array.geometry();
        let mut t = now;
        for b in 0..g.blocks_per_plane {
            let addr = BlockAddr { plane, block: b };
            let pibs: Vec<u32> = self.array.block(addr).valid_pages().collect();
            for pib in pibs {
                let src = addr.page(&g, pib / 3, (pib % 3) as u8);
                let Some(lpn) = self.array.block(addr).lpn_at(pib) else {
                    return Err(Error::invariant("valid page with no LPN during salvage"));
                };
                if self.map.get(lpn) != Some(src) {
                    continue; // stale since the sweep snapshot
                }
                let read = self.array.read(src, t)?;
                t = read.end;
                let dest = self.next_plane();
                self.maybe_gc(dest, t)?;
                let dst_block = self.ensure_host_block(dest)?;
                let (ppa, done) = self.array.program_tlc_page(dst_block, lpn, t)?;
                t = done.end;
                self.note_block_write(dst_block, done.end);
                if self.track_owners {
                    let owner = self.note_page_exit(src);
                    if let Some(o) = owner {
                        self.owners.set(ppa, o);
                    }
                    self.note_move(owner, Attribution::GcMigration);
                }
                self.invalidate_page(src)?;
                self.map.set(lpn, ppa)?;
                self.ledger.program(Attribution::GcMigration);
            }
        }

        // Nothing valid remains: drop the plane's closed blocks from
        // victim selection so GC never picks an unreclaimable victim.
        if let Some(ix) = &mut self.vindex {
            for &b in &self.closed[slot] {
                ix.remove(BlockAddr { plane, block: b });
            }
        }
        self.closed[slot].clear();
        Ok(t)
    }

    // --- garbage collection ---------------------------------------------

    /// Free-block count of a plane.
    pub fn free_blocks(&self, plane: PlaneId) -> usize {
        self.array.free_block_count(plane)
    }

    /// GC low watermark (blocks).
    pub fn gc_low_blocks(&self) -> usize {
        self.gc_low_blocks
    }

    /// Invalid-page count of the block [`Ftl::pop_victim`] would pick
    /// (0 when no closed block is GC-eligible) — the greedy GC gain,
    /// without popping. O(1) amortized from the index; the scan
    /// backend rescans the closed list.
    pub fn peek_victim_gain(&mut self, plane: PlaneId) -> u32 {
        match &mut self.vindex {
            Some(ix) => ix.peek_max(plane).map(|(_, _, inv)| inv).unwrap_or(0),
            None => self.closed[plane.0 as usize]
                .iter()
                .map(|&b| self.array.block(BlockAddr { plane, block: b }).invalid_count())
                .max()
                .unwrap_or(0),
        }
    }

    /// Inline GC: if the plane is below the low watermark, run greedy
    /// GC cycles until the high watermark (or no victim). Host writes
    /// behind it queue on the plane — the realistic GC stall.
    pub fn maybe_gc(&mut self, plane: PlaneId, now: Nanos) -> Result<()> {
        if self.array.free_block_count(plane) >= self.gc_low_blocks {
            return Ok(());
        }
        let mut guard = 0;
        while self.array.free_block_count(plane) < self.gc_high_blocks {
            if !gc::gc_once(self, plane, now)? {
                if self.array.free_block_count(plane) == 0 {
                    return Err(Error::Flash(format!(
                        "plane {}: capacity exhausted (no GC victim with invalid pages)",
                        plane.0
                    )));
                }
                break;
            }
            guard += 1;
            if guard > self.array.geometry().blocks_per_plane {
                return Err(Error::invariant("GC loop did not converge"));
            }
        }
        Ok(())
    }

    // --- audits -----------------------------------------------------------

    /// Full-consistency audit: ledger vs raw counters, mapping vs
    /// per-block back-pointers, per-block counters. Slow; tests and
    /// end-of-run verification only.
    pub fn audit(&self) -> Result<()> {
        let raw = self.array.counters().pages_programmed();
        let led = self.ledger.total_programs();
        if raw != led {
            return Err(Error::invariant(format!(
                "ledger total {led} != array pages programmed {raw}"
            )));
        }
        let g = *self.array.geometry();
        for p in 0..self.n_planes {
            self.array.audit_plane(PlaneId(p))?;
        }
        if let Some(ix) = &self.vindex {
            // the incremental index must equal a fresh rescan of every
            // closed list (positions, buckets, membership)
            for p in 0..self.n_planes {
                let plane = PlaneId(p);
                ix.audit(plane, &self.closed[p as usize], |b| {
                    self.array.block(BlockAddr { plane, block: b }).invalid_count()
                })?;
            }
        }
        if self.track_owners && self.owners.tagged() > self.map.live() {
            return Err(Error::invariant(format!(
                "{} owner tags exceed {} mapped pages (stale tag leak)",
                self.owners.tagged(),
                self.map.live()
            )));
        }
        for (lpn, ppa) in self.map.iter_mapped() {
            let pa = ppa.expand(&g);
            let blk = self.array.block(BlockAddr { plane: pa.plane, block: pa.block });
            if !blk.is_valid(pa.page_in_block()) {
                return Err(Error::invariant(format!(
                    "mapped {lpn:?} points at invalid page {ppa:?}"
                )));
            }
            if blk.lpn_at(pa.page_in_block()) != Some(lpn) {
                return Err(Error::invariant(format!(
                    "back-pointer mismatch at {ppa:?}: {:?} != {lpn:?}",
                    blk.lpn_at(pa.page_in_block())
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn ftl() -> Ftl {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        Ftl::new(&cfg).unwrap()
    }

    #[test]
    fn host_tlc_write_maps_and_attributes() {
        let mut f = ftl();
        let c = f.host_write_tlc(Lpn(7), 0).unwrap();
        assert_eq!(c.end - c.start, f.array.timing().tlc_prog);
        assert!(f.map.get(Lpn(7)).is_some());
        assert_eq!(f.ledger.tlc_direct_writes, 1);
        f.audit().unwrap();
    }

    #[test]
    fn overwrite_invalidates_old() {
        let mut f = ftl();
        f.host_write_tlc(Lpn(7), 0).unwrap();
        let old = f.map.get(Lpn(7)).unwrap();
        f.host_write_tlc(Lpn(7), 0).unwrap();
        let new = f.map.get(Lpn(7)).unwrap();
        assert_ne!(old, new);
        let g = *f.array.geometry();
        let pa = old.expand(&g);
        assert!(!f
            .array
            .block(BlockAddr { plane: pa.plane, block: pa.block })
            .is_valid(pa.page_in_block()));
        f.audit().unwrap();
    }

    #[test]
    fn writes_stripe_round_robin() {
        let mut f = ftl();
        let n = f.planes() as u64;
        for i in 0..n {
            f.host_write_tlc(Lpn(i), 0).unwrap();
        }
        // all planes got exactly one page
        let g = *f.array.geometry();
        for p in 0..f.planes() {
            let total: u32 = (0..g.blocks_per_plane)
                .map(|b| f.array.block(BlockAddr { plane: PlaneId(p), block: b }).written_count())
                .sum();
            assert_eq!(total, 1, "plane {p}");
        }
    }

    #[test]
    fn reads_hit_mapped_and_miss_unmapped() {
        let mut f = ftl();
        f.host_write_tlc(Lpn(3), 0).unwrap();
        let hit = f.host_read(Lpn(3), 1_000_000_000).unwrap();
        assert_eq!(hit.end - hit.start, f.array.timing().tlc_read);
        let miss = f.host_read(Lpn(999), 0).unwrap();
        assert_eq!(miss.end, miss.start, "unmapped read served from controller");
        assert_eq!(f.ledger.host_reads, 2);
    }

    #[test]
    fn migration_moves_and_preserves_mapping() {
        let mut f = ftl();
        for i in 0..6u64 {
            f.host_write_tlc(Lpn(i), 0).unwrap();
        }
        let src = f.map.get(Lpn(0)).unwrap();
        f.migrate_page(src, Attribution::GcMigration, 0).unwrap();
        f.flush_all_migration(0, Attribution::GcMigration).unwrap();
        let new = f.map.get(Lpn(0)).unwrap();
        assert_ne!(src, new);
        assert!(f.ledger.gc_migrations >= 1);
        f.audit().unwrap();
    }

    #[test]
    fn gc_reclaims_space_under_pressure() {
        // Small plane, fill logical space then overwrite to force GC.
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut f = Ftl::new(&cfg).unwrap();
        let lpns = 2_000u64;
        let mut t = 0;
        // Write volume exceeds physical capacity per plane so GC must
        // run to keep up (live set stays at `lpns` pages).
        for round in 0..14 {
            for i in 0..lpns {
                let c = f.host_write_tlc(Lpn(i), t).unwrap();
                t = t.max(c.end);
            }
            // array must stay consistent under sustained overwrites
            if round % 2 == 0 {
                f.audit().unwrap();
            }
        }
        assert!(f.array.counters().erases > 0, "GC must have run");
        assert!(f.ledger.gc_migrations > 0 || f.ledger.total_programs() > 0);
        f.audit().unwrap();
    }

    #[test]
    fn owner_tags_follow_writes_and_moves() {
        let mut f = ftl();
        f.set_tenant_count(2);
        f.set_tenant(Some(0));
        f.host_write_tlc(Lpn(1), 0).unwrap();
        f.set_tenant(Some(1));
        f.host_write_tlc(Lpn(2), 0).unwrap();
        f.set_tenant(None);
        let p1 = f.map.get(Lpn(1)).unwrap();
        let p2 = f.map.get(Lpn(2)).unwrap();
        assert_eq!(f.owner_of(p1), Some(0));
        assert_eq!(f.owner_of(p2), Some(1));
        assert_eq!(f.tagged_pages(), 2);
        // a relocation transfers the tag to the destination page
        f.migrate_page(p1, Attribution::GcMigration, 0).unwrap();
        f.flush_all_migration(0, Attribution::GcMigration).unwrap();
        let p1b = f.map.get(Lpn(1)).unwrap();
        assert_ne!(p1, p1b);
        assert_eq!(f.owner_of(p1b), Some(0));
        assert_eq!(f.owner_of(p1), None, "source tag cleared");
        let ev = f.take_owner_events();
        assert_eq!(ev.moves[0].gc_migrations, 1);
        assert_eq!(ev.moves[1].total(), 0);
        assert_eq!(ev.total_released(), 0, "TLC → TLC move leaves no fast tier");
        f.audit().unwrap();
    }

    #[test]
    fn slc_exit_releases_residency_and_overwrites_book_debt() {
        let mut f = ftl();
        f.set_tenant_count(2);
        let addr = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        f.set_tenant(Some(1));
        for i in 0..4u64 {
            f.program_slc_into(addr, Lpn(100 + i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        // overwriting a cached page releases its residency + books debt
        f.host_write_tlc(Lpn(100), 0).unwrap();
        f.set_tenant(None);
        assert_eq!(f.gc_debt(1), 1);
        assert_eq!(f.gc_debt(0), 0);
        let ev = f.take_owner_events();
        assert_eq!(ev.released[1], 1);
        // reclamation releases the remaining valid pages on migration
        f.reclaim_block(addr, Attribution::Slc2Tlc, 0).unwrap();
        let ev = f.take_owner_events();
        assert_eq!(ev.released[1], 3);
        assert_eq!(ev.moves[1].slc2tlc_migrations, 3);
        f.audit().unwrap();
    }

    #[test]
    fn reprogram_conversion_releases_the_resident_lsb_once() {
        let mut f = ftl();
        f.set_tenant_count(1);
        let addr = f.alloc_block(PlaneId(0), BlockMode::Ips).unwrap();
        f.set_tenant(Some(0));
        f.program_slc_into(addr, Lpn(5), Attribution::SlcCacheWrite, 0).unwrap();
        let _ = f.take_owner_events();
        // first reprogram: the word line reaches 2 bits/cell and the
        // resident SLC page leaves the fast tier (in place)
        f.reprogram_into(addr, Lpn(6), Attribution::ReprogramHost, 0).unwrap();
        let ev = f.take_owner_events();
        assert_eq!(ev.released[0], 1);
        // second reprogram: no further residency to release
        f.reprogram_into(addr, Lpn(7), Attribution::ReprogramHost, 0).unwrap();
        let ev = f.take_owner_events();
        assert_eq!(ev.total_released(), 0);
        // the data itself never moved and stays owned
        assert_eq!(f.owner_of(f.map.get(Lpn(5)).unwrap()), Some(0));
        f.set_tenant(None);
        f.audit().unwrap();
    }

    #[test]
    fn owner_tracking_off_is_inert() {
        let mut f = ftl();
        f.host_write_tlc(Lpn(1), 0).unwrap();
        let p = f.map.get(Lpn(1)).unwrap();
        assert_eq!(f.owner_of(p), None);
        assert_eq!(f.tagged_pages(), 0);
        let ev = f.take_owner_events();
        assert!(ev.released.is_empty() && ev.moves.is_empty());
        assert_eq!(ev.total_released() + ev.total_moved(), 0);
    }

    #[test]
    fn tenant_aware_tie_break_prefers_the_indebted_tenant() {
        let mut f = ftl();
        f.set_tenant_count(2);
        f.set_victim_policy(VictimPolicy::TenantAware);
        let a = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        let b = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        f.set_tenant(Some(0));
        for i in 0..4u64 {
            f.program_slc_into(a, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        f.set_tenant(Some(1));
        for i in 10..14u64 {
            f.program_slc_into(b, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        // one invalidation in each block — a perfect greedy tie
        f.set_tenant(Some(0));
        f.host_write_tlc(Lpn(0), 0).unwrap();
        f.set_tenant(Some(1));
        f.host_write_tlc(Lpn(10), 0).unwrap();
        // tenant 1 books extra debt elsewhere (TLC overwrite)
        f.host_write_tlc(Lpn(500), 0).unwrap();
        f.host_write_tlc(Lpn(500), 0).unwrap();
        f.set_tenant(None);
        assert!(f.gc_debt(1) > f.gc_debt(0));
        assert_eq!(f.dominant_owner(a), Some(0));
        assert_eq!(f.dominant_owner(b), Some(1));
        assert_eq!(f.owned_valid_in_block(b, 1), 3);
        f.register_closed(a);
        f.register_closed(b);
        // greedy alone would take `a` (first at the max); the debt
        // tie-break steers the collection to the indebted tenant's block
        assert_eq!(f.pop_victim(PlaneId(0)), Some(b));
        // with equal remaining candidates the next pick is `a`
        assert_eq!(f.pop_victim(PlaneId(0)), Some(a));
    }

    #[test]
    fn last_block_write_tracks_program_completions() {
        let mut f = ftl();
        let addr = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        assert_eq!(f.last_block_write(addr), 0, "never written");
        let c1 = f.program_slc_into(addr, Lpn(1), Attribution::SlcCacheWrite, 0).unwrap();
        assert_eq!(f.last_block_write(addr), c1.end);
        let c2 = f.program_slc_into(addr, Lpn(2), Attribution::SlcCacheWrite, c1.end).unwrap();
        assert_eq!(f.last_block_write(addr), c2.end, "newest write wins");
        // TLC host writes stamp their block too
        let c3 = f.host_write_tlc_on(PlaneId(1), Lpn(50), 0).unwrap();
        let ppa = f.map.get(Lpn(50)).unwrap();
        let blk = ppa.block(f.array.geometry());
        assert_eq!(f.last_block_write(blk), c3.end);
    }

    #[test]
    fn grouped_reclamation_without_multiplane_equals_serial_units() {
        // lump model (and degenerate dies): the group API must be the
        // exact sequential atomic units
        let build = || {
            let mut cfg = presets::small();
            cfg.cache.scheme = crate::config::Scheme::TlcOnly;
            let mut f = Ftl::new(&cfg).unwrap();
            let a = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
            let b = f.alloc_block(PlaneId(1), BlockMode::Slc).unwrap();
            for i in 0..6u64 {
                f.program_slc_into(a, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
                f.program_slc_into(b, Lpn(100 + i), Attribution::SlcCacheWrite, 0).unwrap();
            }
            (f, a, b)
        };
        let (mut grouped, ga, gb) = build();
        assert!(!grouped.array.multiplane_enabled());
        let g_end =
            grouped.reclaim_blocks_group(&[ga, gb], Attribution::Slc2Tlc, 0).unwrap();
        let (mut serial, sa, sb) = build();
        let mut s_end = serial.reclaim_block(sa, Attribution::Slc2Tlc, 0).unwrap().end;
        s_end = s_end.max(serial.reclaim_block(sb, Attribution::Slc2Tlc, s_end).unwrap().end);
        assert_eq!(g_end, s_end, "fallback is the sequential unit chain");
        assert_eq!(grouped.ledger, serial.ledger);
        grouped.audit().unwrap();
    }

    #[test]
    fn grouped_reclamation_interleaves_sibling_planes() {
        // interconnect + 2 planes/die: the group drains two sibling
        // blocks faster than sequential units would, and leaves the
        // same logical state
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        cfg.sim.interconnect = true;
        let mut f = Ftl::new(&cfg).unwrap();
        assert!(f.array.multiplane_enabled());
        // planes 0 and 1 share die 0 on the small geometry
        let a = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        let b = f.alloc_block(PlaneId(1), BlockMode::Slc).unwrap();
        for i in 0..6u64 {
            f.program_slc_into(a, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
            f.program_slc_into(b, Lpn(100 + i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        let t0 = f.array.all_idle_at();
        let end = f.reclaim_blocks_group(&[a, b], Attribution::Slc2Tlc, t0).unwrap();
        assert!(end > t0);
        assert!(f.array.block(a).is_erased() && f.array.block(b).is_erased());
        assert_eq!(f.ledger.slc2tlc_migrations, 12, "every valid page moved");
        for i in 0..6u64 {
            assert!(f.map.get(Lpn(i)).is_some());
            assert!(f.map.get(Lpn(100 + i)).is_some());
        }
        // the die-interleaved one-shots beat two sequential block units:
        // sequential would pay at least 2 blocks x 2 rounds x tlc_prog
        // of array time on one die; the group shares each round's window
        let serial_floor = 2 * 2 * cfg.timing.tlc_prog;
        assert!(
            end - t0 < serial_floor + 2 * cfg.timing.erase,
            "grouped drain must undercut the sequential floor: {} vs {}",
            end - t0,
            serial_floor + 2 * cfg.timing.erase,
        );
        f.audit().unwrap();
    }

    #[test]
    fn reclaim_block_unit_empties_and_erases() {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut f = Ftl::new(&cfg).unwrap();
        // build an SLC block with some valid pages
        let addr = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        for i in 0..8u64 {
            f.program_slc_into(addr, Lpn(1000 + i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        // overwrite a couple so some pages are invalid
        f.host_write_tlc(Lpn(1000), 0).unwrap();
        let c = f.reclaim_block(addr, Attribution::Slc2Tlc, 0).unwrap();
        assert!(c.end > 0);
        assert!(f.array.block(addr).is_erased());
        assert_eq!(f.ledger.slc2tlc_migrations, 7, "7 valid pages migrated");
        // mappings survived the move
        for i in 1..8u64 {
            assert!(f.map.get(Lpn(1000 + i)).is_some());
        }
        f.audit().unwrap();
    }

    #[test]
    fn retire_plane_salvages_valid_pages_and_redirects_writes() {
        let mut f = ftl();
        let n = f.planes() as u64;
        // stripe writes so plane 0 holds some valid pages, then
        // overwrite one so salvage has a stale entry to skip
        for i in 0..4 * n {
            f.host_write_tlc(Lpn(i), 0).unwrap();
        }
        f.host_write_tlc(Lpn(0), 0).unwrap(); // Lpn(0) leaves plane 0
        let before_migr = f.ledger.gc_migrations;
        let end = f.retire_plane(PlaneId(0), 1_000).unwrap();
        assert!(end >= 1_000);
        assert!(f.array.plane_lost(PlaneId(0)));
        assert!(f.ledger.gc_migrations > before_migr, "salvage relocated pages");
        // every LPN still maps, and none maps into the lost plane
        let g = *f.array.geometry();
        for i in 0..4 * n {
            let ppa = f.map.get(Lpn(i)).expect("mapping survived retirement");
            assert_ne!(ppa.expand(&g).plane, PlaneId(0), "Lpn({i}) salvaged off plane 0");
        }
        // retirement is idempotent and new writes avoid the lost plane
        assert_eq!(f.retire_plane(PlaneId(0), 2_000).unwrap(), 2_000);
        for i in 0..2 * n {
            f.host_write_tlc(Lpn(500 + i), 2_000).unwrap();
            let ppa = f.map.get(Lpn(500 + i)).unwrap();
            assert_ne!(ppa.expand(&g).plane, PlaneId(0));
        }
        f.audit().unwrap();
    }

    #[test]
    fn retire_last_live_plane_is_refused() {
        let mut f = ftl();
        let n = f.planes();
        for p in 0..n - 1 {
            f.retire_plane(PlaneId(p), 0).unwrap();
        }
        assert!(f.retire_plane(PlaneId(n - 1), 0).is_err());
        assert_eq!(f.array.live_planes(), 1);
    }
}
