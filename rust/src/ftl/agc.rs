//! Advanced garbage collection (paper §IV-B, after Jung et al. [15]).
//!
//! AGC decomposes a GC cycle into *atomic steps* — one valid-page
//! migration, or one erase — that can be scheduled inside idle windows
//! and **interrupted between steps** when a host write arrives. IPS/agc
//! uses the migration step's payload differently from normal GC:
//! instead of copying the valid page to fresh TLC space, the page is
//! *reprogrammed into a used SLC word line* of the IPS cache, emptying
//! GC victims and re-arming the SLC window at the same time.
//!
//! [`AgcEngine`] owns victim selection and step sequencing; the cache
//! scheme decides what each yielded page's destination is. Victims come
//! from [`super::Ftl::pop_victim`], so AGC inherits the FTL's victim
//! policy: greedy by default, or tenant-aware (ties broken by the
//! dominant owner's GC debt) when the multi-tenant engine runs under
//! owner attribution.

use super::Ftl;
use crate::config::Nanos;
use crate::flash::array::Completion;
use crate::flash::{BlockAddr, PlaneId, Ppa};
use crate::Result;

/// Idle-time advanced-GC engine.
#[derive(Debug, Default)]
pub struct AgcEngine {
    victim: Option<BlockAddr>,
    /// Victims fully migrated but not yet erased.
    pending_erase: Vec<BlockAddr>,
    /// Steps performed (diagnostics).
    pub steps: u64,
    /// Erases performed by AGC.
    pub erases: u64,
}

impl AgcEngine {
    /// New engine.
    pub fn new() -> AgcEngine {
        AgcEngine::default()
    }

    /// Ensure a victim block is selected; picks from the plane with the
    /// fewest free blocks that has an eligible closed block. Victims
    /// are removed from the FTL's closed list so inline GC cannot race
    /// on them.
    ///
    /// Runs every idle step. The pressure-first probe and the
    /// all-planes fallback each ask [`Ftl::pop_victim`], which answers
    /// from the incremental victim index in O(1) amortized — so a full
    /// no-victim sweep costs O(planes), where the pre-index scan paid
    /// O(planes × closed blocks) per step (the §Perf wall-clock sink
    /// `fig_perf` measures).
    pub fn ensure_victim(&mut self, ftl: &mut Ftl) -> Option<BlockAddr> {
        if let Some(v) = self.victim {
            if ftl.array.block(v).valid_count() > 0 {
                return Some(v);
            }
            // fully migrated: queue for erase
            self.pending_erase.push(v);
            self.victim = None;
        }
        // pressure-first: try the plane with the least free space,
        // then the rest
        let tightest = (0..ftl.planes())
            .map(PlaneId)
            .min_by_key(|p| ftl.free_blocks(*p));
        if let Some(p) = tightest {
            if let Some(v) = ftl.pop_victim(p) {
                self.victim = Some(v);
                return self.victim;
            }
        }
        for p in (0..ftl.planes()).map(PlaneId) {
            if let Some(v) = ftl.pop_victim(p) {
                self.victim = Some(v);
                return self.victim;
            }
        }
        None
    }

    /// Install an externally selected victim (e.g. an IPS cache block
    /// stolen by the scheme). The caller must have removed it from any
    /// other bookkeeping.
    pub fn set_victim(&mut self, addr: BlockAddr) {
        debug_assert!(self.victim.is_none());
        self.victim = Some(addr);
    }

    /// Next valid page of the current victim, if any.
    pub fn next_page(&self, ftl: &Ftl) -> Option<Ppa> {
        let v = self.victim?;
        let g = ftl.array.geometry();
        let blk = ftl.array.block(v);
        let pib = blk.valid_pages().next()?;
        Some(v.page(g, pib / 3, (pib % 3) as u8))
    }

    /// Record that one migration step was performed (bookkeeping).
    pub fn note_step(&mut self) {
        self.steps += 1;
    }

    /// Erase one fully migrated victim if any is pending; returns the
    /// erase completion. This is AGC's "erase" atomic step.
    pub fn erase_step(&mut self, ftl: &mut Ftl, now: Nanos) -> Result<Option<Completion>> {
        // re-check the current victim too
        if let Some(v) = self.victim {
            if ftl.array.block(v).valid_count() == 0 {
                self.pending_erase.push(v);
                self.victim = None;
            }
        }
        match self.pending_erase.pop() {
            Some(addr) => {
                let c = ftl.array.erase(addr, now)?;
                ftl.array.push_free(addr)?;
                self.erases += 1;
                Ok(Some(c))
            }
            None => Ok(None),
        }
    }

    /// Drop any selected victim or pending erase on `plane` (plane
    /// retirement): the FTL has already salvaged its valid pages and
    /// marked the plane lost, so migrating or erasing there is wasted
    /// work.
    pub fn forget_plane(&mut self, plane: PlaneId) {
        if self.victim.map(|v| v.plane == plane).unwrap_or(false) {
            self.victim = None;
        }
        self.pending_erase.retain(|a| a.plane != plane);
    }

    /// Any work available (victim with valid pages, or pending erase)?
    pub fn has_work(&self, ftl: &Ftl) -> bool {
        !self.pending_erase.is_empty()
            || self
                .victim
                .map(|v| ftl.array.block(v).valid_count() > 0)
                .unwrap_or(false)
    }

    /// The current victim (diagnostics).
    pub fn victim(&self) -> Option<BlockAddr> {
        self.victim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::flash::{BlockMode, Lpn};
    use crate::metrics::Attribution;

    fn ftl_with_closed_victim() -> (Ftl, BlockAddr) {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut f = Ftl::new(&cfg).unwrap();
        let v = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        for i in 0..4u64 {
            f.program_slc_into(v, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        // make one page invalid so the victim is GC-eligible
        f.host_write_tlc(Lpn(0), 0).unwrap();
        f.register_closed(v);
        (f, v)
    }

    #[test]
    fn victim_selection_and_page_stream() {
        let (mut f, v) = ftl_with_closed_victim();
        let mut agc = AgcEngine::new();
        assert_eq!(agc.ensure_victim(&mut f), Some(v));
        // inline GC can no longer see it
        assert!(f.pop_victim(PlaneId(0)).is_none());
        let mut moved = 0;
        while let Some(src) = agc.next_page(&f) {
            // emulate the scheme: migrate to TLC (destination detail is
            // the scheme's business; here plain migration suffices)
            f.migrate_page(src, Attribution::AgcReprogram, 0).unwrap();
            f.flush_all_migration(0, Attribution::AgcReprogram).unwrap();
            agc.note_step();
            moved += 1;
            assert!(moved <= 4, "terminates");
        }
        assert_eq!(moved, 3, "three valid pages");
        // erase step finishes the victim
        let c = agc.erase_step(&mut f, 0).unwrap();
        assert!(c.is_some());
        assert!(f.array.block(v).is_erased());
        assert_eq!(agc.erases, 1);
        f.audit().unwrap();
    }

    #[test]
    fn no_work_without_victims() {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut f = Ftl::new(&cfg).unwrap();
        let mut agc = AgcEngine::new();
        assert_eq!(agc.ensure_victim(&mut f), None);
        assert!(!agc.has_work(&f));
        assert!(agc.erase_step(&mut f, 0).unwrap().is_none());
    }
}
