//! Greedy garbage collection.
//!
//! GC reclaims closed blocks whose pages have been invalidated by
//! overwrites or migrations: the victim with the most invalid pages is
//! chosen (greedy), its valid pages are migrated to the plane's
//! migration stream (one-shot TLC word-line programs), and the block
//! is erased back into the free list. GC runs *inline* on the host
//! write path when a plane drops below its free-block low watermark
//! (paper §II-C: "GC operations occur whenever SSD physical space is
//! insufficient, not just when the SLC cache is full").

use super::Ftl;
use crate::config::Nanos;
use crate::flash::PlaneId;
use crate::metrics::Attribution;
use crate::Result;

/// Run one GC cycle on `plane`: pick the greedy victim, migrate its
/// valid pages, erase it. Returns `false` when no victim with invalid
/// pages exists.
pub fn gc_once(ftl: &mut Ftl, plane: PlaneId, now: Nanos) -> Result<bool> {
    let victim = match ftl.pop_victim(plane) {
        Some(v) => v,
        None => return Ok(false),
    };
    ftl.reclaim_block(victim, Attribution::GcMigration, now)?;
    ftl.array.push_free(victim)?;
    Ok(true)
}

/// How many pages a GC cycle on the greedy victim would reclaim
/// (diagnostics / ablation benches). Answered from the victim index
/// in O(1) amortized; GC can only reclaim *closed* blocks, so the
/// answer is the invalid count of the block [`Ftl::pop_victim`] would
/// actually pick (the old implementation scanned every block in the
/// plane, including active and cache-pool blocks GC cannot touch).
pub fn greedy_gain(ftl: &mut Ftl, plane: PlaneId) -> u32 {
    ftl.peek_victim_gain(plane)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::flash::{BlockMode, Lpn};

    #[test]
    fn gc_once_picks_most_invalid() {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut f = Ftl::new(&cfg).unwrap();
        // Block A: 2 invalid; Block B: 4 invalid. GC must erase B.
        let a = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        let b = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        for i in 0..6u64 {
            f.program_slc_into(a, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        for i in 10..16u64 {
            f.program_slc_into(b, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        // overwrite to invalidate: 2 of A, 4 of B
        for i in [0u64, 1, 10, 11, 12, 13] {
            f.host_write_tlc(Lpn(i), 0).unwrap();
        }
        f.register_closed(a);
        f.register_closed(b);
        assert!(gc_once(&mut f, PlaneId(0), 0).unwrap());
        assert!(f.array.block(b).is_erased(), "greedy victim is B");
        assert!(!f.array.block(a).is_erased());
        f.audit().unwrap();
    }

    #[test]
    fn tenant_aware_single_tenant_is_identical_to_greedy() {
        // The differential guarantee at the GC layer: with one tenant
        // every debt is equal, so the tenant-aware policy must make the
        // exact same pick the greedy policy makes.
        let build = |policy| {
            let mut cfg = presets::small();
            cfg.cache.scheme = crate::config::Scheme::TlcOnly;
            let mut f = Ftl::new(&cfg).unwrap();
            f.set_tenant_count(1);
            f.set_victim_policy(policy);
            f.set_tenant(Some(0));
            let a = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
            let b = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
            for i in 0..6u64 {
                f.program_slc_into(a, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
            }
            for i in 10..16u64 {
                f.program_slc_into(b, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
            }
            // equal invalid counts: a genuine tie
            for i in [0u64, 1, 10, 11] {
                f.host_write_tlc(Lpn(i), 0).unwrap();
            }
            f.register_closed(a);
            f.register_closed(b);
            (f, a, b)
        };
        let (mut greedy, ga, _gb) = build(crate::ftl::VictimPolicy::Greedy);
        let (mut aware, aa, _ab) = build(crate::ftl::VictimPolicy::TenantAware);
        let gv = greedy.pop_victim(PlaneId(0)).unwrap();
        let av = aware.pop_victim(PlaneId(0)).unwrap();
        assert_eq!(gv, av, "equal debts must reproduce the greedy pick");
        assert_eq!(gv, ga, "greedy tie goes to the first block at the max");
        let _ = aa;
    }

    #[test]
    fn greedy_gain_reports_the_actual_victims_reclaim() {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut f = Ftl::new(&cfg).unwrap();
        assert_eq!(greedy_gain(&mut f, PlaneId(0)), 0, "no closed blocks, no gain");
        let a = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        let b = f.alloc_block(PlaneId(0), BlockMode::Slc).unwrap();
        for i in 0..6u64 {
            f.program_slc_into(a, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        for i in 10..16u64 {
            f.program_slc_into(b, Lpn(i), Attribution::SlcCacheWrite, 0).unwrap();
        }
        for i in [0u64, 1, 10, 11, 12] {
            f.host_write_tlc(Lpn(i), 0).unwrap();
        }
        f.register_closed(a);
        f.register_closed(b);
        assert_eq!(greedy_gain(&mut f, PlaneId(0)), 3, "b leads with 3 invalid pages");
        assert!(gc_once(&mut f, PlaneId(0), 0).unwrap());
        assert_eq!(greedy_gain(&mut f, PlaneId(0)), 2, "a remains with 2");
    }

    #[test]
    fn gc_without_victims_reports_false() {
        let mut cfg = presets::small();
        cfg.cache.scheme = crate::config::Scheme::TlcOnly;
        let mut f = Ftl::new(&cfg).unwrap();
        assert!(!gc_once(&mut f, PlaneId(0), 0).unwrap());
    }
}
