//! Incremental GC/AGC victim-selection index (§Perf).
//!
//! The scan-based hot path ([`super::Ftl::pop_victim`] before this
//! module existed) re-read every closed block's invalid count on every
//! GC pop, every AGC idle step, and every partition-driven eviction —
//! O(closed blocks) per decision, hostile to production-scale
//! geometries (`presets::large` keeps ≥ 1k closed blocks per plane).
//! [`VictimIndex`] replaces the scan with per-plane **invalid-count
//! buckets** maintained incrementally:
//!
//! * [`VictimIndex::insert`] on block close — O(log closed);
//! * [`VictimIndex::note_invalidate`] on every page invalidation that
//!   hits a closed block — moves the block up one bucket, O(log closed);
//! * [`VictimIndex::peek_max`] — the greedy victim, O(1) amortized
//!   (the max-bucket hint only decays across pops, and every decay was
//!   paid for by the insert/invalidate that raised it);
//! * [`VictimIndex::remove`] / [`VictimIndex::reposition`] mirror the
//!   closed list's `swap_remove` so tie order stays **byte-identical**
//!   to the historical scan.
//!
//! Tie order is the load-bearing subtlety: the old scan picked the
//! *first* block at the maximal invalid count in closed-list order, and
//! the tenant-aware tie-break re-scanned the ties in that same order.
//! Buckets therefore store `(closed-list position, block)` pairs in a
//! `BTreeSet`, whose in-order iteration *is* closed-list order; when
//! `swap_remove` moves the list's last element into a hole, the moved
//! block is re-keyed with [`VictimIndex::reposition`]. The property
//! suite (`tests/prop_victim_index.rs`) drives random
//! write/invalidate/close/erase sequences against the linear-scan
//! oracle and shrinks any divergence.

use crate::flash::{BlockAddr, PlaneId};
use crate::{Error, Result};
use std::collections::BTreeSet;

/// Sentinel for "block not in the closed list".
const NONE: u32 = u32::MAX;

/// Per-plane state: positions, current buckets, and the bucket sets.
struct PlaneIndex {
    /// Closed-list position per block (`NONE` = not closed).
    pos: Vec<u32>,
    /// Invalid-count bucket per block (`NONE` = not closed).
    bucket_of: Vec<u32>,
    /// `(closed-list position, block)` per invalid count; in-order
    /// iteration reproduces the scan's tie order exactly.
    buckets: Vec<BTreeSet<(u32, u32)>>,
    /// Upper bound on the highest non-empty GC-eligible bucket (≥ 1).
    /// Decays lazily in [`PlaneIndex::peek`]; raised eagerly on
    /// insert/invalidate, so the decay is amortized O(1).
    max_hint: u32,
}

impl PlaneIndex {
    fn new(blocks_per_plane: u32, pages_per_block: u32) -> PlaneIndex {
        PlaneIndex {
            pos: vec![NONE; blocks_per_plane as usize],
            bucket_of: vec![NONE; blocks_per_plane as usize],
            buckets: (0..=pages_per_block).map(|_| BTreeSet::new()).collect(),
            max_hint: 0,
        }
    }

    fn peek(&mut self) -> Option<(u32, u32, u32)> {
        while self.max_hint >= 1 {
            if let Some(&(pos, block)) = self.buckets[self.max_hint as usize].iter().next() {
                return Some((pos, block, self.max_hint));
            }
            self.max_hint -= 1;
        }
        None
    }
}

/// The per-plane invalid-count bucket index (see the module docs).
pub struct VictimIndex {
    planes: Vec<PlaneIndex>,
}

impl VictimIndex {
    /// Index covering `planes × blocks_per_plane` blocks with invalid
    /// counts in `[0, pages_per_block]`.
    pub fn new(planes: u32, blocks_per_plane: u32, pages_per_block: u32) -> VictimIndex {
        VictimIndex {
            planes: (0..planes)
                .map(|_| PlaneIndex::new(blocks_per_plane, pages_per_block))
                .collect(),
        }
    }

    /// A block entered the closed list at position `pos` with `invalid`
    /// invalid pages.
    pub fn insert(&mut self, addr: BlockAddr, pos: usize, invalid: u32) {
        let p = &mut self.planes[addr.plane.0 as usize];
        let b = addr.block as usize;
        debug_assert_eq!(p.pos[b], NONE, "block {b} closed twice");
        p.pos[b] = pos as u32;
        p.bucket_of[b] = invalid;
        p.buckets[invalid as usize].insert((pos as u32, addr.block));
        if invalid >= 1 {
            p.max_hint = p.max_hint.max(invalid);
        }
    }

    /// One page of `(plane, block)` was invalidated; if the block is
    /// closed, move it up one bucket. No-op otherwise (active blocks,
    /// cache-pool blocks, and popped victims are not indexed).
    #[inline]
    pub fn note_invalidate(&mut self, plane: PlaneId, block: u32) {
        let p = &mut self.planes[plane.0 as usize];
        let b = block as usize;
        let cur = p.bucket_of[b];
        if cur == NONE {
            return;
        }
        let pos = p.pos[b];
        let next = cur + 1;
        debug_assert!((next as usize) < p.buckets.len(), "invalid > pages_per_block");
        p.buckets[cur as usize].remove(&(pos, block));
        p.buckets[next as usize].insert((pos, block));
        p.bucket_of[b] = next;
        p.max_hint = p.max_hint.max(next);
    }

    /// The greedy pick: `(closed-list position, block, invalid count)`
    /// of the first-in-list block at the maximal non-zero invalid
    /// count, or `None` when no closed block is GC-eligible.
    pub fn peek_max(&mut self, plane: PlaneId) -> Option<(u32, u32, u32)> {
        self.planes[plane.0 as usize].peek()
    }

    /// Iterate every closed block at invalid count `inv` in closed-list
    /// order (the tenant-aware tie-break walks these).
    pub fn ties(&self, plane: PlaneId, inv: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.planes[plane.0 as usize].buckets[inv as usize].iter().copied()
    }

    /// A block left the closed list (popped as a victim).
    pub fn remove(&mut self, addr: BlockAddr) {
        let p = &mut self.planes[addr.plane.0 as usize];
        let b = addr.block as usize;
        let cur = p.bucket_of[b];
        if cur == NONE {
            return;
        }
        p.buckets[cur as usize].remove(&(p.pos[b], addr.block));
        p.pos[b] = NONE;
        p.bucket_of[b] = NONE;
    }

    /// The closed list's `swap_remove` moved `addr` to `new_pos`;
    /// re-key its bucket entry so tie order keeps tracking the list.
    pub fn reposition(&mut self, addr: BlockAddr, new_pos: usize) {
        let p = &mut self.planes[addr.plane.0 as usize];
        let b = addr.block as usize;
        let cur = p.bucket_of[b];
        if cur == NONE || p.pos[b] == new_pos as u32 {
            return;
        }
        let set = &mut p.buckets[cur as usize];
        set.remove(&(p.pos[b], addr.block));
        set.insert((new_pos as u32, addr.block));
        p.pos[b] = new_pos as u32;
    }

    /// Full-consistency audit against a fresh rescan of the closed
    /// list: every closed block is present at its exact position and
    /// bucket (`inv(block)`), and nothing else is indexed. Slow; used
    /// by [`super::Ftl::audit`] and the property suite.
    pub fn audit<F: Fn(u32) -> u32>(
        &self,
        plane: PlaneId,
        closed: &[u32],
        inv: F,
    ) -> Result<()> {
        let p = &self.planes[plane.0 as usize];
        let total: usize = p.buckets.iter().map(|s| s.len()).sum();
        if total != closed.len() {
            return Err(Error::invariant(format!(
                "plane {}: index holds {total} blocks, closed list {}",
                plane.0,
                closed.len()
            )));
        }
        for (i, &b) in closed.iter().enumerate() {
            if p.pos[b as usize] != i as u32 {
                return Err(Error::invariant(format!(
                    "plane {} block {b}: index position {} != list position {i}",
                    plane.0, p.pos[b as usize]
                )));
            }
            let want = inv(b);
            if p.bucket_of[b as usize] != want {
                return Err(Error::invariant(format!(
                    "plane {} block {b}: bucket {} != invalid count {want}",
                    plane.0, p.bucket_of[b as usize]
                )));
            }
            if !p.buckets[want as usize].contains(&(i as u32, b)) {
                return Err(Error::invariant(format!(
                    "plane {} block {b}: missing from bucket {want}",
                    plane.0
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(plane: u32, block: u32) -> BlockAddr {
        BlockAddr { plane: PlaneId(plane), block }
    }

    #[test]
    fn insert_peek_remove_roundtrip() {
        let mut ix = VictimIndex::new(2, 8, 12);
        assert_eq!(ix.peek_max(PlaneId(0)), None);
        ix.insert(addr(0, 3), 0, 2);
        ix.insert(addr(0, 5), 1, 4);
        ix.insert(addr(0, 1), 2, 0); // closed but not eligible
        assert_eq!(ix.peek_max(PlaneId(0)), Some((1, 5, 4)));
        assert_eq!(ix.peek_max(PlaneId(1)), None, "planes are independent");
        ix.remove(addr(0, 5));
        assert_eq!(ix.peek_max(PlaneId(0)), Some((0, 3, 2)));
        ix.remove(addr(0, 3));
        assert_eq!(ix.peek_max(PlaneId(0)), None, "bucket-0 blocks never qualify");
        ix.audit(PlaneId(0), &[1], |_| 0).unwrap();
    }

    #[test]
    fn invalidate_moves_buckets_and_ties_stay_in_list_order() {
        let mut ix = VictimIndex::new(1, 8, 12);
        ix.insert(addr(0, 2), 0, 1);
        ix.insert(addr(0, 6), 1, 1);
        // a tie at 1: the first-in-list block (pos 0) wins
        assert_eq!(ix.peek_max(PlaneId(0)), Some((0, 2, 1)));
        let ties: Vec<(u32, u32)> = ix.ties(PlaneId(0), 1).collect();
        assert_eq!(ties, vec![(0, 2), (1, 6)]);
        // block 6 gains an invalid page and takes the lead
        ix.note_invalidate(PlaneId(0), 6);
        assert_eq!(ix.peek_max(PlaneId(0)), Some((1, 6, 2)));
        // invalidations of unindexed blocks are inert
        ix.note_invalidate(PlaneId(0), 7);
        assert_eq!(ix.peek_max(PlaneId(0)), Some((1, 6, 2)));
        ix.audit(PlaneId(0), &[2, 6], |b| if b == 6 { 2 } else { 1 }).unwrap();
    }

    #[test]
    fn reposition_mirrors_swap_remove() {
        let mut ix = VictimIndex::new(1, 8, 12);
        ix.insert(addr(0, 2), 0, 3);
        ix.insert(addr(0, 6), 1, 3);
        ix.insert(addr(0, 4), 2, 3);
        // pop the pos-0 block the way Ftl does: swap_remove(0) moves
        // the last block (4) into position 0
        ix.remove(addr(0, 2));
        ix.reposition(addr(0, 4), 0);
        assert_eq!(ix.peek_max(PlaneId(0)), Some((0, 4, 3)), "moved block leads the tie");
        ix.audit(PlaneId(0), &[4, 6], |_| 3).unwrap();
    }

    #[test]
    fn audit_catches_divergence() {
        let mut ix = VictimIndex::new(1, 8, 12);
        ix.insert(addr(0, 2), 0, 1);
        assert!(ix.audit(PlaneId(0), &[2], |_| 1).is_ok());
        assert!(ix.audit(PlaneId(0), &[2], |_| 2).is_err(), "stale bucket detected");
        assert!(ix.audit(PlaneId(0), &[2, 3], |_| 1).is_err(), "missing block detected");
        assert!(ix.audit(PlaneId(0), &[], |_| 1).is_err(), "extra block detected");
    }
}
