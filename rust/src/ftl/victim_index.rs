//! Incremental GC/AGC victim-selection index (§Perf).
//!
//! The scan-based hot path ([`super::Ftl::pop_victim`] before this
//! module existed) re-read every closed block's invalid count on every
//! GC pop, every AGC idle step, and every partition-driven eviction —
//! O(closed blocks) per decision, hostile to production-scale
//! geometries (`presets::large` keeps ≥ 1k closed blocks per plane).
//! [`VictimIndex`] replaces the scan with per-plane **invalid-count
//! buckets** maintained incrementally:
//!
//! * [`VictimIndex::insert`] on block close;
//! * [`VictimIndex::note_invalidate`] on every page invalidation that
//!   hits a closed block — moves the block up one bucket;
//! * [`VictimIndex::peek_max`] — the greedy victim, amortized O(1)
//!   bucket lookup (the max-bucket hint only decays across pops, and
//!   every decay was paid for by the insert/invalidate that raised it);
//! * [`VictimIndex::remove`] / [`VictimIndex::reposition`] mirror the
//!   closed list's `swap_remove` so tie order stays **byte-identical**
//!   to the historical scan.
//!
//! Two storage backends share that API (selected by `sim.flat_index`):
//!
//! * **Flat** (default): each bucket is a plain `Vec<u32>` of block
//!   ids, with intrusive per-block `(bucket, slot)` back-pointers.
//!   Insert is a push, remove is a `swap_remove` (repairing the moved
//!   block's slot), and — because buckets do not key on list position —
//!   [`VictimIndex::reposition`] is a single array store with **zero**
//!   bucket mutation. `peek_max` scans one contiguous bucket for the
//!   minimal list position. No tree rebalancing, no per-node heap
//!   allocation, cache-line-friendly scans.
//! * **Tree** (oracle, `sim.flat_index = false`): per-bucket
//!   `BTreeSet<(closed-list position, block)>` whose in-order iteration
//!   *is* closed-list order — the PR 4 structure, retained for
//!   differential testing.
//!
//! Tie order is the load-bearing subtlety: the old scan picked the
//! *first* block at the maximal invalid count in closed-list order, and
//! the tenant-aware tie-break walks the ties replacing its pick only on
//! strictly greater debt. Starting from the minimal-position block,
//! that rule resolves to "maximal debt, ties toward minimal list
//! position" — a property of the *set* of ties, not of iteration order
//! — so the flat backend may return ties in arbitrary bucket order as
//! long as the caller compares `(debt, position)` explicitly (which
//! [`super::Ftl::pop_victim`] does). The property suite
//! (`tests/prop_victim_index.rs`) drives random
//! write/invalidate/close/erase sequences against the linear-scan
//! oracle — and the flat backend in lockstep against the tree — and
//! shrinks any divergence.

use crate::flash::{BlockAddr, PlaneId};
use crate::{Error, Result};
use std::collections::BTreeSet;

/// Sentinel for "block not in the closed list".
const NONE: u32 = u32::MAX;

/// Per-plane state: positions, current buckets, and one of the two
/// bucket stores (the other stays empty).
struct PlaneIndex {
    /// Closed-list position per block (`NONE` = not closed).
    pos: Vec<u32>,
    /// Invalid-count bucket per block (`NONE` = not closed).
    bucket_of: Vec<u32>,
    /// Tree backend: `(closed-list position, block)` per invalid count;
    /// in-order iteration reproduces the scan's tie order exactly.
    tree: Vec<BTreeSet<(u32, u32)>>,
    /// Flat backend: bare block ids per invalid count; unordered.
    flat: Vec<Vec<u32>>,
    /// Flat backend: slot of each block inside its bucket (`NONE` =
    /// not closed). The intrusive back-pointer that makes removal O(1).
    slot_of: Vec<u32>,
    /// Upper bound on the highest non-empty GC-eligible bucket (≥ 1).
    /// Decays lazily in [`PlaneIndex::peek`]; raised eagerly on
    /// insert/invalidate, so the decay is amortized O(1).
    max_hint: u32,
}

impl PlaneIndex {
    fn new(blocks_per_plane: u32, pages_per_block: u32, use_flat: bool) -> PlaneIndex {
        let n = blocks_per_plane as usize;
        let buckets = pages_per_block as usize + 1;
        PlaneIndex {
            pos: vec![NONE; n],
            bucket_of: vec![NONE; n],
            tree: if use_flat { Vec::new() } else { vec![BTreeSet::new(); buckets] },
            flat: if use_flat { vec![Vec::new(); buckets] } else { Vec::new() },
            slot_of: if use_flat { vec![NONE; n] } else { Vec::new() },
            max_hint: 0,
        }
    }

    fn peek(&mut self, use_flat: bool) -> Option<(u32, u32, u32)> {
        while self.max_hint >= 1 {
            let inv = self.max_hint;
            if use_flat {
                // Contiguous min-position scan of the one max bucket.
                let bucket = &self.flat[inv as usize];
                if let Some(&first) = bucket.first() {
                    let mut best = (self.pos[first as usize], first);
                    for &b in &bucket[1..] {
                        let p = self.pos[b as usize];
                        if p < best.0 {
                            best = (p, b);
                        }
                    }
                    return Some((best.0, best.1, inv));
                }
            } else if let Some(&(pos, block)) = self.tree[inv as usize].iter().next() {
                return Some((pos, block, inv));
            }
            self.max_hint -= 1;
        }
        None
    }

    /// Flat-backend removal from the current bucket: `swap_remove`,
    /// repairing the displaced block's slot back-pointer.
    fn flat_unlink(&mut self, block: u32) {
        let b = block as usize;
        let bucket = &mut self.flat[self.bucket_of[b] as usize];
        let slot = self.slot_of[b] as usize;
        debug_assert_eq!(bucket[slot], block, "slot back-pointer desynced");
        bucket.swap_remove(slot);
        if let Some(&moved) = bucket.get(slot) {
            self.slot_of[moved as usize] = slot as u32;
        }
        self.slot_of[b] = NONE;
    }

    /// Flat-backend insertion into bucket `inv`: a push.
    fn flat_link(&mut self, block: u32, inv: u32) {
        self.slot_of[block as usize] = self.flat[inv as usize].len() as u32;
        self.flat[inv as usize].push(block);
    }
}

/// The per-plane invalid-count bucket index (see the module docs).
pub struct VictimIndex {
    planes: Vec<PlaneIndex>,
    use_flat: bool,
}

impl VictimIndex {
    /// Index covering `planes × blocks_per_plane` blocks with invalid
    /// counts in `[0, pages_per_block]`. `use_flat` selects the flat
    /// vec-bucket backend (`sim.flat_index`, the default) over the
    /// `BTreeSet` oracle.
    pub fn new(
        planes: u32,
        blocks_per_plane: u32,
        pages_per_block: u32,
        use_flat: bool,
    ) -> VictimIndex {
        VictimIndex {
            planes: (0..planes)
                .map(|_| PlaneIndex::new(blocks_per_plane, pages_per_block, use_flat))
                .collect(),
            use_flat,
        }
    }

    /// A block entered the closed list at position `pos` with `invalid`
    /// invalid pages.
    pub fn insert(&mut self, addr: BlockAddr, pos: usize, invalid: u32) {
        let p = &mut self.planes[addr.plane.0 as usize];
        let b = addr.block as usize;
        debug_assert_eq!(p.pos[b], NONE, "block {b} closed twice");
        p.pos[b] = pos as u32;
        p.bucket_of[b] = invalid;
        if self.use_flat {
            p.flat_link(addr.block, invalid);
        } else {
            p.tree[invalid as usize].insert((pos as u32, addr.block));
        }
        if invalid >= 1 {
            p.max_hint = p.max_hint.max(invalid);
        }
    }

    /// One page of `(plane, block)` was invalidated; if the block is
    /// closed, move it up one bucket. No-op otherwise (active blocks,
    /// cache-pool blocks, and popped victims are not indexed).
    #[inline]
    pub fn note_invalidate(&mut self, plane: PlaneId, block: u32) {
        let p = &mut self.planes[plane.0 as usize];
        let b = block as usize;
        let cur = p.bucket_of[b];
        if cur == NONE {
            return;
        }
        let next = cur + 1;
        if self.use_flat {
            debug_assert!((next as usize) < p.flat.len(), "invalid > pages_per_block");
            p.flat_unlink(block);
            p.bucket_of[b] = next;
            p.flat_link(block, next);
        } else {
            debug_assert!((next as usize) < p.tree.len(), "invalid > pages_per_block");
            let pos = p.pos[b];
            p.tree[cur as usize].remove(&(pos, block));
            p.tree[next as usize].insert((pos, block));
            p.bucket_of[b] = next;
        }
        p.max_hint = p.max_hint.max(next);
    }

    /// The greedy pick: `(closed-list position, block, invalid count)`
    /// of the first-in-list block at the maximal non-zero invalid
    /// count, or `None` when no closed block is GC-eligible.
    pub fn peek_max(&mut self, plane: PlaneId) -> Option<(u32, u32, u32)> {
        let use_flat = self.use_flat;
        self.planes[plane.0 as usize].peek(use_flat)
    }

    /// Iterate every closed block at invalid count `inv` as
    /// `(closed-list position, block)`. The tree backend yields
    /// closed-list order; the flat backend yields arbitrary bucket
    /// order — callers breaking ties must compare `(debt, position)`
    /// explicitly rather than rely on iteration order.
    pub fn ties(&self, plane: PlaneId, inv: u32) -> TiesIter<'_> {
        let p = &self.planes[plane.0 as usize];
        if self.use_flat {
            TiesIter::Flat { blocks: p.flat[inv as usize].iter(), pos: &p.pos }
        } else {
            TiesIter::Tree(p.tree[inv as usize].iter())
        }
    }

    /// A block left the closed list (popped as a victim).
    pub fn remove(&mut self, addr: BlockAddr) {
        let p = &mut self.planes[addr.plane.0 as usize];
        let b = addr.block as usize;
        let cur = p.bucket_of[b];
        if cur == NONE {
            return;
        }
        if self.use_flat {
            p.flat_unlink(addr.block);
        } else {
            p.tree[cur as usize].remove(&(p.pos[b], addr.block));
        }
        p.pos[b] = NONE;
        p.bucket_of[b] = NONE;
    }

    /// The closed list's `swap_remove` moved `addr` to `new_pos`;
    /// update its position so tie order keeps tracking the list. The
    /// flat backend's buckets do not key on position, so this is a
    /// single array store; the tree oracle re-keys its set entry.
    pub fn reposition(&mut self, addr: BlockAddr, new_pos: usize) {
        let p = &mut self.planes[addr.plane.0 as usize];
        let b = addr.block as usize;
        let cur = p.bucket_of[b];
        if cur == NONE || p.pos[b] == new_pos as u32 {
            return;
        }
        if !self.use_flat {
            let set = &mut p.tree[cur as usize];
            set.remove(&(p.pos[b], addr.block));
            set.insert((new_pos as u32, addr.block));
        }
        p.pos[b] = new_pos as u32;
    }

    /// Full-consistency audit against a fresh rescan of the closed
    /// list: every closed block is present at its exact position and
    /// bucket (`inv(block)`), the intrusive back-pointers agree, and
    /// nothing else is indexed. Slow; used by [`super::Ftl::audit`] and
    /// the property suite.
    pub fn audit<F: Fn(u32) -> u32>(
        &self,
        plane: PlaneId,
        closed: &[u32],
        inv: F,
    ) -> Result<()> {
        let p = &self.planes[plane.0 as usize];
        let total: usize = if self.use_flat {
            p.flat.iter().map(|v| v.len()).sum()
        } else {
            p.tree.iter().map(|s| s.len()).sum()
        };
        if total != closed.len() {
            return Err(Error::invariant(format!(
                "plane {}: index holds {total} blocks, closed list {}",
                plane.0,
                closed.len()
            )));
        }
        for (i, &b) in closed.iter().enumerate() {
            if p.pos[b as usize] != i as u32 {
                return Err(Error::invariant(format!(
                    "plane {} block {b}: index position {} != list position {i}",
                    plane.0, p.pos[b as usize]
                )));
            }
            let want = inv(b);
            if p.bucket_of[b as usize] != want {
                return Err(Error::invariant(format!(
                    "plane {} block {b}: bucket {} != invalid count {want}",
                    plane.0, p.bucket_of[b as usize]
                )));
            }
            let present = if self.use_flat {
                let slot = p.slot_of[b as usize];
                slot != NONE && p.flat[want as usize].get(slot as usize) == Some(&b)
            } else {
                p.tree[want as usize].contains(&(i as u32, b))
            };
            if !present {
                return Err(Error::invariant(format!(
                    "plane {} block {b}: missing from bucket {want}",
                    plane.0
                )));
            }
        }
        Ok(())
    }
}

/// Backend-agnostic tie iterator (see [`VictimIndex::ties`]).
pub enum TiesIter<'a> {
    /// Tree oracle: in-order `(pos, block)` pairs.
    Tree(std::collections::btree_set::Iter<'a, (u32, u32)>),
    /// Flat backend: bucket slots joined with the position array.
    Flat { blocks: std::slice::Iter<'a, u32>, pos: &'a [u32] },
}

impl Iterator for TiesIter<'_> {
    type Item = (u32, u32);

    fn next(&mut self) -> Option<(u32, u32)> {
        match self {
            TiesIter::Tree(it) => it.next().copied(),
            TiesIter::Flat { blocks, pos } => {
                blocks.next().map(|&b| (pos[b as usize], b))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(plane: u32, block: u32) -> BlockAddr {
        BlockAddr { plane: PlaneId(plane), block }
    }

    /// Run a scenario against both backends.
    fn for_both(f: impl Fn(VictimIndex)) {
        f(VictimIndex::new(2, 8, 12, false));
        f(VictimIndex::new(2, 8, 12, true));
    }

    #[test]
    fn insert_peek_remove_roundtrip() {
        for_both(|mut ix| {
            assert_eq!(ix.peek_max(PlaneId(0)), None);
            ix.insert(addr(0, 3), 0, 2);
            ix.insert(addr(0, 5), 1, 4);
            ix.insert(addr(0, 1), 2, 0); // closed but not eligible
            assert_eq!(ix.peek_max(PlaneId(0)), Some((1, 5, 4)));
            assert_eq!(ix.peek_max(PlaneId(1)), None, "planes are independent");
            ix.remove(addr(0, 5));
            assert_eq!(ix.peek_max(PlaneId(0)), Some((0, 3, 2)));
            ix.remove(addr(0, 3));
            assert_eq!(ix.peek_max(PlaneId(0)), None, "bucket-0 blocks never qualify");
            ix.audit(PlaneId(0), &[1], |_| 0).unwrap();
        });
    }

    #[test]
    fn invalidate_moves_buckets_and_ties_cover_the_bucket() {
        for_both(|mut ix| {
            ix.insert(addr(0, 2), 0, 1);
            ix.insert(addr(0, 6), 1, 1);
            // a tie at 1: the first-in-list block (pos 0) wins
            assert_eq!(ix.peek_max(PlaneId(0)), Some((0, 2, 1)));
            let mut ties: Vec<(u32, u32)> = ix.ties(PlaneId(0), 1).collect();
            ties.sort_unstable();
            assert_eq!(ties, vec![(0, 2), (1, 6)], "ties carry exact positions");
            // block 6 gains an invalid page and takes the lead
            ix.note_invalidate(PlaneId(0), 6);
            assert_eq!(ix.peek_max(PlaneId(0)), Some((1, 6, 2)));
            // invalidations of unindexed blocks are inert
            ix.note_invalidate(PlaneId(0), 7);
            assert_eq!(ix.peek_max(PlaneId(0)), Some((1, 6, 2)));
            ix.audit(PlaneId(0), &[2, 6], |b| if b == 6 { 2 } else { 1 }).unwrap();
        });
    }

    #[test]
    fn tree_ties_iterate_in_list_order() {
        // Pinned separately from the shared scenarios: in-list order is
        // a tree-backend guarantee (the flat backend is unordered).
        let mut ix = VictimIndex::new(1, 8, 12, false);
        ix.insert(addr(0, 6), 0, 1);
        ix.insert(addr(0, 2), 1, 1);
        let ties: Vec<(u32, u32)> = ix.ties(PlaneId(0), 1).collect();
        assert_eq!(ties, vec![(0, 6), (1, 2)]);
    }

    #[test]
    fn reposition_mirrors_swap_remove() {
        for_both(|mut ix| {
            ix.insert(addr(0, 2), 0, 3);
            ix.insert(addr(0, 6), 1, 3);
            ix.insert(addr(0, 4), 2, 3);
            // pop the pos-0 block the way Ftl does: swap_remove(0) moves
            // the last block (4) into position 0
            ix.remove(addr(0, 2));
            ix.reposition(addr(0, 4), 0);
            assert_eq!(ix.peek_max(PlaneId(0)), Some((0, 4, 3)), "moved block leads the tie");
            ix.audit(PlaneId(0), &[4, 6], |_| 3).unwrap();
        });
    }

    #[test]
    fn flat_swap_remove_repairs_slots() {
        // Force the swap_remove path: three blocks in one bucket,
        // unlink the slot-0 block, then keep mutating the block whose
        // slot moved — any stale back-pointer trips the audit.
        let mut ix = VictimIndex::new(1, 8, 12, true);
        ix.insert(addr(0, 1), 0, 2);
        ix.insert(addr(0, 3), 1, 2);
        ix.insert(addr(0, 5), 2, 2);
        ix.remove(addr(0, 1)); // bucket [1,3,5] -> [5,3]; 5's slot moved
        ix.reposition(addr(0, 5), 0);
        ix.note_invalidate(PlaneId(0), 5); // unlink via repaired slot
        assert_eq!(ix.peek_max(PlaneId(0)), Some((0, 5, 3)));
        ix.audit(PlaneId(0), &[5, 3], |b| if b == 5 { 3 } else { 2 }).unwrap();
    }

    #[test]
    fn audit_catches_divergence() {
        for_both(|mut ix| {
            ix.insert(addr(0, 2), 0, 1);
            assert!(ix.audit(PlaneId(0), &[2], |_| 1).is_ok());
            assert!(ix.audit(PlaneId(0), &[2], |_| 2).is_err(), "stale bucket detected");
            assert!(ix.audit(PlaneId(0), &[2, 3], |_| 1).is_err(), "missing block detected");
            assert!(ix.audit(PlaneId(0), &[], |_| 1).is_err(), "extra block detected");
        });
    }
}
