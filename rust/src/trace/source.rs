//! Pull-based streaming workload sources (§Streaming workloads).
//!
//! An [`OpSource`] is a deterministic, arrival-sorted iterator of
//! [`TraceOp`]s with the same single-seed RNG discipline as the
//! materializing generators it twins: same seed → byte-identical op
//! sequence, but O(1) memory instead of O(trace). The bounded
//! [`crate::host::SubmissionQueue`] window pulls from a source on
//! `pop`, so a day-scale workload never exists as a `Vec` anywhere —
//! the property the fleet's 1000-device peak-RSS datapoint measures.
//!
//! Implementations:
//! * [`SynthSource`] — incremental-burst twin of
//!   [`synth::generate_scaled`] (one op of state instead of a push
//!   loop; allocation-free per op).
//! * [`SeqFillSource`] — arithmetic twin of
//!   [`scenario::sequential_fill`].
//! * [`bursty_source`] — streaming twin of [`scenario::to_bursty`]:
//!   counts the daily stream's write volume in an O(1)-memory pre-pass
//!   instead of materializing-then-rewriting.
//! * [`DailyStreamsSource`] — arithmetic twin of
//!   [`scenario::daily_streams`].
//! * [`MaterializedSource`] — wraps an existing [`Trace`] (backward
//!   compat, and the differential oracle's feed).
//! * `MsrSource` (in [`super::msr`]) — adapter over the constant-memory
//!   CSV replay.
//!
//! The tenant-mix sources ([`crate::host::tenant::build_mix_sources`])
//! live next to the generators they twin.
//!
//! **Horizon.** Engines need the workload's span without scanning a
//! `Vec`: the fault trigger is `at_frac × horizon`. Arithmetic sources
//! know it in closed form; RNG sources replay a fresh clone of
//! themselves in O(1) memory and cache the answer; a materialized
//! trace scans once at construction. The contract is exact: `horizon()`
//! equals the maximum arrival the source will ever emit (0 if empty) —
//! the lockstep property suite pins it against the materialized max.

use super::profiles::Profile;
use super::scenario::BURSTY_WRITE_BYTES;
use super::synth::{self, SizeMix};
use super::{OpKind, Trace, TraceOp};
use crate::config::{Nanos, MS, US};
use crate::util::rng::{Rng, Zipf};

/// A pull-based, deterministic stream of trace operations.
///
/// Contract:
/// * arrivals are non-decreasing in emission order (the bounded queue
///   and both engines rely on it);
/// * the sequence is a pure function of construction parameters
///   (re-constructing replays byte-identically);
/// * after the first `None`, every later call returns `None`.
pub trait OpSource: Send {
    /// Next operation, or `None` when the workload is exhausted.
    fn next_op(&mut self) -> Option<TraceOp>;

    /// Maximum arrival time this source will ever emit (0 if empty).
    ///
    /// Takes `&mut self` so RNG-driven sources can lazily replay a
    /// fresh clone of themselves (O(1) memory) and cache the answer;
    /// calling it does not disturb the op stream.
    fn horizon(&mut self) -> Nanos;

    /// Workload name (for summaries and reports).
    fn name(&self) -> &str;

    /// Drain into a materialized [`Trace`] — the bridge back to the
    /// historical API, used by the lockstep tests and oracle plumbing.
    fn collect_trace(mut self) -> Trace
    where
        Self: Sized,
    {
        let name = self.name().to_string();
        let mut ops = Vec::new();
        while let Some(op) = self.next_op() {
            ops.push(op);
        }
        Trace { name, ops }
    }

    /// Adapt into a plain `Iterator<Item = TraceOp>` (the shape
    /// `run_bios`-style consumers already take).
    fn ops(self) -> OpIter<Self>
    where
        Self: Sized,
    {
        OpIter(self)
    }
}

/// Iterator adapter over an [`OpSource`] (see [`OpSource::ops`]).
pub struct OpIter<S: OpSource>(pub S);

impl<S: OpSource> Iterator for OpIter<S> {
    type Item = TraceOp;
    fn next(&mut self) -> Option<TraceOp> {
        self.0.next_op()
    }
}

// --- materialized ----------------------------------------------------

/// An already-built [`Trace`] as a source: backward compatibility for
/// callers that hold a `Vec`, and the feed the differential oracle
/// path uses (`sim.streaming_traces = false` differs only in *source
/// type*, never in queue or engine code).
pub struct MaterializedSource {
    trace: Trace,
    pos: usize,
    horizon: Nanos,
}

impl MaterializedSource {
    /// Wrap a trace. Must be arrival-sorted (all generators are).
    pub fn new(trace: Trace) -> MaterializedSource {
        debug_assert!(
            trace.ops.windows(2).all(|w| w[0].at <= w[1].at),
            "trace must be arrival-sorted"
        );
        // same scan the multi-tenant engine historically did to place
        // the fault trigger
        let horizon = trace.ops.iter().map(|o| o.at).max().unwrap_or(0);
        MaterializedSource { trace, pos: 0, horizon }
    }
}

impl OpSource for MaterializedSource {
    fn next_op(&mut self) -> Option<TraceOp> {
        let op = self.trace.ops.get(self.pos).copied()?;
        self.pos += 1;
        Some(op)
    }
    fn horizon(&mut self) -> Nanos {
        self.horizon
    }
    fn name(&self) -> &str {
        &self.trace.name
    }
}

// --- synthetic daily generator ---------------------------------------

/// Streaming twin of [`synth::generate_scaled`]: the same single-seed
/// RNG walk (burst length → per-op write/size/offset draws → gap
/// draws, in exactly that order) carried as incremental state — one
/// pending burst counter instead of a `Vec` push loop. Byte-identical
/// to the materialized generator per (profile, seed, scale), pinned by
/// the lockstep property suite.
pub struct SynthSource {
    profile: Profile,
    seed: u64,
    footprint_limit: u64,
    volume_scale: f64,
    // live generator state (twins of `generate_scaled`'s locals)
    rng: Rng,
    zipf: Zipf,
    sizes: SizeMix,
    target_bytes: u64,
    ws: u64,
    ws_pages: u64,
    t: Nanos,
    written: u64,
    seq_w: u64,
    seq_r: u64,
    burst_left: u64,
    done: bool,
    horizon: Option<Nanos>,
}

impl SynthSource {
    /// Full-volume source (twin of [`synth::generate`]).
    pub fn new(profile: &Profile, seed: u64, footprint_limit: u64) -> SynthSource {
        SynthSource::new_scaled(profile, seed, footprint_limit, 1.0)
    }

    /// Volume-scaled source (twin of [`synth::generate_scaled`]). The
    /// setup mirrors the generator's prologue draw for draw: the two
    /// `below(ws_pages)` calls seed the sequential heads.
    pub fn new_scaled(
        profile: &Profile,
        seed: u64,
        footprint_limit: u64,
        volume_scale: f64,
    ) -> SynthSource {
        let mut rng = Rng::new(seed ^ synth::fxhash(profile.name));
        let target_bytes = ((profile.total_write_bytes as f64) * volume_scale) as u64;
        let ws_scaled = ((profile.working_set_bytes as f64) * volume_scale) as u64;
        let ws = ws_scaled.min(footprint_limit).max(1 << 20);
        let ws_pages = ws / 4096;
        let zipf = Zipf::new(ws_pages.max(2), profile.update_theta);
        let sizes = SizeMix::new(profile.size_mix);
        let seq_w = rng.below(ws_pages) * 4096;
        let seq_r = rng.below(ws_pages) * 4096;
        SynthSource {
            profile: profile.clone(),
            seed,
            footprint_limit,
            volume_scale,
            rng,
            zipf,
            sizes,
            target_bytes,
            ws,
            ws_pages,
            t: 0,
            written: 0,
            seq_w,
            seq_r,
            burst_left: 0,
            done: false,
            horizon: None,
        }
    }

    fn page_of_rank(&self, rank: u64) -> u64 {
        rank.wrapping_mul(0x9E3779B97F4A7C15) % self.ws_pages
    }
}

impl OpSource for SynthSource {
    fn next_op(&mut self) -> Option<TraceOp> {
        if self.done {
            return None;
        }
        if self.burst_left == 0 {
            // top of the materialized `while written < target` loop
            if self.written >= self.target_bytes {
                self.done = true;
                return None;
            }
            self.burst_left = (self.rng.exp(self.profile.burst_len_mean).ceil() as u64).max(1);
        }
        // one iteration of the materialized inner loop, same draw order
        let is_write = self.rng.chance(self.profile.write_ratio);
        let len = self.sizes.sample(&mut self.rng);
        let offset = if is_write {
            if self.rng.chance(self.profile.seq_prob) {
                let o = self.seq_w;
                self.seq_w = (self.seq_w + len as u64) % self.ws;
                o
            } else {
                let rank = self.zipf.sample(&mut self.rng);
                let o = self.page_of_rank(rank) * 4096;
                self.seq_w = (o + len as u64) % self.ws;
                o
            }
        } else if self.rng.chance(self.profile.seq_prob) {
            let o = self.seq_r;
            self.seq_r = (self.seq_r + len as u64) % self.ws;
            o
        } else {
            self.rng.below(self.ws_pages) * 4096
        };
        let offset = offset.min(self.footprint_limit.saturating_sub(len as u64));
        let op = TraceOp {
            at: self.t,
            kind: if is_write { OpKind::Write } else { OpKind::Read },
            offset,
            len,
        };
        self.burst_left -= 1;
        if is_write {
            self.written += len as u64;
            if self.written >= self.target_bytes {
                // the materialized loop `break`s here: this op's
                // intra-burst gap draw is skipped, the trailing idle
                // gap still runs (keeps the RNG walk aligned even
                // though no later op observes it)
                self.t += (self.rng.exp(self.profile.idle_gap_ms) * MS as f64) as Nanos;
                self.done = true;
                return Some(op);
            }
        }
        self.t += (self.rng.exp(self.profile.intra_gap_us) * US as f64) as Nanos;
        if self.burst_left == 0 {
            // idle gap to the next burst
            self.t += (self.rng.exp(self.profile.idle_gap_ms) * MS as f64) as Nanos;
        }
        Some(op)
    }

    fn horizon(&mut self) -> Nanos {
        if let Some(h) = self.horizon {
            return h;
        }
        // arrivals are non-decreasing, so the span is the last arrival:
        // replay a fresh clone of this source (O(1) memory) and cache
        let mut probe = SynthSource::new_scaled(
            &self.profile,
            self.seed,
            self.footprint_limit,
            self.volume_scale,
        );
        let mut h: Nanos = 0;
        while let Some(op) = probe.next_op() {
            h = op.at;
        }
        self.horizon = Some(h);
        h
    }

    fn name(&self) -> &str {
        self.profile.name
    }
}

// --- scenario transforms ---------------------------------------------

/// Arithmetic twin of [`scenario::sequential_fill`]: back-to-back
/// 32 KiB sequential writes, arrivals 1 ns apart, wrapping at the
/// footprint. Closed-form horizon.
pub struct SeqFillSource {
    name: String,
    n: u64,
    i: u64,
    wrap: u64,
}

impl SeqFillSource {
    /// `total_bytes` of sequential 32 KiB writes wrapping at
    /// `footprint_limit` (same arithmetic as `sequential_fill`).
    pub fn new(name: &str, total_bytes: u64, footprint_limit: u64) -> SeqFillSource {
        let n = total_bytes / BURSTY_WRITE_BYTES as u64;
        let wrap = footprint_limit.max(BURSTY_WRITE_BYTES as u64);
        let wrap = wrap - wrap % BURSTY_WRITE_BYTES as u64;
        SeqFillSource { name: name.to_string(), n, i: 0, wrap }
    }
}

impl OpSource for SeqFillSource {
    fn next_op(&mut self) -> Option<TraceOp> {
        if self.i >= self.n {
            return None;
        }
        let i = self.i;
        self.i += 1;
        Some(TraceOp {
            at: i, // 1 ns apart: ordered, but never idle
            kind: OpKind::Write,
            offset: (i * BURSTY_WRITE_BYTES as u64) % self.wrap,
            len: BURSTY_WRITE_BYTES,
        })
    }
    fn horizon(&mut self) -> Nanos {
        self.n.saturating_sub(1)
    }
    fn name(&self) -> &str {
        &self.name
    }
}

/// Streaming twin of [`scenario::to_bursty`]: the bursty rewrite is a
/// pure function of the daily stream's total write volume, so instead
/// of materializing the daily trace and rewriting it, drain the daily
/// *source* in an O(1)-memory counting pre-pass and emit the same
/// `"{name}(bursty)"` sequential fill.
pub fn bursty_source<S: OpSource>(mut daily: S, footprint_limit: u64) -> SeqFillSource {
    let name = format!("{}(bursty)", daily.name());
    let mut total = 0u64;
    while let Some(op) = daily.next_op() {
        if op.kind == OpKind::Write {
            total += op.len as u64;
        }
    }
    SeqFillSource::new(&name, total, footprint_limit)
}

/// Arithmetic twin of [`scenario::daily_streams`] (the Fig. 4
/// motivation workload): `streams` dense write streams separated by
/// `idle_gap`, rolling offset, closed-form horizon.
pub struct DailyStreamsSource {
    name: String,
    streams: u64,
    per_stream: u64,
    idle_gap: Nanos,
    wrap: u64,
    s: u64,
    i: u64,
    offset: u64,
}

impl DailyStreamsSource {
    /// Same parameters and arithmetic as `daily_streams`.
    pub fn new(
        streams: u32,
        stream_bytes: u64,
        idle_gap: Nanos,
        footprint_limit: u64,
    ) -> DailyStreamsSource {
        let per_stream = stream_bytes / BURSTY_WRITE_BYTES as u64;
        let wrap = footprint_limit.max(BURSTY_WRITE_BYTES as u64);
        let wrap = wrap - wrap % BURSTY_WRITE_BYTES as u64;
        DailyStreamsSource {
            name: format!("streams{streams}x{}", stream_bytes >> 30),
            streams: streams as u64,
            per_stream,
            idle_gap,
            wrap,
            s: 0,
            i: 0,
            offset: 0,
        }
    }
}

impl OpSource for DailyStreamsSource {
    fn next_op(&mut self) -> Option<TraceOp> {
        if self.per_stream == 0 || self.s >= self.streams {
            return None;
        }
        let stream_start = self.s * self.idle_gap + self.s * self.per_stream;
        let op = TraceOp {
            at: stream_start + self.i,
            kind: OpKind::Write,
            offset: self.offset,
            len: BURSTY_WRITE_BYTES,
        };
        self.offset = (self.offset + BURSTY_WRITE_BYTES as u64) % self.wrap;
        self.i += 1;
        if self.i == self.per_stream {
            self.i = 0;
            self.s += 1;
        }
        Some(op)
    }
    fn horizon(&mut self) -> Nanos {
        if self.per_stream == 0 || self.streams == 0 {
            return 0;
        }
        let s = self.streams - 1;
        s * self.idle_gap + s * self.per_stream + (self.per_stream - 1)
    }
    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SEC;
    use crate::trace::{profiles, scenario};

    fn assert_lockstep(streamed: Trace, materialized: Trace, label: &str) {
        assert_eq!(streamed.name, materialized.name, "{label}: name");
        assert_eq!(streamed.ops.len(), materialized.ops.len(), "{label}: op count");
        for (i, (a, b)) in streamed.ops.iter().zip(&materialized.ops).enumerate() {
            assert_eq!(a, b, "{label}: op {i} diverged");
        }
    }

    #[test]
    fn synth_source_matches_generate_scaled() {
        let p = profiles::by_name("HM_0").unwrap();
        let mut src = SynthSource::new_scaled(p, 7, 1 << 30, 0.002);
        let h = src.horizon();
        let streamed = src.collect_trace();
        let materialized = synth::generate_scaled(p, 7, 1 << 30, 0.002);
        assert!(!streamed.ops.is_empty());
        assert_eq!(h, materialized.ops.iter().map(|o| o.at).max().unwrap());
        assert_lockstep(streamed, materialized, "HM_0");
    }

    #[test]
    fn synth_source_arrivals_non_decreasing() {
        let p = profiles::by_name("PRXY_0").unwrap();
        let mut src = SynthSource::new_scaled(p, 3, 1 << 28, 0.001);
        let mut last = 0;
        while let Some(op) = src.next_op() {
            assert!(op.at >= last, "arrivals must be sorted");
            last = op.at;
        }
        assert!(src.next_op().is_none(), "fused after exhaustion");
    }

    #[test]
    fn seq_fill_source_matches_sequential_fill() {
        let mut src = SeqFillSource::new("x", 1 << 20, 256 << 10);
        assert_eq!(src.horizon(), (1 << 20) / 32768 - 1);
        let t = scenario::sequential_fill("x", 1 << 20, 256 << 10);
        assert_lockstep(src.collect_trace(), t, "seq-fill");
    }

    #[test]
    fn bursty_source_matches_to_bursty() {
        let p = profiles::by_name("USR_0").unwrap();
        let daily = synth::generate_scaled(p, 11, 1 << 28, 0.001);
        let expect = scenario::to_bursty(&daily, 1 << 26);
        let src = bursty_source(SynthSource::new_scaled(p, 11, 1 << 28, 0.001), 1 << 26);
        assert_lockstep(src.collect_trace(), expect, "bursty");
    }

    #[test]
    fn daily_streams_source_matches_daily_streams() {
        let mut src = DailyStreamsSource::new(5, 1 << 20, 600 * SEC, 1 << 30);
        let t = scenario::daily_streams(5, 1 << 20, 600 * SEC, 1 << 30);
        assert_eq!(src.horizon(), t.ops.iter().map(|o| o.at).max().unwrap());
        assert_lockstep(src.collect_trace(), t, "daily-streams");
    }

    #[test]
    fn materialized_source_round_trips() {
        let t = scenario::sequential_fill("rt", 1 << 19, 1 << 20);
        let mut src = MaterializedSource::new(t.clone());
        assert_eq!(src.horizon(), t.ops.last().unwrap().at);
        assert_lockstep(src.collect_trace(), t, "materialized");
    }

    #[test]
    fn empty_sources_have_zero_horizon() {
        let mut m = MaterializedSource::new(Trace { name: "e".into(), ops: vec![] });
        assert_eq!(m.horizon(), 0);
        assert!(m.next_op().is_none());
        let mut s = SeqFillSource::new("e", 0, 1 << 20);
        assert_eq!(s.horizon(), 0);
        assert!(s.next_op().is_none());
    }
}
