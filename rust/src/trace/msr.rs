//! MSR Cambridge block-trace parser [24].
//!
//! Native CSV format, one request per line:
//! `Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime`
//! where `Timestamp` is a Windows filetime (100 ns ticks since 1601),
//! `Type` is `Read`/`Write`, `Offset`/`Size` are bytes, and
//! `ResponseTime` is in 100 ns units (ignored — we simulate our own).
//!
//! [`load_dir`] looks for `<name>.csv` (case-insensitive) under
//! `$MSR_TRACE_DIR`; callers fall back to [`super::synth`] when absent.
//!
//! Two readers share [`parse_line`]:
//! - [`parse`] collects the whole file, stable-sorts by timestamp, and
//!   normalizes — O(trace) memory, exact.
//! - [`MsrStream`] is a constant-memory iterator: a reusable line
//!   buffer plus a bounded reorder window (a min-heap keyed by
//!   `(timestamp, input order)`). Any request displaced fewer than
//!   `window` lines from its sorted position comes out exactly where
//!   [`parse`] would have put it; larger disorder is reported as an
//!   error instead of silently emitting a time-travelling request.

use super::source::OpSource;
use super::{OpKind, Trace, TraceOp};
use crate::blk::Bio;
use crate::{Error, Result};
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;
use std::io::BufRead;
use std::path::Path;

/// Largest accepted timestamp, in 100 ns ticks: anything whose
/// nanosecond value would not fit a `u64` is a corrupt row, not a
/// plausible filetime (real MSR traces sit near 1.28e17 ticks, ~70× below
/// this). Rejecting here keeps the engines' simulated clocks far from
/// `u64::MAX`, where timestamp arithmetic would saturate or overflow.
const MAX_TIMESTAMP_TICKS: u64 = u64::MAX / 100;

/// Parse one MSR CSV line.
fn parse_line(line: &str, lineno: usize) -> Result<Option<TraceOp>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut fields = line.split(',');
    let err = |what: &str| Error::Trace(format!("line {lineno}: {what} in {line:?}"));
    let ts: u64 = fields
        .next()
        .ok_or_else(|| err("missing timestamp"))?
        .trim()
        .parse()
        .map_err(|_| err("bad timestamp"))?;
    if ts > MAX_TIMESTAMP_TICKS {
        return Err(err("absurd timestamp (exceeds u64 nanoseconds)"));
    }
    let _host = fields.next().ok_or_else(|| err("missing hostname"))?;
    let _disk = fields.next().ok_or_else(|| err("missing disk"))?;
    let kind = match fields.next().ok_or_else(|| err("missing type"))?.trim() {
        t if t.eq_ignore_ascii_case("read") => OpKind::Read,
        t if t.eq_ignore_ascii_case("write") => OpKind::Write,
        _ => return Err(err("bad type")),
    };
    let offset: u64 = fields
        .next()
        .ok_or_else(|| err("missing offset"))?
        .trim()
        .parse()
        .map_err(|_| err("bad offset"))?;
    let len: u64 = fields
        .next()
        .ok_or_else(|| err("missing size"))?
        .trim()
        .parse()
        .map_err(|_| err("bad size"))?;
    Ok(Some(TraceOp {
        at: ts * 100, // 100 ns ticks → ns; cannot overflow (ts capped above)
        kind,
        offset,
        len: len.min(u32::MAX as u64) as u32,
    }))
}

/// Parse an MSR CSV stream into a trace (timestamps normalized to 0).
pub fn parse<R: BufRead>(name: &str, reader: R) -> Result<Trace> {
    let mut ops = Vec::new();
    for (i, line) in reader.lines().enumerate() {
        let line = line?;
        if let Some(op) = parse_line(&line, i + 1)? {
            ops.push(op);
        }
    }
    if ops.is_empty() {
        return Err(Error::Trace(format!("{name}: empty trace")));
    }
    ops.sort_by_key(|o| o.at);
    let t0 = ops[0].at;
    for op in &mut ops {
        op.at -= t0;
    }
    Ok(Trace { name: name.to_string(), ops })
}

/// Default [`MsrStream`] reorder-window size (requests buffered).
pub const DEFAULT_REORDER_WINDOW: usize = 1024;

/// A buffered request waiting in the reorder window, ordered by
/// `(timestamp, input order)` — the same key `parse`'s stable sort uses.
struct Pending {
    at: u64,
    seq: u64,
    op: TraceOp,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        (self.at, self.seq) == (other.at, other.seq)
    }
}
impl Eq for Pending {}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Pending {
    fn cmp(&self, other: &Self) -> Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Constant-memory MSR reader: yields [`TraceOp`]s in timestamp order
/// (normalized so the first emitted request is at t=0) without ever
/// holding more than `window` parsed requests plus one line of text.
///
/// Peak memory is `window × sizeof(TraceOp)` + the longest line —
/// independent of trace length. [`MsrStream::peak_buffered`] reports
/// the high-water mark so tests can prove the bound held.
pub struct MsrStream<R: BufRead> {
    reader: R,
    line: String,
    lineno: usize,
    window: BinaryHeap<Reverse<Pending>>,
    cap: usize,
    seq: u64,
    t0: Option<u64>,
    last_at: Option<u64>,
    peak_buffered: usize,
    eof: bool,
    done: bool,
}

impl<R: BufRead> MsrStream<R> {
    /// Stream with the default reorder window.
    pub fn new(reader: R) -> MsrStream<R> {
        MsrStream::with_window(reader, DEFAULT_REORDER_WINDOW)
    }

    /// Stream with an explicit reorder window (clamped to ≥ 1).
    pub fn with_window(reader: R, window: usize) -> MsrStream<R> {
        let cap = window.max(1);
        MsrStream {
            reader,
            line: String::new(),
            lineno: 0,
            window: BinaryHeap::with_capacity(cap + 1),
            cap,
            seq: 0,
            t0: None,
            last_at: None,
            peak_buffered: 0,
            eof: false,
            done: false,
        }
    }

    /// High-water mark of buffered requests (≤ the window size).
    pub fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }

    /// Requests emitted so far.
    pub fn emitted(&self) -> u64 {
        self.seq - self.window.len() as u64
    }

    /// Adapt the stream into sector-granular [`Bio`]s for
    /// [`crate::sim::Simulator::run_bios`].
    pub fn bios(self, sector_bytes: u32) -> impl Iterator<Item = Result<Bio>> {
        self.map(move |r| r.map(|op| Bio::from_op(&op, sector_bytes)))
    }
}

impl<R: BufRead> Iterator for MsrStream<R> {
    type Item = Result<TraceOp>;

    fn next(&mut self) -> Option<Result<TraceOp>> {
        if self.done {
            return None;
        }
        // keep the window full: any request displaced fewer than `cap`
        // lines from its sorted position gets ordered correctly
        while !self.eof && self.window.len() < self.cap {
            self.line.clear();
            match self.reader.read_line(&mut self.line) {
                Ok(0) => self.eof = true,
                Ok(_) => {
                    self.lineno += 1;
                    match parse_line(&self.line, self.lineno) {
                        Ok(Some(op)) => {
                            let p = Pending { at: op.at, seq: self.seq, op };
                            self.seq += 1;
                            self.window.push(Reverse(p));
                            self.peak_buffered = self.peak_buffered.max(self.window.len());
                        }
                        Ok(None) => {}
                        Err(e) => {
                            self.done = true;
                            return Some(Err(e));
                        }
                    }
                }
                Err(e) => {
                    self.done = true;
                    return Some(Err(e.into()));
                }
            }
        }
        match self.window.pop() {
            Some(Reverse(p)) => {
                if let Some(last) = self.last_at {
                    if p.at < last {
                        self.done = true;
                        return Some(Err(Error::Trace(format!(
                            "trace disorder exceeds the {}-request reorder window \
                             (t={} after t={}); raise the window",
                            self.cap, p.at, last
                        ))));
                    }
                }
                self.last_at = Some(p.at);
                let t0 = *self.t0.get_or_insert(p.at);
                let mut op = p.op;
                op.at = p.at - t0;
                Some(Ok(op))
            }
            None => {
                self.done = true;
                None
            }
        }
    }
}

/// [`OpSource`] adapter over an [`MsrStream`] (§Streaming workloads):
/// the CSV replay already pulls one request at a time; this wraps its
/// fallible items so the bounded submission-queue window and the
/// engines can consume it like any other source. A parse error ends
/// the stream early and is parked for [`MsrSource::take_err`] — the
/// caller decides whether a truncated replay is acceptable.
///
/// `horizon()` is the **high-water arrival seen so far**: an MSR file
/// carries no analytic span, so exact `at_frac` fault placement on a
/// CSV replay needs a materialized pre-scan ([`parse`]) instead. The
/// `ips replay` path schedules no faults, so the limitation is
/// documentation, not a trap.
pub struct MsrSource<R: BufRead + Send> {
    name: String,
    inner: MsrStream<R>,
    err: Option<Error>,
    high_water: u64,
}

impl<R: BufRead + Send> MsrSource<R> {
    /// Wrap a stream under a workload name.
    pub fn new(name: &str, inner: MsrStream<R>) -> MsrSource<R> {
        MsrSource { name: name.to_string(), inner, err: None, high_water: 0 }
    }

    /// The error that ended the stream early, if any (one-shot).
    pub fn take_err(&mut self) -> Option<Error> {
        self.err.take()
    }
}

impl<R: BufRead + Send> OpSource for MsrSource<R> {
    fn next_op(&mut self) -> Option<TraceOp> {
        if self.err.is_some() {
            return None;
        }
        match self.inner.next() {
            Some(Ok(op)) => {
                self.high_water = self.high_water.max(op.at);
                Some(op)
            }
            Some(Err(e)) => {
                self.err = Some(e);
                None
            }
            None => None,
        }
    }
    fn horizon(&mut self) -> u64 {
        self.high_water
    }
    fn name(&self) -> &str {
        &self.name
    }
}

/// Open `path` as a constant-memory stream.
pub fn stream_path(
    path: &Path,
    window: usize,
) -> Result<MsrStream<std::io::BufReader<std::fs::File>>> {
    let f = std::fs::File::open(path)
        .map_err(|e| Error::Trace(format!("open {}: {e}", path.display())))?;
    Ok(MsrStream::with_window(std::io::BufReader::new(f), window))
}

/// Stream `<dir>/<name>.csv` (tries lower/upper case).
pub fn stream_dir(
    dir: &Path,
    name: &str,
    window: usize,
) -> Result<MsrStream<std::io::BufReader<std::fs::File>>> {
    for candidate in [
        dir.join(format!("{}.csv", name.to_ascii_lowercase())),
        dir.join(format!("{name}.csv")),
        dir.join(format!("{}.csv", name.to_ascii_uppercase())),
    ] {
        if candidate.exists() {
            return stream_path(&candidate, window);
        }
    }
    Err(Error::Trace(format!("no CSV for {name} under {}", dir.display())))
}

/// Load `<dir>/<name>.csv` (tries lower/upper case).
pub fn load_dir(dir: &Path, name: &str) -> Result<Trace> {
    for candidate in [
        dir.join(format!("{}.csv", name.to_ascii_lowercase())),
        dir.join(format!("{name}.csv")),
        dir.join(format!("{}.csv", name.to_ascii_uppercase())),
    ] {
        if candidate.exists() {
            let f = std::fs::File::open(&candidate)?;
            return parse(name, std::io::BufReader::new(f));
        }
    }
    Err(Error::Trace(format!("no CSV for {name} under {}", dir.display())))
}

/// The directory from `$MSR_TRACE_DIR`, if configured.
pub fn trace_dir() -> Option<std::path::PathBuf> {
    std::env::var_os("MSR_TRACE_DIR").map(std::path::PathBuf::from)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
128166372003061629,hm,0,Read,383496192,32768,1331
128166372016853424,hm,0,Write,2822144,4096,1790
128166372026185026,hm,0,Write,2877440,8192,981
";

    #[test]
    fn parses_and_normalizes() {
        let t = parse("hm_0", SAMPLE.as_bytes()).unwrap();
        assert_eq!(t.ops.len(), 3);
        assert_eq!(t.ops[0].at, 0, "normalized to zero");
        assert_eq!(t.ops[0].kind, OpKind::Read);
        assert_eq!(t.ops[1].kind, OpKind::Write);
        assert_eq!(t.ops[1].len, 4096);
        // 100ns ticks scaled to ns
        assert_eq!(t.ops[1].at, (128166372016853424 - 128166372003061629) * 100);
        assert_eq!(t.total_write_bytes(), 12288);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("x", "not,a,trace".as_bytes()).is_err());
        assert!(parse("x", "".as_bytes()).is_err());
        assert!(parse("x", "1,h,0,Frobnicate,0,4096,1".as_bytes()).is_err());
    }

    #[test]
    fn rejects_absurd_timestamps() {
        // a corrupt row near u64::MAX must be a parse error, not a
        // near-u64::MAX simulated clock that panics timestamp math
        let src = format!("{},h,0,Write,0,4096,1", u64::MAX);
        let e = parse("x", src.as_bytes());
        assert!(e.is_err());
        assert!(format!("{:?}", e.unwrap_err()).contains("absurd timestamp"));
        // one tick past the cap errors; the cap itself parses
        let over = format!("{},h,0,Write,0,4096,1", MAX_TIMESTAMP_TICKS + 1);
        assert!(parse("x", over.as_bytes()).is_err());
        let at_cap = format!("{},h,0,Write,0,4096,1", MAX_TIMESTAMP_TICKS);
        let t = parse("x", at_cap.as_bytes()).unwrap();
        assert_eq!(t.ops.len(), 1);
        // streaming reader shares parse_line, so it rejects too
        let r: Result<Vec<TraceOp>> = MsrStream::new(src.as_bytes()).collect();
        assert!(r.is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let src = format!("\n# comment\n{SAMPLE}\n");
        let t = parse("hm_0", src.as_bytes()).unwrap();
        assert_eq!(t.ops.len(), 3);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(load_dir(Path::new("/nonexistent-xyz"), "hm_0").is_err());
        assert!(stream_dir(Path::new("/nonexistent-xyz"), "hm_0", 8).is_err());
    }

    #[test]
    fn stream_matches_parse_on_sample() {
        let oracle = parse("hm_0", SAMPLE.as_bytes()).unwrap();
        let streamed: Vec<TraceOp> = MsrStream::new(SAMPLE.as_bytes())
            .collect::<Result<Vec<_>>>()
            .unwrap();
        assert_eq!(streamed, oracle.ops, "order and every field agree");
    }

    #[test]
    fn stream_reorders_within_window_like_parse() {
        // lines deliberately out of timestamp order (displacement 2)
        let src = "\
300,hm,0,Write,8192,4096,1
100,hm,0,Read,0,4096,1
200,hm,0,Write,4096,4096,1
250,hm,0,Write,4096,4096,1
400,hm,0,Read,8192,4096,1
";
        let oracle = parse("x", src.as_bytes()).unwrap();
        let streamed: Vec<TraceOp> = MsrStream::with_window(src.as_bytes(), 4)
            .collect::<Result<Vec<_>>>()
            .unwrap();
        assert_eq!(streamed, oracle.ops);
        assert_eq!(streamed[0].at, 0, "t0 from the earliest request, not the first line");
    }

    #[test]
    fn stream_ties_keep_input_order_like_stable_sort() {
        let src = "\
100,hm,0,Write,0,4096,1
100,hm,0,Read,4096,4096,1
100,hm,0,Write,8192,4096,1
";
        let oracle = parse("x", src.as_bytes()).unwrap();
        let streamed: Vec<TraceOp> = MsrStream::with_window(src.as_bytes(), 2)
            .collect::<Result<Vec<_>>>()
            .unwrap();
        assert_eq!(streamed, oracle.ops, "equal timestamps stay in input order");
    }

    #[test]
    fn stream_overflowing_disorder_errors_instead_of_time_travel() {
        // ts=50 is 4 lines late; a 2-wide window has already emitted 100
        let src = "\
100,hm,0,Write,0,4096,1
200,hm,0,Write,4096,4096,1
300,hm,0,Write,8192,4096,1
400,hm,0,Write,12288,4096,1
50,hm,0,Write,16384,4096,1
";
        let r: Result<Vec<TraceOp>> = MsrStream::with_window(src.as_bytes(), 2).collect();
        assert!(r.is_err(), "regression past the window is an error");
    }

    #[test]
    fn stream_surfaces_parse_errors() {
        let src = "1,h,0,Frobnicate,0,4096,1";
        let r: Result<Vec<TraceOp>> = MsrStream::new(src.as_bytes()).collect();
        assert!(r.is_err());
    }

    /// Generates MSR CSV lines on the fly — no backing buffer, so the
    /// stream's memory bound is provably independent of trace length.
    struct SynthCsv {
        remaining: u64,
        ts: u64,
        buf: Vec<u8>,
        pos: usize,
    }

    impl SynthCsv {
        fn new(lines: u64) -> SynthCsv {
            SynthCsv { remaining: lines, ts: 128166372003061629, buf: Vec::new(), pos: 0 }
        }
    }

    impl std::io::Read for SynthCsv {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.pos == self.buf.len() {
                if self.remaining == 0 {
                    return Ok(0);
                }
                self.remaining -= 1;
                // mild disorder: every other line jumps the queue by
                // more than the inter-arrival step
                let jitter = if self.remaining % 2 == 0 { 0 } else { 15 };
                let off = (self.remaining % 997) * 4096;
                self.buf.clear();
                self.buf.extend_from_slice(
                    format!("{},hm,0,Write,{off},4096,1\n", self.ts - jitter).as_bytes(),
                );
                self.pos = 0;
                self.ts += 10;
            }
            let n = (self.buf.len() - self.pos).min(out.len());
            out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn stream_memory_is_independent_of_trace_length() {
        let count = |lines: u64, window: usize| {
            let mut s =
                MsrStream::with_window(std::io::BufReader::new(SynthCsv::new(lines)), window);
            let mut n = 0u64;
            let mut last = 0;
            for op in &mut s {
                let op = op.unwrap();
                assert!(op.at >= last, "monotone emission");
                last = op.at;
                n += 1;
            }
            (n, s.peak_buffered())
        };
        let (n_small, peak_small) = count(1_000, 64);
        let (n_big, peak_big) = count(200_000, 64);
        assert_eq!(n_small, 1_000);
        assert_eq!(n_big, 200_000);
        assert!(peak_small <= 64 && peak_big <= 64);
        assert_eq!(peak_small, peak_big, "buffer high-water mark does not grow with length");
    }

    #[test]
    fn msr_source_matches_stream_and_tracks_high_water() {
        let expect: Vec<_> =
            MsrStream::new(SAMPLE.as_bytes()).collect::<Result<Vec<_>>>().unwrap();
        let mut src = MsrSource::new("sample", MsrStream::new(SAMPLE.as_bytes()));
        let mut got = Vec::new();
        while let Some(op) = src.next_op() {
            got.push(op);
        }
        assert_eq!(got, expect);
        assert!(src.take_err().is_none());
        assert_eq!(src.horizon(), expect.iter().map(|o| o.at).max().unwrap());
        assert_eq!(src.name(), "sample");
    }

    #[test]
    fn stream_bios_adapter_yields_sector_spans() {
        let bios: Vec<_> = MsrStream::new(SAMPLE.as_bytes())
            .bios(512)
            .collect::<Result<Vec<_>>>()
            .unwrap();
        assert_eq!(bios.len(), 3);
        assert_eq!(bios[0].total_bytes(512), 32768);
        assert_eq!(bios[1].segments[0].sector, 2822144 / 512);
        assert_eq!(bios[1].total_sectors(), 8);
    }
}
