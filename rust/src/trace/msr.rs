//! MSR Cambridge block-trace parser [24].
//!
//! Native CSV format, one request per line:
//! `Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime`
//! where `Timestamp` is a Windows filetime (100 ns ticks since 1601),
//! `Type` is `Read`/`Write`, `Offset`/`Size` are bytes, and
//! `ResponseTime` is in 100 ns units (ignored — we simulate our own).
//!
//! [`load_dir`] looks for `<name>.csv` (case-insensitive) under
//! `$MSR_TRACE_DIR`; callers fall back to [`super::synth`] when absent.

use super::{OpKind, Trace, TraceOp};
use crate::{Error, Result};
use std::io::BufRead;
use std::path::Path;

/// Parse one MSR CSV line.
fn parse_line(line: &str, lineno: usize) -> Result<Option<TraceOp>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut fields = line.split(',');
    let err = |what: &str| Error::Trace(format!("line {lineno}: {what} in {line:?}"));
    let ts: u64 = fields
        .next()
        .ok_or_else(|| err("missing timestamp"))?
        .trim()
        .parse()
        .map_err(|_| err("bad timestamp"))?;
    let _host = fields.next().ok_or_else(|| err("missing hostname"))?;
    let _disk = fields.next().ok_or_else(|| err("missing disk"))?;
    let kind = match fields.next().ok_or_else(|| err("missing type"))?.trim() {
        t if t.eq_ignore_ascii_case("read") => OpKind::Read,
        t if t.eq_ignore_ascii_case("write") => OpKind::Write,
        _ => return Err(err("bad type")),
    };
    let offset: u64 = fields
        .next()
        .ok_or_else(|| err("missing offset"))?
        .trim()
        .parse()
        .map_err(|_| err("bad offset"))?;
    let len: u64 = fields
        .next()
        .ok_or_else(|| err("missing size"))?
        .trim()
        .parse()
        .map_err(|_| err("bad size"))?;
    Ok(Some(TraceOp {
        at: ts.saturating_mul(100), // 100 ns ticks → ns
        kind,
        offset,
        len: len.min(u32::MAX as u64) as u32,
    }))
}

/// Parse an MSR CSV stream into a trace (timestamps normalized to 0).
pub fn parse<R: BufRead>(name: &str, reader: R) -> Result<Trace> {
    let mut ops = Vec::new();
    for (i, line) in reader.lines().enumerate() {
        let line = line?;
        if let Some(op) = parse_line(&line, i + 1)? {
            ops.push(op);
        }
    }
    if ops.is_empty() {
        return Err(Error::Trace(format!("{name}: empty trace")));
    }
    ops.sort_by_key(|o| o.at);
    let t0 = ops[0].at;
    for op in &mut ops {
        op.at -= t0;
    }
    Ok(Trace { name: name.to_string(), ops })
}

/// Load `<dir>/<name>.csv` (tries lower/upper case).
pub fn load_dir(dir: &Path, name: &str) -> Result<Trace> {
    for candidate in [
        dir.join(format!("{}.csv", name.to_ascii_lowercase())),
        dir.join(format!("{name}.csv")),
        dir.join(format!("{}.csv", name.to_ascii_uppercase())),
    ] {
        if candidate.exists() {
            let f = std::fs::File::open(&candidate)?;
            return parse(name, std::io::BufReader::new(f));
        }
    }
    Err(Error::Trace(format!("no CSV for {name} under {}", dir.display())))
}

/// The directory from `$MSR_TRACE_DIR`, if configured.
pub fn trace_dir() -> Option<std::path::PathBuf> {
    std::env::var_os("MSR_TRACE_DIR").map(std::path::PathBuf::from)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
128166372003061629,hm,0,Read,383496192,32768,1331
128166372016853424,hm,0,Write,2822144,4096,1790
128166372026185026,hm,0,Write,2877440,8192,981
";

    #[test]
    fn parses_and_normalizes() {
        let t = parse("hm_0", SAMPLE.as_bytes()).unwrap();
        assert_eq!(t.ops.len(), 3);
        assert_eq!(t.ops[0].at, 0, "normalized to zero");
        assert_eq!(t.ops[0].kind, OpKind::Read);
        assert_eq!(t.ops[1].kind, OpKind::Write);
        assert_eq!(t.ops[1].len, 4096);
        // 100ns ticks scaled to ns
        assert_eq!(t.ops[1].at, (128166372016853424 - 128166372003061629) * 100);
        assert_eq!(t.total_write_bytes(), 12288);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("x", "not,a,trace".as_bytes()).is_err());
        assert!(parse("x", "".as_bytes()).is_err());
        assert!(parse("x", "1,h,0,Frobnicate,0,4096,1".as_bytes()).is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let src = format!("\n# comment\n{SAMPLE}\n");
        let t = parse("hm_0", src.as_bytes()).unwrap();
        assert_eq!(t.ops.len(), 3);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(load_dir(Path::new("/nonexistent-xyz"), "hm_0").is_err());
    }
}
