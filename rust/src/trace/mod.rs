//! Workload machinery: trace representation, the MSR Cambridge CSV
//! parser, synthetic per-volume generators, and the paper's scenario
//! transforms (bursty / daily use).
//!
//! The paper evaluates a subset of the MSR Cambridge server traces
//! [24]. Those traces are a separate multi-GB download; when a real
//! trace directory is available (`$MSR_TRACE_DIR`), [`msr`] parses the
//! native CSV format. Otherwise [`synth`] generates statistically
//! matched traces from the published per-volume characteristics in
//! [`profiles`] — the substitution is documented in DESIGN.md.

pub mod msr;
pub mod profiles;
pub mod scenario;
pub mod source;
pub mod synth;

use crate::config::Nanos;

/// Host operation kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Host read.
    Read,
    /// Host write.
    Write,
}

/// One host request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceOp {
    /// Arrival time (ns, normalized to trace start).
    pub at: Nanos,
    /// Read or write.
    pub kind: OpKind,
    /// Byte offset on the device.
    pub offset: u64,
    /// Length in bytes.
    pub len: u32,
}

/// A whole workload trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Workload name (e.g. "HM_0").
    pub name: String,
    /// Requests sorted by arrival time.
    pub ops: Vec<TraceOp>,
}

impl Trace {
    /// Total bytes written by the trace.
    pub fn total_write_bytes(&self) -> u64 {
        self.ops
            .iter()
            .filter(|o| o.kind == OpKind::Write)
            .map(|o| o.len as u64)
            .sum()
    }

    /// Total bytes read.
    pub fn total_read_bytes(&self) -> u64 {
        self.ops
            .iter()
            .filter(|o| o.kind == OpKind::Read)
            .map(|o| o.len as u64)
            .sum()
    }

    /// Trace duration (last arrival).
    pub fn duration(&self) -> Nanos {
        self.ops.last().map(|o| o.at).unwrap_or(0)
    }

    /// Highest byte offset touched + 1.
    pub fn footprint_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.offset + o.len as u64).max().unwrap_or(0)
    }

    /// Number of write requests.
    pub fn write_ops(&self) -> usize {
        self.ops.iter().filter(|o| o.kind == OpKind::Write).count()
    }

    /// Ensure arrival-time ordering (stable).
    pub fn sort(&mut self) {
        self.ops.sort_by_key(|o| o.at);
    }

    /// Repeat the trace `n` times back to back (used by Fig. 12 to
    /// grow total write size), shifting arrivals by the duration plus
    /// `gap` between copies.
    pub fn repeat(&self, n: u32, gap: Nanos) -> Trace {
        let mut ops = Vec::with_capacity(self.ops.len() * n as usize);
        let period = self.duration() + gap;
        for i in 0..n as u64 {
            for op in &self.ops {
                ops.push(TraceOp { at: op.at + i * period, ..*op });
            }
        }
        Trace { name: format!("{}x{n}", self.name), ops }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Trace {
        Trace {
            name: "t".into(),
            ops: vec![
                TraceOp { at: 0, kind: OpKind::Write, offset: 0, len: 4096 },
                TraceOp { at: 10, kind: OpKind::Read, offset: 4096, len: 8192 },
                TraceOp { at: 20, kind: OpKind::Write, offset: 8192, len: 4096 },
            ],
        }
    }

    #[test]
    fn totals() {
        let tr = t();
        assert_eq!(tr.total_write_bytes(), 8192);
        assert_eq!(tr.total_read_bytes(), 8192);
        assert_eq!(tr.duration(), 20);
        assert_eq!(tr.footprint_bytes(), 12288);
        assert_eq!(tr.write_ops(), 2);
    }

    #[test]
    fn repeat_shifts_time() {
        let tr = t().repeat(3, 5);
        assert_eq!(tr.ops.len(), 9);
        assert_eq!(tr.ops[3].at, 25); // duration 20 + gap 5
        assert_eq!(tr.total_write_bytes(), 3 * 8192);
    }
}
