//! The paper's two evaluation scenarios (§III, §V-A).
//!
//! * **Bursty access**: "incoming writes of all workloads are
//!   configured as sequential writes with 32 KB write size. And then,
//!   arriving time is accelerated so that there is no idle time."
//!   [`to_bursty`] rewrites a trace accordingly (reads dropped, same
//!   total write volume).
//! * **Daily use**: the native trace runs as-is; idle gaps host
//!   background work, and at the end of the workload the SLC cache is
//!   force-flushed ([`Scenario::flush_at_end`]).
//!
//! [`daily_streams`] builds the Fig. 4 motivation workload: N
//! sequential write streams of S bytes with a fixed idle gap between
//! consecutive streams.

use super::{OpKind, Trace, TraceOp};
use crate::config::Nanos;

/// Which scenario a run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Sustained sequential 32 KiB writes, no idle time.
    Bursty,
    /// Native arrivals; idle-time background work; end-of-run flush.
    Daily,
}

impl Scenario {
    /// Scenario name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Bursty => "bursty",
            Scenario::Daily => "daily",
        }
    }
    /// Does the scenario run the scheme's end-of-workload flush?
    /// Both do — paper §III: "at the end of each workload, all data in
    /// the SLC cache is migrated to the TLC space, and the used blocks
    /// are erased" (Fig. 5a shows SLC2TLC fractions for bursty runs
    /// too). Only the *idle-time* background work is daily-only.
    pub fn flush_at_end(&self) -> bool {
        true
    }
    /// Parse from CLI text.
    pub fn parse(s: &str) -> crate::Result<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "bursty" => Ok(Scenario::Bursty),
            "daily" => Ok(Scenario::Daily),
            other => Err(crate::Error::config(format!(
                "unknown scenario {other:?} (want bursty|daily)"
            ))),
        }
    }
}

/// 32 KiB — the paper's bursty write size.
pub const BURSTY_WRITE_BYTES: u32 = 32 * 1024;

/// Rewrite a trace for the bursty scenario: same total write volume,
/// back-to-back sequential 32 KiB writes, zero think time (arrivals
/// 1 ns apart so ordering is preserved but no idle window ever opens).
pub fn to_bursty(trace: &Trace, footprint_limit: u64) -> Trace {
    let total = trace.total_write_bytes();
    sequential_fill(&format!("{}(bursty)", trace.name), total, footprint_limit)
}

/// Sequential 32 KiB writes totalling `total_bytes`, wrapping at
/// `footprint_limit`, with no idle time.
pub fn sequential_fill(name: &str, total_bytes: u64, footprint_limit: u64) -> Trace {
    let n = total_bytes / BURSTY_WRITE_BYTES as u64;
    let wrap = footprint_limit.max(BURSTY_WRITE_BYTES as u64);
    let ops = (0..n)
        .map(|i| TraceOp {
            at: i, // 1 ns apart: ordered, but never idle
            kind: OpKind::Write,
            offset: (i * BURSTY_WRITE_BYTES as u64) % (wrap - wrap % BURSTY_WRITE_BYTES as u64),
            len: BURSTY_WRITE_BYTES,
        })
        .collect();
    Trace { name: name.to_string(), ops }
}

/// The Fig. 4 motivation workload: `streams` sequential write streams
/// of `stream_bytes` each, separated by `idle_gap` of quiet time.
/// Within a stream, requests arrive back to back (the device is the
/// bottleneck).
pub fn daily_streams(
    streams: u32,
    stream_bytes: u64,
    idle_gap: Nanos,
    footprint_limit: u64,
) -> Trace {
    let per_stream = stream_bytes / BURSTY_WRITE_BYTES as u64;
    let wrap = footprint_limit.max(BURSTY_WRITE_BYTES as u64);
    let wrap = wrap - wrap % BURSTY_WRITE_BYTES as u64;
    let mut ops = Vec::with_capacity((streams as u64 * per_stream) as usize);
    let mut offset = 0u64;
    for s in 0..streams as u64 {
        // Streams are arrival-dense; the engine's queueing spreads them
        // out at device speed. Each stream starts after the previous
        // stream's nominal span plus the idle gap; the span estimate
        // uses request count (1 ns apart) — queueing dominates anyway.
        let stream_start = s * idle_gap + s * per_stream;
        for i in 0..per_stream {
            ops.push(TraceOp {
                at: stream_start + i,
                kind: OpKind::Write,
                offset,
                len: BURSTY_WRITE_BYTES,
            });
            offset = (offset + BURSTY_WRITE_BYTES as u64) % wrap;
        }
    }
    Trace { name: format!("streams{streams}x{}", stream_bytes >> 30), ops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MS, SEC};
    use crate::trace::profiles;
    use crate::trace::synth;

    #[test]
    fn bursty_preserves_volume_and_removes_idle() {
        let p = profiles::by_name("HM_0").unwrap();
        let daily = synth::generate_scaled(p, 1, u64::MAX, 0.01);
        let bursty = to_bursty(&daily, 1 << 30);
        // volume preserved to within one request
        let dv = daily.total_write_bytes() as i64;
        let bv = bursty.total_write_bytes() as i64;
        assert!((dv - bv).abs() < BURSTY_WRITE_BYTES as i64 + 1);
        // all 32 KiB writes, arrivals dense
        assert!(bursty.ops.iter().all(|o| o.len == BURSTY_WRITE_BYTES));
        assert!(bursty.ops.iter().all(|o| o.kind == OpKind::Write));
        let max_gap = bursty.ops.windows(2).map(|w| w[1].at - w[0].at).max().unwrap_or(0);
        assert!(max_gap <= 1, "no idle time");
    }

    #[test]
    fn bursty_is_sequential_then_wraps() {
        let t = sequential_fill("x", 1 << 20, 256 << 10);
        assert_eq!(t.ops[0].offset, 0);
        assert_eq!(t.ops[1].offset, 32 << 10);
        // wraps within the footprint
        assert!(t.footprint_bytes() <= 256 << 10);
    }

    #[test]
    fn daily_streams_structure() {
        let t = daily_streams(5, 1 << 20, 600 * SEC, 1 << 30);
        assert_eq!(t.ops.len(), 5 * 32);
        // the gap between stream s and s+1 first ops spans the idle gap
        let per = 32u64;
        let gap = t.ops[per as usize].at - t.ops[per as usize - 1].at;
        assert!(gap >= 600 * SEC - MS, "idle gap present: {gap}");
        assert_eq!(t.total_write_bytes(), 5 << 20);
    }

    #[test]
    fn scenario_parse() {
        assert_eq!(Scenario::parse("bursty").unwrap(), Scenario::Bursty);
        assert_eq!(Scenario::parse("DAILY").unwrap(), Scenario::Daily);
        assert!(Scenario::parse("x").is_err());
        assert!(Scenario::Daily.flush_at_end());
        assert!(Scenario::Bursty.flush_at_end(), "flush applies to both (§III)");
    }
}
