//! Synthetic trace generation from statistical profiles.
//!
//! Generates a burst-structured request stream: bursts of
//! `burst_len_mean` requests with exponential intra-burst gaps,
//! separated by exponential idle gaps (`idle_gap_ms`). Write offsets
//! either continue a sequential run (`seq_prob`) or jump to a
//! Zipf-distributed 4 KiB-aligned position in the working set (update
//! locality). All randomness flows from one seed — traces are exactly
//! reproducible.

use super::profiles::Profile;
use super::{OpKind, Trace, TraceOp};
use crate::config::{Nanos, MS, US};
use crate::util::rng::{Rng, Zipf};

/// Generate a daily-use trace for `profile`, targeting its
/// `total_write_bytes`. `footprint_limit` bounds offsets (the logical
/// device size); pass `u64::MAX` for unbounded.
pub fn generate(profile: &Profile, seed: u64, footprint_limit: u64) -> Trace {
    generate_scaled(profile, seed, footprint_limit, 1.0)
}

/// Like [`generate`] but scaling the write volume by `volume_scale`
/// (used by scaled-down benches and Fig. 12 sweeps).
pub fn generate_scaled(
    profile: &Profile,
    seed: u64,
    footprint_limit: u64,
    volume_scale: f64,
) -> Trace {
    let mut rng = Rng::new(seed ^ fxhash(profile.name));
    let target_bytes = ((profile.total_write_bytes as f64) * volume_scale) as u64;
    // The working set scales with the volume so the overwrite fraction
    // (update locality — what drives invalidation and WA) is invariant
    // under scaling.
    let ws_scaled = ((profile.working_set_bytes as f64) * volume_scale) as u64;
    let ws = ws_scaled.min(footprint_limit).max(1 << 20);
    let ws_pages = ws / 4096;
    let zipf = Zipf::new(ws_pages.max(2), profile.update_theta);
    // scatter the hot ranks around the working set deterministically
    let page_of_rank = |rank: u64| -> u64 { rank.wrapping_mul(0x9E3779B97F4A7C15) % ws_pages };

    let mut ops = Vec::new();
    let mut t: Nanos = 0;
    let mut written = 0u64;
    let mut seq_w: u64 = rng.below(ws_pages) * 4096; // sequential write head
    let mut seq_r: u64 = rng.below(ws_pages) * 4096;
    while written < target_bytes {
        // one burst
        let burst_len = (rng.exp(profile.burst_len_mean).ceil() as u64).max(1);
        for _ in 0..burst_len {
            let is_write = rng.chance(profile.write_ratio);
            let len = {
                let weights: Vec<f64> = profile.size_mix.iter().map(|(_, w)| *w).collect();
                profile.size_mix[rng.weighted(&weights)].0
            };
            let offset = if is_write {
                if rng.chance(profile.seq_prob) {
                    let o = seq_w;
                    seq_w = (seq_w + len as u64) % ws;
                    o
                } else {
                    let rank = zipf.sample(&mut rng);
                    let o = page_of_rank(rank) * 4096;
                    seq_w = (o + len as u64) % ws;
                    o
                }
            } else if rng.chance(profile.seq_prob) {
                let o = seq_r;
                seq_r = (seq_r + len as u64) % ws;
                o
            } else {
                rng.below(ws_pages) * 4096
            };
            let offset = offset.min(footprint_limit.saturating_sub(len as u64));
            ops.push(TraceOp {
                at: t,
                kind: if is_write { OpKind::Write } else { OpKind::Read },
                offset,
                len,
            });
            if is_write {
                written += len as u64;
                if written >= target_bytes {
                    break;
                }
            }
            t += (rng.exp(profile.intra_gap_us) * US as f64) as Nanos;
        }
        // idle gap to the next burst
        t += (rng.exp(profile.idle_gap_ms) * MS as f64) as Nanos;
    }
    let mut trace = Trace { name: profile.name.to_string(), ops };
    trace.sort();
    trace
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::profiles;

    #[test]
    fn hits_write_volume_target() {
        let p = profiles::by_name("HM_0").unwrap();
        let t = generate_scaled(p, 1, u64::MAX, 0.01); // ~60 MiB
        let target = (p.total_write_bytes as f64 * 0.01) as u64;
        let got = t.total_write_bytes();
        assert!(got >= target, "target reached");
        assert!(got < target + (1 << 20), "no gross overshoot");
    }

    #[test]
    fn deterministic_per_seed() {
        let p = profiles::by_name("PRXY_0").unwrap();
        let a = generate_scaled(p, 7, u64::MAX, 0.005);
        let b = generate_scaled(p, 7, u64::MAX, 0.005);
        assert_eq!(a.ops, b.ops);
        let c = generate_scaled(p, 8, u64::MAX, 0.005);
        assert_ne!(a.ops, c.ops);
    }

    #[test]
    fn respects_footprint_limit() {
        let p = profiles::by_name("USR_0").unwrap();
        let limit = 64 << 20;
        let t = generate_scaled(p, 3, limit, 0.002);
        assert!(t.footprint_bytes() <= limit);
    }

    #[test]
    fn write_ratio_roughly_matches() {
        let p = profiles::by_name("PRXY_0").unwrap(); // 0.97 writes
        let t = generate_scaled(p, 5, u64::MAX, 0.01);
        let w = t.write_ops() as f64 / t.ops.len() as f64;
        assert!(w > 0.90, "w={w}");
        let p = profiles::by_name("HM_1").unwrap(); // 0.05 writes
        let t = generate_scaled(p, 5, u64::MAX, 0.05);
        let w = t.write_ops() as f64 / t.ops.len() as f64;
        assert!(w < 0.20, "w={w}");
    }

    #[test]
    fn update_locality_creates_overwrites() {
        // PRXY_0 has a hot 512 MiB working set: a trace writing ~1% of
        // volume must overwrite pages (distinct 4K pages < total pages).
        let p = profiles::by_name("PRXY_0").unwrap();
        let t = generate_scaled(p, 11, u64::MAX, 0.02);
        use std::collections::HashSet;
        let mut pages: HashSet<u64> = HashSet::new();
        let mut total = 0u64;
        for op in t.ops.iter().filter(|o| o.kind == OpKind::Write) {
            let first = op.offset / 4096;
            let n = (op.len as u64).div_ceil(4096);
            for i in 0..n {
                pages.insert(first + i);
                total += 1;
            }
        }
        assert!(
            (pages.len() as u64) < total * 9 / 10,
            "hot set causes repeats: {} distinct of {total}",
            pages.len()
        );
    }

    #[test]
    fn idle_gaps_present_in_daily_traces() {
        let p = profiles::by_name("HM_0").unwrap(); // 400 ms gaps
        let t = generate_scaled(p, 13, u64::MAX, 0.01);
        let mut big_gaps = 0;
        for w in t.ops.windows(2) {
            if w[1].at - w[0].at > 100 * MS {
                big_gaps += 1;
            }
        }
        assert!(big_gaps > 5, "bursty structure with real idle windows");
    }
}
