//! Synthetic trace generation from statistical profiles.
//!
//! Generates a burst-structured request stream: bursts of
//! `burst_len_mean` requests with exponential intra-burst gaps,
//! separated by exponential idle gaps (`idle_gap_ms`). Write offsets
//! either continue a sequential run (`seq_prob`) or jump to a
//! Zipf-distributed 4 KiB-aligned position in the working set (update
//! locality). All randomness flows from one seed — traces are exactly
//! reproducible.

use super::profiles::Profile;
use super::{OpKind, Trace, TraceOp};
use crate::blk::{Bio, Segment};
use crate::config::{Nanos, MS, US};
use crate::util::rng::{Rng, Zipf};

/// Request-size sampler precomputed once per trace/source.
///
/// The generator's inner loop used to rebuild a `weights: Vec<f64>`
/// from `profile.size_mix` for *every request* just to call
/// [`Rng::weighted`]. This table hoists the weights (they are
/// `'static`) and their sum out of the loop, making the draw
/// allocation-free — which is also what lets the streaming
/// [`super::source::SynthSource`] emit ops with zero steady-state
/// allocations (pinned by `tests/alloc_synth_steady.rs`).
///
/// Sampling deliberately replicates `Rng::weighted`'s subtraction scan
/// (same operations, same float order) rather than comparing against a
/// true cumulative-sum table: prefix sums round differently, and the
/// draw must stay bit-identical to the historical per-op path.
#[derive(Clone, Debug)]
pub struct SizeMix {
    mix: &'static [(u32, f64)],
    total: f64,
}

impl SizeMix {
    /// Build the table from a profile's size mix.
    pub fn new(mix: &'static [(u32, f64)]) -> SizeMix {
        SizeMix { mix, total: mix.iter().map(|(_, w)| *w).sum() }
    }

    /// Draw one request size. Consumes exactly one `rng.f64()`, like
    /// the `Rng::weighted` call it replaces.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let mut x = rng.f64() * self.total;
        for &(len, w) in self.mix {
            if x < w {
                return len;
            }
            x -= w;
        }
        self.mix[self.mix.len() - 1].0
    }
}

/// Generate a daily-use trace for `profile`, targeting its
/// `total_write_bytes`. `footprint_limit` bounds offsets (the logical
/// device size); pass `u64::MAX` for unbounded.
pub fn generate(profile: &Profile, seed: u64, footprint_limit: u64) -> Trace {
    generate_scaled(profile, seed, footprint_limit, 1.0)
}

/// Like [`generate`] but scaling the write volume by `volume_scale`
/// (used by scaled-down benches and Fig. 12 sweeps).
pub fn generate_scaled(
    profile: &Profile,
    seed: u64,
    footprint_limit: u64,
    volume_scale: f64,
) -> Trace {
    let mut rng = Rng::new(seed ^ fxhash(profile.name));
    let target_bytes = ((profile.total_write_bytes as f64) * volume_scale) as u64;
    // The working set scales with the volume so the overwrite fraction
    // (update locality — what drives invalidation and WA) is invariant
    // under scaling.
    let ws_scaled = ((profile.working_set_bytes as f64) * volume_scale) as u64;
    let ws = ws_scaled.min(footprint_limit).max(1 << 20);
    let ws_pages = ws / 4096;
    let zipf = Zipf::new(ws_pages.max(2), profile.update_theta);
    let sizes = SizeMix::new(profile.size_mix);
    // scatter the hot ranks around the working set deterministically
    let page_of_rank = |rank: u64| -> u64 { rank.wrapping_mul(0x9E3779B97F4A7C15) % ws_pages };

    let mut ops = Vec::new();
    let mut t: Nanos = 0;
    let mut written = 0u64;
    let mut seq_w: u64 = rng.below(ws_pages) * 4096; // sequential write head
    let mut seq_r: u64 = rng.below(ws_pages) * 4096;
    while written < target_bytes {
        // one burst
        let burst_len = (rng.exp(profile.burst_len_mean).ceil() as u64).max(1);
        for _ in 0..burst_len {
            let is_write = rng.chance(profile.write_ratio);
            let len = sizes.sample(&mut rng);
            let offset = if is_write {
                if rng.chance(profile.seq_prob) {
                    let o = seq_w;
                    seq_w = (seq_w + len as u64) % ws;
                    o
                } else {
                    let rank = zipf.sample(&mut rng);
                    let o = page_of_rank(rank) * 4096;
                    seq_w = (o + len as u64) % ws;
                    o
                }
            } else if rng.chance(profile.seq_prob) {
                let o = seq_r;
                seq_r = (seq_r + len as u64) % ws;
                o
            } else {
                rng.below(ws_pages) * 4096
            };
            let offset = offset.min(footprint_limit.saturating_sub(len as u64));
            ops.push(TraceOp {
                at: t,
                kind: if is_write { OpKind::Write } else { OpKind::Read },
                offset,
                len,
            });
            if is_write {
                written += len as u64;
                if written >= target_bytes {
                    break;
                }
            }
            t += (rng.exp(profile.intra_gap_us) * US as f64) as Nanos;
        }
        // idle gap to the next burst
        t += (rng.exp(profile.idle_gap_ms) * MS as f64) as Nanos;
    }
    let mut trace = Trace { name: profile.name.to_string(), ops };
    trace.sort();
    trace
}

/// Zipf-skewed sector-granular bios for the block front end: hot
/// sectors are rewritten at sub-page sizes (512 B – 64 KiB), so the
/// stream exercises the read-modify-write path. ~70% writes, a few
/// FUA. Deterministic in `(name, seed)` via the same per-name hashing
/// as [`generate`].
pub fn bio_zipf(name: &str, seed: u64, footprint: u64, sector_bytes: u32, count: usize) -> Vec<Bio> {
    let mut rng = Rng::new(seed ^ fxhash(name));
    let sectors = (footprint / sector_bytes as u64).max(16);
    let zipf = Zipf::new(sectors, 0.99);
    // scatter ranks so the hot set isn't one contiguous run
    let scatter = |rank: u64| rank.wrapping_mul(0x9E3779B97F4A7C15) % sectors;
    let sizes: [u32; 6] = [1, 2, 8, 16, 64, 128]; // sectors
    let weights = [0.25, 0.20, 0.30, 0.10, 0.10, 0.05];
    let mut at: Nanos = 0;
    (0..count)
        .map(|_| {
            let sector = scatter(zipf.sample(&mut rng));
            let n = sizes[rng.weighted(&weights)]
                .min((sectors - sector).min(u32::MAX as u64) as u32)
                .max(1);
            let seg = Segment { sector, n_sectors: n };
            let bio = if rng.chance(0.7) {
                Bio::write(at, vec![seg], rng.chance(0.05))
            } else {
                Bio::read(at, vec![seg])
            };
            at += (rng.exp(50.0) * US as f64) as Nanos;
            bio
        })
        .collect()
}

/// Object-store bios: large PUTs as one scatter-gather write over
/// several 64 KiB extents allocated from a log head (occasionally
/// recycling an old extent), small 4 KiB GETs at object boundaries,
/// and explicit flush bios at commit points.
pub fn bio_object_store(
    name: &str,
    seed: u64,
    footprint: u64,
    sector_bytes: u32,
    count: usize,
) -> Vec<Bio> {
    let mut rng = Rng::new(seed ^ fxhash(name));
    let sb = sector_bytes as u64;
    let sectors = (footprint / sb).max((2 << 20) / sb);
    let extent = ((64 << 10) / sb).max(1) as u32; // 64 KiB in sectors
    let extents = (sectors / extent as u64).max(1);
    let mut at: Nanos = 0;
    let mut head: u64 = 0;
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        if rng.chance(0.3) {
            // PUT: 2–8 extents in one scatter-gather write
            let n_seg = 2 + rng.below(7) as usize;
            let mut segs = Vec::with_capacity(n_seg);
            for _ in 0..n_seg {
                let sector = if rng.chance(0.8) {
                    let s = head * extent as u64;
                    head = (head + 1) % extents;
                    s
                } else {
                    rng.below(extents) * extent as u64
                };
                segs.push(Segment { sector, n_sectors: extent });
            }
            out.push(Bio::write(at, segs, false));
            // commit point: metadata must be durable before the ack
            if rng.chance(0.25) && out.len() < count {
                out.push(Bio::flush(at + 1));
            }
        } else {
            // GET: a small read at an extent boundary
            let sector = rng.below(extents) * extent as u64;
            let n = ((4 << 10) / sb).max(1) as u32;
            out.push(Bio::read(at, vec![Segment { sector, n_sectors: n }]));
        }
        at += (rng.exp(200.0) * US as f64) as Nanos;
    }
    out
}

/// Burst-storm bios: tight volleys of page-multiple writes (a tenth of
/// them FUA) separated by long idle lulls — the §III burst arrival
/// pattern expressed at bio granularity.
pub fn bio_burst_storm(
    name: &str,
    seed: u64,
    footprint: u64,
    sector_bytes: u32,
    count: usize,
) -> Vec<Bio> {
    let mut rng = Rng::new(seed ^ fxhash(name));
    let sb = sector_bytes as u64;
    let sectors = (footprint / sb).max((1 << 20) / sb);
    let page = ((4 << 10) / sb).max(1) as u32; // 4 KiB in sectors
    let pages = (sectors / page as u64).max(1);
    let mut at: Nanos = 0;
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let volley = (rng.exp(32.0).ceil() as u64).max(1);
        for _ in 0..volley {
            if out.len() >= count {
                break;
            }
            let sector = rng.below(pages) * page as u64;
            let n = (page * (1 + rng.below(16) as u32))
                .min((sectors - sector).min(u32::MAX as u64) as u32)
                .max(1);
            out.push(Bio::write(at, vec![Segment { sector, n_sectors: n }], rng.chance(0.1)));
            at += (rng.exp(5.0) * US as f64) as Nanos;
        }
        // the lull before the next storm
        at += (rng.exp(50.0) * MS as f64) as Nanos;
    }
    out
}

/// FNV-1a of a workload name — folded into the seed so every named
/// stream draws from an independent deterministic sequence. Shared
/// with the streaming [`super::source::SynthSource`], which must mix
/// its seed identically to stay byte-equal to [`generate_scaled`].
pub(crate) fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::profiles;

    #[test]
    fn hits_write_volume_target() {
        let p = profiles::by_name("HM_0").unwrap();
        let t = generate_scaled(p, 1, u64::MAX, 0.01); // ~60 MiB
        let target = (p.total_write_bytes as f64 * 0.01) as u64;
        let got = t.total_write_bytes();
        assert!(got >= target, "target reached");
        assert!(got < target + (1 << 20), "no gross overshoot");
    }

    #[test]
    fn deterministic_per_seed() {
        let p = profiles::by_name("PRXY_0").unwrap();
        let a = generate_scaled(p, 7, u64::MAX, 0.005);
        let b = generate_scaled(p, 7, u64::MAX, 0.005);
        assert_eq!(a.ops, b.ops);
        let c = generate_scaled(p, 8, u64::MAX, 0.005);
        assert_ne!(a.ops, c.ops);
    }

    #[test]
    fn respects_footprint_limit() {
        let p = profiles::by_name("USR_0").unwrap();
        let limit = 64 << 20;
        let t = generate_scaled(p, 3, limit, 0.002);
        assert!(t.footprint_bytes() <= limit);
    }

    #[test]
    fn write_ratio_roughly_matches() {
        let p = profiles::by_name("PRXY_0").unwrap(); // 0.97 writes
        let t = generate_scaled(p, 5, u64::MAX, 0.01);
        let w = t.write_ops() as f64 / t.ops.len() as f64;
        assert!(w > 0.90, "w={w}");
        let p = profiles::by_name("HM_1").unwrap(); // 0.05 writes
        let t = generate_scaled(p, 5, u64::MAX, 0.05);
        let w = t.write_ops() as f64 / t.ops.len() as f64;
        assert!(w < 0.20, "w={w}");
    }

    #[test]
    fn update_locality_creates_overwrites() {
        // PRXY_0 has a hot 512 MiB working set: a trace writing ~1% of
        // volume must overwrite pages (distinct 4K pages < total pages).
        let p = profiles::by_name("PRXY_0").unwrap();
        let t = generate_scaled(p, 11, u64::MAX, 0.02);
        use std::collections::HashSet;
        let mut pages: HashSet<u64> = HashSet::new();
        let mut total = 0u64;
        for op in t.ops.iter().filter(|o| o.kind == OpKind::Write) {
            let first = op.offset / 4096;
            let n = (op.len as u64).div_ceil(4096);
            for i in 0..n {
                pages.insert(first + i);
                total += 1;
            }
        }
        assert!(
            (pages.len() as u64) < total * 9 / 10,
            "hot set causes repeats: {} distinct of {total}",
            pages.len()
        );
    }

    #[test]
    fn bio_generators_are_deterministic_per_seed() {
        let fp = 256 << 20;
        for gen in [bio_zipf, bio_object_store, bio_burst_storm] {
            let a = gen("t", 7, fp, 512, 500);
            let b = gen("t", 7, fp, 512, 500);
            assert_eq!(a, b);
            let c = gen("t", 8, fp, 512, 500);
            assert_ne!(a, c, "seed matters");
            let d = gen("u", 7, fp, 512, 500);
            assert_ne!(a, d, "name matters");
            assert_eq!(a.len(), 500);
            // arrivals are non-decreasing (the engines assume it)
            for w in a.windows(2) {
                assert!(w[1].at >= w[0].at);
            }
        }
    }

    #[test]
    fn bio_zipf_produces_subpage_writes_and_skew() {
        use crate::blk::BioKind;
        let bios = bio_zipf("z", 3, 256 << 20, 512, 2000);
        let subpage = bios
            .iter()
            .filter(|b| b.kind == BioKind::Write && b.total_bytes(512) % 4096 != 0)
            .count();
        assert!(subpage > 100, "sub-page writes drive RMW: {subpage}");
        // skew: the most popular sector recurs
        use std::collections::HashMap;
        let mut hist: HashMap<u64, u32> = HashMap::new();
        for b in &bios {
            *hist.entry(b.segments[0].sector).or_default() += 1;
        }
        assert!(hist.values().copied().max().unwrap() > 20, "hot sector exists");
    }

    #[test]
    fn bio_object_store_mixes_sg_puts_gets_and_flushes() {
        use crate::blk::BioKind;
        let bios = bio_object_store("os", 5, 1 << 30, 512, 1000);
        let sg_puts =
            bios.iter().filter(|b| b.kind == BioKind::Write && b.segments.len() > 1).count();
        let gets = bios.iter().filter(|b| b.kind == BioKind::Read).count();
        let flushes = bios.iter().filter(|b| b.kind == BioKind::Flush).count();
        assert!(sg_puts > 50, "scatter-gather PUTs: {sg_puts}");
        assert!(gets > 200, "GETs: {gets}");
        assert!(flushes > 10, "commit flushes: {flushes}");
        // PUT extents are 64 KiB each
        let put = bios.iter().find(|b| b.segments.len() > 1).unwrap();
        assert!(put.segments.iter().all(|s| s.n_sectors as u64 * 512 == 64 << 10));
    }

    #[test]
    fn bio_burst_storm_has_volleys_fua_and_lulls() {
        use crate::blk::BioKind;
        let bios = bio_burst_storm("bs", 9, 256 << 20, 512, 2000);
        assert!(bios.iter().all(|b| b.kind == BioKind::Write));
        let fua = bios.iter().filter(|b| b.fua).count();
        assert!(fua > 50, "FUA fraction present: {fua}");
        let mut lulls = 0;
        for w in bios.windows(2) {
            if w[1].at - w[0].at > 10 * MS {
                lulls += 1;
            }
        }
        assert!(lulls > 5, "idle lulls between storms: {lulls}");
        // writes are page-multiple: no RMW in this stream
        assert!(bios.iter().all(|b| b.total_bytes(512) % 4096 == 0));
    }

    #[test]
    fn idle_gaps_present_in_daily_traces() {
        let p = profiles::by_name("HM_0").unwrap(); // 400 ms gaps
        let t = generate_scaled(p, 13, u64::MAX, 0.01);
        let mut big_gaps = 0;
        for w in t.ops.windows(2) {
            if w[1].at - w[0].at > 100 * MS {
                big_gaps += 1;
            }
        }
        assert!(big_gaps > 5, "bursty structure with real idle windows");
    }
}
