//! Per-volume statistical profiles for the MSR Cambridge subset the
//! paper evaluates (11 workloads, Fig. 5/10/11).
//!
//! The real traces are a separate multi-GB download; these profiles
//! capture the axes the evaluation actually depends on — write volume
//! vs SLC-cache size, request-size mix, sequentiality, update locality
//! (how much data is invalidated before reclamation), and idle-gap
//! structure (whether background work can finish between bursts) —
//! from the published per-volume characteristics (Narayanan et al.
//! [24]). Notable paper-anchored facts encoded here:
//!
//! * `HM_1` and `PROJ_4` have small total write volumes (§V-B1: they
//!   stay inside the 4 GB cache, so IPS matches baseline latency);
//! * `STG_0` and `WDEV_0` have *short idle gaps* (§V-B2: IPS/agc
//!   cannot finish reprogramming before the next burst arrives);
//! * `PRXY_0` is update-intensive with a small working set;
//! * `PROJ_0` is the heavy sequential writer.

/// Statistical description of one workload volume.
#[derive(Clone, Debug)]
pub struct Profile {
    /// Volume name as the paper spells it.
    pub name: &'static str,
    /// Fraction of requests that are writes.
    pub write_ratio: f64,
    /// Daily-use total write volume in bytes.
    pub total_write_bytes: u64,
    /// Request-size mix: (bytes, weight).
    pub size_mix: &'static [(u32, f64)],
    /// Probability a write continues the current sequential run.
    pub seq_prob: f64,
    /// Working-set (update footprint) in bytes.
    pub working_set_bytes: u64,
    /// Zipf skew of update offsets (0 = uniform, →1 = very hot).
    pub update_theta: f64,
    /// Mean requests per burst.
    pub burst_len_mean: f64,
    /// Mean gap between requests inside a burst (µs).
    pub intra_gap_us: f64,
    /// Mean idle gap between bursts (ms) — the window background work
    /// gets in the daily scenario.
    pub idle_gap_ms: f64,
}

const KIB: u64 = 1024;
const MIB: u64 = 1024 * KIB;
const GIB: u64 = 1024 * MIB;

const SZ_SMALL: &[(u32, f64)] = &[(4096, 0.6), (8192, 0.25), (16384, 0.1), (32768, 0.05)];
const SZ_MIXED: &[(u32, f64)] =
    &[(4096, 0.35), (8192, 0.25), (16384, 0.2), (32768, 0.15), (65536, 0.05)];
const SZ_LARGE: &[(u32, f64)] =
    &[(8192, 0.15), (16384, 0.2), (32768, 0.3), (65536, 0.35)];

/// The paper's 11-workload subset.
pub const ALL: &[Profile] = &[
    Profile {
        name: "HM_0",
        write_ratio: 0.64,
        total_write_bytes: 20 * GIB,
        size_mix: SZ_SMALL,
        seq_prob: 0.35,
        working_set_bytes: 2 * GIB,
        update_theta: 0.7,
        burst_len_mean: 48.0,
        intra_gap_us: 250.0,
        idle_gap_ms: 400.0,
    },
    Profile {
        name: "HM_1",
        write_ratio: 0.05,
        total_write_bytes: 640 * MIB, // small write volume: stays in cache
        size_mix: SZ_SMALL,
        seq_prob: 0.3,
        working_set_bytes: GIB,
        update_theta: 0.6,
        burst_len_mean: 32.0,
        intra_gap_us: 300.0,
        idle_gap_ms: 700.0,
    },
    Profile {
        name: "MDS_0",
        write_ratio: 0.88,
        total_write_bytes: 8 * GIB,
        size_mix: SZ_MIXED,
        seq_prob: 0.45,
        working_set_bytes: 3 * GIB,
        update_theta: 0.55,
        burst_len_mean: 40.0,
        intra_gap_us: 280.0,
        idle_gap_ms: 500.0,
    },
    Profile {
        name: "PRN_0",
        write_ratio: 0.80,
        total_write_bytes: 14 * GIB,
        size_mix: SZ_MIXED,
        seq_prob: 0.4,
        working_set_bytes: 4 * GIB,
        update_theta: 0.6,
        burst_len_mean: 56.0,
        intra_gap_us: 220.0,
        idle_gap_ms: 350.0,
    },
    Profile {
        name: "PROJ_0",
        write_ratio: 0.87,
        total_write_bytes: 20 * GIB, // the heavy sequential writer
        size_mix: SZ_LARGE,
        seq_prob: 0.7,
        working_set_bytes: 8 * GIB,
        update_theta: 0.4,
        burst_len_mean: 96.0,
        intra_gap_us: 180.0,
        idle_gap_ms: 450.0,
    },
    Profile {
        name: "PROJ_4",
        write_ratio: 0.06,
        total_write_bytes: 512 * MIB, // §V-B1: small total write size
        size_mix: SZ_SMALL,
        seq_prob: 0.35,
        working_set_bytes: GIB,
        update_theta: 0.5,
        burst_len_mean: 24.0,
        intra_gap_us: 350.0,
        idle_gap_ms: 800.0,
    },
    Profile {
        name: "PRXY_0",
        write_ratio: 0.97,
        total_write_bytes: 12 * GIB,
        size_mix: SZ_SMALL,
        seq_prob: 0.2,
        working_set_bytes: 2 * GIB, // hot, update-intensive
        update_theta: 0.85,
        burst_len_mean: 64.0,
        intra_gap_us: 150.0,
        idle_gap_ms: 300.0,
    },
    Profile {
        name: "SRC1_2",
        write_ratio: 0.75,
        total_write_bytes: 15 * GIB,
        size_mix: SZ_LARGE,
        seq_prob: 0.55,
        working_set_bytes: 5 * GIB,
        update_theta: 0.5,
        burst_len_mean: 72.0,
        intra_gap_us: 200.0,
        idle_gap_ms: 420.0,
    },
    Profile {
        name: "STG_0",
        write_ratio: 0.85,
        total_write_bytes: 10 * GIB,
        size_mix: SZ_MIXED,
        seq_prob: 0.5,
        working_set_bytes: 4 * GIB,
        update_theta: 0.45,
        burst_len_mean: 80.0,
        intra_gap_us: 200.0,
        idle_gap_ms: 150.0, // §V-B2: short idle gaps — IPS/agc exception
    },
    Profile {
        name: "USR_0",
        write_ratio: 0.60,
        total_write_bytes: 10 * GIB,
        size_mix: SZ_MIXED,
        seq_prob: 0.4,
        working_set_bytes: 3 * GIB,
        update_theta: 0.65,
        burst_len_mean: 44.0,
        intra_gap_us: 260.0,
        idle_gap_ms: 550.0,
    },
    Profile {
        name: "WDEV_0",
        write_ratio: 0.80,
        total_write_bytes: 7 * GIB,
        size_mix: SZ_SMALL,
        seq_prob: 0.3,
        working_set_bytes: 2 * GIB,
        update_theta: 0.6,
        burst_len_mean: 88.0,
        intra_gap_us: 180.0,
        idle_gap_ms: 130.0, // §V-B2: short idle gaps — IPS/agc exception
    },
];

/// Find a profile by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<&'static Profile> {
    ALL.iter().find(|p| p.name.eq_ignore_ascii_case(name))
}

/// All workload names in presentation order.
pub fn names() -> Vec<&'static str> {
    ALL.iter().map(|p| p.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eleven_workloads() {
        assert_eq!(ALL.len(), 11, "paper Fig. 5 evaluates 11 workloads");
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("hm_0").unwrap().name, "HM_0");
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn profiles_sane() {
        for p in ALL {
            assert!((0.0..=1.0).contains(&p.write_ratio), "{}", p.name);
            assert!(p.total_write_bytes > 0);
            assert!(!p.size_mix.is_empty());
            let total_w: f64 = p.size_mix.iter().map(|(_, w)| *w).sum();
            assert!((total_w - 1.0).abs() < 1e-6, "{} size mix sums to 1", p.name);
            assert!(p.working_set_bytes >= 256 * MIB);
            assert!((0.0..1.0).contains(&p.update_theta));
        }
    }

    #[test]
    fn paper_anchors_hold() {
        // HM_1/PROJ_4 small write volumes (fit the 4 GB cache)
        assert!(by_name("HM_1").unwrap().total_write_bytes < 4 * GIB);
        assert!(by_name("PROJ_4").unwrap().total_write_bytes < 4 * GIB);
        // STG_0/WDEV_0 short idle gaps
        assert!(by_name("STG_0").unwrap().idle_gap_ms < 200.0);
        assert!(by_name("WDEV_0").unwrap().idle_gap_ms < 200.0);
        // most others have roomy gaps
        assert!(by_name("HM_0").unwrap().idle_gap_ms > 200.0);
    }
}
