//! Reliability model of the reprogram operation (paper §IV-D1).
//!
//! The paper asserts IPS is safe because it obeys the device study's
//! restrictions [7]: SLC first (wide margins), at most two reprograms
//! per word line, sequential reprogramming within a two-layer window.
//! This module *checks* that claim for every run:
//!
//! * [`audit::ReliabilityAudit`] — structural audit over the flash
//!   array: reprogram-count budgets and window/ordering restrictions
//!   (they are also enforced inline by [`crate::flash::cell`]; the
//!   audit re-derives them independently).
//! * [`bridge::RberBridge`] — samples reprogram batches through the
//!   AOT-compiled JAX/Pallas voltage model (`artifacts/rber.hlo.txt`)
//!   executed natively via PJRT, reporting predicted raw bit error
//!   rates for SLC pages, reprogrammed TLC pages, and native TLC pages.
//! * [`model`] — a closed-form Rust mirror of the RBER model used when
//!   artifacts are absent (and cross-checked against the artifact in
//!   tests).

pub mod audit;
pub mod bridge;
pub mod model;

pub use audit::ReliabilityAudit;
pub use bridge::RberBridge;
