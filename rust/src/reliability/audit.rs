//! Structural reliability audit (paper §IV-D1 restrictions, re-derived
//! independently of the inline enforcement in [`crate::flash::cell`]).

use crate::flash::{BlockAddr, BlockMode, FlashArray, PlaneId};
use crate::{Error, Result};

/// Result of a reliability audit over the whole array.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReliabilityAudit {
    /// Word lines inspected.
    pub wordlines: u64,
    /// Word lines that have been reprogrammed at least once.
    pub reprogrammed_wls: u64,
    /// Maximum reprogram count observed on any word line.
    pub max_reprograms: u8,
    /// IPS blocks inspected.
    pub ips_blocks: u64,
}

impl ReliabilityAudit {
    /// Run the audit. Errors on any violation of:
    /// * reprogram budget (≤ `max_reprograms` per word line);
    /// * window rule: in an IPS block, only word lines *below* the
    ///   active group's end may hold reprogrammed cells;
    /// * sequential rule: within the active group, a reprogrammed word
    ///   line never follows a less-programmed one (conversion is
    ///   front-to-back).
    pub fn run(array: &FlashArray, max_reprograms: u32) -> Result<ReliabilityAudit> {
        let g = *array.geometry();
        let mut audit = ReliabilityAudit::default();
        for p in 0..g.planes() {
            for b in 0..g.blocks_per_plane {
                let addr = BlockAddr { plane: PlaneId(p), block: b };
                let blk = array.block(addr);
                let n_wls = g.wordlines_per_block();
                let mut prev_pages = u8::MAX;
                let group_wls = 0; // set below for IPS blocks
                let _ = group_wls;
                if blk.mode() == BlockMode::Ips {
                    audit.ips_blocks += 1;
                }
                for wl in 0..n_wls {
                    let s = blk.wl(wl);
                    audit.wordlines += 1;
                    if s.reprograms() > 0 {
                        audit.reprogrammed_wls += 1;
                        audit.max_reprograms = audit.max_reprograms.max(s.reprograms());
                    }
                    if s.reprograms() as u32 > max_reprograms {
                        return Err(Error::invariant(format!(
                            "plane {p} block {b} wl {wl}: {} reprograms > budget {max_reprograms}",
                            s.reprograms()
                        )));
                    }
                    if blk.mode() == BlockMode::Ips {
                        // Window rule: beyond the active group, word
                        // lines must be erased.
                        let group_end = (blk.active_group() + 1)
                            * (g.wordlines_per_layer * 2).min(n_wls);
                        if wl >= group_end && !s.is_erased() {
                            return Err(Error::invariant(format!(
                                "plane {p} block {b} wl {wl}: programmed beyond the \
                                 active window (group end {group_end})"
                            )));
                        }
                        // Sequential rule (within the block as a whole,
                        // fill is monotone): pages never increase after
                        // a less-programmed word line *below* the write
                        // pointer. We check the weaker global form:
                        // erased word lines are never followed by
                        // reprogrammed ones inside the same group.
                        if prev_pages == 0 && s.reprograms() > 0 {
                            return Err(Error::invariant(format!(
                                "plane {p} block {b} wl {wl}: reprogram after erased word line"
                            )));
                        }
                    }
                    prev_pages = s.pages();
                }
            }
        }
        Ok(audit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::flash::Lpn;

    #[test]
    fn clean_array_passes() {
        let cfg = presets::small();
        let array = FlashArray::new(&cfg);
        let a = ReliabilityAudit::run(&array, 2).unwrap();
        assert_eq!(a.reprogrammed_wls, 0);
        assert!(a.wordlines > 0);
    }

    #[test]
    fn legal_ips_cycle_passes() {
        let cfg = presets::small();
        let mut array = FlashArray::new(&cfg);
        let addr = array.pop_free(PlaneId(0)).unwrap();
        array.block_mut(addr).set_mode(BlockMode::Ips).unwrap();
        let group_wls = 2 * cfg.geometry.wordlines_per_layer;
        for i in 0..group_wls {
            array.program_slc(addr, Lpn(i as u64), 0).unwrap();
        }
        for i in 0..group_wls * 2 {
            array.reprogram(addr, Lpn(100 + i as u64), 0).unwrap();
        }
        let a = ReliabilityAudit::run(&array, 2).unwrap();
        assert_eq!(a.reprogrammed_wls, group_wls as u64);
        assert_eq!(a.max_reprograms, 2);
        assert_eq!(a.ips_blocks as u64, 1);
    }
}
