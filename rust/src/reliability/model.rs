//! Closed-form Rust mirror of the L2 RBER model.
//!
//! Used when `artifacts/` is absent and as an independent cross-check
//! of the artifact path. The model matches
//! `python/compile/model.py::rber_model` in *shape* (not bit-exactly —
//! it is analytic rather than Monte-Carlo): the probability that a
//! cell lands in the wrong read window given programming overshoot
//! (uniform in one variation-adjusted step) plus neighbour coupling.

/// Parameters of the voltage model (level spacing = 1.0).
#[derive(Clone, Copy, Debug)]
pub struct RberParams {
    /// ISPP step size.
    pub step: f64,
    /// Process variation of the step.
    pub sigma: f64,
    /// Neighbour coupling strength.
    pub alpha: f64,
}

impl Default for RberParams {
    fn default() -> Self {
        RberParams { step: 0.25, sigma: 0.25, alpha: 0.02 }
    }
}

/// Analytic RBER estimates per page kind.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RberEstimate {
    /// SLC-stage LSB error rate.
    pub slc: f64,
    /// Reprogrammed-TLC mean bit error rate.
    pub ips_tlc: f64,
    /// Native one-shot TLC mean bit error rate.
    pub native_tlc: f64,
}

/// Effective post-program voltage spread: overshoot (uniform within
/// one variation-adjusted step) plus two-neighbour coupling.
fn spread(p: &RberParams, passes: f64) -> f64 {
    let overshoot = p.step * (1.0 + p.sigma / 2.0);
    // each pass adds coupling from two neighbours whose deltas are O(levels)
    overshoot + passes * p.alpha * 2.0 * 2.0
}

/// Probability of crossing a read boundary `margin` away given spread
/// `s` (uniform model: mass beyond the margin).
fn cross(margin: f64, s: f64) -> f64 {
    if s <= margin {
        0.0
    } else {
        ((s - margin) / s).clamp(0.0, 1.0)
    }
}

/// Estimate RBERs under `p`.
///
/// SLC margins are 1.0 (two states at spacing 2.0, threshold between);
/// TLC margins are 0.5 (eight states at spacing 1.0). IPS cells see
/// interference from three programming passes (program + 2 reprograms,
/// §IV-D1: "twice the cell-to-cell interference"); native TLC from one.
pub fn estimate(p: &RberParams) -> RberEstimate {
    RberEstimate {
        slc: cross(1.0, spread(p, 1.0)),
        ips_tlc: cross(0.5, spread(p, 3.0)),
        native_tlc: cross(0.5, spread(p, 1.0)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_params_are_error_free() {
        let e = estimate(&RberParams { step: 0.25, sigma: 0.0, alpha: 0.0 });
        assert_eq!(e.slc, 0.0);
        assert_eq!(e.ips_tlc, 0.0);
        assert_eq!(e.native_tlc, 0.0);
    }

    #[test]
    fn slc_more_robust_than_tlc() {
        let e = estimate(&RberParams { step: 0.4, sigma: 0.5, alpha: 0.05 });
        assert!(e.slc <= e.ips_tlc);
    }

    #[test]
    fn ips_pays_for_extra_passes() {
        let e = estimate(&RberParams { step: 0.4, sigma: 0.5, alpha: 0.05 });
        assert!(e.ips_tlc >= e.native_tlc);
    }

    #[test]
    fn monotone_in_alpha() {
        let lo = estimate(&RberParams { alpha: 0.01, ..Default::default() });
        let hi = estimate(&RberParams { alpha: 0.20, ..Default::default() });
        assert!(hi.ips_tlc >= lo.ips_tlc);
    }

    #[test]
    fn bounded() {
        let e = estimate(&RberParams { step: 5.0, sigma: 2.0, alpha: 1.0 });
        for v in [e.slc, e.ips_tlc, e.native_tlc] {
            assert!((0.0..=1.0).contains(&v));
        }
    }
}
