//! Bridge to the AOT-compiled RBER artifact.
//!
//! Feeds sampled word-line batches (data bits + per-phase programming
//! noise) to `artifacts/rber.hlo.txt` — the JAX/Pallas ISPP voltage
//! model — through the PJRT runtime, and averages the returned
//! per-page raw bit error rates. The noise inputs come from the run's
//! seeded PRNG, so audits are reproducible.

use crate::runtime::{self, Runtime, RBER_ARTIFACT};
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Batch shape fixed at lowering time (see `python/compile/aot.py`).
pub const PAGES: usize = 64;
/// Cells per page in the artifact batch.
pub const CELLS: usize = 1024;

/// Aggregated RBER prediction from the artifact.
#[derive(Clone, Copy, Debug, Default)]
pub struct RberReport {
    /// Mean RBER of pages written by the SLC + 2-reprogram chain.
    pub ips_tlc: f64,
    /// Mean RBER of one-shot TLC pages.
    pub native_tlc: f64,
    /// Mean RBER of SLC-stage reads.
    pub slc: f64,
    /// Batches evaluated.
    pub batches: u32,
}

/// The RBER artifact bridge.
pub struct RberBridge {
    rt: Runtime,
    key: String,
}

impl RberBridge {
    /// Load the artifact; errors if `make artifacts` has not run.
    pub fn new() -> Result<RberBridge> {
        let dir = runtime::artifact_dir()
            .ok_or_else(|| Error::Runtime("artifacts/ not found (run `make artifacts`)".into()))?;
        let path = dir.join(RBER_ARTIFACT);
        if !path.exists() {
            return Err(Error::Runtime(format!("{} missing", path.display())));
        }
        let mut rt = Runtime::new()?;
        let key = rt.load(&path)?;
        Ok(RberBridge { rt, key })
    }

    /// Evaluate one batch: random data bits and noise from `rng`,
    /// with the given process variation and coupling strength.
    pub fn run_batch(&self, rng: &mut Rng, sigma: f32, alpha: f32) -> Result<RberReport> {
        let n = PAGES * CELLS;
        let bits: Vec<i32> = (0..n).map(|_| rng.below(8) as i32).collect();
        let mut noise = || -> Vec<f32> { (0..n).map(|_| rng.f64() as f32).collect() };
        let (n1, n2, n3) = (noise(), noise(), noise());
        let dims = [PAGES as i64, CELLS as i64];
        let args = [
            runtime::literal_i32(&bits, &dims)?,
            runtime::literal_f32(&n1, &dims)?,
            runtime::literal_f32(&n2, &dims)?,
            runtime::literal_f32(&n3, &dims)?,
            runtime::literal_scalar(sigma),
            runtime::literal_scalar(alpha),
        ];
        let out = self.rt.execute(&self.key, &args)?;
        if out.len() != 3 {
            return Err(Error::Runtime(format!("expected 3 outputs, got {}", out.len())));
        }
        let mean = |v: &[f32]| v.iter().map(|&x| x as f64).sum::<f64>() / v.len().max(1) as f64;
        Ok(RberReport {
            ips_tlc: mean(&runtime::to_vec_f32(&out[0])?),
            native_tlc: mean(&runtime::to_vec_f32(&out[1])?),
            slc: mean(&runtime::to_vec_f32(&out[2])?),
            batches: 1,
        })
    }

    /// Average over `batches` batches.
    pub fn run(&self, seed: u64, batches: u32, sigma: f32, alpha: f32) -> Result<RberReport> {
        let mut rng = Rng::new(seed);
        let mut acc = RberReport::default();
        for _ in 0..batches.max(1) {
            let r = self.run_batch(&mut rng, sigma, alpha)?;
            acc.ips_tlc += r.ips_tlc;
            acc.native_tlc += r.native_tlc;
            acc.slc += r.slc;
            acc.batches += 1;
        }
        let n = acc.batches as f64;
        acc.ips_tlc /= n;
        acc.native_tlc /= n;
        acc.slc /= n;
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end: Pallas-authored model executed from Rust via PJRT.
    /// Skips when artifacts are absent.
    #[test]
    fn artifact_rber_behaves_physically() {
        let bridge = match RberBridge::new() {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping: {e}");
                return;
            }
        };
        // clean conditions: error-free
        let clean = bridge.run(1, 1, 0.0, 0.0).unwrap();
        assert_eq!(clean.ips_tlc, 0.0, "{clean:?}");
        assert_eq!(clean.slc, 0.0);
        // noisy conditions: SLC most robust; interference raises RBER
        let lo = bridge.run(2, 2, 0.3, 0.02).unwrap();
        let hi = bridge.run(2, 2, 0.3, 0.25).unwrap();
        assert!(lo.slc <= lo.ips_tlc + 1e-9, "{lo:?}");
        assert!(hi.ips_tlc >= lo.ips_tlc, "hi={hi:?} lo={lo:?}");
    }
}
