//! Block state: word-line states, validity bitmap, sequential write
//! pointers, and the IPS layer-group window.
//!
//! A block operates in one of three modes ([`BlockMode`]):
//!
//! * `Tlc` — normal high-density block, one-shot programmed word line
//!   by word line;
//! * `Slc` — traditional SLC-cache block: every word line stores one
//!   page (this is how the baseline/Turbo-Write cache and the
//!   cooperative design's traditional part are built);
//! * `Ips` — the paper's in-place-switch block: word lines are first
//!   SLC-programmed *inside the active layer group* (default two
//!   layers, the reprogram reliability window of [7]), later
//!   reprogrammed in place to full TLC, after which the next layer
//!   group becomes the new SLC window (paper Fig. 6a, Steps 1–3).
//!
//! # Data layout (§Perf, hot-path pass #2)
//!
//! Block state is split into scalar metadata ([`BlockMeta`]: mode,
//! counters, write pointers) and the three page-granular arrays
//! (word-line states, validity bitmap, LPN back-pointers). The arrays
//! live in one of two layouts selected by `sim.soa_blocks`:
//!
//! * **SoA arenas** (default): one [`PlaneArena`] per plane holds the
//!   arrays of *all* its blocks contiguously, indexed by
//!   `(block, page)` — GC valid-page scans and victim debt walks
//!   stream through contiguous memory instead of chasing a heap
//!   allocation per block.
//! * **Inline vectors** (oracle): each [`Block`] owns its own `Vec`s —
//!   the historical layout, retained as the byte-identical
//!   differential oracle.
//!
//! Both layouts are driven by the *same* logic: every operation is
//! implemented exactly once on the borrowed views [`BlockRef`] /
//! [`BlockMut`], and [`Block`] (the inline form, still used standalone
//! in unit tests) delegates by viewing its own vectors. Equivalence is
//! therefore by construction; `soa_matches_inline_under_random_ops`
//! pins it anyway.

use super::cell::{PageKind, WlState};
use super::geometry::Lpn;
use crate::config::Geometry;
use crate::{Error, Result};

/// Operating mode of a block (assigned while erased, sticky until
/// reassigned).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockMode {
    /// One-shot TLC block.
    Tlc,
    /// Traditional SLC-cache block (1 page / word line over the whole block).
    Slc,
    /// IPS block with a moving SLC layer-group window.
    Ips,
}

/// Sentinel for "no LPN" in per-page back-pointers.
pub const NO_LPN: u32 = u32::MAX;

/// Scalar per-block metadata: mode, counters, and write pointers.
///
/// Always stored inline in [`Block`] (it is small and hot); only the
/// page-granular arrays move into the [`PlaneArena`] under
/// `sim.soa_blocks`.
#[derive(Clone, Debug)]
pub struct BlockMeta {
    mode: BlockMode,
    /// Number of currently valid pages.
    valid_count: u32,
    /// Number of written (programmed) pages, valid or not.
    written_count: u32,
    /// Next word line for an initial program.
    write_wl: u32,
    /// `Tlc` mode only: next bit within `write_wl` for page-granular
    /// programming (0 = LSB, 1 = CSB, 2 = MSB).
    write_bit: u8,
    /// IPS: index of the active layer group serving as the SLC window.
    active_group: u32,
    /// IPS: next word line (within the active group) to reprogram.
    reprog_wl: u32,
    /// Lifetime erase count (wear levelling metric, paper §IV-D2).
    erase_count: u32,
    /// Word lines per block (cached from geometry).
    n_wls: u32,
    /// Word lines per IPS layer group.
    group_wls: u32,
}

impl BlockMeta {
    fn new(g: &Geometry, group_layers: u32) -> BlockMeta {
        let n_wls = g.wordlines_per_block();
        BlockMeta {
            mode: BlockMode::Tlc,
            valid_count: 0,
            written_count: 0,
            write_wl: 0,
            write_bit: 0,
            active_group: 0,
            reprog_wl: 0,
            erase_count: 0,
            n_wls,
            group_wls: group_layers * g.wordlines_per_layer,
        }
    }
}

/// `u64` words in a block's validity bitmap.
fn valid_words(g: &Geometry) -> usize {
    (g.pages_per_block as usize + 63) / 64
}

/// One flash block in the inline (AoS) layout: scalar metadata plus
/// its own page arrays. Standalone `Block`s drive the unit tests and
/// serve as the `sim.soa_blocks = false` oracle; all operations
/// delegate to the shared view logic ([`BlockRef`]/[`BlockMut`]).
#[derive(Clone, Debug)]
pub struct Block {
    pub(crate) meta: BlockMeta,
    /// Per-word-line state.
    wls: Vec<WlState>,
    /// Validity bitmap over TLC page slots (`pages_per_block` bits).
    valid: Vec<u64>,
    /// Back-pointers: LPN stored in each page slot (for GC); lazily
    /// allocated on first program to keep untouched blocks cheap.
    p2l: Vec<u32>,
}

/// SoA page-metadata arenas for every block of one plane: word-line
/// states, validity bitmaps, and LPN back-pointers stored contiguously
/// and indexed by `(block, page)`. The arena owns the arrays; scalar
/// state stays in each block's [`BlockMeta`].
///
/// Unlike the inline layout's lazy `p2l`, the arena back-pointers are
/// preallocated and `NO_LPN`-filled — `lpn_at` of a never-programmed
/// slot reads the sentinel instead of an absent vector, which is the
/// same observable `None`.
pub struct PlaneArena {
    /// Word lines per block (slice stride into `wls`).
    n_wls: usize,
    /// Bitmap words per block (slice stride into `valid`).
    words: usize,
    /// Page slots per block (slice stride into `p2l`).
    pages: usize,
    wls: Vec<WlState>,
    valid: Vec<u64>,
    p2l: Vec<u32>,
}

impl PlaneArena {
    /// Erased arenas for `n_blocks` blocks.
    pub fn new(g: &Geometry, n_blocks: u32) -> PlaneArena {
        let n_wls = g.wordlines_per_block() as usize;
        let words = valid_words(g);
        let pages = n_wls * 3;
        let n = n_blocks as usize;
        PlaneArena {
            n_wls,
            words,
            pages,
            wls: vec![WlState::ERASED; n_wls * n],
            valid: vec![0u64; words * n],
            p2l: vec![NO_LPN; pages * n],
        }
    }

    /// Immutable view of block `b` over this arena's slices.
    pub fn block_ref<'a>(&'a self, meta: &'a BlockMeta, b: u32) -> BlockRef<'a> {
        let b = b as usize;
        BlockRef {
            meta,
            wls: &self.wls[b * self.n_wls..(b + 1) * self.n_wls],
            valid: &self.valid[b * self.words..(b + 1) * self.words],
            p2l: &self.p2l[b * self.pages..(b + 1) * self.pages],
        }
    }

    /// Mutable view of block `b` over this arena's slices.
    pub fn block_mut<'a>(&'a mut self, meta: &'a mut BlockMeta, b: u32) -> BlockMut<'a> {
        let b = b as usize;
        BlockMut {
            meta,
            wls: &mut self.wls[b * self.n_wls..(b + 1) * self.n_wls],
            valid: &mut self.valid[b * self.words..(b + 1) * self.words],
            p2l: P2lMut::Fixed(&mut self.p2l[b * self.pages..(b + 1) * self.pages]),
        }
    }
}

/// Immutable block view: metadata plus borrowed page arrays, layout
/// agnostic (inline vectors or arena slices). All read-side block
/// logic lives here.
#[derive(Clone, Copy)]
pub struct BlockRef<'a> {
    meta: &'a BlockMeta,
    wls: &'a [WlState],
    valid: &'a [u64],
    /// Empty while the inline layout's lazy `p2l` is unallocated.
    p2l: &'a [u32],
}

/// The two mutable back-pointer layouts behind [`BlockMut`]: the
/// inline lazy vector (allocated on first program, freed on erase) and
/// the arena's preallocated `NO_LPN`-filled slice.
pub enum P2lMut<'a> {
    /// Inline layout: lazily allocated vector.
    Lazy(&'a mut Vec<u32>),
    /// Arena layout: preallocated slice, `NO_LPN` = absent.
    Fixed(&'a mut [u32]),
}

/// Mutable block view; all state-changing block logic lives here.
pub struct BlockMut<'a> {
    meta: &'a mut BlockMeta,
    wls: &'a mut [WlState],
    valid: &'a mut [u64],
    p2l: P2lMut<'a>,
}

impl Block {
    /// Create an erased block with inline page arrays.
    pub fn new(g: &Geometry, group_layers: u32) -> Block {
        let n_wls = g.wordlines_per_block();
        Block {
            meta: BlockMeta::new(g, group_layers),
            wls: vec![WlState::ERASED; n_wls as usize],
            valid: vec![0u64; valid_words(g)],
            p2l: Vec::new(),
        }
    }

    /// Create a block whose page arrays live in a [`PlaneArena`]: only
    /// the scalar metadata is stored here; the vectors stay empty and
    /// untouched (the owning array always routes through arena views).
    pub(crate) fn meta_only(g: &Geometry, group_layers: u32) -> Block {
        Block {
            meta: BlockMeta::new(g, group_layers),
            wls: Vec::new(),
            valid: Vec::new(),
            p2l: Vec::new(),
        }
    }

    /// View this inline block's own arrays.
    pub fn as_view(&self) -> BlockRef<'_> {
        BlockRef { meta: &self.meta, wls: &self.wls, valid: &self.valid, p2l: &self.p2l }
    }

    /// Mutable view over this inline block's own arrays.
    pub fn as_view_mut(&mut self) -> BlockMut<'_> {
        BlockMut {
            meta: &mut self.meta,
            wls: &mut self.wls,
            valid: &mut self.valid,
            p2l: P2lMut::Lazy(&mut self.p2l),
        }
    }

    // --- delegated API (kept so standalone blocks and the oracle
    // --- exercise the exact same view logic) -----------------------

    /// Current mode.
    pub fn mode(&self) -> BlockMode {
        self.as_view().mode()
    }
    /// Valid page count.
    pub fn valid_count(&self) -> u32 {
        self.as_view().valid_count()
    }
    /// Written (programmed) page count, valid or not.
    pub fn written_count(&self) -> u32 {
        self.as_view().written_count()
    }
    /// Invalid (written but superseded) page count.
    pub fn invalid_count(&self) -> u32 {
        self.as_view().invalid_count()
    }
    /// Lifetime erases.
    pub fn erase_count(&self) -> u32 {
        self.as_view().erase_count()
    }
    /// Seed the lifetime erase count before any traffic; see
    /// [`BlockMut::pre_age`].
    pub fn pre_age(&mut self, erases: u32) -> Result<()> {
        self.as_view_mut().pre_age(erases)
    }
    /// Is the block completely erased?
    pub fn is_erased(&self) -> bool {
        self.as_view().is_erased()
    }
    /// Word-line state (for audits).
    pub fn wl(&self, wl: u32) -> WlState {
        self.as_view().wl(wl)
    }
    /// IPS active layer group index.
    pub fn active_group(&self) -> u32 {
        self.as_view().active_group()
    }
    /// Number of layer groups in this block.
    pub fn group_count(&self) -> u32 {
        self.as_view().group_count()
    }
    /// Page validity.
    pub fn is_valid(&self, pib: u32) -> bool {
        self.as_view().is_valid(pib)
    }
    /// Has the page slot been programmed?
    pub fn is_written(&self, pib: u32) -> bool {
        self.as_view().is_written(pib)
    }
    /// LPN stored at a page slot.
    pub fn lpn_at(&self, pib: u32) -> Option<Lpn> {
        self.as_view().lpn_at(pib)
    }
    /// Storage kind of a page (drives read latency).
    pub fn page_kind(&self, pib: u32) -> PageKind {
        self.as_view().page_kind(pib)
    }
    /// Iterate valid page slots (ascending).
    pub fn valid_pages(&self) -> impl Iterator<Item = u32> + '_ {
        self.as_view().valid_pages()
    }
    /// Assign a mode; only legal while erased.
    pub fn set_mode(&mut self, mode: BlockMode) -> Result<()> {
        self.as_view_mut().set_mode(mode)
    }
    /// Word lines still available for an initial SLC program.
    pub fn slc_free_wls(&self) -> u32 {
        self.as_view().slc_free_wls()
    }
    /// IPS: word lines with reprogram work remaining; see
    /// [`BlockRef::reprogrammable_wls`].
    pub fn reprogrammable_wls(&self) -> u32 {
        self.as_view().reprogrammable_wls()
    }
    /// IPS: individual reprogram operations remaining in the active group.
    pub fn reprogram_ops_remaining(&self) -> u32 {
        self.as_view().reprogram_ops_remaining()
    }
    /// Free one-shot TLC word lines.
    pub fn tlc_free_wls(&self) -> u32 {
        self.as_view().tlc_free_wls()
    }
    /// Free page slots for page-granular TLC programming.
    pub fn tlc_free_pages(&self) -> u32 {
        self.as_view().tlc_free_pages()
    }
    /// IPS: the word line the next reprogram operation will target.
    pub fn next_reprogram_wl(&self) -> Option<u32> {
        self.as_view().next_reprogram_wl()
    }
    /// Does the block have another layer group after the active one?
    pub fn has_next_group(&self) -> bool {
        self.as_view().has_next_group()
    }
    /// Program one SLC page; see [`BlockMut::program_slc`].
    pub fn program_slc(&mut self, lpn: Lpn) -> Result<u32> {
        self.as_view_mut().program_slc(lpn)
    }
    /// One-shot TLC program; see [`BlockMut::program_tlc_oneshot`].
    pub fn program_tlc_oneshot(&mut self, lpns: &[Lpn]) -> Result<Vec<u32>> {
        self.as_view_mut().program_tlc_oneshot(lpns)
    }
    /// Page-granular TLC program; see [`BlockMut::program_tlc_page`].
    pub fn program_tlc_page(&mut self, lpn: Lpn) -> Result<u32> {
        self.as_view_mut().program_tlc_page(lpn)
    }
    /// One reprogram operation; see [`BlockMut::reprogram_next`].
    pub fn reprogram_next(&mut self, lpn: Lpn, max_reprograms: u32) -> Result<(u32, bool)> {
        self.as_view_mut().reprogram_next(lpn, max_reprograms)
    }
    /// Advance the IPS window; see [`BlockMut::advance_group`].
    pub fn advance_group(&mut self) -> Result<u32> {
        self.as_view_mut().advance_group()
    }
    /// Invalidate a page slot.
    pub fn invalidate(&mut self, pib: u32) -> Result<()> {
        self.as_view_mut().invalidate(pib)
    }
    /// Erase the block.
    pub fn erase(&mut self) -> Result<()> {
        self.as_view_mut().erase()
    }
}

impl<'a> BlockRef<'a> {
    // --- accessors -------------------------------------------------

    /// Current mode.
    pub fn mode(&self) -> BlockMode {
        self.meta.mode
    }
    /// Valid page count.
    pub fn valid_count(&self) -> u32 {
        self.meta.valid_count
    }
    /// Written (programmed) page count, valid or not.
    pub fn written_count(&self) -> u32 {
        self.meta.written_count
    }
    /// Invalid (written but superseded) page count.
    pub fn invalid_count(&self) -> u32 {
        self.meta.written_count - self.meta.valid_count
    }
    /// Lifetime erases.
    pub fn erase_count(&self) -> u32 {
        self.meta.erase_count
    }
    /// Is the block completely erased?
    pub fn is_erased(&self) -> bool {
        self.meta.written_count == 0
            && self.meta.write_wl == 0
            && self.meta.write_bit == 0
            && self.wls.iter().all(|w| w.is_erased())
    }
    /// Word-line state (for audits).
    pub fn wl(&self, wl: u32) -> WlState {
        self.wls[wl as usize]
    }
    /// IPS active layer group index.
    pub fn active_group(&self) -> u32 {
        self.meta.active_group
    }
    /// Number of layer groups in this block.
    pub fn group_count(&self) -> u32 {
        self.meta.n_wls / self.meta.group_wls
    }

    /// Page validity.
    pub fn is_valid(&self, pib: u32) -> bool {
        self.valid[(pib / 64) as usize] >> (pib % 64) & 1 == 1
    }

    /// Has the page slot been programmed?
    pub fn is_written(&self, pib: u32) -> bool {
        let wl = pib / 3;
        let bit = (pib % 3) as u8;
        self.wls[wl as usize].pages() > bit
    }

    /// LPN stored at a page slot (`None` if never programmed or
    /// invalidated — absent vector slot and `NO_LPN` sentinel read
    /// identically).
    pub fn lpn_at(&self, pib: u32) -> Option<Lpn> {
        let v = *self.p2l.get(pib as usize)?;
        if v == NO_LPN {
            None
        } else {
            Some(Lpn(v as u64))
        }
    }

    /// Storage kind of a page (drives read latency).
    ///
    /// `Slc` blocks always read at SLC speed; `Tlc` blocks at TLC
    /// speed; `Ips` blocks depend on how far the word line has been
    /// reprogrammed (an SLC page reads fast until its word line holds
    /// ≥ 2 bits per cell).
    pub fn page_kind(&self, pib: u32) -> PageKind {
        match self.meta.mode {
            BlockMode::Slc => PageKind::Slc,
            BlockMode::Tlc => PageKind::Tlc,
            BlockMode::Ips => self.wls[(pib / 3) as usize].kind(),
        }
    }

    /// Iterate valid page slots (ascending). Takes the (Copy) view by
    /// value so the iterator borrows only the underlying arrays.
    pub fn valid_pages(self) -> impl Iterator<Item = u32> + 'a {
        self.valid
            .iter()
            .enumerate()
            .flat_map(|(w, &bits)| BitIter { bits, base: w as u32 * 64 })
    }

    // --- SLC window / capacity queries ------------------------------

    /// Word lines still available for an initial SLC program.
    ///
    /// `Slc` blocks: the rest of the block. `Ips` blocks: the erased
    /// remainder of the active layer group. `Tlc` blocks: 0.
    pub fn slc_free_wls(&self) -> u32 {
        let m = self.meta;
        match m.mode {
            BlockMode::Slc => m.n_wls - m.write_wl,
            BlockMode::Ips => {
                let group_end = (m.active_group + 1) * m.group_wls;
                group_end.saturating_sub(m.write_wl.max(m.active_group * m.group_wls))
            }
            BlockMode::Tlc => 0,
        }
    }

    /// IPS: word lines in the active group that are programmed but not
    /// yet full TLC (i.e. reprogram work remaining, in units of word
    /// lines; each needs up to 2 reprogram operations).
    pub fn reprogrammable_wls(&self) -> u32 {
        let m = self.meta;
        if m.mode != BlockMode::Ips {
            return 0;
        }
        let group_start = m.active_group * m.group_wls;
        let group_end = group_start + m.group_wls;
        (group_start.max(m.reprog_wl)..group_end.min(m.write_wl))
            .filter(|&wl| !self.wls[wl as usize].is_full() && !self.wls[wl as usize].is_erased())
            .count() as u32
    }

    /// IPS: individual reprogram operations remaining in the active group.
    pub fn reprogram_ops_remaining(&self) -> u32 {
        let m = self.meta;
        if m.mode != BlockMode::Ips {
            return 0;
        }
        let group_start = m.active_group * m.group_wls;
        let group_end = group_start + m.group_wls;
        (group_start..group_end.min(m.write_wl))
            .map(|wl| 3u32.saturating_sub(self.wls[wl as usize].pages() as u32))
            .sum()
    }

    /// Free one-shot TLC word lines (for `Tlc` blocks; only whole
    /// erased word lines count).
    pub fn tlc_free_wls(&self) -> u32 {
        let m = self.meta;
        match m.mode {
            BlockMode::Tlc => {
                let partial = if m.write_bit > 0 { 1 } else { 0 };
                m.n_wls - m.write_wl - partial
            }
            _ => 0,
        }
    }

    /// Free page slots for page-granular TLC programming.
    pub fn tlc_free_pages(&self) -> u32 {
        let m = self.meta;
        match m.mode {
            BlockMode::Tlc => (m.n_wls - m.write_wl) * 3 - m.write_bit as u32,
            _ => 0,
        }
    }

    /// IPS: the word line the next reprogram operation will target
    /// (programmed but not full, inside the active group), if any.
    pub fn next_reprogram_wl(&self) -> Option<u32> {
        let m = self.meta;
        if m.mode != BlockMode::Ips {
            return None;
        }
        let group_start = m.active_group * m.group_wls;
        let group_end = group_start + m.group_wls;
        (group_start.max(m.reprog_wl)..group_end.min(m.write_wl)).find(|&wl| {
            let s = self.wls[wl as usize];
            !s.is_erased() && !s.is_full()
        })
    }

    /// Does the block have another layer group after the active one?
    pub fn has_next_group(&self) -> bool {
        self.meta.mode == BlockMode::Ips && self.meta.active_group + 1 < self.group_count()
    }
}

impl<'a> BlockMut<'a> {
    /// Reborrow immutably (for read checks inside mutations).
    pub fn as_ref(&self) -> BlockRef<'_> {
        BlockRef {
            meta: self.meta,
            wls: self.wls,
            valid: self.valid,
            p2l: match &self.p2l {
                P2lMut::Lazy(v) => v.as_slice(),
                P2lMut::Fixed(s) => s,
            },
        }
    }

    /// Seed the lifetime erase count before any traffic (fleet wear
    /// heterogeneity: a pre-aged device starts with uneven wear, which
    /// perturbs the min-erase allocator). Only legal on a pristine,
    /// fully erased block.
    pub fn pre_age(&mut self, erases: u32) -> Result<()> {
        if !self.as_ref().is_erased() || self.meta.erase_count != 0 {
            return Err(Error::invariant("pre_age of a used block"));
        }
        self.meta.erase_count = erases;
        Ok(())
    }

    // --- mode management -------------------------------------------

    /// Assign a mode; only legal while erased.
    pub fn set_mode(&mut self, mode: BlockMode) -> Result<()> {
        if !self.as_ref().is_erased() {
            return Err(Error::Flash("mode change on non-erased block".into()));
        }
        self.meta.mode = mode;
        Ok(())
    }

    // --- programming -----------------------------------------------

    /// Store an LPN back-pointer. Inline layout: allocate the lazy
    /// vector on first use. Arena layout: the slice is preallocated.
    fn p2l_set(&mut self, pib: u32, lpn: u32) {
        match &mut self.p2l {
            P2lMut::Lazy(v) => {
                if v.is_empty() {
                    **v = vec![NO_LPN; self.wls.len() * 3];
                }
                v[pib as usize] = lpn;
            }
            P2lMut::Fixed(s) => s[pib as usize] = lpn,
        }
    }

    fn mark_written(&mut self, pib: u32, lpn: Lpn) {
        self.p2l_set(pib, lpn.0 as u32);
        self.valid[(pib / 64) as usize] |= 1 << (pib % 64);
        self.meta.valid_count += 1;
        self.meta.written_count += 1;
    }

    /// Program one SLC page at the write pointer; returns the page slot.
    ///
    /// Legal on `Slc` blocks anywhere, on `Ips` blocks only inside the
    /// active layer group.
    pub fn program_slc(&mut self, lpn: Lpn) -> Result<u32> {
        match self.meta.mode {
            BlockMode::Tlc => {
                return Err(Error::Flash("SLC program on TLC block".into()));
            }
            BlockMode::Ips => {
                let group_start = self.meta.active_group * self.meta.group_wls;
                let group_end = group_start + self.meta.group_wls;
                if self.meta.write_wl < group_start || self.meta.write_wl >= group_end {
                    return Err(Error::Flash(format!(
                        "IPS SLC program outside active group (wl {} not in [{},{}))",
                        self.meta.write_wl, group_start, group_end
                    )));
                }
            }
            BlockMode::Slc => {}
        }
        if self.meta.write_wl >= self.meta.n_wls {
            return Err(Error::Flash("SLC program past end of block".into()));
        }
        let wl = self.meta.write_wl;
        self.wls[wl as usize] = self.wls[wl as usize].program_slc()?;
        self.meta.write_wl += 1;
        let pib = wl * 3;
        self.mark_written(pib, lpn);
        Ok(pib)
    }

    /// One-shot TLC program of the next word line with 1..=3 LPNs;
    /// unfilled slots are wasted (marked written+invalid is *not*
    /// needed — they are simply never valid). Returns the page slots
    /// actually used.
    pub fn program_tlc_oneshot(&mut self, lpns: &[Lpn]) -> Result<Vec<u32>> {
        if self.meta.mode != BlockMode::Tlc {
            return Err(Error::Flash("one-shot TLC program on non-TLC block".into()));
        }
        if lpns.is_empty() || lpns.len() > 3 {
            return Err(Error::Flash("one-shot program needs 1..=3 pages".into()));
        }
        if self.meta.write_wl >= self.meta.n_wls {
            return Err(Error::Flash("TLC program past end of block".into()));
        }
        if self.meta.write_bit != 0 {
            return Err(Error::Flash(
                "one-shot program on a partially page-programmed word line".into(),
            ));
        }
        let wl = self.meta.write_wl;
        self.wls[wl as usize] = self.wls[wl as usize].program_tlc_oneshot()?;
        self.meta.write_wl += 1;
        let mut slots = Vec::with_capacity(lpns.len());
        for (i, &lpn) in lpns.iter().enumerate() {
            let pib = wl * 3 + i as u32;
            self.mark_written(pib, lpn);
            slots.push(pib);
        }
        // wasted slots still count as written capacity
        self.meta.written_count += (3 - lpns.len()) as u32;
        Ok(slots)
    }

    /// Page-granular TLC program: writes the next page slot (LSB →
    /// CSB → MSB per word line, sequentially) at TLC-program latency.
    /// This is the host-write path's TLC programming model (paper
    /// Table I: "3 ms for TLC write" per page). Returns the page slot.
    pub fn program_tlc_page(&mut self, lpn: Lpn) -> Result<u32> {
        if self.meta.mode != BlockMode::Tlc {
            return Err(Error::Flash("page-granular TLC program on non-TLC block".into()));
        }
        if self.meta.write_wl >= self.meta.n_wls {
            return Err(Error::Flash("TLC program past end of block".into()));
        }
        let wl = self.meta.write_wl;
        self.wls[wl as usize] = self.wls[wl as usize].program_incremental()?;
        let pib = wl * 3 + self.meta.write_bit as u32;
        self.meta.write_bit += 1;
        if self.meta.write_bit == 3 {
            self.meta.write_bit = 0;
            self.meta.write_wl += 1;
        }
        self.mark_written(pib, lpn);
        Ok(pib)
    }

    /// One reprogram operation on the IPS window: adds one page (CSB
    /// or MSB) to the next not-yet-full word line in the active group,
    /// sequentially. Returns `(page_slot, wordline_now_full)`.
    pub fn reprogram_next(&mut self, lpn: Lpn, max_reprograms: u32) -> Result<(u32, bool)> {
        if self.meta.mode != BlockMode::Ips {
            return Err(Error::Flash("reprogram on non-IPS block".into()));
        }
        let group_start = self.meta.active_group * self.meta.group_wls;
        let group_end = group_start + self.meta.group_wls;
        // advance the reprogram pointer past full word lines
        let mut wl = self.meta.reprog_wl.max(group_start);
        while wl < group_end && (self.wls[wl as usize].is_full()) {
            wl += 1;
        }
        if wl >= group_end || wl >= self.meta.write_wl {
            return Err(Error::Flash("no reprogrammable word line in active group".into()));
        }
        let state = self.wls[wl as usize];
        if state.is_erased() {
            return Err(Error::Flash("reprogram reached an erased word line".into()));
        }
        let bit = state.next_bit();
        self.wls[wl as usize] = state.reprogram(max_reprograms)?;
        let pib = wl * 3 + bit as u32;
        self.mark_written(pib, lpn);
        let full = self.wls[wl as usize].is_full();
        self.meta.reprog_wl = if full { wl + 1 } else { wl };
        Ok((pib, full))
    }

    /// Advance the IPS window to the next layer group once the active
    /// one is fully reprogrammed (paper Fig. 6a Step 3). Returns the new
    /// group index.
    pub fn advance_group(&mut self) -> Result<u32> {
        if self.meta.mode != BlockMode::Ips {
            return Err(Error::Flash("advance_group on non-IPS block".into()));
        }
        let group_start = self.meta.active_group * self.meta.group_wls;
        let group_end = group_start + self.meta.group_wls;
        let all_full = (group_start..group_end).all(|wl| self.wls[wl as usize].is_full());
        if !all_full {
            return Err(Error::Flash(
                "cannot advance: active group not fully reprogrammed".into(),
            ));
        }
        if !self.as_ref().has_next_group() {
            return Err(Error::Flash("no next layer group".into()));
        }
        self.meta.active_group += 1;
        self.meta.write_wl = self.meta.active_group * self.meta.group_wls;
        self.meta.reprog_wl = self.meta.write_wl;
        Ok(self.meta.active_group)
    }

    // --- invalidation / erase ---------------------------------------

    /// Invalidate a page slot (its LPN was overwritten or migrated).
    pub fn invalidate(&mut self, pib: u32) -> Result<()> {
        if !self.as_ref().is_valid(pib) {
            return Err(Error::invariant(format!("double invalidate of page {pib}")));
        }
        self.valid[(pib / 64) as usize] &= !(1 << (pib % 64));
        self.meta.valid_count -= 1;
        match &mut self.p2l {
            P2lMut::Lazy(v) => {
                if !v.is_empty() {
                    v[pib as usize] = NO_LPN;
                }
            }
            P2lMut::Fixed(s) => s[pib as usize] = NO_LPN,
        }
        Ok(())
    }

    /// Erase the block. Only legal when no valid pages remain.
    pub fn erase(&mut self) -> Result<()> {
        if self.meta.valid_count != 0 {
            return Err(Error::invariant(format!(
                "erase of block with {} valid pages",
                self.meta.valid_count
            )));
        }
        for wl in self.wls.iter_mut() {
            *wl = wl.erase();
        }
        for w in self.valid.iter_mut() {
            *w = 0;
        }
        match &mut self.p2l {
            P2lMut::Lazy(v) => {
                v.clear();
                v.shrink_to_fit();
            }
            P2lMut::Fixed(s) => s.fill(NO_LPN),
        }
        self.meta.written_count = 0;
        self.meta.write_wl = 0;
        self.meta.write_bit = 0;
        self.meta.active_group = 0;
        self.meta.reprog_wl = 0;
        self.meta.erase_count += 1;
        Ok(())
    }
}

struct BitIter {
    bits: u64,
    base: u32,
}

impl Iterator for BitIter {
    type Item = u32;
    #[inline]
    fn next(&mut self) -> Option<u32> {
        if self.bits == 0 {
            return None;
        }
        let tz = self.bits.trailing_zeros();
        self.bits &= self.bits - 1;
        Some(self.base + tz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::prop::{self, one_of, vec_of};

    fn small_block() -> (Block, Geometry) {
        let g = presets::small().geometry;
        (Block::new(&g, 2), g)
    }

    #[test]
    fn slc_block_fills_every_wordline() {
        let (mut b, g) = small_block();
        b.set_mode(BlockMode::Slc).unwrap();
        let n = g.wordlines_per_block();
        for i in 0..n {
            let pib = b.program_slc(Lpn(i as u64)).unwrap();
            assert_eq!(pib, i * 3);
        }
        assert_eq!(b.slc_free_wls(), 0);
        assert!(b.program_slc(Lpn(0)).is_err());
        assert_eq!(b.valid_count(), n);
    }

    #[test]
    fn ips_block_full_cycle() {
        let (mut b, g) = small_block();
        b.set_mode(BlockMode::Ips).unwrap();
        let group_wls = 2 * g.wordlines_per_layer; // 4
        // Step 1: fill the SLC window
        for i in 0..group_wls {
            b.program_slc(Lpn(i as u64)).unwrap();
        }
        assert_eq!(b.slc_free_wls(), 0);
        assert!(b.program_slc(Lpn(99)).is_err(), "window exhausted");
        // Step 2: reprogram 2 ops per word line
        assert_eq!(b.reprogram_ops_remaining(), group_wls * 2);
        let mut added = 0;
        while b.reprogram_ops_remaining() > 0 {
            let (_pib, _full) = b.reprogram_next(Lpn(100 + added), 2).unwrap();
            added += 1;
        }
        assert_eq!(added as u32, group_wls * 2);
        // Step 3: advance to the next group; SLC writes flow again
        b.advance_group().unwrap();
        assert_eq!(b.active_group(), 1);
        assert_eq!(b.slc_free_wls(), group_wls);
        b.program_slc(Lpn(500)).unwrap();
        // original SLC data still valid (in-place, no migration)
        assert!(b.is_valid(0));
        assert_eq!(b.lpn_at(0), Some(Lpn(0)));
    }

    #[test]
    fn ips_reprogram_requires_window() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Ips).unwrap();
        assert!(b.reprogram_next(Lpn(0), 2).is_err(), "nothing programmed yet");
        b.program_slc(Lpn(1)).unwrap();
        let (pib, full) = b.reprogram_next(Lpn(2), 2).unwrap();
        assert_eq!(pib, 1); // CSB of wl 0
        assert!(!full);
        let (pib, full) = b.reprogram_next(Lpn(3), 2).unwrap();
        assert_eq!(pib, 2); // MSB of wl 0
        assert!(full);
        assert!(b.reprogram_next(Lpn(4), 2).is_err(), "wl1 never SLC-programmed");
    }

    #[test]
    fn advance_requires_full_group() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Ips).unwrap();
        b.program_slc(Lpn(1)).unwrap();
        assert!(b.advance_group().is_err());
    }

    #[test]
    fn oneshot_tlc_and_waste_accounting() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Tlc).unwrap();
        let slots = b.program_tlc_oneshot(&[Lpn(1), Lpn(2), Lpn(3)]).unwrap();
        assert_eq!(slots, vec![0, 1, 2]);
        let slots = b.program_tlc_oneshot(&[Lpn(4)]).unwrap();
        assert_eq!(slots, vec![3]);
        assert_eq!(b.valid_count(), 4);
        assert_eq!(b.written_count(), 6); // 2 slots wasted on wl 1
    }

    #[test]
    fn page_granular_tlc_fills_sequentially() {
        let (mut b, g) = small_block();
        b.set_mode(BlockMode::Tlc).unwrap();
        let total = g.pages_per_block;
        for i in 0..total {
            let pib = b.program_tlc_page(Lpn(i as u64)).unwrap();
            assert_eq!(pib, i, "slots fill in order");
        }
        assert_eq!(b.tlc_free_pages(), 0);
        assert!(b.program_tlc_page(Lpn(0)).is_err());
        assert_eq!(b.valid_count(), total);
        assert_eq!(b.written_count(), total);
    }

    #[test]
    fn oneshot_rejected_mid_wordline() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Tlc).unwrap();
        b.program_tlc_page(Lpn(1)).unwrap(); // wl0 partially programmed
        assert!(b.program_tlc_oneshot(&[Lpn(2), Lpn(3), Lpn(4)]).is_err());
        // finish the word line page-granularly, then one-shot works
        b.program_tlc_page(Lpn(2)).unwrap();
        b.program_tlc_page(Lpn(3)).unwrap();
        b.program_tlc_oneshot(&[Lpn(4), Lpn(5), Lpn(6)]).unwrap();
    }

    #[test]
    fn erase_rules() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Slc).unwrap();
        b.program_slc(Lpn(7)).unwrap();
        assert!(b.erase().is_err(), "valid page present");
        b.invalidate(0).unwrap();
        assert!(b.invalidate(0).is_err(), "double invalidate");
        b.erase().unwrap();
        assert!(b.is_erased());
        assert_eq!(b.erase_count(), 1);
        // mode change now legal
        b.set_mode(BlockMode::Tlc).unwrap();
    }

    #[test]
    fn valid_pages_iterator() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Tlc).unwrap();
        b.program_tlc_oneshot(&[Lpn(1), Lpn(2), Lpn(3)]).unwrap();
        b.invalidate(1).unwrap();
        let v: Vec<u32> = b.valid_pages().collect();
        assert_eq!(v, vec![0, 2]);
    }

    /// Property: random legal op sequences keep counts consistent.
    #[test]
    fn block_counters_consistent_under_random_ops() {
        #[derive(Clone, Debug)]
        enum Op {
            Slc,
            Reprog,
            InvalidateFirst,
            Advance,
        }
        let gen = vec_of(
            one_of(vec![Op::Slc, Op::Reprog, Op::InvalidateFirst, Op::Advance]),
            0,
            64,
        );
        prop::check("block counters consistent", 256, gen, |ops| {
            let g = presets::small().geometry;
            let mut b = Block::new(&g, 2);
            b.set_mode(BlockMode::Ips).unwrap();
            let mut lpn = 0u64;
            for op in ops {
                lpn += 1;
                match op {
                    Op::Slc => {
                        let _ = b.program_slc(Lpn(lpn));
                    }
                    Op::Reprog => {
                        let _ = b.reprogram_next(Lpn(lpn), 2);
                    }
                    Op::InvalidateFirst => {
                        let first = b.valid_pages().next();
                        if let Some(p) = first {
                            b.invalidate(p).map_err(|e| e.to_string())?;
                        }
                    }
                    Op::Advance => {
                        let _ = b.advance_group();
                    }
                }
                let recount = b.valid_pages().count() as u32;
                if recount != b.valid_count() {
                    return Err(format!(
                        "bitmap count {recount} != counter {}",
                        b.valid_count()
                    ));
                }
                if b.valid_count() > b.written_count() {
                    return Err("valid > written".into());
                }
            }
            Ok(())
        });
    }

    /// Property: an arena-backed block and an inline block stay in
    /// observable lockstep (results, errors, and full page state)
    /// under random op sequences — the SoA layout differential.
    #[test]
    fn soa_matches_inline_under_random_ops() {
        #[derive(Clone, Debug)]
        enum Op {
            Slc,
            TlcPage,
            Oneshot,
            Reprog,
            InvalidateFirst,
            Advance,
            Erase,
            SetMode(BlockMode),
        }
        let gen = vec_of(
            one_of(vec![
                Op::Slc,
                Op::TlcPage,
                Op::Oneshot,
                Op::Reprog,
                Op::InvalidateFirst,
                Op::Advance,
                Op::Erase,
                Op::SetMode(BlockMode::Slc),
                Op::SetMode(BlockMode::Ips),
                Op::SetMode(BlockMode::Tlc),
            ]),
            0,
            96,
        );
        prop::check("soa matches inline", 256, gen, |ops| {
            let g = presets::small().geometry;
            let mut inline = Block::new(&g, 2);
            let mut meta = Block::meta_only(&g, 2);
            let mut arena = PlaneArena::new(&g, 1);
            let mut lpn = 0u64;
            for op in ops {
                lpn += 1;
                let mut soa = arena.block_mut(&mut meta.meta, 0);
                let (a, b): (Result<u64>, Result<u64>) = match op {
                    Op::Slc => (
                        inline.program_slc(Lpn(lpn)).map(u64::from),
                        soa.program_slc(Lpn(lpn)).map(u64::from),
                    ),
                    Op::TlcPage => (
                        inline.program_tlc_page(Lpn(lpn)).map(u64::from),
                        soa.program_tlc_page(Lpn(lpn)).map(u64::from),
                    ),
                    Op::Oneshot => {
                        let ls = [Lpn(lpn), Lpn(lpn + 1)];
                        (
                            inline.program_tlc_oneshot(&ls).map(|v| v.len() as u64),
                            soa.program_tlc_oneshot(&ls).map(|v| v.len() as u64),
                        )
                    }
                    Op::Reprog => (
                        inline.reprogram_next(Lpn(lpn), 2).map(|(p, f)| p as u64 * 2 + f as u64),
                        soa.reprogram_next(Lpn(lpn), 2).map(|(p, f)| p as u64 * 2 + f as u64),
                    ),
                    Op::InvalidateFirst => match inline.valid_pages().next() {
                        Some(p) => (
                            inline.invalidate(p).map(|_| 0),
                            soa.invalidate(p).map(|_| 0),
                        ),
                        None => continue,
                    },
                    Op::Advance => (
                        inline.advance_group().map(u64::from),
                        soa.advance_group().map(u64::from),
                    ),
                    Op::Erase => (inline.erase().map(|_| 0), soa.erase().map(|_| 0)),
                    Op::SetMode(m) => {
                        (inline.set_mode(m).map(|_| 0), soa.set_mode(m).map(|_| 0))
                    }
                };
                match (&a, &b) {
                    (Ok(x), Ok(y)) if x == y => {}
                    (Err(_), Err(_)) => {}
                    _ => return Err(format!("divergent results: {a:?} vs {b:?}")),
                }
                let iv = inline.as_view();
                let av = arena.block_ref(&meta.meta, 0);
                if (iv.valid_count(), iv.written_count(), iv.erase_count())
                    != (av.valid_count(), av.written_count(), av.erase_count())
                {
                    return Err("counter divergence".into());
                }
                for pib in 0..g.pages_per_block {
                    if iv.is_valid(pib) != av.is_valid(pib)
                        || iv.is_written(pib) != av.is_written(pib)
                        || iv.lpn_at(pib) != av.lpn_at(pib)
                        || iv.page_kind(pib) != av.page_kind(pib)
                    {
                        return Err(format!("page {pib} state divergence"));
                    }
                }
            }
            Ok(())
        });
    }
}
