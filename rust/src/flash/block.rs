//! Block state: word-line states, validity bitmap, sequential write
//! pointers, and the IPS layer-group window.
//!
//! A block operates in one of three modes ([`BlockMode`]):
//!
//! * `Tlc` — normal high-density block, one-shot programmed word line
//!   by word line;
//! * `Slc` — traditional SLC-cache block: every word line stores one
//!   page (this is how the baseline/Turbo-Write cache and the
//!   cooperative design's traditional part are built);
//! * `Ips` — the paper's in-place-switch block: word lines are first
//!   SLC-programmed *inside the active layer group* (default two
//!   layers, the reprogram reliability window of [7]), later
//!   reprogrammed in place to full TLC, after which the next layer
//!   group becomes the new SLC window (paper Fig. 6a, Steps 1–3).

use super::cell::{PageKind, WlState};
use super::geometry::Lpn;
use crate::config::Geometry;
use crate::{Error, Result};

/// Operating mode of a block (assigned while erased, sticky until
/// reassigned).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockMode {
    /// One-shot TLC block.
    Tlc,
    /// Traditional SLC-cache block (1 page / word line over the whole block).
    Slc,
    /// IPS block with a moving SLC layer-group window.
    Ips,
}

/// Sentinel for "no LPN" in per-page back-pointers.
pub const NO_LPN: u32 = u32::MAX;

/// One flash block.
#[derive(Clone, Debug)]
pub struct Block {
    mode: BlockMode,
    /// Per-word-line state.
    wls: Vec<WlState>,
    /// Validity bitmap over TLC page slots (`pages_per_block` bits).
    valid: Vec<u64>,
    /// Back-pointers: LPN stored in each page slot (for GC); lazily
    /// allocated on first program to keep untouched blocks cheap.
    p2l: Vec<u32>,
    /// Number of currently valid pages.
    valid_count: u32,
    /// Number of written (programmed) pages, valid or not.
    written_count: u32,
    /// Next word line for an initial program.
    write_wl: u32,
    /// `Tlc` mode only: next bit within `write_wl` for page-granular
    /// programming (0 = LSB, 1 = CSB, 2 = MSB).
    write_bit: u8,
    /// IPS: index of the active layer group serving as the SLC window.
    active_group: u32,
    /// IPS: next word line (within the active group) to reprogram.
    reprog_wl: u32,
    /// Lifetime erase count (wear levelling metric, paper §IV-D2).
    erase_count: u32,
    /// Word lines per block (cached from geometry).
    n_wls: u32,
    /// Word lines per IPS layer group.
    group_wls: u32,
}

impl Block {
    /// Create an erased block.
    pub fn new(g: &Geometry, group_layers: u32) -> Block {
        let n_wls = g.wordlines_per_block();
        Block {
            mode: BlockMode::Tlc,
            wls: vec![WlState::ERASED; n_wls as usize],
            valid: vec![0u64; (g.pages_per_block as usize + 63) / 64],
            p2l: Vec::new(),
            valid_count: 0,
            written_count: 0,
            write_wl: 0,
            write_bit: 0,
            active_group: 0,
            reprog_wl: 0,
            erase_count: 0,
            n_wls,
            group_wls: group_layers * g.wordlines_per_layer,
        }
    }

    // --- accessors -------------------------------------------------

    /// Current mode.
    pub fn mode(&self) -> BlockMode {
        self.mode
    }
    /// Valid page count.
    pub fn valid_count(&self) -> u32 {
        self.valid_count
    }
    /// Written (programmed) page count, valid or not.
    pub fn written_count(&self) -> u32 {
        self.written_count
    }
    /// Invalid (written but superseded) page count.
    pub fn invalid_count(&self) -> u32 {
        self.written_count - self.valid_count
    }
    /// Lifetime erases.
    pub fn erase_count(&self) -> u32 {
        self.erase_count
    }
    /// Seed the lifetime erase count before any traffic (fleet wear
    /// heterogeneity: a pre-aged device starts with uneven wear, which
    /// perturbs the min-erase allocator). Only legal on a pristine,
    /// fully erased block.
    pub fn pre_age(&mut self, erases: u32) -> Result<()> {
        if !self.is_erased() || self.erase_count != 0 {
            return Err(Error::invariant("pre_age of a used block"));
        }
        self.erase_count = erases;
        Ok(())
    }
    /// Is the block completely erased?
    pub fn is_erased(&self) -> bool {
        self.written_count == 0
            && self.write_wl == 0
            && self.write_bit == 0
            && self.wls.iter().all(|w| w.is_erased())
    }
    /// Word-line state (for audits).
    pub fn wl(&self, wl: u32) -> WlState {
        self.wls[wl as usize]
    }
    /// IPS active layer group index.
    pub fn active_group(&self) -> u32 {
        self.active_group
    }
    /// Number of layer groups in this block.
    pub fn group_count(&self) -> u32 {
        self.n_wls / self.group_wls
    }

    /// Page validity.
    pub fn is_valid(&self, pib: u32) -> bool {
        self.valid[(pib / 64) as usize] >> (pib % 64) & 1 == 1
    }

    /// Has the page slot been programmed?
    pub fn is_written(&self, pib: u32) -> bool {
        let wl = pib / 3;
        let bit = (pib % 3) as u8;
        self.wls[wl as usize].pages() > bit
    }

    /// LPN stored at a page slot (panics if never programmed).
    pub fn lpn_at(&self, pib: u32) -> Option<Lpn> {
        let v = *self.p2l.get(pib as usize)?;
        if v == NO_LPN {
            None
        } else {
            Some(Lpn(v as u64))
        }
    }

    /// Storage kind of a page (drives read latency).
    ///
    /// `Slc` blocks always read at SLC speed; `Tlc` blocks at TLC
    /// speed; `Ips` blocks depend on how far the word line has been
    /// reprogrammed (an SLC page reads fast until its word line holds
    /// ≥ 2 bits per cell).
    pub fn page_kind(&self, pib: u32) -> PageKind {
        match self.mode {
            BlockMode::Slc => PageKind::Slc,
            BlockMode::Tlc => PageKind::Tlc,
            BlockMode::Ips => self.wls[(pib / 3) as usize].kind(),
        }
    }

    /// Iterate valid page slots (ascending).
    pub fn valid_pages(&self) -> impl Iterator<Item = u32> + '_ {
        self.valid
            .iter()
            .enumerate()
            .flat_map(|(w, &bits)| BitIter { bits, base: w as u32 * 64 })
    }

    // --- mode management -------------------------------------------

    /// Assign a mode; only legal while erased.
    pub fn set_mode(&mut self, mode: BlockMode) -> Result<()> {
        if !self.is_erased() {
            return Err(Error::Flash("mode change on non-erased block".into()));
        }
        self.mode = mode;
        Ok(())
    }

    // --- SLC window / capacity queries ------------------------------

    /// Word lines still available for an initial SLC program.
    ///
    /// `Slc` blocks: the rest of the block. `Ips` blocks: the erased
    /// remainder of the active layer group. `Tlc` blocks: 0.
    pub fn slc_free_wls(&self) -> u32 {
        match self.mode {
            BlockMode::Slc => self.n_wls - self.write_wl,
            BlockMode::Ips => {
                let group_end = (self.active_group + 1) * self.group_wls;
                group_end.saturating_sub(self.write_wl.max(self.active_group * self.group_wls))
            }
            BlockMode::Tlc => 0,
        }
    }

    /// IPS: word lines in the active group that are programmed but not
    /// yet full TLC (i.e. reprogram work remaining, in units of word
    /// lines; each needs up to 2 reprogram operations).
    pub fn reprogrammable_wls(&self) -> u32 {
        if self.mode != BlockMode::Ips {
            return 0;
        }
        let group_start = self.active_group * self.group_wls;
        let group_end = group_start + self.group_wls;
        (group_start.max(self.reprog_wl)..group_end.min(self.write_wl))
            .filter(|&wl| !self.wls[wl as usize].is_full() && !self.wls[wl as usize].is_erased())
            .count() as u32
    }

    /// IPS: individual reprogram operations remaining in the active group.
    pub fn reprogram_ops_remaining(&self) -> u32 {
        if self.mode != BlockMode::Ips {
            return 0;
        }
        let group_start = self.active_group * self.group_wls;
        let group_end = group_start + self.group_wls;
        (group_start..group_end.min(self.write_wl))
            .map(|wl| 3u32.saturating_sub(self.wls[wl as usize].pages() as u32))
            .sum()
    }

    /// Free one-shot TLC word lines (for `Tlc` blocks; only whole
    /// erased word lines count).
    pub fn tlc_free_wls(&self) -> u32 {
        match self.mode {
            BlockMode::Tlc => {
                let partial = if self.write_bit > 0 { 1 } else { 0 };
                self.n_wls - self.write_wl - partial
            }
            _ => 0,
        }
    }

    /// Free page slots for page-granular TLC programming.
    pub fn tlc_free_pages(&self) -> u32 {
        match self.mode {
            BlockMode::Tlc => {
                (self.n_wls - self.write_wl) * 3 - self.write_bit as u32
            }
            _ => 0,
        }
    }

    /// IPS: the word line the next reprogram operation will target
    /// (programmed but not full, inside the active group), if any.
    pub fn next_reprogram_wl(&self) -> Option<u32> {
        if self.mode != BlockMode::Ips {
            return None;
        }
        let group_start = self.active_group * self.group_wls;
        let group_end = group_start + self.group_wls;
        (group_start.max(self.reprog_wl)..group_end.min(self.write_wl)).find(|&wl| {
            let s = self.wls[wl as usize];
            !s.is_erased() && !s.is_full()
        })
    }

    /// Does the block have another layer group after the active one?
    pub fn has_next_group(&self) -> bool {
        self.mode == BlockMode::Ips && self.active_group + 1 < self.group_count()
    }

    // --- programming -----------------------------------------------

    fn ensure_p2l(&mut self) {
        if self.p2l.is_empty() {
            self.p2l = vec![NO_LPN; self.wls.len() * 3];
        }
    }

    fn mark_written(&mut self, pib: u32, lpn: Lpn) {
        self.ensure_p2l();
        self.p2l[pib as usize] = lpn.0 as u32;
        self.valid[(pib / 64) as usize] |= 1 << (pib % 64);
        self.valid_count += 1;
        self.written_count += 1;
    }

    /// Program one SLC page at the write pointer; returns the page slot.
    ///
    /// Legal on `Slc` blocks anywhere, on `Ips` blocks only inside the
    /// active layer group.
    pub fn program_slc(&mut self, lpn: Lpn) -> Result<u32> {
        match self.mode {
            BlockMode::Tlc => {
                return Err(Error::Flash("SLC program on TLC block".into()));
            }
            BlockMode::Ips => {
                let group_start = self.active_group * self.group_wls;
                let group_end = group_start + self.group_wls;
                if self.write_wl < group_start || self.write_wl >= group_end {
                    return Err(Error::Flash(format!(
                        "IPS SLC program outside active group (wl {} not in [{},{}))",
                        self.write_wl, group_start, group_end
                    )));
                }
            }
            BlockMode::Slc => {}
        }
        if self.write_wl >= self.n_wls {
            return Err(Error::Flash("SLC program past end of block".into()));
        }
        let wl = self.write_wl;
        self.wls[wl as usize] = self.wls[wl as usize].program_slc()?;
        self.write_wl += 1;
        let pib = wl * 3;
        self.mark_written(pib, lpn);
        Ok(pib)
    }

    /// One-shot TLC program of the next word line with 1..=3 LPNs;
    /// unfilled slots are wasted (marked written+invalid is *not*
    /// needed — they are simply never valid). Returns the page slots
    /// actually used.
    pub fn program_tlc_oneshot(&mut self, lpns: &[Lpn]) -> Result<Vec<u32>> {
        if self.mode != BlockMode::Tlc {
            return Err(Error::Flash("one-shot TLC program on non-TLC block".into()));
        }
        if lpns.is_empty() || lpns.len() > 3 {
            return Err(Error::Flash("one-shot program needs 1..=3 pages".into()));
        }
        if self.write_wl >= self.n_wls {
            return Err(Error::Flash("TLC program past end of block".into()));
        }
        if self.write_bit != 0 {
            return Err(Error::Flash(
                "one-shot program on a partially page-programmed word line".into(),
            ));
        }
        let wl = self.write_wl;
        self.wls[wl as usize] = self.wls[wl as usize].program_tlc_oneshot()?;
        self.write_wl += 1;
        let mut slots = Vec::with_capacity(lpns.len());
        for (i, &lpn) in lpns.iter().enumerate() {
            let pib = wl * 3 + i as u32;
            self.mark_written(pib, lpn);
            slots.push(pib);
        }
        // wasted slots still count as written capacity
        self.written_count += (3 - lpns.len()) as u32;
        Ok(slots)
    }

    /// Page-granular TLC program: writes the next page slot (LSB →
    /// CSB → MSB per word line, sequentially) at TLC-program latency.
    /// This is the host-write path's TLC programming model (paper
    /// Table I: "3 ms for TLC write" per page). Returns the page slot.
    pub fn program_tlc_page(&mut self, lpn: Lpn) -> Result<u32> {
        if self.mode != BlockMode::Tlc {
            return Err(Error::Flash("page-granular TLC program on non-TLC block".into()));
        }
        if self.write_wl >= self.n_wls {
            return Err(Error::Flash("TLC program past end of block".into()));
        }
        let wl = self.write_wl;
        self.wls[wl as usize] = self.wls[wl as usize].program_incremental()?;
        let pib = wl * 3 + self.write_bit as u32;
        self.write_bit += 1;
        if self.write_bit == 3 {
            self.write_bit = 0;
            self.write_wl += 1;
        }
        self.mark_written(pib, lpn);
        Ok(pib)
    }

    /// One reprogram operation on the IPS window: adds one page (CSB
    /// or MSB) to the next not-yet-full word line in the active group,
    /// sequentially. Returns `(page_slot, wordline_now_full)`.
    pub fn reprogram_next(&mut self, lpn: Lpn, max_reprograms: u32) -> Result<(u32, bool)> {
        if self.mode != BlockMode::Ips {
            return Err(Error::Flash("reprogram on non-IPS block".into()));
        }
        let group_start = self.active_group * self.group_wls;
        let group_end = group_start + self.group_wls;
        // advance the reprogram pointer past full word lines
        let mut wl = self.reprog_wl.max(group_start);
        while wl < group_end && (self.wls[wl as usize].is_full()) {
            wl += 1;
        }
        if wl >= group_end || wl >= self.write_wl {
            return Err(Error::Flash("no reprogrammable word line in active group".into()));
        }
        let state = self.wls[wl as usize];
        if state.is_erased() {
            return Err(Error::Flash("reprogram reached an erased word line".into()));
        }
        let bit = state.next_bit();
        self.wls[wl as usize] = state.reprogram(max_reprograms)?;
        let pib = wl * 3 + bit as u32;
        self.mark_written(pib, lpn);
        let full = self.wls[wl as usize].is_full();
        self.reprog_wl = if full { wl + 1 } else { wl };
        Ok((pib, full))
    }

    /// Advance the IPS window to the next layer group once the active
    /// one is fully reprogrammed (paper Fig. 6a Step 3). Returns the new
    /// group index.
    pub fn advance_group(&mut self) -> Result<u32> {
        if self.mode != BlockMode::Ips {
            return Err(Error::Flash("advance_group on non-IPS block".into()));
        }
        let group_start = self.active_group * self.group_wls;
        let group_end = group_start + self.group_wls;
        let all_full =
            (group_start..group_end).all(|wl| self.wls[wl as usize].is_full());
        if !all_full {
            return Err(Error::Flash(
                "cannot advance: active group not fully reprogrammed".into(),
            ));
        }
        if !self.has_next_group() {
            return Err(Error::Flash("no next layer group".into()));
        }
        self.active_group += 1;
        self.write_wl = self.active_group * self.group_wls;
        self.reprog_wl = self.write_wl;
        Ok(self.active_group)
    }

    // --- invalidation / erase ---------------------------------------

    /// Invalidate a page slot (its LPN was overwritten or migrated).
    pub fn invalidate(&mut self, pib: u32) -> Result<()> {
        if !self.is_valid(pib) {
            return Err(Error::invariant(format!("double invalidate of page {pib}")));
        }
        self.valid[(pib / 64) as usize] &= !(1 << (pib % 64));
        self.valid_count -= 1;
        if !self.p2l.is_empty() {
            self.p2l[pib as usize] = NO_LPN;
        }
        Ok(())
    }

    /// Erase the block. Only legal when no valid pages remain.
    pub fn erase(&mut self) -> Result<()> {
        if self.valid_count != 0 {
            return Err(Error::invariant(format!(
                "erase of block with {} valid pages",
                self.valid_count
            )));
        }
        for wl in &mut self.wls {
            *wl = wl.erase();
        }
        for w in &mut self.valid {
            *w = 0;
        }
        self.p2l.clear();
        self.p2l.shrink_to_fit();
        self.written_count = 0;
        self.write_wl = 0;
        self.write_bit = 0;
        self.active_group = 0;
        self.reprog_wl = 0;
        self.erase_count += 1;
        Ok(())
    }
}

struct BitIter {
    bits: u64,
    base: u32,
}

impl Iterator for BitIter {
    type Item = u32;
    #[inline]
    fn next(&mut self) -> Option<u32> {
        if self.bits == 0 {
            return None;
        }
        let tz = self.bits.trailing_zeros();
        self.bits &= self.bits - 1;
        Some(self.base + tz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::prop::{self, one_of, vec_of};

    fn small_block() -> (Block, Geometry) {
        let g = presets::small().geometry;
        (Block::new(&g, 2), g)
    }

    #[test]
    fn slc_block_fills_every_wordline() {
        let (mut b, g) = small_block();
        b.set_mode(BlockMode::Slc).unwrap();
        let n = g.wordlines_per_block();
        for i in 0..n {
            let pib = b.program_slc(Lpn(i as u64)).unwrap();
            assert_eq!(pib, i * 3);
        }
        assert_eq!(b.slc_free_wls(), 0);
        assert!(b.program_slc(Lpn(0)).is_err());
        assert_eq!(b.valid_count(), n);
    }

    #[test]
    fn ips_block_full_cycle() {
        let (mut b, g) = small_block();
        b.set_mode(BlockMode::Ips).unwrap();
        let group_wls = 2 * g.wordlines_per_layer; // 4
        // Step 1: fill the SLC window
        for i in 0..group_wls {
            b.program_slc(Lpn(i as u64)).unwrap();
        }
        assert_eq!(b.slc_free_wls(), 0);
        assert!(b.program_slc(Lpn(99)).is_err(), "window exhausted");
        // Step 2: reprogram 2 ops per word line
        assert_eq!(b.reprogram_ops_remaining(), group_wls * 2);
        let mut added = 0;
        while b.reprogram_ops_remaining() > 0 {
            let (_pib, _full) = b.reprogram_next(Lpn(100 + added), 2).unwrap();
            added += 1;
        }
        assert_eq!(added as u32, group_wls * 2);
        // Step 3: advance to the next group; SLC writes flow again
        b.advance_group().unwrap();
        assert_eq!(b.active_group(), 1);
        assert_eq!(b.slc_free_wls(), group_wls);
        b.program_slc(Lpn(500)).unwrap();
        // original SLC data still valid (in-place, no migration)
        assert!(b.is_valid(0));
        assert_eq!(b.lpn_at(0), Some(Lpn(0)));
    }

    #[test]
    fn ips_reprogram_requires_window() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Ips).unwrap();
        assert!(b.reprogram_next(Lpn(0), 2).is_err(), "nothing programmed yet");
        b.program_slc(Lpn(1)).unwrap();
        let (pib, full) = b.reprogram_next(Lpn(2), 2).unwrap();
        assert_eq!(pib, 1); // CSB of wl 0
        assert!(!full);
        let (pib, full) = b.reprogram_next(Lpn(3), 2).unwrap();
        assert_eq!(pib, 2); // MSB of wl 0
        assert!(full);
        assert!(b.reprogram_next(Lpn(4), 2).is_err(), "wl1 never SLC-programmed");
    }

    #[test]
    fn advance_requires_full_group() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Ips).unwrap();
        b.program_slc(Lpn(1)).unwrap();
        assert!(b.advance_group().is_err());
    }

    #[test]
    fn oneshot_tlc_and_waste_accounting() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Tlc).unwrap();
        let slots = b.program_tlc_oneshot(&[Lpn(1), Lpn(2), Lpn(3)]).unwrap();
        assert_eq!(slots, vec![0, 1, 2]);
        let slots = b.program_tlc_oneshot(&[Lpn(4)]).unwrap();
        assert_eq!(slots, vec![3]);
        assert_eq!(b.valid_count(), 4);
        assert_eq!(b.written_count(), 6); // 2 slots wasted on wl 1
    }

    #[test]
    fn page_granular_tlc_fills_sequentially() {
        let (mut b, g) = small_block();
        b.set_mode(BlockMode::Tlc).unwrap();
        let total = g.pages_per_block;
        for i in 0..total {
            let pib = b.program_tlc_page(Lpn(i as u64)).unwrap();
            assert_eq!(pib, i, "slots fill in order");
        }
        assert_eq!(b.tlc_free_pages(), 0);
        assert!(b.program_tlc_page(Lpn(0)).is_err());
        assert_eq!(b.valid_count(), total);
        assert_eq!(b.written_count(), total);
    }

    #[test]
    fn oneshot_rejected_mid_wordline() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Tlc).unwrap();
        b.program_tlc_page(Lpn(1)).unwrap(); // wl0 partially programmed
        assert!(b.program_tlc_oneshot(&[Lpn(2), Lpn(3), Lpn(4)]).is_err());
        // finish the word line page-granularly, then one-shot works
        b.program_tlc_page(Lpn(2)).unwrap();
        b.program_tlc_page(Lpn(3)).unwrap();
        b.program_tlc_oneshot(&[Lpn(4), Lpn(5), Lpn(6)]).unwrap();
    }

    #[test]
    fn erase_rules() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Slc).unwrap();
        b.program_slc(Lpn(7)).unwrap();
        assert!(b.erase().is_err(), "valid page present");
        b.invalidate(0).unwrap();
        assert!(b.invalidate(0).is_err(), "double invalidate");
        b.erase().unwrap();
        assert!(b.is_erased());
        assert_eq!(b.erase_count(), 1);
        // mode change now legal
        b.set_mode(BlockMode::Tlc).unwrap();
    }

    #[test]
    fn valid_pages_iterator() {
        let (mut b, _g) = small_block();
        b.set_mode(BlockMode::Tlc).unwrap();
        b.program_tlc_oneshot(&[Lpn(1), Lpn(2), Lpn(3)]).unwrap();
        b.invalidate(1).unwrap();
        let v: Vec<u32> = b.valid_pages().collect();
        assert_eq!(v, vec![0, 2]);
    }

    /// Property: random legal op sequences keep counts consistent.
    #[test]
    fn block_counters_consistent_under_random_ops() {
        #[derive(Clone, Debug)]
        enum Op {
            Slc,
            Reprog,
            InvalidateFirst,
            Advance,
        }
        let gen = vec_of(
            one_of(vec![Op::Slc, Op::Reprog, Op::InvalidateFirst, Op::Advance]),
            0,
            64,
        );
        prop::check("block counters consistent", 256, gen, |ops| {
            let g = presets::small().geometry;
            let mut b = Block::new(&g, 2);
            b.set_mode(BlockMode::Ips).unwrap();
            let mut lpn = 0u64;
            for op in ops {
                lpn += 1;
                match op {
                    Op::Slc => {
                        let _ = b.program_slc(Lpn(lpn));
                    }
                    Op::Reprog => {
                        let _ = b.reprogram_next(Lpn(lpn), 2);
                    }
                    Op::InvalidateFirst => {
                        let first = b.valid_pages().next();
                        if let Some(p) = first {
                            b.invalidate(p).map_err(|e| e.to_string())?;
                        }
                    }
                    Op::Advance => {
                        let _ = b.advance_group();
                    }
                }
                let recount = b.valid_pages().count() as u32;
                if recount != b.valid_count() {
                    return Err(format!(
                        "bitmap count {recount} != counter {}",
                        b.valid_count()
                    ));
                }
                if b.valid_count() > b.written_count() {
                    return Err("valid > written".into());
                }
            }
            Ok(())
        });
    }
}
