//! Word-line cell state: how many pages a word line currently stores
//! and how it got there (program vs reprogram).
//!
//! A TLC word line stores up to three pages (LSB/CSB/MSB). The paper's
//! IPS design uses it in three shapes:
//!
//! * **TLC one-shot**: erased → 3 pages in one program operation;
//! * **SLC**: erased → 1 page (two low voltage states, Fig. 6b);
//! * **IPS reprogram**: SLC word line → +CSB (reprogram #1) → +MSB
//!   (reprogram #2), each at TLC-program latency.
//!
//! [`WlState`] tracks `(pages_programmed, reprogram_count)` in a single
//! byte; the restrictions of the device study [7] — at most
//! `max_reprograms` reprograms per word line, reprogramming only inside
//! the active two-layer window, sequential order — are enforced here
//! and in [`super::block`].

use crate::{Error, Result};

/// How a page is currently stored — determines read latency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageKind {
    /// Word line holds a single bit per cell: SLC read speed.
    Slc,
    /// Word line holds ≥ 2 bits per cell: TLC read speed.
    Tlc,
}

/// Per-word-line programming state, packed into one byte:
/// low nibble = pages programmed (0..=3), high nibble = reprogram count.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct WlState(u8);

impl WlState {
    /// Erased, never programmed.
    pub const ERASED: WlState = WlState(0);

    /// Pages currently programmed on this word line (0..=3).
    #[inline]
    pub fn pages(self) -> u8 {
        self.0 & 0x0f
    }

    /// Reprogram operations applied since the initial program.
    #[inline]
    pub fn reprograms(self) -> u8 {
        self.0 >> 4
    }

    /// Is the word line erased?
    #[inline]
    pub fn is_erased(self) -> bool {
        self.0 == 0
    }

    /// Current storage kind (valid only if programmed).
    #[inline]
    pub fn kind(self) -> PageKind {
        if self.pages() <= 1 {
            PageKind::Slc
        } else {
            PageKind::Tlc
        }
    }

    /// Word line is fully TLC (3 pages).
    #[inline]
    pub fn is_full(self) -> bool {
        self.pages() == 3
    }

    /// Apply an SLC program (erased → 1 page, bit 0).
    pub fn program_slc(self) -> Result<WlState> {
        if !self.is_erased() {
            return Err(Error::Flash(format!(
                "SLC program on non-erased word line ({} pages)",
                self.pages()
            )));
        }
        Ok(WlState(1))
    }

    /// Apply a TLC one-shot program (erased → 3 pages).
    pub fn program_tlc_oneshot(self) -> Result<WlState> {
        if !self.is_erased() {
            return Err(Error::Flash(format!(
                "one-shot TLC program on non-erased word line ({} pages)",
                self.pages()
            )));
        }
        Ok(WlState(3))
    }

    /// Apply a page-granular (incremental / shadow) TLC program: adds
    /// one page without consuming reprogram budget. Only legal on
    /// word lines of `Tlc`-mode blocks (enforced by [`super::block`]);
    /// this is how the host write path programs TLC space one page at
    /// a time at the Table-I 3 ms latency.
    pub fn program_incremental(self) -> Result<WlState> {
        let pages = self.pages();
        if pages >= 3 {
            return Err(Error::Flash("incremental program on full word line".into()));
        }
        if self.reprograms() > 0 {
            return Err(Error::Flash(
                "incremental program on a reprogrammed word line".into(),
            ));
        }
        Ok(WlState((pages + 1) | (self.0 & 0xf0)))
    }

    /// Apply one reprogram operation (adds exactly one page).
    ///
    /// `max_reprograms` is the per-word-line budget (paper/[7]: IPS uses
    /// 2; the device tolerates at most 4).
    pub fn reprogram(self, max_reprograms: u32) -> Result<WlState> {
        let pages = self.pages();
        if pages == 0 {
            return Err(Error::Flash("reprogram on erased word line".into()));
        }
        if pages >= 3 {
            return Err(Error::Flash("reprogram on full TLC word line".into()));
        }
        let reps = self.reprograms();
        if reps as u32 >= max_reprograms {
            return Err(Error::Flash(format!(
                "reprogram budget exhausted ({reps}/{max_reprograms})"
            )));
        }
        Ok(WlState((pages + 1) | ((reps + 1) << 4)))
    }

    /// Erase back to the pristine state.
    #[inline]
    pub fn erase(self) -> WlState {
        WlState::ERASED
    }

    /// Bit position the *next* reprogram would fill (1 = CSB, 2 = MSB).
    #[inline]
    pub fn next_bit(self) -> u8 {
        self.pages()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, one_of, vec_of};

    #[test]
    fn slc_then_two_reprograms_reach_tlc() {
        let wl = WlState::ERASED.program_slc().unwrap();
        assert_eq!(wl.pages(), 1);
        assert_eq!(wl.kind(), PageKind::Slc);
        let wl = wl.reprogram(2).unwrap();
        assert_eq!(wl.pages(), 2);
        assert_eq!(wl.kind(), PageKind::Tlc);
        assert_eq!(wl.reprograms(), 1);
        let wl = wl.reprogram(2).unwrap();
        assert!(wl.is_full());
        assert_eq!(wl.reprograms(), 2);
        // third reprogram rejected: word line is full
        assert!(wl.reprogram(4).is_err());
    }

    #[test]
    fn oneshot_tlc() {
        let wl = WlState::ERASED.program_tlc_oneshot().unwrap();
        assert!(wl.is_full());
        assert_eq!(wl.reprograms(), 0);
        assert!(wl.program_slc().is_err());
        assert!(wl.program_tlc_oneshot().is_err());
    }

    #[test]
    fn incremental_tlc_fills_without_budget() {
        let mut wl = WlState::ERASED;
        for expect in 1..=3u8 {
            wl = wl.program_incremental().unwrap();
            assert_eq!(wl.pages(), expect);
            assert_eq!(wl.reprograms(), 0);
        }
        assert!(wl.program_incremental().is_err());
        // reprogrammed word lines cannot be incrementally programmed
        let wl = WlState::ERASED.program_slc().unwrap().reprogram(2).unwrap();
        assert!(wl.program_incremental().is_err());
    }

    #[test]
    fn reprogram_budget_enforced() {
        let wl = WlState::ERASED.program_slc().unwrap();
        let wl = wl.reprogram(1).unwrap();
        assert!(wl.reprogram(1).is_err(), "budget of 1 exhausted");
        assert!(wl.reprogram(2).is_ok(), "budget of 2 allows the second");
    }

    #[test]
    fn erased_cannot_be_reprogrammed() {
        assert!(WlState::ERASED.reprogram(2).is_err());
    }

    #[test]
    fn erase_resets() {
        let wl = WlState::ERASED.program_slc().unwrap().reprogram(2).unwrap();
        assert_eq!(wl.erase(), WlState::ERASED);
    }

    #[test]
    fn next_bit_tracks_pages() {
        let wl = WlState::ERASED.program_slc().unwrap();
        assert_eq!(wl.next_bit(), 1); // CSB next
        let wl = wl.reprogram(2).unwrap();
        assert_eq!(wl.next_bit(), 2); // MSB next
    }

    /// Property: under ANY random op sequence, the invariants hold —
    /// pages ∈ [0,3]; reprograms never exceed the budget; pages only
    /// reachable through legal transitions.
    #[test]
    fn random_op_sequences_preserve_invariants() {
        #[derive(Clone, Debug)]
        enum Op {
            ProgSlc,
            ProgTlc,
            Reprog,
            Erase,
        }
        let gen = vec_of(
            one_of(vec![Op::ProgSlc, Op::ProgTlc, Op::Reprog, Op::Erase]),
            0,
            24,
        );
        prop::check("wl state machine closed under ops", 512, gen, |ops| {
            let mut wl = WlState::ERASED;
            for op in ops {
                let next = match op {
                    Op::ProgSlc => wl.program_slc(),
                    Op::ProgTlc => wl.program_tlc_oneshot(),
                    Op::Reprog => wl.reprogram(2),
                    Op::Erase => Ok(wl.erase()),
                };
                if let Ok(n) = next {
                    wl = n;
                }
                if wl.pages() > 3 {
                    return Err(format!("pages out of range: {wl:?}"));
                }
                if wl.reprograms() > 2 {
                    return Err(format!("budget exceeded: {wl:?}"));
                }
                if wl.reprograms() > 0 && wl.pages() <= wl.reprograms() {
                    return Err(format!("inconsistent counts: {wl:?}"));
                }
            }
            Ok(())
        });
    }
}
