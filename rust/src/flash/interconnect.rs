//! The interconnect resource model: channel bus, die, and plane
//! occupancy (paper §II-A: channel → chip → die → plane).
//!
//! The historical timing model charged every flash operation against a
//! single per-plane `busy_until` lump, which makes channel-bus transfer
//! time and die-level exclusivity — the contention that shapes tail
//! latency once many tenants share a device — invisible. This module
//! is the explicit three-level replacement:
//!
//! * **channel bus** — the data-transfer phase. Moving one page over a
//!   channel costs `timing.bus_ns_per_page`; transfers on one channel
//!   serialize (all chips of a channel share its bus), transfers on
//!   different channels proceed in parallel. Programs transfer *before*
//!   their array phase (host → die data-in), reads transfer *after*
//!   (die → host data-out). Erases move no data.
//! * **die** — one array operation at a time per die: while a plane of
//!   a die is programming/reading/erasing, its sibling planes wait
//!   (single charge-pump/control logic), *unless* the operations were
//!   issued as one multi-plane interleaved group (see
//!   [`Interconnect::occupy_program_group`]).
//! * **plane** — the array phase itself, the innermost serialization
//!   level (this is the only level the lump model knew about).
//!
//! Every scheduled operation returns a phase-split [`Completion`]:
//! `queued_ns` (time spent waiting for any of the three resources),
//! `transfer_ns` (bus), `array_ns` (in-array), plus the `start`/`end`
//! interval the rest of the stack always consumed.
//!
//! **Differential contract** (`sim.interconnect = false`, the default):
//! the legacy plane-lump arbitration survives unchanged behind the same
//! API and is the byte-for-byte oracle. With the model enabled,
//! `bus_ns_per_page = 0` and a degenerate geometry (one plane per die
//! per channel), the three levels collapse onto the plane level and the
//! new backend reproduces the lump completions exactly — pinned by
//! `tests/prop_interconnect.rs` and `tests/integration_interconnect.rs`.

use crate::config::{Config, Nanos};

/// A scheduled flash operation's service record.
///
/// `start`/`end` are the service interval (queueing shows up as
/// `start > issue`); the three `*_ns` fields split the operation's cost
/// by phase so engines can attribute request latency to waiting,
/// bus transfer, and array time. For composite FTL operations that fold
/// a dependent pre-read into one record (reprogram), the phase fields
/// cover the whole composite, so `queued + transfer + array` may exceed
/// `end - start`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Completion {
    /// Service start (≥ issue time; queueing shows up as `start > now`).
    pub start: Nanos,
    /// Service end — when the data is durable / the resources free up.
    pub end: Nanos,
    /// Time spent waiting on busy resources (bus, die, plane).
    pub queued_ns: Nanos,
    /// Channel-bus data-transfer phase (0 under the lump model).
    pub transfer_ns: Nanos,
    /// In-array phase (the Table-I operation latency).
    pub array_ns: Nanos,
}

impl Completion {
    /// A zero-cost completion at `now` (controller-served, no flash).
    pub fn instant(now: Nanos) -> Completion {
        Completion { start: now, end: now, queued_ns: 0, transfer_ns: 0, array_ns: 0 }
    }

    /// Service time (`end - start`).
    pub fn service_ns(&self) -> Nanos {
        self.end - self.start
    }

    /// Fold another operation's phase split into this record (composite
    /// FTL ops: reprogram's mandatory pre-read). `start`/`end` keep
    /// describing the final operation's service interval.
    pub fn fold_phases(&mut self, other: &Completion) {
        self.queued_ns += other.queued_ns;
        self.transfer_ns += other.transfer_ns;
        self.array_ns += other.array_ns;
    }
}

/// How an operation uses the channel bus relative to its array phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpClass {
    /// Array phase first, then data out over the bus (reads).
    Read,
    /// Data in over the bus first, then the array phase (programs).
    Program,
    /// Array phase only, no data moves (erase).
    ArrayOnly,
}

/// The three-level occupancy model (plus the legacy plane-lump mode).
pub struct Interconnect {
    /// `true` = channel/die/plane arbitration; `false` = the historical
    /// plane-lump oracle.
    enabled: bool,
    bus_ns_per_page: Nanos,
    planes_per_die: u32,
    planes_per_channel: u32,
    plane_busy: Vec<Nanos>,
    die_busy: Vec<Nanos>,
    channel_busy: Vec<Nanos>,
}

impl Interconnect {
    /// Build from a config (geometry + timing + `sim.interconnect`).
    pub fn new(cfg: &Config) -> Interconnect {
        let g = cfg.geometry;
        let planes = g.planes() as usize;
        let planes_per_die = g.planes_per_die;
        let planes_per_channel = g.chips_per_channel * g.dies_per_chip * g.planes_per_die;
        let dies = planes / planes_per_die as usize;
        Interconnect {
            enabled: cfg.sim.interconnect,
            bus_ns_per_page: cfg.timing.bus_ns_per_page,
            planes_per_die,
            planes_per_channel,
            plane_busy: vec![0; planes],
            die_busy: vec![0; dies],
            channel_busy: vec![0; g.channels as usize],
        }
    }

    /// Is the three-level model active (vs the plane-lump oracle)?
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Can operations on sibling planes of one die interleave as a
    /// multi-plane group? Requires the model *and* actual multi-plane
    /// dies — with one plane per die there is nothing to interleave,
    /// and the degenerate-geometry identity oracle relies on the
    /// batched paths reducing to the legacy issue order.
    pub fn multiplane(&self) -> bool {
        self.enabled && self.planes_per_die >= 2
    }

    /// Bus cost per page in force.
    pub fn bus_ns_per_page(&self) -> Nanos {
        self.bus_ns_per_page
    }

    #[inline]
    fn die_of(&self, plane: u32) -> usize {
        (plane / self.planes_per_die) as usize
    }

    #[inline]
    fn channel_of(&self, plane: u32) -> usize {
        (plane / self.planes_per_channel) as usize
    }

    /// When the plane's array next frees up.
    pub fn plane_busy_until(&self, plane: u32) -> Nanos {
        self.plane_busy[plane as usize]
    }

    /// Latest busy-until across every resource (drain point).
    pub fn all_idle_at(&self) -> Nanos {
        let p = self.plane_busy.iter().copied().max().unwrap_or(0);
        let d = self.die_busy.iter().copied().max().unwrap_or(0);
        let c = self.channel_busy.iter().copied().max().unwrap_or(0);
        p.max(d).max(c)
    }

    /// Schedule one flash operation on `plane` issued at `now`:
    /// `array_ns` of array time, `pages` pages over the bus (per the
    /// class). Returns the phase-split completion.
    pub fn occupy(
        &mut self,
        plane: u32,
        class: OpClass,
        array_ns: Nanos,
        pages: u32,
        now: Nanos,
    ) -> Completion {
        if !self.enabled {
            // the historical lump: one per-plane timeline, everything
            // attributed to the array phase
            let start = now.max(self.plane_busy[plane as usize]);
            let end = start + array_ns;
            self.plane_busy[plane as usize] = end;
            return Completion {
                start,
                end,
                queued_ns: start - now,
                transfer_ns: 0,
                array_ns,
            };
        }
        let die = self.die_of(plane);
        let ch = self.channel_of(plane);
        let xfer = self.bus_ns_per_page * pages as Nanos;
        match class {
            OpClass::Program if xfer > 0 => {
                // data-in over the bus, then the array phase
                let t0 = now.max(self.channel_busy[ch]);
                let t1 = t0 + xfer;
                self.channel_busy[ch] = t1;
                let a0 = t1.max(self.die_busy[die]).max(self.plane_busy[plane as usize]);
                let a1 = a0 + array_ns;
                self.die_busy[die] = a1;
                self.plane_busy[plane as usize] = a1;
                Completion {
                    start: t0,
                    end: a1,
                    queued_ns: (t0 - now) + (a0 - t1),
                    transfer_ns: xfer,
                    array_ns,
                }
            }
            OpClass::Read if xfer > 0 => {
                // array phase, then data-out over the bus
                let a0 = now.max(self.die_busy[die]).max(self.plane_busy[plane as usize]);
                let a1 = a0 + array_ns;
                self.die_busy[die] = a1;
                self.plane_busy[plane as usize] = a1;
                let t0 = a1.max(self.channel_busy[ch]);
                let t1 = t0 + xfer;
                self.channel_busy[ch] = t1;
                Completion {
                    start: a0,
                    end: t1,
                    queued_ns: (a0 - now) + (t0 - a1),
                    transfer_ns: xfer,
                    array_ns,
                }
            }
            // erase, or zero bus time: array phase only (a zero-length
            // transfer must not serialize anything — this is what makes
            // the bus_ns = 0 degenerate case collapse onto the lump)
            _ => {
                let a0 = now.max(self.die_busy[die]).max(self.plane_busy[plane as usize]);
                let a1 = a0 + array_ns;
                self.die_busy[die] = a1;
                self.plane_busy[plane as usize] = a1;
                Completion {
                    start: a0,
                    end: a1,
                    queued_ns: a0 - now,
                    transfer_ns: 0,
                    array_ns,
                }
            }
        }
    }

    /// Schedule a batch of program operations on **distinct planes**,
    /// issued together at `now`, as multi-plane interleaved groups:
    /// members on sibling planes of one die share a single die window
    /// (duration = the slowest member — the interleaved command of
    /// multi-plane NAND), members on distinct dies/channels proceed in
    /// parallel. Bus transfers still serialize per channel in member
    /// order. Under the lump model (or with nothing to interleave) this
    /// degenerates to issuing every member independently at `now`.
    ///
    /// `ops` entries are `(plane, array_ns, pages)`; completions come
    /// back in member order.
    pub fn occupy_program_group(
        &mut self,
        ops: &[(u32, Nanos, u32)],
        now: Nanos,
    ) -> Vec<Completion> {
        if !self.multiplane() {
            return ops
                .iter()
                .map(|&(plane, array_ns, pages)| {
                    self.occupy(plane, OpClass::Program, array_ns, pages, now)
                })
                .collect();
        }
        debug_assert!(
            {
                let mut planes: Vec<u32> = ops.iter().map(|o| o.0).collect();
                planes.sort_unstable();
                planes.windows(2).all(|w| w[0] != w[1])
            },
            "group members must target distinct planes"
        );
        // phase 1: bus transfers serialize per channel in member order
        let mut xfer_done: Vec<(Nanos, Nanos)> = Vec::with_capacity(ops.len()); // (t0, t1)
        for &(plane, _, pages) in ops {
            let xfer = self.bus_ns_per_page * pages as Nanos;
            if xfer == 0 {
                xfer_done.push((now, now));
                continue;
            }
            let ch = self.channel_of(plane);
            let t0 = now.max(self.channel_busy[ch]);
            let t1 = t0 + xfer;
            self.channel_busy[ch] = t1;
            xfer_done.push((t0, t1));
        }
        // phase 2: one interleaved array window per die
        let mut out = vec![Completion::instant(now); ops.len()];
        let member_dies: Vec<usize> = ops.iter().map(|o| self.die_of(o.0)).collect();
        let mut order: Vec<usize> = (0..ops.len()).collect();
        order.sort_by_key(|&i| (member_dies[i], i));
        let mut i = 0;
        while i < order.len() {
            let die = member_dies[order[i]];
            let mut j = i;
            while j < order.len() && member_dies[order[j]] == die {
                j += 1;
            }
            let members = &order[i..j];
            let mut ready = self.die_busy[die];
            let mut window = 0;
            for &m in members {
                let (_, t1) = xfer_done[m];
                ready = ready.max(t1).max(self.plane_busy[ops[m].0 as usize]);
                window = window.max(ops[m].1);
            }
            let a0 = ready;
            let a1 = a0 + window;
            self.die_busy[die] = a1;
            for &m in members {
                self.plane_busy[ops[m].0 as usize] = a1;
                let (t0, t1) = xfer_done[m];
                let xfer = t1 - t0;
                out[m] = Completion {
                    start: if xfer > 0 { t0 } else { a0 },
                    end: a1,
                    queued_ns: (t0 - now) + (a0 - t1),
                    transfer_ns: xfer,
                    array_ns: ops[m].1,
                };
            }
            i = j;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, US};

    /// Degenerate geometry: one plane per die per channel, bus = 0.
    fn degenerate_cfg(interconnect: bool) -> Config {
        let mut cfg = presets::small();
        cfg.geometry.channels = 4;
        cfg.geometry.chips_per_channel = 1;
        cfg.geometry.dies_per_chip = 1;
        cfg.geometry.planes_per_die = 1;
        cfg.timing.bus_ns_per_page = 0;
        cfg.sim.interconnect = interconnect;
        cfg
    }

    /// Contended geometry: 2 dies/chip × 2 planes/die share channels.
    fn contended_cfg() -> Config {
        let mut cfg = presets::small();
        cfg.geometry.channels = 2;
        cfg.geometry.chips_per_channel = 1;
        cfg.geometry.dies_per_chip = 2;
        cfg.geometry.planes_per_die = 2;
        cfg.timing.bus_ns_per_page = 10 * US;
        cfg.sim.interconnect = true;
        cfg
    }

    #[test]
    fn lump_mode_reproduces_plane_lump_math() {
        let mut cfg = presets::small();
        cfg.sim.interconnect = false;
        let mut ic = Interconnect::new(&cfg);
        let c1 = ic.occupy(0, OpClass::Program, 500, 1, 0);
        assert_eq!((c1.start, c1.end), (0, 500));
        assert_eq!((c1.queued_ns, c1.transfer_ns, c1.array_ns), (0, 0, 500));
        // second op on the same plane queues behind the first
        let c2 = ic.occupy(0, OpClass::Program, 500, 1, 0);
        assert_eq!((c2.start, c2.end, c2.queued_ns), (500, 1000, 500));
        // another plane runs in parallel, even same-die in lump mode
        let c3 = ic.occupy(1, OpClass::Read, 66, 1, 0);
        assert_eq!(c3.start, 0);
    }

    #[test]
    fn degenerate_interconnect_matches_lump_exactly() {
        let mut lump = Interconnect::new(&degenerate_cfg(false));
        let mut ic = Interconnect::new(&degenerate_cfg(true));
        let script: &[(u32, OpClass, Nanos, u32, Nanos)] = &[
            (0, OpClass::Program, 500, 1, 0),
            (0, OpClass::Read, 66, 1, 0),
            (1, OpClass::Program, 3000, 3, 100),
            (0, OpClass::ArrayOnly, 10_000, 0, 200),
            (1, OpClass::Read, 66, 1, 4000),
            (2, OpClass::Program, 500, 1, 50),
        ];
        for &(p, class, ns, pages, now) in script {
            let a = lump.occupy(p, class, ns, pages, now);
            let b = ic.occupy(p, class, ns, pages, now);
            assert_eq!(a, b, "degenerate geometry + bus 0 must be byte-identical");
        }
        assert_eq!(lump.all_idle_at(), ic.all_idle_at());
    }

    #[test]
    fn die_exclusivity_serializes_sibling_planes() {
        let mut ic = Interconnect::new(&contended_cfg());
        // planes 0 and 1 share die 0; plane 2 is die 1
        let a = ic.occupy(0, OpClass::ArrayOnly, 1000, 0, 0);
        let b = ic.occupy(1, OpClass::ArrayOnly, 1000, 0, 0);
        let c = ic.occupy(2, OpClass::ArrayOnly, 1000, 0, 0);
        assert_eq!(a.end, 1000);
        assert_eq!(b.start, 1000, "sibling plane waits for the die");
        assert_eq!(b.queued_ns, 1000);
        assert_eq!(c.start, 0, "distinct die proceeds in parallel");
    }

    #[test]
    fn channel_bus_serializes_transfers_and_splits_phases() {
        let mut ic = Interconnect::new(&contended_cfg());
        // dies 0 and 1 share channel 0: array phases overlap, but the
        // two programs' data-in transfers serialize on the bus
        let a = ic.occupy(0, OpClass::Program, 500_000, 1, 0);
        let b = ic.occupy(2, OpClass::Program, 500_000, 1, 0);
        assert_eq!(a.transfer_ns, 10_000);
        assert_eq!(a.start, 0);
        assert_eq!(a.end, 10_000 + 500_000);
        assert_eq!(b.start, 10_000, "second transfer waits for the bus");
        assert_eq!(b.queued_ns, 10_000);
        assert_eq!(b.end, 20_000 + 500_000);
        // a read's data-out also crosses the bus, after the array phase
        let r = ic.occupy(4, OpClass::Read, 66_000, 1, 0);
        assert_eq!(r.transfer_ns, 10_000);
        assert_eq!(r.end, 66_000 + 10_000, "channel 1 bus was free");
    }

    #[test]
    fn program_group_interleaves_same_die_and_parallelizes_dies() {
        let mut ic = Interconnect::new(&contended_cfg());
        // members: planes 0+1 (die 0), plane 2 (die 1) — one channel
        let comps = ic.occupy_program_group(
            &[(0, 3_000_000, 3), (1, 3_000_000, 3), (2, 3_000_000, 3)],
            0,
        );
        // transfers serialize on channel 0: 30 µs each
        assert_eq!(comps[0].transfer_ns, 30_000);
        assert_eq!(comps[0].start, 0);
        assert_eq!(comps[1].start, 30_000);
        assert_eq!(comps[2].start, 60_000);
        // die 0 runs planes 0+1 as ONE interleaved window (ready after
        // the second member's transfer), die 1 in parallel
        assert_eq!(comps[0].end, comps[1].end, "same die, one window");
        assert_eq!(comps[0].end, 60_000 + 3_000_000);
        assert_eq!(comps[2].end, 90_000 + 3_000_000);
        // vs sequential: two separate die-0 programs would cost 6 ms
        assert!(comps[1].end < 30_000 + 2 * 3_000_000);
    }

    #[test]
    fn group_without_multiplane_dies_degenerates_to_individual_issue() {
        let mut a = Interconnect::new(&degenerate_cfg(true));
        let mut b = Interconnect::new(&degenerate_cfg(true));
        let ops: [(u32, Nanos, u32); 3] = [(0, 3000, 3), (1, 3000, 3), (2, 3000, 2)];
        let grouped = a.occupy_program_group(&ops, 7);
        let individual: Vec<Completion> = ops
            .iter()
            .map(|&(p, ns, pages)| b.occupy(p, OpClass::Program, ns, pages, 7))
            .collect();
        assert_eq!(grouped, individual);
        assert!(!a.multiplane(), "one plane per die: nothing to interleave");
    }

    #[test]
    fn queued_transfer_array_split_accounts_for_waits() {
        let mut ic = Interconnect::new(&contended_cfg());
        let _ = ic.occupy(0, OpClass::ArrayOnly, 1_000_000, 0, 0); // die 0 busy 1 ms
        let c = ic.occupy(1, OpClass::Program, 500_000, 1, 0);
        // transfer runs immediately (bus free), array waits for the die
        assert_eq!(c.transfer_ns, 10_000);
        assert_eq!(c.array_ns, 500_000);
        assert_eq!(c.queued_ns, 1_000_000 - 10_000, "die wait after the transfer");
        assert_eq!(c.end, 1_000_000 + 500_000);
    }
}
