//! The flash back end: 3D NAND geometry, word-line/layer cell model,
//! blocks, planes, and the timed array.
//!
//! This is the substrate the paper's FTL and cache schemes sit on. It
//! enforces the *device-level* rules the paper relies on:
//!
//! * blocks are programmed sequentially (word line order);
//! * TLC word lines are written with **one-shot programming** — three
//!   pages (LSB/CSB/MSB) per word line in a single program operation
//!   (paper §II-A, [10]);
//! * SLC-mode programming stores one bit (the LSB page) per word line;
//! * **reprogram** adds one page to an already-programmed word line
//!   (SLC → +CSB → +MSB), at most [`crate::config::CacheConfig::max_reprograms`]
//!   times, only inside the block's active *layer-group window* and in
//!   sequential order (the reliability restrictions of [7], §II-B);
//! * a block may only be erased when it has no valid pages.
//!
//! Violations return [`crate::Error::Flash`] / [`crate::Error::Invariant`]
//! — the property tests drive random command sequences against these.

pub mod array;
pub mod block;
pub mod cell;
pub mod geometry;
pub mod interconnect;

pub use array::{FlashArray, FlashCounters, FlashOp};
pub use block::{Block, BlockMeta, BlockMode, BlockMut, BlockRef, PlaneArena, NO_LPN};
pub use cell::{PageKind, WlState};
pub use geometry::{BlockAddr, Lpn, PageAddr, PlaneId, Ppa};
pub use interconnect::{Completion, Interconnect, OpClass};
