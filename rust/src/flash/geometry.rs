//! Physical addressing: planes, blocks, word lines, layers, pages.
//!
//! A physical page address ([`Ppa`]) is a flat `u64` index over the
//! whole SSD in (plane, block, word line, bit) order; helpers convert
//! between the flat form and structured [`PageAddr`]. Flat indices keep
//! the mapping tables dense (`u32`-sized at Table-I scale) and the hot
//! path free of hashing.

use crate::config::Geometry;

/// Logical page number (host side).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Lpn(pub u64);

/// Flat physical page address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Ppa(pub u64);

/// Global plane index in `[0, geometry.planes())`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlaneId(pub u32);

/// Block coordinate: plane + block-within-plane.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockAddr {
    /// Owning plane.
    pub plane: PlaneId,
    /// Block index within the plane.
    pub block: u32,
}

/// Fully structured page address.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageAddr {
    /// Owning plane.
    pub plane: PlaneId,
    /// Block within plane.
    pub block: u32,
    /// Word line within block.
    pub wordline: u32,
    /// Bit position on the word line: 0 = LSB, 1 = CSB, 2 = MSB.
    pub bit: u8,
}

impl PlaneId {
    /// Decompose into (channel, chip, die, plane-in-die).
    pub fn decompose(self, g: &Geometry) -> (u32, u32, u32, u32) {
        let per_channel = g.chips_per_channel * g.dies_per_chip * g.planes_per_die;
        let per_chip = g.dies_per_chip * g.planes_per_die;
        let per_die = g.planes_per_die;
        let channel = self.0 / per_channel;
        let rem = self.0 % per_channel;
        let chip = rem / per_chip;
        let rem = rem % per_chip;
        let die = rem / per_die;
        let plane = rem % per_die;
        (channel, chip, die, plane)
    }

    /// Compose from (channel, chip, die, plane-in-die).
    pub fn compose(g: &Geometry, channel: u32, chip: u32, die: u32, plane: u32) -> PlaneId {
        let per_channel = g.chips_per_channel * g.dies_per_chip * g.planes_per_die;
        let per_chip = g.dies_per_chip * g.planes_per_die;
        let per_die = g.planes_per_die;
        PlaneId(channel * per_channel + chip * per_chip + die * per_die + plane)
    }

    /// Channel index of this plane (for bus-level accounting).
    pub fn channel(self, g: &Geometry) -> u32 {
        self.decompose(g).0
    }
}

impl PageAddr {
    /// Page index within its block (`wordline * 3 + bit`).
    pub fn page_in_block(&self) -> u32 {
        self.wordline * 3 + self.bit as u32
    }

    /// Layer index of this page's word line.
    pub fn layer(&self, g: &Geometry) -> u32 {
        self.wordline / g.wordlines_per_layer
    }

    /// Flatten to a [`Ppa`].
    pub fn flatten(&self, g: &Geometry) -> Ppa {
        let per_plane = g.pages_per_plane();
        let per_block = g.pages_per_block as u64;
        Ppa(self.plane.0 as u64 * per_plane
            + self.block as u64 * per_block
            + self.page_in_block() as u64)
    }
}

impl Ppa {
    /// Expand a flat address into its structured form.
    pub fn expand(self, g: &Geometry) -> PageAddr {
        let per_plane = g.pages_per_plane();
        let per_block = g.pages_per_block as u64;
        let plane = (self.0 / per_plane) as u32;
        let rem = self.0 % per_plane;
        let block = (rem / per_block) as u32;
        let pib = (rem % per_block) as u32;
        PageAddr { plane: PlaneId(plane), block, wordline: pib / 3, bit: (pib % 3) as u8 }
    }

    /// Owning block.
    pub fn block(self, g: &Geometry) -> BlockAddr {
        let pa = self.expand(g);
        BlockAddr { plane: pa.plane, block: pa.block }
    }
}

impl BlockAddr {
    /// Flat page address of (wordline, bit) in this block.
    pub fn page(self, g: &Geometry, wordline: u32, bit: u8) -> Ppa {
        PageAddr { plane: self.plane, block: self.block, wordline, bit }.flatten(g)
    }
}

/// Iterate all plane ids in channel-major order.
pub fn all_planes(g: &Geometry) -> impl Iterator<Item = PlaneId> {
    (0..g.planes()).map(PlaneId)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::util::prop::{self, tuple2, u64_up_to};

    #[test]
    fn plane_compose_decompose_roundtrip() {
        let g = presets::table1().geometry;
        for id in [0u32, 1, 15, 63, 127] {
            let p = PlaneId(id);
            let (ch, chip, die, pl) = p.decompose(&g);
            assert_eq!(PlaneId::compose(&g, ch, chip, die, pl), p);
            assert!(ch < g.channels && chip < g.chips_per_channel);
            assert!(die < g.dies_per_chip && pl < g.planes_per_die);
        }
    }

    #[test]
    fn ppa_roundtrip_property() {
        let g = presets::table1().geometry;
        let max = g.total_pages() - 1;
        prop::check("ppa expand/flatten roundtrip", 512, u64_up_to(max), |&raw| {
            let ppa = Ppa(raw);
            let pa = ppa.expand(&g);
            if pa.flatten(&g) != ppa {
                return Err(format!("{pa:?} flattened to {:?}", pa.flatten(&g)));
            }
            if pa.bit > 2 {
                return Err("bit out of range".into());
            }
            Ok(())
        });
    }

    #[test]
    fn page_addr_fields_bounded_property() {
        let g = presets::small().geometry;
        let max = g.total_pages() - 1;
        prop::check(
            "expanded fields within geometry",
            512,
            tuple2(u64_up_to(max), u64_up_to(1)),
            |&(raw, _)| {
                let pa = Ppa(raw).expand(&g);
                if pa.plane.0 >= g.planes() {
                    return Err("plane out of range".into());
                }
                if pa.block >= g.blocks_per_plane {
                    return Err("block out of range".into());
                }
                if pa.wordline >= g.wordlines_per_block() {
                    return Err("wordline out of range".into());
                }
                if pa.layer(&g) >= g.layers_per_block() {
                    return Err("layer out of range".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn layer_math() {
        let g = presets::table1().geometry;
        assert_eq!(g.wordlines_per_block(), 128);
        assert_eq!(g.layers_per_block(), 64);
        let pa = PageAddr { plane: PlaneId(0), block: 0, wordline: 5, bit: 2 };
        assert_eq!(pa.layer(&g), 2); // wl 5, 2 wls/layer
        assert_eq!(pa.page_in_block(), 17);
    }

    #[test]
    fn all_planes_count() {
        let g = presets::table1().geometry;
        assert_eq!(all_planes(&g).count() as u32, 128);
    }
}
