//! The timed flash array: every plane's blocks plus per-plane service
//! timelines and raw operation counters.
//!
//! The array is the single owner of all [`Block`] state. Callers (FTL,
//! cache schemes) express *logical* intent (`program_slc`, `reprogram`,
//! `erase`, …); the array applies the state change, charges the
//! Table-I latency against the owning plane's timeline, and returns the
//! `[start, end)` service interval. Planes are the unit of parallelism
//! (paper §II-A: channel → chip → die → plane; plane is the innermost
//! level at which flash operations serialize).

use super::block::Block;
#[cfg(test)]
use super::block::BlockMode;
use super::geometry::{BlockAddr, Lpn, PlaneId, Ppa};
use crate::config::{Config, Geometry, Nanos, Timing};
use crate::{Error, Result};
use std::collections::VecDeque;

/// A scheduled flash operation's service interval.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Completion {
    /// Service start (≥ issue time; queueing shows up as `start > now`).
    pub start: Nanos,
    /// Service end — when the data is durable / the plane frees up.
    pub end: Nanos,
}

/// Kinds of raw flash operations (for counters and audits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlashOp {
    /// SLC page read.
    ReadSlc,
    /// TLC page read.
    ReadTlc,
    /// SLC page program.
    ProgSlc,
    /// One-shot TLC word-line program.
    ProgTlcWl,
    /// Reprogram operation (one added page).
    Reprogram,
    /// Block erase.
    Erase,
}

/// Raw operation counters (monotonic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FlashCounters {
    /// SLC page reads.
    pub reads_slc: u64,
    /// TLC page reads.
    pub reads_tlc: u64,
    /// SLC page programs.
    pub progs_slc: u64,
    /// One-shot TLC word-line programs.
    pub progs_tlc_wl: u64,
    /// Pages written by one-shot TLC programs (≤ 3 per word line).
    pub progs_tlc_pages: u64,
    /// Reprogram operations (each adds one page).
    pub reprograms: u64,
    /// Block erases.
    pub erases: u64,
}

impl FlashCounters {
    /// Total pages physically programmed (the WA numerator).
    pub fn pages_programmed(&self) -> u64 {
        self.progs_slc + self.progs_tlc_pages + self.reprograms
    }
}

struct PlaneState {
    blocks: Vec<Block>,
    busy_until: Nanos,
    free_blocks: VecDeque<u32>,
}

/// The whole back end.
pub struct FlashArray {
    geometry: Geometry,
    timing: Timing,
    max_reprograms: u32,
    planes: Vec<PlaneState>,
    counters: FlashCounters,
}

impl FlashArray {
    /// Build a fully erased array from a config.
    pub fn new(cfg: &Config) -> FlashArray {
        let g = cfg.geometry;
        let planes = (0..g.planes())
            .map(|_| PlaneState {
                blocks: (0..g.blocks_per_plane)
                    .map(|_| Block::new(&g, cfg.cache.group_layers))
                    .collect(),
                busy_until: 0,
                free_blocks: (0..g.blocks_per_plane).collect(),
            })
            .collect();
        FlashArray {
            geometry: g,
            timing: cfg.timing,
            max_reprograms: cfg.cache.max_reprograms,
            planes,
            counters: FlashCounters::default(),
        }
    }

    /// Geometry in force.
    pub fn geometry(&self) -> &Geometry {
        &self.geometry
    }
    /// Timing in force.
    pub fn timing(&self) -> &Timing {
        &self.timing
    }
    /// Raw op counters.
    pub fn counters(&self) -> &FlashCounters {
        &self.counters
    }

    /// Immutable block access.
    pub fn block(&self, addr: BlockAddr) -> &Block {
        &self.planes[addr.plane.0 as usize].blocks[addr.block as usize]
    }
    /// Mutable block access (state-only mutations; timing-neutral).
    pub fn block_mut(&mut self, addr: BlockAddr) -> &mut Block {
        &mut self.planes[addr.plane.0 as usize].blocks[addr.block as usize]
    }

    /// When the plane becomes free.
    pub fn plane_busy_until(&self, plane: PlaneId) -> Nanos {
        self.planes[plane.0 as usize].busy_until
    }

    /// Latest busy-until across all planes (drain point).
    pub fn all_idle_at(&self) -> Nanos {
        self.planes.iter().map(|p| p.busy_until).max().unwrap_or(0)
    }

    /// Free (erased, unassigned) blocks in a plane.
    pub fn free_block_count(&self, plane: PlaneId) -> usize {
        self.planes[plane.0 as usize].free_blocks.len()
    }

    /// Take a free block from a plane (caller assigns its mode).
    pub fn pop_free(&mut self, plane: PlaneId) -> Option<BlockAddr> {
        let b = self.planes[plane.0 as usize].free_blocks.pop_front()?;
        Some(BlockAddr { plane, block: b })
    }

    /// Take the free block with the lowest erase count among the first
    /// `window` candidates (wear-levelling allocation, §IV-D2; the
    /// bounded window keeps allocation O(1)).
    pub fn pop_free_min_erase(&mut self, plane: PlaneId, window: usize) -> Option<BlockAddr> {
        let p = &mut self.planes[plane.0 as usize];
        if p.free_blocks.is_empty() {
            return None;
        }
        let lim = p.free_blocks.len().min(window.max(1));
        let mut best = 0usize;
        let mut best_ec = u32::MAX;
        for i in 0..lim {
            let b = p.free_blocks[i];
            let ec = p.blocks[b as usize].erase_count();
            if ec < best_ec {
                best_ec = ec;
                best = i;
            }
        }
        let b = p.free_blocks.remove(best)?;
        Some(BlockAddr { plane, block: b })
    }

    /// Return an erased block to the plane's free list.
    pub fn push_free(&mut self, addr: BlockAddr) -> Result<()> {
        if !self.block(addr).is_erased() {
            return Err(Error::invariant("push_free of non-erased block"));
        }
        self.planes[addr.plane.0 as usize].free_blocks.push_back(addr.block);
        Ok(())
    }

    #[inline]
    fn occupy(&mut self, plane: PlaneId, now: Nanos, latency: Nanos) -> Completion {
        let p = &mut self.planes[plane.0 as usize];
        let start = now.max(p.busy_until);
        let end = start + latency;
        p.busy_until = end;
        Completion { start, end }
    }

    // --- timed operations -------------------------------------------

    /// Read one page; latency depends on the word line's current kind.
    pub fn read(&mut self, ppa: Ppa, now: Nanos) -> Result<Completion> {
        let pa = ppa.expand(&self.geometry);
        let block = &self.planes[pa.plane.0 as usize].blocks[pa.block as usize];
        if !block.is_written(pa.page_in_block()) {
            return Err(Error::Flash(format!("read of unwritten page {ppa:?}")));
        }
        let (latency, op) = match block.page_kind(pa.page_in_block()) {
            super::cell::PageKind::Slc => (self.timing.slc_read, FlashOp::ReadSlc),
            super::cell::PageKind::Tlc => (self.timing.tlc_read, FlashOp::ReadTlc),
        };
        self.count(op, 1);
        Ok(self.occupy(pa.plane, now, latency))
    }

    /// Program one SLC page at `addr`'s write pointer.
    pub fn program_slc(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        now: Nanos,
    ) -> Result<(Ppa, Completion)> {
        let g = self.geometry;
        let pib = self.block_mut(addr).program_slc(lpn)?;
        self.count(FlashOp::ProgSlc, 1);
        let done = self.occupy(addr.plane, now, self.timing.slc_prog);
        Ok((addr.page(&g, pib / 3, 0), done))
    }

    /// One-shot TLC program of the next word line with 1..=3 pages.
    pub fn program_tlc(
        &mut self,
        addr: BlockAddr,
        lpns: &[Lpn],
        now: Nanos,
    ) -> Result<(Vec<Ppa>, Completion)> {
        let g = self.geometry;
        let slots = self.block_mut(addr).program_tlc_oneshot(lpns)?;
        self.counters.progs_tlc_wl += 1;
        self.counters.progs_tlc_pages += slots.len() as u64;
        let done = self.occupy(addr.plane, now, self.timing.tlc_prog);
        let ppas = slots.iter().map(|&pib| addr.page(&g, pib / 3, (pib % 3) as u8)).collect();
        Ok((ppas, done))
    }

    /// Page-granular TLC program of the next page slot (host path;
    /// Table I: 3 ms per page).
    pub fn program_tlc_page(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        now: Nanos,
    ) -> Result<(Ppa, Completion)> {
        let g = self.geometry;
        let pib = self.block_mut(addr).program_tlc_page(lpn)?;
        self.counters.progs_tlc_pages += 1;
        let done = self.occupy(addr.plane, now, self.timing.tlc_prog);
        Ok((addr.page(&g, pib / 3, (pib % 3) as u8), done))
    }

    /// One reprogram operation in `addr`'s active IPS window.
    /// Returns the new page's address, whether the word line is now
    /// full TLC, and the service interval.
    pub fn reprogram(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        now: Nanos,
    ) -> Result<(Ppa, bool, Completion)> {
        let g = self.geometry;
        let max = self.max_reprograms;
        let (pib, full) = self.block_mut(addr).reprogram_next(lpn, max)?;
        self.count(FlashOp::Reprogram, 1);
        let done = self.occupy(addr.plane, now, self.timing.reprogram);
        Ok((addr.page(&g, pib / 3, (pib % 3) as u8), full, done))
    }

    /// Erase a block (must hold no valid pages). The block is NOT
    /// returned to the free list — the owner decides whether it goes
    /// back to general allocation ([`FlashArray::push_free`]) or stays
    /// claimed (e.g. as an SLC-cache block awaiting reuse).
    pub fn erase(&mut self, addr: BlockAddr, now: Nanos) -> Result<Completion> {
        self.block_mut(addr).erase()?;
        self.count(FlashOp::Erase, 1);
        let done = self.occupy(addr.plane, now, self.timing.erase);
        Ok(done)
    }

    /// Invalidate a page (timing-neutral metadata update).
    pub fn invalidate(&mut self, ppa: Ppa) -> Result<()> {
        let pa = ppa.expand(&self.geometry);
        self.planes[pa.plane.0 as usize].blocks[pa.block as usize]
            .invalidate(pa.page_in_block())
    }

    fn count(&mut self, op: FlashOp, n: u64) {
        match op {
            FlashOp::ReadSlc => self.counters.reads_slc += n,
            FlashOp::ReadTlc => self.counters.reads_tlc += n,
            FlashOp::ProgSlc => self.counters.progs_slc += n,
            FlashOp::ProgTlcWl => self.counters.progs_tlc_wl += n,
            FlashOp::Reprogram => self.counters.reprograms += n,
            FlashOp::Erase => self.counters.erases += n,
        }
    }

    // --- audits -------------------------------------------------------

    /// Recount valid pages across a plane (slow; tests/audits only).
    pub fn audit_plane(&self, plane: PlaneId) -> Result<()> {
        for (bi, b) in self.planes[plane.0 as usize].blocks.iter().enumerate() {
            let recount = b.valid_pages().count() as u32;
            if recount != b.valid_count() {
                return Err(Error::invariant(format!(
                    "plane {} block {bi}: bitmap {recount} != counter {}",
                    plane.0,
                    b.valid_count()
                )));
            }
            if b.valid_count() > b.written_count() {
                return Err(Error::invariant(format!(
                    "plane {} block {bi}: valid {} > written {}",
                    plane.0,
                    b.valid_count(),
                    b.written_count()
                )));
            }
        }
        Ok(())
    }

    /// Total erase-count spread (wear levelling audit, §IV-D2).
    pub fn erase_count_spread(&self) -> (u32, u32) {
        let mut min = u32::MAX;
        let mut max = 0;
        for p in &self.planes {
            for b in &p.blocks {
                min = min.min(b.erase_count());
                max = max.max(b.erase_count());
            }
        }
        (if min == u32::MAX { 0 } else { min }, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn array() -> FlashArray {
        FlashArray::new(&presets::small())
    }

    #[test]
    fn free_list_starts_full() {
        let a = array();
        let g = *a.geometry();
        for p in 0..g.planes() {
            assert_eq!(a.free_block_count(PlaneId(p)), g.blocks_per_plane as usize);
        }
    }

    #[test]
    fn timing_charged_per_plane() {
        let mut a = array();
        let t = *a.timing();
        let b0 = a.pop_free(PlaneId(0)).unwrap();
        a.block_mut(b0).set_mode(BlockMode::Slc).unwrap();
        let (_ppa, c1) = a.program_slc(b0, Lpn(1), 0).unwrap();
        assert_eq!(c1.start, 0);
        assert_eq!(c1.end, t.slc_prog);
        // second op on the same plane queues behind the first
        let (_ppa, c2) = a.program_slc(b0, Lpn(2), 0).unwrap();
        assert_eq!(c2.start, t.slc_prog);
        assert_eq!(c2.end, 2 * t.slc_prog);
        // an op on another plane runs in parallel
        let b1 = a.pop_free(PlaneId(1)).unwrap();
        a.block_mut(b1).set_mode(BlockMode::Slc).unwrap();
        let (_ppa, c3) = a.program_slc(b1, Lpn(3), 0).unwrap();
        assert_eq!(c3.start, 0);
    }

    #[test]
    fn read_latency_tracks_cell_kind() {
        let mut a = array();
        let t = *a.timing();
        let b = a.pop_free(PlaneId(0)).unwrap();
        a.block_mut(b).set_mode(BlockMode::Ips).unwrap();
        let (ppa, done) = a.program_slc(b, Lpn(1), 0).unwrap();
        let c = a.read(ppa, done.end).unwrap();
        assert_eq!(c.end - c.start, t.slc_read, "SLC page reads at SLC speed");
        // reprogram the word line to 2 bits → reads become TLC speed
        let (_p, _f, done) = a.reprogram(b, Lpn(2), c.end).unwrap();
        let c = a.read(ppa, done.end).unwrap();
        assert_eq!(c.end - c.start, t.tlc_read, "reprogrammed page reads at TLC speed");
    }

    #[test]
    fn counters_accumulate() {
        let mut a = array();
        let b = a.pop_free(PlaneId(0)).unwrap();
        a.block_mut(b).set_mode(BlockMode::Tlc).unwrap();
        a.program_tlc(b, &[Lpn(1), Lpn(2), Lpn(3)], 0).unwrap();
        a.program_tlc(b, &[Lpn(4)], 0).unwrap();
        let c = a.counters();
        assert_eq!(c.progs_tlc_wl, 2);
        assert_eq!(c.progs_tlc_pages, 4);
        assert_eq!(c.pages_programmed(), 4);
    }

    #[test]
    fn erase_returns_to_free_list() {
        let mut a = array();
        let b = a.pop_free(PlaneId(0)).unwrap();
        let before = a.free_block_count(PlaneId(0));
        a.block_mut(b).set_mode(BlockMode::Slc).unwrap();
        a.program_slc(b, Lpn(1), 0).unwrap();
        let g = *a.geometry();
        a.invalidate(b.page(&g, 0, 0)).unwrap();
        a.erase(b, 0).unwrap();
        assert_eq!(a.free_block_count(PlaneId(0)), before, "erase does not auto-free");
        a.push_free(b).unwrap();
        assert_eq!(a.free_block_count(PlaneId(0)), before + 1);
        assert_eq!(a.counters().erases, 1);
    }

    #[test]
    fn unwritten_read_rejected() {
        let mut a = array();
        assert!(a.read(Ppa(0), 0).is_err());
    }

    #[test]
    fn audit_passes_on_fresh_array() {
        let a = array();
        for p in 0..a.geometry().planes() {
            a.audit_plane(PlaneId(p)).unwrap();
        }
    }
}
