//! The timed flash array: every plane's blocks plus the interconnect
//! timing model and raw operation counters.
//!
//! The array is the single owner of all [`Block`] state. Callers (FTL,
//! cache schemes) express *logical* intent (`program_slc`, `reprogram`,
//! `erase`, …); the array applies the state change, charges the
//! Table-I latency through the [`Interconnect`] resource model
//! (channel bus → die → plane under `sim.interconnect`, the historical
//! per-plane lump otherwise), and returns the phase-split
//! [`Completion`] (paper §II-A: channel → chip → die → plane).

use super::block::{Block, BlockMut, BlockRef, PlaneArena};
#[cfg(test)]
use super::block::BlockMode;
use super::geometry::{BlockAddr, Lpn, PlaneId, Ppa};
use super::interconnect::{Interconnect, OpClass};
use crate::config::{Config, Geometry, Nanos, Timing};
use crate::util::rng::mix64;
use crate::{Error, Result};
use std::collections::VecDeque;

pub use super::interconnect::Completion;

/// Kinds of raw flash operations (for counters and audits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlashOp {
    /// SLC page read.
    ReadSlc,
    /// TLC page read.
    ReadTlc,
    /// SLC page program.
    ProgSlc,
    /// One-shot TLC word-line program.
    ProgTlcWl,
    /// Reprogram operation (one added page).
    Reprogram,
    /// Block erase.
    Erase,
}

/// Raw operation counters (monotonic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FlashCounters {
    /// SLC page reads.
    pub reads_slc: u64,
    /// TLC page reads.
    pub reads_tlc: u64,
    /// SLC page programs.
    pub progs_slc: u64,
    /// One-shot TLC word-line programs.
    pub progs_tlc_wl: u64,
    /// Pages written by one-shot TLC programs (≤ 3 per word line).
    pub progs_tlc_pages: u64,
    /// Reprogram operations (each adds one page).
    pub reprograms: u64,
    /// Block erases.
    pub erases: u64,
}

impl FlashCounters {
    /// Total pages physically programmed (the WA numerator).
    pub fn pages_programmed(&self) -> u64 {
        self.progs_slc + self.progs_tlc_pages + self.reprograms
    }
}

struct PlaneState {
    /// Per-block state. Under `sim.soa_blocks` each block holds only
    /// its scalar metadata and the page arrays live in `arena`; in the
    /// inline oracle layout each block owns its own vectors.
    blocks: Vec<Block>,
    /// SoA page-metadata arenas (`Some` iff `sim.soa_blocks`); see
    /// [`PlaneArena`].
    arena: Option<PlaneArena>,
    free_blocks: VecDeque<u32>,
    /// Fault injection: a lost plane never hands out free blocks again
    /// and silently swallows returns; resident data stays readable so
    /// the FTL can salvage it.
    lost: bool,
}

/// The whole back end.
pub struct FlashArray {
    geometry: Geometry,
    timing: Timing,
    max_reprograms: u32,
    planes: Vec<PlaneState>,
    /// The timing model: channel/die/plane occupancy (or the lump).
    ic: Interconnect,
    counters: FlashCounters,
    /// Fault injection: program/erase latency multiplier in percent
    /// (100 = nominal). Models wear-induced slowdown; reads keep Table-I
    /// speed.
    slow_x100: u32,
}

impl FlashArray {
    /// Build a fully erased array from a config. With
    /// `sim.pre_age_erases > 0` every block starts with a deterministic
    /// initial erase count in `[0, pre_age_erases]` — a pure function
    /// of `(sim.seed, flat block index)`, never of execution order, so
    /// sharded fleet devices reproduce byte-identically. Initial wear
    /// perturbs the min-erase allocator (`pop_free_min_erase`), which
    /// is what makes a worn device behave differently from a fresh one.
    pub fn new(cfg: &Config) -> FlashArray {
        let g = cfg.geometry;
        let soa = cfg.sim.soa_blocks;
        let mut planes: Vec<PlaneState> = (0..g.planes())
            .map(|_| PlaneState {
                blocks: (0..g.blocks_per_plane)
                    .map(|_| {
                        if soa {
                            Block::meta_only(&g, cfg.cache.group_layers)
                        } else {
                            Block::new(&g, cfg.cache.group_layers)
                        }
                    })
                    .collect(),
                arena: soa.then(|| PlaneArena::new(&g, g.blocks_per_plane)),
                free_blocks: (0..g.blocks_per_plane).collect(),
                lost: false,
            })
            .collect();
        if cfg.sim.pre_age_erases > 0 {
            let span = cfg.sim.pre_age_erases as u64 + 1;
            for (p, plane) in planes.iter_mut().enumerate() {
                for (b, blk) in plane.blocks.iter_mut().enumerate() {
                    let flat = p as u64 * g.blocks_per_plane as u64 + b as u64;
                    let wear = (mix64(cfg.sim.seed, flat) % span) as u32;
                    blk.pre_age(wear).expect("fresh blocks are pristine");
                }
            }
        }
        FlashArray {
            geometry: g,
            timing: cfg.timing,
            max_reprograms: cfg.cache.max_reprograms,
            planes,
            ic: Interconnect::new(cfg),
            counters: FlashCounters::default(),
            slow_x100: 100,
        }
    }

    /// Geometry in force.
    pub fn geometry(&self) -> &Geometry {
        &self.geometry
    }
    /// Timing in force.
    pub fn timing(&self) -> &Timing {
        &self.timing
    }
    /// Raw op counters.
    pub fn counters(&self) -> &FlashCounters {
        &self.counters
    }

    /// Immutable block access: a layout-agnostic view over either the
    /// block's inline arrays or the plane arena (`sim.soa_blocks`).
    pub fn block(&self, addr: BlockAddr) -> BlockRef<'_> {
        let p = &self.planes[addr.plane.0 as usize];
        let b = &p.blocks[addr.block as usize];
        match &p.arena {
            Some(a) => a.block_ref(&b.meta, addr.block),
            None => b.as_view(),
        }
    }
    /// Mutable block access (state-only mutations; timing-neutral).
    pub fn block_mut(&mut self, addr: BlockAddr) -> BlockMut<'_> {
        let p = &mut self.planes[addr.plane.0 as usize];
        let b = &mut p.blocks[addr.block as usize];
        match &mut p.arena {
            Some(a) => a.block_mut(&mut b.meta, addr.block),
            None => b.as_view_mut(),
        }
    }

    /// When the plane becomes free.
    pub fn plane_busy_until(&self, plane: PlaneId) -> Nanos {
        self.ic.plane_busy_until(plane.0)
    }

    /// Latest busy-until across all resources (drain point).
    pub fn all_idle_at(&self) -> Nanos {
        self.ic.all_idle_at()
    }

    /// Is the channel/die/plane interconnect model active (vs the
    /// historical per-plane lump)?
    pub fn interconnect_enabled(&self) -> bool {
        self.ic.enabled()
    }

    /// Can same-die sibling planes interleave as multi-plane groups?
    pub fn multiplane_enabled(&self) -> bool {
        self.ic.multiplane()
    }

    /// Free (erased, unassigned) blocks in a plane.
    pub fn free_block_count(&self, plane: PlaneId) -> usize {
        self.planes[plane.0 as usize].free_blocks.len()
    }

    // --- fault injection ---------------------------------------------

    /// Retire a plane: it stops serving free blocks (pop returns
    /// `None`, returns are swallowed) while resident pages stay
    /// readable for salvage. Returns the count of free blocks dropped
    /// from allocation.
    pub fn mark_plane_lost(&mut self, plane: PlaneId) -> usize {
        let p = &mut self.planes[plane.0 as usize];
        p.lost = true;
        let dropped = p.free_blocks.len();
        p.free_blocks.clear();
        dropped
    }

    /// Has this plane been retired by fault injection?
    pub fn plane_lost(&self, plane: PlaneId) -> bool {
        self.planes[plane.0 as usize].lost
    }

    /// Planes still serving allocations.
    pub fn live_planes(&self) -> u32 {
        self.planes.iter().filter(|p| !p.lost).count() as u32
    }

    /// Set the wear-slowdown multiplier for programs and erases, in
    /// percent of nominal (100 = off, 200 = 2× slower). Clamped to ≥ 1.
    pub fn set_program_slowdown(&mut self, x100: u32) {
        self.slow_x100 = x100.max(1);
    }

    /// Current program/erase slowdown (percent of nominal).
    pub fn program_slowdown(&self) -> u32 {
        self.slow_x100
    }

    /// Apply the wear-slowdown multiplier to a program/erase latency.
    fn slowed(&self, ns: Nanos) -> Nanos {
        if self.slow_x100 == 100 {
            ns
        } else {
            ns.saturating_mul(self.slow_x100 as u64) / 100
        }
    }

    /// Take a free block from a plane (caller assigns its mode).
    pub fn pop_free(&mut self, plane: PlaneId) -> Option<BlockAddr> {
        let p = &mut self.planes[plane.0 as usize];
        if p.lost {
            return None;
        }
        let b = p.free_blocks.pop_front()?;
        Some(BlockAddr { plane, block: b })
    }

    /// Take the free block with the lowest erase count among the first
    /// `window` candidates (wear-levelling allocation, §IV-D2; the
    /// bounded window keeps allocation O(1)).
    pub fn pop_free_min_erase(&mut self, plane: PlaneId, window: usize) -> Option<BlockAddr> {
        let p = &mut self.planes[plane.0 as usize];
        if p.lost || p.free_blocks.is_empty() {
            return None;
        }
        let lim = p.free_blocks.len().min(window.max(1));
        let mut best = 0usize;
        let mut best_ec = u32::MAX;
        for i in 0..lim {
            let b = p.free_blocks[i];
            let ec = p.blocks[b as usize].erase_count();
            if ec < best_ec {
                best_ec = ec;
                best = i;
            }
        }
        let b = p.free_blocks.remove(best)?;
        Some(BlockAddr { plane, block: b })
    }

    /// Return an erased block to the plane's free list. Returns to a
    /// lost plane are swallowed: the block never rejoins allocation.
    pub fn push_free(&mut self, addr: BlockAddr) -> Result<()> {
        if self.planes[addr.plane.0 as usize].lost {
            return Ok(());
        }
        if !self.block(addr).is_erased() {
            return Err(Error::invariant("push_free of non-erased block"));
        }
        self.planes[addr.plane.0 as usize].free_blocks.push_back(addr.block);
        Ok(())
    }

    // --- timed operations -------------------------------------------

    /// Read one page; latency depends on the word line's current kind.
    /// The data-out transfer crosses the channel bus after the array
    /// phase (interconnect model; the lump charges the array only).
    pub fn read(&mut self, ppa: Ppa, now: Nanos) -> Result<Completion> {
        let pa = ppa.expand(&self.geometry);
        let block = self.block(BlockAddr { plane: pa.plane, block: pa.block });
        if !block.is_written(pa.page_in_block()) {
            return Err(Error::Flash(format!("read of unwritten page {ppa:?}")));
        }
        let (latency, op) = match block.page_kind(pa.page_in_block()) {
            super::cell::PageKind::Slc => (self.timing.slc_read, FlashOp::ReadSlc),
            super::cell::PageKind::Tlc => (self.timing.tlc_read, FlashOp::ReadTlc),
        };
        self.count(op, 1);
        Ok(self.ic.occupy(pa.plane.0, OpClass::Read, latency, 1, now))
    }

    /// Program one SLC page at `addr`'s write pointer.
    pub fn program_slc(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        now: Nanos,
    ) -> Result<(Ppa, Completion)> {
        let g = self.geometry;
        let pib = self.block_mut(addr).program_slc(lpn)?;
        self.count(FlashOp::ProgSlc, 1);
        let lat = self.slowed(self.timing.slc_prog);
        let done = self.ic.occupy(addr.plane.0, OpClass::Program, lat, 1, now);
        Ok((addr.page(&g, pib / 3, 0), done))
    }

    /// One-shot TLC program of the next word line with 1..=3 pages.
    pub fn program_tlc(
        &mut self,
        addr: BlockAddr,
        lpns: &[Lpn],
        now: Nanos,
    ) -> Result<(Vec<Ppa>, Completion)> {
        let g = self.geometry;
        let slots = self.block_mut(addr).program_tlc_oneshot(lpns)?;
        self.counters.progs_tlc_wl += 1;
        self.counters.progs_tlc_pages += slots.len() as u64;
        let done = self.ic.occupy(
            addr.plane.0,
            OpClass::Program,
            self.slowed(self.timing.tlc_prog),
            slots.len() as u32,
            now,
        );
        let ppas = slots.iter().map(|&pib| addr.page(&g, pib / 3, (pib % 3) as u8)).collect();
        Ok((ppas, done))
    }

    /// One-shot TLC programs on **distinct planes**, issued together at
    /// `now` as multi-plane interleaved groups: members on sibling
    /// planes of one die share a single die window, distinct dies and
    /// channels proceed in parallel (see
    /// [`Interconnect::occupy_program_group`]). Under the lump model —
    /// or with one plane per die — this is byte-identical to calling
    /// [`FlashArray::program_tlc`] for every member at `now`.
    pub fn program_tlc_group(
        &mut self,
        ops: &[(BlockAddr, &[Lpn])],
        now: Nanos,
    ) -> Result<Vec<(Vec<Ppa>, Completion)>> {
        let g = self.geometry;
        let mut metas: Vec<(BlockAddr, Vec<u32>)> = Vec::with_capacity(ops.len());
        for (addr, lpns) in ops {
            let slots = self.block_mut(*addr).program_tlc_oneshot(lpns)?;
            self.counters.progs_tlc_wl += 1;
            self.counters.progs_tlc_pages += slots.len() as u64;
            metas.push((*addr, slots));
        }
        let sched: Vec<(u32, Nanos, u32)> = metas
            .iter()
            .map(|(addr, slots)| {
                (addr.plane.0, self.slowed(self.timing.tlc_prog), slots.len() as u32)
            })
            .collect();
        let comps = self.ic.occupy_program_group(&sched, now);
        Ok(metas
            .into_iter()
            .zip(comps)
            .map(|((addr, slots), done)| {
                let ppas =
                    slots.iter().map(|&pib| addr.page(&g, pib / 3, (pib % 3) as u8)).collect();
                (ppas, done)
            })
            .collect())
    }

    /// Page-granular TLC program of the next page slot (host path;
    /// Table I: 3 ms per page).
    pub fn program_tlc_page(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        now: Nanos,
    ) -> Result<(Ppa, Completion)> {
        let g = self.geometry;
        let pib = self.block_mut(addr).program_tlc_page(lpn)?;
        self.counters.progs_tlc_pages += 1;
        let lat = self.slowed(self.timing.tlc_prog);
        let done = self.ic.occupy(addr.plane.0, OpClass::Program, lat, 1, now);
        Ok((addr.page(&g, pib / 3, (pib % 3) as u8), done))
    }

    /// One reprogram operation in `addr`'s active IPS window.
    /// Returns the new page's address, whether the word line is now
    /// full TLC, and the service interval.
    pub fn reprogram(
        &mut self,
        addr: BlockAddr,
        lpn: Lpn,
        now: Nanos,
    ) -> Result<(Ppa, bool, Completion)> {
        let g = self.geometry;
        let max = self.max_reprograms;
        let (pib, full) = self.block_mut(addr).reprogram_next(lpn, max)?;
        self.count(FlashOp::Reprogram, 1);
        let lat = self.slowed(self.timing.reprogram);
        let done = self.ic.occupy(addr.plane.0, OpClass::Program, lat, 1, now);
        Ok((addr.page(&g, pib / 3, (pib % 3) as u8), full, done))
    }

    /// Erase a block (must hold no valid pages). No data crosses the
    /// bus. The block is NOT returned to the free list — the owner
    /// decides whether it goes back to general allocation
    /// ([`FlashArray::push_free`]) or stays claimed (e.g. as an
    /// SLC-cache block awaiting reuse).
    pub fn erase(&mut self, addr: BlockAddr, now: Nanos) -> Result<Completion> {
        self.block_mut(addr).erase()?;
        self.count(FlashOp::Erase, 1);
        let lat = self.slowed(self.timing.erase);
        Ok(self.ic.occupy(addr.plane.0, OpClass::ArrayOnly, lat, 0, now))
    }

    /// Invalidate a page (timing-neutral metadata update).
    pub fn invalidate(&mut self, ppa: Ppa) -> Result<()> {
        let pa = ppa.expand(&self.geometry);
        self.block_mut(BlockAddr { plane: pa.plane, block: pa.block })
            .invalidate(pa.page_in_block())
    }

    fn count(&mut self, op: FlashOp, n: u64) {
        match op {
            FlashOp::ReadSlc => self.counters.reads_slc += n,
            FlashOp::ReadTlc => self.counters.reads_tlc += n,
            FlashOp::ProgSlc => self.counters.progs_slc += n,
            FlashOp::ProgTlcWl => self.counters.progs_tlc_wl += n,
            FlashOp::Reprogram => self.counters.reprograms += n,
            FlashOp::Erase => self.counters.erases += n,
        }
    }

    // --- audits -------------------------------------------------------

    /// Recount valid pages across a plane (slow; tests/audits only).
    pub fn audit_plane(&self, plane: PlaneId) -> Result<()> {
        for bi in 0..self.planes[plane.0 as usize].blocks.len() {
            let b = self.block(BlockAddr { plane, block: bi as u32 });
            let recount = b.valid_pages().count() as u32;
            if recount != b.valid_count() {
                return Err(Error::invariant(format!(
                    "plane {} block {bi}: bitmap {recount} != counter {}",
                    plane.0,
                    b.valid_count()
                )));
            }
            if b.valid_count() > b.written_count() {
                return Err(Error::invariant(format!(
                    "plane {} block {bi}: valid {} > written {}",
                    plane.0,
                    b.valid_count(),
                    b.written_count()
                )));
            }
        }
        Ok(())
    }

    /// Total erase-count spread (wear levelling audit, §IV-D2).
    pub fn erase_count_spread(&self) -> (u32, u32) {
        let mut min = u32::MAX;
        let mut max = 0;
        for p in &self.planes {
            for b in &p.blocks {
                min = min.min(b.erase_count());
                max = max.max(b.erase_count());
            }
        }
        (if min == u32::MAX { 0 } else { min }, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn array() -> FlashArray {
        FlashArray::new(&presets::small())
    }

    #[test]
    fn pre_age_seeds_deterministic_wear() {
        let mut cfg = presets::small();
        assert_eq!(array().erase_count_spread(), (0, 0), "pristine by default");
        cfg.sim.pre_age_erases = 100;
        let a = FlashArray::new(&cfg);
        let b = FlashArray::new(&cfg);
        let (min, max) = a.erase_count_spread();
        assert!(max > min, "wear is heterogeneous across blocks");
        assert!(max <= 100, "bounded by the knob");
        assert_eq!((min, max), b.erase_count_spread(), "pure function of (seed, block)");
        cfg.sim.seed = 43;
        let c = FlashArray::new(&cfg);
        let same = (0..cfg.geometry.blocks_per_plane).all(|i| {
            let addr = BlockAddr { plane: PlaneId(0), block: i };
            a.block(addr).erase_count() == c.block(addr).erase_count()
        });
        assert!(!same, "a different seed ages a different pattern");
    }

    #[test]
    fn free_list_starts_full() {
        let a = array();
        let g = *a.geometry();
        for p in 0..g.planes() {
            assert_eq!(a.free_block_count(PlaneId(p)), g.blocks_per_plane as usize);
        }
    }

    #[test]
    fn timing_charged_per_plane() {
        let mut a = array();
        let t = *a.timing();
        let b0 = a.pop_free(PlaneId(0)).unwrap();
        a.block_mut(b0).set_mode(BlockMode::Slc).unwrap();
        let (_ppa, c1) = a.program_slc(b0, Lpn(1), 0).unwrap();
        assert_eq!(c1.start, 0);
        assert_eq!(c1.end, t.slc_prog);
        // second op on the same plane queues behind the first
        let (_ppa, c2) = a.program_slc(b0, Lpn(2), 0).unwrap();
        assert_eq!(c2.start, t.slc_prog);
        assert_eq!(c2.end, 2 * t.slc_prog);
        // an op on another plane runs in parallel
        let b1 = a.pop_free(PlaneId(1)).unwrap();
        a.block_mut(b1).set_mode(BlockMode::Slc).unwrap();
        let (_ppa, c3) = a.program_slc(b1, Lpn(3), 0).unwrap();
        assert_eq!(c3.start, 0);
    }

    #[test]
    fn read_latency_tracks_cell_kind() {
        let mut a = array();
        let t = *a.timing();
        let b = a.pop_free(PlaneId(0)).unwrap();
        a.block_mut(b).set_mode(BlockMode::Ips).unwrap();
        let (ppa, done) = a.program_slc(b, Lpn(1), 0).unwrap();
        let c = a.read(ppa, done.end).unwrap();
        assert_eq!(c.end - c.start, t.slc_read, "SLC page reads at SLC speed");
        // reprogram the word line to 2 bits → reads become TLC speed
        let (_p, _f, done) = a.reprogram(b, Lpn(2), c.end).unwrap();
        let c = a.read(ppa, done.end).unwrap();
        assert_eq!(c.end - c.start, t.tlc_read, "reprogrammed page reads at TLC speed");
    }

    #[test]
    fn counters_accumulate() {
        let mut a = array();
        let b = a.pop_free(PlaneId(0)).unwrap();
        a.block_mut(b).set_mode(BlockMode::Tlc).unwrap();
        a.program_tlc(b, &[Lpn(1), Lpn(2), Lpn(3)], 0).unwrap();
        a.program_tlc(b, &[Lpn(4)], 0).unwrap();
        let c = a.counters();
        assert_eq!(c.progs_tlc_wl, 2);
        assert_eq!(c.progs_tlc_pages, 4);
        assert_eq!(c.pages_programmed(), 4);
    }

    #[test]
    fn erase_returns_to_free_list() {
        let mut a = array();
        let b = a.pop_free(PlaneId(0)).unwrap();
        let before = a.free_block_count(PlaneId(0));
        a.block_mut(b).set_mode(BlockMode::Slc).unwrap();
        a.program_slc(b, Lpn(1), 0).unwrap();
        let g = *a.geometry();
        a.invalidate(b.page(&g, 0, 0)).unwrap();
        a.erase(b, 0).unwrap();
        assert_eq!(a.free_block_count(PlaneId(0)), before, "erase does not auto-free");
        a.push_free(b).unwrap();
        assert_eq!(a.free_block_count(PlaneId(0)), before + 1);
        assert_eq!(a.counters().erases, 1);
    }

    #[test]
    fn unwritten_read_rejected() {
        let mut a = array();
        assert!(a.read(Ppa(0), 0).is_err());
    }

    #[test]
    fn interconnect_mode_splits_phases_and_serializes_the_die() {
        // small geometry: planes_per_die = 2, so planes 0 and 1 share a
        // die; give the bus a nonzero per-page cost
        let mut cfg = presets::small();
        cfg.sim.interconnect = true;
        cfg.timing.bus_ns_per_page = 10_000;
        let mut a = FlashArray::new(&cfg);
        assert!(a.interconnect_enabled() && a.multiplane_enabled());
        let t = *a.timing();
        let b0 = a.pop_free(PlaneId(0)).unwrap();
        let b1 = a.pop_free(PlaneId(1)).unwrap();
        a.block_mut(b0).set_mode(BlockMode::Slc).unwrap();
        a.block_mut(b1).set_mode(BlockMode::Slc).unwrap();
        let (_p, c0) = a.program_slc(b0, Lpn(1), 0).unwrap();
        assert_eq!(c0.transfer_ns, 10_000, "data-in crosses the bus");
        assert_eq!(c0.array_ns, t.slc_prog);
        assert_eq!(c0.end, 10_000 + t.slc_prog);
        // the sibling plane's program waits for the die (and the bus)
        let (_p, c1) = a.program_slc(b1, Lpn(2), 0).unwrap();
        assert_eq!(c1.start, 10_000, "second transfer queues on the bus");
        assert_eq!(c1.end, c0.end + t.slc_prog, "die serializes the array phases");
        assert!(c1.queued_ns > 0);
    }

    #[test]
    fn program_group_matches_individual_issue_under_the_lump() {
        // lump model: a group is byte-identical to member-wise issue
        let mk = || {
            let mut a = array();
            let b0 = a.pop_free(PlaneId(0)).unwrap();
            let b1 = a.pop_free(PlaneId(1)).unwrap();
            a.block_mut(b0).set_mode(BlockMode::Tlc).unwrap();
            a.block_mut(b1).set_mode(BlockMode::Tlc).unwrap();
            (a, b0, b1)
        };
        let (mut ga, b0, b1) = mk();
        let wl3 = [Lpn(1), Lpn(2), Lpn(3)];
        let wl1 = [Lpn(4)];
        let group = ga
            .program_tlc_group(&[(b0, &wl3[..]), (b1, &wl1[..])], 5)
            .unwrap();
        let (mut ia, c0, c1) = mk();
        let one = ia.program_tlc(c0, &[Lpn(1), Lpn(2), Lpn(3)], 5).unwrap();
        let two = ia.program_tlc(c1, &[Lpn(4)], 5).unwrap();
        assert_eq!(group[0], one);
        assert_eq!(group[1], two);
        assert_eq!(ga.counters(), ia.counters());
    }

    #[test]
    fn lost_plane_stops_allocating_but_stays_readable() {
        let mut a = array();
        let b = a.pop_free(PlaneId(0)).unwrap();
        a.block_mut(b).set_mode(BlockMode::Slc).unwrap();
        let (ppa, done) = a.program_slc(b, Lpn(1), 0).unwrap();
        let dropped = a.mark_plane_lost(PlaneId(0));
        assert!(dropped > 0, "free blocks retired from allocation");
        assert!(a.plane_lost(PlaneId(0)));
        assert_eq!(a.live_planes(), a.geometry().planes() - 1);
        assert!(a.pop_free(PlaneId(0)).is_none());
        assert!(a.pop_free_min_erase(PlaneId(0), 8).is_none());
        assert_eq!(a.free_block_count(PlaneId(0)), 0);
        // resident data survives for salvage reads
        a.read(ppa, done.end).unwrap();
        // returns to the lost plane are swallowed, not errors
        a.invalidate(ppa).unwrap();
        a.erase(b, done.end).unwrap();
        a.push_free(b).unwrap();
        assert_eq!(a.free_block_count(PlaneId(0)), 0);
        // other planes keep allocating
        assert!(a.pop_free(PlaneId(1)).is_some());
    }

    #[test]
    fn program_slowdown_scales_programs_and_erases_not_reads() {
        let mut a = array();
        let t = *a.timing();
        a.set_program_slowdown(200);
        assert_eq!(a.program_slowdown(), 200);
        let b = a.pop_free(PlaneId(0)).unwrap();
        a.block_mut(b).set_mode(BlockMode::Slc).unwrap();
        let (ppa, c) = a.program_slc(b, Lpn(1), 0).unwrap();
        assert_eq!(c.end - c.start, 2 * t.slc_prog, "2x slower program");
        let r = a.read(ppa, c.end).unwrap();
        assert_eq!(r.end - r.start, t.slc_read, "reads keep nominal speed");
        a.invalidate(ppa).unwrap();
        let e = a.erase(b, r.end).unwrap();
        assert_eq!(e.end - e.start, 2 * t.erase, "2x slower erase");
    }

    #[test]
    fn audit_passes_on_fresh_array() {
        let a = array();
        for p in 0..a.geometry().planes() {
            a.audit_plane(PlaneId(p)).unwrap();
        }
    }

    /// The SoA arenas and the inline per-block vectors are the same
    /// device: identical op sequence → identical completions, counters,
    /// and page state (the `sim.soa_blocks` differential at array level).
    #[test]
    fn soa_array_matches_inline_array() {
        let mk = |soa: bool| {
            let mut cfg = presets::small();
            cfg.sim.soa_blocks = soa;
            FlashArray::new(&cfg)
        };
        let mut s = mk(true);
        let mut i = mk(false);
        let drive = |a: &mut FlashArray| -> Vec<String> {
            let mut log = Vec::new();
            let b0 = a.pop_free(PlaneId(0)).unwrap();
            let b1 = a.pop_free(PlaneId(1)).unwrap();
            a.block_mut(b0).set_mode(BlockMode::Ips).unwrap();
            a.block_mut(b1).set_mode(BlockMode::Tlc).unwrap();
            let (ppa, c) = a.program_slc(b0, Lpn(1), 0).unwrap();
            log.push(format!("{ppa:?} {c:?}"));
            let (p, f, c) = a.reprogram(b0, Lpn(2), c.end).unwrap();
            log.push(format!("{p:?} {f} {c:?}"));
            let (ps, c) = a.program_tlc(b1, &[Lpn(3), Lpn(4)], 0).unwrap();
            log.push(format!("{ps:?} {c:?}"));
            let r = a.read(ppa, c.end).unwrap();
            log.push(format!("{r:?}"));
            a.invalidate(ppa).unwrap();
            for pib in 0..a.geometry().pages_per_block {
                let b = a.block(b0);
                log.push(format!(
                    "{} {} {:?} {:?}",
                    b.is_valid(pib),
                    b.is_written(pib),
                    b.lpn_at(pib),
                    b.page_kind(pib)
                ));
            }
            let b = a.block(b0);
            log.push(format!("{} {} {}", b.valid_count(), b.written_count(), b.erase_count()));
            log.push(format!("{:?}", a.counters()));
            for p in 0..a.geometry().planes() {
                a.audit_plane(PlaneId(p)).unwrap();
            }
            log
        };
        assert_eq!(drive(&mut s), drive(&mut i));
    }
}
