//! Workload-driven simulation: replays a [`Trace`] through a cache
//! scheme over the FTL and flash array, detecting idle windows and
//! collecting the paper's metrics.
//!
//! Timing model: each host request is split into 4 KiB pages; each
//! page becomes one flash operation routed by the scheme. Queueing is
//! captured by the flash array's resource timelines — the historical
//! per-plane lump, or (under `sim.interconnect`) the channel-bus /
//! die / plane model of [`crate::flash::Interconnect`] — so an
//! operation issued at `now` on a busy resource starts when it frees
//! up, and request latency includes the conflict delays the paper
//! analyses (host writes arriving during baseline block reclamation
//! wait; IPS/agc's page-granular steps barely delay them). Each
//! completion's queued/transfer/array phase split feeds the engine's
//! [`crate::metrics::PhaseStats`] accountants.
//!
//! Idle windows: when the gap between the device quiescing and the
//! next arrival exceeds `cache.idle_threshold`, the scheme's
//! `idle_work` runs with the next arrival as its deadline (background
//! steps issued before the deadline may overrun it — exactly the
//! paper's Fig. 7 conflict).

use crate::blk::{self, Bio, BioKind};
use crate::cache::{self, CachePolicy};
use crate::config::{Config, Nanos};
use crate::flash::Lpn;
use crate::ftl::Ftl;
use crate::metrics::{BandwidthTimeline, BlkStats, LatencyStats, PhaseStats, RunSummary};
use crate::trace::scenario::Scenario;
use crate::trace::source::OpSource;
use crate::trace::{OpKind, Trace, TraceOp};
use crate::Result;

/// A configured simulator instance (one scheme over one fresh SSD).
pub struct Simulator {
    cfg: Config,
    ftl: Ftl,
    policy: Box<dyn CachePolicy>,
    /// Host write-request latencies.
    pub write_latency: LatencyStats,
    /// Host read-request latencies.
    pub read_latency: LatencyStats,
    /// Phase split of the flash ops behind host writes.
    pub write_phases: PhaseStats,
    /// Phase split of the flash ops behind host reads.
    pub read_phases: PhaseStats,
    /// Host write bandwidth timeline.
    pub bandwidth: BandwidthTimeline,
    /// Host read bandwidth timeline.
    pub read_bandwidth: BandwidthTimeline,
    /// Block-front-end counters (zero under the page front end).
    pub blk: BlkStats,
    /// Simulated clock (last activity).
    now: Nanos,
}

impl Simulator {
    /// Build a simulator for `cfg` (scheme from `cfg.cache.scheme`).
    pub fn new(cfg: Config) -> Result<Simulator> {
        cfg.validate()?;
        let mut ftl = Ftl::new(&cfg)?;
        let mut policy = cache::build(&cfg);
        policy.init(&mut ftl)?;
        Ok(Simulator {
            write_latency: LatencyStats::with_resolution(
                cfg.sim.hist_sub_buckets,
                cfg.sim.latency_samples,
            ),
            read_latency: LatencyStats::with_resolution(
                cfg.sim.hist_sub_buckets,
                cfg.sim.latency_samples,
            ),
            write_phases: PhaseStats::default(),
            read_phases: PhaseStats::default(),
            bandwidth: BandwidthTimeline::new(cfg.sim.bandwidth_window),
            read_bandwidth: BandwidthTimeline::new(cfg.sim.bandwidth_window),
            blk: BlkStats::default(),
            cfg,
            ftl,
            policy,
            now: 0,
        })
    }

    /// Access the FTL (diagnostics, audits).
    pub fn ftl(&self) -> &Ftl {
        &self.ftl
    }
    /// Scheme name.
    pub fn scheme_name(&self) -> &'static str {
        self.policy.name()
    }
    /// Logical page limit for trace construction.
    pub fn lpn_limit(&self) -> u64 {
        self.ftl.map.lpn_limit()
    }
    /// Logical byte capacity for trace construction.
    pub fn logical_bytes(&self) -> u64 {
        self.lpn_limit() * self.cfg.geometry.page_bytes as u64
    }

    /// Replay a whole trace under `scenario`; returns the run summary.
    pub fn run(&mut self, trace: &Trace, scenario: Scenario) -> Result<RunSummary> {
        if self.cfg.blk.enabled {
            // route through the bio front end: one single-segment bio
            // per trace op, sector-granular
            let sector = self.cfg.blk.sector_bytes;
            let fua = self.cfg.blk.fua;
            let name = trace.name.clone();
            let bios = trace.ops.iter().map(|op| {
                let mut b = Bio::from_op(op, sector);
                if fua && b.kind == BioKind::Write {
                    b.fua = true;
                }
                Ok(b)
            });
            return self.run_bios(&name, bios, scenario);
        }
        self.run_ops(&trace.name, trace.ops.iter().copied(), scenario)
    }

    /// Replay a pull-based [`OpSource`] — the streaming twin of
    /// [`Simulator::run`], converged on the iterator shape `run_bios`
    /// already has: ops are consumed one at a time, so a day-scale
    /// synthetic workload ([`crate::trace::source::SynthSource`]) holds
    /// O(1) trace memory. Routes through the exact dispatch body `run`
    /// uses (and through [`Simulator::run_bios`] under the block front
    /// end), so streamed-vs-materialized equality reduces to the
    /// sources themselves — pinned by the lockstep property suite and
    /// the `sim.streaming_traces` differential tests.
    pub fn run_source<S: OpSource>(&mut self, source: S, scenario: Scenario) -> Result<RunSummary> {
        let name = source.name().to_string();
        if self.cfg.blk.enabled {
            let sector = self.cfg.blk.sector_bytes;
            let fua = self.cfg.blk.fua;
            let bios = source.ops().map(move |op| {
                let mut b = Bio::from_op(&op, sector);
                if fua && b.kind == BioKind::Write {
                    b.fua = true;
                }
                Ok(b)
            });
            return self.run_bios(&name, bios, scenario);
        }
        self.run_ops(&name, source.ops(), scenario)
    }

    /// Shared page-front-end replay body: `run` feeds it a materialized
    /// trace's ops, `run_source` feeds it a streaming source — both by
    /// value through one iterator, so the two paths cannot diverge.
    fn run_ops<I>(&mut self, name: &str, ops: I, scenario: Scenario) -> Result<RunSummary>
    where
        I: IntoIterator<Item = TraceOp>,
    {
        let wall0 = std::time::Instant::now();
        let idle_threshold = self.cfg.cache.idle_threshold;
        let page = self.cfg.geometry.page_bytes as u64;
        let lpn_limit = self.ftl.map.lpn_limit();
        let mut host_bytes = 0u64;
        let mut host_bytes_read = 0u64;

        for op in ops {
            let arrival = op.at;
            // idle window before this arrival?
            if scenario == Scenario::Daily {
                let quiesce = self.now;
                if arrival > quiesce.saturating_add(idle_threshold) {
                    let start = quiesce.saturating_add(idle_threshold);
                    self.policy.idle_work(&mut self.ftl, start, arrival)?;
                }
            }
            let first_lpn = (op.offset / page) % lpn_limit;
            let n_pages = (op.len as u64).div_ceil(page).max(1);
            match op.kind {
                OpKind::Write => {
                    let mut req_end = arrival;
                    for i in 0..n_pages {
                        let lpn = Lpn((first_lpn + i) % lpn_limit);
                        self.ftl.ledger.host_page();
                        let c = self.policy.host_write_page(&mut self.ftl, lpn, arrival)?;
                        self.write_phases.add(&c);
                        req_end = req_end.max(c.end);
                    }
                    self.write_latency.record(req_end - arrival);
                    self.bandwidth.record(req_end, op.len as u64);
                    host_bytes += op.len as u64;
                    self.now = self.now.max(req_end);
                }
                OpKind::Read => {
                    let mut req_end = arrival;
                    for i in 0..n_pages {
                        let lpn = Lpn((first_lpn + i) % lpn_limit);
                        let c = self.ftl.host_read(lpn, arrival)?;
                        self.read_phases.add(&c);
                        req_end = req_end.max(c.end);
                    }
                    self.read_latency.record(req_end - arrival);
                    self.read_bandwidth.record(req_end, op.len as u64);
                    host_bytes_read += op.len as u64;
                    self.now = self.now.max(req_end);
                }
            }
            self.now = self.now.max(arrival);
        }

        // end-of-workload flush (daily): clear/convert the SLC cache
        if scenario.flush_at_end() {
            let end = self.policy.flush(&mut self.ftl, self.now)?;
            self.now = self.now.max(end);
        }

        if self.cfg.sim.verify {
            self.ftl.audit()?;
        }

        Ok(RunSummary {
            scheme: self.policy.name().to_string(),
            workload: name.to_string(),
            scenario: scenario.name().to_string(),
            seed: self.cfg.sim.seed,
            write_latency: self.write_latency.clone(),
            read_latency: self.read_latency.clone(),
            write_phases: self.write_phases,
            read_phases: self.read_phases,
            ledger: self.ftl.ledger,
            bandwidth: self.bandwidth.clone(),
            read_bandwidth: self.read_bandwidth.clone(),
            blk: self.blk,
            sim_end: self.now,
            host_bytes_written: host_bytes,
            host_bytes_read,
            wall_clock: wall0.elapsed(),
        })
    }

    /// Replay a bio stream (block front end). The streaming twin of
    /// [`Simulator::run`]: bios are consumed one at a time, so a
    /// million-request MSR replay ([`crate::trace::msr::MsrStream`])
    /// holds only its reorder window in memory, never the whole trace.
    ///
    /// Dispatch per bio: split/merge via [`blk::plan`], RMW pre-reads
    /// before partially covered write pages (billed to this request's
    /// latency and the ledger's host reads), flush/FUA barriers through
    /// the scheme's `write_barrier`. With page-aligned bios and
    /// `merge_window = 0` this is byte-identical to the page front end
    /// (enforced by `tests/integration_blk.rs`).
    pub fn run_bios<I>(&mut self, name: &str, bios: I, scenario: Scenario) -> Result<RunSummary>
    where
        I: IntoIterator<Item = Result<Bio>>,
    {
        let wall0 = std::time::Instant::now();
        let idle_threshold = self.cfg.cache.idle_threshold;
        let page = self.cfg.geometry.page_bytes as u64;
        let lpn_limit = self.ftl.map.lpn_limit();
        let blk_cfg = self.cfg.blk;
        let mut host_bytes = 0u64;
        let mut host_bytes_read = 0u64;
        let mut writes_since_flush = 0u32;
        // planner scratch (§Perf): reused across every bio of the
        // replay under batched dispatch (zero steady-state allocations
        // once grown); the oracle path allocates per bio as before
        let batched = self.cfg.sim.batched_dispatch;
        let mut plan_buf = blk::Plan::default();

        for bio in bios {
            let bio = bio?;
            let arrival = bio.at;
            if scenario == Scenario::Daily {
                let quiesce = self.now;
                if arrival > quiesce.saturating_add(idle_threshold) {
                    let start = quiesce.saturating_add(idle_threshold);
                    self.policy.idle_work(&mut self.ftl, start, arrival)?;
                }
            }
            if batched {
                blk::plan_into(&bio, &blk_cfg, page, &mut plan_buf);
            } else {
                plan_buf = blk::plan(&bio, &blk_cfg, page);
            }
            let plan = &plan_buf;
            match plan.kind {
                BioKind::Write if plan.pages.is_empty() => {
                    // zero-length payload: no pages to program, no
                    // latency sample, no bandwidth contribution — a 0 ns
                    // sample here would skew p50 under sparse replays
                    self.blk.empty_bios += 1;
                }
                BioKind::Write => {
                    self.blk.bios += 1;
                    self.blk.splits += plan.splits;
                    self.blk.merges += plan.merges;
                    self.blk.rmw_reads += plan.rmw_reads;
                    self.blk.write_pages += plan.pages.len() as u64;
                    let mut req_end = arrival;
                    for io in &plan.pages {
                        let lpn = Lpn(io.page % lpn_limit);
                        let mut issue = arrival;
                        if io.pre_read {
                            // RMW: fetch the page's old sectors before
                            // overwriting part of it; the program waits
                            // for the read
                            let pre = self.ftl.host_read(lpn, arrival)?;
                            self.write_phases.add(&pre);
                            issue = pre.end;
                            req_end = req_end.max(pre.end);
                        }
                        self.ftl.ledger.host_page();
                        let c = self.policy.host_write_page(&mut self.ftl, lpn, issue)?;
                        self.write_phases.add(&c);
                        req_end = req_end.max(c.end);
                    }
                    let mut barrier = bio.fua;
                    if bio.fua {
                        self.blk.fua_writes += 1;
                    }
                    if blk_cfg.flush_every > 0 {
                        writes_since_flush += 1;
                        if writes_since_flush >= blk_cfg.flush_every {
                            barrier = true;
                        }
                    }
                    if barrier {
                        // every barrier resets the periodic-flush
                        // counter — FUA and explicit flush bios already
                        // persisted everything a `flush_every` barrier
                        // would, so counting writes across them would
                        // schedule a spurious second barrier
                        writes_since_flush = 0;
                        // serial engine: everything in flight is what
                        // `self.now` already tracks — drain to it
                        let drain = self.now.max(req_end);
                        let t = self.policy.write_barrier(&mut self.ftl, drain)?;
                        self.now = self.now.max(t);
                        self.blk.flushes += 1;
                    }
                    let bytes = bio.total_bytes(blk_cfg.sector_bytes);
                    self.write_latency.record(req_end - arrival);
                    self.bandwidth.record(req_end, bytes);
                    host_bytes += bytes;
                    self.now = self.now.max(req_end);
                }
                BioKind::Read => {
                    self.blk.bios += 1;
                    self.blk.splits += plan.splits;
                    self.blk.merges += plan.merges;
                    self.blk.read_pages += plan.pages.len() as u64;
                    let mut req_end = arrival;
                    for io in &plan.pages {
                        let lpn = Lpn(io.page % lpn_limit);
                        let c = self.ftl.host_read(lpn, arrival)?;
                        self.read_phases.add(&c);
                        req_end = req_end.max(c.end);
                    }
                    let bytes = bio.total_bytes(blk_cfg.sector_bytes);
                    self.read_latency.record(req_end - arrival);
                    self.read_bandwidth.record(req_end, bytes);
                    host_bytes_read += bytes;
                    self.now = self.now.max(req_end);
                }
                BioKind::Flush => {
                    writes_since_flush = 0;
                    let drain = self.now.max(arrival);
                    let t = self.policy.write_barrier(&mut self.ftl, drain)?;
                    self.now = self.now.max(t);
                    self.blk.flushes += 1;
                }
            }
            self.now = self.now.max(arrival);
        }

        if scenario.flush_at_end() {
            let end = self.policy.flush(&mut self.ftl, self.now)?;
            self.now = self.now.max(end);
        }

        if self.cfg.sim.verify {
            self.ftl.audit()?;
        }

        Ok(RunSummary {
            scheme: self.policy.name().to_string(),
            workload: name.to_string(),
            scenario: scenario.name().to_string(),
            seed: self.cfg.sim.seed,
            write_latency: self.write_latency.clone(),
            read_latency: self.read_latency.clone(),
            write_phases: self.write_phases,
            read_phases: self.read_phases,
            ledger: self.ftl.ledger,
            bandwidth: self.bandwidth.clone(),
            read_bandwidth: self.read_bandwidth.clone(),
            blk: self.blk,
            sim_end: self.now,
            host_bytes_written: host_bytes,
            host_bytes_read,
            wall_clock: wall0.elapsed(),
        })
    }

    /// Convenience: build + run in one call.
    pub fn run_once(cfg: Config, trace: &Trace, scenario: Scenario) -> Result<RunSummary> {
        Simulator::new(cfg)?.run(trace, scenario)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, Scheme, MS, SEC, US};
    use crate::trace::{scenario, synth, profiles};

    fn small_cfg(scheme: Scheme) -> Config {
        let mut cfg = presets::small();
        cfg.cache.scheme = scheme;
        cfg.cache.slc_cache_bytes = 1 << 20;
        cfg.sim.verify = true;
        cfg
    }

    #[test]
    fn bursty_baseline_shows_cliff() {
        let cfg = small_cfg(Scheme::Baseline);
        let mut sim = Simulator::new(cfg.clone()).unwrap();
        // write 3× the cache size sequentially, no idle
        let trace = scenario::sequential_fill("seq", 3 << 20, sim.logical_bytes());
        let s = sim.run(&trace, scenario::Scenario::Bursty).unwrap();
        // breakdown: both SLC writes (pre-cliff) and TLC writes (post)
        assert!(s.ledger.slc_cache_writes > 0);
        assert!(s.ledger.tlc_direct_writes > 0);
        // bandwidth collapses after the cliff: mean latency between
        // pure-SLC and pure-TLC page cost
        assert!(s.mean_write_latency() > cfg.timing.slc_prog as f64);
        // no idle-time migration during the run; the end-of-workload
        // flush moves at most one cache's worth (§III)
        let cache_pages = (1u64 << 20) / 4096;
        assert!(s.ledger.slc2tlc_migrations <= cache_pages);
        assert!(s.wa() < 1.4, "wa={}", s.wa());
    }

    #[test]
    fn daily_baseline_reclaims_and_amplifies() {
        let cfg = small_cfg(Scheme::Baseline);
        let mut sim = Simulator::new(cfg).unwrap();
        // two 1 MiB streams with a long idle gap between
        let trace = scenario::daily_streams(2, 1 << 20, 60 * SEC, sim.logical_bytes());
        let s = sim.run(&trace, scenario::Scenario::Daily).unwrap();
        assert!(s.ledger.slc2tlc_migrations > 0, "idle reclamation ran");
        assert!(s.wa() > 1.5, "daily-use WA grows: {}", s.wa());
    }

    #[test]
    fn daily_ips_avoids_amplification() {
        let cfg = small_cfg(Scheme::Ips);
        let mut sim = Simulator::new(cfg).unwrap();
        let trace = scenario::daily_streams(2, 1 << 20, 60 * SEC, sim.logical_bytes());
        let s = sim.run(&trace, scenario::Scenario::Daily).unwrap();
        assert!(s.wa() < 1.1, "IPS keeps WA near 1: {}", s.wa());
    }

    #[test]
    fn bursty_ips_beats_baseline_after_cliff() {
        // total volume = 4× cache: baseline pays TLC for 3/4 of it;
        // IPS intermittently re-arms SLC windows.
        let vol = 4u64 << 20;
        let run = |scheme| {
            let cfg = small_cfg(scheme);
            let mut sim = Simulator::new(cfg).unwrap();
            let t = scenario::sequential_fill("seq", vol, sim.logical_bytes());
            sim.run(&t, scenario::Scenario::Bursty).unwrap()
        };
        let base = run(Scheme::Baseline);
        let ips = run(Scheme::Ips);
        assert!(
            ips.mean_write_latency() < base.mean_write_latency(),
            "ips {} < baseline {}",
            ips.mean_write_latency(),
            base.mean_write_latency()
        );
    }

    #[test]
    fn synthetic_profile_runs_all_schemes_daily() {
        let p = profiles::by_name("HM_0").unwrap();
        for scheme in [Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc, Scheme::Coop] {
            let mut cfg = small_cfg(scheme);
            cfg.cache.idle_threshold = 10 * MS;
            let mut sim = Simulator::new(cfg).unwrap();
            let trace = synth::generate_scaled(p, 42, sim.logical_bytes(), 0.002);
            let s = sim.run(&trace, scenario::Scenario::Daily).unwrap();
            assert!(s.ledger.host_pages > 0, "{scheme:?} processed writes");
            assert!(s.wa() >= 0.999, "{scheme:?} WA >= 1: {}", s.wa());
            // audit ran inside (verify=true) — reaching here is the test
        }
    }

    #[test]
    fn read_latency_tracked() {
        let mut cfg = small_cfg(Scheme::Baseline);
        cfg.sim.latency_samples = 4; // read tails are inspectable too
        let mut sim = Simulator::new(cfg).unwrap();
        let mut trace = scenario::sequential_fill("seq", 256 << 10, sim.logical_bytes());
        // append reads of the just-written range
        let dur = trace.duration();
        for i in 0..8u64 {
            trace.ops.push(crate::trace::TraceOp {
                at: dur + 1 + i,
                kind: OpKind::Read,
                offset: i * 4096,
                len: 4096,
            });
        }
        let s = sim.run(&trace, scenario::Scenario::Bursty).unwrap();
        assert_eq!(s.read_latency.count(), 8);
        assert!(s.read_latency.mean() > 0.0);
        // cfg.sim.latency_samples applies to reads as well as writes
        assert_eq!(s.read_latency.raw_us().len(), 4);
        // reads feed the bandwidth timeline too, not just latency
        assert_eq!(s.read_bandwidth.total_bytes(), 8 * 4096);
        assert_eq!(s.host_bytes_read, 8 * 4096);
        assert!(s.avg_read_bandwidth_mbs() > 0.0);
        // and the phase accountants saw every flash op
        assert_eq!(s.read_phases.ops, 8);
        assert!(s.write_phases.ops > 0);
        assert_eq!(s.write_phases.transfer_ns, 0, "lump model moves no bus data");
    }

    #[test]
    fn blk_subpage_write_pays_rmw_pre_read() {
        // full-page write maps the LPN, then a quarter-page overwrite
        // must pre-read the mapped page before programming
        let trace = crate::trace::Trace {
            name: "subpage".into(),
            ops: vec![
                crate::trace::TraceOp { at: 0, kind: OpKind::Write, offset: 0, len: 4096 },
                crate::trace::TraceOp { at: 2 * MS, kind: OpKind::Write, offset: 0, len: 1024 },
            ],
        };
        let run = |rmw: bool| {
            let mut cfg = small_cfg(Scheme::Ips);
            cfg.blk.enabled = true;
            cfg.blk.merge_window = 0;
            cfg.blk.rmw = rmw;
            Simulator::new(cfg).unwrap().run(&trace, scenario::Scenario::Bursty).unwrap()
        };
        let s = run(true);
        assert_eq!(s.blk.bios, 2);
        assert_eq!(s.blk.write_pages, 2);
        assert_eq!(s.blk.rmw_reads, 1, "only the partial page needs the old data");
        assert_eq!(s.ledger.host_reads, 1, "pre-read hits the ledger");
        assert_eq!(s.ledger.host_pages, 2);
        assert_eq!(s.host_bytes_written, 4096 + 1024, "host volume is sector-accurate");
        // the program waited for the mapped pre-read: the run ends at
        // least one SLC read later than the blind-overwrite run
        let blind = run(false);
        assert_eq!(blind.ledger.host_reads, 0);
        assert!(
            s.sim_end >= blind.sim_end + 20 * US,
            "RMW serializes read before program: {} vs {}",
            s.sim_end,
            blind.sim_end
        );
    }

    #[test]
    fn blk_rmw_off_blind_overwrites() {
        let mut cfg = small_cfg(Scheme::Ips);
        cfg.blk.enabled = true;
        cfg.blk.rmw = false;
        let mut sim = Simulator::new(cfg).unwrap();
        let trace = crate::trace::Trace {
            name: "subpage".into(),
            ops: vec![crate::trace::TraceOp { at: 0, kind: OpKind::Write, offset: 0, len: 1024 }],
        };
        let s = sim.run(&trace, scenario::Scenario::Bursty).unwrap();
        assert_eq!(s.blk.rmw_reads, 0);
        assert_eq!(s.ledger.host_reads, 0);
    }

    #[test]
    fn blk_flush_every_counts_barriers() {
        let mut cfg = small_cfg(Scheme::Baseline);
        cfg.blk.enabled = true;
        cfg.blk.flush_every = 2;
        let mut sim = Simulator::new(cfg).unwrap();
        let trace = scenario::sequential_fill("seq", 256 << 10, sim.logical_bytes());
        let writes = trace.write_ops() as u64;
        let s = sim.run(&trace, scenario::Scenario::Bursty).unwrap();
        assert_eq!(s.blk.flushes, writes / 2, "a barrier every second write bio");
        assert_eq!(s.blk.bios, writes);
    }

    #[test]
    fn blk_fua_barriers_every_write() {
        let mut cfg = small_cfg(Scheme::Baseline);
        cfg.blk.enabled = true;
        cfg.blk.fua = true;
        let mut sim = Simulator::new(cfg).unwrap();
        let trace = scenario::sequential_fill("seq", 128 << 10, sim.logical_bytes());
        let writes = trace.write_ops() as u64;
        let s = sim.run(&trace, scenario::Scenario::Bursty).unwrap();
        assert_eq!(s.blk.fua_writes, writes);
        assert_eq!(s.blk.flushes, writes);
    }

    #[test]
    fn flush_bio_resets_periodic_barrier_counter() {
        // regression: an explicit flush bio used to leave
        // `writes_since_flush` untouched, so the next write after a
        // host flush could fire a spurious second barrier
        use crate::blk::Segment;
        let page_w = |at, page: u64| {
            Ok(Bio::write(at, vec![Segment { sector: page * 8, n_sectors: 8 }], false))
        };
        let mut cfg = small_cfg(Scheme::Baseline);
        cfg.blk.enabled = true;
        cfg.blk.flush_every = 2;
        let mut sim = Simulator::new(cfg.clone()).unwrap();
        let bios = vec![page_w(0, 0), Ok(Bio::flush(MS)), page_w(2 * MS, 1)];
        let s = sim.run_bios("flush-then-write", bios, scenario::Scenario::Bursty).unwrap();
        assert_eq!(s.blk.bios, 2);
        assert_eq!(s.blk.flushes, 1, "only the explicit flush barriers; no spurious second");

        // FUA barriers restart the countdown too
        let mut sim = Simulator::new(cfg).unwrap();
        let bios = vec![
            Ok(Bio::write(0, vec![Segment { sector: 0, n_sectors: 8 }], true)),
            page_w(MS, 1),
        ];
        let s = sim.run_bios("fua-then-write", bios, scenario::Scenario::Bursty).unwrap();
        assert_eq!(s.blk.fua_writes, 1);
        assert_eq!(s.blk.flushes, 1, "the FUA barrier counts; the follow-up write does not");
    }

    #[test]
    fn zero_length_write_bio_is_skipped_not_sampled() {
        // regression: an empty write plan used to record a 0 ns latency
        // sample and a 0-byte bandwidth point, dragging p50 down
        use crate::blk::Segment;
        let mut cfg = small_cfg(Scheme::Ips);
        cfg.blk.enabled = true;
        let mut sim = Simulator::new(cfg).unwrap();
        let bios = vec![
            Ok(Bio::write(0, Vec::new(), false)),
            Ok(Bio::write(MS, vec![Segment { sector: 0, n_sectors: 8 }], false)),
        ];
        let s = sim.run_bios("sparse", bios, scenario::Scenario::Bursty).unwrap();
        assert_eq!(s.blk.empty_bios, 1);
        assert_eq!(s.blk.bios, 1, "the empty bio is not counted as dispatched");
        assert_eq!(s.blk.write_pages, 1);
        assert_eq!(s.write_latency.count(), 1, "no 0 ns sample from the empty bio");
        assert!(s.write_latency.mean() > 0.0);
        assert_eq!(s.host_bytes_written, 4096);
    }

    #[test]
    fn huge_timestamp_daily_replay_errors_or_saturates() {
        // regression: a corrupt near-u64::MAX MSR row must surface as a
        // parse error through the streaming daily replay, never a panic
        let csv = format!(
            "128166372003061629,hm,0,Write,0,4096,1\n{},hm,0,Write,4096,4096,1\n",
            u64::MAX
        );
        let mut cfg = small_cfg(Scheme::Ips);
        cfg.blk.enabled = true;
        cfg.sim.verify = false;
        let mut sim = Simulator::new(cfg).unwrap();
        let stream = crate::trace::msr::MsrStream::new(csv.as_bytes()).bios(512);
        let r = sim.run_bios("corrupt", stream, scenario::Scenario::Daily);
        assert!(r.is_err(), "absurd timestamp is a parse error, not a clock");

        // and the idle-window arithmetic itself saturates: a maximal
        // threshold simply means "never idle", not an overflowing add
        let mut cfg = small_cfg(Scheme::Baseline);
        cfg.cache.idle_threshold = u64::MAX;
        let mut sim = Simulator::new(cfg).unwrap();
        let trace = scenario::daily_streams(2, 256 << 10, 60 * SEC, sim.logical_bytes());
        let s = sim.run(&trace, scenario::Scenario::Daily).unwrap();
        assert_eq!(s.ledger.slc2tlc_migrations, 0, "no idle window ever opens");
    }

    #[test]
    fn latency_samples_captured_for_fig9() {
        let mut cfg = small_cfg(Scheme::Baseline);
        cfg.sim.latency_samples = 100;
        let mut sim = Simulator::new(cfg).unwrap();
        let trace = scenario::sequential_fill("seq", 1 << 20, sim.logical_bytes());
        let s = sim.run(&trace, scenario::Scenario::Bursty).unwrap();
        assert_eq!(s.write_latency.raw_us().len(), 32.min(100));
    }
}
