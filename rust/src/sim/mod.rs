//! The discrete-event simulation engine.

pub mod engine;

pub use engine::Simulator;
