//! `ips` — hybrid 3D SSD simulator and paper-reproduction launcher.
//!
//! Subcommands:
//! * `reproduce`    — regenerate the paper's figures (`--fig 3|...|all`);
//! * `run`          — one simulation: scheme × workload × scenario;
//! * `multi-tenant` — tenants → submission queues → scheduler → scheme,
//!   with per-tenant latency/WA attribution; `--fleet` sweeps the
//!   (scheme × scheduler) cross-product on worker threads;
//! * `fleet`        — device-population sweep: N heterogeneous SSDs
//!   (capacity / OP / pre-aged wear) per scheme × mix, folded into
//!   fleet-wide percentiles by mergeable histograms (JSON/CSV export);
//! * `replay`       — stream an MSR CSV through the block front end in
//!   constant memory (bounded reorder window, sector-granular bios);
//! * `sweep`        — ablations (cache size, idle threshold, group width);
//! * `audit`        — reprogram reliability audit via the PJRT artifact;
//! * `list`         — workloads, schemes, presets.
//!
//! `run`, `multi-tenant` and `replay` accept the `--blk` family: route
//! host requests through the bio-style block front end (sector-granular
//! scatter-gather, page split + contiguous merge, read-modify-write for
//! sub-page writes, flush/FUA barriers). Any `--blk-*` option implies
//! `--blk` itself.

use ips::cache;
use ips::config::{presets, AttributionMode, Config, MixKind, QosMode, SchedKind, Scheme, MS};
use ips::coordinator::{experiment, fleet, ExpOptions};
use ips::host::MultiTenantSimulator;
use ips::sim::Simulator;
use ips::trace::scenario::{self, Scenario};
use ips::trace::profiles;
use ips::util::cli::Command;
use ips::util::fmt::{bytes, nanos, TextTable};

/// The `--blk` option family, shared by `run`, `multi-tenant` and
/// `replay`.
fn blk_opts(c: Command) -> Command {
    c.flag("blk", None, "sector-granular block front end (split/merge/RMW/flush)")
        .opt("blk-sector-bytes", None, "B", "logical sector size (implies --blk)", None)
        .opt(
            "blk-merge-window",
            None,
            "N",
            "merge lookback in planned pages, 0 = off (implies --blk)",
            None,
        )
        .opt(
            "blk-flush-every",
            None,
            "N",
            "flush barrier every N writes per stream, 0 = off (implies --blk)",
            None,
        )
        .flag("blk-fua", None, "mark every write FUA: barrier per write (implies --blk)")
}

fn cli() -> Command {
    Command::new("ips", "In-place Switch: reprogramming-based SLC cache for hybrid 3D SSDs")
        .subcommand(
            Command::new("reproduce", "regenerate the paper's evaluation figures")
                .opt("fig", Some('f'), "N", "figure id (2|3|4|5|9|10|11|12|all)", Some("all"))
                .opt("scale", None, "N", "geometry divisor vs Table I", Some("4"))
                .opt("volume-scale", None, "F", "workload volume multiplier (default: 1/scale^2)", None)
                .opt("seed", Some('s'), "SEED", "rng seed", Some("42"))
                .opt("out", Some('o'), "DIR", "CSV output directory", Some("results"))
                .opt("threads", Some('j'), "N", "worker threads", None)
                .opt("workload", Some('w'), "NAME", "restrict to workload (repeatable)", None),
        )
        .subcommand(blk_opts(
            Command::new("run", "run one simulation")
                .opt("scheme", None, "S", "tlc-only|baseline|ips|ips-agc|coop", Some("ips"))
                .opt("workload", Some('w'), "NAME", "workload profile (or 'seq')", Some("HM_0"))
                .opt("scenario", None, "X", "bursty|daily", Some("daily"))
                .opt("scale", None, "N", "geometry divisor vs Table I", Some("4"))
                .opt("volume-scale", None, "F", "volume multiplier (default 1/scale^2)", None)
                .opt("seed", Some('s'), "SEED", "rng seed", Some("42"))
                .opt("config", Some('c'), "FILE", "TOML config overriding the preset", None)
                .flag("verify", None, "run full consistency audits"),
        ))
        .subcommand(blk_opts(
            Command::new("multi-tenant", "multi-tenant host front end (queues + scheduler)")
                .opt("scheme", None, "S", "tlc-only|baseline|ips|ips-agc|coop", Some("ips"))
                .opt("scheduler", None, "P", "fifo|round-robin|weighted-fair", Some("fifo"))
                .opt(
                    "mix",
                    Some('m'),
                    "M",
                    "aggressor-victims|uniform|read-heavy|write-heavy",
                    Some("aggressor-victims"),
                )
                .opt("tenants", Some('n'), "N", "tenant count", Some("4"))
                .opt("scenario", None, "X", "bursty|daily", Some("bursty"))
                .opt("scale", None, "N", "geometry divisor vs Table I", Some("8"))
                .opt("seed", Some('s'), "SEED", "rng seed", Some("42"))
                .opt("threads", Some('j'), "N", "fleet worker threads", None)
                .opt("config", Some('c'), "FILE", "TOML config overriding the preset", None)
                .flag("fleet", None, "sweep the full (scheme x scheduler) cross-product")
                .flag("partition", None, "per-tenant SLC cache slices (fleet: adds variants)")
                .opt("reserved-frac", None, "F", "reserved fraction of the cache", None)
                .opt("qos", None, "Q", "admission control: off|strict|slo", None)
                .opt("qos-rate", None, "MBPS", "per-tenant sustained rate (MB/s)", None)
                .opt("qos-burst", None, "KIB", "token-bucket burst budget (KiB)", None)
                .opt("slo-p99", None, "MS", "victim p99 SLO target (ms, slo mode)", None)
                .opt(
                    "attribution",
                    None,
                    "A",
                    "shared-cost attribution: proportional|owner (fleet: owner adds both)",
                    None,
                )
                .flag("interconnect", None, "channel/die/plane timing model (vs plane lump)")
                .opt(
                    "bus-ns-per-page",
                    None,
                    "NS",
                    "channel-bus ns per page (implies --interconnect)",
                    None,
                )
                .opt("channels", None, "N", "override geometry channel count", None)
                .opt("dies-per-chip", None, "N", "override geometry dies per chip", None)
                .flag("verify", None, "run full consistency audits"),
        ))
        .subcommand(
            Command::new("fleet", "device-population sweep folded into fleet-wide percentiles")
                .opt("devices", Some('d'), "N", "population size", Some("8"))
                .opt("scheme", None, "S", "tlc-only|baseline|ips|ips-agc|coop|all", Some("all"))
                .opt(
                    "mix",
                    Some('m'),
                    "M",
                    "aggressor-victims|uniform|read-heavy|write-heavy",
                    Some("aggressor-victims"),
                )
                .opt("tenants", Some('n'), "N", "tenant count per device", Some("4"))
                .opt("scenario", None, "X", "bursty|daily", Some("bursty"))
                .opt("scale", None, "N", "geometry divisor vs Table I", Some("8"))
                .opt("seed", Some('s'), "SEED", "population seed", Some("42"))
                .opt("threads", Some('j'), "N", "worker threads", None)
                .opt(
                    "faults",
                    None,
                    "FRAC",
                    "fraction of devices given a mid-run fault schedule",
                    Some("0"),
                )
                .opt("json", None, "FILE", "write the fleet rollup as JSON", None)
                .opt("csv", None, "FILE", "write the fleet rollup as CSV", None)
                .opt(
                    "bench-out",
                    None,
                    "FILE",
                    "wall-clock/peak-RSS datapoint JSON (\"none\" to skip)",
                    Some("BENCH_PR10.json"),
                )
                .flag("per-device", None, "also print the per-device breakdown (CSV rows)"),
        )
        .subcommand(blk_opts(
            Command::new("replay", "stream an MSR CSV through the block front end")
                .opt("csv", None, "FILE", "MSR-format CSV file to stream", None)
                .opt("trace", Some('t'), "NAME", "<name>.csv under $MSR_TRACE_DIR", None)
                .opt("scheme", None, "S", "tlc-only|baseline|ips|ips-agc|coop", Some("ips"))
                .opt("scenario", None, "X", "bursty|daily", Some("daily"))
                .opt("scale", None, "N", "geometry divisor vs Table I", Some("4"))
                .opt("seed", Some('s'), "SEED", "rng seed", Some("42"))
                .opt("window", None, "N", "reorder window (max buffered requests)", Some("1024"))
                .flag("verify", None, "run full consistency audits"),
        ))
        .subcommand(
            Command::new("sweep", "ablation sweeps")
                .opt(
                    "what",
                    None,
                    "W",
                    "cache-size|idle-threshold|group-layers|device-qd|qd-joint|interconnect",
                    Some("cache-size"),
                )
                .opt("scale", None, "N", "geometry divisor", Some("8"))
                .opt("seed", Some('s'), "SEED", "rng seed", Some("42"))
                .opt(
                    "bus-ns-per-page",
                    None,
                    "NS",
                    "channel-bus ns per page (interconnect sweep)",
                    None,
                )
                .opt("channels", None, "N", "channel counts, comma-separated", None)
                .opt("dies-per-chip", None, "N", "dies/chip counts, comma-separated", None)
                .opt("workload", Some('w'), "NAME", "workload", Some("HM_0")),
        )
        .subcommand(
            Command::new("perf", "perf harness: structures, scan-vs-index, lump-vs-interconnect")
                .opt("preset", Some('p'), "P", "small|medium|large|table1", Some("large"))
                .opt("scenario", None, "X", "bursty|daily|both", Some("both"))
                .opt("scheme", None, "S", "tlc-only|baseline|ips|ips-agc|coop|all", Some("all"))
                .opt(
                    "volume-mult",
                    None,
                    "F",
                    "write volume as a multiple of logical capacity",
                    Some("2.0"),
                )
                .opt(
                    "compare",
                    None,
                    "C",
                    "structures (BENCH_PR9) | victim-index (BENCH_PR4) | interconnect (BENCH_PR5)",
                    Some("structures"),
                )
                .opt(
                    "out",
                    Some('o'),
                    "FILE",
                    "JSON perf-trajectory output (default by mode)",
                    Some("auto"),
                ),
        )
        .subcommand(
            Command::new("audit", "reprogram reliability audit (PJRT artifact)")
                .opt("sigma", None, "F", "process variation", Some("0.3"))
                .opt("alpha", None, "F", "interference coupling", Some("0.02"))
                .opt("batches", None, "N", "batches to average", Some("4"))
                .opt("seed", Some('s'), "SEED", "rng seed", Some("42")),
        )
        .subcommand(Command::new("list", "list workloads, schemes and presets"))
}

fn main() {
    let parsed = cli().parse_or_exit();
    let result = match parsed.subcommand {
        Some("reproduce") => cmd_reproduce(parsed.sub().unwrap()),
        Some("run") => cmd_run(parsed.sub().unwrap()),
        Some("multi-tenant") => cmd_multitenant(parsed.sub().unwrap()),
        Some("fleet") => cmd_fleet(parsed.sub().unwrap()),
        Some("replay") => cmd_replay(parsed.sub().unwrap()),
        Some("sweep") => cmd_sweep(parsed.sub().unwrap()),
        Some("perf") => cmd_perf(parsed.sub().unwrap()),
        Some("audit") => cmd_audit(parsed.sub().unwrap()),
        Some("list") => cmd_list(),
        _ => {
            println!("{}", cli().help());
            return;
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn opts_from(p: &ips::util::cli::Parsed) -> ips::Result<ExpOptions> {
    let mut opts = ExpOptions::default();
    opts.scale = p.get_u64("scale").map_err(|e| ips::Error::config(e))? as u32;
    if p.get("volume-scale").is_some() {
        opts.volume_scale = Some(p.get_f64("volume-scale").map_err(ips::Error::config)?);
    }
    opts.seed = p.get_u64("seed").map_err(ips::Error::config)?;
    if let Some(out) = p.get("out") {
        opts.out_dir = out.into();
    }
    if let Some(t) = p.get("threads") {
        opts.threads = t.parse().map_err(|_| ips::Error::config("--threads: bad integer"))?;
    }
    let w = p.get_all("workload");
    if !w.is_empty() {
        opts.workloads = Some(w.to_vec());
    }
    Ok(opts)
}

fn cmd_reproduce(p: &ips::util::cli::Parsed) -> ips::Result<()> {
    let opts = opts_from(p)?;
    let fig = p.get("fig").unwrap_or("all").to_string();
    println!(
        "reproducing fig {fig} at scale 1/{} (volume x{:.5}, seed {}, {} threads)",
        opts.scale,
        opts.volume(),
        opts.seed,
        opts.threads
    );
    experiment::run_figure(&fig, &opts)
}

/// Fold the `--blk` option family into `cfg.blk`; any `--blk-*`
/// option implies `--blk` itself (an inert knob would be a silent
/// misconfiguration, like `--bus-ns-per-page` and `--interconnect`).
fn apply_blk_flags(p: &ips::util::cli::Parsed, cfg: &mut Config) -> ips::Result<()> {
    if p.flag("blk") {
        cfg.blk.enabled = true;
    }
    if p.get("blk-sector-bytes").is_some() {
        cfg.blk.sector_bytes = p.get_u64("blk-sector-bytes").map_err(ips::Error::config)? as u32;
        cfg.blk.enabled = true;
    }
    if p.get("blk-merge-window").is_some() {
        cfg.blk.merge_window = p.get_u64("blk-merge-window").map_err(ips::Error::config)? as u32;
        cfg.blk.enabled = true;
    }
    if p.get("blk-flush-every").is_some() {
        cfg.blk.flush_every = p.get_u64("blk-flush-every").map_err(ips::Error::config)? as u32;
        cfg.blk.enabled = true;
    }
    if p.flag("blk-fua") {
        cfg.blk.fua = true;
        cfg.blk.enabled = true;
    }
    Ok(())
}

/// Rows describing what the block front end did, appended to the
/// single-run metric table when `--blk` ran.
fn blk_rows(t: &mut TextTable, blk: &ips::metrics::BlkStats) {
    t.row(vec!["blk_bios".into(), blk.bios.to_string()]);
    t.row(vec!["blk_splits".into(), blk.splits.to_string()]);
    t.row(vec!["blk_merges".into(), blk.merges.to_string()]);
    t.row(vec!["blk_rmw_pre_reads".into(), blk.rmw_reads.to_string()]);
    t.row(vec!["blk_flushes".into(), blk.flushes.to_string()]);
    t.row(vec!["blk_fua_writes".into(), blk.fua_writes.to_string()]);
}

fn cmd_run(p: &ips::util::cli::Parsed) -> ips::Result<()> {
    let opts = opts_from(p)?;
    let scheme = Scheme::parse(p.get("scheme").unwrap_or("ips"))?;
    let mut cfg = experiment::exp_config(&opts, scheme);
    if let Some(path) = p.get("config") {
        cfg = Config::load(std::path::Path::new(path), cfg)?;
    }
    if p.flag("verify") {
        cfg.sim.verify = true;
    }
    apply_blk_flags(p, &mut cfg)?;
    let scen = Scenario::parse(p.get("scenario").unwrap_or("daily"))?;
    let workload = p.get("workload").unwrap_or("HM_0").to_string();
    let mut sim = Simulator::new(cfg.clone())?;
    let trace = if workload == "seq" {
        scenario::sequential_fill("seq", cfg.cache.slc_cache_bytes * 2, sim.logical_bytes())
    } else {
        let daily = experiment::workload_trace(&opts, &workload, sim.logical_bytes())?;
        match scen {
            Scenario::Bursty => scenario::to_bursty(&daily, sim.logical_bytes()),
            Scenario::Daily => daily,
        }
    };
    println!(
        "run: scheme={} workload={} scenario={} writes={} ({}){}",
        scheme.name(),
        workload,
        scen.name(),
        trace.write_ops(),
        bytes(trace.total_write_bytes()),
        if cfg.blk.enabled {
            format!(
                " [blk: sector {} B, merge window {}, flush every {}, fua {}]",
                cfg.blk.sector_bytes, cfg.blk.merge_window, cfg.blk.flush_every, cfg.blk.fua
            )
        } else {
            String::new()
        },
    );
    let s = sim.run(&trace, scen)?;
    let mut t = TextTable::new(&["metric", "value"]);
    t.row(vec!["scheme".into(), s.scheme.clone()]);
    t.row(vec!["host_pages".into(), s.ledger.host_pages.to_string()]);
    t.row(vec!["mean_write_latency".into(), nanos(s.mean_write_latency() as u64)]);
    t.row(vec!["p95_write_latency".into(), nanos(s.write_latency.percentile(0.95))]);
    t.row(vec!["write_amplification".into(), format!("{:.4}", s.wa())]);
    t.row(vec!["avg_bandwidth_mb_s".into(), format!("{:.1}", s.avg_write_bandwidth_mbs())]);
    t.row(vec!["avg_read_bandwidth_mb_s".into(), format!("{:.1}", s.avg_read_bandwidth_mbs())]);
    t.row(vec![
        "write_phases_q/xfer/arr_ms".into(),
        format!(
            "{:.3}/{:.3}/{:.3}",
            s.write_phases.mean_queued_ns() / 1e6,
            s.write_phases.mean_transfer_ns() / 1e6,
            s.write_phases.mean_array_ns() / 1e6
        ),
    ]);
    t.row(vec!["slc_cache_writes".into(), s.ledger.slc_cache_writes.to_string()]);
    t.row(vec!["reprogram_host_writes".into(), s.ledger.reprogram_host_writes.to_string()]);
    t.row(vec!["agc_reprogram_writes".into(), s.ledger.agc_reprogram_writes.to_string()]);
    t.row(vec!["coop_reprogram_writes".into(), s.ledger.coop_reprogram_writes.to_string()]);
    t.row(vec!["slc2tlc_migrations".into(), s.ledger.slc2tlc_migrations.to_string()]);
    t.row(vec!["gc_migrations".into(), s.ledger.gc_migrations.to_string()]);
    if cfg.blk.enabled {
        blk_rows(&mut t, &s.blk);
    }
    t.row(vec!["sim_end".into(), nanos(s.sim_end)]);
    t.row(vec!["wall_clock".into(), format!("{:.2?}", s.wall_clock)]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_replay(p: &ips::util::cli::Parsed) -> ips::Result<()> {
    use ips::blk::{Bio, BioKind};
    use ips::trace::msr;
    let opts = opts_from(p)?;
    let scheme = Scheme::parse(p.get("scheme").unwrap_or("ips"))?;
    let mut cfg = experiment::exp_config(&opts, scheme);
    cfg.blk.enabled = true;
    apply_blk_flags(p, &mut cfg)?;
    if p.flag("verify") {
        cfg.sim.verify = true;
    }
    let scen = Scenario::parse(p.get("scenario").unwrap_or("daily"))?;
    let window = p.get_u64("window").map_err(ips::Error::config)? as usize;
    let (name, mut stream) = match (p.get("csv"), p.get("trace")) {
        (Some(path), _) => {
            let path = std::path::Path::new(path);
            let name =
                path.file_stem().and_then(|s| s.to_str()).unwrap_or("replay").to_string();
            (name, msr::stream_path(path, window)?)
        }
        (None, Some(t)) => {
            let dir = msr::trace_dir()
                .ok_or_else(|| ips::Error::config("--trace needs $MSR_TRACE_DIR set"))?;
            (t.to_string(), msr::stream_dir(&dir, t, window)?)
        }
        (None, None) => {
            return Err(ips::Error::config("replay needs --csv FILE or --trace NAME"))
        }
    };
    println!(
        "replay: {name} scheme={} scenario={} [blk: sector {} B, merge window {}, \
         flush every {}, fua {}] reorder window {window}",
        scheme.name(),
        scen.name(),
        cfg.blk.sector_bytes,
        cfg.blk.merge_window,
        cfg.blk.flush_every,
        cfg.blk.fua,
    );
    let mut sim = Simulator::new(cfg.clone())?;
    let sector = cfg.blk.sector_bytes;
    let fua = cfg.blk.fua;
    let bios = (&mut stream).map(|r| {
        r.map(|op| {
            let mut b = Bio::from_op(&op, sector);
            if fua && b.kind == BioKind::Write {
                b.fua = true;
            }
            b
        })
    });
    let s = sim.run_bios(&name, bios, scen)?;
    println!(
        "streamed {} requests; peak buffered {} (bound: the {window}-request window, \
         not the trace)",
        stream.emitted(),
        stream.peak_buffered(),
    );
    let mut t = TextTable::new(&["metric", "value"]);
    t.row(vec!["scheme".into(), s.scheme.clone()]);
    t.row(vec!["host_pages".into(), s.ledger.host_pages.to_string()]);
    t.row(vec!["host_reads".into(), s.ledger.host_reads.to_string()]);
    t.row(vec!["mean_write_latency".into(), nanos(s.mean_write_latency() as u64)]);
    t.row(vec!["p95_write_latency".into(), nanos(s.write_latency.percentile(0.95))]);
    t.row(vec!["write_amplification".into(), format!("{:.4}", s.wa())]);
    t.row(vec!["host_bytes_written".into(), bytes(s.host_bytes_written)]);
    blk_rows(&mut t, &s.blk);
    t.row(vec!["sim_end".into(), nanos(s.sim_end)]);
    t.row(vec!["wall_clock".into(), format!("{:.2?}", s.wall_clock)]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_multitenant(p: &ips::util::cli::Parsed) -> ips::Result<()> {
    let opts = opts_from(p)?;
    let scheme = Scheme::parse(p.get("scheme").unwrap_or("ips"))?;
    let mut cfg = experiment::exp_config(&opts, scheme);
    if let Some(path) = p.get("config") {
        cfg = Config::load(std::path::Path::new(path), cfg)?;
    }
    cfg.host.tenants = p.get_u64("tenants").map_err(ips::Error::config)? as u32;
    cfg.host.scheduler = SchedKind::parse(p.get("scheduler").unwrap_or("fifo"))?;
    cfg.host.mix = MixKind::parse(p.get("mix").unwrap_or("aggressor-victims"))?;
    if p.flag("verify") {
        cfg.sim.verify = true;
    }
    if p.flag("partition") {
        cfg.cache.partition.enabled = true;
    }
    if p.get("reserved-frac").is_some() {
        cfg.cache.partition.reserved_frac = p.get_f64("reserved-frac").map_err(ips::Error::config)?;
        cfg.cache.partition.enabled = true;
    }
    if let Some(q) = p.get("qos") {
        cfg.host.qos.mode = QosMode::parse(q)?;
    }
    if p.get("qos-rate").is_some() {
        cfg.host.qos.rate_mbps = p.get_f64("qos-rate").map_err(ips::Error::config)?;
    }
    if p.get("qos-burst").is_some() {
        cfg.host.qos.burst_bytes = p.get_u64("qos-burst").map_err(ips::Error::config)? << 10;
    }
    if p.get("slo-p99").is_some() {
        cfg.host.qos.slo_p99 =
            (p.get_f64("slo-p99").map_err(ips::Error::config)? * 1e6) as u64;
        // an SLO target implies the slo mode (explicit --qos wins)
        if cfg.host.qos.mode == QosMode::Off {
            cfg.host.qos.mode = QosMode::Slo;
        }
    }
    // bucket parameters imply enforcement, like --reserved-frac
    // implies --partition — otherwise they would be silently inert
    if (p.get("qos-rate").is_some() || p.get("qos-burst").is_some())
        && cfg.host.qos.mode == QosMode::Off
    {
        cfg.host.qos.mode = QosMode::Strict;
    }
    if let Some(a) = p.get("attribution") {
        cfg.host.attribution = AttributionMode::parse(a)?;
    }
    // [timing] / geometry knobs: the interconnect model and its grid.
    // A bus override implies the model (an inert knob would be a silent
    // misconfiguration); geometry overrides are validated below — bad
    // channel/die counts or a transfer-bound bus error out loudly.
    if p.flag("interconnect") {
        cfg.sim.interconnect = true;
    }
    if p.get("bus-ns-per-page").is_some() {
        cfg.timing.bus_ns_per_page = p.get_u64("bus-ns-per-page").map_err(ips::Error::config)?;
        cfg.sim.interconnect = true;
    }
    if p.get("channels").is_some() {
        cfg.geometry.channels = p.get_u64("channels").map_err(ips::Error::config)? as u32;
    }
    if p.get("dies-per-chip").is_some() {
        cfg.geometry.dies_per_chip =
            p.get_u64("dies-per-chip").map_err(ips::Error::config)? as u32;
    }
    apply_blk_flags(p, &mut cfg)?;
    cfg.validate()?;
    // exact per-tenant percentiles need raw capture
    cfg.sim.latency_samples = cfg.sim.latency_samples.max(100_000);
    let scen = Scenario::parse(p.get("scenario").unwrap_or("bursty"))?;

    if p.flag("fleet") {
        let mix = cfg.host.mix;
        // --partition or --qos turns the fleet into a paired
        // shared-vs-isolated comparison (the isolated variants honor
        // the requested QoS mode); otherwise it is the PR-1 shared
        // sweep. Without this, an explicit --qos would be silently
        // reset by IsolationVariant::Shared in every cell.
        let variants = if cfg.cache.partition.enabled || cfg.host.qos.mode != QosMode::Off {
            fleet::IsolationVariant::all().to_vec()
        } else {
            vec![fleet::IsolationVariant::Shared]
        };
        // --attribution owner turns the fleet into a paired
        // proportional-vs-owner comparison on top of the variant axis
        let attributions = if cfg.host.attribution == AttributionMode::Owner {
            AttributionMode::all().to_vec()
        } else {
            vec![AttributionMode::Proportional]
        };
        let spec = fleet::FleetSpec {
            base: cfg,
            schemes: Scheme::all().to_vec(),
            scheds: SchedKind::all().to_vec(),
            mixes: vec![mix],
            variants,
            attributions,
            scenario: scen,
            seed: opts.seed,
            threads: opts.threads,
        };
        let jobs = spec.jobs().len();
        println!(
            "fleet: {jobs} runs ({} schemes x {} schedulers x {} variants, mix {}, \
             {} tenants, {} threads)",
            spec.schemes.len(),
            spec.scheds.len(),
            spec.variants.len(),
            mix.name(),
            spec.base.host.tenants,
            spec.threads
        );
        let results = fleet::run_fleet(&spec)?;
        println!("\n== fleet sweep ({} / {} scenario) ==", mix.name(), scen.name());
        print!("{}", fleet::summary_table(&results).render());
        return Ok(());
    }

    let mut sim = MultiTenantSimulator::new(cfg.clone())?;
    println!(
        "multi-tenant: scheme={} scheduler={} mix={} tenants={} scenario={} \
         partition={} qos={}",
        scheme.name(),
        cfg.host.scheduler.name(),
        cfg.host.mix.name(),
        sim.tenants(),
        scen.name(),
        cfg.cache.partition.enabled,
        cfg.host.qos.mode.name(),
    );
    let s = sim.run(scen)?;
    print!("{}", fleet::tenant_table(&s).render());
    if s.front_end == "blk" {
        println!(
            "blk: {} bios  splits {}  merges {}  rmw pre-reads {}  flushes {} (fua {})",
            s.blk.bios, s.blk.splits, s.blk.merges, s.blk.rmw_reads, s.blk.flushes, s.blk.fua_writes
        );
    }
    println!(
        "device: wa {:.3}  background pages {}  throttle stalls {}  sim end {}  wall {:.2?}",
        s.wa(),
        s.background.total_programs(),
        s.total_throttle_stalls(),
        nanos(s.sim_end),
        s.wall_clock
    );
    Ok(())
}

fn cmd_fleet(p: &ips::util::cli::Parsed) -> ips::Result<()> {
    use ips::coordinator::perf;
    let mut opts = ExpOptions::default();
    opts.scale = p.get_u64("scale").map_err(ips::Error::config)? as u32;
    opts.seed = p.get_u64("seed").map_err(ips::Error::config)?;
    if let Some(t) = p.get("threads") {
        opts.threads = t.parse().map_err(|_| ips::Error::config("--threads: bad integer"))?;
    }
    let devices = p.get_u64("devices").map_err(ips::Error::config)? as u32;
    if devices == 0 {
        return Err(ips::Error::config("--devices: population must be non-empty"));
    }
    let mix = MixKind::parse(p.get("mix").unwrap_or("aggressor-victims"))?;
    let scen = Scenario::parse(p.get("scenario").unwrap_or("bursty"))?;
    let schemes = match p.get("scheme").unwrap_or("all") {
        "all" => Scheme::all().to_vec(),
        s => vec![Scheme::parse(s)?],
    };
    // The scheme slot of the base config is irrelevant — every device
    // run overrides it from the scheme axis.
    let fault_rate: f64 = p
        .get("faults")
        .unwrap_or("0")
        .parse()
        .map_err(|_| ips::Error::config("--faults: bad fraction"))?;
    if !(0.0..=1.0).contains(&fault_rate) {
        return Err(ips::Error::config("--faults: fraction must be in [0, 1]"));
    }
    let mut base = experiment::exp_config(&opts, Scheme::Ips);
    base.host.tenants = p.get_u64("tenants").map_err(ips::Error::config)? as u32;
    base.host.mix = mix;
    let spec = fleet::PopulationSpec {
        base,
        devices,
        schemes,
        mixes: vec![mix],
        scenario: scen,
        fault_rate,
        seed: opts.seed,
        threads: opts.threads,
    };
    println!(
        "fleet: {} devices x {} schemes x {} mixes = {} runs ({} tenants, {} scenario, \
         {} threads, fault rate {:.2})",
        spec.devices,
        spec.schemes.len(),
        spec.mixes.len(),
        spec.devices as usize * spec.schemes.len() * spec.mixes.len(),
        spec.base.host.tenants,
        scen.name(),
        spec.threads,
        spec.fault_rate
    );
    // the streaming sharded fold: per-device runs are folded and
    // dropped as they finish, so memory stays at one run per worker
    // regardless of the population size
    let (cells, device_csv, stats) = fleet::run_population_streaming(&spec)?;
    println!(
        "streamed {} device runs (peak resident: {})",
        stats.runs, stats.peak_resident_runs
    );
    // the rack-scale datapoint: measurements, printed (and recorded in
    // BENCH_PR10.json) but never part of the deterministic outputs
    let wall_s = stats.wall_clock.as_secs_f64();
    println!(
        "fleet wall-clock: {:.3} s ({:.1} device runs/s)",
        wall_s,
        if wall_s > 0.0 { stats.runs as f64 / wall_s } else { 0.0 }
    );
    match stats.peak_rss_kb {
        0 => println!("peak RSS: unavailable (no procfs VmHWM)"),
        kb => println!("peak RSS: {:.1} MiB ({kb} KiB VmHWM)", kb as f64 / 1024.0),
    }
    match p.get("bench-out").unwrap_or("BENCH_PR10.json") {
        "none" => {}
        out => {
            std::fs::write(out, perf::fleet_stream_json(&spec, &stats))?;
            println!("wrote {out}");
        }
    }
    if p.flag("per-device") {
        println!("\n== per-device breakdown ==");
        print!("{device_csv}");
    }
    println!("\n== fleet rollup ({} devices) ==", spec.devices);
    print!("{}", fleet::population_table(&cells).render());
    if let Some(path) = p.get("json") {
        std::fs::write(path, fleet::population_json(&cells))?;
        println!("wrote {path}");
    }
    if let Some(path) = p.get("csv") {
        std::fs::write(path, fleet::population_csv(&cells))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep(p: &ips::util::cli::Parsed) -> ips::Result<()> {
    let mut opts = ExpOptions::default();
    opts.scale = p.get_u64("scale").map_err(ips::Error::config)? as u32;
    opts.seed = p.get_u64("seed").map_err(ips::Error::config)?;
    let workload = p.get("workload").unwrap_or("HM_0").to_string();
    let what = p.get("what").unwrap_or("cache-size").to_string();
    let mut table = TextTable::new(&["point", "scheme", "mean_lat_ms", "wa"]);
    let run_point = |table: &mut TextTable, label: String, cfg: Config| -> ips::Result<()> {
        let mut sim = Simulator::new(cfg)?;
        let daily = experiment::workload_trace(&opts, &workload, sim.logical_bytes())?;
        let s = sim.run(&daily, Scenario::Daily)?;
        table.row(vec![
            label,
            s.scheme.clone(),
            format!("{:.3}", s.mean_write_latency() / 1e6),
            format!("{:.3}", s.wa()),
        ]);
        Ok(())
    };
    match what.as_str() {
        "cache-size" => {
            for mult in [0.5, 1.0, 2.0, 4.0] {
                let mut cfg = experiment::exp_config(&opts, Scheme::Baseline);
                cfg.cache.slc_cache_bytes =
                    ((cfg.cache.slc_cache_bytes as f64) * mult) as u64;
                run_point(&mut table, format!("cache x{mult}"), cfg)?;
            }
        }
        "idle-threshold" => {
            for ms_th in [10u64, 50, 100, 500, 2000] {
                let mut cfg = experiment::exp_config(&opts, Scheme::IpsAgc);
                cfg.cache.idle_threshold = ms_th * MS;
                run_point(&mut table, format!("idle {ms_th}ms"), cfg)?;
            }
        }
        "group-layers" => {
            for layers in [1u32, 2, 4] {
                let mut cfg = experiment::exp_config(&opts, Scheme::Ips);
                cfg.cache.group_layers = layers;
                run_point(&mut table, format!("{layers} layers"), cfg)?;
            }
        }
        "interconnect" => {
            // channel/die-count scaling under the three-level timing
            // model: the ablation axis the interconnect refactor opens
            let parse_list = |key: &str, default: &[u32]| -> ips::Result<Vec<u32>> {
                match p.get(key) {
                    None => Ok(default.to_vec()),
                    Some(s) => s
                        .split(',')
                        .map(|x| {
                            x.trim().parse::<u32>().map_err(|_| {
                                ips::Error::config(format!("--{key}: bad integer {x:?}"))
                            })
                        })
                        .collect(),
                }
            };
            let channels = parse_list("channels", &[1, 2, 4, 8])?;
            let dies = parse_list("dies-per-chip", &[1, 2, 4])?;
            let mut base = experiment::exp_config(&opts, Scheme::Baseline);
            base.host.tenants = 4;
            base.sim.latency_samples = 100_000;
            if p.get("bus-ns-per-page").is_some() {
                base.timing.bus_ns_per_page =
                    p.get_u64("bus-ns-per-page").map_err(ips::Error::config)?;
            }
            let points =
                fleet::interconnect_sweep(&base, Scenario::Bursty, &channels, &dies)?;
            println!(
                "\n== ablation: interconnect channel/die scaling (aggressor-victims, \
                 bus {} ns/page) ==",
                base.timing.bus_ns_per_page
            );
            print!("{}", fleet::interconnect_table(&points).render());
            return Ok(());
        }
        "qd-joint" => {
            // joint host-SQ × device-window ablation (ROADMAP): the two
            // windows interact — a deep SQ only hurts the victims when
            // the device window is deep enough to drain it in arrival
            // order — so each is swept against the other
            let mut base = experiment::exp_config(&opts, Scheme::Baseline);
            base.sim.latency_samples = 100_000;
            let mut joint_table = TextTable::new(&[
                "queue_depth",
                "device_qd",
                "mean_lat_ms",
                "victim_p99_ms",
                "wa",
            ]);
            for (sq, qd, s) in fleet::qd_joint_sweep(
                &base,
                Scenario::Bursty,
                &[1, 8, 64],
                &[1, 4, 16],
            )? {
                joint_table.row(vec![
                    sq.to_string(),
                    qd.to_string(),
                    format!("{:.3}", s.write_latency.mean() / 1e6),
                    format!("{:.3}", s.max_victim_p99() as f64 / 1e6),
                    format!("{:.3}", s.wa()),
                ]);
            }
            println!("\n== ablation: qd-joint (aggressor-victims mix) ==");
            print!("{}", joint_table.render());
            return Ok(());
        }
        "device-qd" => {
            // multi-tenant: the device window is what makes dispatch
            // order (and therefore the victims' tail) matter — so this
            // ablation gets its own table with the victim p99 column
            let mut base = experiment::exp_config(&opts, Scheme::Baseline);
            base.sim.latency_samples = 100_000;
            let mut qd_table = TextTable::new(&[
                "point",
                "scheme",
                "mean_lat_ms",
                "victim_p99_ms",
                "wa",
            ]);
            for (qd, s) in
                fleet::device_qd_sweep(&base, Scenario::Bursty, &[1, 2, 4, 8, 16, 32])?
            {
                qd_table.row(vec![
                    format!("qd {qd}"),
                    s.scheme.clone(),
                    format!("{:.3}", s.write_latency.mean() / 1e6),
                    format!("{:.3}", s.max_victim_p99() as f64 / 1e6),
                    format!("{:.3}", s.wa()),
                ]);
            }
            println!("\n== ablation: device-qd (aggressor-victims mix) ==");
            print!("{}", qd_table.render());
            return Ok(());
        }
        other => return Err(ips::Error::config(format!("unknown sweep {other:?}"))),
    }
    println!("\n== ablation: {what} (workload {workload}) ==");
    print!("{}", table.render());
    Ok(())
}

fn cmd_perf(p: &ips::util::cli::Parsed) -> ips::Result<()> {
    use ips::coordinator::perf;
    let preset = p.get("preset").unwrap_or("large").to_string();
    let base = perf::preset_by_name(&preset)?;
    let volume_mult = p.get_f64("volume-mult").map_err(ips::Error::config)?;
    let schemes: Vec<Scheme> = match p.get("scheme").unwrap_or("all") {
        "all" => Scheme::all().to_vec(),
        s => vec![Scheme::parse(s)?],
    };
    let scenarios: Vec<Scenario> = match p.get("scenario").unwrap_or("both") {
        "both" => vec![Scenario::Bursty, Scenario::Daily],
        s => vec![Scenario::parse(s)?],
    };
    match p.get("compare").unwrap_or("structures") {
        "structures" | "hot-path" => {
            return cmd_perf_structures(p, &preset, &base, &schemes, &scenarios, volume_mult)
        }
        "victim-index" | "index" => {}
        "interconnect" | "timing" => {
            return cmd_perf_interconnect(p, &preset, &base, &schemes, &scenarios, volume_mult)
        }
        other => {
            return Err(ips::Error::config(format!(
                "unknown perf comparison {other:?} (want structures|victim-index|interconnect)"
            )))
        }
    }
    println!(
        "perf: preset={preset} ({} planes x {} blocks/plane), volume x{volume_mult} of \
         logical, {} scheme(s) x {} scenario(s), scan vs index",
        base.geometry.planes(),
        base.geometry.blocks_per_plane,
        schemes.len(),
        scenarios.len()
    );
    let mut table = TextTable::new(&[
        "preset",
        "scheme",
        "scenario",
        "host_pages",
        "scan_kops",
        "index_kops",
        "speedup",
        "identical",
    ]);
    let mut cells = Vec::new();
    for &scheme in &schemes {
        for &scen in &scenarios {
            let c = perf::run_cell(&preset, &base, scheme, scen, volume_mult)?;
            println!(
                "  {:<9} {:<6}  scan {:>8.1}ms  index {:>8.1}ms  speedup {:>6.2}x  {}",
                c.scheme,
                c.scenario,
                c.scan_wall.as_secs_f64() * 1e3,
                c.index_wall.as_secs_f64() * 1e3,
                c.speedup(),
                if c.identical { "ok" } else { "DIVERGED" }
            );
            table.row(vec![
                c.preset.clone(),
                c.scheme.into(),
                c.scenario.into(),
                c.host_pages.to_string(),
                format!("{:.1}", c.ops_scan() / 1e3),
                format!("{:.1}", c.ops_index() / 1e3),
                format!("{:.2}x", c.speedup()),
                c.identical.to_string(),
            ]);
            cells.push(c);
        }
    }
    println!("\n== perf: victim index vs linear scan ==");
    print!("{}", table.render());
    let gc_heavy: Vec<&ips::coordinator::perf::PerfCell> =
        cells.iter().filter(|c| c.scenario == "bursty").collect();
    if let Some(best) = gc_heavy
        .iter()
        .max_by(|a, b| a.speedup().partial_cmp(&b.speedup()).unwrap_or(std::cmp::Ordering::Equal))
    {
        println!(
            "GC-heavy bursty headline: {} at {:.2}x ops/sec (target >= 2x on presets::large)",
            best.scheme,
            best.speedup()
        );
    }
    let out = match p.get("out") {
        Some("auto") | None => "BENCH_PR4.json",
        Some(o) => o,
    };
    std::fs::write(out, perf::perf_json(&cells))?;
    println!("wrote {out}");
    if cells.iter().any(|c| !c.identical) {
        return Err(ips::Error::invariant(
            "scan and index runs diverged — the victim index changed simulation results",
        ));
    }
    Ok(())
}

/// `ips perf --compare interconnect`: the lump-vs-interconnect
/// trajectory (BENCH_PR5.json) — wall-clock overhead of the
/// three-level model plus the simulated-time contention it surfaces.
fn cmd_perf_interconnect(
    p: &ips::util::cli::Parsed,
    preset: &str,
    base: &Config,
    schemes: &[Scheme],
    scenarios: &[Scenario],
    volume_mult: f64,
) -> ips::Result<()> {
    use ips::coordinator::perf;
    println!(
        "perf: preset={preset} ({} planes, {} planes/die, bus {} ns/page), volume \
         x{volume_mult} of logical, {} scheme(s) x {} scenario(s), lump vs interconnect",
        base.geometry.planes(),
        base.geometry.planes_per_die,
        base.timing.bus_ns_per_page,
        schemes.len(),
        scenarios.len()
    );
    let mut table = TextTable::new(&[
        "preset",
        "scheme",
        "scenario",
        "host_pages",
        "lump_kops",
        "ic_kops",
        "overhead",
        "sim_end_ratio",
    ]);
    let cells = perf::run_timing_matrix(preset, base, schemes, scenarios, volume_mult)?;
    for c in &cells {
        println!(
            "  {:<9} {:<6}  lump {:>8.1}ms  ic {:>8.1}ms  overhead {:>5.2}x  sim-time {:>6.4}x",
            c.scheme,
            c.scenario,
            c.lump_wall.as_secs_f64() * 1e3,
            c.ic_wall.as_secs_f64() * 1e3,
            c.overhead(),
            c.sim_end_ratio(),
        );
        table.row(vec![
            c.preset.clone(),
            c.scheme.into(),
            c.scenario.into(),
            c.host_pages.to_string(),
            format!("{:.1}", c.ops_lump() / 1e3),
            format!("{:.1}", c.ops_ic() / 1e3),
            format!("{:.2}x", c.overhead()),
            format!("{:.4}x", c.sim_end_ratio()),
        ]);
    }
    println!("\n== perf: interconnect model vs plane lump ==");
    print!("{}", table.render());
    let out = match p.get("out") {
        Some("auto") | None => "BENCH_PR5.json",
        Some(o) => o,
    };
    std::fs::write(out, perf::timing_json(&cells))?;
    println!("wrote {out}");
    Ok(())
}

/// `ips perf` (default) / `--compare structures`: the hot-path
/// data-structure pass — flat bucket indices, SoA plane arenas,
/// incremental attribution, batched dispatch — against its four
/// oracles (BENCH_PR9.json), plus the blocks-per-plane × channel-count
/// scaling sweep on the IPS scheme.
fn cmd_perf_structures(
    p: &ips::util::cli::Parsed,
    preset: &str,
    base: &Config,
    schemes: &[Scheme],
    scenarios: &[Scenario],
    volume_mult: f64,
) -> ips::Result<()> {
    use ips::coordinator::perf;
    println!(
        "perf: preset={preset} ({} planes x {} blocks/plane), volume x{volume_mult} of \
         logical, {} scheme(s) x {} scenario(s), oracle vs flat/SoA/incremental/batched",
        base.geometry.planes(),
        base.geometry.blocks_per_plane,
        schemes.len(),
        scenarios.len()
    );
    let mut table = TextTable::new(&[
        "preset",
        "scheme",
        "scenario",
        "host_pages",
        "oracle_kops",
        "new_kops",
        "speedup",
        "identical",
    ]);
    let mut cells = Vec::new();
    for &scheme in schemes {
        for &scen in scenarios {
            let c = perf::run_struct_cell(preset, base, scheme, scen, volume_mult)?;
            println!(
                "  {:<9} {:<6}  oracle {:>8.1}ms  new {:>8.1}ms  speedup {:>6.2}x  {}",
                c.scheme,
                c.scenario,
                c.oracle_wall.as_secs_f64() * 1e3,
                c.new_wall.as_secs_f64() * 1e3,
                c.speedup(),
                if c.identical { "ok" } else { "DIVERGED" }
            );
            table.row(vec![
                c.preset.clone(),
                c.scheme.into(),
                c.scenario.into(),
                c.host_pages.to_string(),
                format!("{:.1}", c.ops_oracle() / 1e3),
                format!("{:.1}", c.ops_new() / 1e3),
                format!("{:.2}x", c.speedup()),
                c.identical.to_string(),
            ]);
            cells.push(c);
        }
    }
    println!("\n== perf: hot-path structures vs oracles ==");
    print!("{}", table.render());
    if let Some(best) = cells
        .iter()
        .filter(|c| c.scenario == "bursty")
        .max_by(|a, b| a.speedup().partial_cmp(&b.speedup()).unwrap_or(std::cmp::Ordering::Equal))
    {
        println!(
            "GC-heavy bursty headline: {} at {:.2}x host-pages/sec over the oracles",
            best.scheme,
            best.speedup()
        );
    }
    // scaling sweep: where do the O(blocks)/O(planes) oracle costs
    // bite? Grid is relative to the preset's geometry so every preset
    // sweeps the same shape; IPS bursty is the paper's headline cell.
    let g = &base.geometry;
    let blocks: Vec<u32> = [1u32, 2, 4].iter().map(|m| g.blocks_per_plane * m).collect();
    let chans: Vec<u32> = [1u32, 2].iter().map(|m| g.channels * m).collect();
    // still past the overwrite cliff, but keeps the 4x/2x grid points
    // tractable on the large preset
    let sweep_mult = volume_mult.min(1.5);
    println!(
        "\nscaling sweep: blocks/plane {blocks:?} x channels {chans:?} (ips, bursty, \
         volume x{sweep_mult})"
    );
    let sweep = perf::run_scaling_sweep(
        base,
        Scheme::Ips,
        Scenario::Bursty,
        sweep_mult,
        &blocks,
        &chans,
    )?;
    let mut st = TextTable::new(&[
        "blocks/plane",
        "channels",
        "host_pages",
        "oracle_kops",
        "new_kops",
        "speedup",
        "identical",
    ]);
    for pt in &sweep {
        st.row(vec![
            pt.blocks_per_plane.to_string(),
            pt.channels.to_string(),
            pt.host_pages.to_string(),
            format!("{:.1}", pt.ops_oracle() / 1e3),
            format!("{:.1}", pt.ops_new() / 1e3),
            format!("{:.2}x", pt.speedup()),
            pt.identical.to_string(),
        ]);
    }
    print!("{}", st.render());
    let out = match p.get("out") {
        Some("auto") | None => "BENCH_PR9.json",
        Some(o) => o,
    };
    std::fs::write(out, perf::structures_json(&cells, &sweep))?;
    println!("wrote {out}");
    if cells.iter().any(|c| !c.identical) || sweep.iter().any(|s| !s.identical) {
        return Err(ips::Error::invariant(
            "oracle and new-structure runs diverged — a hot-path structure changed \
             simulation results",
        ));
    }
    Ok(())
}

fn cmd_audit(p: &ips::util::cli::Parsed) -> ips::Result<()> {
    let sigma = p.get_f64("sigma").map_err(ips::Error::config)? as f32;
    let alpha = p.get_f64("alpha").map_err(ips::Error::config)? as f32;
    let batches = p.get_u64("batches").map_err(ips::Error::config)? as u32;
    let seed = p.get_u64("seed").map_err(ips::Error::config)?;
    let bridge = ips::reliability::RberBridge::new()?;
    let r = bridge.run(seed, batches, sigma, alpha)?;
    let mut t = TextTable::new(&["metric", "value"]);
    t.row(vec!["sigma".into(), format!("{sigma}")]);
    t.row(vec!["alpha".into(), format!("{alpha}")]);
    t.row(vec!["batches".into(), r.batches.to_string()]);
    t.row(vec!["slc_rber".into(), format!("{:.6}", r.slc)]);
    t.row(vec!["ips_tlc_rber".into(), format!("{:.6}", r.ips_tlc)]);
    t.row(vec!["native_tlc_rber".into(), format!("{:.6}", r.native_tlc)]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_list() -> ips::Result<()> {
    println!("workloads (MSR Cambridge subset, Fig. 5):");
    for prof in profiles::ALL {
        println!(
            "  {:<8} writes {:>6}  ratio {:.2}  idle-gap {:>6.0} ms",
            prof.name,
            bytes(prof.total_write_bytes),
            prof.write_ratio,
            prof.idle_gap_ms
        );
    }
    println!("\nschemes:");
    for s in Scheme::all() {
        println!("  {}", s.name());
    }
    println!("\npresets: table1 (384 GB, Table I), coop64 (64 GB cache), small, bench_medium");
    let _ = cache::build(&presets::small()); // exercise the factory
    Ok(())
}
