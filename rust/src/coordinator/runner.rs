//! Threaded fan-out over independent simulation runs.
//!
//! Each run owns a fresh [`crate::sim::Simulator`]; runs share nothing,
//! so a simple scoped work-queue suffices (no tokio in the offline
//! environment — plain `std::thread::scope`).

use std::collections::VecDeque;
use std::sync::Mutex;

/// Map `f` over `items` on up to `threads` workers, preserving input
/// order in the output. Panics in workers propagate.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    let queue: Mutex<VecDeque<(usize, T)>> = Mutex::new(items.into_iter().enumerate().collect());
    let results: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let next = queue.lock().unwrap().pop_front();
                match next {
                    Some((i, item)) => {
                        let r = f(item);
                        results.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("worker completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map((0..100).collect(), 8, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_works() {
        let out = parallel_map(vec![1, 2, 3], 1, |x: i32| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(vec![5], 16, |x: i32| x * x);
        assert_eq!(out, vec![25]);
    }
}
