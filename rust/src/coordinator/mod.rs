//! Experiment coordination: figure definitions, the threaded runner,
//! and paper-style reporting.
//!
//! `ips reproduce --fig N` regenerates the data behind every figure of
//! the paper's evaluation (§V), printing the same rows/series the paper
//! reports and writing full series to `results/figN_*.csv`. See
//! DESIGN.md's experiment index for the figure ↔ module map.

pub mod experiment;
pub mod fleet;
pub mod perf;
pub mod report;
pub mod runner;

use std::path::PathBuf;

/// Options shared by all experiments.
#[derive(Clone, Debug)]
pub struct ExpOptions {
    /// Geometry divisor vs the paper's Table I (1 = full scale).
    pub scale: u32,
    /// Workload write-volume multiplier; `None` scales volumes with
    /// capacity (1/scale², preserving cache pressure).
    pub volume_scale: Option<f64>,
    /// PRNG seed.
    pub seed: u64,
    /// Output directory for CSV series.
    pub out_dir: PathBuf,
    /// Worker threads for independent runs.
    pub threads: usize,
    /// Restrict to these workloads (None = the paper's 11).
    pub workloads: Option<Vec<String>>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            scale: 4,
            volume_scale: None,
            seed: 42,
            out_dir: PathBuf::from("results"),
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            workloads: None,
        }
    }
}

impl ExpOptions {
    /// Workload names to run.
    pub fn workload_names(&self) -> Vec<&str> {
        match &self.workloads {
            Some(w) => w.iter().map(|s| s.as_str()).collect(),
            None => crate::trace::profiles::names(),
        }
    }

    /// Effective volume multiplier: explicit, or capacity-proportional
    /// (geometry scale divides channels *and* blocks/plane → capacity
    /// shrinks by scale², and workload volumes follow to preserve the
    /// paper's cache-pressure ratios).
    pub fn volume(&self) -> f64 {
        self.volume_scale
            .unwrap_or_else(|| 1.0 / (self.scale as f64 * self.scale as f64))
    }
}
