//! Report rendering: titled tables on stdout, full series as CSV under
//! the experiment's output directory.

use super::ExpOptions;
use crate::util::fmt::TextTable;
use crate::Result;

/// Print a titled table.
pub fn print_table(title: &str, table: &TextTable) {
    println!("\n== {title} ==");
    print!("{}", table.render());
}

/// Persist a table as `<out_dir>/<name>.csv`.
pub fn save_csv(opts: &ExpOptions, name: &str, table: &TextTable) -> Result<()> {
    let path = opts.out_dir.join(format!("{name}.csv"));
    table.write_csv(&path)?;
    println!("   -> {}", path.display());
    Ok(())
}

/// Arithmetic mean of a slice (reports use it for the paper's
/// "on average, X reduces Y by Z times" lines).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Format ns as ms with 3 decimals (paper plots are in ms).
pub fn ms(ns: f64) -> String {
    format!("{:.3}", ns / 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ms_formats() {
        assert_eq!(ms(3_000_000.0), "3.000");
    }
}
