//! One function per paper figure (§V evaluation + §III motivation).
//!
//! Every function prints the paper-comparable summary rows and writes
//! the full series to CSV. Scaling: geometry is Table I divided by
//! `opts.scale` (channels and blocks/plane), workload volumes follow
//! capacity (see [`super::ExpOptions::volume`]), so cache-pressure
//! ratios — what the figures are about — are preserved.

use super::report::{mean, ms, print_table, save_csv};
use super::runner::parallel_map;
use super::ExpOptions;
use crate::config::{presets, Config, Scheme, MS, SEC};
use crate::metrics::RunSummary;
use crate::sim::Simulator;
use crate::trace::scenario::{self, Scenario};
use crate::trace::{profiles, synth, Trace};
use crate::util::fmt::TextTable;
use crate::{Error, Result};

/// Scale any base config's geometry by `scale` (channels and
/// blocks/plane) and its dedicated-cache size by capacity.
pub fn scale_config(mut cfg: Config, scale: u32) -> Config {
    if scale <= 1 {
        return cfg;
    }
    let before = cfg.geometry.capacity_bytes();
    cfg.geometry.channels = (cfg.geometry.channels / scale).max(1);
    cfg.geometry.blocks_per_plane = (cfg.geometry.blocks_per_plane / scale).max(16);
    let after = cfg.geometry.capacity_bytes();
    let ratio = after as f64 / before as f64;
    cfg.cache.slc_cache_bytes = ((cfg.cache.slc_cache_bytes as f64) * ratio).max(4096.0) as u64;
    cfg
}

/// Table-I config at the experiment scale, with scheme + seed applied.
pub fn exp_config(opts: &ExpOptions, scheme: Scheme) -> Config {
    let mut cfg = scale_config(presets::table1(), opts.scale);
    cfg.cache.scheme = scheme;
    cfg.sim.seed = opts.seed;
    cfg
}

/// Coop config (paper §V-A: 64 GB total cache) at the experiment scale.
pub fn coop_config(opts: &ExpOptions) -> Config {
    let mut cfg = scale_config(presets::coop64(), opts.scale);
    // re-derive the IPS fraction for the scaled geometry
    let g = &cfg.geometry;
    let slc_pages_per_block = g.wordlines_per_block() as u64;
    let trad_blocks =
        (cfg.cache.slc_cache_bytes / g.page_bytes as u64).div_ceil(slc_pages_per_block);
    cfg.cache.ips_block_fraction =
        (1.0 - trad_blocks as f64 / g.blocks() as f64).clamp(0.05, 1.0);
    cfg.sim.seed = opts.seed;
    cfg
}

/// Baseline comparator with the coop design's total cache size.
pub fn baseline64_config(opts: &ExpOptions) -> Config {
    let coop = coop_config(opts);
    let mut cfg = exp_config(opts, Scheme::Baseline);
    // total coop cache ≈ trad part + IPS part; paper rounds to 64 GB
    let total = (64u64 << 30) >> (2 * (opts.scale.trailing_zeros()));
    let capacity_scaled = cfg.geometry.capacity_bytes();
    cfg.cache.slc_cache_bytes = total.min(capacity_scaled / 6).max(coop.cache.slc_cache_bytes);
    cfg
}

/// Synthesize the daily trace for a workload at experiment scale.
pub fn workload_trace(opts: &ExpOptions, name: &str, logical_bytes: u64) -> Result<Trace> {
    // real MSR traces win when available
    if let Some(dir) = crate::trace::msr::trace_dir() {
        if let Ok(t) = crate::trace::msr::load_dir(&dir, name) {
            return Ok(t);
        }
    }
    let p = profiles::by_name(name)
        .ok_or_else(|| Error::config(format!("unknown workload {name:?}")))?;
    Ok(synth::generate_scaled(p, opts.seed, logical_bytes, opts.volume()))
}

/// Run one (config, trace, scenario) on a fresh simulator.
pub fn run_one(cfg: Config, trace: &Trace, scenario: Scenario) -> Result<RunSummary> {
    Simulator::run_once(cfg, trace, scenario)
}

fn gib(b: u64) -> f64 {
    b as f64 / (1u64 << 30) as f64
}

// ====================================================================
// Fig. 2 — reprogram reliability model (background for §IV-D1)
// ====================================================================

/// Reliability: RBER of the SLC → reprogram chain vs native TLC, from
/// the AOT artifact when present, else the analytic mirror.
pub fn fig2(opts: &ExpOptions) -> Result<()> {
    let mut table = TextTable::new(&[
        "sigma", "alpha", "slc_rber", "ips_tlc_rber", "native_tlc_rber", "source",
    ]);
    let sweep = [(0.0f32, 0.0f32), (0.3, 0.02), (0.3, 0.10), (0.6, 0.02), (0.6, 0.10)];
    match crate::reliability::RberBridge::new() {
        Ok(bridge) => {
            for &(sigma, alpha) in &sweep {
                let r = bridge.run(opts.seed, 2, sigma, alpha)?;
                table.row(vec![
                    format!("{sigma:.2}"),
                    format!("{alpha:.2}"),
                    format!("{:.5}", r.slc),
                    format!("{:.5}", r.ips_tlc),
                    format!("{:.5}", r.native_tlc),
                    "pjrt-artifact".into(),
                ]);
            }
        }
        Err(e) => {
            println!("(artifact unavailable: {e}; using analytic mirror)");
            for &(sigma, alpha) in &sweep {
                let e = crate::reliability::model::estimate(&crate::reliability::model::RberParams {
                    step: 0.25,
                    sigma: sigma as f64,
                    alpha: alpha as f64,
                });
                table.row(vec![
                    format!("{sigma:.2}"),
                    format!("{alpha:.2}"),
                    format!("{:.5}", e.slc),
                    format!("{:.5}", e.ips_tlc),
                    format!("{:.5}", e.native_tlc),
                    "analytic".into(),
                ]);
            }
        }
    }
    print_table("Fig. 2 — reprogram reliability (RBER by stage)", &table);
    save_csv(opts, "fig02_reliability", &table)
}

// ====================================================================
// Fig. 3 — bursty bandwidth cliff
// ====================================================================

/// Bursty access on the baseline: bandwidth vs cumulative data
/// written; the cliff sits at the SLC-cache size.
pub fn fig3(opts: &ExpOptions) -> Result<()> {
    let mut cfg = exp_config(opts, Scheme::Baseline);
    cfg.sim.bandwidth_window = 200 * MS;
    let cache = cfg.cache.slc_cache_bytes;
    let mut sim = Simulator::new(cfg)?;
    let total = cache * 5 / 2;
    let trace = scenario::sequential_fill("fig3", total, sim.logical_bytes());
    let s = sim.run(&trace, Scenario::Bursty)?;
    let series = s.bandwidth.series_vs_cumulative_gb();
    let mut table = TextTable::new(&["cum_gb", "mb_per_s"]);
    for (gb, mbs) in &series {
        table.row(vec![format!("{gb:.3}"), format!("{mbs:.1}")]);
    }
    // locate the cliff: first window below half the initial bandwidth
    let first = series.first().map(|x| x.1).unwrap_or(0.0);
    let cliff = series.iter().find(|(_, m)| *m < first / 2.0).map(|(g, _)| *g);
    let mut summary = TextTable::new(&["metric", "value"]);
    summary.row(vec!["slc_cache_gib".into(), format!("{:.3}", gib(cache))]);
    summary.row(vec!["pre_cliff_mb_s".into(), format!("{first:.1}")]);
    summary.row(vec![
        "post_cliff_mb_s".into(),
        format!("{:.1}", series.last().map(|x| x.1).unwrap_or(0.0)),
    ]);
    summary.row(vec![
        "cliff_at_gib".into(),
        cliff.map(|c| format!("{c:.3}")).unwrap_or_else(|| "none".into()),
    ]);
    print_table("Fig. 3 — bursty bandwidth cliff (baseline)", &summary);
    save_csv(opts, "fig03_bursty_cliff", &table)
}

// ====================================================================
// Fig. 4 — daily use: periodic sequential writes
// ====================================================================

/// Five sequential write streams with idle gaps: bandwidth stays flat
/// because idle-time reclamation keeps re-arming the cache.
pub fn fig4(opts: &ExpOptions) -> Result<()> {
    let mut cfg = exp_config(opts, Scheme::Baseline);
    cfg.sim.bandwidth_window = 500 * MS;
    // Fig. 4 is the §III *real-SSD* experiment: a 500 GB drive with a
    // ~65 GB cache and 20 GB streams (streams fit the cache; idle
    // reclamation keeps bandwidth flat). Emulate those proportions:
    // cache = 13% of capacity, stream = 4%.
    cfg.cache.slc_cache_bytes = (cfg.geometry.capacity_bytes() as f64 * 0.13) as u64;
    let stream = (cfg.geometry.capacity_bytes() as f64 * 0.04) as u64;
    let mut sim = Simulator::new(cfg)?;
    let trace = scenario::daily_streams(5, stream, 600 * SEC, sim.logical_bytes());
    let s = sim.run(&trace, Scenario::Daily)?;
    let series: Vec<(u64, f64)> =
        s.bandwidth.series_mbs().into_iter().filter(|(_, m)| *m > 0.0).collect();
    let mut table = TextTable::new(&["t_s", "mb_per_s"]);
    for (t, m) in &series {
        table.row(vec![format!("{:.1}", *t as f64 / 1e9), format!("{m:.1}")]);
    }
    let rates: Vec<f64> = series.iter().map(|x| x.1).collect();
    let mut summary = TextTable::new(&["metric", "value"]);
    summary.row(vec!["streams".into(), "5".into()]);
    summary.row(vec!["stream_gib".into(), format!("{:.3}", gib(stream))]);
    summary.row(vec!["mean_mb_s".into(), format!("{:.1}", mean(&rates))]);
    summary.row(vec![
        "min_mb_s".into(),
        format!("{:.1}", rates.iter().cloned().fold(f64::MAX, f64::min)),
    ]);
    summary.row(vec![
        "max_mb_s".into(),
        format!("{:.1}", rates.iter().cloned().fold(0.0, f64::max)),
    ]);
    summary.row(vec!["wa".into(), format!("{:.3}", s.wa())]);
    print_table("Fig. 4 — daily-use bandwidth (baseline, idle reclamation)", &summary);
    save_csv(opts, "fig04_daily_use", &table)
}

// ====================================================================
// Fig. 5 — writes breakdown + WA (baseline, bursty & daily)
// ====================================================================

/// Writes breakdown (SLC / SLC2TLC / TLC) and WA per workload.
pub fn fig5(opts: &ExpOptions) -> Result<()> {
    for (scen, csv) in [(Scenario::Bursty, "fig05a_bursty"), (Scenario::Daily, "fig05b_daily")] {
        let names = opts.workload_names();
        let jobs: Vec<String> = names.iter().map(|s| s.to_string()).collect();
        let results = parallel_map(jobs, opts.threads, |name| -> Result<RunSummary> {
            let cfg = exp_config(opts, Scheme::Baseline);
            let mut sim = Simulator::new(cfg)?;
            let daily = workload_trace(opts, &name, sim.logical_bytes())?;
            let trace = match scen {
                Scenario::Bursty => scenario::to_bursty(&daily, sim.logical_bytes()),
                Scenario::Daily => daily,
            };
            sim.run(&trace, scen)
        });
        let mut table =
            TextTable::new(&["workload", "slc_frac", "slc2tlc_frac", "tlc_frac", "wa"]);
        for (name, r) in names.iter().zip(results) {
            let r = r?;
            let (slc, migr, tlc) = r.ledger.breakdown();
            table.row(vec![
                name.to_string(),
                format!("{slc:.3}"),
                format!("{migr:.3}"),
                format!("{tlc:.3}"),
                format!("{:.3}", r.wa()),
            ]);
        }
        print_table(
            &format!("Fig. 5 — writes breakdown & WA ({})", scen.name()),
            &table,
        );
        save_csv(opts, csv, &table)?;
    }
    Ok(())
}

// ====================================================================
// Fig. 9 — runtime write latencies (baseline vs IPS, bursty HM_0)
// ====================================================================

/// Per-write latency over the first 100 k writes.
pub fn fig9(opts: &ExpOptions) -> Result<()> {
    let specs = [Scheme::Baseline, Scheme::Ips];
    let results = parallel_map(specs.to_vec(), opts.threads, |scheme| -> Result<RunSummary> {
        let mut cfg = exp_config(opts, scheme);
        cfg.sim.latency_samples = 100_000;
        let mut sim = Simulator::new(cfg)?;
        let daily = workload_trace(opts, "HM_0", sim.logical_bytes())?;
        let trace = scenario::to_bursty(&daily, sim.logical_bytes());
        sim.run(&trace, Scenario::Bursty)
    });
    let mut table = TextTable::new(&["write_idx", "baseline_us", "ips_us"]);
    let base = results[0].as_ref().map_err(|e| Error::config(e.to_string()))?;
    let ips = results[1].as_ref().map_err(|e| Error::config(e.to_string()))?;
    let a = base.write_latency.raw_us();
    let b = ips.write_latency.raw_us();
    let n = a.len().min(b.len());
    let stride = (n / 1000).max(1);
    for i in (0..n).step_by(stride) {
        table.row(vec![i.to_string(), a[i].to_string(), b[i].to_string()]);
    }
    let mut summary = TextTable::new(&["scheme", "mean_ms", "p95_ms", "writes"]);
    for r in [&base, &ips] {
        summary.row(vec![
            r.scheme.clone(),
            ms(r.mean_write_latency()),
            ms(r.write_latency.percentile(0.95) as f64),
            r.write_latency.count().to_string(),
        ]);
    }
    print_table("Fig. 9 — runtime write latency (bursty HM_0)", &summary);
    save_csv(opts, "fig09_latency_runtime", &table)
}

// ====================================================================
// Fig. 10 — IPS vs baseline, normalized (bursty + daily)
// ====================================================================

/// Normalized write latency and WA of IPS vs baseline.
pub fn fig10(opts: &ExpOptions) -> Result<()> {
    for (scen, csv) in [(Scenario::Bursty, "fig10a_bursty"), (Scenario::Daily, "fig10b_daily")] {
        let table = normalized_schemes(opts, scen, &[Scheme::Baseline, Scheme::Ips])?;
        print_table(
            &format!("Fig. 10 — IPS vs baseline ({}) [normalized]", scen.name()),
            &table,
        );
        save_csv(opts, csv, &table)?;
    }
    Ok(())
}

// ====================================================================
// Fig. 11 — IPS and IPS/agc, daily, normalized
// ====================================================================

/// Normalized write latency and WA of IPS and IPS/agc vs baseline.
pub fn fig11(opts: &ExpOptions) -> Result<()> {
    let table =
        normalized_schemes(opts, Scenario::Daily, &[Scheme::Baseline, Scheme::Ips, Scheme::IpsAgc])?;
    print_table("Fig. 11 — IPS and IPS/agc vs baseline (daily) [normalized]", &table);
    save_csv(opts, "fig11_ips_agc", &table)
}

/// Shared machinery for Figs. 10/11: run `schemes[0]` as the base and
/// the rest normalized to it, one row per workload + a mean row.
fn normalized_schemes(
    opts: &ExpOptions,
    scen: Scenario,
    schemes: &[Scheme],
) -> Result<TextTable> {
    let names = opts.workload_names();
    let mut jobs = Vec::new();
    for name in &names {
        for &scheme in schemes {
            jobs.push((name.to_string(), scheme));
        }
    }
    let results = parallel_map(jobs, opts.threads, |(name, scheme)| -> Result<RunSummary> {
        let cfg = exp_config(opts, scheme);
        let mut sim = Simulator::new(cfg)?;
        let daily = workload_trace(opts, &name, sim.logical_bytes())?;
        let trace = match scen {
            Scenario::Bursty => scenario::to_bursty(&daily, sim.logical_bytes()),
            Scenario::Daily => daily,
        };
        sim.run(&trace, scen)
    });
    let mut header = vec!["workload".to_string()];
    for &s in &schemes[1..] {
        header.push(format!("{}_lat_norm", s.name().replace('/', "_")));
        header.push(format!("{}_wa_norm", s.name().replace('/', "_")));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = TextTable::new(&header_refs);
    let per = schemes.len();
    let mut sums = vec![Vec::new(); 2 * (per - 1)];
    for (wi, name) in names.iter().enumerate() {
        let base = results[wi * per].as_ref().map_err(|e| Error::config(e.to_string()))?;
        let mut row = vec![name.to_string()];
        for si in 1..per {
            let r = results[wi * per + si]
                .as_ref()
                .map_err(|e| Error::config(e.to_string()))?;
            let lat = r.mean_write_latency() / base.mean_write_latency().max(1.0);
            let wa = r.wa() / base.wa().max(1e-9);
            row.push(format!("{lat:.3}"));
            row.push(format!("{wa:.3}"));
            sums[2 * (si - 1)].push(lat);
            sums[2 * (si - 1) + 1].push(wa);
        }
        table.row(row);
    }
    let mut mean_row = vec!["MEAN".to_string()];
    for s in &sums {
        mean_row.push(format!("{:.3}", mean(s)));
    }
    table.row(mean_row);
    Ok(table)
}

// ====================================================================
// Fig. 12 — cooperative design (64 GB cache)
// ====================================================================

/// (a) bursty HM_0 with total write size swept 1.0×..2.125× of the
/// cache; (b) daily at cache-sized total writes. Normalized to a
/// baseline with the same total cache.
pub fn fig12(opts: &ExpOptions) -> Result<()> {
    // ---- (a) bursty volume sweep --------------------------------
    let coop_cfg = coop_config(opts);
    let base_cfg = baseline64_config(opts);
    let cache_total = base_cfg.cache.slc_cache_bytes;
    let multiples = [1.0f64, 1.33, 1.67, 2.0, 2.125];
    let mut jobs = Vec::new();
    for &m in &multiples {
        jobs.push((m, true));
        jobs.push((m, false));
    }
    let results = parallel_map(jobs, opts.threads, |(m, is_coop)| -> Result<RunSummary> {
        let cfg = if is_coop { coop_cfg.clone() } else { base_cfg.clone() };
        let mut sim = Simulator::new(cfg)?;
        let total = ((cache_total as f64) * m) as u64;
        let trace = scenario::sequential_fill("fig12a", total, sim.logical_bytes());
        sim.run(&trace, Scenario::Bursty)
    });
    let mut table =
        TextTable::new(&["write_multiple", "write_gib", "lat_norm", "wa_norm"]);
    for (i, &m) in multiples.iter().enumerate() {
        let coop = results[2 * i].as_ref().map_err(|e| Error::config(e.to_string()))?;
        let base = results[2 * i + 1].as_ref().map_err(|e| Error::config(e.to_string()))?;
        table.row(vec![
            format!("{m:.3}"),
            format!("{:.2}", gib(((cache_total as f64) * m) as u64)),
            format!("{:.3}", coop.mean_write_latency() / base.mean_write_latency().max(1.0)),
            format!("{:.3}", coop.wa() / base.wa().max(1e-9)),
        ]);
    }
    print_table("Fig. 12a — cooperative vs baseline-64G (bursty, volume sweep)", &table);
    save_csv(opts, "fig12a_coop_bursty", &table)?;

    // ---- (b) daily, per workload --------------------------------
    let names = opts.workload_names();
    let mut jobs = Vec::new();
    for name in &names {
        jobs.push((name.to_string(), true));
        jobs.push((name.to_string(), false));
    }
    let results = parallel_map(jobs, opts.threads, |(name, is_coop)| -> Result<RunSummary> {
        let cfg = if is_coop { coop_cfg.clone() } else { base_cfg.clone() };
        let mut sim = Simulator::new(cfg)?;
        let one = workload_trace(opts, &name, sim.logical_bytes())?;
        // repeat the workload until total writes reach the cache size
        // (paper: "we set total write size to 64GB")
        let reps = (cache_total as f64 / one.total_write_bytes().max(1) as f64)
            .ceil()
            .clamp(1.0, 64.0) as u32;
        let trace = one.repeat(reps, 2 * SEC);
        sim.run(&trace, Scenario::Daily)
    });
    let mut table = TextTable::new(&["workload", "lat_norm", "wa_norm"]);
    let mut lat_all = Vec::new();
    let mut wa_all = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let coop = results[2 * i].as_ref().map_err(|e| Error::config(e.to_string()))?;
        let base = results[2 * i + 1].as_ref().map_err(|e| Error::config(e.to_string()))?;
        let lat = coop.mean_write_latency() / base.mean_write_latency().max(1.0);
        let wa = coop.wa() / base.wa().max(1e-9);
        lat_all.push(lat);
        wa_all.push(wa);
        table.row(vec![name.to_string(), format!("{lat:.3}"), format!("{wa:.3}")]);
    }
    table.row(vec!["MEAN".into(), format!("{:.3}", mean(&lat_all)), format!("{:.3}", mean(&wa_all))]);
    print_table("Fig. 12b — cooperative vs baseline-64G (daily) [normalized]", &table);
    save_csv(opts, "fig12b_coop_daily", &table)
}

/// Run every figure.
pub fn run_all(opts: &ExpOptions) -> Result<()> {
    fig2(opts)?;
    fig3(opts)?;
    fig4(opts)?;
    fig5(opts)?;
    fig9(opts)?;
    fig10(opts)?;
    fig11(opts)?;
    fig12(opts)?;
    Ok(())
}

/// Dispatch by figure id.
pub fn run_figure(fig: &str, opts: &ExpOptions) -> Result<()> {
    match fig {
        "2" => fig2(opts),
        "3" => fig3(opts),
        "4" => fig4(opts),
        "5" => fig5(opts),
        "9" => fig9(opts),
        "10" => fig10(opts),
        "11" => fig11(opts),
        "12" => fig12(opts),
        "all" => run_all(opts),
        other => Err(Error::config(format!(
            "unknown figure {other:?} (want 2|3|4|5|9|10|11|12|all)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExpOptions {
        ExpOptions {
            scale: 16,
            volume_scale: Some(1.0 / 2048.0),
            seed: 7,
            out_dir: std::env::temp_dir().join("ips_exp_test"),
            threads: 4,
            workloads: Some(vec!["HM_0".into(), "PROJ_4".into()]),
        }
    }

    #[test]
    fn scale_config_preserves_ratio() {
        let full = presets::table1();
        let s = scale_config(full.clone(), 4);
        let cap_ratio = s.geometry.capacity_bytes() as f64 / full.geometry.capacity_bytes() as f64;
        let cache_ratio = s.cache.slc_cache_bytes as f64 / full.cache.slc_cache_bytes as f64;
        assert!((cap_ratio - cache_ratio).abs() / cap_ratio < 0.05);
        s.validate().unwrap();
    }

    #[test]
    fn coop_and_baseline64_configs_valid() {
        let opts = tiny_opts();
        coop_config(&opts).validate().unwrap();
        baseline64_config(&opts).validate().unwrap();
    }

    #[test]
    fn fig3_runs_at_tiny_scale() {
        let opts = tiny_opts();
        fig3(&opts).unwrap();
        assert!(opts.out_dir.join("fig03_bursty_cliff.csv").exists());
    }

    #[test]
    fn fig10_runs_at_tiny_scale() {
        let opts = tiny_opts();
        fig10(&opts).unwrap();
        assert!(opts.out_dir.join("fig10a_bursty.csv").exists());
        assert!(opts.out_dir.join("fig10b_daily.csv").exists());
    }
}
