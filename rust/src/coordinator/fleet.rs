//! Thread-parallel fleet runner: the (scheme × scheduler × tenant-mix)
//! cross-product of independent multi-tenant simulations.
//!
//! Every cell of the cross-product is one fresh
//! [`MultiTenantSimulator`] — runs share nothing, so they fan out over
//! [`super::runner::parallel_map`] worker threads. Per-run seeds are
//! derived from the *cell coordinates* (not the execution order), so a
//! parallel sweep produces byte-identical summaries to a serial one —
//! asserted by `tests/integration_multitenant.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use super::runner::parallel_map;
use crate::config::{
    AttributionMode, Config, FaultConfig, FaultKind, MixKind, Nanos, QosMode, SchedKind, Scheme,
};
use crate::host::{MultiTenantSimulator, MultiTenantSummary};
use crate::metrics::{LatencyStats, Ledger, PhaseStats};
use crate::trace::scenario::Scenario;
use crate::util::fmt::TextTable;
use crate::util::rng::mix64;
use crate::Result;

/// Cache-isolation variant of one fleet cell: the shared cache the
/// PR-1 sweep measures, per-tenant partitioning, or partitioning plus
/// QoS admission control.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IsolationVariant {
    /// Shared SLC cache, no admission control (the PR-1 baseline).
    Shared,
    /// Per-tenant reserved slices + shared overflow pool.
    Partitioned,
    /// Partitioning plus token-bucket QoS in front of the scheduler.
    PartitionedQos,
}

impl IsolationVariant {
    /// Display name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            IsolationVariant::Shared => "shared",
            IsolationVariant::Partitioned => "partitioned",
            IsolationVariant::PartitionedQos => "partitioned+qos",
        }
    }
    /// All variants, in presentation order.
    pub fn all() -> [IsolationVariant; 3] {
        [IsolationVariant::Shared, IsolationVariant::Partitioned, IsolationVariant::PartitionedQos]
    }
    /// Impose the variant on a cell's config. `PartitionedQos` keeps a
    /// base QoS mode that is already on (so a spec can sweep `slo`),
    /// defaulting to `strict` otherwise.
    pub fn apply(&self, cfg: &mut Config) {
        match self {
            IsolationVariant::Shared => {
                cfg.cache.partition.enabled = false;
                cfg.host.qos.mode = QosMode::Off;
            }
            IsolationVariant::Partitioned => {
                cfg.cache.partition.enabled = true;
                cfg.host.qos.mode = QosMode::Off;
            }
            IsolationVariant::PartitionedQos => {
                cfg.cache.partition.enabled = true;
                if cfg.host.qos.mode == QosMode::Off {
                    cfg.host.qos.mode = QosMode::Strict;
                }
            }
        }
    }
}

/// One cell of the fleet cross-product.
#[derive(Clone, Copy, Debug)]
pub struct FleetJob {
    /// Cache scheme under test.
    pub scheme: Scheme,
    /// Request scheduler under test.
    pub scheduler: SchedKind,
    /// Tenant mix under test.
    pub mix: MixKind,
    /// Cache-isolation variant under test.
    pub variant: IsolationVariant,
    /// Attribution variant under test (proportional vs exact owner).
    pub attribution: AttributionMode,
    /// Per-run seed (derived from the cell, not the execution order).
    pub seed: u64,
}

/// The sweep specification.
#[derive(Clone, Debug)]
pub struct FleetSpec {
    /// Base configuration (geometry, timing, `[host]` tenant knobs).
    pub base: Config,
    /// Schemes axis.
    pub schemes: Vec<Scheme>,
    /// Schedulers axis.
    pub scheds: Vec<SchedKind>,
    /// Tenant-mix axis.
    pub mixes: Vec<MixKind>,
    /// Cache-isolation axis (shared / partitioned / partitioned+QoS).
    pub variants: Vec<IsolationVariant>,
    /// Attribution axis (proportional / owner). Like the isolation
    /// axis, it does not perturb the cell seed, so proportional and
    /// owner runs of a cell are a paired comparison.
    pub attributions: Vec<AttributionMode>,
    /// Scenario each cell runs under.
    pub scenario: Scenario,
    /// Base seed the per-cell seeds derive from.
    pub seed: u64,
    /// Worker threads.
    pub threads: usize,
}

impl FleetSpec {
    /// Full sweep over every scheme × scheduler × mix with `base`'s
    /// host settings (shared cache — the PR-1 sweep).
    pub fn full(base: Config, seed: u64, threads: usize) -> FleetSpec {
        FleetSpec {
            base,
            schemes: Scheme::all().to_vec(),
            scheds: SchedKind::all().to_vec(),
            mixes: MixKind::all().to_vec(),
            variants: vec![IsolationVariant::Shared],
            attributions: vec![AttributionMode::Proportional],
            scenario: Scenario::Bursty,
            seed,
            threads,
        }
    }

    /// The cross-product, in deterministic presentation order. Seeds
    /// mix the cell coordinates into the base seed so that reordering
    /// or filtering the axes never changes a given cell's seed. The
    /// isolation variant is deliberately *not* mixed in: shared vs
    /// partitioned cells of the same (scheme, scheduler, mix) run the
    /// exact same tenant traces, so their comparison is paired.
    pub fn jobs(&self) -> Vec<FleetJob> {
        let mut out = Vec::with_capacity(
            self.schemes.len()
                * self.scheds.len()
                * self.mixes.len()
                * self.variants.len()
                * self.attributions.len(),
        );
        for &scheme in &self.schemes {
            for &scheduler in &self.scheds {
                for &mix in &self.mixes {
                    // one seed per (scheme, scheduler, mix) cell — every
                    // variant and attribution mode of the cell
                    // deliberately shares it (paired comparisons)
                    let cell = mix64(
                        hash_str(scheme.name()),
                        mix64(hash_str(scheduler.name()), hash_str(mix.name())),
                    );
                    let seed = mix64(self.seed, cell);
                    for &variant in &self.variants {
                        for &attribution in &self.attributions {
                            out.push(FleetJob {
                                scheme,
                                scheduler,
                                mix,
                                variant,
                                attribution,
                                seed,
                            });
                        }
                    }
                }
            }
        }
        out
    }
}

/// FNV-1a — a stable 64-bit name hash (seed derivation only).
fn hash_str(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Execute the sweep: one fresh simulator per cell, fanned out over
/// `spec.threads` workers, results in `spec.jobs()` order.
pub fn run_fleet(spec: &FleetSpec) -> Result<Vec<MultiTenantSummary>> {
    let jobs = spec.jobs();
    let results = parallel_map(jobs, spec.threads, |job| -> Result<MultiTenantSummary> {
        let mut cfg = spec.base.clone();
        cfg.cache.scheme = job.scheme;
        cfg.host.scheduler = job.scheduler;
        cfg.host.mix = job.mix;
        cfg.host.attribution = job.attribution;
        cfg.sim.seed = job.seed;
        job.variant.apply(&mut cfg);
        MultiTenantSimulator::run_once(cfg, spec.scenario)
    });
    results.into_iter().collect()
}

/// The ROADMAP's device-QD ablation: the same multi-tenant cell re-run
/// at each device-side queue depth (serial — each point is one run).
/// The window size is what makes dispatch order matter, so the victim
/// tail typically *grows* with QD under FIFO while fair schedulers
/// hold it flat.
pub fn device_qd_sweep(
    base: &Config,
    scenario: Scenario,
    qds: &[usize],
) -> Result<Vec<(usize, MultiTenantSummary)>> {
    qds.iter()
        .map(|&qd| {
            let mut cfg = base.clone();
            cfg.host.device_qd = qd.max(1);
            Ok((qd, MultiTenantSimulator::run_once(cfg, scenario)?))
        })
        .collect()
}

/// The ROADMAP's joint window ablation: `host.queue_depth` (how many
/// commands one tenant may keep outstanding) crossed with
/// `host.device_qd` (how many dispatched requests the device holds in
/// flight). The two windows interact — a deep SQ only hurts the
/// victims when the device window is deep enough to drain it in
/// arrival order — and only the device side was ablated before this.
/// Every cell runs the same base seed, so the grid is fully paired.
/// Returns `(queue_depth, device_qd, summary)` rows in row-major
/// (queue-depth-major) order.
pub fn qd_joint_sweep(
    base: &Config,
    scenario: Scenario,
    queue_depths: &[usize],
    device_qds: &[usize],
) -> Result<Vec<(usize, usize, MultiTenantSummary)>> {
    let mut out = Vec::with_capacity(queue_depths.len() * device_qds.len());
    for &sq in queue_depths {
        for &qd in device_qds {
            let mut cfg = base.clone();
            cfg.host.queue_depth = sq.max(1);
            cfg.host.device_qd = qd.max(1);
            out.push((sq, qd, MultiTenantSimulator::run_once(cfg, scenario)?));
        }
    }
    Ok(out)
}

/// The channel/die-count scaling sweep the interconnect model opens
/// up: the same multi-tenant cell re-run at every (channels,
/// dies_per_chip) grid point with `sim.interconnect` forced on, so the
/// victim tail and the queued/transfer/array phase split can be read
/// against the hardware's real parallelism. Every cell keeps the base
/// seed (paired comparisons — the geometry changes logical capacity,
/// so traces scale with it, but seed-derived arrival patterns match).
/// Returns `(channels, dies_per_chip, summary)` rows in channel-major
/// order.
pub fn interconnect_sweep(
    base: &Config,
    scenario: Scenario,
    channels: &[u32],
    dies_per_chip: &[u32],
) -> Result<Vec<(u32, u32, MultiTenantSummary)>> {
    let mut out = Vec::with_capacity(channels.len() * dies_per_chip.len());
    for &ch in channels {
        for &dies in dies_per_chip {
            let mut cfg = base.clone();
            // no silent clamping: a zero channel/die count is a grid
            // mistake and geometry validation rejects it loudly
            cfg.geometry.channels = ch;
            cfg.geometry.dies_per_chip = dies;
            cfg.sim.interconnect = true;
            cfg.validate()?;
            out.push((ch, dies, MultiTenantSimulator::run_once(cfg, scenario)?));
        }
    }
    Ok(out)
}

/// Render an interconnect sweep with the per-phase latency breakdown.
pub fn interconnect_table(points: &[(u32, u32, MultiTenantSummary)]) -> TextTable {
    let mut table = TextTable::new(&[
        "channels",
        "dies",
        "scheme",
        "mean_ms",
        "victim_p99_ms",
        "q_ms",
        "xfer_ms",
        "arr_ms",
        "wa",
    ]);
    for (ch, dies, s) in points {
        table.row(vec![
            ch.to_string(),
            dies.to_string(),
            s.scheme.clone(),
            format!("{:.3}", s.write_latency.mean() / 1e6),
            format!("{:.3}", s.max_victim_p99() as f64 / 1e6),
            format!("{:.3}", s.write_phases.mean_queued_ns() / 1e6),
            format!("{:.3}", s.write_phases.mean_transfer_ns() / 1e6),
            format!("{:.3}", s.write_phases.mean_array_ns() / 1e6),
            format!("{:.3}", s.wa()),
        ]);
    }
    table
}

/// Render a sweep as the paper-style summary table (deterministic:
/// wall-clock is deliberately excluded so serial and parallel sweeps
/// render byte-identically). The q/xfer/arr columns are the
/// device-wide per-phase write-latency breakdown (mean per flash op).
pub fn summary_table(results: &[MultiTenantSummary]) -> TextTable {
    let mut table = TextTable::new(&[
        "scheme",
        "scheduler",
        "mix",
        "variant",
        "attr",
        "front",
        "seed",
        "mean_ms",
        "p99_ms",
        "wa",
        "victim_p99_ms",
        "q_ms",
        "xfer_ms",
        "arr_ms",
        "stalls",
        "bg_pages",
    ]);
    for s in results {
        table.row(vec![
            s.scheme.clone(),
            s.scheduler.clone(),
            s.mix.clone(),
            s.variant_name(),
            s.attribution.clone(),
            s.front_end.clone(),
            format!("{:#018x}", s.seed),
            format!("{:.3}", s.write_latency.mean() / 1e6),
            format!("{:.3}", s.write_latency.percentile_best(0.99) as f64 / 1e6),
            format!("{:.3}", s.wa()),
            format!("{:.3}", s.max_victim_p99() as f64 / 1e6),
            format!("{:.3}", s.write_phases.mean_queued_ns() / 1e6),
            format!("{:.3}", s.write_phases.mean_transfer_ns() / 1e6),
            format!("{:.3}", s.write_phases.mean_array_ns() / 1e6),
            s.total_throttle_stalls().to_string(),
            s.background.total_programs().to_string(),
        ]);
    }
    table
}

/// Serialize a sweep's summary rows as deterministic JSON (hand-rolled
/// — the crate is dependency-free). Field order and float formatting
/// are fixed, and wall-clock is excluded, so the same sweep always
/// yields byte-identical output: this is what the bench-smoke golden
/// check ([`crate::util::golden`]) compares against the committed
/// `rust/benches/golden/*.json` files.
pub fn summary_json(results: &[MultiTenantSummary]) -> String {
    let mut out = String::from("{\"rows\":[\n");
    for (i, s) in results.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "{{\"scheme\":\"{}\",\"scheduler\":\"{}\",\"mix\":\"{}\",\"variant\":\"{}\",\
             \"attr\":\"{}\",\"timing\":\"{}\",\"front\":\"{}\",\"seed\":\"{:#018x}\",\
             \"mean_ms\":\"{:.3}\",\
             \"p99_ms\":\"{:.3}\",\"wa\":\"{:.3}\",\"victim_p99_ms\":\"{:.3}\",\
             \"q_ms\":\"{:.3}\",\"xfer_ms\":\"{:.3}\",\"arr_ms\":\"{:.3}\",\"stalls\":{},\
             \"bg_pages\":{},\"blk_rmw\":{},\"blk_flushes\":{},\"host_bytes\":{},\
             \"sim_end\":{}}}",
            s.scheme,
            s.scheduler,
            s.mix,
            s.variant_name(),
            s.attribution,
            s.timing_model,
            s.front_end,
            s.seed,
            s.write_latency.mean() / 1e6,
            s.write_latency.percentile_best(0.99) as f64 / 1e6,
            s.wa(),
            s.max_victim_p99() as f64 / 1e6,
            s.write_phases.mean_queued_ns() / 1e6,
            s.write_phases.mean_transfer_ns() / 1e6,
            s.write_phases.mean_array_ns() / 1e6,
            s.total_throttle_stalls(),
            s.background.total_programs(),
            s.blk.rmw_reads,
            s.blk.flushes,
            s.host_bytes_written,
            s.sim_end,
        ));
    }
    out.push_str("\n]}\n");
    out
}

/// Render one run's per-tenant breakdown (the `multi-tenant`
/// subcommand's detail view). The q/xfer/arr columns are each tenant's
/// per-phase write-latency attribution (mean ms per flash op) from the
/// interconnect model — all-array with zero transfer under the lump.
pub fn tenant_table(s: &MultiTenantSummary) -> TextTable {
    let mut table = TextTable::new(&[
        "tenant",
        "weight",
        "writes",
        "reads",
        "mean_ms",
        "p50_ms",
        "p99_ms",
        "q_ms",
        "xfer_ms",
        "arr_ms",
        "mb_s",
        "wa",
        "res_pg",
        "occ_pk",
        "denied",
        "stalls",
        "mig_pg",
        "rmw",
    ]);
    let span_s = (s.sim_end as f64 / 1e9).max(1e-9);
    for t in &s.tenants {
        table.row(vec![
            t.name.clone(),
            format!("{:.2}", t.weight),
            t.write_latency.count().to_string(),
            t.read_latency.count().to_string(),
            format!("{:.3}", t.mean_write_latency() / 1e6),
            format!("{:.3}", t.p50_write_latency() as f64 / 1e6),
            format!("{:.3}", t.p99_write_latency() as f64 / 1e6),
            format!("{:.3}", t.write_phases.mean_queued_ns() / 1e6),
            format!("{:.3}", t.write_phases.mean_transfer_ns() / 1e6),
            format!("{:.3}", t.write_phases.mean_array_ns() / 1e6),
            format!("{:.1}", t.host_bytes_written as f64 / 1e6 / span_s),
            format!("{:.3}", t.wa()),
            t.cache_reserved_pages.to_string(),
            t.cache_occupancy_peak.to_string(),
            t.slc_denied_pages.to_string(),
            t.throttle_stalls.to_string(),
            t.migrated_pages_owned.to_string(),
            t.blk.rmw_reads.to_string(),
        ]);
    }
    table.row(vec![
        "(device)".into(),
        "-".into(),
        s.write_latency.count().to_string(),
        s.read_latency.count().to_string(),
        format!("{:.3}", s.write_latency.mean() / 1e6),
        format!("{:.3}", s.write_latency.percentile_best(0.50) as f64 / 1e6),
        format!("{:.3}", s.write_latency.percentile_best(0.99) as f64 / 1e6),
        format!("{:.3}", s.write_phases.mean_queued_ns() / 1e6),
        format!("{:.3}", s.write_phases.mean_transfer_ns() / 1e6),
        format!("{:.3}", s.write_phases.mean_array_ns() / 1e6),
        format!("{:.1}", s.host_bytes_written as f64 / 1e6 / span_s),
        format!("{:.3}", s.wa()),
        s.cache_capacity_pages.to_string(),
        "-".into(),
        "-".into(),
        s.total_throttle_stalls().to_string(),
        s.tenants.iter().map(|t| t.migrated_pages_owned).sum::<u64>().to_string(),
        s.blk.rmw_reads.to_string(),
    ]);
    table.row(vec![
        "(background)".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("+{} pages", s.background.total_programs()),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    table
}

// ---------------------------------------------------------------------
// Device-population fleet axis
// ---------------------------------------------------------------------

/// One simulated SSD's heterogeneity profile within a device
/// population: capacity (blocks per plane), over-provisioning
/// (`sim.logical_frac`), pre-aged wear (`sim.pre_age_erases`), the
/// workload-skew class (hot/neutral/cold devices scale the aggressor's
/// cache-footprint multiplier), and the fault schedule (what breaks on
/// this device mid-run, if anything).
/// Profiles are a pure function of `(population seed, device index)` —
/// never of the scheme/mix axes — so every scheme is measured over the
/// *same* population (same capacities, same skew, *same faults*) and
/// cross-scheme comparisons stay paired.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceProfile {
    /// Device index within the population.
    pub device: u32,
    /// Per-device `geometry.blocks_per_plane` (capacity axis).
    pub blocks_per_plane: u32,
    /// Per-device exported logical fraction (1 − OP; the OP axis).
    pub logical_frac: f64,
    /// Per-device max initial erase count (0 = pristine; the wear axis).
    pub pre_age_erases: u32,
    /// Workload-skew multiplier applied to
    /// `host.aggressor_cache_mult` (the hot/cold device-class axis).
    pub skew: f64,
    /// Mid-run fault schedule (`kind == None` for healthy devices).
    pub fault: FaultConfig,
    /// Per-device seed component mixed into each run's trace seed.
    pub seed: u64,
}

/// A device-population sweep: `devices` heterogeneous SSDs × schemes ×
/// mixes, sharded across threads, folded into fleet-wide percentiles.
#[derive(Clone, Debug)]
pub struct PopulationSpec {
    /// Base configuration each device profile perturbs.
    pub base: Config,
    /// Population size.
    pub devices: u32,
    /// Schemes axis.
    pub schemes: Vec<Scheme>,
    /// Tenant-mix axis.
    pub mixes: Vec<MixKind>,
    /// Scenario each device runs under.
    pub scenario: Scenario,
    /// Fraction of the population assigned a fault schedule
    /// (clamped to `[0, 1]`; 0 = every device healthy).
    pub fault_rate: f64,
    /// Base seed: profiles and per-run seeds derive from it.
    pub seed: u64,
    /// Worker threads.
    pub threads: usize,
}

/// Capacity steps in quarters of the base `blocks_per_plane`
/// (0.75×, 1×, 1.5×).
const BPP_QUARTER_STEPS: [u32; 3] = [3, 4, 6];
/// Over-provisioning steps (exported logical fraction).
const OP_STEPS: [f64; 4] = [0.70, 0.75, 0.80, 0.85];
/// Pre-age steps (max initial erases: pristine → heavily worn).
const AGE_STEPS: [u32; 4] = [0, 50, 200, 1000];
/// Workload-skew steps (cold / neutral / hot device classes, as a
/// multiplier on the aggressor's cache-footprint knob).
const SKEW_STEPS: [f64; 3] = [0.5, 1.0, 1.5];
/// Fault-onset steps, as a fraction of the trace arrival horizon.
const FAULT_AT_STEPS: [f64; 3] = [0.25, 0.50, 0.75];
/// Wear-slowdown steps (program/erase latency multiplier ×100).
const SLOW_STEPS: [u32; 3] = [150, 200, 400];

impl PopulationSpec {
    /// A heterogeneous population over all schemes on the
    /// aggressor/victims mix (the headline fleet experiment: does the
    /// victim-p99 ranking survive wear/OP heterogeneity?).
    pub fn heterogeneous(base: Config, devices: u32, seed: u64, threads: usize) -> PopulationSpec {
        PopulationSpec {
            base,
            devices,
            schemes: Scheme::all().to_vec(),
            mixes: vec![MixKind::AggressorVictims],
            scenario: Scenario::Bursty,
            fault_rate: 0.0,
            seed,
            threads,
        }
    }

    /// The device profiles, in device order. Each axis cycles through
    /// its steps with a seed-derived phase (and a stride coprime to the
    /// step count), so any population of ≥ 4 devices is guaranteed to
    /// mix capacities, OP levels, and wear ages rather than gambling on
    /// hash collisions.
    pub fn profiles(&self) -> Vec<DeviceProfile> {
        let quarter = (self.base.geometry.blocks_per_plane / 4).max(1);
        let planes = self.base.geometry.planes();
        (0..self.devices)
            .map(|d| {
                let bpp_i = ((d as u64 + mix64(self.seed, 1)) % 3) as usize;
                let op_i = ((d as u64 + mix64(self.seed, 2)) % 4) as usize;
                let age_i = ((3 * d as u64 + mix64(self.seed, 3)) % 4) as usize;
                let skew_i = ((5 * d as u64 + mix64(self.seed, 4)) % 3) as usize;
                DeviceProfile {
                    device: d,
                    blocks_per_plane: (quarter * BPP_QUARTER_STEPS[bpp_i]).max(4),
                    logical_frac: OP_STEPS[op_i],
                    pre_age_erases: AGE_STEPS[age_i],
                    skew: SKEW_STEPS[skew_i],
                    fault: self.fault_for(d, planes),
                    seed: mix64(self.seed, mix64(hash_str("device"), d as u64)),
                }
            })
            .collect()
    }

    /// The fault schedule for one device: a pure function of
    /// `(population seed, device index)` — never of the scheme/mix axes
    /// — so every scheme sees the *identical* degradation pattern and
    /// healthy-vs-faulted deltas are paired comparisons. Roughly
    /// `fault_rate` of the population is faulted; faulted devices
    /// alternate plane loss and wear slowdown with cycled onset times.
    fn fault_for(&self, d: u32, planes: u32) -> FaultConfig {
        let rate_mills = (self.fault_rate.clamp(0.0, 1.0) * 1000.0).round() as u64;
        let h = mix64(self.seed, mix64(hash_str("fault"), d as u64));
        if h % 1000 >= rate_mills {
            return FaultConfig::default(); // kind: None — healthy
        }
        // single-plane geometries cannot lose a plane; fall back to
        // slowdown-only schedules rather than failing validation
        let kind = if planes >= 2 && (h >> 10) % 2 == 0 {
            FaultKind::PlaneLoss
        } else {
            FaultKind::Slowdown
        };
        FaultConfig {
            kind,
            at_frac: FAULT_AT_STEPS[((h >> 12) % 3) as usize],
            plane: ((h >> 16) % planes.max(1) as u64) as u32,
            slow_x100: SLOW_STEPS[((h >> 24) % 3) as usize],
        }
    }

    /// The per-device run config for one (scheme, mix) cell. The fleet
    /// path carries **no raw per-request vectors**: `latency_samples`
    /// is forced to 0, so percentiles come from the mergeable
    /// log-linear histograms alone and a 10^8-request device costs the
    /// same fixed ~30 KB per collector.
    fn device_config(&self, scheme: Scheme, mix: MixKind, p: &DeviceProfile) -> Result<Config> {
        let mut cfg = self.base.clone();
        cfg.cache.scheme = scheme;
        cfg.host.mix = mix;
        cfg.geometry.blocks_per_plane = p.blocks_per_plane;
        cfg.sim.logical_frac = p.logical_frac;
        cfg.sim.pre_age_erases = p.pre_age_erases;
        cfg.host.aggressor_cache_mult = (self.base.host.aggressor_cache_mult * p.skew).max(0.1);
        cfg.fault = p.fault;
        cfg.sim.latency_samples = 0;
        let cell = mix64(hash_str(scheme.name()), hash_str(mix.name()));
        cfg.sim.seed = mix64(p.seed, cell);
        cfg.validate()?;
        Ok(cfg)
    }
}

/// One device's completed run within a population sweep.
#[derive(Clone, Debug)]
pub struct DeviceRun {
    /// Scheme this device ran.
    pub scheme: Scheme,
    /// Tenant mix this device ran.
    pub mix: MixKind,
    /// The device's heterogeneity profile.
    pub profile: DeviceProfile,
    /// The device-level summary (histograms, ledgers, phases).
    pub summary: MultiTenantSummary,
}

/// Execute a population sweep: scheme-major, then mix, then device,
/// fanned out over `spec.threads` workers with results in spec order
/// (the property the byte-identical serial-vs-parallel fold rests on).
pub fn run_population(spec: &PopulationSpec) -> Result<Vec<DeviceRun>> {
    let profiles = spec.profiles();
    let mut jobs = Vec::with_capacity(spec.schemes.len() * spec.mixes.len() * profiles.len());
    for &scheme in &spec.schemes {
        for &mix in &spec.mixes {
            for &profile in &profiles {
                jobs.push((scheme, mix, profile));
            }
        }
    }
    let results = parallel_map(jobs, spec.threads, |(scheme, mix, profile)| -> Result<DeviceRun> {
        let cfg = spec.device_config(scheme, mix, &profile)?;
        let summary = MultiTenantSimulator::run_once(cfg, spec.scenario)?;
        Ok(DeviceRun { scheme, mix, profile, summary })
    });
    results.into_iter().collect()
}

/// Fleet-wide rollup of one (scheme, mix) cell across the population:
/// pure histogram / [`PhaseStats`] / [`Ledger`] merges — per-device
/// summaries fold without ever touching raw per-request samples, and
/// because same-resolution histogram merges are exact counter
/// additions, serial and sharded folds agree byte for byte.
#[derive(Clone, Debug)]
pub struct PopulationSummary {
    /// Scheme name.
    pub scheme: String,
    /// Tenant-mix name.
    pub mix: String,
    /// Scenario name.
    pub scenario: String,
    /// Devices folded in.
    pub devices: u32,
    /// Devices with no fault scheduled.
    pub devices_healthy: u32,
    /// Devices with a fault schedule (plane loss or wear slowdown).
    pub devices_faulted: u32,
    /// Fleet-wide host write latency (merged histograms).
    pub write_latency: LatencyStats,
    /// Fleet-wide host read latency.
    pub read_latency: LatencyStats,
    /// Fleet-wide victim-tenant write latency (merged across every
    /// victim tenant of every device — the headline tail).
    pub victim_latency: LatencyStats,
    /// Victim-tenant write latency over healthy devices only.
    pub victim_latency_healthy: LatencyStats,
    /// Victim-tenant write latency over faulted devices only — read
    /// against the healthy column, this is the degradation headline.
    pub victim_latency_faulted: LatencyStats,
    /// Fleet-wide write phase split.
    pub write_phases: PhaseStats,
    /// Fleet-wide WA ledger.
    pub ledger: Ledger,
    /// Fleet-wide background (GC/migration) ledger.
    pub background: Ledger,
    /// Total host bytes written across the population.
    pub host_bytes_written: u64,
    /// Total QoS throttle stalls across the population.
    pub throttle_stalls: u64,
    /// Latest simulated end time across the population.
    pub sim_end_max: Nanos,
}

impl PopulationSummary {
    fn empty(scheme: &str, mix: &str, scenario: &str, sub_buckets: u32) -> PopulationSummary {
        PopulationSummary {
            scheme: scheme.to_string(),
            mix: mix.to_string(),
            scenario: scenario.to_string(),
            devices: 0,
            devices_healthy: 0,
            devices_faulted: 0,
            write_latency: LatencyStats::with_resolution(sub_buckets, 0),
            read_latency: LatencyStats::with_resolution(sub_buckets, 0),
            victim_latency: LatencyStats::with_resolution(sub_buckets, 0),
            victim_latency_healthy: LatencyStats::with_resolution(sub_buckets, 0),
            victim_latency_faulted: LatencyStats::with_resolution(sub_buckets, 0),
            write_phases: PhaseStats::default(),
            ledger: Ledger::default(),
            background: Ledger::default(),
            host_bytes_written: 0,
            throttle_stalls: 0,
            sim_end_max: 0,
        }
    }

    /// Fleet write amplification.
    pub fn wa(&self) -> f64 {
        self.ledger.write_amplification()
    }

    /// Merge another rollup of the same `(scheme, mix)` cell into this
    /// one. Every constituent is an exact counter addition (histograms,
    /// phases, ledgers) or a sum/max, so merging shard partials in
    /// shard order is byte-identical to folding the devices serially —
    /// the invariant the streaming sweep rests on.
    pub fn merge(&mut self, other: &PopulationSummary) {
        debug_assert!(self.scheme == other.scheme && self.mix == other.mix);
        self.devices += other.devices;
        self.devices_healthy += other.devices_healthy;
        self.devices_faulted += other.devices_faulted;
        self.write_latency.merge(&other.write_latency);
        self.read_latency.merge(&other.read_latency);
        self.victim_latency.merge(&other.victim_latency);
        self.victim_latency_healthy.merge(&other.victim_latency_healthy);
        self.victim_latency_faulted.merge(&other.victim_latency_faulted);
        self.write_phases.merge(&other.write_phases);
        self.ledger.merge(&other.ledger);
        self.background.merge(&other.background);
        self.host_bytes_written += other.host_bytes_written;
        self.throttle_stalls += other.throttle_stalls;
        self.sim_end_max = self.sim_end_max.max(other.sim_end_max);
    }
}

/// Fold per-device runs into per-(scheme, mix) fleet summaries, in
/// first-seen (spec) order. Works on any `DeviceRun` slice in a
/// deterministic order; [`run_population`] output qualifies whatever
/// the thread count was.
pub fn fold_population(runs: &[DeviceRun]) -> Vec<PopulationSummary> {
    let mut out: Vec<PopulationSummary> = Vec::new();
    for r in runs {
        fold_run_into(&mut out, r);
    }
    out
}

/// Fold one device run into its `(scheme, mix)` cell, appending the
/// cell in first-seen order. This is the single fold step both the
/// collect-then-fold path ([`fold_population`]) and the streaming
/// sharded path ([`run_population_streaming`]) share, so the two can
/// never drift apart.
fn fold_run_into(out: &mut Vec<PopulationSummary>, r: &DeviceRun) {
    let s = &r.summary;
    let pos = out.iter().position(|c| c.scheme == s.scheme && c.mix == s.mix);
    let cell = match pos {
        Some(i) => &mut out[i],
        None => {
            out.push(PopulationSummary::empty(
                &s.scheme,
                &s.mix,
                &s.scenario,
                s.write_latency.sub_buckets(),
            ));
            out.last_mut().expect("just pushed")
        }
    };
    let faulted = r.profile.fault.kind != FaultKind::None;
    cell.devices += 1;
    if faulted {
        cell.devices_faulted += 1;
    } else {
        cell.devices_healthy += 1;
    }
    cell.write_latency.merge(&s.write_latency);
    cell.read_latency.merge(&s.read_latency);
    for t in s.tenants.iter().filter(|t| t.name.starts_with("victim")) {
        cell.victim_latency.merge(&t.write_latency);
        if faulted {
            cell.victim_latency_faulted.merge(&t.write_latency);
        } else {
            cell.victim_latency_healthy.merge(&t.write_latency);
        }
    }
    cell.write_phases.merge(&s.write_phases);
    cell.ledger.merge(&s.ledger);
    cell.background.merge(&s.background);
    cell.host_bytes_written += s.host_bytes_written;
    cell.throttle_stalls += s.total_throttle_stalls();
    cell.sim_end_max = cell.sim_end_max.max(s.sim_end);
}

/// Merge a shard-partial cell into the global cell list (find-or-append
/// by `(scheme, mix)`, preserving first-seen order). Because shards are
/// *contiguous* slices of the scheme-major job list, concatenating
/// partials in shard order reproduces the serial first-seen order.
fn merge_cell_into(out: &mut Vec<PopulationSummary>, c: PopulationSummary) {
    match out.iter_mut().find(|x| x.scheme == c.scheme && x.mix == c.mix) {
        Some(x) => x.merge(&c),
        None => out.push(c),
    }
}

/// Memory accounting from a streaming population sweep.
///
/// `wall_clock` and `peak_rss_kb` are *measurements* (the rack-scale
/// datapoint `ips fleet` prints and `BENCH_PR10.json` records): they
/// vary run to run and are deliberately excluded from the
/// deterministic table/JSON/CSV outputs the golden gates compare.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    /// Peak number of `DeviceRun`s resident at once across all workers
    /// — the bounded-memory invariant (≤ one per worker thread, never
    /// the whole population).
    pub peak_resident_runs: usize,
    /// Total device runs executed.
    pub runs: usize,
    /// Wall-clock time of the whole sweep (fan-out through fold).
    pub wall_clock: std::time::Duration,
    /// Process peak RSS in KiB after the sweep (`VmHWM`, Linux procfs;
    /// 0 where unavailable). With `sim.streaming_traces` on, device
    /// workloads are never materialized, so this tracks simulator
    /// state, not trace vectors.
    pub peak_rss_kb: u64,
}

/// Per-device CSV header for the streaming sweep's row stream.
pub const DEVICE_CSV_HEADER: &str =
    "device,scheme,mix,bpp,logical_frac,pre_age,skew,fault,writes,p99_ms,victim_p99_ms,wa\n";

/// One streamed per-device CSV row (matches [`DEVICE_CSV_HEADER`]).
/// The `fault` column reports what actually *fired* during the run
/// (from the summary), not merely what was scheduled.
fn device_csv_row(r: &DeviceRun) -> String {
    let s = &r.summary;
    format!(
        "{},{},{},{},{:.2},{},{:.2},{},{},{:.3},{:.3},{:.3}\n",
        r.profile.device,
        s.scheme,
        s.mix,
        r.profile.blocks_per_plane,
        r.profile.logical_frac,
        r.profile.pre_age_erases,
        r.profile.skew,
        s.fault,
        s.write_latency.count(),
        s.write_latency.percentile(0.99) as f64 / 1e6,
        s.max_victim_p99() as f64 / 1e6,
        s.wa(),
    )
}

/// Execute a population sweep as a **streaming fold**: the job list is
/// split into contiguous shards (one per worker), each worker folds its
/// devices into a shard-partial [`PopulationSummary`] list and streams
/// the per-device CSV row through a bounded channel, dropping the
/// `DeviceRun` immediately. A 1000-device sweep therefore never holds
/// more than one `DeviceRun` per worker in memory (asserted via the
/// returned [`StreamStats`] high-water mark), while producing
/// byte-identical rollups to [`run_population`] + [`fold_population`]
/// at any thread count — shards are contiguous and every constituent
/// merge is an exact counter addition.
///
/// Returns `(cells, per_device_csv, stats)`; the CSV rows are in
/// deterministic job order regardless of worker interleaving.
pub fn run_population_streaming(
    spec: &PopulationSpec,
) -> Result<(Vec<PopulationSummary>, String, StreamStats)> {
    let wall0 = std::time::Instant::now();
    let profiles = spec.profiles();
    let mut jobs = Vec::with_capacity(spec.schemes.len() * spec.mixes.len() * profiles.len());
    for &scheme in &spec.schemes {
        for &mix in &spec.mixes {
            for &profile in &profiles {
                jobs.push((scheme, mix, profile));
            }
        }
    }
    let n = jobs.len();
    if n == 0 {
        return Ok((Vec::new(), DEVICE_CSV_HEADER.to_string(), StreamStats::default()));
    }
    let threads = spec.threads.clamp(1, n);
    let shard_len = n.div_ceil(threads);
    let mut shards: Vec<Vec<(usize, (Scheme, MixKind, DeviceProfile))>> = Vec::new();
    for (i, job) in jobs.into_iter().enumerate() {
        if i % shard_len == 0 {
            shards.push(Vec::with_capacity(shard_len));
        }
        shards.last_mut().expect("shard pushed").push((i, job));
    }
    let resident = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    // bounded row channel: workers block when the drain falls behind,
    // so the row backlog is as bounded as the runs themselves
    let (tx, rx) = mpsc::sync_channel::<(usize, String)>(2 * threads);
    let (mut rows, partials) = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(shards.len());
        for shard in shards {
            let tx = tx.clone();
            let (resident, peak) = (&resident, &peak);
            handles.push(scope.spawn(move || -> Result<Vec<PopulationSummary>> {
                let mut partial: Vec<PopulationSummary> = Vec::new();
                for (idx, (scheme, mix, profile)) in shard {
                    let cfg = spec.device_config(scheme, mix, &profile)?;
                    let cur = resident.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(cur, Ordering::SeqCst);
                    let summary = MultiTenantSimulator::run_once(cfg, spec.scenario)?;
                    let run = DeviceRun { scheme, mix, profile, summary };
                    fold_run_into(&mut partial, &run);
                    let row = device_csv_row(&run);
                    drop(run); // the whole point: nothing accumulates
                    resident.fetch_sub(1, Ordering::SeqCst);
                    if tx.send((idx, row)).is_err() {
                        break; // drain side gone — a sibling errored
                    }
                }
                Ok(partial)
            }));
        }
        drop(tx);
        let mut rows: Vec<(usize, String)> = Vec::with_capacity(n);
        for item in rx.iter() {
            rows.push(item);
        }
        let partials: Vec<Result<Vec<PopulationSummary>>> =
            handles.into_iter().map(|h| h.join().expect("population worker panicked")).collect();
        (rows, partials)
    });
    let mut cells: Vec<PopulationSummary> = Vec::new();
    for partial in partials {
        for c in partial? {
            merge_cell_into(&mut cells, c);
        }
    }
    rows.sort_unstable_by_key(|&(i, _)| i);
    let mut csv = String::from(DEVICE_CSV_HEADER);
    for (_, row) in rows {
        csv.push_str(&row);
    }
    let stats = StreamStats {
        peak_resident_runs: peak.load(Ordering::SeqCst),
        runs: n,
        wall_clock: wall0.elapsed(),
        peak_rss_kb: crate::util::mem::peak_rss_kb().unwrap_or(0),
    };
    Ok((cells, csv, stats))
}

/// Render the fleet rollup (one row per scheme × mix cell) with the
/// p50/p99/p99.9 headlines. Deterministic — no wall-clock columns.
pub fn population_table(cells: &[PopulationSummary]) -> TextTable {
    let mut table = TextTable::new(&[
        "scheme",
        "mix",
        "devices",
        "faulted",
        "writes",
        "p50_ms",
        "p99_ms",
        "p999_ms",
        "victim_p99_ms",
        "victim_p999_ms",
        "healthy_vp99_ms",
        "faulted_vp99_ms",
        "wa",
        "stalls",
    ]);
    for c in cells {
        table.row(vec![
            c.scheme.clone(),
            c.mix.clone(),
            c.devices.to_string(),
            c.devices_faulted.to_string(),
            c.write_latency.count().to_string(),
            format!("{:.3}", c.write_latency.percentile(0.50) as f64 / 1e6),
            format!("{:.3}", c.write_latency.percentile(0.99) as f64 / 1e6),
            format!("{:.3}", c.write_latency.percentile(0.999) as f64 / 1e6),
            format!("{:.3}", c.victim_latency.percentile(0.99) as f64 / 1e6),
            format!("{:.3}", c.victim_latency.percentile(0.999) as f64 / 1e6),
            format!("{:.3}", c.victim_latency_healthy.percentile(0.99) as f64 / 1e6),
            format!("{:.3}", c.victim_latency_faulted.percentile(0.99) as f64 / 1e6),
            format!("{:.3}", c.wa()),
            c.throttle_stalls.to_string(),
        ]);
    }
    table
}

/// Render the per-device breakdown of a population run (which device
/// profile produced which tail — the heterogeneity detail view).
pub fn device_table(runs: &[DeviceRun]) -> TextTable {
    let mut table = TextTable::new(&[
        "device",
        "scheme",
        "mix",
        "bpp",
        "logical_frac",
        "pre_age",
        "skew",
        "fault",
        "writes",
        "p99_ms",
        "victim_p99_ms",
        "wa",
    ]);
    for r in runs {
        let s = &r.summary;
        table.row(vec![
            r.profile.device.to_string(),
            s.scheme.clone(),
            s.mix.clone(),
            r.profile.blocks_per_plane.to_string(),
            format!("{:.2}", r.profile.logical_frac),
            r.profile.pre_age_erases.to_string(),
            format!("{:.2}", r.profile.skew),
            s.fault.clone(),
            s.write_latency.count().to_string(),
            format!("{:.3}", s.write_latency.percentile(0.99) as f64 / 1e6),
            format!("{:.3}", s.max_victim_p99() as f64 / 1e6),
            format!("{:.3}", s.wa()),
        ]);
    }
    table
}

/// Serialize a fleet rollup as deterministic, machine-readable JSON
/// (hand-rolled — dependency-free crate). Field order and float
/// formatting are fixed and wall-clock is excluded: the same
/// population folded serially or sharded yields byte-identical output,
/// which is both the acceptance invariant's test surface and what the
/// `fig_fleet` golden snapshot gates on.
pub fn population_json(cells: &[PopulationSummary]) -> String {
    let mut out = String::from("{\"rows\":[\n");
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "{{\"scheme\":\"{}\",\"mix\":\"{}\",\"scenario\":\"{}\",\"devices\":{},\
             \"devices_healthy\":{},\"devices_faulted\":{},\
             \"writes\":{},\"reads\":{},\
             \"mean_ms\":\"{:.3}\",\"p50_ms\":\"{:.3}\",\"p99_ms\":\"{:.3}\",\
             \"p999_ms\":\"{:.3}\",\"max_ms\":\"{:.3}\",\
             \"victim_p99_ms\":\"{:.3}\",\"victim_p999_ms\":\"{:.3}\",\
             \"healthy_victim_p99_ms\":\"{:.3}\",\"faulted_victim_p99_ms\":\"{:.3}\",\
             \"wa\":\"{:.3}\",\"q_ms\":\"{:.3}\",\"xfer_ms\":\"{:.3}\",\"arr_ms\":\"{:.3}\",\
             \"stalls\":{},\"bg_pages\":{},\"host_bytes\":{},\"sim_end_max\":{}}}",
            c.scheme,
            c.mix,
            c.scenario,
            c.devices,
            c.devices_healthy,
            c.devices_faulted,
            c.write_latency.count(),
            c.read_latency.count(),
            c.write_latency.mean() / 1e6,
            c.write_latency.percentile(0.50) as f64 / 1e6,
            c.write_latency.percentile(0.99) as f64 / 1e6,
            c.write_latency.percentile(0.999) as f64 / 1e6,
            c.write_latency.max() as f64 / 1e6,
            c.victim_latency.percentile(0.99) as f64 / 1e6,
            c.victim_latency.percentile(0.999) as f64 / 1e6,
            c.victim_latency_healthy.percentile(0.99) as f64 / 1e6,
            c.victim_latency_faulted.percentile(0.99) as f64 / 1e6,
            c.wa(),
            c.write_phases.mean_queued_ns() / 1e6,
            c.write_phases.mean_transfer_ns() / 1e6,
            c.write_phases.mean_array_ns() / 1e6,
            c.throttle_stalls,
            c.background.total_programs(),
            c.host_bytes_written,
            c.sim_end_max,
        ));
    }
    out.push_str("\n]}\n");
    out
}

/// The same rollup as CSV rows (trex-summarize shape: one machine
/// format feeds both the figure pipeline and spreadsheet triage).
pub fn population_csv(cells: &[PopulationSummary]) -> String {
    let mut out = String::from(
        "scheme,mix,scenario,devices,devices_healthy,devices_faulted,writes,\
         p50_ms,p99_ms,p999_ms,victim_p99_ms,victim_p999_ms,\
         healthy_victim_p99_ms,faulted_victim_p99_ms,wa,stalls,host_bytes\n",
    );
    for c in cells {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{},{}\n",
            c.scheme,
            c.mix,
            c.scenario,
            c.devices,
            c.devices_healthy,
            c.devices_faulted,
            c.write_latency.count(),
            c.write_latency.percentile(0.50) as f64 / 1e6,
            c.write_latency.percentile(0.99) as f64 / 1e6,
            c.write_latency.percentile(0.999) as f64 / 1e6,
            c.victim_latency.percentile(0.99) as f64 / 1e6,
            c.victim_latency.percentile(0.999) as f64 / 1e6,
            c.victim_latency_healthy.percentile(0.99) as f64 / 1e6,
            c.victim_latency_faulted.percentile(0.99) as f64 / 1e6,
            c.wa(),
            c.throttle_stalls,
            c.host_bytes_written,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn tiny_spec(threads: usize) -> FleetSpec {
        let mut base = presets::small();
        base.cache.slc_cache_bytes = 1 << 20;
        base.host.tenants = 3;
        base.host.aggressor_cache_mult = 1.5;
        FleetSpec {
            base,
            schemes: vec![Scheme::Baseline, Scheme::Ips],
            scheds: vec![SchedKind::Fifo, SchedKind::RoundRobin],
            mixes: vec![MixKind::AggressorVictims],
            variants: vec![IsolationVariant::Shared],
            attributions: vec![AttributionMode::Proportional],
            scenario: Scenario::Bursty,
            seed: 42,
            threads,
        }
    }

    #[test]
    fn jobs_cover_the_cross_product() {
        let spec = tiny_spec(1);
        let jobs = spec.jobs();
        assert_eq!(jobs.len(), 4);
        // seeds are distinct per cell and stable across invocations
        let seeds: Vec<u64> = jobs.iter().map(|j| j.seed).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "distinct per-cell seeds");
        assert_eq!(seeds, spec.jobs().iter().map(|j| j.seed).collect::<Vec<_>>());
    }

    #[test]
    fn cell_seed_ignores_axis_order() {
        let spec = tiny_spec(1);
        let mut rev = spec.clone();
        rev.schemes.reverse();
        rev.scheds.reverse();
        let find = |jobs: &[FleetJob], s: Scheme, d: SchedKind| {
            jobs.iter().find(|j| j.scheme == s && j.scheduler == d).unwrap().seed
        };
        let a = spec.jobs();
        let b = rev.jobs();
        assert_eq!(
            find(&a, Scheme::Ips, SchedKind::Fifo),
            find(&b, Scheme::Ips, SchedKind::Fifo),
            "a cell's seed is a function of the cell, not its position"
        );
    }

    #[test]
    fn parallel_sweep_matches_serial_byte_for_byte() {
        let serial = run_fleet(&tiny_spec(1)).unwrap();
        let parallel = run_fleet(&tiny_spec(4)).unwrap();
        assert_eq!(
            summary_table(&serial).render(),
            summary_table(&parallel).render(),
            "thread count must not leak into results"
        );
    }

    #[test]
    fn variant_axis_pairs_seeds_and_labels_runs() {
        let mut spec = tiny_spec(1);
        spec.schemes = vec![Scheme::Baseline];
        spec.scheds = vec![SchedKind::Fifo];
        spec.variants = IsolationVariant::all().to_vec();
        let jobs = spec.jobs();
        assert_eq!(jobs.len(), 3);
        // paired comparison: all variants of a cell share the seed
        assert!(jobs.windows(2).all(|w| w[0].seed == w[1].seed));
        let results = run_fleet(&spec).unwrap();
        assert!(!results[0].partitioned && results[0].qos_mode == "off");
        assert!(results[1].partitioned && results[1].qos_mode == "off");
        assert!(results[2].partitioned && results[2].qos_mode == "strict");
        // identical offered load across variants (same traces)
        assert_eq!(results[0].host_bytes_written, results[1].host_bytes_written);
        assert_eq!(results[0].host_bytes_written, results[2].host_bytes_written);
    }

    #[test]
    fn attribution_axis_pairs_seeds_and_labels_runs() {
        let mut spec = tiny_spec(1);
        spec.schemes = vec![Scheme::Baseline];
        spec.scheds = vec![SchedKind::Fifo];
        spec.variants = vec![IsolationVariant::Partitioned];
        spec.attributions = AttributionMode::all().to_vec();
        let jobs = spec.jobs();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].seed, jobs[1].seed, "attribution runs are paired");
        let results = run_fleet(&spec).unwrap();
        assert_eq!(results[0].attribution, "proportional");
        assert_eq!(results[1].attribution, "owner");
        // same traces, same offered load — only the accounting differs
        assert_eq!(results[0].host_bytes_written, results[1].host_bytes_written);
        // device-level totals close under both attributions
        for s in &results {
            let mut sum = crate::metrics::Ledger::default();
            for t in &s.tenants {
                sum.merge(&t.ledger);
            }
            sum.merge(&s.background);
            assert_eq!(sum, s.ledger, "{} attribution closes", s.attribution);
        }
    }

    #[test]
    fn qd_joint_sweep_covers_the_grid_with_paired_seeds() {
        let mut base = presets::small();
        base.cache.slc_cache_bytes = 1 << 20;
        base.host.tenants = 3;
        base.host.aggressor_cache_mult = 1.5;
        base.sim.latency_samples = 100_000;
        let points =
            qd_joint_sweep(&base, Scenario::Bursty, &[1, 32], &[1, 4, 16]).unwrap();
        assert_eq!(points.len(), 6, "2 × 3 grid, one run per cell");
        // row-major order, queue-depth-major
        let coords: Vec<(usize, usize)> = points.iter().map(|&(sq, qd, _)| (sq, qd)).collect();
        assert_eq!(coords, vec![(1, 1), (1, 4), (1, 16), (32, 1), (32, 4), (32, 16)]);
        for (sq, qd, s) in &points {
            assert_eq!(s.seed, base.sim.seed, "cell ({sq},{qd}) keeps the paired seed");
            assert!(s.host_bytes_written > 0);
        }
        // the windows change scheduling, never the offered load
        assert!(points.windows(2).all(|w| {
            w[0].2.host_bytes_written == w[1].2.host_bytes_written
        }));
    }

    #[test]
    fn interconnect_sweep_covers_the_grid_with_phases() {
        let mut base = presets::small();
        base.cache.slc_cache_bytes = 1 << 20;
        base.host.tenants = 3;
        base.host.aggressor_cache_mult = 1.5;
        base.sim.latency_samples = 100_000;
        let points =
            interconnect_sweep(&base, Scenario::Bursty, &[1, 2], &[1, 2]).unwrap();
        assert_eq!(points.len(), 4, "2 x 2 grid, one run per cell");
        let coords: Vec<(u32, u32)> = points.iter().map(|&(c, d, _)| (c, d)).collect();
        assert_eq!(coords, vec![(1, 1), (1, 2), (2, 1), (2, 2)]);
        for (ch, dies, s) in &points {
            assert_eq!(s.timing_model, "interconnect", "cell ({ch},{dies})");
            assert!(s.host_bytes_written > 0);
            assert!(s.write_phases.transfer_ns > 0, "bus time visible at ({ch},{dies})");
        }
        let rendered = interconnect_table(&points).render();
        assert!(rendered.contains("xfer_ms"));
    }

    #[test]
    fn summary_json_is_deterministic_and_structured() {
        let spec = tiny_spec(1);
        let a = summary_json(&run_fleet(&spec).unwrap());
        let b = summary_json(&run_fleet(&spec).unwrap());
        assert_eq!(a, b, "same sweep, same bytes");
        assert!(a.starts_with("{\"rows\":["));
        assert!(a.contains("\"scheme\":\"baseline\""));
        assert!(a.contains("\"attr\":\"proportional\""));
        assert!(a.trim_end().ends_with("]}"));
    }

    #[test]
    fn device_qd_sweep_runs_each_point() {
        let mut base = presets::small();
        base.cache.slc_cache_bytes = 1 << 20;
        base.host.tenants = 3;
        base.host.aggressor_cache_mult = 1.5;
        let points =
            device_qd_sweep(&base, Scenario::Bursty, &[1, 4, 16]).unwrap();
        assert_eq!(points.len(), 3);
        for (qd, s) in &points {
            assert!(s.host_bytes_written > 0, "qd {qd} served traffic");
        }
        // identical offered load at every queue depth
        assert_eq!(points[0].1.host_bytes_written, points[2].1.host_bytes_written);
        // a deeper device window can only help or keep device p99 — but
        // it must not change WHO was served
        assert_eq!(points[0].1.write_latency.count(), points[2].1.write_latency.count());
    }

    fn tiny_population(devices: u32, threads: usize) -> PopulationSpec {
        let mut base = presets::small();
        base.cache.slc_cache_bytes = 1 << 20;
        base.host.tenants = 3;
        base.host.aggressor_cache_mult = 1.5;
        PopulationSpec {
            base,
            devices,
            schemes: vec![Scheme::Baseline, Scheme::Ips],
            mixes: vec![MixKind::AggressorVictims],
            scenario: Scenario::Bursty,
            fault_rate: 0.0,
            seed: 42,
            threads,
        }
    }

    #[test]
    fn profiles_are_heterogeneous_and_scheme_independent() {
        let spec = tiny_population(4, 1);
        let profiles = spec.profiles();
        assert_eq!(profiles.len(), 4);
        // each axis cycles by construction: ≥ 4 devices guarantees mixed
        // capacities, OP levels, and wear ages
        let distinct = |f: &dyn Fn(&DeviceProfile) -> u64| {
            let mut v: Vec<u64> = profiles.iter().map(f).collect();
            v.sort_unstable();
            v.dedup();
            v.len()
        };
        assert!(distinct(&|p| p.blocks_per_plane as u64) >= 2, "capacity axis varies");
        assert!(distinct(&|p| (p.logical_frac * 100.0) as u64) >= 2, "OP axis varies");
        assert!(distinct(&|p| p.pre_age_erases as u64) >= 2, "wear axis varies");
        // the population is a function of (seed, device) only: changing
        // the scheme axis must not change who the devices are
        let mut one_scheme = spec.clone();
        one_scheme.schemes = vec![Scheme::TlcOnly];
        assert_eq!(profiles, one_scheme.profiles(), "paired across schemes");
        assert_eq!(profiles, spec.profiles(), "stable across calls");
    }

    #[test]
    fn population_fold_is_byte_identical_serial_vs_sharded() {
        let serial = run_population(&tiny_population(4, 1)).unwrap();
        let sharded = run_population(&tiny_population(4, 4)).unwrap();
        let a = population_json(&fold_population(&serial));
        let b = population_json(&fold_population(&sharded));
        assert_eq!(a, b, "thread count must not leak into the fleet fold");
        assert!(a.starts_with("{\"rows\":["));
        assert!(a.contains("\"scheme\":\"baseline\""));
        assert!(a.contains("\"p999_ms\""));
        let csv = population_csv(&fold_population(&serial));
        assert!(csv.starts_with("scheme,mix,"));
        assert_eq!(csv.lines().count(), 3, "header + one row per cell");
    }

    #[test]
    fn fault_schedules_are_paired_deterministic_and_rate_scaled() {
        let mut spec = tiny_population(8, 1);
        spec.fault_rate = 1.0;
        let profiles = spec.profiles();
        assert!(
            profiles.iter().all(|p| p.fault.kind != FaultKind::None),
            "rate 1.0 faults every device"
        );
        // both failure modes appear over 8 devices on a multi-plane base
        let kinds: Vec<FaultKind> = profiles.iter().map(|p| p.fault.kind).collect();
        assert!(kinds.contains(&FaultKind::PlaneLoss), "plane-loss scheduled");
        assert!(kinds.contains(&FaultKind::Slowdown), "slowdown scheduled");
        // the skew axis cycles like the capacity/OP/wear axes
        let mut skews: Vec<u64> = profiles.iter().map(|p| (p.skew * 100.0) as u64).collect();
        skews.sort_unstable();
        skews.dedup();
        assert!(skews.len() >= 2, "workload-skew classes vary");
        // paired comparisons: the schedule is a pure function of
        // (population seed, device) — the scheme axis must not move it
        let mut one = spec.clone();
        one.schemes = vec![Scheme::TlcOnly];
        assert_eq!(profiles, one.profiles(), "faults identical across schemes");
        assert_eq!(profiles, spec.profiles(), "stable across calls");
        // rate 0 leaves the whole population healthy
        spec.fault_rate = 0.0;
        assert!(spec.profiles().iter().all(|p| p.fault.kind == FaultKind::None));
        // every scheduled fault yields a valid device config (plane
        // index in range, onset in [0,1], multiplier sane)
        spec.fault_rate = 0.5;
        for p in spec.profiles() {
            spec.device_config(Scheme::Ips, MixKind::AggressorVictims, &p).unwrap();
        }
    }

    #[test]
    fn faulted_streaming_fold_matches_collected_fold_byte_for_byte() {
        let mut serial = tiny_population(4, 1);
        serial.fault_rate = 1.0;
        let mut sharded = serial.clone();
        sharded.threads = 4;
        // reference: the collect-then-fold path on one thread
        let runs = run_population(&serial).unwrap();
        let reference = population_json(&fold_population(&runs));
        let (c1, csv1, st1) = run_population_streaming(&serial).unwrap();
        let (c4, csv4, st4) = run_population_streaming(&sharded).unwrap();
        assert_eq!(population_json(&c1), reference, "streaming fold == collected fold");
        assert_eq!(population_json(&c4), reference, "thread count must not leak");
        assert_eq!(csv1, csv4, "per-device row stream is order-deterministic");
        assert_eq!(st1.runs, 8, "2 schemes × 4 devices");
        // bounded memory: the high-water is per-worker, never the population
        assert_eq!(st1.peak_resident_runs, 1, "serial streams one run at a time");
        assert!(st4.peak_resident_runs <= 4, "≤ one resident run per worker");
        // the healthy/faulted split is folded and exported
        assert!(reference.contains("\"devices_healthy\":0"));
        assert!(reference.contains("\"faulted_victim_p99_ms\""));
        for c in &c1 {
            assert_eq!(c.devices_healthy + c.devices_faulted, c.devices);
            assert_eq!(c.devices_faulted, 4, "rate 1.0 faults all of {}", c.scheme);
            assert!(c.victim_latency_faulted.count() > 0, "faulted victims folded");
            assert_eq!(c.victim_latency_healthy.count(), 0, "no healthy devices to fold");
        }
        let csv = population_csv(&c1);
        assert!(csv.lines().next().unwrap().contains("faulted_victim_p99_ms"));
        assert!(csv1.starts_with(DEVICE_CSV_HEADER));
        assert_eq!(csv1.lines().count(), 9, "header + one row per device run");
        // every streamed row reports a fired fault
        for row in csv1.lines().skip(1) {
            assert!(row.contains("plane-loss") || row.contains("slowdown"), "{row}");
        }
    }

    #[test]
    fn mixed_fleet_folds_healthy_and_faulted_separately() {
        // hand-build a mixed population from two paired specs so the
        // healthy/faulted split itself (not the rate hash) is under test
        let mut healthy = tiny_population(2, 1);
        healthy.schemes = vec![Scheme::Ips];
        let mut faulted = healthy.clone();
        faulted.fault_rate = 1.0;
        let mut runs = run_population(&healthy).unwrap();
        runs.extend(run_population(&faulted).unwrap());
        let cells = fold_population(&runs);
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.devices, 4);
        assert_eq!(c.devices_healthy, 2);
        assert_eq!(c.devices_faulted, 2);
        assert!(c.victim_latency_healthy.count() > 0);
        assert!(c.victim_latency_faulted.count() > 0);
        let both = c.victim_latency_healthy.count() + c.victim_latency_faulted.count();
        assert_eq!(both, c.victim_latency.count(), "split partitions the victim fold");
    }

    #[test]
    fn fleet_path_has_no_raw_vectors_and_bounded_percentiles() {
        let runs = run_population(&tiny_population(2, 2)).unwrap();
        assert_eq!(runs.len(), 4, "2 schemes × 2 devices");
        for r in &runs {
            assert!(r.summary.write_latency.raw_us().is_empty(), "no raw on the fleet path");
            for t in &r.summary.tenants {
                assert!(t.write_latency.raw_us().is_empty());
                assert!(t.read_latency.raw_us().is_empty());
            }
        }
        let cells = fold_population(&runs);
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert_eq!(c.devices, 2);
            assert!(c.write_latency.count() > 0, "{} folded traffic", c.scheme);
            assert!(c.victim_latency.count() > 0, "victim tenants folded");
            for q in [0.5, 0.99, 0.999, 1.0] {
                assert!(
                    c.write_latency.percentile(q) <= c.write_latency.max(),
                    "{} q={q}: percentile bounded by observed max",
                    c.scheme
                );
            }
            assert!(
                c.victim_latency.percentile(0.999) >= c.victim_latency.percentile(0.99),
                "tail quantiles are monotone"
            );
        }
        let rendered = population_table(&cells).render();
        assert!(rendered.contains("victim_p999_ms"));
        let detail = device_table(&runs).render();
        assert!(detail.contains("pre_age"));
    }
}
