//! Wall-clock perf harness: the victim index vs the linear-scan oracle
//! (`ips perf --compare victim-index`, `benches/fig_perf.rs` →
//! `BENCH_PR4.json`), the interconnect timing model vs the plane lump
//! (`--compare interconnect` → `BENCH_PR5.json`), and the hot-path
//! data-structure pass — flat bucket indices, SoA plane arenas,
//! incremental attribution, batched dispatch — vs its four oracles
//! (`--compare structures`, the default → `BENCH_PR9.json`, including
//! a blocks-per-plane × channel-count scaling sweep).
//!
//! Each cell runs the *same* (preset, scheme, scenario, trace) twice —
//! once with `sim.victim_index = false` (the historical scan backend)
//! and once with the incremental bucket index — and reports simulated
//! host pages per wall-clock second for both, the speedup, and whether
//! the two runs produced **identical** simulation results (ledger,
//! latencies, WA, simulated end time, raw latency samples). The
//! identity check is the differential guarantee riding along with every
//! measurement: a speedup that changes a single metric is a bug, not a
//! win.
//!
//! The headline cell is GC-heavy high-utilization bursty on
//! [`crate::config::presets::large`]: the write volume is a multiple of
//! the *logical* capacity, so the run overwrites its whole footprint
//! and inline GC pops victims continuously from ~1k-block closed lists
//! — exactly where the scan paid O(closed) per pop and the index pays
//! O(1). The daily scenario adds the AGC idle loop, whose no-victim
//! sweeps cost O(planes × closed) per idle step under the scan.
//!
//! Output is hand-rolled JSON (the crate is dependency-free) written to
//! `BENCH_PR4.json`; wall-clock fields are measurements, not goldens —
//! the committed perf trajectory is the *file format plus harness*, and
//! CI's `perf-smoke` job regenerates and uploads the numbers per run.

use super::fleet;
use crate::config::{presets, Config, Scheme, SEC};
use crate::metrics::RunSummary;
use crate::sim::Simulator;
use crate::trace::scenario::{self, Scenario};
use crate::{Error, Result};
use std::time::Duration;

/// One (preset, scheme, scenario) measurement: scan vs index.
#[derive(Clone, Debug)]
pub struct PerfCell {
    /// Preset name.
    pub preset: String,
    /// Scheme name.
    pub scheme: &'static str,
    /// Scenario name.
    pub scenario: &'static str,
    /// Simulated host pages each run served (identical in both).
    pub host_pages: u64,
    /// Wall clock of the linear-scan run.
    pub scan_wall: Duration,
    /// Wall clock of the index run.
    pub index_wall: Duration,
    /// Did both runs produce identical simulation results?
    pub identical: bool,
}

impl PerfCell {
    /// Simulated host pages per wall-clock second, scan backend.
    pub fn ops_scan(&self) -> f64 {
        self.host_pages as f64 / self.scan_wall.as_secs_f64().max(1e-9)
    }
    /// Simulated host pages per wall-clock second, index backend.
    pub fn ops_index(&self) -> f64 {
        self.host_pages as f64 / self.index_wall.as_secs_f64().max(1e-9)
    }
    /// Index speedup over the scan (ops/sec ratio).
    pub fn speedup(&self) -> f64 {
        self.scan_wall.as_secs_f64() / self.index_wall.as_secs_f64().max(1e-9)
    }
}

/// Resolve a perf preset by name.
pub fn preset_by_name(name: &str) -> Result<Config> {
    match name.to_ascii_lowercase().as_str() {
        "small" => Ok(presets::small()),
        "medium" | "bench-medium" => Ok(presets::bench_medium()),
        "large" => Ok(presets::large()),
        "table1" => Ok(presets::table1()),
        other => Err(Error::config(format!(
            "unknown perf preset {other:?} (want small|medium|large|table1)"
        ))),
    }
}

/// Are two runs of the same cell byte-identical in every simulation
/// metric? (Wall clock is the only field allowed to differ.)
pub fn summaries_identical(a: &RunSummary, b: &RunSummary) -> bool {
    a.ledger == b.ledger
        && a.sim_end == b.sim_end
        && a.host_bytes_written == b.host_bytes_written
        && a.write_latency.count() == b.write_latency.count()
        && a.write_latency.mean().to_bits() == b.write_latency.mean().to_bits()
        && a.write_latency.max() == b.write_latency.max()
        && a.write_latency.percentile(0.50) == b.write_latency.percentile(0.50)
        && a.write_latency.percentile(0.99) == b.write_latency.percentile(0.99)
        && a.write_latency.raw_us() == b.write_latency.raw_us()
        && a.read_latency.count() == b.read_latency.count()
        && a.read_latency.mean().to_bits() == b.read_latency.mean().to_bits()
}

/// Build the cell's trace. Bursty: one sequential burst of
/// `volume_mult ×` the logical capacity (wrapping ⇒ full-footprint
/// overwrites ⇒ sustained inline GC). Daily: the same volume split into
/// 8 streams with 30 s idle gaps, so idle-time reclamation/AGC runs.
fn cell_trace(scen: Scenario, logical_bytes: u64, volume_mult: f64) -> crate::trace::Trace {
    let volume = ((logical_bytes as f64 * volume_mult) as u64).max(1 << 20);
    match scen {
        Scenario::Bursty => scenario::sequential_fill("perf-burst", volume, logical_bytes),
        Scenario::Daily => scenario::daily_streams(8, volume / 8, 30 * SEC, logical_bytes),
    }
}

/// Run one (scheme, scenario) cell on `base`: scan first, then index,
/// identical traces and seeds. `Err` only on simulation failure — a
/// *result divergence* is reported via [`PerfCell::identical`] so the
/// caller decides how loudly to fail.
pub fn run_cell(
    preset: &str,
    base: &Config,
    scheme: Scheme,
    scen: Scenario,
    volume_mult: f64,
) -> Result<PerfCell> {
    let mut runs: Vec<RunSummary> = Vec::with_capacity(2);
    for use_index in [false, true] {
        let mut cfg = base.clone();
        cfg.cache.scheme = scheme;
        cfg.sim.victim_index = use_index;
        // timing runs measure the hot path, not the end-of-run audit;
        // the identity check below is the correctness gate
        cfg.sim.verify = false;
        let mut sim = Simulator::new(cfg)?;
        let trace = cell_trace(scen, sim.logical_bytes(), volume_mult);
        runs.push(sim.run(&trace, scen)?);
    }
    let (scan, index) = (&runs[0], &runs[1]);
    Ok(PerfCell {
        preset: preset.to_string(),
        scheme: scheme.name(),
        scenario: scen.name(),
        host_pages: index.ledger.host_pages,
        scan_wall: scan.wall_clock,
        index_wall: index.wall_clock,
        identical: summaries_identical(scan, index),
    })
}

/// Run the full perf matrix: `schemes × scenarios` on one preset.
pub fn run_matrix(
    preset: &str,
    base: &Config,
    schemes: &[Scheme],
    scenarios: &[Scenario],
    volume_mult: f64,
) -> Result<Vec<PerfCell>> {
    let mut cells = Vec::with_capacity(schemes.len() * scenarios.len());
    for &scheme in schemes {
        for &scen in scenarios {
            cells.push(run_cell(preset, base, scheme, scen, volume_mult)?);
        }
    }
    Ok(cells)
}

// --- timing-model comparison (BENCH_PR5) ---------------------------

/// One (preset, scheme, scenario) measurement: lump vs interconnect.
///
/// Unlike the victim-index cells this is NOT a differential — the two
/// backends model different hardware, so simulated results legitimately
/// diverge (that divergence is the feature). The record captures the
/// interconnect model's wall-clock overhead (host pages per second on
/// both backends) plus the simulated-time ratio, the "how much
/// contention was invisible before" headline.
#[derive(Clone, Debug)]
pub struct TimingCell {
    /// Preset name.
    pub preset: String,
    /// Scheme name.
    pub scheme: &'static str,
    /// Scenario name.
    pub scenario: &'static str,
    /// Simulated host pages served (identical offered load).
    pub host_pages: u64,
    /// Wall clock of the plane-lump run.
    pub lump_wall: Duration,
    /// Wall clock of the interconnect run.
    pub ic_wall: Duration,
    /// Simulated end time under the lump.
    pub lump_sim_end: u64,
    /// Simulated end time under the interconnect model.
    pub ic_sim_end: u64,
}

impl TimingCell {
    /// Simulated host pages per wall-clock second, lump backend.
    pub fn ops_lump(&self) -> f64 {
        self.host_pages as f64 / self.lump_wall.as_secs_f64().max(1e-9)
    }
    /// Simulated host pages per wall-clock second, interconnect.
    pub fn ops_ic(&self) -> f64 {
        self.host_pages as f64 / self.ic_wall.as_secs_f64().max(1e-9)
    }
    /// Wall-clock overhead of the interconnect model (>1 = slower).
    pub fn overhead(&self) -> f64 {
        self.ic_wall.as_secs_f64() / self.lump_wall.as_secs_f64().max(1e-9)
    }
    /// Simulated-time ratio (>1 = the lump was hiding contention).
    pub fn sim_end_ratio(&self) -> f64 {
        self.ic_sim_end as f64 / (self.lump_sim_end as f64).max(1e-9)
    }
}

/// Run one (scheme, scenario) cell on `base` twice — plane-lump, then
/// interconnect — over the identical trace and seed.
pub fn run_timing_cell(
    preset: &str,
    base: &Config,
    scheme: Scheme,
    scen: Scenario,
    volume_mult: f64,
) -> Result<TimingCell> {
    let mut runs: Vec<RunSummary> = Vec::with_capacity(2);
    for use_interconnect in [false, true] {
        let mut cfg = base.clone();
        cfg.cache.scheme = scheme;
        cfg.sim.interconnect = use_interconnect;
        cfg.sim.verify = false;
        let mut sim = Simulator::new(cfg)?;
        let trace = cell_trace(scen, sim.logical_bytes(), volume_mult);
        runs.push(sim.run(&trace, scen)?);
    }
    let (lump, ic) = (&runs[0], &runs[1]);
    Ok(TimingCell {
        preset: preset.to_string(),
        scheme: scheme.name(),
        scenario: scen.name(),
        host_pages: ic.ledger.host_pages,
        lump_wall: lump.wall_clock,
        ic_wall: ic.wall_clock,
        lump_sim_end: lump.sim_end,
        ic_sim_end: ic.sim_end,
    })
}

/// Run the timing-model matrix: `schemes × scenarios` on one preset.
pub fn run_timing_matrix(
    preset: &str,
    base: &Config,
    schemes: &[Scheme],
    scenarios: &[Scenario],
    volume_mult: f64,
) -> Result<Vec<TimingCell>> {
    let mut cells = Vec::with_capacity(schemes.len() * scenarios.len());
    for &scheme in schemes {
        for &scen in scenarios {
            cells.push(run_timing_cell(preset, base, scheme, scen, volume_mult)?);
        }
    }
    Ok(cells)
}

/// Serialize timing cells as the `BENCH_PR5.json` trajectory record.
pub fn timing_json(cells: &[TimingCell]) -> String {
    let mut out = String::from(
        "{\"bench\":\"BENCH_PR5\",\"unit\":\"host pages per wall-clock second\",\"rows\":[\n",
    );
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "{{\"preset\":\"{}\",\"scheme\":\"{}\",\"scenario\":\"{}\",\"host_pages\":{},\
             \"lump_ms\":{:.3},\"ic_ms\":{:.3},\"ops_lump\":{:.0},\"ops_ic\":{:.0},\
             \"overhead\":{:.3},\"sim_end_ratio\":{:.4}}}",
            c.preset,
            c.scheme,
            c.scenario,
            c.host_pages,
            c.lump_wall.as_secs_f64() * 1e3,
            c.ic_wall.as_secs_f64() * 1e3,
            c.ops_lump(),
            c.ops_ic(),
            c.overhead(),
            c.sim_end_ratio(),
        ));
    }
    out.push_str("\n]}\n");
    out
}

// --- hot-path structures comparison (BENCH_PR9) --------------------

/// Set the four hot-path data-structure knobs together (§Perf pass #2):
/// flat bucket indices, SoA plane arenas, incremental attribution and
/// batched dispatch. `false` selects every historical oracle structure
/// (BTreeSet buckets, inline per-block vectors, snapshot-diff
/// attribution, per-iteration dispatch allocation).
fn set_struct_knobs(cfg: &mut Config, on: bool) {
    cfg.sim.flat_index = on;
    cfg.sim.soa_blocks = on;
    cfg.sim.incremental_attribution = on;
    cfg.sim.batched_dispatch = on;
}

/// One (preset, scheme, scenario) measurement: oracle structures vs
/// the flat/SoA/incremental/batched hot-path structures. Like the
/// victim-index cells this IS a differential — both runs must produce
/// byte-identical simulation results; the four knobs only change data
/// layout and bookkeeping strategy, never behaviour.
#[derive(Clone, Debug)]
pub struct StructCell {
    /// Preset name.
    pub preset: String,
    /// Scheme name.
    pub scheme: &'static str,
    /// Scenario name.
    pub scenario: &'static str,
    /// Simulated host pages each run served (identical in both).
    pub host_pages: u64,
    /// Wall clock of the oracle-structures run.
    pub oracle_wall: Duration,
    /// Wall clock of the flat/SoA/incremental/batched run.
    pub new_wall: Duration,
    /// Did both runs produce identical simulation results?
    pub identical: bool,
}

impl StructCell {
    /// Simulated host pages per wall-clock second, oracle structures.
    pub fn ops_oracle(&self) -> f64 {
        self.host_pages as f64 / self.oracle_wall.as_secs_f64().max(1e-9)
    }
    /// Simulated host pages per wall-clock second, new structures.
    pub fn ops_new(&self) -> f64 {
        self.host_pages as f64 / self.new_wall.as_secs_f64().max(1e-9)
    }
    /// New-structures speedup over the oracles (ops/sec ratio).
    pub fn speedup(&self) -> f64 {
        self.oracle_wall.as_secs_f64() / self.new_wall.as_secs_f64().max(1e-9)
    }
}

/// Run one (scheme, scenario) cell on `base`: oracle structures first,
/// then the new hot-path structures, identical traces and seeds. `Err`
/// only on simulation failure — a *result divergence* is reported via
/// [`StructCell::identical`] so the caller decides how loudly to fail.
pub fn run_struct_cell(
    preset: &str,
    base: &Config,
    scheme: Scheme,
    scen: Scenario,
    volume_mult: f64,
) -> Result<StructCell> {
    let mut runs: Vec<RunSummary> = Vec::with_capacity(2);
    for use_new in [false, true] {
        let mut cfg = base.clone();
        cfg.cache.scheme = scheme;
        set_struct_knobs(&mut cfg, use_new);
        cfg.sim.verify = false;
        let mut sim = Simulator::new(cfg)?;
        let trace = cell_trace(scen, sim.logical_bytes(), volume_mult);
        runs.push(sim.run(&trace, scen)?);
    }
    let (oracle, new) = (&runs[0], &runs[1]);
    Ok(StructCell {
        preset: preset.to_string(),
        scheme: scheme.name(),
        scenario: scen.name(),
        host_pages: new.ledger.host_pages,
        oracle_wall: oracle.wall_clock,
        new_wall: new.wall_clock,
        identical: summaries_identical(oracle, new),
    })
}

/// Run the structures matrix: `schemes × scenarios` on one preset.
pub fn run_struct_matrix(
    preset: &str,
    base: &Config,
    schemes: &[Scheme],
    scenarios: &[Scenario],
    volume_mult: f64,
) -> Result<Vec<StructCell>> {
    let mut cells = Vec::with_capacity(schemes.len() * scenarios.len());
    for &scheme in schemes {
        for &scen in scenarios {
            cells.push(run_struct_cell(preset, base, scheme, scen, volume_mult)?);
        }
    }
    Ok(cells)
}

/// One point of the blocks-per-plane × channel-count scaling sweep:
/// the same oracle-vs-new differential on a resized geometry. The
/// offered volume tracks logical capacity (via `cell_trace`), so
/// host-pages/sec is comparable across points and the per-axis trend
/// shows where each oracle structure's O(blocks)/O(planes) cost bites.
#[derive(Clone, Debug)]
pub struct ScalePoint {
    /// Blocks per plane at this point.
    pub blocks_per_plane: u32,
    /// Channel count at this point.
    pub channels: u32,
    /// Simulated host pages each run served (identical in both).
    pub host_pages: u64,
    /// Wall clock of the oracle-structures run.
    pub oracle_wall: Duration,
    /// Wall clock of the flat/SoA/incremental/batched run.
    pub new_wall: Duration,
    /// Did both runs produce identical simulation results?
    pub identical: bool,
}

impl ScalePoint {
    /// Simulated host pages per wall-clock second, oracle structures.
    pub fn ops_oracle(&self) -> f64 {
        self.host_pages as f64 / self.oracle_wall.as_secs_f64().max(1e-9)
    }
    /// Simulated host pages per wall-clock second, new structures.
    pub fn ops_new(&self) -> f64 {
        self.host_pages as f64 / self.new_wall.as_secs_f64().max(1e-9)
    }
    /// New-structures speedup over the oracles (ops/sec ratio).
    pub fn speedup(&self) -> f64 {
        self.oracle_wall.as_secs_f64() / self.new_wall.as_secs_f64().max(1e-9)
    }
}

/// Run the scaling sweep: every `blocks_per_plane × channels` grid
/// point gets one oracle-vs-new cell on `base` with the geometry
/// resized (cache bytes and everything else held fixed — growing the
/// array only loosens the cache-fraction validation).
pub fn run_scaling_sweep(
    base: &Config,
    scheme: Scheme,
    scen: Scenario,
    volume_mult: f64,
    blocks_per_plane: &[u32],
    channels: &[u32],
) -> Result<Vec<ScalePoint>> {
    let mut pts = Vec::with_capacity(blocks_per_plane.len() * channels.len());
    for &bpp in blocks_per_plane {
        for &ch in channels {
            let mut runs: Vec<RunSummary> = Vec::with_capacity(2);
            for use_new in [false, true] {
                let mut cfg = base.clone();
                cfg.cache.scheme = scheme;
                cfg.geometry.blocks_per_plane = bpp;
                cfg.geometry.channels = ch;
                set_struct_knobs(&mut cfg, use_new);
                cfg.sim.verify = false;
                let mut sim = Simulator::new(cfg)?;
                let trace = cell_trace(scen, sim.logical_bytes(), volume_mult);
                runs.push(sim.run(&trace, scen)?);
            }
            let (oracle, new) = (&runs[0], &runs[1]);
            pts.push(ScalePoint {
                blocks_per_plane: bpp,
                channels: ch,
                host_pages: new.ledger.host_pages,
                oracle_wall: oracle.wall_clock,
                new_wall: new.wall_clock,
                identical: summaries_identical(oracle, new),
            });
        }
    }
    Ok(pts)
}

/// Serialize structure cells plus the scaling sweep as the
/// `BENCH_PR9.json` trajectory record. Deterministic field order;
/// wall-clock values are measurements, not goldens.
pub fn structures_json(cells: &[StructCell], sweep: &[ScalePoint]) -> String {
    let mut out = String::from(
        "{\"bench\":\"BENCH_PR9\",\"unit\":\"host pages per wall-clock second\",\"rows\":[\n",
    );
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "{{\"preset\":\"{}\",\"scheme\":\"{}\",\"scenario\":\"{}\",\"host_pages\":{},\
             \"oracle_ms\":{:.3},\"new_ms\":{:.3},\"ops_oracle\":{:.0},\"ops_new\":{:.0},\
             \"speedup\":{:.3},\"identical\":{}}}",
            c.preset,
            c.scheme,
            c.scenario,
            c.host_pages,
            c.oracle_wall.as_secs_f64() * 1e3,
            c.new_wall.as_secs_f64() * 1e3,
            c.ops_oracle(),
            c.ops_new(),
            c.speedup(),
            c.identical,
        ));
    }
    out.push_str("\n],\"scaling\":[\n");
    for (i, p) in sweep.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "{{\"blocks_per_plane\":{},\"channels\":{},\"host_pages\":{},\
             \"oracle_ms\":{:.3},\"new_ms\":{:.3},\"ops_oracle\":{:.0},\"ops_new\":{:.0},\
             \"speedup\":{:.3},\"identical\":{}}}",
            p.blocks_per_plane,
            p.channels,
            p.host_pages,
            p.oracle_wall.as_secs_f64() * 1e3,
            p.new_wall.as_secs_f64() * 1e3,
            p.ops_oracle(),
            p.ops_new(),
            p.speedup(),
            p.identical,
        ));
    }
    out.push_str("\n]}\n");
    out
}

/// Serialize a fleet sweep's wall-clock/peak-RSS datapoint as the
/// `BENCH_PR10.json` trajectory record — the rack-scale number ROADMAP
/// open item 1 calls for. The shape fields (`devices`, axes, threads,
/// `streaming_traces`) are deterministic; `wall_s`, `runs_per_s`, and
/// `peak_rss_kb` are measurements, which is why this record lives
/// beside the bench artifacts and never inside the golden-gated
/// table/JSON/CSV outputs.
pub fn fleet_stream_json(spec: &fleet::PopulationSpec, stats: &fleet::StreamStats) -> String {
    let wall_s = stats.wall_clock.as_secs_f64();
    let runs_per_s = if wall_s > 0.0 { stats.runs as f64 / wall_s } else { 0.0 };
    format!(
        "{{\"bench\":\"BENCH_PR10\",\"unit\":\"device runs per wall-clock second\",\
         \"devices\":{},\"runs\":{},\"schemes\":{},\"mixes\":{},\"tenants\":{},\
         \"scenario\":\"{}\",\"fault_rate\":{:.3},\"threads\":{},\"streaming_traces\":{},\
         \"peak_resident_runs\":{},\"wall_s\":{:.3},\"runs_per_s\":{:.1},\"peak_rss_kb\":{}}}\n",
        spec.devices,
        stats.runs,
        spec.schemes.len(),
        spec.mixes.len(),
        spec.base.host.tenants,
        spec.scenario.name(),
        spec.fault_rate,
        spec.threads,
        spec.base.sim.streaming_traces,
        stats.peak_resident_runs,
        wall_s,
        runs_per_s,
        stats.peak_rss_kb,
    )
}

/// Serialize cells as the `BENCH_PR4.json` perf-trajectory record.
/// Deterministic field order; wall-clock values are measurements.
pub fn perf_json(cells: &[PerfCell]) -> String {
    let mut out = String::from(
        "{\"bench\":\"BENCH_PR4\",\"unit\":\"host pages per wall-clock second\",\"rows\":[\n",
    );
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "{{\"preset\":\"{}\",\"scheme\":\"{}\",\"scenario\":\"{}\",\"host_pages\":{},\
             \"scan_ms\":{:.3},\"index_ms\":{:.3},\"ops_scan\":{:.0},\"ops_index\":{:.0},\
             \"speedup\":{:.3},\"identical\":{}}}",
            c.preset,
            c.scheme,
            c.scenario,
            c.host_pages,
            c.scan_wall.as_secs_f64() * 1e3,
            c.index_wall.as_secs_f64() * 1e3,
            c.ops_scan(),
            c.ops_index(),
            c.speedup(),
            c.identical,
        ));
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_reject() {
        assert!(preset_by_name("small").is_ok());
        assert!(preset_by_name("medium").is_ok());
        assert!(preset_by_name("large").is_ok());
        assert!(preset_by_name("wat").is_err());
    }

    #[test]
    fn fleet_stream_json_records_the_datapoint() {
        use crate::config::MixKind;
        let spec = fleet::PopulationSpec {
            base: presets::small(),
            devices: 3,
            schemes: vec![Scheme::Ips],
            mixes: vec![MixKind::AggressorVictims],
            scenario: Scenario::Bursty,
            fault_rate: 0.5,
            seed: 1,
            threads: 2,
        };
        let stats = fleet::StreamStats {
            peak_resident_runs: 2,
            runs: 3,
            wall_clock: Duration::from_millis(1500),
            peak_rss_kb: 2048,
        };
        let json = fleet_stream_json(&spec, &stats);
        assert!(json.contains("\"bench\":\"BENCH_PR10\""));
        assert!(json.contains("\"devices\":3"));
        assert!(json.contains("\"streaming_traces\":true"));
        assert!(json.contains("\"wall_s\":1.500"));
        assert!(json.contains("\"runs_per_s\":2.0"));
        assert!(json.contains("\"peak_rss_kb\":2048"));
        assert!(json.trim_end().ends_with('}'));
    }

    #[test]
    fn one_cell_runs_and_is_identical() {
        // the smallest meaningful cell: GC-heavy bursty on the small
        // preset, TLC-only (pure FTL/GC path, no cache scheme noise)
        let base = presets::small();
        let cell = run_cell("small", &base, Scheme::TlcOnly, Scenario::Bursty, 1.2).unwrap();
        assert!(cell.host_pages > 0);
        assert!(cell.identical, "scan and index runs must agree on every metric");
        assert!(cell.speedup() > 0.0);
        let json = perf_json(&[cell]);
        assert!(json.contains("\"scheme\":\"tlc-only\""));
        assert!(json.contains("\"identical\":true"));
        assert!(json.trim_end().ends_with("]}"));
    }

    #[test]
    fn daily_cell_exercises_idle_work_identically() {
        let base = presets::small();
        let cell = run_cell("small", &base, Scheme::IpsAgc, Scenario::Daily, 0.5).unwrap();
        assert!(cell.identical, "AGC idle loop must make the same picks on both backends");
    }

    #[test]
    fn struct_cell_runs_and_is_identical() {
        // full IPS scheme: exercises flat index, SoA arenas (cache
        // blocks reprogram in place), incremental attribution and
        // batched dispatch against all four oracles at once
        let base = presets::small();
        let cell = run_struct_cell("small", &base, Scheme::Ips, Scenario::Bursty, 1.2).unwrap();
        assert!(cell.host_pages > 0);
        assert!(cell.identical, "oracle and new structures must agree on every metric");
        assert!(cell.speedup() > 0.0);
    }

    #[test]
    fn scaling_sweep_covers_the_grid_identically() {
        let base = presets::small();
        let g = &base.geometry;
        let pts = run_scaling_sweep(
            &base,
            Scheme::TlcOnly,
            Scenario::Bursty,
            1.0,
            &[g.blocks_per_plane, g.blocks_per_plane * 2],
            &[g.channels],
        )
        .unwrap();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert!(p.host_pages > 0);
            assert!(p.identical, "{}x{} diverged", p.blocks_per_plane, p.channels);
        }
        // doubling blocks doubles capacity, so the offered volume (and
        // served pages) must grow with the geometry
        assert!(pts[1].host_pages > pts[0].host_pages);
        let json = structures_json(&[], &pts);
        assert!(json.contains("\"bench\":\"BENCH_PR9\""));
        assert!(json.contains("\"scaling\":["));
        assert!(json.contains("\"blocks_per_plane\""));
        assert!(json.trim_end().ends_with("]}"));
    }

    #[test]
    fn timing_cell_shows_the_contention_the_lump_hid() {
        // small geometry has 2 planes/die and a 10 µs bus: the
        // interconnect run must serve the same offered load in MORE
        // simulated time (die exclusivity + bus transfers), never less
        let base = presets::small();
        let cell =
            run_timing_cell("small", &base, Scheme::TlcOnly, Scenario::Bursty, 1.0).unwrap();
        assert!(cell.host_pages > 0);
        assert!(
            cell.sim_end_ratio() >= 1.0,
            "added contention cannot shrink simulated time: {}",
            cell.sim_end_ratio()
        );
        let json = timing_json(&[cell]);
        assert!(json.contains("\"bench\":\"BENCH_PR5\""));
        assert!(json.contains("\"sim_end_ratio\""));
        assert!(json.trim_end().ends_with("]}"));
    }
}
