//! Minimal leveled stderr logger honouring `IPS_LOG`
//! (`error|warn|info|debug|trace`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity levels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but continuing.
    Warn = 1,
    /// Progress messages (default).
    Info = 2,
    /// Per-experiment detail.
    Debug = 3,
    /// Per-event firehose.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn current() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let parsed = match std::env::var("IPS_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the log level programmatically (tests, benches).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is `level` enabled?
pub fn enabled(level: Level) -> bool {
    (level as u8) <= current()
}

/// Log a preformatted message at `level`.
pub fn log(level: Level, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {msg}");
    }
}

/// `info!`-style macros.
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}
/// Warn-level log macro.
#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}
/// Debug-level log macro.
#[macro_export]
macro_rules! debug_log {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
