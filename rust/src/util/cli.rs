//! Declarative command-line parsing (offline stand-in for `clap`).
//!
//! Supports subcommands, long/short flags, options with values
//! (`--opt v`, `--opt=v`), repeated options, positional arguments, and
//! generated `--help` text.
//!
//! ```no_run
//! use ips::util::cli::{Command, Parsed};
//! let cmd = Command::new("demo", "demo tool")
//!     .flag("verbose", Some('v'), "chatty output")
//!     .opt("seed", None, "SEED", "rng seed", Some("42"));
//! let parsed = cmd.parse_from(vec!["--verbose".into(), "--seed=7".into()]).unwrap();
//! assert!(parsed.flag("verbose"));
//! assert_eq!(parsed.get_u64("seed").unwrap(), 7);
//! ```

use std::collections::BTreeMap;

/// Specification of one option/flag.
#[derive(Clone, Debug)]
struct Spec {
    name: &'static str,
    short: Option<char>,
    value_name: Option<&'static str>, // None => boolean flag
    help: &'static str,
    default: Option<&'static str>,
}

/// A (sub)command specification.
#[derive(Clone, Debug)]
pub struct Command {
    name: &'static str,
    about: &'static str,
    specs: Vec<Spec>,
    positionals: Vec<(&'static str, &'static str, bool)>, // (name, help, required)
    subs: Vec<Command>,
}

/// Parse result: values keyed by option name.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    /// Which subcommand matched (path of names), if any.
    pub subcommand: Option<&'static str>,
    /// Nested parse result for the subcommand.
    sub: Option<Box<Parsed>>,
    flags: BTreeMap<&'static str, bool>,
    values: BTreeMap<&'static str, Vec<String>>,
    positionals: BTreeMap<&'static str, String>,
}

/// CLI parsing error with a user-facing message.
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Command {
    /// New command with a name and a one-line description.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, specs: Vec::new(), positionals: Vec::new(), subs: Vec::new() }
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &'static str, short: Option<char>, help: &'static str) -> Self {
        self.specs.push(Spec { name, short, value_name: None, help, default: None });
        self
    }

    /// Add an option that takes a value, with an optional default.
    pub fn opt(
        mut self,
        name: &'static str,
        short: Option<char>,
        value_name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.specs.push(Spec { name, short, value_name: Some(value_name), help, default });
        self
    }

    /// Add a positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str, required: bool) -> Self {
        self.positionals.push((name, help, required));
        self
    }

    /// Add a subcommand.
    pub fn subcommand(mut self, sub: Command) -> Self {
        self.subs.push(sub);
        self
    }

    /// Render `--help` text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.subs.is_empty() {
            s.push_str(" <SUBCOMMAND>");
        }
        if !self.specs.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        for (p, _, req) in &self.positionals {
            if *req {
                s.push_str(&format!(" <{p}>"));
            } else {
                s.push_str(&format!(" [{p}]"));
            }
        }
        s.push('\n');
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h, _) in &self.positionals {
                s.push_str(&format!("  {p:<18} {h}\n"));
            }
        }
        if !self.specs.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for spec in &self.specs {
                let short = spec.short.map(|c| format!("-{c}, ")).unwrap_or_else(|| "    ".into());
                let long = match spec.value_name {
                    Some(v) => format!("--{} <{}>", spec.name, v),
                    None => format!("--{}", spec.name),
                };
                let def = spec.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
                s.push_str(&format!("  {short}{long:<28} {}{def}\n", spec.help));
            }
        }
        if !self.subs.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for sub in &self.subs {
                s.push_str(&format!("  {:<18} {}\n", sub.name, sub.about));
            }
        }
        s
    }

    /// Parse `std::env::args` (skipping argv0). Exits the process on
    /// `--help` or error — the binary-facing entry point.
    pub fn parse_or_exit(&self) -> Parsed {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(args) {
            Ok(p) => p,
            Err(CliError(msg)) => {
                if msg == "__help__" {
                    println!("{}", self.help());
                    std::process::exit(0);
                }
                eprintln!("error: {msg}\n\n{}", self.help());
                std::process::exit(2);
            }
        }
    }

    /// Parse an explicit argument vector.
    pub fn parse_from(&self, args: Vec<String>) -> std::result::Result<Parsed, CliError> {
        let mut parsed = Parsed::default();
        // seed defaults
        for spec in &self.specs {
            if let (Some(_), Some(d)) = (spec.value_name, spec.default) {
                parsed.values.insert(spec.name, vec![d.to_string()]);
            }
        }
        let mut pos_idx = 0usize;
        let mut i = 0usize;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(CliError("__help__".into()));
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key}")))?;
                match spec.value_name {
                    None => {
                        if inline_val.is_some() {
                            return Err(CliError(format!("flag --{key} takes no value")));
                        }
                        parsed.flags.insert(spec.name, true);
                    }
                    Some(_) => {
                        let v = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                args.get(i)
                                    .cloned()
                                    .ok_or_else(|| CliError(format!("--{key} needs a value")))?
                            }
                        };
                        // explicit value replaces the default; repeats accumulate
                        let entry = parsed.values.entry(spec.name).or_default();
                        if spec.default.map(|d| entry.len() == 1 && entry[0] == d).unwrap_or(false)
                        {
                            entry.clear();
                        }
                        entry.push(v);
                    }
                }
            } else if let Some(rest) = a.strip_prefix('-') {
                if rest.len() != 1 {
                    return Err(CliError(format!("unknown argument {a}")));
                }
                let c = rest.chars().next().unwrap();
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.short == Some(c))
                    .ok_or_else(|| CliError(format!("unknown option -{c}")))?;
                if spec.value_name.is_some() {
                    i += 1;
                    let v = args
                        .get(i)
                        .cloned()
                        .ok_or_else(|| CliError(format!("-{c} needs a value")))?;
                    parsed.values.entry(spec.name).or_default().push(v);
                } else {
                    parsed.flags.insert(spec.name, true);
                }
            } else if let Some(sub) = self.subs.iter().find(|s| s.name == a) {
                let rest = args[i + 1..].to_vec();
                let sub_parsed = sub.parse_from(rest)?;
                parsed.subcommand = Some(sub.name);
                parsed.sub = Some(Box::new(sub_parsed));
                return Ok(parsed);
            } else {
                // positional
                match self.positionals.get(pos_idx) {
                    Some((name, _, _)) => {
                        parsed.positionals.insert(name, a.clone());
                        pos_idx += 1;
                    }
                    None => return Err(CliError(format!("unexpected argument {a}"))),
                }
            }
            i += 1;
        }
        for (name, _, required) in &self.positionals {
            if *required && !parsed.positionals.contains_key(name) {
                return Err(CliError(format!("missing required argument <{name}>")));
            }
        }
        Ok(parsed)
    }
}

impl Parsed {
    /// Was the boolean flag given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
    /// Last value of an option (replaces repeats), if any.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }
    /// All values of a repeated option.
    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
    /// Positional argument value.
    pub fn pos(&self, name: &str) -> Option<&str> {
        self.positionals.get(name).map(|s| s.as_str())
    }
    /// Nested parse result of the matched subcommand.
    pub fn sub(&self) -> Option<&Parsed> {
        self.sub.as_deref()
    }
    /// Parse an option as `u64`.
    pub fn get_u64(&self, name: &str) -> std::result::Result<u64, CliError> {
        let v = self.get(name).ok_or_else(|| CliError(format!("--{name} missing")))?;
        v.parse().map_err(|_| CliError(format!("--{name}: expected integer, got {v:?}")))
    }
    /// Parse an option as `f64`.
    pub fn get_f64(&self, name: &str) -> std::result::Result<f64, CliError> {
        let v = self.get(name).ok_or_else(|| CliError(format!("--{name} missing")))?;
        v.parse().map_err(|_| CliError(format!("--{name}: expected float, got {v:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Command {
        Command::new("demo", "test tool")
            .flag("verbose", Some('v'), "chatty")
            .opt("seed", Some('s'), "SEED", "rng seed", Some("42"))
            .opt("fig", None, "N", "figure", None)
            .positional("input", "input file", false)
            .subcommand(Command::new("run", "run it").opt("n", None, "N", "count", Some("1")))
    }

    #[test]
    fn defaults_apply() {
        let p = demo().parse_from(vec![]).unwrap();
        assert_eq!(p.get_u64("seed").unwrap(), 42);
        assert!(!p.flag("verbose"));
        assert!(p.get("fig").is_none());
    }

    #[test]
    fn long_and_inline_forms() {
        let p = demo().parse_from(vec!["--seed".into(), "7".into()]).unwrap();
        assert_eq!(p.get_u64("seed").unwrap(), 7);
        let p = demo().parse_from(vec!["--seed=9".into()]).unwrap();
        assert_eq!(p.get_u64("seed").unwrap(), 9);
    }

    #[test]
    fn short_flags() {
        let p = demo().parse_from(vec!["-v".into(), "-s".into(), "5".into()]).unwrap();
        assert!(p.flag("verbose"));
        assert_eq!(p.get_u64("seed").unwrap(), 5);
    }

    #[test]
    fn subcommand_routing() {
        let p = demo().parse_from(vec!["run".into(), "--n".into(), "3".into()]).unwrap();
        assert_eq!(p.subcommand, Some("run"));
        assert_eq!(p.sub().unwrap().get_u64("n").unwrap(), 3);
    }

    #[test]
    fn positional_capture() {
        let p = demo().parse_from(vec!["file.txt".into()]).unwrap();
        assert_eq!(p.pos("input"), Some("file.txt"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(demo().parse_from(vec!["--nope".into()]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(demo().parse_from(vec!["--fig".into()]).is_err());
    }

    #[test]
    fn help_lists_everything() {
        let h = demo().help();
        assert!(h.contains("--seed"));
        assert!(h.contains("SUBCOMMANDS"));
        assert!(h.contains("run"));
    }
}
