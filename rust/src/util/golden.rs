//! Golden-file regression checks for the bench-smoke CI job.
//!
//! The simulator is deterministic, so a bench's summary rows (seeds,
//! latencies, WA, victim tails — everything except wall clock) admit a
//! **tolerance-free** comparison against a committed snapshot. Under
//! `IPS_BENCH_SMOKE=1` the fig benches serialize their fleet summaries
//! ([`crate::coordinator::fleet::summary_json`]) and call [`check`]:
//!
//! * snapshot exists and matches → silent pass;
//! * snapshot exists and differs → `Err` (the bench panics, CI fails) —
//!   attribution drift now breaks the build instead of silently
//!   shifting figures;
//! * snapshot missing → it is **bootstrapped**: the candidate is
//!   written and reported as `Created`, so the first smoke run on a
//!   fresh machine produces the files to commit;
//! * `IPS_GOLDEN_UPDATE=1` → rewrite unconditionally (`Updated`) — the
//!   blessing path after an intentional behaviour change.
//!
//! Snapshots live in `rust/benches/golden/*.json` (override the
//! directory with `IPS_GOLDEN_DIR`), resolved against
//! `CARGO_MANIFEST_DIR` so `cargo bench` works from any cwd.

use std::fs;
use std::path::PathBuf;

/// What [`check`] did.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GoldenOutcome {
    /// Snapshot existed and matched byte-for-byte.
    Match,
    /// No snapshot existed; the candidate was written (commit it).
    Created(PathBuf),
    /// `IPS_GOLDEN_UPDATE=1`: the snapshot was rewritten.
    Updated(PathBuf),
}

/// Directory the snapshots live in.
fn golden_dir() -> PathBuf {
    if let Ok(d) = std::env::var("IPS_GOLDEN_DIR") {
        return PathBuf::from(d);
    }
    let root = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    PathBuf::from(root).join("rust").join("benches").join("golden")
}

/// Compare `content` against the committed snapshot `<name>.json`.
/// Returns `Err(diff summary)` on a mismatch; see the module docs for
/// the bootstrap/update behaviour.
pub fn check(name: &str, content: &str) -> Result<GoldenOutcome, String> {
    let dir = golden_dir();
    let path = dir.join(format!("{name}.json"));
    let update = std::env::var("IPS_GOLDEN_UPDATE").map(|v| v == "1").unwrap_or(false);
    let write = |outcome: fn(PathBuf) -> GoldenOutcome| -> Result<GoldenOutcome, String> {
        fs::create_dir_all(&dir).map_err(|e| format!("golden {name}: mkdir: {e}"))?;
        fs::write(&path, content).map_err(|e| format!("golden {name}: write: {e}"))?;
        Ok(outcome(path.clone()))
    };
    if update {
        return write(GoldenOutcome::Updated);
    }
    match fs::read_to_string(&path) {
        Ok(want) => {
            if want == content {
                Ok(GoldenOutcome::Match)
            } else {
                Err(diff_summary(name, &want, content))
            }
        }
        Err(_) => write(GoldenOutcome::Created),
    }
}

/// Bench-side wrapper: run [`check`], print the outcome, and panic on
/// a mismatch (failing the smoke job). One call per bench keeps the
/// reporting wording in one place.
pub fn check_and_report(name: &str, content: &str) {
    match check(name, content) {
        Ok(GoldenOutcome::Match) => println!("golden {name}: OK"),
        Ok(GoldenOutcome::Created(p)) => {
            println!("golden {name}: bootstrapped {} — commit it", p.display());
        }
        Ok(GoldenOutcome::Updated(p)) => println!("golden {name}: updated {}", p.display()),
        Err(e) => panic!("{e}"),
    }
}

/// First differing line, for an actionable failure message.
fn diff_summary(name: &str, want: &str, got: &str) -> String {
    for (i, (w, g)) in want.lines().zip(got.lines()).enumerate() {
        if w != g {
            return format!(
                "golden {name}: mismatch at line {}:\n  committed: {w}\n  measured:  {g}\n\
                 (rerun with IPS_GOLDEN_UPDATE=1 to bless an intentional change)",
                i + 1
            );
        }
    }
    format!(
        "golden {name}: line count changed ({} committed vs {} measured)\n\
         (rerun with IPS_GOLDEN_UPDATE=1 to bless an intentional change)",
        want.lines().count(),
        got.lines().count()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialize the env-var dance: tests in one binary share the
    /// process environment.
    fn with_dir<R>(f: impl FnOnce() -> R) -> R {
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join(format!(
            "ips-golden-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&dir);
        std::env::set_var("IPS_GOLDEN_DIR", &dir);
        let r = f();
        std::env::remove_var("IPS_GOLDEN_DIR");
        let _ = fs::remove_dir_all(&dir);
        r
    }

    #[test]
    fn bootstrap_then_match_then_mismatch() {
        with_dir(|| {
            let created = check("smoke", "{\"rows\":[1]}\n").unwrap();
            assert!(matches!(created, GoldenOutcome::Created(_)), "{created:?}");
            assert_eq!(check("smoke", "{\"rows\":[1]}\n").unwrap(), GoldenOutcome::Match);
            let err = check("smoke", "{\"rows\":[2]}\n").unwrap_err();
            assert!(err.contains("mismatch at line 1"), "{err}");
            assert!(err.contains("IPS_GOLDEN_UPDATE"), "{err}");
        });
    }

    #[test]
    fn update_blesses_a_change() {
        with_dir(|| {
            check("bless", "old\n").unwrap();
            std::env::set_var("IPS_GOLDEN_UPDATE", "1");
            let updated = check("bless", "new\n").unwrap();
            std::env::remove_var("IPS_GOLDEN_UPDATE");
            assert!(matches!(updated, GoldenOutcome::Updated(_)));
            assert_eq!(check("bless", "new\n").unwrap(), GoldenOutcome::Match);
        });
    }

    #[test]
    fn line_count_change_is_reported() {
        with_dir(|| {
            check("lines", "a\nb\n").unwrap();
            let err = check("lines", "a\nb\nc\n").unwrap_err();
            assert!(err.contains("line count changed"), "{err}");
        });
    }
}
