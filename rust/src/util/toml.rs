//! TOML-subset parser for the config system (offline stand-in for
//! `toml` + `serde`).
//!
//! Supported: `[table]` / `[a.b]` headers, `key = value` with dotted
//! keys, strings (`"..."` with escapes), integers (with `_`
//! separators), floats, booleans, homogeneous arrays, `#` comments.
//! Unsupported on purpose (and rejected loudly): inline tables, arrays
//! of tables, multi-line strings, datetimes.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// UTF-8 string.
    Str(String),
    /// 64-bit signed integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous array.
    Array(Vec<Value>),
    /// Nested table.
    Table(Table),
}

/// A TOML table: ordered map from key to value.
pub type Table = BTreeMap<String, Value>;

/// Parse error with line number.
#[derive(Debug)]
pub struct TomlError {
    /// 1-based line number.
    pub line: usize,
    /// Human-readable message.
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for TomlError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, TomlError> {
    Err(TomlError { line, msg: msg.into() })
}

/// Parse a TOML document into a root [`Table`].
pub fn parse(src: &str) -> Result<Table, TomlError> {
    let mut root = Table::new();
    let mut current_path: Vec<String> = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            if line.starts_with("[[") {
                return err(lineno, "arrays of tables are not supported");
            }
            let inner = inner
                .strip_suffix(']')
                .ok_or(TomlError { line: lineno, msg: "unterminated table header".into() })?;
            current_path =
                split_key(inner, lineno)?.into_iter().map(|s| s.to_string()).collect();
            // materialize the table
            ensure_table(&mut root, &current_path, lineno)?;
            continue;
        }
        let eq = match find_unquoted(line, '=') {
            Some(i) => i,
            None => return err(lineno, format!("expected `key = value`, got {line:?}")),
        };
        let key_part = line[..eq].trim();
        let val_part = line[eq + 1..].trim();
        if key_part.is_empty() || val_part.is_empty() {
            return err(lineno, "empty key or value");
        }
        let mut path = current_path.clone();
        path.extend(split_key(key_part, lineno)?.into_iter().map(|s| s.to_string()));
        let value = parse_value(val_part, lineno)?;
        insert(&mut root, &path, value, lineno)?;
    }
    Ok(root)
}

/// Strip a `#` comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    match find_unquoted(line, '#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn find_unquoted(line: &str, target: char) -> Option<usize> {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else if c == '"' {
            in_str = true;
        } else if c == target {
            return Some(i);
        }
    }
    None
}

fn split_key(key: &str, lineno: usize) -> Result<Vec<&str>, TomlError> {
    let parts: Vec<&str> = key.split('.').map(|p| p.trim()).collect();
    if parts.iter().any(|p| p.is_empty()) {
        return err(lineno, format!("bad key {key:?}"));
    }
    for p in &parts {
        if !p.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
            return err(lineno, format!("bad key component {p:?} (quote keys are unsupported)"));
        }
    }
    Ok(parts)
}

fn ensure_table<'a>(
    root: &'a mut Table,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut Table, TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Value::Table(Table::new()));
        match entry {
            Value::Table(t) => cur = t,
            _ => return err(lineno, format!("{part:?} is not a table")),
        }
    }
    Ok(cur)
}

fn insert(root: &mut Table, path: &[String], value: Value, lineno: usize) -> Result<(), TomlError> {
    let (last, prefix) = path.split_last().expect("non-empty path");
    let table = ensure_table(root, prefix, lineno)?;
    if table.contains_key(last) {
        return err(lineno, format!("duplicate key {last:?}"));
    }
    table.insert(last.clone(), value);
    Ok(())
}

fn parse_value(s: &str, lineno: usize) -> Result<Value, TomlError> {
    if let Some(rest) = s.strip_prefix('"') {
        let mut out = String::new();
        let mut escaped = false;
        for (i, c) in rest.char_indices() {
            if escaped {
                out.push(match c {
                    'n' => '\n',
                    't' => '\t',
                    'r' => '\r',
                    '\\' => '\\',
                    '"' => '"',
                    _ => return err(lineno, format!("bad escape \\{c}")),
                });
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                if rest[i + 1..].trim().is_empty() {
                    return Ok(Value::Str(out));
                }
                return err(lineno, "trailing characters after string");
            } else {
                out.push(c);
            }
        }
        return err(lineno, "unterminated string");
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or(TomlError { line: lineno, msg: "unterminated array".into() })?;
        let mut vals = Vec::new();
        for item in split_array_items(inner) {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            vals.push(parse_value(item, lineno)?);
        }
        return Ok(Value::Array(vals));
    }
    if s == "{" || s.starts_with('{') {
        return err(lineno, "inline tables are not supported");
    }
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    err(lineno, format!("cannot parse value {s:?}"))
}

fn split_array_items(s: &str) -> Vec<&str> {
    // split on commas not inside strings or nested brackets
    let mut items = Vec::new();
    let mut depth = 0i32;
    let mut in_str = false;
    let mut escaped = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '[' => depth += 1,
            ']' => depth -= 1,
            ',' if depth == 0 => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&s[start..]);
    items
}

// ------------------------------------------------------------------
// Typed accessors used by the config layer.
// ------------------------------------------------------------------

/// Typed view over a parsed table with dotted-path lookups.
pub struct View<'a> {
    root: &'a Table,
}

impl<'a> View<'a> {
    /// Wrap a parsed root table.
    pub fn new(root: &'a Table) -> Self {
        View { root }
    }

    /// Look up `a.b.c`.
    pub fn lookup(&self, path: &str) -> Option<&'a Value> {
        let mut cur = self.root;
        let parts: Vec<&str> = path.split('.').collect();
        for (i, part) in parts.iter().enumerate() {
            let v = cur.get(*part)?;
            if i == parts.len() - 1 {
                return Some(v);
            }
            match v {
                Value::Table(t) => cur = t,
                _ => return None,
            }
        }
        None
    }

    /// `u64` at path, or default.
    pub fn u64_or(&self, path: &str, default: u64) -> u64 {
        match self.lookup(path) {
            Some(Value::Int(i)) if *i >= 0 => *i as u64,
            _ => default,
        }
    }

    /// `f64` at path (accepts int literals), or default.
    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        match self.lookup(path) {
            Some(Value::Float(f)) => *f,
            Some(Value::Int(i)) => *i as f64,
            _ => default,
        }
    }

    /// `bool` at path, or default.
    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        match self.lookup(path) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    /// String at path, or default.
    pub fn str_or(&self, path: &str, default: &str) -> String {
        match self.lookup(path) {
            Some(Value::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let t = parse(
            r#"
            # top comment
            title = "ips" # trailing comment
            seed = 1_000
            ratio = 0.75
            on = true

            [ssd.geometry]
            channels = 8
            chips = 4
            "#,
        )
        .unwrap();
        let v = View::new(&t);
        assert_eq!(v.str_or("title", ""), "ips");
        assert_eq!(v.u64_or("seed", 0), 1000);
        assert!((v.f64_or("ratio", 0.0) - 0.75).abs() < 1e-12);
        assert!(v.bool_or("on", false));
        assert_eq!(v.u64_or("ssd.geometry.channels", 0), 8);
        assert_eq!(v.u64_or("ssd.geometry.chips", 0), 4);
    }

    #[test]
    fn arrays() {
        let t = parse("sizes = [4, 8, 16]\nnames = [\"a\", \"b\"]").unwrap();
        match t.get("sizes").unwrap() {
            Value::Array(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn string_escapes() {
        let t = parse(r#"s = "a\nb\"c""#).unwrap();
        assert_eq!(t.get("s"), Some(&Value::Str("a\nb\"c".into())));
    }

    #[test]
    fn dotted_keys() {
        let t = parse("a.b.c = 3").unwrap();
        let v = View::new(&t);
        assert_eq!(v.u64_or("a.b.c", 0), 3);
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn garbage_rejected_with_line() {
        let e = parse("ok = 1\nwhat").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = parse(r##"s = "a#b""##).unwrap();
        assert_eq!(t.get("s"), Some(&Value::Str("a#b".into())));
    }

    #[test]
    fn inline_tables_rejected() {
        assert!(parse("a = { b = 1 }").is_err());
    }

    #[test]
    fn missing_paths_default() {
        let t = parse("x = 1").unwrap();
        let v = View::new(&t);
        assert_eq!(v.u64_or("nope.deep", 9), 9);
    }
}
