//! Property-based testing runner (offline stand-in for `proptest`).
//!
//! A property is a function from a generated input to `Result<(), String>`.
//! The runner draws `cases` random inputs; on the first failure it
//! greedily shrinks the input through the generator's `shrink` hook and
//! reports the minimal counterexample together with the seed that
//! reproduces it.
//!
//! ```no_run
//! use ips::util::prop::{self, Gen};
//! prop::check("addition commutes", 256, prop::tuple2(prop::u64_up_to(1000), prop::u64_up_to(1000)),
//!     |&(a, b)| if a + b == b + a { Ok(()) } else { Err("no".into()) });
//! ```

use crate::util::rng::Rng;

/// A generator of values of type `T` with shrinking.
pub trait Gen {
    /// Generated value type.
    type Value: Clone + std::fmt::Debug;
    /// Draw a random value.
    fn gen(&self, rng: &mut Rng) -> Self::Value;
    /// Propose strictly "smaller" candidates (default: none).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs; panics with a minimal
/// counterexample on failure. The seed comes from `IPS_PROP_SEED` if
/// set (for reproduction), else a fixed default so CI is deterministic.
pub fn check<G, F>(name: &str, cases: u32, gen: G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    let seed = std::env::var("IPS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA11CE5EED);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // shrink greedily
            let mut best = input;
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 1000 {
                improved = false;
                rounds += 1;
                for cand in gen.shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x})\n  \
                 minimal counterexample: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

// ------------------------------------------------------------------
// Primitive generators
// ------------------------------------------------------------------

/// Uniform `u64` in `[0, max]` with halving shrinks.
pub struct U64UpTo(pub u64);

/// Uniform u64 in `[0, max]`.
pub fn u64_up_to(max: u64) -> U64UpTo {
    U64UpTo(max)
}

impl Gen for U64UpTo {
    type Value = u64;
    fn gen(&self, rng: &mut Rng) -> u64 {
        rng.range(0, self.0)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > 0 {
            out.push(0);
            out.push(v / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform `usize` in `[lo, hi]`.
pub struct UsizeIn(pub usize, pub usize);

/// Uniform usize in `[lo, hi]`.
pub fn usize_in(lo: usize, hi: usize) -> UsizeIn {
    UsizeIn(lo, hi)
}

impl Gen for UsizeIn {
    type Value = usize;
    fn gen(&self, rng: &mut Rng) -> usize {
        rng.range(self.0 as u64, self.1 as u64) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// `f64` in `[lo, hi)`.
pub struct F64In(pub f64, pub f64);

/// Uniform f64 in `[lo, hi)`.
pub fn f64_in(lo: f64, hi: f64) -> F64In {
    F64In(lo, hi)
}

impl Gen for F64In {
    type Value = f64;
    fn gen(&self, rng: &mut Rng) -> f64 {
        self.0 + rng.f64() * (self.1 - self.0)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        if *v > self.0 {
            vec![self.0, self.0 + (v - self.0) / 2.0]
        } else {
            Vec::new()
        }
    }
}

/// Vector of values from an inner generator, with length and element shrinks.
pub struct VecOf<G> {
    inner: G,
    min_len: usize,
    max_len: usize,
}

/// Vector of `inner` values with length in `[min_len, max_len]`.
pub fn vec_of<G: Gen>(inner: G, min_len: usize, max_len: usize) -> VecOf<G> {
    VecOf { inner, min_len, max_len }
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn gen(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.range(self.min_len as u64, self.max_len as u64) as usize;
        (0..n).map(|_| self.inner.gen(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        // remove halves / single elements
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2.max(self.min_len)].to_vec());
            let mut minus_last = v.clone();
            minus_last.pop();
            out.push(minus_last);
            let mut minus_first = v.clone();
            minus_first.remove(0);
            out.push(minus_first);
        }
        // shrink one element
        for (i, e) in v.iter().enumerate().take(8) {
            for cand in self.inner.shrink(e) {
                let mut w = v.clone();
                w[i] = cand;
                out.push(w);
            }
        }
        out.retain(|w| w.len() >= self.min_len);
        out
    }
}

/// Pair of two generators.
pub struct Tuple2<A, B>(pub A, pub B);

/// Pair generator.
pub fn tuple2<A: Gen, B: Gen>(a: A, b: B) -> Tuple2<A, B> {
    Tuple2(a, b)
}

impl<A: Gen, B: Gen> Gen for Tuple2<A, B> {
    type Value = (A::Value, B::Value);
    fn gen(&self, rng: &mut Rng) -> Self::Value {
        (self.0.gen(rng), self.1.gen(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Choose uniformly from a fixed list of values.
pub struct OneOf<T: Clone + std::fmt::Debug>(pub Vec<T>);

/// Uniform choice from a list.
pub fn one_of<T: Clone + std::fmt::Debug>(items: Vec<T>) -> OneOf<T> {
    OneOf(items)
}

impl<T: Clone + std::fmt::Debug> Gen for OneOf<T> {
    type Value = T;
    fn gen(&self, rng: &mut Rng) -> T {
        rng.pick(&self.0).clone()
    }
    fn shrink(&self, v: &T) -> Vec<T>
    where
        T: Clone,
    {
        // shrink toward the first (assumed simplest) choice
        let first = self.0.first().cloned();
        match first {
            Some(f) if format!("{f:?}") != format!("{v:?}") => vec![f],
            _ => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum symmetric", 128, tuple2(u64_up_to(1000), u64_up_to(1000)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        check("all below 500", 512, u64_up_to(1000), |&x| {
            if x < 500 {
                Ok(())
            } else {
                Err(format!("{x} >= 500"))
            }
        });
    }

    #[test]
    fn shrinking_reaches_small_values() {
        // capture the panic message and check the counterexample is minimal-ish
        let result = std::panic::catch_unwind(|| {
            check("no big", 512, u64_up_to(1 << 40), |&x| {
                if x < 1024 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            })
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // greedy halving should land close to the 1024 boundary
        assert!(msg.contains("counterexample"), "{msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = vec_of(u64_up_to(10), 2, 5);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = g.gen(&mut rng);
            assert!((2..=5).contains(&v.len()));
        }
    }
}
