//! Process memory introspection (hand-rolled, Linux procfs).
//!
//! The fleet's rack-scale datapoint pairs wall clock with peak
//! resident set: `VmHWM` from `/proc/self/status` is the kernel's
//! high-water RSS for this process, which on the streaming path is
//! dominated by the simulators themselves rather than materialized
//! trace vectors. Like wall clock, it is a *measurement* — `ips fleet`
//! prints it and `BENCH_PR10.json` records it, and it is deliberately
//! excluded from every deterministic table/JSON/CSV output the golden
//! gates compare.

/// Peak resident-set size of this process in KiB (`VmHWM`), or `None`
/// off Linux or when procfs is unavailable.
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches("kB").trim().parse().ok();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_rss_reports_on_linux() {
        if cfg!(target_os = "linux") {
            let kb = peak_rss_kb().expect("VmHWM in /proc/self/status");
            assert!(kb > 0, "a running process has resident memory");
        } else {
            // elsewhere the probe degrades to None, never panics
            let _ = peak_rss_kb();
        }
    }
}
