//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** (Blackman & Vigna) seeded through SplitMix64, plus the
//! handful of distributions the synthetic trace generator needs. All
//! simulator randomness flows through [`Rng`] so runs are exactly
//! reproducible from a `u64` seed (recorded in every report).

/// xoshiro256** pseudo-random generator.
///
/// Fast (sub-ns per draw), passes BigCrush, and trivially serializable.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 step — used for seeding and as a cheap stateless hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Stateless 64-bit mix of two values (for derived sub-seeds).
#[inline]
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.rotate_left(32) ^ 0x6a09_e667_f3bc_c909;
    splitmix64(&mut s)
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a named sub-component.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(mix64(self.next_u64(), tag))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be non-zero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method (unbiased).
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive (full-range safe).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        lo + self.below(span + 1)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with mean `mean` (inter-arrival gaps).
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        // Inverse-CDF; clamp the uniform away from 0 to avoid inf.
        let u = self.f64().max(1e-18);
        -mean * u.ln()
    }

    /// Bounded Pareto draw in `[lo, hi]` with shape `alpha` (burst sizes).
    pub fn pareto(&mut self, lo: f64, hi: f64, alpha: f64) -> f64 {
        let u = self.f64();
        let la = lo.powf(alpha);
        let ha = hi.powf(alpha);
        let x = (-(u * (1.0 - la / ha)) + 1.0).powf(-1.0 / alpha) * lo;
        x.clamp(lo, hi)
    }

    /// Standard normal via Box–Muller (retention / noise models).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.f64().max(1e-18);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        mean + std * r * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Weighted index draw; `weights` need not be normalized.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= *w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf-distributed sampler over `[0, n)` with skew `theta` in `(0,1)`.
///
/// Uses the standard YCSB-style rejection-free approximation with
/// precomputed constants; draws are O(1).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipf {
    /// Build a sampler over `n` items with skew `theta` (0 = uniform-ish,
    /// 0.99 = highly skewed). `n` must be ≥ 1.
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n >= 1);
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Zipf { n, theta, alpha, zetan, eta, zeta2: zeta2 }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Exact for small n, Euler–Maclaurin tail for large n.
        let direct = n.min(10_000);
        let mut z = 0.0;
        for i in 1..=direct {
            z += 1.0 / (i as f64).powf(theta);
        }
        if n > direct {
            // integral approximation of the tail
            let a = direct as f64;
            let b = n as f64;
            z += (b.powf(1.0 - theta) - a.powf(1.0 - theta)) / (1.0 - theta);
        }
        z
    }

    /// Draw an item rank in `[0, n)`; rank 0 is the hottest.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let _ = self.zeta2;
        let rank = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        rank.min(self.n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean_roughly_right() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(2.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
        assert!((var - 9.0).abs() < 0.5, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let z = Zipf::new(1000, 0.9);
        let mut r = Rng::new(5);
        let mut counts = vec![0u32; 1000];
        for _ in 0..50_000 {
            let x = z.sample(&mut r);
            assert!(x < 1000);
            counts[x as usize] += 1;
        }
        // hottest item should dominate the median item decisively
        assert!(counts[0] > 20 * counts[500].max(1));
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0u32; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > 5 * c[0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn pareto_bounded() {
        let mut r = Rng::new(33);
        for _ in 0..10_000 {
            let x = r.pareto(4.0, 64.0, 1.2);
            assert!((4.0..=64.0).contains(&x));
        }
    }
}
