//! Measurement harness for `benches/*` (offline stand-in for
//! `criterion`; used with `harness = false`).
//!
//! Provides wall-clock measurement with warmup, adaptive iteration
//! counts, robust statistics (mean / median / p95 / min), and a small
//! results table. Benchmarks register named closures; the harness can
//! filter them by the substring argument `cargo bench -- <filter>`.

use std::time::{Duration, Instant};

/// Statistics for one benchmark.
#[derive(Clone, Debug)]
pub struct Stats {
    /// Benchmark name.
    pub name: String,
    /// Number of timed iterations.
    pub iters: u32,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Median per-iteration time.
    pub median: Duration,
    /// 95th percentile per-iteration time.
    pub p95: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<u64>,
}

impl Stats {
    /// items/second if a throughput denominator was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n as f64 / self.mean.as_secs_f64())
    }
}

/// Format a duration compactly (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// The bench harness. Create one in `main`, `register` closures, `run`.
pub struct Harness {
    filter: Option<String>,
    /// Target time to spend measuring each benchmark.
    pub measure_time: Duration,
    /// Warmup time before measuring.
    pub warmup_time: Duration,
    /// Smoke mode (`IPS_BENCH_SMOKE=1`): run each benchmark exactly
    /// once with no warmup — CI uses this to catch bench bit-rot at PR
    /// time without paying for real measurements.
    pub smoke: bool,
    results: Vec<Stats>,
}

impl Default for Harness {
    fn default() -> Self {
        Self::new()
    }
}

impl Harness {
    /// Create a harness, reading the filter from `std::env::args` and
    /// time budgets from `IPS_BENCH_MEASURE_MS` / `IPS_BENCH_WARMUP_MS`.
    pub fn new() -> Self {
        // cargo bench passes "--bench"; anything else is a filter.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        let measure_ms = std::env::var("IPS_BENCH_MEASURE_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2_000u64);
        let warmup_ms = std::env::var("IPS_BENCH_WARMUP_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(300u64);
        let smoke = std::env::var("IPS_BENCH_SMOKE").map(|s| s == "1").unwrap_or(false);
        Harness {
            filter,
            measure_time: Duration::from_millis(measure_ms),
            warmup_time: Duration::from_millis(warmup_ms),
            smoke,
            results: Vec::new(),
        }
    }

    /// Should this benchmark run under the current filter?
    pub fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map(|f| name.contains(f)).unwrap_or(true)
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    /// `items` is the optional throughput denominator (e.g. host pages
    /// written per iteration).
    pub fn bench<F: FnMut()>(&mut self, name: &str, items: Option<u64>, mut f: F) {
        if !self.enabled(name) {
            return;
        }
        if self.smoke {
            // one timed run, no warmup: existence proof, not measurement
            let t0 = Instant::now();
            f();
            let d = t0.elapsed();
            let stats = Stats {
                name: name.to_string(),
                iters: 1,
                mean: d,
                median: d,
                p95: d,
                min: d,
                items_per_iter: items,
            };
            self.report_line(&stats);
            self.results.push(stats);
            return;
        }
        // Warmup and calibration: find how many iters fit the budget.
        let warm_start = Instant::now();
        let mut calib_iters = 0u32;
        while warm_start.elapsed() < self.warmup_time || calib_iters == 0 {
            f();
            calib_iters += 1;
            if calib_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed() / calib_iters.max(1);
        let target = self
            .measure_time
            .as_nanos()
            .checked_div(per_iter.as_nanos().max(1))
            .unwrap_or(1) as u32;
        let iters = target.clamp(5, 10_000);

        let mut samples: Vec<Duration> = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let total: Duration = samples.iter().sum();
        let stats = Stats {
            name: name.to_string(),
            iters,
            mean: total / iters,
            median: samples[samples.len() / 2],
            p95: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
            min: samples[0],
            items_per_iter: items,
        };
        self.report_line(&stats);
        self.results.push(stats);
    }

    fn report_line(&self, s: &Stats) {
        let tp = match s.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>9.2} M items/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>9.2} K items/s", t / 1e3),
            Some(t) => format!("  {t:>9.2} items/s"),
            None => String::new(),
        };
        println!(
            "{:<44} {:>10}/iter  (median {:>10}, p95 {:>10}, min {:>10}, n={}){}",
            s.name,
            fmt_duration(s.mean),
            fmt_duration(s.median),
            fmt_duration(s.p95),
            fmt_duration(s.min),
            s.iters,
            tp
        );
    }

    /// All collected stats.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Print a closing summary (called at the end of each bench binary).
    pub fn finish(&self) {
        println!("\n{} benchmark(s) complete.", self.results.len());
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut h = Harness {
            filter: None,
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            smoke: false,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        h.bench("noop-ish", Some(100), || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert_eq!(h.results().len(), 1);
        let s = &h.results()[0];
        assert!(s.iters >= 5);
        assert!(s.mean >= s.min);
        assert!(s.throughput().unwrap() > 0.0);
    }

    #[test]
    fn filter_skips() {
        let mut h = Harness {
            filter: Some("match-me".into()),
            measure_time: Duration::from_millis(5),
            warmup_time: Duration::from_millis(1),
            smoke: false,
            results: Vec::new(),
        };
        h.bench("other", None, || {});
        assert!(h.results().is_empty());
        h.bench("yes-match-me", None, || {});
        assert_eq!(h.results().len(), 1);
    }

    #[test]
    fn smoke_mode_runs_exactly_once() {
        let mut h = Harness {
            filter: None,
            measure_time: Duration::from_millis(5000),
            warmup_time: Duration::from_millis(5000),
            smoke: true,
            results: Vec::new(),
        };
        let mut calls = 0u32;
        h.bench("smoke", Some(1), || calls += 1);
        assert_eq!(calls, 1, "smoke mode never warms up or repeats");
        assert_eq!(h.results()[0].iters, 1);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(5)).ends_with("s"));
    }
}
