//! Plain-text table rendering and CSV emission for reports.

use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned text table.
#[derive(Clone, Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    /// New table with the given header.
    pub fn new(header: &[&str]) -> Self {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header arity; panics otherwise).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                // right-align numeric-looking cells, left-align text
                let c = &cells[i];
                let numeric = c.chars().next().map(|ch| ch.is_ascii_digit() || ch == '-' || ch == '+').unwrap_or(false)
                    && c.chars().all(|ch| ch.is_ascii_digit() || ".-+eE%x×".contains(ch));
                if numeric {
                    line.push_str(&format!("{c:>width$}", width = widths[i]));
                } else {
                    line.push_str(&format!("{c:<width$}", width = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Write the table as CSV to `path` (creating parent dirs).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            let escaped: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            writeln!(f, "{}", escaped.join(","))?;
        }
        Ok(())
    }
}

/// Format a byte count in binary units.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format nanoseconds compactly.
pub fn nanos(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Format a ratio like the paper does ("0.75x").
pub fn ratio(x: f64) -> String {
    format!("{x:.3}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(&["name", "value"]);
        t.row(vec!["alpha".into(), "1.5".into()]);
        t.row(vec!["bb".into(), "222.75".into()]);
        let s = t.render();
        assert!(s.contains("alpha"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "2".into()]);
        let dir = std::env::temp_dir().join("ips_fmt_test");
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"x,y\",2"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn byte_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(4096), "4.00 KiB");
        assert!(bytes(4u64 << 30).starts_with("4.00 GiB"));
    }

    #[test]
    fn nano_units() {
        assert_eq!(nanos(100), "100 ns");
        assert!(nanos(3_000_000).contains("ms"));
    }
}
