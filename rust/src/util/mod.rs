//! Self-contained utility substrates.
//!
//! The build environment is offline with only the `xla` crate closure
//! available, so everything a typical systems project pulls from
//! crates.io is implemented here from scratch:
//!
//! * [`rng`] — SplitMix64 seeding + xoshiro256** PRNG with the
//!   distributions the trace generator needs (uniform, zipf, pareto,
//!   exponential, normal).
//! * [`cli`] — a small declarative argument parser (flags, options,
//!   subcommands, `--help` generation).
//! * [`toml`] — a TOML-subset parser for the config system (tables,
//!   dotted keys, strings, ints, floats, bools, arrays, comments).
//! * [`prop`] — a property-based testing runner with generators and
//!   greedy shrinking (stand-in for `proptest`).
//! * [`bench`] — a measurement harness (warmup, adaptive iteration
//!   count, mean/median/p99, throughput) used by `benches/*` with
//!   `harness = false` (stand-in for `criterion`).
//! * [`fmt`] — plain-text table rendering + CSV writing for reports.
//! * [`golden`] — tolerance-free golden-file checks for the bench-smoke
//!   CI job (bootstraps missing snapshots, `IPS_GOLDEN_UPDATE=1` to
//!   bless intentional changes).
//! * [`logging`] — leveled stderr logger honouring `IPS_LOG`.
//! * [`mem`] — hand-rolled `/proc/self/status` peak-RSS probe for the
//!   fleet's wall-clock/peak-RSS datapoint.

pub mod bench;
pub mod cli;
pub mod fmt;
pub mod golden;
pub mod logging;
pub mod mem;
pub mod prop;
pub mod rng;
pub mod toml;
